"""Quickstart — the paper's Figure 1 program, in this framework.

Distributed SpMV with independent computation / format / distribution /
schedule descriptions. Runs on any machine (the distributed loop executes
through the single-process simulation backend here; on a pod the same
LoweredKernel drives shard_map — see examples/spmv_distributed.py).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import repro.core as rc
from repro.core import formats as F
from repro.core.schedule import CPUThread, Schedule
from repro.core.tdn import dist
from repro.core.tensor import Tensor

# --- Machine: 1-D grid of processors (Fig. 1 line 5) -----------------------
pieces = 4
M = rc.Machine(("x", pieces))

# --- Tensors + formats (Fig. 1 lines 12-22) --------------------------------
rng = np.random.default_rng(0)
n, m = 64, 48
dense_B = ((rng.random((n, m)) < 0.15) *
           rng.standard_normal((n, m))).astype(np.float32)

a = Tensor.zeros_dense("a", (n,))                      # BlockedDense
B = Tensor.from_dense("B", dense_B, F.CSR())           # BlockedCSR
c = Tensor.from_dense("c", rng.standard_normal(m)      # ReplDense
                      .astype(np.float32))

# data distributions (TDN): block a and B row-wise, replicate c
distributions = {
    "a": dist(a, "x -> x", M),
    "B": dist(B, "xy -> x", M),
    "c": dist(c, "x -> *", M),
}

# --- Computation (Fig. 1 line 26) ------------------------------------------
i, j = rc.index_vars("i j")
stmt = rc.Assignment(a(i), B(i, j) * c(j))

# --- Schedule (Fig. 1 lines 30-39) ------------------------------------------
io, ii = rc.index_vars("io ii")
s = (Schedule(stmt, M)
     .divide(i, io, ii, M.x)          # block i for each node
     .distribute(io)                  # each block on its own node
     .communicate([a, B, c], io)      # fetch sub-tensors per iteration
     .parallelize(ii, CPUThread))     # leaf parallelism

# --- Compile + run -----------------------------------------------------------
kernel = rc.lower_stmt(stmt, M, schedule=s, distributions=distributions)
y = kernel.run()

expected = dense_B @ np.asarray(c.to_dense())
print("leaf kernel:        ", kernel.leaf_name)
print("max |err| vs dense: ", float(np.abs(y - expected).max()))
print("row imbalance:      ", round(kernel.imbalance(), 3))
print("communication:      ", kernel.comm.as_dict())
assert np.allclose(y, expected, atol=1e-4)
print("OK — distributed SpMV matches the dense oracle")
