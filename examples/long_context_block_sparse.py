"""Block-sparse sliding-window attention from the paper's format machinery
(models/sparse_attention.py) — the long_500k path for full-attention archs.

Builds the banded block mask as a block-CSR core.Tensor, packs it ELL-style
(same layout as the TPU kernels), runs attention over only the listed
blocks, and validates against a dense masked reference.

    PYTHONPATH=src python examples/long_context_block_sparse.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sparse_attention import (band_plan, block_sparse_attention,
                                           mask_to_ell)

B, S, H, hd = 2, 1024, 4, 32
Q_BLOCK, WINDOW = 128, 256

mask = band_plan(S, Q_BLOCK, WINDOW)
print(f"block mask: {mask.shape[0]}x{mask.shape[1]} blocks, "
      f"{mask.nnz} present ({mask.nnz / mask.shape[0]**2:.1%} of dense)")
idx = mask_to_ell(mask)

key = jax.random.PRNGKey(0)
q, k, v = (jax.random.normal(kk, (B, S, H, hd), jnp.float32)
           for kk in jax.random.split(key, 3))
out = jax.jit(lambda q, k, v: block_sparse_attention(
    q, k, v, idx, Q_BLOCK, window=WINDOW))(q, k, v)

# dense reference with the same mask
scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / hd ** 0.5
pos = np.arange(S)
m = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - WINDOW)
ref = jnp.einsum("bhqk,bkhd->bqhd",
                 jax.nn.softmax(jnp.where(m[None, None], scores, -1e30), -1),
                 v)
err = float(jnp.abs(out - ref).max())
print(f"max |err| vs dense windowed reference: {err:.2e}")
assert err < 1e-4
print("OK — compute scales with S*window, not S^2")
