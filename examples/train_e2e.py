"""End-to-end training driver: a ~100M-param dense LM for a few hundred
steps with checkpoint/restart (deliverable b).

Defaults are sized for this single-CPU container (~10M params, 300 steps,
loss visibly decreasing on the structured synthetic corpus). Scale up with
--dmodel/--layers/--steps; on a pod the same Trainer shards over the
production mesh automatically.

    PYTHONPATH=src python examples/train_e2e.py --steps 300
"""
import argparse
import dataclasses

from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch.train import Trainer
from repro.runtime.fault import RestartPolicy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dmodel", type=int, default=256)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    cfg = ArchConfig(
        name="e2e-dense", family="dense", n_layers=args.layers,
        d_model=args.dmodel, n_heads=max(args.dmodel // 64, 1),
        n_kv_heads=max(args.dmodel // 128, 1), d_ff=args.dmodel * 4,
        vocab_size=8192, remat=False, dtype="float32")
    print(f"params ≈ {cfg.param_count()/1e6:.1f}M")
    shape = ShapeConfig("e2e", "train", seq_len=args.seq,
                        global_batch=args.batch)
    tr = Trainer(cfg, shape, ckpt_dir=args.ckpt, ckpt_every=100,
                 total_steps=args.steps, peak_lr=1e-3)
    RestartPolicy(max_restarts=2).run_with_restarts(
        lambda: tr.run(args.steps),
        on_restart=lambda n: print(f"[restart {n}]"))
    if not tr.metrics_log:
        print(f"checkpoint already at step {tr.step} ≥ {args.steps}; "
              f"nothing to train (use a fresh --ckpt dir to restart)")
        return
    first = tr.metrics_log[0]["loss"]
    last = tr.metrics_log[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
