"""The paper's technique inside the LM stack: MoE token routing as a
distributed sparse tensor computation (DESIGN.md §4).

The router's (token × expert) assignment is a sparse matrix with top-k
non-zeros per row. This example builds it as a core.Tensor, then compares
the two distribution strategies of paper §II-D on it:

- expert-major UNIVERSE partition (block of experts per device) — imbalance
  equals routing skew;
- coordinate-fused NON-ZERO partition (Fig. 5c) — balanced by construction;

and shows the same effect inside the real `models.moe` layer via its
capacity-drop counter.

    PYTHONPATH=src python examples/moe_sparse_dispatch.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core as rc
from repro.core import formats as F
from repro.core.partition import (partition_by_bounds,
                                  partition_tensor_nonzeros,
                                  partition_tensor_rows)
from repro.core.tensor import Tensor
from repro.models.moe import moe_apply, moe_init

E, TOPK, N, D = 16, 2, 4096, 64
pieces = 8
rng = np.random.default_rng(0)

# --- skewed router: zipf-popular experts (the realistic failure mode) -------
popularity = 1.0 / np.arange(1, E + 1) ** 1.2
popularity /= popularity.sum()
assign = np.stack([rng.choice(E, TOPK, replace=False, p=popularity)
                   for _ in range(N)])
coords = np.stack([np.repeat(np.arange(N), TOPK), assign.ravel()], 1)
routing = Tensor.from_coo("R", (N, E), coords,
                          np.ones(N * TOPK, np.float32),
                          F.CSC())  # expert-major: experts are the root level

# expert-major universe partition: block of experts per device
uni = partition_tensor_rows(routing, partition_by_bounds(E, pieces))
# coordinate-fused non-zero partition (paper Fig. 5c)
nnz = partition_tensor_nonzeros(routing, pieces)

print(f"router: {N} tokens x {E} experts, top-{TOPK}, zipf skew")
print(f"  expert-major universe partition imbalance: {uni.imbalance():.2f}")
print(f"  fused non-zero partition imbalance:        {nnz.imbalance():.2f}")

# --- the same skew inside the real MoE layer --------------------------------
params = moe_init(jax.random.PRNGKey(0), D, 4 * D, E)
x = jax.random.normal(jax.random.PRNGKey(1), (4, N // 4, D))
y, aux = jax.jit(lambda p, x: moe_apply(
    p, x, n_experts=E, top_k=TOPK, capacity_factor=1.25))(params, x)
print(f"moe layer out: {y.shape}, load-balance aux loss: {float(aux):.3f}")
print("OK")
