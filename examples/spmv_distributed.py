"""Row-based vs non-zero-based distributed SpMV (paper §II-D) on a skewed
matrix, including real shard_map SPMD execution when multiple devices are
available (run with XLA_FLAGS=--xla_force_host_platform_device_count=8 to
see the multi-device path on CPU).

    PYTHONPATH=src python examples/spmv_distributed.py
"""
import numpy as np

import repro.core as rc
from repro.core.lower import default_nnz_schedule, default_row_schedule, lower
from repro.core.tensor import Tensor
from repro.data.spdata import powerlaw_matrix

pieces = 8
M = rc.Machine(("x", pieces))

B = powerlaw_matrix("B", 4000, 4000, avg_nnz_per_row=12, seed=0)
c = Tensor.from_dense("c", np.random.default_rng(1)
                      .standard_normal(4000).astype(np.float32))
a = Tensor.zeros_dense("a", (4000,))
stmt = rc.parse_tin("a(i) = B(i,j) * c(j)", a=a, B=B, c=c)
expected = B.to_dense() @ np.asarray(c.to_dense())

for name, sched in [("row-based", default_row_schedule(stmt, M)),
                    ("nnz-based", default_nnz_schedule(stmt, M))]:
    k = lower(stmt, M, schedule=sched)
    y = k.run()
    assert np.allclose(y, expected, atol=1e-3)
    vb = k.plans["B"].vals_bounds
    counts = vb[:, 1] - vb[:, 0]
    print(f"{name:10s} leaf={k.leaf_name:10s} imbalance="
          f"{k.imbalance():5.2f} shard nnz: min={counts.min()} "
          f"max={counts.max()}  comm={k.comm.total_network_bytes()}B")

# --- real SPMD execution when the host exposes enough devices ---------------
import jax  # noqa: E402

if len(jax.devices()) >= pieces:
    from repro.distributed.executor import to_spmd
    from repro.distributed.mesh import machine_to_mesh

    mesh = machine_to_mesh(M)
    for name, sched in [("row-based", default_row_schedule(stmt, M)),
                        ("nnz-based", default_nnz_schedule(stmt, M))]:
        k = lower(stmt, M, schedule=sched)
        y = to_spmd(k, mesh)()
        assert np.allclose(y, expected, atol=1e-3)
        print(f"{name} via shard_map on {pieces} devices: OK")
else:
    print(f"(single device — rerun with XLA_FLAGS="
          f"--xla_force_host_platform_device_count={pieces} "
          f"for the shard_map path)")
