"""Batched sparse-attention decode through the serving fast path.

The sliding-window block mask (models/sparse_attention.band_plan) is a
sparse matrix that never changes between decode steps — exactly the shape
the serving fast path freezes: ``band_decode_kernel`` lowers it ONCE per
batch bucket (one plan, one CSR shard pack, one jitted runner), and every
step folds the live decode streams' per-kv-block summary vectors into a
single bucketized SpMM via ``run_many``. A ``SparseKernelServer`` then
drives the same kernel from a request queue, reporting p50/p99 latency
against an SLO.

    PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np

import repro.core as rc
from repro.core import formats as F
from repro.core.tensor import Tensor
from repro.launch.serve import SparseKernelServer
from repro.models.sparse_attention import band_decode_kernel, band_plan
from repro.runtime import telemetry

SEQ, Q_BLOCK, WINDOW = 2048, 64, 256
BATCH = 8
machine = rc.Machine(("x", 4))

mask = band_plan(SEQ, Q_BLOCK, WINDOW)
nq = mask.shape[0]
print(f"band mask: {nq}x{nq} blocks, {mask.nnz} present "
      f"({mask.nnz / nq**2:.1%} of dense)")

# --- batched decode: B streams -> one SpMM ---------------------------------
bk = band_decode_kernel(SEQ, Q_BLOCK, WINDOW, machine, batch=BATCH)
rng = np.random.default_rng(0)
streams = [rng.integers(-3, 4, nq).astype(np.float32) for _ in range(BATCH)]
outs = bk.run_many(streams)

dense_mask = mask.to_dense()
for v, y in zip(streams, outs):
    assert np.array_equal(np.asarray(y).ravel(), dense_mask @ v)
print(f"run_many: {BATCH} decode streams -> one SpMM, bit-for-bit vs "
      "dense reference")
print(bk.explain())

# --- the same kernel behind a request queue --------------------------------
stmt = rc.parse_tin("y(i) = attn_mask(i,j) * v(j)",
                    y=Tensor.zeros_dense("y", (nq,)),
                    attn_mask=mask,
                    v=Tensor.zeros_dense("v", (nq,)))
srv = SparseKernelServer(stmt, machine, max_batch=BATCH, slo_ms=100.0)
for wave in range(4):
    for v in streams:
        srv.submit(rng.permutation(v))
    srv.drain()
stats = srv.stats()
print(f"served {stats['served']} requests: p50={stats['p50_ms']:.2f}ms "
      f"p99={stats['p99_ms']:.2f}ms "
      f"SLO({stats['slo_ms']:.0f}ms) attainment={stats['slo_attainment']:.0%}")

snap = telemetry.METRICS.snapshot()
occ = snap.get("histograms", {}).get("serve.batch.occupancy", {})
if occ:
    print(f"batch occupancy: mean={occ['mean']:.2f} over {occ['count']} "
          "batches (1.0 = no padded slots)")
