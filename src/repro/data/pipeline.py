"""Deterministic, sharded, resumable data pipeline.

Production constraints at pod scale:

- each data-parallel shard reads ONLY its slice (no global shuffle traffic);
- the cursor (step counter + rng state) is part of the checkpoint, so a
  restore replays the exact batch sequence (fault tolerance);
- host→device transfer is double-buffered (prefetch thread) so input never
  serializes the step.

The token source here is a synthetic corpus (hash-mixed token ids with
document structure) — a real deployment swaps `TokenSource` for a file
reader with identical cursor semantics.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_tokens: int = 0
    d_model: int = 0          # for frontend embedding stubs


class TokenSource:
    """Deterministic synthetic corpus: batch i is a pure function of
    (seed, i) — restart-safe without any saved buffer."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int, shard: int = 0,
                 n_shards: int = 1) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        b = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, shard]))
        tokens = rng.integers(0, cfg.vocab_size,
                              size=(b, cfg.seq_len), dtype=np.int32)
        # inject document structure: BOS resets + short repeats so the loss
        # is learnable in the e2e example (not pure noise)
        bos = (rng.random((b, cfg.seq_len)) < 0.01)
        tokens = np.where(bos, 1, tokens)
        repeat = rng.random((b, cfg.seq_len)) < 0.3
        shifted = np.roll(tokens, 1, axis=1)
        tokens = np.where(repeat, shifted, tokens)
        out = {"tokens": tokens}
        if cfg.frontend_tokens:
            out["frontend"] = rng.standard_normal(
                (b, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)
        return out


class Pipeline:
    """Prefetching iterator with a checkpointable cursor."""

    def __init__(self, cfg: DataConfig, *, shard: int = 0, n_shards: int = 1,
                 prefetch: int = 2, start_step: int = 0):
        self.cfg = cfg
        self.source = TokenSource(cfg)
        self.shard, self.n_shards = shard, n_shards
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        s = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(s, self.shard, self.n_shards)
            try:
                self._q.put((s, batch), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def __next__(self) -> Dict[str, np.ndarray]:
        while True:
            s, batch = self._q.get()
            if s == self.step:      # drop stale prefetches after a restore
                self.step += 1
                return batch
            if s > self.step:       # worker ahead of a rewound cursor
                self._restart_worker()

    def _restart_worker(self) -> None:
        self._stop.set()
        self._thread.join()
        self._q = queue.Queue(maxsize=self._q.maxsize)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # -- checkpoint integration ----------------------------------------
    def cursor(self) -> Dict[str, int]:
        return {"step": self.step, "shard": self.shard,
                "n_shards": self.n_shards, "seed": self.cfg.seed}

    def restore(self, cursor: Dict[str, int]) -> None:
        assert cursor["seed"] == self.cfg.seed, "seed mismatch on restore"
        self.step = int(cursor["step"])
        self._restart_worker()

    def close(self) -> None:
        self._stop.set()
