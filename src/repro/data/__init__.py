from . import pipeline, spdata

__all__ = ["pipeline", "spdata"]
