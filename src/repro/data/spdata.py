"""Sparse matrix/tensor generators for benchmarks and tests.

Stand-ins for the paper's SuiteSparse / FROSTT / Freebase datasets
(Table II), matched on the structural properties that drive the paper's
results: skewed row degrees (power-law — web graphs like arabic-2005),
banded PDE matrices (nlpkkt240; also the weak-scaling matrix of Fig. 13),
and uniform random. All generators are deterministic in ``seed``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from ..core import formats as F
from ..core.tensor import Tensor


def uniform_sparse(name: str, shape: Tuple[int, ...], density: float,
                   seed: int = 0, fmt=None) -> Tensor:
    rng = np.random.default_rng(seed)
    nnz = max(int(np.prod([float(s) for s in shape]) * density), 1)
    coords = np.stack([rng.integers(0, s, nnz) for s in shape], axis=1)
    vals = rng.standard_normal(nnz).astype(np.float32)
    fmt = fmt or (F.CSR() if len(shape) == 2 else F.CSF(len(shape)))
    return Tensor.from_coo(name, shape, coords, vals, fmt)


def powerlaw_matrix(name: str, n: int, m: int, avg_nnz_per_row: int = 16,
                    alpha: float = 1.6, seed: int = 0) -> Tensor:
    """Zipf-distributed row degrees — the load-imbalance regime where the
    paper's non-zero partitions beat universe partitions (§II-D)."""
    rng = np.random.default_rng(seed)
    raw = rng.zipf(alpha, size=n).astype(np.float64)
    deg = np.minimum(np.maximum(
        (raw / raw.mean() * avg_nnz_per_row).astype(np.int64), 1), m)
    rows = np.repeat(np.arange(n, dtype=np.int64), deg)
    cols = rng.integers(0, m, size=rows.shape[0])
    vals = rng.standard_normal(rows.shape[0]).astype(np.float32)
    return Tensor.from_coo(name, (n, m),
                           np.stack([rows, cols], 1), vals, F.CSR())


def banded_matrix(name: str, n: int, bandwidth: int = 5,
                  seed: int = 0) -> Tensor:
    """The weak-scaling matrix of paper Fig. 13 (synthetic banded)."""
    rng = np.random.default_rng(seed)
    offs = np.arange(-bandwidth, bandwidth + 1)
    rows = np.repeat(np.arange(n, dtype=np.int64), offs.shape[0])
    cols = rows + np.tile(offs, n)
    keep = (cols >= 0) & (cols < n)
    rows, cols = rows[keep], cols[keep]
    vals = rng.standard_normal(rows.shape[0]).astype(np.float32)
    return Tensor.from_coo(name, (n, n),
                           np.stack([rows, cols], 1), vals, F.CSR())


def powerlaw_tensor3(name: str, dims: Tuple[int, int, int],
                     avg_nnz_per_slice: int = 64, alpha: float = 1.8,
                     seed: int = 0) -> Tensor:
    """FROSTT-like 3-tensor with skewed slice sizes."""
    rng = np.random.default_rng(seed)
    n = dims[0]
    raw = rng.zipf(alpha, size=n).astype(np.float64)
    deg = np.minimum(np.maximum(
        (raw / raw.mean() * avg_nnz_per_slice).astype(np.int64), 1),
        dims[1] * dims[2])
    i = np.repeat(np.arange(n, dtype=np.int64), deg)
    j = rng.integers(0, dims[1], size=i.shape[0])
    k = rng.integers(0, dims[2], size=i.shape[0])
    vals = rng.standard_normal(i.shape[0]).astype(np.float32)
    return Tensor.from_coo(name, dims, np.stack([i, j, k], 1), vals,
                           F.CSF(3))
