"""jax version compatibility shims.

The repo targets the `axis_types=` Mesh API (jax >= 0.5), but must also run
on the baked-in jax 0.4.x toolchain where ``jax.sharding.AxisType`` does not
exist yet. ``make_mesh_compat`` is the single Mesh constructor both
`distributed.mesh` and `launch.mesh` go through: it passes explicit Auto
axis types when the installed jax supports them and silently omits them
otherwise (0.4.x meshes are Auto-only, so the semantics are identical).
"""
from __future__ import annotations

from typing import Sequence

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType as _AxisType
except ImportError:  # jax 0.4.x
    _AxisType = None

if hasattr(jax, "shard_map"):          # jax >= 0.6 top-level API
    shard_map = jax.shard_map
else:                                  # jax 0.4.x/0.5.x experimental home
    from jax.experimental.shard_map import shard_map  # noqa: F401


def make_mesh_compat(shape: Sequence[int], axes: Sequence[str]):
    """`jax.make_mesh` with Auto axis types where the API exists."""
    shape = tuple(shape)
    axes = tuple(axes)
    if _AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(_AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)
