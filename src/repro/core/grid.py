"""Multi-axis (grid) distribution subsystem — 2-D and 3-D processor grids.

SpDISTAL's `distribute((i, k, …) → (x, y, …))` maps SEVERAL index
variables onto a multi-dimensional machine grid (the DISTAL machine
abstraction, paper §II-C / Fig. 4c), with communication planned per grid
axis:

- :class:`GridPlan` — the per-axis universe splits and the cross-product
  tile map: color ``(p, q)`` owns row window ``p`` × column window ``q``
  of the distributed sparse operand (block-aligned when it is blocked);
  order-3 grids add a third window axis — bricks ``(p, q, r)`` for
  order-3 operands, nested column splits (one loop variable divided onto
  two machine axes), and the REPLICATED 2.5-D schedules where the sparse
  operand keeps its (P, Q) tiles and the third axis splits a loop
  variable that does not index it.
- **Per-axis communication planning** (``grid_axis_bytes``): an operand
  is sliced by the machine axes its distributed index variables ride;
  along every OTHER axis it is broadcast, hierarchically in grid order
  (each broadcast multiplies the copies downstream axes must move).
  Output partials all-reduce along exactly the axes whose distributed
  variable is a reduction variable. This is SUMMA specialized to sparse
  operands — a 2-D SpMM at P×Q pieces moves ``|C|·(P−1) + |A|·(Q−1)``
  bytes versus 1-D's ``|C|·(PQ−1)`` — and, with replication, the
  communication-avoiding 2.5-D tradeoff: replicating B along ``z``
  costs ``|B|·(R−1)`` broadcast bytes but shrinks the output all-reduce
  from ``|A|·(QR−1)`` to ``|A|·(Q−1)``, a win whenever ``|A|·Q > |B|``.
- **Grid emitters**: the vmap simulation backend for SpMV / SpMM / SDDMM
  tiles (scalar and blocked), k-replicated SpMM / SDDMM, brick SpMTTKRP
  and nested-column SpAdd3 — reusing the same leaf kernels as the 1-D
  path. The SPMD analogs live in ``distributed/executor.py`` (builders
  over genuine ``Mesh((P, Q), ...)`` / ``Mesh((P, Q, R), ...)`` with
  ``psum`` scoped to exactly the reduction axes the schedule leaves).

Grid NON-ZERO schedules do not pass through here: a nested pos-split
canonicalizes to the flat equal split of the fused position space, so
``core.lower`` runs them through the 1-D nnz machinery at ``P*Q(*R)``
pieces (bit-for-bit their ``Px1`` counterparts) and only re-attributes
the communication to the axes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import lower as L
from ..runtime import telemetry
from .partition import (Bounds, ShardedTensor, TensorPartition,
                        block_aligned_row_bounds, materialize_bcsr_grid,
                        materialize_coo3_grid, materialize_csr_grid,
                        materialize_dense_cols, materialize_dense_grid,
                        materialize_dense_rows, materialize_replicated,
                        partition_by_bounds, partition_tensor_cols,
                        partition_tensor_grid, partition_tensor_grid3,
                        partition_tensor_rows, replicate_tensor)
from . import formats as F
from .schedule import DistStrategy
from .tdn import Machine
from .tensor import Tensor
from .tin import Assignment
from ..kernels import ref as K
from ..kernels.layout import pack_rowwindow_blocks


@dataclasses.dataclass
class GridPlan:
    """Per-axis splits + the cross-product tile map of a grid distribution.

    ``row_bounds`` (P, 2) splits the first distributed variable's universe,
    ``col_bounds`` (Q, 2) the second's; the flat color of tile ``(p, q)``
    is ``p * Q + q`` (row-major), the convention every grid shard set and
    emitter shares. Order-3 grids add ``dep_bounds`` (R, 2) — the third
    distributed variable's windows — with flat color ``(p*Q + q)*R + r``;
    ``nested`` marks plans whose column windows are the JOINT y×z split of
    one variable divided twice (``col_bounds`` then has Q·R windows);
    ``replicate`` carries the strategy's (tensor, axis) replication pairs
    (the replicated operand keeps 2-D (P, Q) tiles shared across z). Only
    universe strategies flow through a GridPlan — grid nnz schedules
    canonicalize to the flat 1-D split (module docstring)."""

    axis_x: str
    axis_y: str
    row_bounds: Bounds                # (P, 2) over extent(vars[0])
    col_bounds: Bounds                # (Q, 2) over extent(vars[1])
    axis_z: Optional[str] = None
    dep_bounds: Optional[Bounds] = None   # (R, 2) over extent(vars[2])
    replicate: Tuple[Tuple[str, str], ...] = ()
    nested: Optional[Tuple[int, int]] = None  # (Q, R) of a joint col split

    @property
    def P(self) -> int:
        return int(self.row_bounds.shape[0])

    @property
    def Q(self) -> int:
        return int(self.col_bounds.shape[0])

    @property
    def R(self) -> int:
        return 1 if self.dep_bounds is None else int(self.dep_bounds.shape[0])

    @property
    def pieces(self) -> int:
        return self.P * self.Q * self.R

    def tile_windows(self):
        """Yield ``(p, q, (rlo, rhi), (clo, chi))`` in flat-color order."""
        for p in range(self.P):
            for q in range(self.Q):
                yield (p, q,
                       (int(self.row_bounds[p, 0]), int(self.row_bounds[p, 1])),
                       (int(self.col_bounds[q, 0]), int(self.col_bounds[q, 1])))

    def tile_windows3(self):
        """Yield ``(p, q, r, rw, cw, dw)`` in flat-color order (3-D plans)."""
        for p in range(self.P):
            for q in range(self.Q):
                for r in range(self.R):
                    yield (p, q, r,
                           (int(self.row_bounds[p, 0]),
                            int(self.row_bounds[p, 1])),
                           (int(self.col_bounds[q, 0]),
                            int(self.col_bounds[q, 1])),
                           (int(self.dep_bounds[r, 0]),
                            int(self.dep_bounds[r, 1])))

    @staticmethod
    def _check_axis(bounds: Bounds, n: int, label: str) -> None:
        if bounds[0, 0] != 0 or bounds[-1, 1] != n:
            raise AssertionError(f"{label} windows do not span [0, {n})")
        for w in range(bounds.shape[0]):
            if bounds[w, 0] > bounds[w, 1]:
                raise AssertionError(f"negative {label} window {w}")
            if w and bounds[w, 0] != bounds[w - 1, 1]:
                raise AssertionError(
                    f"{label} windows {w - 1}/{w} overlap or gap")

    def validate(self, n_rows: int, n_cols: int,
                 n_dep: Optional[int] = None) -> None:
        """Tiling invariant: the grid tiles cover ``[0, n_rows) × [0,
        n_cols)`` (× ``[0, n_dep)`` for 3-D plans) exactly once — each
        axis's windows are sorted, disjoint, and gap-free."""
        self._check_axis(self.row_bounds, n_rows, "row")
        self._check_axis(self.col_bounds, n_cols, "col")
        if self.dep_bounds is not None:
            if n_dep is None:
                raise AssertionError(
                    "3-D plan validated without the third-axis extent")
            self._check_axis(self.dep_bounds, n_dep, "dep")

    def validate_coverage(self, part: TensorPartition,
                          shape: Tuple[int, ...]) -> None:
        """Per-operand coverage invariant, replication-aware: every
        dimension the partition windows must be tiled exactly once
        (sorted, disjoint, gap-free); a dimension with NO windows is
        replicated — every piece sees its full extent by construction —
        and legal only when the partition's color count divides the
        grid's (replica shards are shared across the leftover machine
        axes, not sliced by them). Applies to the window-structured grid
        partitions (tiles / bricks / dense grids / slices), whose levels
        follow dimension order."""
        for d, lp in enumerate(part.levels):
            if lp.coord_bounds is None:
                continue          # replicated / unsplit: full extent
            self._check_axis(lp.coord_bounds, shape[d], f"dim{d}")
        if part.pieces and self.pieces % part.pieces:
            raise AssertionError(
                f"operand colors ({part.pieces}) do not divide the machine "
                f"grid ({self.pieces}): replicas cannot be evenly shared")


def compute_grid_plan(stmt: Assignment, strat: DistStrategy) -> GridPlan:
    """Derive the per-axis universe splits for a grid universe strategy:
    equal splits of the distributed variables' extents, snapped to block
    boundaries when the distributed sparse operand is blocked (so every
    co-partitioned tensor shares the same per-color windows).

    Three-variable strategies dispatch on shape: three DISTINCT variables
    matching an order-3 sparse operand's leading dimensions → P×Q×R
    bricks; one variable divided onto two machine axes (vars ``(i, j,
    j)``) → nested column split (Q·R joint windows); otherwise the third
    variable does not index the sparse operand — a REPLICATED 2.5-D
    schedule, which must name the operand in ``strat.replicate``."""
    if not strat.is_grid or strat.space != "universe":
        raise ValueError("grid plan requires a multi-var universe strategy")
    if len(strat.vars) not in (2, 3):
        raise NotImplementedError(
            f"grid distribution supports 2 or 3 machine dimensions, got "
            f"{len(strat.vars)} distributed vars {strat.vars}")
    dx, dy = strat.machine_dims[0], strat.machine_dims[1]
    v0, v1 = strat.vars[0], strat.vars[1]
    spa = stmt.sparse_accesses()[0]
    Bt = spa.tensor
    n0, n1 = stmt.var_extent(v0), stmt.var_extent(v1)

    if len(strat.vars) == 3:
        dz, v2 = strat.machine_dims[2], strat.vars[2]
        if v1.name == v2.name:
            # nested column split: one variable rides both y and z — the
            # effective tiling is (P, Q·R), zero communication (spadd3)
            if tuple(spa.idx[:2]) != (v0, v1):
                raise NotImplementedError(
                    f"nested grid split must divide the sparse operand's "
                    f"leading variables, got ({v0}, {v1}) for {spa}")
            return GridPlan(
                axis_x=dx.name, axis_y=dy.name, axis_z=dz.name,
                row_bounds=partition_by_bounds(n0, dx.size),
                col_bounds=partition_by_bounds(n1, dy.size * dz.size),
                nested=(dy.size, dz.size))
        if len(spa.idx) >= 3 and tuple(spa.idx[:3]) == (v0, v1, v2):
            # order-3 bricks (spmttkrp)
            return GridPlan(
                axis_x=dx.name, axis_y=dy.name, axis_z=dz.name,
                row_bounds=partition_by_bounds(n0, dx.size),
                col_bounds=partition_by_bounds(n1, dy.size),
                dep_bounds=partition_by_bounds(stmt.var_extent(v2), dz.size))
        # replicated 2.5-D: v2 does not index the sparse operand — B keeps
        # its (P, Q) tiles, shared by every z-slice; replication must be
        # DECLARED, it is a schedule decision, not an inference
        if tuple(spa.idx[:2]) != (v0, v1):
            raise NotImplementedError(
                f"grid distribution must distribute the sparse operand's "
                f"first two index variables, got ({v0}, {v1}) for {spa}")
        rep = dict(strat.replicate)
        if rep.get(Bt.name) != dz.name:
            raise ValueError(
                f"3-var grid schedule: {v2} does not index the sparse "
                f"operand {Bt.name} — declare the replication explicitly "
                f"with .replicate([{Bt.name}], {dz.name})")
        if getattr(Bt.format, "is_blocked", False):
            raise NotImplementedError(
                "replicated 2.5-D schedules support scalar sparse formats")
        return GridPlan(
            axis_x=dx.name, axis_y=dy.name, axis_z=dz.name,
            row_bounds=partition_by_bounds(n0, dx.size),
            col_bounds=partition_by_bounds(n1, dy.size),
            dep_bounds=partition_by_bounds(stmt.var_extent(v2), dz.size),
            replicate=strat.replicate)

    if tuple(spa.idx[:2]) != (v0, v1):
        raise NotImplementedError(
            f"2-D grid distribution must distribute the sparse operand's "
            f"first two index variables, got ({v0}, {v1}) for {spa}")
    if getattr(Bt.format, "is_blocked", False):
        br, bc = Bt.format.block_shape
        row_bounds = block_aligned_row_bounds(n0, dx.size, br)
        col_bounds = block_aligned_row_bounds(n1, dy.size, bc)
    else:
        row_bounds = partition_by_bounds(n0, dx.size)
        col_bounds = partition_by_bounds(n1, dy.size)
    return GridPlan(axis_x=dx.name, axis_y=dy.name,
                    row_bounds=row_bounds, col_bounds=col_bounds)


def _var_dim_map(strat: DistStrategy) -> Dict[str, List[str]]:
    """Distributed variable name → the machine axes it rides (two axes for
    a nested divide)."""
    m: Dict[str, List[str]] = {}
    for v, d in zip(strat.vars, strat.machine_dims):
        m.setdefault(v.name, []).append(d.name)
    return m


def _sliced_dims(acc, strat: DistStrategy,
                 vdm: Dict[str, List[str]]) -> Set[str]:
    """Machine axes that SLICE this access — the communication key: along
    every other axis the operand is broadcast (shared by all colors of
    that axis). The distributed sparse operand is sliced by the axes of
    its matching leading variables; a dense operand by the axis of a
    distributed variable at position 0 (row windows, when dim 0 is the
    storage root) or position 1 (column windows, all-dense only)."""
    t = acc.tensor
    names = [v.name for v in acc.idx]
    vs = [v.name for v in strat.vars]
    if t.format.is_sparse and len(names) >= 2 and names[:2] == vs[:2]:
        sliced = set(vdm[names[0]]) | set(vdm[names[1]])
        if len(names) >= 3 and len(vs) >= 3 and names[2] == vs[2]:
            sliced |= set(vdm[names[2]])
        return sliced
    sliced: Set[str] = set()
    if names and names[0] in vdm and t.format.level_of_dim(0) == 0:
        sliced.add(vdm[names[0]][0])
    if len(names) > 1 and names[1] in vdm and t.format.is_all_dense:
        sliced.add(vdm[names[1]][-1])
    return sliced


def _axis_bounds(gp: GridPlan) -> Dict[str, Bounds]:
    b = {gp.axis_x: gp.row_bounds, gp.axis_y: gp.col_bounds}
    if gp.dep_bounds is not None:
        b[gp.axis_z] = gp.dep_bounds
    return b


def _grid_plans(stmt: Assignment, strat: DistStrategy, gp: GridPlan,
                ) -> Dict[str, TensorPartition]:
    """Fig. 9a steps 1 & 2 on a grid: the distributed sparse operand (and a
    sparse output sharing its index pattern) takes cross-product tiles /
    bricks; every other operand is sliced by whichever distributed
    variables index it — row windows, column windows, both (a dense
    grid), or neither (replication)."""
    vdm = _var_dim_map(strat)
    ab = _axis_bounds(gp)
    vs = [v.name for v in strat.vars]
    plans: Dict[str, TensorPartition] = {}
    for acc in stmt.accesses():
        t = acc.tensor
        if t.name in plans:
            continue
        names = [v.name for v in acc.idx]
        if t.format.is_sparse and len(names) >= 2 and names[:2] == vs[:2]:
            if (gp.dep_bounds is not None and not gp.replicate
                    and len(names) >= 3 and names[2] == vs[2]):
                plans[t.name] = partition_tensor_grid3(
                    t, gp.row_bounds, gp.col_bounds, gp.dep_bounds)
            else:
                # 2-D tiles: also the nested joint split (col_bounds is
                # the Q·R product) and the replicated operand's SHARED
                # (P, Q) tiling — the same partition, and therefore the
                # same SHARD_CACHE key, as the unreplicated 2-D plan
                plans[t.name] = partition_tensor_grid(
                    t, gp.row_bounds, gp.col_bounds)
            continue
        row_axis = col_axis = None
        if names and names[0] in vdm and t.format.level_of_dim(0) == 0:
            row_axis = vdm[names[0]][0]
        if len(names) > 1 and names[1] in vdm and t.format.is_all_dense:
            col_axis = vdm[names[1]][-1]
        if row_axis is not None and col_axis is not None:
            plans[t.name] = partition_tensor_grid(
                t, ab[row_axis], ab[col_axis])
        elif row_axis is not None:
            plans[t.name] = partition_tensor_rows(t, ab[row_axis])
        elif col_axis is not None:
            plans[t.name] = partition_tensor_cols(t, ab[col_axis])
        else:
            plans[t.name] = replicate_tensor(t, gp.pieces)
    return plans


def grid_axis_bytes(stmt: Assignment, strat: DistStrategy,
                    ) -> Dict[str, "L.AxisComm"]:
    """Per-axis byte formulas of a grid schedule, computed from the
    statement + strategy alone (no GridPlan / partitioning needed).

    Broadcast: walking the machine axes in grid order, an operand NOT
    sliced by an axis is broadcast along it; each such broadcast
    multiplies the copies every later broadcast axis must move (a fully
    replicated operand on a 2-D grid moves ``|t|`` along x, then ``P·|t|``
    along y — one copy per grid row). A replicated 2.5-D operand is
    sliced by x and y but not z, so it lands exactly ``|t|`` on z:
    network bytes ``|t|·(R−1)`` = payload × (replicas − 1).

    Reduce: output partials all-reduce along exactly the axes whose
    distributed variable is a reduction variable, hierarchically in grid
    order (spmttkrp bricks: ``|A|`` along y then ``Q·|A|`` along z).
    Replication REMOVES an axis from this set by splitting a
    non-reduction variable over it — the 2.5-D saving.

    This is both the ledger `lower_grid` records on the kernel and the
    estimator `core.plan_search` scores grid candidates with before
    committing to a plan."""
    dims = strat.machine_dims
    vdm = _var_dim_map(strat)
    out_name = stmt.lhs.tensor.name
    axes = {d.name: L.AxisComm(size=d.size) for d in dims}
    seen = set()
    for acc in stmt.accesses():
        t = acc.tensor
        if t.name in seen or t.name == out_name:
            continue
        seen.add(t.name)
        sliced = _sliced_dims(acc, strat, vdm)
        m = 1
        for d in dims:
            if d.name in sliced:
                continue
            axes[d.name].broadcast_bytes += m * L._nbytes(t)
            m *= d.size
    m = 1
    for d, v in zip(dims, strat.vars):
        if v in stmt.reduction_vars:
            axes[d.name].reduce_bytes += m * L._nbytes(stmt.lhs.tensor)
            m *= d.size
    return axes


def _grid_comm(stmt: Assignment, strat: DistStrategy,
               gp: GridPlan) -> L.CommStats:
    """Per-axis communication plan recorded on the kernel — the shared
    ``grid_axis_bytes`` formulas over the normalized statement (whose
    access tensors are exactly the planned tensors)."""
    comm = L.CommStats(pieces=gp.pieces)
    comm.axes = grid_axis_bytes(stmt, strat)
    return comm


# ---------------------------------------------------------------------------
# The grid lowering entry point (called from core.lower._lower_impl)
# ---------------------------------------------------------------------------

def lower_grid(stmt: Assignment, machine: Machine, strat: DistStrategy,
               jit: bool, fallbacks, declared_formats, snap,
               distributions=None) -> "L.LoweredKernel":
    out_t: Tensor = stmt.lhs.tensor
    with telemetry.span("lower.plan", sig=stmt.signature(),
                        space=strat.space, pieces=strat.pieces,
                        grid=list(strat.grid_shape)):
        gp = compute_grid_plan(stmt, strat)

        plan_key = L._plan_cache_key(stmt, strat, None)
        plans = L._PLAN_CACHE.get(plan_key) if plan_key is not None else None
        telemetry.instant("lower.plan.cache", hit=plans is not None,
                          memoizable=plan_key is not None)
        if plans is not None:
            current: Dict[str, Tensor] = {}
            for acc in stmt.accesses():
                current.setdefault(acc.tensor.name, acc.tensor)
            plans = {name: dataclasses.replace(p, tensor=current[name])
                     for name, p in plans.items()}
        else:
            plans = _grid_plans(stmt, strat, gp)
            if plan_key is not None:
                L._PLAN_CACHE.put(plan_key, {
                    name: dataclasses.replace(p, tensor=None)
                    for name, p in plans.items()})

    comm = _grid_comm(stmt, strat, gp)

    # ---- materialize ------------------------------------------------------
    shards: Dict[str, ShardedTensor] = {}
    with telemetry.span("lower.materialize", sig=stmt.signature(),
                        pieces=gp.pieces):
        for name, plan in plans.items():
            if name == out_t.name:
                continue                  # grid outputs assemble from leaves
            t = plan.tensor
            if plan.replicated:
                shards[name] = materialize_replicated(t, gp.pieces)
            elif plan.grid is not None and len(plan.grid) == 3:
                shards[name] = materialize_coo3_grid(t, plan)
            elif plan.grid is not None and t.format.is_sparse:
                shards[name] = (materialize_bcsr_grid(t, plan)
                                if t.format.is_blocked
                                else materialize_csr_grid(t, plan))
            elif plan.grid is not None:
                shards[name] = materialize_dense_grid(
                    t, plan.levels[0].coord_bounds,
                    plan.levels[1].coord_bounds)
            elif plan.root_coord_bounds is None:
                shards[name] = materialize_dense_cols(
                    t, plan.levels[1].coord_bounds)
            else:
                shards[name] = materialize_dense_rows(
                    t, plan.root_coord_bounds)

    # data-vs-computation distribution mismatch cost (C4), as in the 1-D
    # path: a declared data distribution that does not match the grid plan
    # charges the operand's reshuffle.
    if distributions:
        for name, d in distributions.items():
            want = plans.get(name)
            if want is None or want.replicated:
                continue
            have = d.plan(plans[name].tensor)
            if not L._plans_equal(want, have):
                comm.redistribute_bytes += L._nbytes(plans[name].tensor)

    with telemetry.span("lower.emit", sig=stmt.signature(),
                        space=strat.space) as esp:
        leaf_name, runner = _emit_grid(stmt, strat, gp, plans, shards,
                                       jit=jit)
        esp.set(leaf=leaf_name)
    return L.LoweredKernel(
        stmt=stmt, strategy=strat, machine=machine, plans=plans,
        shards=shards, runner=runner, comm=comm, leaf_name=leaf_name,
        fallbacks=fallbacks, declared_formats=declared_formats,
        cache=L._cache_delta(snap),
    )


# ---------------------------------------------------------------------------
# Grid emitters — vmap simulation backend, ONE format-generic emitter per
# expression (the level tree selects scalar vs blocked tile leaves). Tiles
# reuse the 1-D leaf kernels: a (p, q) tile is a CSR-convention shard whose
# column-local crd indexes the q-th window slice of the dense co-operand;
# SUMMA reduction is the sum over the q axis of each grid row's partials.
# ---------------------------------------------------------------------------

def _emit_grid(stmt, strat, gp, plans, shards, jit=True):
    sig = stmt.signature()
    if gp.replicate:
        table = {
            "d2(i,j)=s2(i,k)*d2(k,j)": _emit_spmm_grid_rep,
            "s2(i,j)=s2(i,j)*d2(i,k)*d2(k,j)": _emit_sddmm_grid_rep,
        }
        kind = "replicated 2.5-D"
    elif gp.dep_bounds is not None:
        table = {
            "d2(i,l)=s3(i,j,k)*d2(j,l)*d2(k,l)": _emit_spmttkrp_grid3,
        }
        kind = "3-D brick"
    else:
        table = {
            "d1(i)=s2(i,j)*d1(j)": _emit_spmv_grid,
            "d2(i,j)=s2(i,k)*d2(k,j)": _emit_spmm_grid,
            "s2(i,j)=s2(i,j)*d2(i,k)*d2(k,j)": _emit_sddmm_grid,
            "s2(i,j)=s2(i,j)+s2(i,j)+s2(i,j)": _emit_spadd3_grid,
        }
        kind = "nested-column grid" if gp.nested else "2-D grid"
    emitter = table.get(sig)
    if emitter is None:
        raise NotImplementedError(
            f"no {kind} emitter for {sig}; schedule a 1-D distribution")
    return emitter(stmt, gp, plans, shards, jit=jit)


def _grid_blocked(stmt) -> bool:
    for acc in stmt.rhs.accesses():
        if acc.tensor.format.is_sparse:
            return acc.tensor.level_tree().blocked
    return False


def _color_axes(PQ: int, Q: int):
    color = jnp.arange(PQ, dtype=jnp.int32)
    return color // Q, color % Q


def _emit_spmv_grid(stmt, gp, plans, shards, jit=True):
    B = shards[stmt.rhs.accesses()[0].tensor.name]
    c = shards[stmt.rhs.accesses()[1].tensor.name]
    n = stmt.lhs.tensor.shape[0]
    a = B.arrays
    P, Q = int(B.meta["P"]), int(B.meta["Q"])
    if _grid_blocked(stmt):
        max_gcw = int(a["bcol_count"].max())
        cw = pack_window_vec_blocks(np.asarray(c.arrays["vals"]), max_gcw,
                                    int(B.meta["bc"]))

        def fn(pos, crd, tiles, cw, row_start, row_count):
            _, q = _color_axes(pos.shape[0], Q)
            blocks = jax.vmap(
                lambda p_, c_, t_, q_:
                K.leaf_bcsr_spmv_rows(p_, c_, t_, cw[q_]))(
                pos, crd, tiles, q)                      # (P*Q, mbr*br)
            partial = blocks.reshape(P, Q, blocks.shape[1]).sum(axis=1)
            return L._scatter_rows((n,), partial, row_start, row_count)

        args = (a["pos1"], a["crd1"], a["vals"], cw,
                a["row_start"], a["row_count"])
        f = L._runner(jit, "bcsr_spmv_grid_rows", (n, P, Q), args,
                      lambda: fn)
        return "bcsr_spmv_grid_rows", lambda: np.asarray(f(*args))

    mr = int(B.meta["max_rows"])
    cw = c.arrays["vals"]                                # (Q, max_kw)

    def fn(pos, crd, vals, cw, row_start, row_count):
        _, q = _color_axes(pos.shape[0], Q)
        blocks = jax.vmap(
            lambda p_, c_, v_, q_: K.leaf_spmv_rows(p_, c_, v_, cw[q_]))(
            pos, crd, vals, q)                           # (P*Q, mr)
        partial = blocks.reshape(P, Q, mr).sum(axis=1)
        return L._scatter_rows((n,), partial, row_start, row_count)

    args = (a["pos1"], a["crd1"], a["vals"], cw,
            a["row_start"], a["row_count"])
    f = L._runner(jit, "spmv_grid_rows", (n, P, Q, mr), args, lambda: fn)
    return "spmv_grid_rows", lambda: np.asarray(f(*args))


def _emit_spmm_grid(stmt, gp, plans, shards, jit=True):
    Bacc, Cacc = stmt.rhs.accesses()
    B, C = shards[Bacc.tensor.name], shards[Cacc.tensor.name]
    out_shape = stmt.lhs.tensor.shape
    a = B.arrays
    P, Q = int(B.meta["P"]), int(B.meta["Q"])
    if _grid_blocked(stmt):
        max_gcw = int(a["bcol_count"].max())
        Cw = pack_window_mat_row_blocks(np.asarray(C.arrays["vals"]),
                                        max_gcw, int(B.meta["bc"]))

        def fn(pos, crd, tiles, Cw, row_start, row_count):
            _, q = _color_axes(pos.shape[0], Q)
            blocks = jax.vmap(
                lambda p_, c_, t_, q_:
                K.leaf_bcsr_spmm_rows(p_, c_, t_, Cw[q_]))(
                pos, crd, tiles, q)                      # (P*Q, mbr*br, J)
            partial = blocks.reshape(P, Q, blocks.shape[1],
                                     out_shape[1]).sum(axis=1)
            return L._scatter_rows(out_shape, partial, row_start, row_count)

        args = (a["pos1"], a["crd1"], a["vals"], Cw,
                a["row_start"], a["row_count"])
        f = L._runner(jit, "bcsr_spmm_grid_rows", (P, Q) + out_shape, args,
                      lambda: fn)
        return "bcsr_spmm_grid_rows", lambda: np.asarray(f(*args))

    mr = int(B.meta["max_rows"])
    Cw = C.arrays["vals"]                                # (Q, max_kw, J)

    def fn(pos, crd, vals, Cw, row_start, row_count):
        _, q = _color_axes(pos.shape[0], Q)
        blocks = jax.vmap(
            lambda p_, c_, v_, q_: K.leaf_spmm_rows(p_, c_, v_, Cw[q_]))(
            pos, crd, vals, q)                           # (P*Q, mr, J)
        partial = blocks.reshape(P, Q, mr, out_shape[1]).sum(axis=1)
        return L._scatter_rows(out_shape, partial, row_start, row_count)

    args = (a["pos1"], a["crd1"], a["vals"], Cw,
            a["row_start"], a["row_count"])
    f = L._runner(jit, "spmm_grid_rows", (P, Q, mr) + out_shape, args,
                  lambda: fn)
    return "spmm_grid_rows", lambda: np.asarray(f(*args))


def _emit_sddmm_grid(stmt, gp, plans, shards, jit=True):
    """Grid SDDMM is pure owner-computes: tile (p, q) samples its B tile
    against C's p-th row window and D's q-th column window; outputs stay
    aligned with B's stored positions (scattered home by ``val_idx``) —
    no reduction on either axis. Blocked trees sample whole (br, bc)
    tiles; the walk and scatter logic is identical."""
    accs = stmt.rhs.accesses()
    B = shards[accs[0].tensor.name]
    C = shards[accs[1].tensor.name]
    D = shards[accs[2].tensor.name]
    Bt = accs[0].tensor
    a = B.arrays
    Q = int(B.meta["Q"])
    if _grid_blocked(stmt):
        P = int(B.meta["P"])
        br, bc = int(B.meta["br"]), int(B.meta["bc"])
        max_brows = int(B.meta["max_brows"])
        max_gcw = int(a["bcol_count"].max())
        C_blk = pack_rowwindow_blocks(C.arrays["vals"], max_brows, br)
        Dw = pack_window_mat_inner_blocks(np.asarray(D.arrays["vals"]),
                                          max_gcw, bc)
        total_blocks = int(Bt.levels[1].nnz or 0)

        def fn(pos, crd, tiles, Cw, Dw, val_idx, nnz_count):
            p, q = _color_axes(pos.shape[0], Q)

            def leaf(pos_, crd_, t_, p_, q_):
                brow = K.rows_from_pos(pos_, crd_.shape[0])
                return K.leaf_bcsr_sddmm(brow, crd_, t_, Cw[p_], Dw[q_])

            out = jax.vmap(leaf)(pos, crd, tiles, p, q)  # (PQ, mt, br, bc)
            return L._scatter_by_val_idx(total_blocks, out, val_idx,
                                         nnz_count)

        args = (a["pos1"], a["crd1"], a["vals"], C_blk, Dw, a["val_idx"],
                a["nnz_count"])
        f = L._runner(jit, "bcsr_sddmm_grid_rows",
                      (total_blocks, P, Q, br, bc), args, lambda: fn)

        def run():
            new_tiles = np.asarray(f(*args))
            return Tensor(stmt.lhs.tensor.name, Bt.shape, Bt.format,
                          Bt.levels, new_tiles, Bt.dtype)

        return "bcsr_sddmm_grid_rows", run

    Cw = C.arrays["vals"]                                # (P, max_rw, K)
    Dw = D.arrays["vals"]                                # (Q, K, max_mw)
    total_nnz = Bt.nnz

    def fn(pos, crd, vals, Cw, Dw, val_idx, nnz_count):
        p, q = _color_axes(pos.shape[0], Q)
        out = jax.vmap(
            lambda pos_, crd_, v_, p_, q_:
            K.leaf_sddmm_rows(pos_, crd_, v_, Cw[p_], Dw[q_]))(
            pos, crd, vals, p, q)                        # (P*Q, max_tnnz)
        return L._scatter_by_val_idx(total_nnz, out, val_idx, nnz_count)

    args = (a["pos1"], a["crd1"], a["vals"], Cw, Dw, a["val_idx"],
            a["nnz_count"])
    f = L._runner(jit, "sddmm_grid_rows", (total_nnz, Q), args, lambda: fn)

    def run():
        new_vals = np.asarray(f(*args))
        return Tensor(stmt.lhs.tensor.name, Bt.shape, Bt.format, Bt.levels,
                      new_vals, Bt.dtype)

    return "sddmm_grid_rows", run


# ---------------------------------------------------------------------------
# Communication-avoiding emitters: 2.5-D replicated SpMM / SDDMM (the sparse
# operand keeps its (P, Q) tiles — fingerprint-shared across the z axis —
# while the third machine axis splits a non-reduction loop variable), the
# P×Q×R brick SpMTTKRP, and the nested-column SpAdd3.
# ---------------------------------------------------------------------------

def _emit_spmm_grid_rep(stmt, gp, plans, shards, jit=True):
    """2.5-D SpMM: B(i, k) tiled (P, Q) and replicated along z; C(k, j)
    dense-grid sliced (k by y, j by z); each z-slice r computes the SAME
    (P, Q) SUMMA as the unreplicated 2-D plan restricted to its column
    window — partials sum along y only (the all-reduce the replication
    spares shrinks from QR−1 to Q−1 hops), and the z-slices concatenate
    disjoint output columns. Bit-for-bit equal to the (P, Q) 2-D plan:
    output columns are independent lanes of the same leaf contraction."""
    Bacc, Cacc = stmt.rhs.accesses()
    B, C = shards[Bacc.tensor.name], shards[Cacc.tensor.name]
    out_shape = stmt.lhs.tensor.shape
    a = B.arrays
    P, Q = int(B.meta["P"]), int(B.meta["Q"])
    R = int(gp.R)
    mr = int(B.meta["max_rows"])
    max_jw = int(C.meta["max_cols"])
    Cw = C.arrays["vals"]                         # (Q, R, max_kw, max_jw)
    widths = tuple(int(w) for w in C.arrays["col_count"])   # (R,)

    def fn(pos, crd, vals, Cw, row_start, row_count):
        _, q = _color_axes(pos.shape[0], Q)
        outs = []
        for r in range(R):
            blocks = jax.vmap(
                lambda p_, c_, v_, q_:
                K.leaf_spmm_rows(p_, c_, v_, Cw[q_, r]))(
                pos, crd, vals, q)               # (P*Q, mr, max_jw)
            partial = blocks.reshape(P, Q, mr, max_jw).sum(axis=1)
            outs.append(L._scatter_rows((out_shape[0], max_jw), partial,
                                        row_start, row_count)[:, :widths[r]])
        return jnp.concatenate(outs, axis=1)

    args = (a["pos1"], a["crd1"], a["vals"], Cw,
            a["row_start"], a["row_count"])
    f = L._runner(jit, "spmm_grid_rep_rows",
                  (P, Q, R, mr, max_jw, widths) + out_shape, args,
                  lambda: fn)
    return "spmm_grid_rep_rows", lambda: np.asarray(f(*args))


def _emit_sddmm_grid_rep(stmt, gp, plans, shards, jit=True):
    """2.5-D SDDMM: B's sampling tiles stay (P, Q), shared across z; the
    contraction variable k splits over z — C(i, k) dense-grid (x rows ×
    z cols), D(k, j) dense-grid (z rows × y cols). Each z-slice samples a
    partial dot product; partials sum along z (the only reduction axis)
    and scatter home by B's stored positions."""
    accs = stmt.rhs.accesses()
    B = shards[accs[0].tensor.name]
    C = shards[accs[1].tensor.name]               # (P, R, max_rw, max_kw)
    D = shards[accs[2].tensor.name]               # (R, Q, max_kw, max_mw)
    Bt = accs[0].tensor
    a = B.arrays
    Q = int(B.meta["Q"])
    R = int(gp.R)
    Cw, Dw = C.arrays["vals"], D.arrays["vals"]
    total_nnz = Bt.nnz

    def fn(pos, crd, vals, Cw, Dw, val_idx, nnz_count):
        p, q = _color_axes(pos.shape[0], Q)
        out = jnp.zeros(crd.shape, dtype=vals.dtype)
        for r in range(R):
            out = out + jax.vmap(
                lambda pos_, crd_, v_, p_, q_:
                K.leaf_sddmm_rows(pos_, crd_, v_, Cw[p_, r], Dw[r, q_]))(
                pos, crd, vals, p, q)            # (P*Q, max_tnnz)
        return L._scatter_by_val_idx(total_nnz, out, val_idx, nnz_count)

    args = (a["pos1"], a["crd1"], a["vals"], Cw, Dw, a["val_idx"],
            a["nnz_count"])
    f = L._runner(jit, "sddmm_grid_rep_rows", (total_nnz, Q, R), args,
                  lambda: fn)

    def run():
        new_vals = np.asarray(f(*args))
        return Tensor(stmt.lhs.tensor.name, Bt.shape, Bt.format, Bt.levels,
                      new_vals, Bt.dtype)

    return "sddmm_grid_rep_rows", run


def _emit_spmttkrp_grid3(stmt, gp, plans, shards, jit=True):
    """P×Q×R brick SpMTTKRP: brick (p, q, r) contracts its COO entries
    (brick-local coordinates) against C's q-th and D's r-th row windows;
    partials sum over the Q·R bricks sharing a row window (the y and z
    all-reduce) and scatter into the output rows."""
    accs = stmt.rhs.accesses()
    B = shards[accs[0].tensor.name]
    C = shards[accs[1].tensor.name]               # (Q, max_jw, L)
    D = shards[accs[2].tensor.name]               # (R, max_kw, L)
    out_shape = stmt.lhs.tensor.shape
    a = B.arrays
    P, Q, R = int(B.meta["P"]), int(B.meta["Q"]), int(B.meta["R"])
    max_rows = int(B.meta["max_rows"])
    Cw, Dw = C.arrays["vals"], D.arrays["vals"]

    def fn(d0, d1, d2, vals, Cw, Dw, row_start, row_count):
        color = jnp.arange(d0.shape[0], dtype=jnp.int32)
        q = (color // R) % Q
        r = color % R
        blocks = jax.vmap(
            lambda i_, j_, k_, v_, q_, r_:
            K.leaf_spmttkrp_nnz(i_, j_, k_, v_, Cw[q_], Dw[r_], max_rows))(
            d0, d1, d2, vals, q, r)              # (P*Q*R, max_rows, L)
        partial = blocks.reshape(P, Q * R, max_rows, out_shape[1]).sum(axis=1)
        return L._scatter_rows(out_shape, partial, row_start, row_count)

    args = (a["dim0"], a["dim1"], a["dim2"], a["vals"], Cw, Dw,
            a["row_start"], a["row_count"])
    f = L._runner(jit, "spmttkrp_grid3_rows", (P, Q, R, max_rows) + out_shape,
                  args, lambda: fn)
    return "spmttkrp_grid3_rows", lambda: np.asarray(f(*args))


def _emit_spadd3_grid(stmt, gp, plans, shards, jit=True):
    """Grid SpAdd3: all three addends share the same (P, Qr) tile windows
    (Qr = Q·R for a nested 3-D split), so each tile unions its three
    local coordinate sets with the 1-D leaf — zero communication — and
    host assembly offsets rows AND columns back to global coordinates."""
    accs = stmt.rhs.accesses()
    Bs = [shards[acc.tensor.name] for acc in accs]
    n_rows, n_cols = stmt.lhs.tensor.shape
    Qr = int(Bs[0].meta["Q"])
    max_cw = int(np.asarray(Bs[0].arrays["col_count"]).max())

    def fn(args):
        (p1, c1, v1), (p2, c2, v2), (p3, c3, v3) = args
        leaf = partial(K.leaf_spadd3_rows, n_cols=max_cw)
        return jax.vmap(leaf)(p1, c1, v1, p2, c2, v2, p3, c3, v3)

    args = tuple(
        (S.arrays["pos1"], S.arrays["crd1"], S.arrays["vals"]) for S in Bs)
    flat = tuple(x for trip in args for x in trip)
    f = L._runner(jit, "spadd3_grid_rows", (n_rows, n_cols, Qr, max_cw),
                  flat, lambda: fn)

    def run():
        rows, cols, vals, counts = (np.asarray(x) for x in f(args))
        rs = np.asarray(Bs[0].arrays["row_start"])
        cs = np.asarray(Bs[0].arrays["col_start"])
        out_rows, out_cols, out_vals = [], [], []
        for color in range(rows.shape[0]):
            p, q = divmod(color, Qr)
            k = int(counts[color])
            out_rows.append(rows[color, :k] + rs[p])
            out_cols.append(cols[color, :k] + cs[q])
            out_vals.append(vals[color, :k])
        coords = np.stack([np.concatenate(out_rows),
                           np.concatenate(out_cols)], 1)
        return Tensor.from_coo(stmt.lhs.tensor.name, (n_rows, n_cols),
                               coords, np.concatenate(out_vals),
                               F.CSR(), dedupe=True)

    return "spadd3_grid_rows", run


# -- per-window block packing for the blocked grid leaves -------------------
# The grid column windows are block-aligned (the planner snaps them), so a
# window's slice of the dense co-operand reshapes straight into (bc-sized)
# blocks. These pack from the MATERIALIZED window shards — the cached
# (Q, max_w, ...) arrays — so a warm re-lower never re-densifies the
# operand; both the vmap emitters here and the shard_map builders in
# distributed/executor.py share them.

def pack_window_vec_blocks(vals: np.ndarray, max_gcw: int, bc: int,
                           ) -> np.ndarray:
    """Dense-vector window shards (Q, max_kw) → column blocks
    (Q, max_gcw, bc); padding past each window is already zero."""
    Q, kw = vals.shape
    out = np.zeros((Q, max_gcw * bc), vals.dtype)
    out[:, :kw] = vals
    return out.reshape(Q, max_gcw, bc)


def pack_window_mat_row_blocks(vals: np.ndarray, max_gcw: int, bc: int,
                               ) -> np.ndarray:
    """Dense-matrix row-window shards (Q, max_kw, J) → leading-dim blocks
    (Q, max_gcw, bc, J)."""
    Q, kw, J = vals.shape
    out = np.zeros((Q, max_gcw * bc, J), vals.dtype)
    out[:, :kw] = vals
    return out.reshape(Q, max_gcw, bc, J)


def pack_window_mat_inner_blocks(vals: np.ndarray, max_gcw: int, bc: int,
                                 ) -> np.ndarray:
    """Dense-matrix column-window shards (Q, K, max_mw) → trailing-dim
    blocks (Q, max_gcw, K, bc) — the per-window analog of
    ``layout.pack_mat_inner_blocks``."""
    Q, K, mw = vals.shape
    out = np.zeros((Q, K, max_gcw * bc), vals.dtype)
    out[:, :, :mw] = vals
    return np.ascontiguousarray(
        out.reshape(Q, K, max_gcw, bc).transpose(0, 2, 1, 3))

