"""Multi-axis (grid) distribution subsystem — 2-D processor grids.

SpDISTAL's `distribute((i, k, …) → (x, y, …))` maps SEVERAL index
variables onto a multi-dimensional machine grid (the DISTAL machine
abstraction, paper §II-C / Fig. 4c), with communication planned per grid
axis. This module is that subsystem for 2-D grids:

- :class:`GridPlan` — the per-axis universe splits and the cross-product
  tile map: color ``(p, q)`` owns row window ``p`` × column window ``q``
  of the distributed sparse operand (block-aligned when it is blocked).
- **Per-axis communication planning**: operands sliced by the second loop
  variable broadcast along ``x`` (all grid rows in a column share them),
  operands sliced by the first broadcast along ``y``, and — when the
  second variable is a reduction variable — output partials all-reduce
  along ``y`` only. This is SUMMA specialized to sparse operands: a 2-D
  SpMM at P×Q pieces moves ``|C|·(P−1) + |A|·(Q−1)`` bytes versus 1-D's
  ``|C|·(PQ−1)``, strictly fewer whenever ``|A| < P·|C|``.
- **Grid emitters**: the vmap simulation backend for SpMV / SpMM / SDDMM
  tiles (scalar and blocked), reusing the same leaf kernels as the 1-D
  path — a tile is just a CSR-convention shard with column-local
  coordinates contracted against its axis-window co-operand slice. The
  SPMD analogs live in ``distributed/executor.py`` (``*_grid_rows``
  builders over a genuine ``Mesh((P, Q), ("x", "y"))`` with ``psum``
  scoped to the reduction axis only).

Grid NON-ZERO schedules do not pass through here: a nested pos-split
canonicalizes to the flat equal split of the fused position space, so
``core.lower`` runs them through the 1-D nnz machinery at ``P*Q`` pieces
(bit-for-bit their ``Px1`` counterparts) and only re-attributes the
communication to the axes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import lower as L
from .partition import (Bounds, ShardedTensor, TensorPartition,
                        block_aligned_row_bounds, materialize_bcsr_grid,
                        materialize_csr_grid, materialize_dense_cols,
                        materialize_dense_rows, materialize_replicated,
                        partition_by_bounds, partition_tensor_cols,
                        partition_tensor_grid, partition_tensor_rows,
                        replicate_tensor)
from .schedule import DistStrategy
from .tdn import Machine
from .tensor import Tensor
from .tin import Assignment
from ..kernels import ref as K
from ..kernels.layout import pack_rowwindow_blocks


@dataclasses.dataclass
class GridPlan:
    """Per-axis splits + the cross-product tile map of a 2-D distribution.

    ``row_bounds`` (P, 2) splits the first distributed variable's universe,
    ``col_bounds`` (Q, 2) the second's; the flat color of tile ``(p, q)``
    is ``p * Q + q`` (row-major), the convention every grid shard set and
    emitter shares. Only universe strategies flow through a GridPlan —
    grid nnz schedules canonicalize to the flat 1-D split (module
    docstring)."""

    axis_x: str
    axis_y: str
    row_bounds: Bounds                # (P, 2) over extent(vars[0])
    col_bounds: Bounds                # (Q, 2) over extent(vars[1])

    @property
    def P(self) -> int:
        return int(self.row_bounds.shape[0])

    @property
    def Q(self) -> int:
        return int(self.col_bounds.shape[0])

    @property
    def pieces(self) -> int:
        return self.P * self.Q

    def tile_windows(self):
        """Yield ``(p, q, (rlo, rhi), (clo, chi))`` in flat-color order."""
        for p in range(self.P):
            for q in range(self.Q):
                yield (p, q,
                       (int(self.row_bounds[p, 0]), int(self.row_bounds[p, 1])),
                       (int(self.col_bounds[q, 0]), int(self.col_bounds[q, 1])))

    def validate(self, n_rows: int, n_cols: int) -> None:
        """Tiling invariant: the P×Q tiles cover ``[0, n_rows) × [0,
        n_cols)`` exactly once — each axis's windows are sorted, disjoint,
        and gap-free."""
        for bounds, n, label in ((self.row_bounds, n_rows, "row"),
                                 (self.col_bounds, n_cols, "col")):
            if bounds[0, 0] != 0 or bounds[-1, 1] != n:
                raise AssertionError(f"{label} windows do not span [0, {n})")
            for w in range(bounds.shape[0]):
                if bounds[w, 0] > bounds[w, 1]:
                    raise AssertionError(f"negative {label} window {w}")
                if w and bounds[w, 0] != bounds[w - 1, 1]:
                    raise AssertionError(
                        f"{label} windows {w - 1}/{w} overlap or gap")


def compute_grid_plan(stmt: Assignment, strat: DistStrategy) -> GridPlan:
    """Derive the per-axis universe splits for a 2-D universe strategy:
    equal splits of the two distributed variables' extents, snapped to
    block boundaries when the distributed sparse operand is blocked (so
    every co-partitioned tensor shares the same per-color windows)."""
    if not strat.is_grid or strat.space != "universe":
        raise ValueError("grid plan requires a multi-var universe strategy")
    if len(strat.vars) != 2:
        raise NotImplementedError(
            f"grid distribution supports exactly 2 machine dimensions, got "
            f"{len(strat.vars)} distributed vars {strat.vars}")
    dx, dy = strat.machine_dims[0], strat.machine_dims[1]
    v0, v1 = strat.vars[0], strat.vars[1]
    spa = stmt.sparse_accesses()[0]
    if tuple(spa.idx[:2]) != (v0, v1):
        raise NotImplementedError(
            f"2-D grid distribution must distribute the sparse operand's "
            f"first two index variables, got ({v0}, {v1}) for {spa}")
    n0, n1 = stmt.var_extent(v0), stmt.var_extent(v1)
    Bt = spa.tensor
    if getattr(Bt.format, "is_blocked", False):
        br, bc = Bt.format.block_shape
        row_bounds = block_aligned_row_bounds(n0, dx.size, br)
        col_bounds = block_aligned_row_bounds(n1, dy.size, bc)
    else:
        row_bounds = partition_by_bounds(n0, dx.size)
        col_bounds = partition_by_bounds(n1, dy.size)
    return GridPlan(axis_x=dx.name, axis_y=dy.name,
                    row_bounds=row_bounds, col_bounds=col_bounds)


def _grid_tag(acc, v0, v1) -> str:
    """Which slicing a grid schedule gives this access: ``xy`` = cross
    product tiles, ``x``/``y`` = sliced by that axis's windows, ``*`` =
    replicated. The tag is also the communication key: an operand sliced
    along one axis broadcasts along the ORTHOGONAL axis."""
    t = acc.tensor
    idx = tuple(acc.idx)
    if (t.format.is_sparse and len(idx) >= 2
            and idx[0] == v0 and idx[1] == v1):
        return "xy"
    if v0 in idx and idx.index(v0) == 0 and t.format.level_of_dim(0) == 0:
        return "x"
    if v1 in idx and idx.index(v1) == 0 and t.format.level_of_dim(0) == 0:
        return "y"
    if v1 in idx and idx.index(v1) == 1 and t.format.is_all_dense:
        return "ycols"
    return "*"


def _grid_axis_tags(stmt: Assignment, strat: DistStrategy,
                    ) -> Dict[str, str]:
    v0, v1 = strat.vars[0], strat.vars[1]
    tags: Dict[str, str] = {}
    for acc in stmt.accesses():
        tags.setdefault(acc.tensor.name, _grid_tag(acc, v0, v1))
    return tags


def _grid_plans(stmt: Assignment, strat: DistStrategy, gp: GridPlan,
                ) -> Tuple[Dict[str, TensorPartition], Dict[str, str]]:
    """Fig. 9a steps 1 & 2 on a grid: the distributed sparse operand (and a
    sparse output sharing its index pattern) takes cross-product tiles;
    every other operand is sliced by whichever distributed variable
    indexes it — tagged with the axis it rides (``axis_of``)."""
    axis_of = _grid_axis_tags(stmt, strat)
    plans: Dict[str, TensorPartition] = {}
    for acc in stmt.accesses():
        t = acc.tensor
        if t.name in plans:
            continue
        tag = axis_of[t.name]
        if tag == "xy":
            plans[t.name] = partition_tensor_grid(t, gp.row_bounds,
                                                  gp.col_bounds)
        elif tag == "x":
            plans[t.name] = partition_tensor_rows(t, gp.row_bounds)
        elif tag == "y":
            plans[t.name] = partition_tensor_rows(t, gp.col_bounds)
        elif tag == "ycols":
            plans[t.name] = partition_tensor_cols(t, gp.col_bounds)
        else:
            plans[t.name] = replicate_tensor(t, gp.pieces)
    return plans, axis_of


def grid_axis_bytes(stmt: Assignment, strat: DistStrategy,
                    ) -> Dict[str, "L.AxisComm"]:
    """Per-axis byte formulas of a 2-D grid schedule, computed from the
    statement + strategy alone (no GridPlan / partitioning needed): an
    operand sliced along one axis is shared by (broadcast to) every color
    of the ORTHOGONAL axis; a fully replicated operand broadcasts
    hierarchically (x once, then y within each of the P grid rows); when
    the column variable is a reduction variable, every grid row
    all-reduces its output window along y.

    This is both the ledger `lower_grid` records on the kernel and the
    estimator `core.plan_search` scores 2-D candidates with before
    committing to a plan."""
    v0, v1 = strat.vars[0], strat.vars[1]
    dx, dy = strat.machine_dims[0], strat.machine_dims[1]
    P = dx.size
    out_name = stmt.lhs.tensor.name
    axes = {dx.name: L.AxisComm(size=dx.size),
            dy.name: L.AxisComm(size=dy.size)}
    seen = set()
    for acc in stmt.accesses():
        t = acc.tensor
        if t.name in seen or t.name == out_name:
            continue
        seen.add(t.name)
        tag = _grid_tag(acc, v0, v1)
        if tag == "xy":
            continue                      # tiles: owned, nothing moves
        if tag == "*":
            axes[dx.name].broadcast_bytes += L._nbytes(t)
            axes[dy.name].broadcast_bytes += P * L._nbytes(t)
        elif tag in ("y", "ycols"):       # sliced by y → broadcast along x
            axes[dx.name].broadcast_bytes += L._nbytes(t)
        else:                             # sliced by x → broadcast along y
            axes[dy.name].broadcast_bytes += L._nbytes(t)
    if v1 in stmt.reduction_vars:
        axes[dy.name].reduce_bytes += L._nbytes(stmt.lhs.tensor)
    return axes


def _grid_comm(stmt: Assignment, strat: DistStrategy, gp: GridPlan,
               plans: Dict[str, TensorPartition], axis_of: Dict[str, str],
               out_t: Tensor) -> L.CommStats:
    """Per-axis communication plan recorded on the kernel — the shared
    ``grid_axis_bytes`` formulas over the normalized statement (whose
    access tensors are exactly the planned tensors)."""
    comm = L.CommStats(pieces=gp.pieces)
    comm.axes = grid_axis_bytes(stmt, strat)
    return comm


# ---------------------------------------------------------------------------
# The grid lowering entry point (called from core.lower._lower_impl)
# ---------------------------------------------------------------------------

def lower_grid(stmt: Assignment, machine: Machine, strat: DistStrategy,
               jit: bool, fallbacks, declared_formats, snap,
               distributions=None) -> "L.LoweredKernel":
    out_t: Tensor = stmt.lhs.tensor
    gp = compute_grid_plan(stmt, strat)

    plan_key = L._plan_cache_key(stmt, strat, None)
    plans = L._PLAN_CACHE.get(plan_key) if plan_key is not None else None
    if plans is not None:
        current: Dict[str, Tensor] = {}
        for acc in stmt.accesses():
            current.setdefault(acc.tensor.name, acc.tensor)
        plans = {name: dataclasses.replace(p, tensor=current[name])
                 for name, p in plans.items()}
        axis_of = _grid_axis_tags(stmt, strat)
    else:
        plans, axis_of = _grid_plans(stmt, strat, gp)
        if plan_key is not None:
            L._PLAN_CACHE.put(plan_key, {
                name: dataclasses.replace(p, tensor=None)
                for name, p in plans.items()})

    comm = _grid_comm(stmt, strat, gp, plans, axis_of, out_t)

    # ---- materialize ------------------------------------------------------
    shards: Dict[str, ShardedTensor] = {}
    for name, plan in plans.items():
        if name == out_t.name:
            continue                      # grid outputs assemble from leaves
        t = plan.tensor
        if plan.replicated:
            shards[name] = materialize_replicated(t, gp.pieces)
        elif plan.grid is not None:
            shards[name] = (materialize_bcsr_grid(t, plan)
                            if t.format.is_blocked
                            else materialize_csr_grid(t, plan))
        elif plan.root_coord_bounds is None:
            shards[name] = materialize_dense_cols(
                t, plan.levels[1].coord_bounds)
        else:
            shards[name] = materialize_dense_rows(t, plan.root_coord_bounds)

    # data-vs-computation distribution mismatch cost (C4), as in the 1-D
    # path: a declared data distribution that does not match the grid plan
    # charges the operand's reshuffle.
    if distributions:
        for name, d in distributions.items():
            want = plans.get(name)
            if want is None or want.replicated:
                continue
            have = d.plan(plans[name].tensor)
            if not L._plans_equal(want, have):
                comm.redistribute_bytes += L._nbytes(plans[name].tensor)

    leaf_name, runner = _emit_grid(stmt, strat, gp, plans, shards, jit=jit)
    return L.LoweredKernel(
        stmt=stmt, strategy=strat, machine=machine, plans=plans,
        shards=shards, runner=runner, comm=comm, leaf_name=leaf_name,
        fallbacks=fallbacks, declared_formats=declared_formats,
        cache=L._cache_delta(snap),
    )


# ---------------------------------------------------------------------------
# Grid emitters — vmap simulation backend, ONE format-generic emitter per
# expression (the level tree selects scalar vs blocked tile leaves). Tiles
# reuse the 1-D leaf kernels: a (p, q) tile is a CSR-convention shard whose
# column-local crd indexes the q-th window slice of the dense co-operand;
# SUMMA reduction is the sum over the q axis of each grid row's partials.
# ---------------------------------------------------------------------------

def _emit_grid(stmt, strat, gp, plans, shards, jit=True):
    sig = stmt.signature()
    table = {
        "d1(i)=s2(i,j)*d1(j)": _emit_spmv_grid,
        "d2(i,j)=s2(i,k)*d2(k,j)": _emit_spmm_grid,
        "s2(i,j)=s2(i,j)*d2(i,k)*d2(k,j)": _emit_sddmm_grid,
    }
    emitter = table.get(sig)
    if emitter is None:
        raise NotImplementedError(
            f"no 2-D grid emitter for {sig}; schedule a 1-D distribution "
            "(spmv/spmm/sddmm are grid-distributable)")
    return emitter(stmt, gp, plans, shards, jit=jit)


def _grid_blocked(stmt) -> bool:
    for acc in stmt.rhs.accesses():
        if acc.tensor.format.is_sparse:
            return acc.tensor.level_tree().blocked
    return False


def _color_axes(PQ: int, Q: int):
    color = jnp.arange(PQ, dtype=jnp.int32)
    return color // Q, color % Q


def _emit_spmv_grid(stmt, gp, plans, shards, jit=True):
    B = shards[stmt.rhs.accesses()[0].tensor.name]
    c = shards[stmt.rhs.accesses()[1].tensor.name]
    n = stmt.lhs.tensor.shape[0]
    a = B.arrays
    P, Q = int(B.meta["P"]), int(B.meta["Q"])
    if _grid_blocked(stmt):
        max_gcw = int(a["bcol_count"].max())
        cw = pack_window_vec_blocks(np.asarray(c.arrays["vals"]), max_gcw,
                                    int(B.meta["bc"]))

        def fn(pos, crd, tiles, cw, row_start, row_count):
            _, q = _color_axes(pos.shape[0], Q)
            blocks = jax.vmap(
                lambda p_, c_, t_, q_:
                K.leaf_bcsr_spmv_rows(p_, c_, t_, cw[q_]))(
                pos, crd, tiles, q)                      # (P*Q, mbr*br)
            partial = blocks.reshape(P, Q, blocks.shape[1]).sum(axis=1)
            return L._scatter_rows((n,), partial, row_start, row_count)

        args = (a["pos1"], a["crd1"], a["vals"], cw,
                a["row_start"], a["row_count"])
        f = L._runner(jit, "bcsr_spmv_grid_rows", (n, P, Q), args,
                      lambda: fn)
        return "bcsr_spmv_grid_rows", lambda: np.asarray(f(*args))

    mr = int(B.meta["max_rows"])
    cw = c.arrays["vals"]                                # (Q, max_kw)

    def fn(pos, crd, vals, cw, row_start, row_count):
        _, q = _color_axes(pos.shape[0], Q)
        blocks = jax.vmap(
            lambda p_, c_, v_, q_: K.leaf_spmv_rows(p_, c_, v_, cw[q_]))(
            pos, crd, vals, q)                           # (P*Q, mr)
        partial = blocks.reshape(P, Q, mr).sum(axis=1)
        return L._scatter_rows((n,), partial, row_start, row_count)

    args = (a["pos1"], a["crd1"], a["vals"], cw,
            a["row_start"], a["row_count"])
    f = L._runner(jit, "spmv_grid_rows", (n, P, Q, mr), args, lambda: fn)
    return "spmv_grid_rows", lambda: np.asarray(f(*args))


def _emit_spmm_grid(stmt, gp, plans, shards, jit=True):
    Bacc, Cacc = stmt.rhs.accesses()
    B, C = shards[Bacc.tensor.name], shards[Cacc.tensor.name]
    out_shape = stmt.lhs.tensor.shape
    a = B.arrays
    P, Q = int(B.meta["P"]), int(B.meta["Q"])
    if _grid_blocked(stmt):
        max_gcw = int(a["bcol_count"].max())
        Cw = pack_window_mat_row_blocks(np.asarray(C.arrays["vals"]),
                                        max_gcw, int(B.meta["bc"]))

        def fn(pos, crd, tiles, Cw, row_start, row_count):
            _, q = _color_axes(pos.shape[0], Q)
            blocks = jax.vmap(
                lambda p_, c_, t_, q_:
                K.leaf_bcsr_spmm_rows(p_, c_, t_, Cw[q_]))(
                pos, crd, tiles, q)                      # (P*Q, mbr*br, J)
            partial = blocks.reshape(P, Q, blocks.shape[1],
                                     out_shape[1]).sum(axis=1)
            return L._scatter_rows(out_shape, partial, row_start, row_count)

        args = (a["pos1"], a["crd1"], a["vals"], Cw,
                a["row_start"], a["row_count"])
        f = L._runner(jit, "bcsr_spmm_grid_rows", (P, Q) + out_shape, args,
                      lambda: fn)
        return "bcsr_spmm_grid_rows", lambda: np.asarray(f(*args))

    mr = int(B.meta["max_rows"])
    Cw = C.arrays["vals"]                                # (Q, max_kw, J)

    def fn(pos, crd, vals, Cw, row_start, row_count):
        _, q = _color_axes(pos.shape[0], Q)
        blocks = jax.vmap(
            lambda p_, c_, v_, q_: K.leaf_spmm_rows(p_, c_, v_, Cw[q_]))(
            pos, crd, vals, q)                           # (P*Q, mr, J)
        partial = blocks.reshape(P, Q, mr, out_shape[1]).sum(axis=1)
        return L._scatter_rows(out_shape, partial, row_start, row_count)

    args = (a["pos1"], a["crd1"], a["vals"], Cw,
            a["row_start"], a["row_count"])
    f = L._runner(jit, "spmm_grid_rows", (P, Q, mr) + out_shape, args,
                  lambda: fn)
    return "spmm_grid_rows", lambda: np.asarray(f(*args))


def _emit_sddmm_grid(stmt, gp, plans, shards, jit=True):
    """Grid SDDMM is pure owner-computes: tile (p, q) samples its B tile
    against C's p-th row window and D's q-th column window; outputs stay
    aligned with B's stored positions (scattered home by ``val_idx``) —
    no reduction on either axis. Blocked trees sample whole (br, bc)
    tiles; the walk and scatter logic is identical."""
    accs = stmt.rhs.accesses()
    B = shards[accs[0].tensor.name]
    C = shards[accs[1].tensor.name]
    D = shards[accs[2].tensor.name]
    Bt = accs[0].tensor
    a = B.arrays
    Q = int(B.meta["Q"])
    if _grid_blocked(stmt):
        P = int(B.meta["P"])
        br, bc = int(B.meta["br"]), int(B.meta["bc"])
        max_brows = int(B.meta["max_brows"])
        max_gcw = int(a["bcol_count"].max())
        C_blk = pack_rowwindow_blocks(C.arrays["vals"], max_brows, br)
        Dw = pack_window_mat_inner_blocks(np.asarray(D.arrays["vals"]),
                                          max_gcw, bc)
        total_blocks = int(Bt.levels[1].nnz or 0)

        def fn(pos, crd, tiles, Cw, Dw, val_idx, nnz_count):
            p, q = _color_axes(pos.shape[0], Q)

            def leaf(pos_, crd_, t_, p_, q_):
                brow = K.rows_from_pos(pos_, crd_.shape[0])
                return K.leaf_bcsr_sddmm(brow, crd_, t_, Cw[p_], Dw[q_])

            out = jax.vmap(leaf)(pos, crd, tiles, p, q)  # (PQ, mt, br, bc)
            return L._scatter_by_val_idx(total_blocks, out, val_idx,
                                         nnz_count)

        args = (a["pos1"], a["crd1"], a["vals"], C_blk, Dw, a["val_idx"],
                a["nnz_count"])
        f = L._runner(jit, "bcsr_sddmm_grid_rows",
                      (total_blocks, P, Q, br, bc), args, lambda: fn)

        def run():
            new_tiles = np.asarray(f(*args))
            return Tensor(stmt.lhs.tensor.name, Bt.shape, Bt.format,
                          Bt.levels, new_tiles, Bt.dtype)

        return "bcsr_sddmm_grid_rows", run

    Cw = C.arrays["vals"]                                # (P, max_rw, K)
    Dw = D.arrays["vals"]                                # (Q, K, max_mw)
    total_nnz = Bt.nnz

    def fn(pos, crd, vals, Cw, Dw, val_idx, nnz_count):
        p, q = _color_axes(pos.shape[0], Q)
        out = jax.vmap(
            lambda pos_, crd_, v_, p_, q_:
            K.leaf_sddmm_rows(pos_, crd_, v_, Cw[p_], Dw[q_]))(
            pos, crd, vals, p, q)                        # (P*Q, max_tnnz)
        return L._scatter_by_val_idx(total_nnz, out, val_idx, nnz_count)

    args = (a["pos1"], a["crd1"], a["vals"], Cw, Dw, a["val_idx"],
            a["nnz_count"])
    f = L._runner(jit, "sddmm_grid_rows", (total_nnz, Q), args, lambda: fn)

    def run():
        new_vals = np.asarray(f(*args))
        return Tensor(stmt.lhs.tensor.name, Bt.shape, Bt.format, Bt.levels,
                      new_vals, Bt.dtype)

    return "sddmm_grid_rows", run


# -- per-window block packing for the blocked grid leaves -------------------
# The grid column windows are block-aligned (the planner snaps them), so a
# window's slice of the dense co-operand reshapes straight into (bc-sized)
# blocks. These pack from the MATERIALIZED window shards — the cached
# (Q, max_w, ...) arrays — so a warm re-lower never re-densifies the
# operand; both the vmap emitters here and the shard_map builders in
# distributed/executor.py share them.

def pack_window_vec_blocks(vals: np.ndarray, max_gcw: int, bc: int,
                           ) -> np.ndarray:
    """Dense-vector window shards (Q, max_kw) → column blocks
    (Q, max_gcw, bc); padding past each window is already zero."""
    Q, kw = vals.shape
    out = np.zeros((Q, max_gcw * bc), vals.dtype)
    out[:, :kw] = vals
    return out.reshape(Q, max_gcw, bc)


def pack_window_mat_row_blocks(vals: np.ndarray, max_gcw: int, bc: int,
                               ) -> np.ndarray:
    """Dense-matrix row-window shards (Q, max_kw, J) → leading-dim blocks
    (Q, max_gcw, bc, J)."""
    Q, kw, J = vals.shape
    out = np.zeros((Q, max_gcw * bc, J), vals.dtype)
    out[:, :kw] = vals
    return out.reshape(Q, max_gcw, bc, J)


def pack_window_mat_inner_blocks(vals: np.ndarray, max_gcw: int, bc: int,
                                 ) -> np.ndarray:
    """Dense-matrix column-window shards (Q, K, max_mw) → trailing-dim
    blocks (Q, max_gcw, K, bc) — the per-window analog of
    ``layout.pack_mat_inner_blocks``."""
    Q, K, mw = vals.shape
    out = np.zeros((Q, K, max_gcw * bc), vals.dtype)
    out[:, :, :mw] = vals
    return np.ascontiguousarray(
        out.reshape(Q, K, max_gcw, bc).transpose(0, 2, 1, 3))

