"""Tensor Distribution Notation (TDN) — the data-distribution language
(paper §II-B "Data Distribution", Figs. 4 & 5).

A TDN statement names each dimension of a tensor and each dimension of an
abstract machine grid; shared names mean "partitioned by". SpDISTAL's
extensions implemented here:

- **universe partitions** (default): split the coordinate range equally.
- **non-zero partitions** (tilde ``~x``): split the stored non-zeros equally.
- **coordinate fusion** (``xy->f``): flatten dimensions into one logical
  coordinate that can be the target of a non-zero partition.

String syntax (mirrors the paper's math)::

    dist(B, "xy -> x",  M)      # B_xy |->_x M      row-wise (Fig. 4b)
    dist(B, "xy -> xy", M2)     # tiled onto 2-D machine (Fig. 4c)
    dist(c, "x  -> ~x", M)      # non-zero split of sparse vector (Fig. 5b)
    dist(B, "xy ~f> f", M)      # fuse x,y into f; nnz split (Fig. 5c)
    dist(c, "x  -> *",  M)      # replicate onto all of M (Fig. 1 ReplDense)

Machine axes are named positionally after the tensor names used on the RHS.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from . import partition as part
from .partition import (Bounds, TensorPartition, materialize_coo_nnz,
                        materialize_csr_rows, materialize_dense_rows,
                        materialize_replicated, partition_by_bounds,
                        partition_tensor_nonzeros, partition_tensor_rows,
                        replicate_tensor, ShardedTensor)
from .tensor import Tensor


@dataclasses.dataclass(frozen=True)
class MachineDim:
    name: str
    size: int


class Machine:
    """An abstract n-dimensional grid of processors (paper Fig. 1 line 5).

    Maps one-to-one onto mesh axes of a `jax.sharding.Mesh` at lowering time
    (`distributed.mesh.machine_to_mesh`).
    """

    def __init__(self, *dims: Tuple[str, int]):
        if len(dims) == 1 and isinstance(dims[0], int):
            dims = (("x", dims[0]),)
        self.dims = tuple(MachineDim(n, int(s)) for n, s in dims)

    @staticmethod
    def grid(*sizes: int, names: Optional[Sequence[str]] = None) -> "Machine":
        names = names or ["x", "y", "z", "w"][: len(sizes)]
        return Machine(*[(n, s) for n, s in zip(names, sizes)])

    @property
    def n_procs(self) -> int:
        return int(np.prod([d.size for d in self.dims])) if self.dims else 1

    def dim(self, name: str) -> MachineDim:
        for d in self.dims:
            if d.name == name:
                return d
        raise KeyError(name)

    def __getattr__(self, name: str) -> MachineDim:
        try:
            return self.dim(name)
        except KeyError as e:
            raise AttributeError(name) from e

    def __repr__(self) -> str:
        return f"Machine({', '.join(f'{d.name}={d.size}' for d in self.dims)})"


@dataclasses.dataclass
class Distribution:
    """Parsed TDN statement for one tensor."""

    tensor_dims: Tuple[str, ...]       # names for the tensor dims, in order
    machine: Machine
    mapping: Tuple[str, ...]           # machine dim -> tensor dim name / "*"
    nonzero: bool = False              # tilde split
    fused: Optional[Tuple[str, ...]] = None  # dims fused into the target
    replicate: bool = False

    @property
    def pieces(self) -> int:
        return self.machine.n_procs

    # -- application ------------------------------------------------------
    def plan(self, tensor: Tensor) -> TensorPartition:
        """Compute the coordinate-tree partition this TDN statement implies
        (paper §V-C: TDN compiles into divide/distribute scheduling)."""
        if self.replicate:
            return replicate_tensor(tensor, self.pieces)
        pieces = self.pieces
        if self.nonzero:
            if self.fused is not None and \
                    set(self.fused) != set(self.tensor_dims):
                # partial fusion (paper Fig. 5: non-zero slices/tubes):
                # split the position space at the level of the LAST fused
                # dim; image/preimage derive the rest of the tree
                if tuple(self.fused) != tuple(
                        self.tensor_dims[: len(self.fused)]):
                    raise NotImplementedError(
                        "fusion of non-prefix dims — reorder the format so "
                        "the fused dims are stored first")
                return partition_tensor_nonzeros(
                    tensor, pieces, fused_levels=len(self.fused))
            return partition_tensor_nonzeros(tensor, pieces)
        # universe partition of the mapped (root) dimension
        target = self.mapping[0]
        dim_index = self.tensor_dims.index(target)
        lvl = tensor.format.level_of_dim(dim_index)
        if lvl != 0:
            raise NotImplementedError(
                f"universe partition of non-root storage level {lvl}; "
                "reorder the format (e.g. use CSC) so the distributed "
                "dimension is stored first")
        n = tensor.shape[dim_index]
        return partition_tensor_rows(tensor, partition_by_bounds(n, pieces))

    def materialize(self, tensor: Tensor) -> ShardedTensor:
        p = self.plan(tensor)
        if p.replicated:
            return materialize_replicated(tensor, self.pieces)
        if self.nonzero:
            return materialize_coo_nnz(tensor, p)
        if tensor.format.is_all_dense:
            return materialize_dense_rows(tensor, p.root_coord_bounds)
        return materialize_csr_rows(tensor, p)


def dist(tensor_or_dims, spec: str, machine: Machine) -> Distribution:
    """Parse ``"xy -> x"`` / ``"xy ~f> f"`` / ``"x -> *"`` TDN strings."""
    if isinstance(tensor_or_dims, Tensor):
        order = tensor_or_dims.order
        names = tuple("xyzw"[:order])
    else:
        names = tuple(tensor_or_dims)
    spec = spec.replace(" ", "")
    fused = None
    nonzero = False
    if "~" in spec and ">" in spec:
        # "xy~f>f" fusion+nnz  or  "x->~x" simple nnz
        if "->" in spec:
            lhs, rhs = spec.split("->")
            nonzero = rhs.startswith("~")
            rhs = rhs.lstrip("~")
        else:
            lhs, rest = spec.split("~", 1)
            fname, rhs = rest.split(">", 1)
            fused = tuple(lhs)
            nonzero = True
    else:
        lhs, rhs = spec.split("->")
    if rhs == "*":
        return Distribution(names, machine, ("*",), replicate=True)
    mapping = tuple(rhs) if fused is None else (rhs,)
    return Distribution(names, machine, mapping, nonzero=nonzero, fused=fused)
