"""Lowering scheduled TIN statements to executable JAX (paper §IV).

This is the Fig. 9a code-generation algorithm adapted to XLA's static-SPMD
model (DESIGN.md §2):

1. **Plan**: for the distributed index variable, create the *initial level
   partition* — universe partitions for coordinate-value loops, non-zero
   partitions for coordinate-position loops — then derive full
   coordinate-tree partitions of every accessed tensor with
   image/preimage (``partition_tensor_rows`` / ``partition_tensor_nonzeros``)
   and replicate tensors not indexed by the distributed variable
   (``partitionRemainingCoordinateTrees`` → TDN replication).
2. **Materialize**: pack per-color sub-tensors into stacked padded arrays.
3. **Emit**: select the specialized leaf kernel for (expression signature ×
   strategy space × format), wrap it in the distributed loop — `jax.vmap`
   over the color axis for the single-process simulation backend, or
   `jax.shard_map` over a real mesh axis for SPMD execution — and place the
   collectives implied by ``communicate`` (replication = all-gather ahead of
   the loop; overlapping output roots = reduction after it).

The result is a *bespoke compiled function* per (computation, format,
data distribution, computation distribution) — the paper's compilation
thesis, versus interpretation (see core/interp.py for the CTF analog).
"""
from __future__ import annotations

import dataclasses
import logging
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .cache import BATCH_BUCKETS, LRUCache, avals_key, batch_bucket
from . import formats as fmt
from . import levels
from .partition import (CONVERT_CACHE_STATS, SHARD_CACHE_STATS,
                        ShardedTensor, TensorPartition,
                        block_aligned_row_bounds, clear_convert_cache,
                        clear_shard_cache, convert_tensor_cached,
                        elastic_row_bounds, fingerprint_memo,
                        materialize_add_stream, materialize_bcsr_nnz,
                        materialize_bcsr_rows, materialize_coo_nnz,
                        materialize_csr_rows, materialize_dense_cols,
                        materialize_dense_grid, materialize_dense_rows,
                        materialize_dense_rows_pieces, materialize_pieces,
                        materialize_replicated,
                        materialize_replicated_elastic, partition_by_bounds,
                        partition_tensor_nonzeros, partition_tensor_rows,
                        replicate_tensor, tensor_fingerprint,
                        weights_fingerprint)
from .schedule import DistStrategy, Schedule
from .tdn import Distribution, Machine
from .tensor import Tensor
from .tin import Access, Assignment, IndexVar, Mul

log = logging.getLogger(__name__)
from ..runtime import telemetry
from ..kernels import ref as K
from ..kernels.layout import (pack_mat_inner_blocks, pack_mat_row_blocks,
                              pack_rowwindow_blocks, pack_vec_blocks)


@dataclasses.dataclass
class AxisComm:
    """Per-machine-axis communication ledger for grid-distributed kernels.

    ``broadcast_bytes`` / ``reduce_bytes`` hold the TOTAL distinct payload
    moved along this axis (summed over the orthogonal axis's groups); each
    payload byte reaches / leaves ``size - 1`` peers, so the wire cost is
    ``payload * (size - 1)``. Attributing movement to the axis that carries
    it is what makes the SUMMA win visible: a 2-D SpMM broadcasts the dense
    operand's k-windows along x only and reduces output partials along y
    only, strictly less than 1-D's full replication at equal piece count."""

    size: int = 1
    broadcast_bytes: int = 0
    reduce_bytes: int = 0

    def network_bytes(self) -> int:
        return (self.broadcast_bytes + self.reduce_bytes) * \
            max(self.size - 1, 0)

    def as_dict(self) -> Dict[str, int]:
        return {"size": self.size, "broadcast_bytes": self.broadcast_bytes,
                "reduce_bytes": self.reduce_bytes,
                "network_bytes": self.network_bytes()}


@dataclasses.dataclass
class CommStats:
    """Communication model for the lowered kernel (drives §Roofline).

    ``replicate_bytes``: payload all-gathered to every color before the
    distributed loop (paper's `communicate` at the loop).
    ``reduce_bytes``: overlapping-output payload reduced after the loop
    (non-zero strategies).
    ``redistribute_bytes``: data-vs-computation distribution mismatch cost
    (paper §II-D final paragraph — legal but costed).
    ``axes``: per-machine-axis breakdown for grid (multi-axis) schedules —
    bytes live EITHER in the flat fields (1-D strategies) or in ``axes``
    (grid strategies), never both, so totals never double count.
    ``overlap_total_bytes`` / ``overlap_hidden_bytes``: set by the
    double-buffered executor (distributed.executor.run_overlapped) — how
    much of the shard-transfer traffic was in flight while a leaf kernel
    ran. Attribution only: these RE-DESCRIBE bytes already counted above,
    so they never enter ``total_network_bytes``."""

    pieces: int = 1
    replicate_bytes: int = 0
    reduce_bytes: int = 0
    redistribute_bytes: int = 0
    axes: Dict[str, AxisComm] = dataclasses.field(default_factory=dict)
    overlap_total_bytes: int = 0
    overlap_hidden_bytes: int = 0

    def total_network_bytes(self) -> int:
        # all-gather of b bytes to P nodes moves b*(P-1); reductions likewise
        p = max(self.pieces - 1, 0)
        return (self.replicate_bytes + self.reduce_bytes) * p + \
            self.redistribute_bytes + \
            sum(a.network_bytes() for a in self.axes.values())

    def as_dict(self) -> Dict[str, int]:
        out = {
            "pieces": self.pieces,
            "replicate_bytes": self.replicate_bytes,
            "reduce_bytes": self.reduce_bytes,
            "redistribute_bytes": self.redistribute_bytes,
            "total_network_bytes": self.total_network_bytes(),
        }
        if self.axes:
            out["axes"] = {n: a.as_dict() for n, a in self.axes.items()}
        if self.overlap_total_bytes:
            out["overlap_total_bytes"] = self.overlap_total_bytes
            out["overlap_hidden_bytes"] = self.overlap_hidden_bytes
        return out


# ---------------------------------------------------------------------------
# Re-plan fast path: plan memoization + compiled-runner reuse. Together with
# partition.SHARD_CACHE these make re-lowering over unchanged inputs
# near-free — the expensive assembly (partition walk, numpy shard packing,
# jit re-tracing) happens once; a straggler re-plan or repeated solve pays
# only content fingerprinting + execution.
# ---------------------------------------------------------------------------

# Memoized plans: (signature, strategy, pieces, weights, operand
# fingerprints) -> {name: TensorPartition}. An unchanged schedule over
# unchanged operands skips the partitioning walk entirely; _plans_equal is
# the differential check (tests assert a memoized plan equals a freshly
# computed one).
_PLAN_CACHE = LRUCache(capacity=64)
PLAN_CACHE_STATS = _PLAN_CACHE.stats

# Compiled runners: (emitter name, static trace constants, shard array
# shapes/dtypes) -> the jitted compute fn. The emitter name encodes
# expression × strategy × format family (bcsr emitters are distinct
# functions); shard avals subsume the declared-format component because the
# emitters are format-general once shards are materialized (the densified
# row-window view). Reusing the jitted callable object is what lets jax's
# compilation cache hit instead of re-tracing per lower.
_RUNNER_CACHE = LRUCache(capacity=128)
RUNNER_CACHE_STATS = _RUNNER_CACHE.stats


def set_plan_cache_capacity(capacity: int) -> None:
    _PLAN_CACHE.set_capacity(capacity)


def set_runner_cache_capacity(capacity: int) -> None:
    _RUNNER_CACHE.set_capacity(capacity)


def clear_lowering_caches() -> None:
    """Drop plan, runner, shard, tuned-plan, and SPMD-executable caches —
    the cold path, used by benchmarks to measure what re-lowering cost
    before the caches."""
    _PLAN_CACHE.clear()
    _RUNNER_CACHE.clear()
    clear_shard_cache()
    clear_convert_cache()
    import sys
    executor = sys.modules.get("repro.distributed.executor")
    if executor is not None:     # deferred: executor imports this module
        executor.clear_spmd_cache()
    plan_search = sys.modules.get("repro.core.plan_search")
    if plan_search is not None:  # deferred: the planner imports this module
        plan_search.clear_tuned_plan_cache()


@dataclasses.dataclass
class CacheStats:
    """Per-lower cache effectiveness, snapshotted onto LoweredKernel.cache
    (alongside CommStats): how much of this lower's plan / shard-packing /
    jit-tracing work was reused from previous lowers."""

    plan_hits: int = 0
    plan_misses: int = 0
    shard_hits: int = 0
    shard_misses: int = 0
    runner_hits: int = 0
    runner_misses: int = 0
    convert_hits: int = 0
    convert_misses: int = 0
    # schedule="auto" tuned-plan cache (core.plan_search): a hit means the
    # lower skipped the candidate search entirely.
    tuned_hits: int = 0
    tuned_misses: int = 0

    @property
    def shard_reuse(self) -> float:
        """Fraction of shard-cache lookups this lower served from cache —
        the elastic-resize metric (relower asserts ≥ 0.5 reuse on a
        migration-style P→P−1; bench_fault reports it). 0.0 when the
        lower did no shard lookups at all."""
        total = self.shard_hits + self.shard_misses
        return self.shard_hits / total if total else 0.0

    @property
    def warm(self) -> bool:
        """True when the lower re-assembled nothing (full fast path)."""
        return (self.plan_misses == 0 and self.shard_misses == 0
                and self.runner_misses == 0 and self.convert_misses == 0
                and self.tuned_misses == 0)

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


def _tuned_cache_stats() -> Dict[str, int]:
    """Tuned-plan cache counters, read lazily: plan_search imports this
    module, so lower only sees its stats once the planner is in use."""
    import sys
    plan_search = sys.modules.get("repro.core.plan_search")
    if plan_search is None:
        return {"hits": 0, "misses": 0}
    return plan_search.TUNED_PLAN_CACHE_STATS


def _cache_snapshot() -> Tuple[int, ...]:
    tuned = _tuned_cache_stats()
    return (PLAN_CACHE_STATS["hits"], PLAN_CACHE_STATS["misses"],
            SHARD_CACHE_STATS["hits"], SHARD_CACHE_STATS["misses"],
            RUNNER_CACHE_STATS["hits"], RUNNER_CACHE_STATS["misses"],
            CONVERT_CACHE_STATS["hits"], CONVERT_CACHE_STATS["misses"],
            tuned["hits"], tuned["misses"])


def _cache_delta(snap: Tuple[int, ...]) -> CacheStats:
    now = _cache_snapshot()
    d = [b - a for a, b in zip(snap, now)]
    return CacheStats(plan_hits=d[0], plan_misses=d[1], shard_hits=d[2],
                      shard_misses=d[3], runner_hits=d[4], runner_misses=d[5],
                      convert_hits=d[6], convert_misses=d[7],
                      tuned_hits=d[8], tuned_misses=d[9])


@dataclasses.dataclass
class LoweredKernel:
    """A compiled distributed sparse kernel + its plan artifacts.

    ``fallbacks`` records every operand the lowering engine had to convert
    because no direct kernel exists for its declared format (each entry is
    ``"name: <from> -> <to>"``); an empty list means the cell lowered
    directly. ``declared_formats`` keeps the structured form (operand name
    → declared format key) — the plans hold the CONVERTED tensors, so the
    declared key is only recoverable from here. The conformance matrix
    reports this census.
    """

    stmt: Assignment
    strategy: DistStrategy
    machine: Machine
    plans: Dict[str, TensorPartition]
    shards: Dict[str, ShardedTensor]
    runner: Callable[[], Any]
    comm: CommStats
    leaf_name: str
    fallbacks: List[str] = dataclasses.field(default_factory=list)
    declared_formats: Dict[str, str] = dataclasses.field(default_factory=dict)
    cache: CacheStats = dataclasses.field(default_factory=CacheStats)
    # schedule="auto" provenance: the winning plan_search.SchedulePoint
    # (estimated/measured costs, tile choice), None for hand schedules.
    tuned: Optional[Any] = None

    def run(self):
        return self.runner()

    def cell_id(self) -> str:
        """Conformance-matrix cell ID: ``<expr>/<format>/<strategy>/<mesh>``
        (e.g. ``spmm/dcsr/nnz/4x1``). The format component is the sparse
        operand's DECLARED format — a fallback cell keeps its declared key
        and is distinguished by a non-empty ``fallbacks`` list."""
        name = self._dist_sparse_name()
        key = "dense"
        if name is not None:
            key = self.declared_formats.get(
                name, fmt.format_key(self.plans[name].tensor.format))
        return (f"{expression_key(self.stmt.signature())}/{key}/"
                f"{self.strategy.space_label}/{self.strategy.mesh_label}")

    def imbalance(self) -> float:
        name = self._dist_sparse_name()
        return self.plans[name].imbalance() if name in self.plans else 0.0

    def _dist_sparse_name(self) -> Optional[str]:
        for acc in self.stmt.rhs.accesses():
            if acc.tensor.format.is_sparse:
                return acc.tensor.name
        return None

    def explain(self) -> str:
        """Human-readable plan provenance: what was chosen, what it costs,
        and — for ``schedule="auto"`` lowers — every candidate the
        autoscheduler scored and why this one won."""
        lines = [f"kernel {self.cell_id()}  leaf={self.leaf_name}",
                 f"  schedule: space={self.strategy.space} "
                 f"mesh={self.strategy.mesh_label} "
                 f"pieces={self.strategy.pieces}"]
        if self.fallbacks:
            lines.append("  fallbacks: " + "; ".join(self.fallbacks))
        t = self.tuned
        if t is not None:
            cands = getattr(t, "candidates", None) or []
            lines.append(
                f"  autoscheduler winner: {t.label} "
                f"est={t.est_cost_s:.3e}s"
                + (f" measured={t.measured_s:.3e}s"
                   if t.measured_s is not None else " (not measured)"))
            if cands:
                lines.append(f"  candidates scored: {len(cands)} "
                             "(model cost order; top-K measured)")
                for i, c in enumerate(cands):
                    meas = (f" measured={c['measured_s']:.3e}s"
                            if c.get("measured_s") is not None else "")
                    mark = " <- winner" if c["label"] == t.label else ""
                    lines.append(f"    {i + 1:2d}. {c['label']:<28s} "
                                 f"est={c['est_cost_s']:.3e}s{meas}{mark}")
        else:
            lines.append("  hand-picked schedule (no candidate search ran)")
        comm = self.comm
        if comm.axes:
            per_ax = ", ".join(
                f"{n}: bcast={a.broadcast_bytes} reduce={a.reduce_bytes}"
                for n, a in comm.axes.items())
            lines.append(f"  comm: {per_ax} "
                         f"(net={comm.total_network_bytes()})")
        else:
            lines.append(
                f"  comm: replicate={comm.replicate_bytes} "
                f"reduce={comm.reduce_bytes} "
                f"redistribute={comm.redistribute_bytes} "
                f"(net={comm.total_network_bytes()})")
        cs = self.cache
        lines.append(
            f"  cache: plan {cs.plan_hits}h/{cs.plan_misses}m, "
            f"shard {cs.shard_hits}h/{cs.shard_misses}m, "
            f"runner {cs.runner_hits}h/{cs.runner_misses}m, "
            f"tuned {cs.tuned_hits}h/{cs.tuned_misses}m"
            + (" [warm]" if cs.warm else ""))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def _scatter_rows(global_shape, blocks, row_start, row_count):
    """Assemble per-color padded row blocks into the global output (the
    inverse of the row partition; disjoint rows → add == set; overlapping
    rows (nnz strategy) → correct reduction)."""
    P, max_rows = blocks.shape[0], blocks.shape[1]
    out = jnp.zeros(global_shape, dtype=blocks.dtype)
    idx = row_start[:, None] + jnp.arange(max_rows, dtype=row_start.dtype)[None, :]
    mask = jnp.arange(max_rows)[None, :] < row_count[:, None]
    idx = jnp.clip(idx, 0, global_shape[0] - 1)
    flat_blocks = blocks.reshape((P * max_rows,) + blocks.shape[2:])
    flat_idx = idx.reshape(-1)
    flat_mask = mask.reshape(-1)
    mshape = (-1,) + (1,) * (blocks.ndim - 2)
    return out.at[flat_idx].add(flat_blocks * flat_mask.reshape(mshape).astype(blocks.dtype))


def _scatter_vals(total_nnz, val_blocks, nnz_start, nnz_count):
    P, max_nnz = val_blocks.shape
    out = jnp.zeros((total_nnz,), dtype=val_blocks.dtype)
    idx = nnz_start[:, None] + jnp.arange(max_nnz, dtype=nnz_start.dtype)[None, :]
    mask = jnp.arange(max_nnz)[None, :] < nnz_count[:, None]
    idx = jnp.clip(idx, 0, max(total_nnz - 1, 0))
    return out.at[idx.reshape(-1)].add((val_blocks * mask).reshape(-1))


def _nbytes(t: Tensor) -> int:
    if t.format.is_all_dense:
        return int(np.prod(t.shape)) * t.vals.dtype.itemsize
    if t.format.is_blocked:
        # block-granular payload: one (br, bc) tile + one block coord per
        # stored block position, plus the block-grid pos arrays
        tile = int(np.prod(t.format.block_shape)) * t.vals.dtype.itemsize
        n_blocks = int(t.vals.shape[0]) if t.vals.ndim else 0
        n = n_blocks * (tile + 4)
        for ld in t.levels:
            if ld.pos is not None:
                n += ld.pos.nbytes
        return n
    n = t.nnz * (t.vals.dtype.itemsize + 4)  # vals + one crd per level approx
    for ld in t.levels:
        if ld.pos is not None:
            n += ld.pos.nbytes
    return n


def _scatter_block_vals(total_blocks, tile_blocks, nnz_start, nnz_count):
    """Blocked value-region assembly: per-color (br, bc) output tiles into
    the global stored-block axis — ``_scatter_rows`` with the block axis as
    the row dimension."""
    br, bc = tile_blocks.shape[2], tile_blocks.shape[3]
    return _scatter_rows((max(total_blocks, 1), br, bc), tile_blocks,
                         nnz_start, nnz_count)[:total_blocks]


def _scatter_by_val_idx(total, out, val_idx, nnz_count):
    """Permuted value-region assembly: scatter per-color leaf outputs
    (scalar slots or (br, bc) tiles) home by their ``val_idx`` map —
    global storage positions recorded by a permuted (transpose) walk or a
    non-contiguous grid tiling. Padding slots are masked by ``nnz_count``.
    The trace-side twin of executor._assemble_vals."""
    mask = (jnp.arange(out.shape[1])[None, :]
            < nnz_count[:, None]).astype(out.dtype)
    idx = jnp.clip(val_idx, 0, max(total - 1, 0)).reshape(-1)
    m = mask.reshape(mask.shape + (1,) * (out.ndim - 2))
    flat = (out * m).reshape((-1,) + out.shape[2:])
    return jnp.zeros((total,) + out.shape[2:], out.dtype).at[idx].add(flat)


# ---------------------------------------------------------------------------
# Format dispatch: which kernel family handles a signature, and whether it
# supports a sparse operand's format directly (queried from the kernel
# modules themselves — the level-iterator capability contract lives with
# the leaves). Modules are resolved LAZILY: they import
# jax.experimental.pallas at top level, which interpret-only / planning-only
# users of core.lower should not pay for.
# ---------------------------------------------------------------------------

_SIG_KERNEL = {
    "d1(i)=s2(i,j)*d1(j)": ("spmv", "spmv"),
    "d2(i,j)=s2(i,k)*d2(k,j)": ("spmm", "spmm"),
    "s2(i,j)=s2(i,j)+s2(i,j)+s2(i,j)": ("spadd3", "spadd3"),
    "s2(i,j)=s2(i,j)*d2(i,k)*d2(k,j)": ("sddmm", "sddmm"),
    "s2(i,j)=s3(i,j,k)*d1(k)": ("spttv", "spmttkrp"),
    "d2(i,l)=s3(i,j,k)*d2(j,l)*d2(k,l)": ("spmttkrp", "spmttkrp"),
}


def _kernel_supports(module: str):
    import importlib
    return importlib.import_module(f"..kernels.{module}",
                                   package=__package__).supports


def expression_key(sig: str) -> str:
    """Short expression name for conformance cell IDs (``spmm`` in
    ``spmm/dcsr/nnz/4x1``); falls back to the raw signature."""
    entry = _SIG_KERNEL.get(sig)
    return entry[0] if entry else sig


def _normalize_operands(
    stmt: Assignment, space: str,
) -> Tuple[Assignment, List[str], Dict[str, str]]:
    """Format-conversion fallback (logged): every sparse rhs operand whose
    format the selected kernel family cannot iterate directly is converted
    to the canonical target (CSR / CSF). The returned statement is what the
    planner and emitters see; the fallback census (display strings + the
    structured name → declared-key map) is recorded on the LoweredKernel
    and surfaced by the conformance matrix."""
    sig = stmt.signature()
    entry = _SIG_KERNEL.get(sig)
    if entry is None:
        return stmt, [], {}
    kernel_name, module = entry
    supports = _kernel_supports(module)
    mapping: Dict[str, Tensor] = {}
    fallbacks: List[str] = []
    declared: Dict[str, str] = {}
    # Blocked operands of a multi-operand family (spadd3) must share ONE
    # block layout — the tile-union leaves merge tiles positionally. Mixed
    # layouts force the blocked operands through the conversion fallback.
    sparse_ops = {acc.tensor.name: acc.tensor for acc in stmt.rhs.accesses()
                  if acc.tensor.format.is_sparse}
    force_convert: set = set()
    if (len(sparse_ops) > 1
            and any(t.format.is_blocked for t in sparse_ops.values())
            and len({t.format for t in sparse_ops.values()}) > 1):
        force_convert = {name for name, t in sparse_ops.items()
                         if t.format.is_blocked}
    for acc in stmt.rhs.accesses():
        t = acc.tensor
        if not t.format.is_sparse or t.name in mapping:
            continue
        if supports(t.format, space) and t.name not in force_convert:
            continue
        if not isinstance(t, Tensor):   # TensorVar dry-run: nothing to convert
            continue
        target = fmt.conversion_target(t.format)
        declared[t.name] = fmt.format_key(t.format)
        fallbacks.append(
            f"{t.name}: {fmt.format_key(t.format)} -> {fmt.format_key(target)}")
        log.warning(
            "no direct %s/%s kernel for %s stored as %s; converting to %s "
            "(conformance cell falls back)",
            kernel_name, space, t.name, t.format, target)
        mapping[t.name] = convert_tensor_cached(t, target)
    return stmt.with_tensors(mapping), fallbacks, declared


# ---------------------------------------------------------------------------
# The lowering entry point
# ---------------------------------------------------------------------------

def lower(
    stmt: Assignment,
    machine: Machine,
    schedule: Union[Schedule, str, None] = None,
    distributions: Optional[Dict[str, Distribution]] = None,
    jit: bool = True,
    weights: Optional[np.ndarray] = None,
    *,
    elastic: bool = False,
    init_bounds: Optional[np.ndarray] = None,
) -> LoweredKernel:
    """Compile a scheduled TIN statement into a distributed executable.

    ``schedule`` may be a hand-built :class:`Schedule`, ``None`` (the
    default 1-D row schedule), or the string ``"auto"`` — the
    cost-model-driven autoscheduler (:mod:`repro.core.plan_search`)
    enumerates strategy × grid-factorization × tile candidates, scores
    them with structural stats + the per-axis byte formulas, optionally
    refines the top-K by timing, and memoizes the winner in a tuned-plan
    cache keyed by content fingerprints (hits observable as
    ``kernel.cache.tuned_hits``).

    ``distributions`` declares the *data* distribution per tensor (TDN). The
    *computation* distribution comes from the schedule. Where they disagree
    the kernel stays correct but `comm.redistribute_bytes` charges the
    reshuffle (paper §II-D).

    ``weights`` (pieces,) skews the non-zero splits toward faster shards —
    the straggler re-plan (runtime/fault.StragglerMitigator emits them;
    re-lowering with new weights is the re-plan, and the plan/shard/runner
    caches make everything the weights did NOT change near-free). Ignored
    by universe (rows) schedules, whose splits are coordinate-driven.

    ``elastic=True`` routes 1-D materialization through PER-PIECE shard
    caching (partition.materialize_pieces): each color is its own
    SHARD_CACHE entry, so a later :func:`relower` onto a resized machine
    reuses every color whose window the resize left alone. The stacked
    arrays are bit-for-bit the whole-set materializers' output (runners
    are shared); the cost is per-color cache keys, so the default path
    keeps its one-entry-per-tensor accounting. ``init_bounds`` (pieces, 2)
    overrides the initial equal split — the elastic-resize entry point
    feeds merged survivor windows here (see relower)."""
    with fingerprint_memo(), telemetry.span(
            "lower", sig=stmt.signature()) as sp:
        k = _lower_impl(stmt, machine, schedule, distributions, jit,
                        weights, elastic=elastic, init_bounds=init_bounds)
        sp.set(cell=k.cell_id(), leaf=k.leaf_name,
               pieces=k.strategy.pieces, warm=k.cache.warm)
        _record_lower_metrics(k)
        return k


def _record_lower_metrics(k: "LoweredKernel") -> None:
    """Fold one lower's cache delta and communication ledger into the
    process metrics registry (+ a trace instant with the cache delta)."""
    cs = k.cache
    for field, v in (("plan", cs.plan_hits), ("shard", cs.shard_hits),
                     ("runner", cs.runner_hits), ("convert", cs.convert_hits),
                     ("tuned", cs.tuned_hits)):
        if v:
            telemetry.METRICS.counter(f"lower.cache.{field}.hits", v)
    for field, v in (("plan", cs.plan_misses), ("shard", cs.shard_misses),
                     ("runner", cs.runner_misses),
                     ("convert", cs.convert_misses),
                     ("tuned", cs.tuned_misses)):
        if v:
            telemetry.METRICS.counter(f"lower.cache.{field}.misses", v)
    telemetry.METRICS.counter("lower.count")
    if k.cache.warm:
        telemetry.METRICS.counter("lower.warm_count")
    comm = k.comm
    if comm.axes:
        for name, ax in comm.axes.items():
            telemetry.METRICS.counter(f"comm.axis.{name}.broadcast_bytes",
                                      ax.broadcast_bytes)
            telemetry.METRICS.counter(f"comm.axis.{name}.reduce_bytes",
                                      ax.reduce_bytes)
    else:
        telemetry.METRICS.counter("comm.replicate_bytes",
                                  comm.replicate_bytes)
        telemetry.METRICS.counter("comm.reduce_bytes", comm.reduce_bytes)
    telemetry.METRICS.counter("comm.network_bytes",
                              comm.total_network_bytes())
    telemetry.instant("lower.cache", **cs.as_dict())


def _lower_impl(stmt, machine, schedule, distributions, jit, weights,
                elastic=False, init_bounds=None):
    snap = _cache_snapshot()
    tuned_point = None
    if isinstance(schedule, str):
        if schedule != "auto":
            raise ValueError(
                f"unknown schedule string {schedule!r}; pass a Schedule, "
                "None, or 'auto'")
        from . import plan_search
        schedule, machine, tuned_point = plan_search.resolve_auto(
            stmt, machine, weights=weights, jit=jit)
    if schedule is None:
        schedule = default_row_schedule(stmt, machine)
    strat = schedule.strategy()
    pieces = strat.pieces
    sig = stmt.signature()

    # Format dispatch: convert operands with no direct kernel (logged).
    stmt, fallbacks, declared_formats = _normalize_operands(stmt, strat.space)

    # Multi-axis (grid) universe schedules route to the grid subsystem:
    # cross-product tile plans, per-axis communication, SUMMA-style
    # emitters. Grid NON-ZERO schedules fall through — a nested pos-split
    # canonicalizes to the flat equal split of the fused position space
    # (pieces = P*Q), so the 1-D nnz machinery lowers them bit-for-bit
    # identically; only the communication attribution (below) and the SPMD
    # mesh shape differ.
    if strat.is_grid and strat.space == "universe":
        from . import grid as grid_mod
        k = grid_mod.lower_grid(stmt, machine, strat, jit=jit,
                                fallbacks=fallbacks,
                                declared_formats=declared_formats,
                                snap=snap, distributions=distributions)
        k.tuned = tuned_point
        return k

    out_t: Tensor = stmt.lhs.tensor
    shards: Dict[str, ShardedTensor] = {}
    comm = CommStats(pieces=pieces)

    # ---- Step 1 & 2 of Fig. 9a: initial + derived partitions --------------
    # Memoized on (signature, strategy, operand fingerprints, weights): an
    # unchanged schedule over unchanged operands skips partitioning.
    plan_span = telemetry.span("lower.plan", sig=sig, space=strat.space,
                               pieces=pieces)
    plan_span.__enter__()
    plan_key = _plan_cache_key(stmt, strat, weights, init_bounds)
    plans = _PLAN_CACHE.get(plan_key) if plan_key is not None else None
    telemetry.instant("lower.plan.cache",
                      hit=plans is not None, memoizable=plan_key is not None)
    if plans is not None:
        # Rebind each memoized plan to the CURRENT statement's tensor
        # objects: the cached plans pin the objects from the lower that
        # populated them, and the key only proves the current tensors'
        # content — a pinned object may have been mutated in place since.
        current: Dict[str, Tensor] = {}
        for acc in stmt.accesses():
            current.setdefault(acc.tensor.name, acc.tensor)
        plans = {name: dataclasses.replace(p, tensor=current[name])
                 for name, p in plans.items()}
    else:
        plans = _compute_plans(stmt, strat, out_t, weights, init_bounds)
        if plan_key is not None:
            # Stored without tensor refs: the cache holds only the small
            # bounds arrays instead of pinning O(nnz) storage of up to
            # `capacity` statements; hits rebind (above) by name, and
            # every plan name is an access name by construction.
            _PLAN_CACHE.put(plan_key, {
                name: dataclasses.replace(p, tensor=None)
                for name, p in plans.items()})
    plan_span.__exit__(None, None, None)

    # ---- materialize -------------------------------------------------------
    mat_span = telemetry.span("lower.materialize", sig=sig, pieces=pieces)
    mat_span.__enter__()
    if (sig, strat.space) in _SELF_MATERIALIZING:
        # spadd3/nnz: the emitter consumes equal (or straggler-weighted)
        # chunks of the CONCATENATED stored-entry stream, packed by the
        # materialization layer (cached — a weighted re-plan re-slices the
        # cached stream). Comm = every chunk's union ships to the root for
        # the cross-chunk merge — coords+vals per entry, a whole (br, bc)
        # tile per entry for blocked operands.
        add_tensors, seen = [], set()
        for acc in stmt.rhs.accesses():
            t = acc.tensor
            if t.format.is_sparse and t.name not in seen:
                seen.add(t.name)
                add_tensors.append(t)
        shards["_addstream"] = materialize_add_stream(add_tensors, pieces,
                                                      weights)
        n_entries = shards["_addstream"].meta["n_entries"]
        if add_tensors and add_tensors[0].format.is_blocked:
            tile = int(np.prod(add_tensors[0].format.block_shape))
            comm.reduce_bytes += n_entries * (8 + tile * 4)
        else:
            comm.reduce_bytes += n_entries * 12
    for name, plan in plans.items():
        t = plan.tensor
        if (sig, strat.space) in _SELF_MATERIALIZING:
            continue  # the emitter packs its own chunks (spadd3/nnz)
        if name == out_t.name and _output_is_assembled(sig):
            continue  # outputs assembled from leaf results, not materialized
        if plan.replicated:
            shards[name] = (materialize_replicated_elastic(t, pieces)
                            if elastic else materialize_replicated(t, pieces))
            comm.replicate_bytes += _nbytes(t)
        elif strat.space == "nnz" and t.format.is_sparse:
            kind = "bcsr_nnz" if t.format.is_blocked else "coo_nnz"
            shards[name] = (materialize_pieces(kind, t, plan) if elastic
                            else (materialize_bcsr_nnz(t, plan)
                                  if t.format.is_blocked
                                  else materialize_coo_nnz(t, plan)))
        elif (t.format.is_sparse and not t.format.is_blocked
                and t.order >= 3 and t.format.levels[1].singleton):
            # trailing-singleton trees (COO3) have no grouped middle level:
            # the universe row plan materializes the FLAT walk (coordinate
            # columns bucketed by row window) and the flat leaves consume it
            shards[name] = (materialize_pieces("coo_nnz", t, plan) if elastic
                            else materialize_coo_nnz(t, plan))
        elif t.format.is_all_dense:
            shards[name] = (
                materialize_dense_rows_pieces(t, plan.root_coord_bounds)
                if elastic
                else materialize_dense_rows(t, plan.root_coord_bounds))
        elif t.format.is_blocked:
            shards[name] = (materialize_pieces("bcsr_rows", t, plan)
                            if elastic else materialize_bcsr_rows(t, plan))
        else:
            shards[name] = (materialize_pieces("csr_rows", t, plan)
                            if elastic else materialize_csr_rows(t, plan))

    # data-vs-computation distribution mismatch cost (C4)
    if distributions:
        for name, d in distributions.items():
            want = plans.get(name)
            if want is None or want.replicated:
                continue
            have = d.plan(plans[name].tensor)
            if not _plans_equal(want, have):
                comm.redistribute_bytes += _nbytes(plans[name].tensor)

    if strat.space == "nnz" and (sig, strat.space) not in _SELF_MATERIALIZING:
        ov = plans[next(iter(plans))]  # position tensor plan
        if ov.tensor.format.dim_of_level(0) != 0:
            # storage root doesn't track output rows (CSC, BCSC): every
            # color reduces a FULL-extent output partial (see
            # _nnz_row_windows / _bcsr_nnz_windows). reduce_bytes is the
            # per-reduction payload; total_network_bytes multiplies by
            # (pieces-1).
            comm.reduce_bytes += _nbytes(out_t)
        elif ov.tensor.format.is_blocked:
            # overlapping BLOCK-rows reduce across colors; the payload per
            # overlapped block-row is its br-row output stripe
            bb = ov.levels[0].coord_bounds
            br = ov.tensor.format.block_shape[0]
            comm.reduce_bytes += int(
                (bb[:, 1] - bb[:, 0]).sum()
                - (bb[:, 1].max() - bb[:, 0].min())
            ) * br * 4
        else:
            # overlapping output rows reduced across colors
            comm.reduce_bytes += int(
                (ov.root_coord_bounds[:, 1] - ov.root_coord_bounds[:, 0]).sum()
                - (ov.root_coord_bounds[:, 1].max()
                   - ov.root_coord_bounds[:, 0].min())
            ) * 4

    # Grid nnz schedules: re-attribute the flat replicate/reduce payload to
    # the machine axes under the hierarchical collective model (broadcast:
    # along x once, then along y within each of the P grid rows; reduce in
    # reverse) — totals are unchanged (b*(PQ-1)), the per-axis ledger is
    # what the comm-volume benches and the SPMD psum scoping read.
    if strat.is_grid:
        m = 1
        axes = {}
        for d in strat.machine_dims:
            axes[d.name] = AxisComm(size=d.size,
                                    broadcast_bytes=m * comm.replicate_bytes,
                                    reduce_bytes=m * comm.reduce_bytes)
            m *= d.size
        comm.axes = axes
        comm.replicate_bytes = 0
        comm.reduce_bytes = 0
    mat_span.__exit__(None, None, None)

    # ---- emit: pick leaf + build runner ------------------------------------
    with telemetry.span("lower.emit", sig=sig, space=strat.space) as esp:
        leaf_name, runner = _emit(stmt, strat, plans, shards, jit=jit)
        esp.set(leaf=leaf_name)
    return LoweredKernel(
        stmt=stmt, strategy=strat, machine=machine, plans=plans,
        shards=shards, runner=runner, comm=comm, leaf_name=leaf_name,
        fallbacks=fallbacks, declared_formats=declared_formats,
        cache=_cache_delta(snap), tuned=tuned_point,
    )


def _plan_cache_key(stmt: Assignment, strat: DistStrategy,
                    weights: Optional[np.ndarray],
                    init_bounds: Optional[np.ndarray] = None,
                    ) -> Optional[Tuple]:
    """Memoization key for the partitioning step: signature + strategy +
    per-operand content fingerprints (+ straggler weights + elastic
    init-bounds override). None disables caching (dry-run TensorVar
    operands have no storage to fingerprint)."""
    ops = []
    for acc in stmt.accesses():
        t = acc.tensor
        if not isinstance(t, Tensor):
            return None
        ops.append((t.name, tensor_fingerprint(t),
                    tuple(v.name for v in acc.idx)))
    from .partition import _crc_arrays
    init_crc = (None if init_bounds is None
                else _crc_arrays(0, np.asarray(init_bounds, dtype=np.int64)))
    return (stmt.signature(), strat.space,
            tuple(v.name for v in strat.vars),
            tuple(d.size for d in strat.machine_dims),
            tuple(strat.replicate),
            weights_fingerprint(weights), init_crc, tuple(ops))


def _compute_plans(stmt: Assignment, strat: DistStrategy, out_t: Tensor,
                   weights: Optional[np.ndarray],
                   init_bounds: Optional[np.ndarray] = None,
                   ) -> Dict[str, TensorPartition]:
    """Fig. 9a steps 1 & 2: initial + derived coordinate-tree partitions.

    ``init_bounds`` replaces the equal initial split (universe: root
    coordinate windows; nnz: split-level position windows) with
    caller-supplied windows — relower's migration bounds, already
    block-aligned because they come from a previous plan of the same
    operands."""
    plans: Dict[str, TensorPartition] = {}
    pieces = strat.pieces
    sig = stmt.signature()
    dist_var = strat.var
    if strat.space == "universe":
        # coordinate-value loop -> createInitialUniversePartitions
        n = stmt.var_extent(dist_var)
        if init_bounds is not None:
            bounds = np.asarray(init_bounds, dtype=np.int64)
        else:
            bounds = partition_by_bounds(n, pieces)
            # A blocked operand distributed on its row dimension snaps the
            # universe split to block-row boundaries so EVERY co-partitioned
            # tensor (dense row operands, the output) shares the same
            # per-color row windows — whichever level stores the rows (BCSR
            # and BCSC).
            for acc in stmt.rhs.accesses():
                t = acc.tensor
                if (t.format.is_sparse and t.format.is_blocked
                        and dist_var in acc.idx
                        and acc.idx.index(dist_var) == 0):
                    bounds = block_aligned_row_bounds(
                        n, pieces, t.format.block_shape[0])
                    break
        for acc in stmt.accesses():
            t = acc.tensor
            if t.name in plans:
                continue
            if dist_var in acc.idx:
                lvl_dim = acc.idx.index(dist_var)
                if t.format.level_of_dim(lvl_dim) == 0:
                    # distributed dim at the storage root: the image chain
                    plans[t.name] = partition_tensor_rows(t, bounds)
                    continue
                if lvl_dim == 0 and t.format.is_sparse:
                    # column-major root (CSC/BCSC): the transpose walk
                    # realizes the same row windows (partition routes it)
                    plans[t.name] = partition_tensor_rows(t, bounds)
                    continue
            # not indexed by the distributed var at the root -> communicate
            # fetches the whole tensor per color (replication)
            plans[t.name] = replicate_tensor(t, pieces)
    elif (sig, strat.space) in _SELF_MATERIALIZING:
        # spadd3/nnz: plan each operand's equal nnz split (imbalance ~0 by
        # construction); the packed chunk shards come from the
        # materialization layer at materialize time.
        for acc in stmt.rhs.accesses():
            t = acc.tensor
            if t.name in plans:
                continue
            if t.format.is_sparse:
                plans[t.name] = partition_tensor_nonzeros(t, pieces)
            else:
                plans[t.name] = replicate_tensor(t, pieces)
    else:
        # coordinate-position loop -> createInitialNonZeroPartition of the
        # position-space (sparse) tensor, then partition the remaining
        # coordinate trees from its derived root partition.
        pos_tensor = None
        for acc in stmt.rhs.accesses():
            if acc.tensor.format.is_sparse:
                pos_tensor = acc.tensor
                break
        if pos_tensor is None:
            raise ValueError("nnz schedule requires a sparse rhs tensor")
        p = partition_tensor_nonzeros(pos_tensor, pieces, weights,
                                      init_bounds=init_bounds)
        plans[pos_tensor.name] = p
        root_bounds = p.root_coord_bounds
        for acc in stmt.accesses():
            t = acc.tensor
            if t.name in plans:
                continue
            if (t is out_t and not t.format.is_sparse
                    and stmt.lhs.idx
                    and stmt.lhs.idx[0] == pos_tensor_root_var(stmt, pos_tensor)):
                plans[t.name] = partition_tensor_rows(t, root_bounds)
            else:
                plans[t.name] = replicate_tensor(t, pieces)
    return plans


def pos_tensor_root_var(stmt: Assignment, pos_tensor: Tensor) -> IndexVar:
    """The index variable iterated at the tensor's STORAGE root level (for
    CSC that is the column variable — non-zero partitions then own column
    windows, and output-row locality is gone)."""
    for acc in stmt.rhs.accesses():
        if acc.tensor is pos_tensor:
            return acc.idx[pos_tensor.format.dim_of_level(0)]
    raise KeyError(pos_tensor.name)


def _output_is_assembled(sig: str) -> bool:
    # sparse outputs (sddmm, spttv, spadd3) are assembled from leaf results
    return sig.startswith("s")


# (sig, space) pairs whose emitter packs its own shard chunks at emit time
# (no per-tensor materialization wanted; see _emit_spadd3_nnz).
_SELF_MATERIALIZING = {
    ("s2(i,j)=s2(i,j)+s2(i,j)+s2(i,j)", "nnz"),
}


def _plans_equal(a: TensorPartition, b: TensorPartition) -> bool:
    if a.replicated != b.replicated:
        return False
    if (a.vals_bounds is None) != (b.vals_bounds is None):
        return False
    if a.vals_bounds is not None and not np.array_equal(a.vals_bounds, b.vals_bounds):
        return False
    if (a.root_coord_bounds is None) != (b.root_coord_bounds is None):
        return False
    if a.root_coord_bounds is not None and \
            not np.array_equal(a.root_coord_bounds, b.root_coord_bounds):
        return False
    return True


def default_row_schedule(stmt: Assignment, machine: Machine) -> Schedule:
    """The paper's Fig. 1 schedule generalized: divide the first result
    variable over the machine's first dimension, distribute, communicate."""
    i = stmt.result_vars[0]
    io, ii = IndexVar(f"{i.name}o"), IndexVar(f"{i.name}i")
    s = Schedule(stmt, machine)
    s.divide(i, io, ii, machine.dims[0]).distribute(io)
    s.communicate(stmt.tensors(), io)
    return s


def default_nnz_schedule(stmt: Assignment, machine: Machine) -> Schedule:
    """Fuse all sparse loops and split non-zeros evenly (paper §II-D)."""
    spa = stmt.sparse_accesses()[0]
    s = Schedule(stmt, machine)
    vs = list(spa.idx)
    f = vs[0]
    for v in vs[1:]:
        nf = IndexVar(f"{f.name}{v.name}")
        s.fuse(f, v, nf)
        f = nf
    fo, fi = IndexVar(f"{f.name}o"), IndexVar(f"{f.name}i")
    s.pos_split(f, fo, fi, machine.dims[0]).distribute(fo)
    s.communicate(stmt.tensors(), fo)
    return s


def default_grid_schedule(stmt: Assignment, machine: Machine) -> Schedule:
    """2-D universe schedule — the paper's ``distribute((i, k) → (x, y))``:
    divide the sparse operand's two index variables over the machine's two
    dimensions and distribute both, tiling the operand onto the processor
    grid (SUMMA-style for SpMM/SpMV, owner-computes tiles for SDDMM)."""
    spa = stmt.sparse_accesses()[0]
    if len(spa.idx) < 2 or len(machine.dims) < 2:
        raise ValueError("grid schedule needs a 2-D sparse operand and a "
                         "2-D machine")
    i, k2 = spa.idx[0], spa.idx[1]
    io, ii = IndexVar(f"{i.name}o"), IndexVar(f"{i.name}i")
    ko, ki = IndexVar(f"{k2.name}o"), IndexVar(f"{k2.name}i")
    s = Schedule(stmt, machine)
    s.divide(i, io, ii, machine.dims[0])
    s.divide(k2, ko, ki, machine.dims[1])
    s.distribute(io, ko)
    s.communicate(stmt.tensors(), io)
    return s


def default_grid_nnz_schedule(stmt: Assignment, machine: Machine) -> Schedule:
    """2-D non-zero schedule: fuse the sparse loops, then NEST the position
    split over both machine dimensions — color (p, q) owns block p*Q+q of
    the fused non-zero stream (canonically equal to the flat P*Q split, so
    2-D nnz cells are bit-for-bit their Px1 counterparts)."""
    if len(machine.dims) < 2:
        raise ValueError("grid nnz schedule needs a 2-D machine")
    spa = stmt.sparse_accesses()[0]
    s = Schedule(stmt, machine)
    vs = list(spa.idx)
    f = vs[0]
    for v in vs[1:]:
        nf = IndexVar(f"{f.name}{v.name}")
        s.fuse(f, v, nf)
        f = nf
    outers = []
    cur = f
    for d in machine.dims:
        co, ci = IndexVar(f"{cur.name}o"), IndexVar(f"{cur.name}i")
        s.pos_split(cur, co, ci, d)
        outers.append(co)
        cur = ci
    s.distribute(*outers)
    s.communicate(stmt.tensors(), outers[0])
    return s


def default_grid3_schedule(stmt: Assignment, machine: Machine) -> Schedule:
    """3-D universe schedule over an order-3 machine grid. An order-3
    sparse operand maps its three index variables onto the three machine
    dimensions (P×Q×R COO bricks); an order-2 operand nests a second
    divide of its column variable so the grid reads ``i → x, j → (y, z)``
    (the joint Q·R column split used by spadd3)."""
    if len(machine.dims) < 3:
        raise ValueError("grid3 schedule needs a 3-D machine")
    spa = stmt.sparse_accesses()[0]
    s = Schedule(stmt, machine)
    if len(spa.idx) >= 3:
        outers = []
        for v, d in zip(spa.idx[:3], machine.dims[:3]):
            vo, vi = IndexVar(f"{v.name}o"), IndexVar(f"{v.name}i")
            s.divide(v, vo, vi, d)
            outers.append(vo)
        s.distribute(*outers)
        s.communicate(stmt.tensors(), outers[0])
        return s
    i, j = spa.idx[0], spa.idx[1]
    io, ii = IndexVar(f"{i.name}o"), IndexVar(f"{i.name}i")
    jo, ji = IndexVar(f"{j.name}o"), IndexVar(f"{j.name}i")
    jio, jii = IndexVar(f"{ji.name}o"), IndexVar(f"{ji.name}i")
    s.divide(i, io, ii, machine.dims[0])
    s.divide(j, jo, ji, machine.dims[1])
    s.divide(ji, jio, jii, machine.dims[2])
    s.distribute(io, jo, jio)
    s.communicate(stmt.tensors(), io)
    return s


def default_replicated_schedule(stmt: Assignment, machine: Machine) -> Schedule:
    """2.5-D communication-avoiding schedule: tile the sparse operand over
    the first two machine dimensions (as the 2-D grid schedule does) and
    split the remaining dense loop variable over the third, replicating
    the sparse operand along it — each z-layer computes a disjoint slab of
    the dense contraction, so the cross-grid reduction shrinks from a
    (Q·R−1)-hop all-reduce to (Q−1) hops at the cost of broadcasting the
    sparse operand R−1 extra times."""
    if len(machine.dims) < 3:
        raise ValueError("replicated schedule needs a 3-D machine")
    spa = stmt.sparse_accesses()[0]
    v0, v1 = spa.idx[0], spa.idx[1]
    rest = [v for v in stmt.all_vars if v not in spa.idx]
    if not rest:
        raise ValueError("replicated schedule needs a loop variable outside "
                         "the sparse operand's index set")
    v2 = rest[0]
    s = Schedule(stmt, machine)
    outers = []
    for v, d in zip((v0, v1, v2), machine.dims[:3]):
        vo, vi = IndexVar(f"{v.name}o"), IndexVar(f"{v.name}i")
        s.divide(v, vo, vi, d)
        outers.append(vo)
    s.distribute(*outers)
    s.replicate([spa.tensor], machine.dims[2])
    s.communicate(stmt.tensors(), outers[0])
    return s


# ---------------------------------------------------------------------------
# Elastic re-plan: mesh-as-data. A Schedule traces against ONE machine, but
# the STRATEGY it canonicalizes to is plain data (space, grid rank,
# replication, tile) — so moving a lowered kernel to a different machine is
# a pure function of (strategy, new machine), not a re-trace of user
# schedule code. relower() is the elastic entry point: rebuild the
# schedule family on the new machine, derive migration-friendly initial
# bounds, and re-lower with per-piece shard caching so everything the
# resize did not touch is a cache hit.
# ---------------------------------------------------------------------------


def rebuild_schedule(stmt: Assignment, machine: Machine,
                     strat: DistStrategy) -> Schedule:
    """Re-instantiate ``strat``'s schedule family against a NEW machine —
    the same reconstruction the autoscheduler's SchedulePoint.build uses
    (core/plan_search.py), driven here by an existing strategy instead of
    a search candidate."""
    nd = len(machine.dims)
    if strat.replicate and nd >= 3:
        s = default_replicated_schedule(stmt, machine)
    elif nd >= 3:
        s = default_grid3_schedule(stmt, machine)
    elif nd == 2:
        s = (default_grid_schedule(stmt, machine)
             if strat.space == "universe"
             else default_grid_nnz_schedule(stmt, machine))
    elif strat.space == "universe":
        s = default_row_schedule(stmt, machine)
    else:
        s = default_nnz_schedule(stmt, machine)
    if strat.tile is not None:
        s.tile_hint(*strat.tile)
    return s


def _elastic_init_bounds(kernel: LoweredKernel) -> Optional[np.ndarray]:
    """The initial split the kernel's plans were derived from: universe →
    the (block-aligned) root row windows; nnz → the position tensor's
    split-level windows (== vals_bounds under full fusion / block split).
    None when no migration-style reuse applies (grids, spadd3/nnz whose
    per-operand splits are independent)."""
    strat = kernel.strategy
    if strat.is_grid:
        return None
    if (kernel.stmt.signature(), strat.space) in _SELF_MATERIALIZING:
        return None
    if strat.space == "universe":
        for p in kernel.plans.values():
            if not p.replicated and p.root_coord_bounds is not None:
                return np.asarray(p.root_coord_bounds, dtype=np.int64)
        return None
    for acc in kernel.stmt.rhs.accesses():
        if acc.tensor.format.is_sparse:
            p = kernel.plans.get(acc.tensor.name)
            if p is not None and p.vals_bounds is not None:
                return np.asarray(p.vals_bounds, dtype=np.int64)
            return None
    return None


def relower(kernel: LoweredKernel, new_machine: Machine, *,
            dead: Optional[int] = None,
            weights: Optional[np.ndarray] = None,
            jit: bool = True) -> LoweredKernel:
    """Re-plan a lowered kernel for a DIFFERENT machine — shrunk, grown,
    or re-factorized — reusing every cache entry the resize leaves valid.

    ``dead`` names the lost piece for a P→P−1 shrink: its window is merged
    into a neighbor (partition.elastic_row_bounds) instead of re-splitting
    equally, so P−2 of the surviving windows — and their per-piece shard
    cache entries, seeded by a previous ``lower(..., elastic=True)`` — are
    bitwise unchanged. Reuse is observable as ``kernel.cache.shard_reuse``
    (≥ 50% asserted in tests/bench for row-split resizes). Without
    ``dead`` (or for grids / weighted re-plans) the new machine gets a
    fresh equal split; replicated operands still hit regardless.

    ``weights`` forwards to the straggler re-plan path — e.g.
    ``relower(kernel, kernel.machine, weights=w)`` re-balances in place
    on the SAME machine."""
    stmt = kernel.stmt
    old = kernel.strategy
    schedule = rebuild_schedule(stmt, new_machine, old)
    new_strat = schedule.strategy()
    init = None
    if (dead is not None and weights is None
            and not old.is_grid and not new_strat.is_grid
            and new_strat.space == old.space
            and new_strat.pieces == old.pieces - 1):
        ob = _elastic_init_bounds(kernel)
        if ob is not None and ob.shape[0] == old.pieces:
            init = elastic_row_bounds(ob, dead)
    return lower(stmt, new_machine, schedule=schedule, jit=jit,
                 weights=weights, elastic=True, init_bounds=init)


# ---------------------------------------------------------------------------
# Leaf emission — ONE format-generic emitter per expression × strategy,
# parameterized by the operands' LEVEL TREES (core/levels.py). An emitter
# never asks "which format?"; it asks the tree which walk the shards were
# materialized from — blocked (tile leaves), grouped (pos/crd leaves), flat
# trailing-singleton (coordinate-column leaves) — and whether the walk was
# permuted (``val_idx`` scatter maps from the transpose walk). Every
# emitter returns ``(leaf_name, runner)``; the leaf name records the
# selected leaf family and is the SPMD builder dispatch key
# (distributed/executor.py SPMD_BUILDERS).
# ---------------------------------------------------------------------------

def _emit(stmt, strat, plans, shards, jit=True) -> Tuple[str, Callable]:
    sig = stmt.signature()
    emitter = _EMITTERS.get((sig, strat.space))
    if emitter is None:
        return (f"generic[{sig}|{strat.space}]",
                _emit_generic_fallback(stmt, strat, plans, shards, jit=jit))
    return emitter(stmt, strat, plans, shards, jit=jit)


def _runner(jit, name, static, arrays, build):
    """Compiled-runner cache front-end used by every emitter.

    ``build()`` returns the raw compute fn; all per-lower DATA must flow
    through its arguments (``arrays`` is the argument prototype used for the
    shapes/dtypes key component) and every Python constant baked into the
    trace must be listed in ``static``. On a key match the previously
    jitted callable is returned, so jax's compilation cache hits instead of
    re-tracing — this is what makes a warm re-lower skip compilation."""
    if not jit:
        return build()
    key = (name, tuple(static), avals_key(arrays))

    def _jit_build():
        with telemetry.span("lower.jit", leaf=name):
            return jax.jit(build())

    return _RUNNER_CACHE.get_or_build(key, _jit_build)


def _nnz_row_windows(B: ShardedTensor, n: int):
    """Row-window parameters for a flat (coordinate-column) shard set.
    When the storage root tracks output rows — row-major nnz splits AND
    universe flat walks, whose windows are then disjoint — leaves compute
    into the shard's root window; otherwise (CSC) every shard computes a
    full-extent partial and the scatter reduces the overlap."""
    a = B.arrays
    if B.meta.get("root_dim", 0) == 0 and B.meta["max_rows"] > 0:
        return a["row_start"], a["row_count"], int(B.meta["max_rows"])
    pieces = B.pieces
    row_start = jnp.zeros((pieces,), dtype=jnp.int32)
    row_count = jnp.full((pieces,), n, dtype=jnp.int32)
    return row_start, row_count, int(n)


def _bcsr_nnz_windows(B: ShardedTensor):
    """Block-row window parameters for a blocked nnz shard set. Column-
    major roots (BCSC — the root tracks block-columns) and empty shard
    sets fall back to full-grid windows, so leaves reduce over the whole
    block grid and clip bounds / segment counts stay positive."""
    a = B.arrays
    max_brows = int(B.meta["max_brows"])
    if B.meta.get("root_dim", 0) == 0 and max_brows > 0:
        return a["brow_start"], a["row_start"], a["row_count"], max_brows
    pieces = B.pieces
    n = int(B.meta["n_rows"])
    brow_start = jnp.zeros((pieces,), dtype=jnp.int32)
    row_start = jnp.zeros((pieces,), dtype=jnp.int32)
    row_count = jnp.full((pieces,), n, dtype=jnp.int32)
    return brow_start, row_start, row_count, max(int(B.meta["grid_rows"]), 1)


# -- SpMV -------------------------------------------------------------------

def _emit_spmv_rows(stmt, strat, plans, shards, jit=True):
    Bt = stmt.rhs.accesses()[0].tensor
    B = shards[Bt.name]
    c = shards[stmt.rhs.accesses()[1].tensor.name]
    n = stmt.lhs.tensor.shape[0]
    a = B.arrays
    if levels.tree_of(Bt).blocked:
        c_blk = pack_vec_blocks(np.asarray(c.arrays["vals"]),
                                int(B.meta["grid_cols"]), int(B.meta["bc"]))

        def fn(pos, crd, tiles, cb, row_start, row_count):
            blocks = jax.vmap(K.leaf_bcsr_spmv_rows,
                              in_axes=(0, 0, 0, None))(
                pos, crd, tiles, cb)                 # (P, max_brows * br)
            return _scatter_rows((n,), blocks, row_start, row_count)

        args = (a["pos1"], a["crd1"], a["vals"], c_blk,
                a["row_start"], a["row_count"])
        f = _runner(jit, "bcsr_spmv_rows", (n,), args, lambda: fn)
        return "bcsr_spmv_rows", lambda: np.asarray(f(*args))

    cv = c.arrays["vals"]

    def fn(pos, crd, vals, cvec, row_start, row_count):
        blocks = jax.vmap(K.leaf_spmv_rows, in_axes=(0, 0, 0, None))(
            pos, crd, vals, cvec)
        return _scatter_rows((n,), blocks, row_start, row_count)

    args = (a["pos1"], a["crd1"], a["vals"], cv,
            a["row_start"], a["row_count"])
    f = _runner(jit, "spmv_rows", (n,), args, lambda: fn)
    return "spmv_rows", lambda: np.asarray(f(*args))


def _emit_spmv_nnz(stmt, strat, plans, shards, jit=True):
    Bt = stmt.rhs.accesses()[0].tensor
    B = shards[Bt.name]
    c = shards[stmt.rhs.accesses()[1].tensor.name]
    n = stmt.lhs.tensor.shape[0]
    a = B.arrays
    if levels.tree_of(Bt).blocked:
        brow_start, row_start, row_count, max_brows = _bcsr_nnz_windows(B)
        c_blk = pack_vec_blocks(np.asarray(c.arrays["vals"]),
                                int(B.meta["grid_cols"]), int(B.meta["bc"]))

        def fn(bd0, bd1, tiles, cb, brow_start, row_start, row_count):
            rl = jnp.clip(bd0 - brow_start[:, None], 0, max_brows - 1)
            blocks = jax.vmap(
                K.leaf_bcsr_spmv_nnz, in_axes=(0, 0, 0, None, None))(
                rl, bd1, tiles, cb, max_brows)       # (P, max_brows * br)
            return _scatter_rows((n,), blocks, row_start, row_count)

        args = (a["bdim0"], a["bdim1"], a["vals"], c_blk,
                brow_start, row_start, row_count)
        f = _runner(jit, "bcsr_spmv_nnz", (n, max_brows), args, lambda: fn)
        return "bcsr_spmv_nnz", lambda: np.asarray(f(*args))

    row_start, row_count, max_rows = _nnz_row_windows(B, n)
    cv = c.arrays["vals"]

    def fn(rows, cols, vals, cvec, row_start, row_count):
        rl = jnp.clip(rows - row_start[:, None], 0, max_rows - 1)
        blocks = jax.vmap(K.leaf_spmv_nnz, in_axes=(0, 0, 0, None, None))(
            rl, cols, vals, cvec, max_rows)
        return _scatter_rows((n,), blocks, row_start, row_count)

    args = (a["dim0"], a["dim1"], a["vals"], cv, row_start, row_count)
    f = _runner(jit, "spmv_nnz", (n, max_rows), args, lambda: fn)
    return "spmv_nnz", lambda: np.asarray(f(*args))


# -- SpMM -------------------------------------------------------------------

def _emit_spmm_rows(stmt, strat, plans, shards, jit=True):
    Bacc, Cacc = stmt.rhs.accesses()
    B, C = shards[Bacc.tensor.name], shards[Cacc.tensor.name]
    out_shape = stmt.lhs.tensor.shape
    a = B.arrays
    if levels.tree_of(Bacc.tensor).blocked:
        C_blk = pack_mat_row_blocks(np.asarray(C.arrays["vals"]),
                                    int(B.meta["grid_cols"]),
                                    int(B.meta["bc"]))

        def fn(pos, crd, tiles, Cb, row_start, row_count):
            blocks = jax.vmap(K.leaf_bcsr_spmm_rows,
                              in_axes=(0, 0, 0, None))(
                pos, crd, tiles, Cb)                 # (P, max_brows*br, J)
            return _scatter_rows(out_shape, blocks, row_start, row_count)

        args = (a["pos1"], a["crd1"], a["vals"], C_blk,
                a["row_start"], a["row_count"])
        f = _runner(jit, "bcsr_spmm_rows", out_shape, args, lambda: fn)
        return "bcsr_spmm_rows", lambda: np.asarray(f(*args))

    Cv = C.arrays["vals"]

    def fn(pos, crd, vals, Cmat, row_start, row_count):
        blocks = jax.vmap(K.leaf_spmm_rows, in_axes=(0, 0, 0, None))(
            pos, crd, vals, Cmat)
        return _scatter_rows(out_shape, blocks, row_start, row_count)

    args = (a["pos1"], a["crd1"], a["vals"], Cv,
            a["row_start"], a["row_count"])
    f = _runner(jit, "spmm_rows", out_shape, args, lambda: fn)
    return "spmm_rows", lambda: np.asarray(f(*args))


def _emit_spmm_nnz(stmt, strat, plans, shards, jit=True):
    Bacc, Cacc = stmt.rhs.accesses()
    B, C = shards[Bacc.tensor.name], shards[Cacc.tensor.name]
    out_shape = stmt.lhs.tensor.shape
    a = B.arrays
    if levels.tree_of(Bacc.tensor).blocked:
        brow_start, row_start, row_count, max_brows = _bcsr_nnz_windows(B)
        C_blk = pack_mat_row_blocks(np.asarray(C.arrays["vals"]),
                                    int(B.meta["grid_cols"]),
                                    int(B.meta["bc"]))

        def fn(bd0, bd1, tiles, Cb, brow_start, row_start, row_count):
            rl = jnp.clip(bd0 - brow_start[:, None], 0, max_brows - 1)
            blocks = jax.vmap(
                K.leaf_bcsr_spmm_nnz, in_axes=(0, 0, 0, None, None))(
                rl, bd1, tiles, Cb, max_brows)
            return _scatter_rows(out_shape, blocks, row_start, row_count)

        args = (a["bdim0"], a["bdim1"], a["vals"], C_blk,
                brow_start, row_start, row_count)
        f = _runner(jit, "bcsr_spmm_nnz", out_shape + (max_brows,), args,
                    lambda: fn)
        return "bcsr_spmm_nnz", lambda: np.asarray(f(*args))

    row_start, row_count, max_rows = _nnz_row_windows(B, out_shape[0])
    Cv = C.arrays["vals"]

    def fn(rows, cols, vals, Cmat, row_start, row_count):
        rl = jnp.clip(rows - row_start[:, None], 0, max_rows - 1)
        blocks = jax.vmap(K.leaf_spmm_nnz, in_axes=(0, 0, 0, None, None))(
            rl, cols, vals, Cmat, max_rows)
        return _scatter_rows(out_shape, blocks, row_start, row_count)

    args = (a["dim0"], a["dim1"], a["vals"], Cv, row_start, row_count)
    f = _runner(jit, "spmm_nnz", out_shape + (max_rows,), args, lambda: fn)
    return "spmm_nnz", lambda: np.asarray(f(*args))


# -- SpAdd3 -----------------------------------------------------------------

def _emit_spadd3_rows(stmt, strat, plans, shards, jit=True):
    """Fused three-way add over shared row windows. Scalar trees: two-phase
    coordinate union per shard, host assembly into CSR. Blocked trees:
    tile union at block granularity (duplicate blocks merge by summing
    (br, bc) tiles), host assembly with Tensor.from_blocks — the output
    format follows the inputs' blocked format. Transpose-walked shards
    (CSC/BCSC) feed the SAME leaves: the walk already delivered row-window
    locality."""
    accs = stmt.rhs.accesses()
    Bs = [shards[acc.tensor.name] for acc in accs]
    Bt = accs[0].tensor
    n_rows, n_cols = stmt.lhs.tensor.shape
    if levels.tree_of(Bt).blocked:
        br, bc = int(Bs[0].meta["br"]), int(Bs[0].meta["bc"])

        def fn(args):
            (p1, c1, t1), (p2, c2, t2), (p3, c3, t3) = args
            return jax.vmap(K.leaf_bcsr_spadd3_rows)(
                p1, c1, t1, p2, c2, t2, p3, c3, t3)

        args = tuple((S.arrays["pos1"], S.arrays["crd1"], S.arrays["vals"])
                     for S in Bs)
        flat = tuple(x for trip in args for x in trip)
        f = _runner(jit, "bcsr_spadd3_rows", (n_rows, n_cols, br, bc), flat,
                    lambda: fn)

        def run():
            rows, cols, tiles, counts = (np.asarray(x) for x in f(args))
            brs = np.asarray(Bs[0].arrays["brow_start"])
            out_coords, out_tiles = [], []
            for p in range(rows.shape[0]):
                k = int(counts[p])
                out_coords.append(
                    np.stack([rows[p, :k] + brs[p], cols[p, :k]], axis=1))
                out_tiles.append(tiles[p, :k])
            return Tensor.from_blocks(
                stmt.lhs.tensor.name, (n_rows, n_cols), Bt.format,
                np.concatenate(out_coords), np.concatenate(out_tiles),
                dedupe=False)    # block-row windows are disjoint
        return "bcsr_spadd3_rows", run

    def fn(args):
        (p1, c1, v1), (p2, c2, v2), (p3, c3, v3) = args
        leaf = partial(K.leaf_spadd3_rows, n_cols=n_cols)
        return jax.vmap(leaf)(p1, c1, v1, p2, c2, v2, p3, c3, v3)

    args = tuple(
        (S.arrays["pos1"], S.arrays["crd1"], S.arrays["vals"]) for S in Bs)
    flat = tuple(x for trip in args for x in trip)
    f = _runner(jit, "spadd3_rows", (n_rows, n_cols), flat, lambda: fn)

    def run():
        rows, cols, vals, counts = (np.asarray(x) for x in f(args))
        # global assembly: offset shard-local rows by row_start
        out_rows, out_cols, out_vals = [], [], []
        rs = np.asarray(Bs[0].arrays["row_start"])
        for p in range(rows.shape[0]):
            k = int(counts[p])
            out_rows.append(rows[p, :k] + rs[p])
            out_cols.append(cols[p, :k])
            out_vals.append(vals[p, :k])
        coords = np.stack([np.concatenate(out_rows),
                           np.concatenate(out_cols)], 1)
        return Tensor.from_coo(stmt.lhs.tensor.name, (n_rows, n_cols),
                               coords, np.concatenate(out_vals),
                               fmt.CSR(), dedupe=True)

    return "spadd3_rows", run


def _emit_spadd3_nnz(stmt, strat, plans, shards, jit=True):
    """Non-zero SpAdd: the coordinate-position loop of an addition iterates
    the CONCATENATED stored-entry stream of all addends; splitting it evenly
    is the load-balanced strategy (paper §II-D applied to additions — the
    union position space is the natural fused space). The packed chunks
    come from the materialization layer (``materialize_add_stream``, keyed
    ``_addstream`` in the shard set) so a straggler re-plan re-slices a
    cached stream instead of re-walking the operands. Scalar trees union
    coordinates, blocked trees union whole tiles; boundary-straddling
    duplicates merge in the host assembly's dedupe."""
    Bt = stmt.rhs.accesses()[0].tensor
    n_rows, n_cols = stmt.lhs.tensor.shape
    pieces = strat.pieces
    S = shards["_addstream"]
    a = S.arrays
    max_c = int(S.meta["max_nnz"])
    if levels.tree_of(Bt).blocked:
        gr = int(S.meta["grid_rows"])
        br, bc = int(S.meta["br"]), int(S.meta["bc"])

        def fn(bd0, bd1, tiles, cnt):
            leaf = partial(K.leaf_bcsr_spadd_union_chunk, n_brows=gr)
            return jax.vmap(leaf)(bd0, bd1, tiles, cnt)

        f = _runner(jit, "bcsr_spadd3_nnz", (gr, br, bc),
                    (a["dim0"], a["dim1"], a["vals"], a["nnz_count"]),
                    lambda: fn)

        def run():
            if max_c == 0:
                return Tensor.from_blocks(
                    stmt.lhs.tensor.name, (n_rows, n_cols), Bt.format,
                    np.zeros((0, 2), np.int64),
                    np.zeros((0, br, bc), np.float32))
            rows, cols, tiles, counts = (np.asarray(x) for x in
                                         f(a["dim0"], a["dim1"], a["vals"],
                                           jnp.asarray(a["nnz_count"])))
            out_coords, out_tiles = [], []
            for p in range(rows.shape[0]):
                k = int(counts[p])
                out_coords.append(
                    np.stack([rows[p, :k], cols[p, :k]], axis=1))
                out_tiles.append(tiles[p, :k])
            return Tensor.from_blocks(
                stmt.lhs.tensor.name, (n_rows, n_cols), Bt.format,
                np.concatenate(out_coords), np.concatenate(out_tiles),
                dedupe=True)
        return "bcsr_spadd3_nnz", run

    def fn(rows, cols, v, cnt):
        leaf = partial(K.leaf_spadd_union_chunk, n_rows=n_rows)
        return jax.vmap(leaf)(rows, cols, v, cnt)

    f = _runner(jit, "spadd3_nnz", (n_rows,),
                (a["dim0"], a["dim1"], a["vals"], a["nnz_count"]),
                lambda: fn)

    def run():
        if max_c == 0:
            return Tensor.from_coo(stmt.lhs.tensor.name, (n_rows, n_cols),
                                   np.zeros((0, 2), np.int64),
                                   np.zeros((0,), np.float32), fmt.CSR())
        r, c, v, k = (np.asarray(x) for x in
                      f(a["dim0"], a["dim1"], a["vals"],
                        jnp.asarray(a["nnz_count"])))
        out_r, out_c, out_v = [], [], []
        for p in range(pieces):
            kk = int(k[p])
            out_r.append(r[p, :kk])
            out_c.append(c[p, :kk])
            out_v.append(v[p, :kk])
        coords_out = np.stack(
            [np.concatenate(out_r), np.concatenate(out_c)], axis=1)
        return Tensor.from_coo(stmt.lhs.tensor.name, (n_rows, n_cols),
                               coords_out, np.concatenate(out_v),
                               fmt.CSR(), dedupe=True)

    return "spadd3_nnz", run


# -- SDDMM ------------------------------------------------------------------

def _emit_sddmm_rows(stmt, strat, plans, shards, jit=True):
    """Row-based SDDMM: B and C's matching row block local per color, D
    replicated; output vals stay aligned with B's stored positions
    (pattern-preserving universe strategy). Ordered walks scatter back by
    value-space intervals; transpose-walked shards (CSC/BCSC) scatter home
    through their ``val_idx`` permutation instead."""
    accs = stmt.rhs.accesses()
    B = shards[accs[0].tensor.name]
    C = shards[accs[1].tensor.name]
    D = shards[accs[2].tensor.name]
    Bt = accs[0].tensor
    a = B.arrays
    if levels.tree_of(Bt).blocked:
        br, bc = int(B.meta["br"]), int(B.meta["bc"])
        max_brows = int(B.meta["max_brows"])
        # local C row blocks: pad the per-color row windows to the block grid
        C_blk = pack_rowwindow_blocks(C.arrays["vals"], max_brows, br)
        D_blk = pack_mat_inner_blocks(np.asarray(D.arrays["vals"]),
                                      int(B.meta["grid_cols"]), bc)
        total_blocks = int(Bt.levels[1].nnz or 0)
        if "val_idx" in a:
            def fn(pos, crd, tiles, Cl, Db, val_idx, nnz_count):
                def leaf(pos_, crd_, tiles_, Cl_):
                    brow = K.rows_from_pos(pos_, crd_.shape[0])
                    return K.leaf_bcsr_sddmm(brow, crd_, tiles_, Cl_, Db)
                out = jax.vmap(leaf)(pos, crd, tiles, Cl)
                return _scatter_by_val_idx(total_blocks, out, val_idx,
                                           nnz_count)

            args = (a["pos1"], a["crd1"], a["vals"], C_blk, D_blk,
                    a["val_idx"], a["nnz_count"])
            f = _runner(jit, "bcsr_sddmm_rows", (total_blocks, br, bc),
                        args, lambda: fn)
        else:
            vb = plans[Bt.name].vals_bounds
            nnz_start = jnp.asarray(vb[:, 0].astype(np.int32))
            nnz_count = jnp.asarray((vb[:, 1] - vb[:, 0]).astype(np.int32))

            def fn(pos, crd, tiles, Cl, Db, nnz_start, nnz_count):
                def leaf(pos_, crd_, tiles_, Cl_):
                    brow = K.rows_from_pos(pos_, crd_.shape[0])
                    return K.leaf_bcsr_sddmm(brow, crd_, tiles_, Cl_, Db)
                out = jax.vmap(leaf)(pos, crd, tiles, Cl)
                return _scatter_block_vals(total_blocks, out, nnz_start,
                                           nnz_count)

            args = (a["pos1"], a["crd1"], a["vals"], C_blk, D_blk,
                    nnz_start, nnz_count)
            f = _runner(jit, "bcsr_sddmm_rows", (total_blocks,), args,
                        lambda: fn)

        def run():
            new_tiles = np.asarray(f(*args))
            return Tensor(stmt.lhs.tensor.name, Bt.shape, Bt.format,
                          Bt.levels, new_tiles, Bt.dtype)
        return "bcsr_sddmm_rows", run

    Cv = C.arrays["vals"]                   # (P, max_rows, K) row blocks
    Dv = D.arrays["vals"]                   # (K, m) replicated
    total_nnz = Bt.nnz
    if "val_idx" in a:
        def fn(pos, crd, vals, Cl, Dm, val_idx, nnz_count):
            out = jax.vmap(K.leaf_sddmm_rows, in_axes=(0, 0, 0, 0, None))(
                pos, crd, vals, Cl, Dm)
            return _scatter_by_val_idx(total_nnz, out, val_idx, nnz_count)

        args = (a["pos1"], a["crd1"], a["vals"], Cv, Dv, a["val_idx"],
                a["nnz_count"])
        f = _runner(jit, "sddmm_rows", (total_nnz,), args, lambda: fn)
    else:
        vb = plans[Bt.name].vals_bounds
        nnz_start = jnp.asarray(vb[:, 0].astype(np.int32))
        nnz_count = jnp.asarray((vb[:, 1] - vb[:, 0]).astype(np.int32))

        def fn(pos, crd, vals, Cl, Dm, nnz_start, nnz_count):
            out = jax.vmap(K.leaf_sddmm_rows, in_axes=(0, 0, 0, 0, None))(
                pos, crd, vals, Cl, Dm)
            return _scatter_vals(total_nnz, out, nnz_start, nnz_count)

        args = (a["pos1"], a["crd1"], a["vals"], Cv, Dv, nnz_start,
                nnz_count)
        f = _runner(jit, "sddmm_rows", (total_nnz,), args, lambda: fn)

    def run():
        new_vals = np.asarray(f(*args))
        return Tensor(stmt.lhs.tensor.name, Bt.shape, Bt.format, Bt.levels,
                      new_vals, Bt.dtype)

    return "sddmm_rows", run


def _emit_sddmm_nnz(stmt, strat, plans, shards, jit=True):
    accs = stmt.rhs.accesses()
    B = shards[accs[0].tensor.name]
    C = shards[accs[1].tensor.name]
    D = shards[accs[2].tensor.name]
    Bt = accs[0].tensor
    a = B.arrays
    vb = plans[Bt.name].vals_bounds
    nnz_start = jnp.asarray(vb[:, 0].astype(np.int32))
    if levels.tree_of(Bt).blocked:
        br, bc = int(B.meta["br"]), int(B.meta["bc"])
        C_blk = pack_mat_row_blocks(np.asarray(C.arrays["vals"]),
                                    int(B.meta["grid_rows"]), br)
        D_blk = pack_mat_inner_blocks(np.asarray(D.arrays["vals"]),
                                      int(B.meta["grid_cols"]), bc)
        total_blocks = int(Bt.levels[1].nnz or 0)

        def fn(bd0, bd1, tiles, Cb, Db, counts, nnz_start):
            out = jax.vmap(K.leaf_bcsr_sddmm,
                           in_axes=(0, 0, 0, None, None))(
                bd0, bd1, tiles, Cb, Db)
            return _scatter_block_vals(total_blocks, out, nnz_start, counts)

        args = (a["bdim0"], a["bdim1"], a["vals"], C_blk, D_blk,
                a["nnz_count"], nnz_start)
        f = _runner(jit, "bcsr_sddmm_nnz", (total_blocks,), args,
                    lambda: fn)

        def run():
            new_tiles = np.asarray(f(*args))
            return Tensor(stmt.lhs.tensor.name, Bt.shape, Bt.format,
                          Bt.levels, new_tiles, Bt.dtype)
        return "bcsr_sddmm_nnz", run

    Cv, Dv = C.arrays["vals"], D.arrays["vals"]
    total_nnz = Bt.nnz

    def fn(rows, cols, vals, Cm, Dm, counts, nnz_start):
        out = jax.vmap(K.leaf_sddmm_nnz, in_axes=(0, 0, 0, None, None))(
            rows, cols, vals, Cm, Dm)
        return _scatter_vals(total_nnz, out, nnz_start, counts)

    args = (a["dim0"], a["dim1"], a["vals"], Cv, Dv, a["nnz_count"],
            nnz_start)
    f = _runner(jit, "sddmm_nnz", (total_nnz,), args, lambda: fn)

    def run():
        new_vals = np.asarray(f(*args))
        out = stmt.lhs.tensor
        return Tensor(out.name, Bt.shape, Bt.format, Bt.levels, new_vals,
                      Bt.dtype)

    return "sddmm_nnz", run


# -- SpTTV ------------------------------------------------------------------

def _spttv_flat_runner(stmt, shards, jit, name):
    """Flat-walk SpTTV: per-position products; (i, j) assembly happens on
    host (the result pattern is the walk's ij columns; duplicates merge in
    from_coo). Consumed by BOTH the nnz strategy and the universe strategy
    over trailing-singleton trees (COO3), whose shard sets are the same
    coordinate-column convention; ``name`` keeps the runner-cache label
    truthful about which strategy compiled it."""
    accs = stmt.rhs.accesses()
    B = shards[accs[0].tensor.name]
    c = shards[accs[1].tensor.name]
    Bt = accs[0].tensor
    a = B.arrays
    cv = c.arrays["vals"]

    def fn(dk, vals, cvec):
        return vals * jnp.take(cvec, dk, axis=0)

    f = _runner(jit, name, (), (a["dim2"], a["vals"], cv), lambda: fn)

    def run():
        prod = np.asarray(f(a["dim2"], a["vals"], cv)).ravel()
        di = np.asarray(a["dim0"]).ravel().astype(np.int64)
        dj = np.asarray(a["dim1"]).ravel().astype(np.int64)
        counts = np.asarray(a["nnz_count"])
        mask = np.zeros(prod.shape[0], bool)
        mn = a["dim0"].shape[1]
        for p in range(counts.shape[0]):
            mask[p * mn: p * mn + counts[p]] = True
        coords = np.stack([di[mask], dj[mask]], 1)
        # the assembled output format follows the input's (i, j) levels
        out_fmt = fmt.Format(Bt.format.levels[:2])
        return Tensor.from_coo(stmt.lhs.tensor.name, Bt.shape[:2], coords,
                               prod[mask], out_fmt, dedupe=True)

    return run


def _emit_spttv_rows(stmt, strat, plans, shards, jit=True):
    accs = stmt.rhs.accesses()
    Bt = accs[0].tensor
    if levels.tree_of(Bt).trailing_singletons:
        # no grouped middle level: the universe plan materialized the flat
        # walk bucketed by row window — consume it with the flat leaf
        return "spttv_flat_rows", _spttv_flat_runner(stmt, shards, jit,
                                                     "spttv_flat_rows")
    B = shards[Bt.name]
    c = shards[accs[1].tensor.name]
    a = B.arrays
    cv = c.arrays["vals"]
    # output pattern = B's (i,j) level; vals live at level-1 positions
    ij_bounds = plans[Bt.name].levels[1].pos_bounds
    total_ij = Bt.levels[1].nnz
    ij_start = jnp.asarray(ij_bounds[:, 0].astype(np.int32))
    ij_count = jnp.asarray(
        (ij_bounds[:, 1] - ij_bounds[:, 0]).astype(np.int32))

    def fn(pos1, crd1, pos2, crd2, vals, cvec, ij_start, ij_count):
        out = jax.vmap(K.leaf_spttv_rows, in_axes=(0, 0, 0, 0, 0, None))(
            pos1, crd1, pos2, crd2, vals, cvec)
        return _scatter_vals(total_ij, out, ij_start, ij_count)

    args = (a["pos1"], a["crd1"], a["pos2"], a["crd2"], a["vals"], cv,
            ij_start, ij_count)
    f = _runner(jit, "spttv_rows", (total_ij,), args, lambda: fn)

    def run():
        new_vals = np.asarray(f(*args))
        # output tensor: (i,j) matrix with B's ij pattern, in the format
        # the input's first two levels spell — CSF yields CSR, DCSF yields
        # DCSR (the output format follows the input's)
        import copy
        lv = [copy.copy(Bt.levels[0]), copy.copy(Bt.levels[1])]
        out_fmt = fmt.Format(Bt.format.levels[:2])
        return Tensor(stmt.lhs.tensor.name, Bt.shape[:2], out_fmt, lv,
                      new_vals, Bt.dtype)

    return "spttv_rows", run


def _emit_spttv_nnz(stmt, strat, plans, shards, jit=True):
    return "spttv_nnz", _spttv_flat_runner(stmt, shards, jit, "spttv_nnz")


# -- SpMTTKRP ---------------------------------------------------------------

def _spmttkrp_flat_runner(stmt, shards, jit, name):
    """Flat-walk MTTKRP: per-position (i, j, k) contributions segment-summed
    into the shard's row window. Consumed by the nnz strategy (overlapping
    windows, reduced by the scatter) AND the universe strategy over
    trailing-singleton trees (COO3 — disjoint windows, same leaf)."""
    accs = stmt.rhs.accesses()
    B = shards[accs[0].tensor.name]
    C = shards[accs[1].tensor.name]
    D = shards[accs[2].tensor.name]
    out_shape = stmt.lhs.tensor.shape
    a = B.arrays
    row_start, row_count, max_rows = _nnz_row_windows(B, out_shape[0])
    Cv, Dv = C.arrays["vals"], D.arrays["vals"]

    def fn(di, dj, dk, vals, Cm, Dm, row_start, row_count):
        rl = jnp.clip(di - row_start[:, None], 0, max_rows - 1)
        blocks = jax.vmap(
            K.leaf_spmttkrp_nnz, in_axes=(0, 0, 0, 0, None, None, None))(
            rl, dj, dk, vals, Cm, Dm, max_rows)
        return _scatter_rows(out_shape, blocks, row_start, row_count)

    args = (a["dim0"], a["dim1"], a["dim2"], a["vals"], Cv, Dv,
            row_start, row_count)
    f = _runner(jit, name, out_shape + (max_rows,), args, lambda: fn)
    return lambda: np.asarray(f(*args))


def _emit_spmttkrp_rows(stmt, strat, plans, shards, jit=True):
    accs = stmt.rhs.accesses()
    Bt = accs[0].tensor
    if levels.tree_of(Bt).trailing_singletons:
        return "spmttkrp_flat_rows", _spmttkrp_flat_runner(
            stmt, shards, jit, "spmttkrp_flat_rows")
    B = shards[Bt.name]
    C = shards[accs[1].tensor.name]
    D = shards[accs[2].tensor.name]
    out_shape = stmt.lhs.tensor.shape
    a = B.arrays
    Cv, Dv = C.arrays["vals"], D.arrays["vals"]

    def fn(pos1, crd1, pos2, crd2, vals, Cm, Dm, row_start, row_count):
        blocks = jax.vmap(
            K.leaf_spmttkrp_rows, in_axes=(0, 0, 0, 0, 0, None, None))(
            pos1, crd1, pos2, crd2, vals, Cm, Dm)
        return _scatter_rows(out_shape, blocks, row_start, row_count)

    args = (a["pos1"], a["crd1"], a["pos2"], a["crd2"], a["vals"], Cv, Dv,
            a["row_start"], a["row_count"])
    f = _runner(jit, "spmttkrp_rows", out_shape, args, lambda: fn)
    return "spmttkrp_rows", lambda: np.asarray(f(*args))


def _emit_spmttkrp_nnz(stmt, strat, plans, shards, jit=True):
    return "spmttkrp_nnz", _spmttkrp_flat_runner(stmt, shards, jit,
                                                 "spmttkrp_nnz")


def _emit_generic_fallback(stmt, strat, plans, shards, jit=True):
    """Correctness fallback for arbitrary TIN: densify and einsum.

    Kept for generality (the paper supports *all* of tensor algebra); not a
    performance path and flagged as such by leaf_name."""
    del strat, plans, shards

    def run():
        from .interp import interpret
        return interpret(stmt)

    return run


# One generic emitter per expression × strategy — the whole specialization
# table. Format variation lives in the level trees the emitters query, not
# in this table.
_EMITTERS = {
    ("d1(i)=s2(i,j)*d1(j)", "universe"): _emit_spmv_rows,
    ("d1(i)=s2(i,j)*d1(j)", "nnz"): _emit_spmv_nnz,
    ("d2(i,j)=s2(i,k)*d2(k,j)", "universe"): _emit_spmm_rows,
    ("d2(i,j)=s2(i,k)*d2(k,j)", "nnz"): _emit_spmm_nnz,
    ("s2(i,j)=s2(i,j)+s2(i,j)+s2(i,j)", "universe"): _emit_spadd3_rows,
    ("s2(i,j)=s2(i,j)+s2(i,j)+s2(i,j)", "nnz"): _emit_spadd3_nnz,
    ("s2(i,j)=s2(i,j)*d2(i,k)*d2(k,j)", "universe"): _emit_sddmm_rows,
    ("s2(i,j)=s2(i,j)*d2(i,k)*d2(k,j)", "nnz"): _emit_sddmm_nnz,
    ("s2(i,j)=s3(i,j,k)*d1(k)", "universe"): _emit_spttv_rows,
    ("s2(i,j)=s3(i,j,k)*d1(k)", "nnz"): _emit_spttv_nnz,
    ("d2(i,l)=s3(i,j,k)*d2(j,l)*d2(k,l)", "universe"): _emit_spmttkrp_rows,
    ("d2(i,l)=s3(i,j,k)*d2(j,l)*d2(k,l)", "nnz"): _emit_spmttkrp_nnz,
}


# ---------------------------------------------------------------------------
# Serving fast path (ISSUE 10): request batching over a lowered kernel.
#
# A request queue of B right-hand-side vectors against one frozen sparse
# operand is ONE SpMM: stacking the vectors as columns promotes SpMV to
# SpMM (or widens an SpMM), so B requests share a single plan, a single
# shard materialization of the sparse operand, and a single jitted runner.
# Batch sizes are padded up to a bucket (cache.batch_bucket) so the
# runner caches see at most len(buckets) distinct widths under mixed
# traffic. The per-call work is only: pack the batch columns, re-pack the
# dense RHS shard (rebind_dense — no plan, no fingerprinting, runner-cache
# hit), execute, slice the per-request outputs back out.
# ---------------------------------------------------------------------------

def _materialize_dense_operand(t: Tensor, plan: TensorPartition, pieces: int,
                               cache: bool = False) -> ShardedTensor:
    """Re-pack ONE all-dense operand under its existing partition geometry
    — the same branch structure the 1-D and grid lowering paths use, minus
    every sparse case (rebinds only ever swap dense request data)."""
    if plan.replicated:
        return materialize_replicated(t, pieces, cache=cache)
    if plan.grid is not None:
        return materialize_dense_grid(t, plan.levels[0].coord_bounds,
                                      plan.levels[1].coord_bounds,
                                      cache=cache)
    if plan.root_coord_bounds is None:
        return materialize_dense_cols(t, plan.levels[1].coord_bounds,
                                      cache=cache)
    return materialize_dense_rows(t, plan.root_coord_bounds, cache=cache)


def rebind_dense(kernel: LoweredKernel, mapping: Dict[str, Tensor], *,
                 jit: bool = True, cache: bool = False) -> LoweredKernel:
    """A copy of ``kernel`` with dense operands swapped by name.

    The partition geometry is kept (bounds depend only on shapes and the
    sparse pattern, both unchanged), so the swap re-packs just the named
    operands' shards and re-emits — a pure runner-cache hit when the new
    values have the old shapes. This is the serving hot path: no plan
    recompute, no content fingerprinting of any operand. ``comm`` is
    carried over unchanged (the model depends on shapes, not values).

    Only all-dense operands can rebind; a sparse swap changes the
    partition itself and must go through ``lower()`` / ``relower()``."""
    strat = kernel.strategy
    stmt = kernel.stmt.with_tensors(mapping)
    plans = dict(kernel.plans)
    shards = dict(kernel.shards)
    for name, t in mapping.items():
        old = plans.get(name)
        if old is None:
            raise KeyError(f"operand {name!r} not in kernel plans "
                           f"({sorted(plans)})")
        if (old.tensor is not None and old.tensor.format.is_sparse) \
                or t.format.is_sparse:
            raise ValueError(
                f"rebind_dense only swaps all-dense operands; {name!r} is "
                "sparse — re-plan through lower()/relower() instead")
        plans[name] = dataclasses.replace(old, tensor=t)
        if name in shards:
            shards[name] = _materialize_dense_operand(
                t, plans[name], strat.pieces, cache=cache)
    if strat.is_grid and strat.space == "universe":
        from . import grid as grid_mod
        gp = grid_mod.compute_grid_plan(stmt, strat)
        leaf_name, runner = grid_mod._emit_grid(stmt, strat, gp, plans,
                                                shards, jit=jit)
    else:
        leaf_name, runner = _emit(stmt, strat, plans, shards, jit=jit)
    return dataclasses.replace(kernel, stmt=stmt, plans=plans,
                               shards=shards, runner=runner,
                               leaf_name=leaf_name)


#: Batchable signatures: per-request RHS shape, promoted signature.
_BATCHABLE = {
    "d1(i)=s2(i,j)*d1(j)": "spmv",        # requests are (m,) vectors
    "d2(i,j)=s2(i,k)*d2(k,j)": "spmm",    # requests are (m, jw) panels
}


@dataclasses.dataclass
class _BucketEntry:
    kernel: LoweredKernel
    rhs_name: str
    out_name: str
    bucket: int
    jw: int                      # per-request column width (1 for spmv)
    m: int                       # RHS rows


class BatchedKernel:
    """Bucketized request batching over one scheduled sparse statement.

    ``run_many([x_0, ..., x_{B-1}])`` stacks the request vectors (or
    fixed-width panels) as columns of one dense RHS, pads the batch up to
    the smallest registered bucket, executes the per-bucket lowered SpMM
    once, and slices per-request outputs back out. Each bucket lowers
    lazily exactly once — one plan, one set of sparse shards, one jitted
    runner — and later batches of any size in that bucket reuse all three
    via :func:`rebind_dense`.

    ``schedule`` may be a Schedule, None, the string ``"auto"``, or a
    callable ``(stmt, machine) -> Schedule`` applied to the PROMOTED
    statement (e.g. ``default_nnz_schedule`` / ``default_grid_schedule``).
    ``mesh`` routes execution through the shard_map SPMD executor instead
    of the vmap simulation (bounded identically: _SPMD_RUN_CACHE keys on
    the bucket-padded avals).
    """

    def __init__(self, stmt: Assignment, machine: Machine,
                 schedule: Any = None, *, buckets=BATCH_BUCKETS,
                 jit: bool = True, mesh: Any = None):
        sig = stmt.signature()
        if sig not in _BATCHABLE:
            raise NotImplementedError(
                f"lower_batched supports {sorted(_BATCHABLE)}; got {sig}")
        self.stmt = stmt
        self.machine = machine
        self.schedule = schedule
        self.buckets = tuple(sorted(int(b) for b in buckets))
        self.jit = jit
        self.mesh = mesh
        self.kind = _BATCHABLE[sig]
        self._entries: Dict[int, _BucketEntry] = {}

    # -- construction ------------------------------------------------------
    def _promoted_stmt(self, bucket: int) -> Tuple[Assignment, str, str, int]:
        stmt = self.stmt
        sparse_acc = stmt.rhs.accesses()[0]
        rhs_acc = stmt.rhs.accesses()[-1]
        rhs_name = rhs_acc.tensor.name
        out_name = stmt.lhs.tensor.name
        n = stmt.lhs.tensor.shape[0]
        m = rhs_acc.tensor.shape[0]
        if self.kind == "spmv":
            # promote a(i) = B(i,j) * c(j)  →  A(i,j) = B(i,k) * C(k,j):
            # each request vector is one column of C. Index vars are
            # rebuilt with the canonical SpMM names (the emitter table and
            # default schedules key on them); a caller-tuned schedule is
            # passed as a callable over the promoted statement.
            i, k, j = IndexVar("i"), IndexVar("k"), IndexVar("j")
            out = Tensor.zeros_dense(out_name, (n, bucket))
            X = Tensor.from_dense(rhs_name,
                                  np.zeros((m, bucket), np.float32))
            bstmt = Assignment(
                Access(out, (i, j)),
                Mul(Access(sparse_acc.tensor, (i, k)),
                    Access(X, (k, j))))
            return bstmt, rhs_name, out_name, 1
        # spmm: widen the dense RHS to bucket panels of the original width
        jw = stmt.lhs.tensor.shape[1]
        out = Tensor.zeros_dense(out_name, (n, bucket * jw))
        X = Tensor.from_dense(rhs_name,
                              np.zeros((m, bucket * jw), np.float32))
        bstmt = stmt.with_tensors({out_name: out, rhs_name: X})
        return bstmt, rhs_name, out_name, jw

    def _entry(self, bucket: int) -> _BucketEntry:
        e = self._entries.get(bucket)
        if e is not None:
            return e
        bstmt, rhs_name, out_name, jw = self._promoted_stmt(bucket)
        sched = self.schedule
        if callable(sched) and not isinstance(sched, Schedule):
            sched = sched(bstmt, self.machine)
        with telemetry.span("serve.batch.lower", bucket=bucket):
            kernel = lower(bstmt, self.machine, schedule=sched, jit=self.jit)
        telemetry.METRICS.counter("serve.buckets_lowered")
        e = _BucketEntry(kernel=kernel, rhs_name=rhs_name,
                         out_name=out_name, bucket=bucket, jw=jw,
                         m=bstmt.rhs.accesses()[-1].tensor.shape[0])
        self._entries[bucket] = e
        return e

    def warm(self, batch: int) -> "BatchedKernel":
        """Pre-lower the bucket that will serve batches of size ``batch``."""
        self._entry(batch_bucket(batch, self.buckets))
        return self

    # -- execution ---------------------------------------------------------
    def run_many(self, rhs_batch) -> List[np.ndarray]:
        """Execute one batched step over ``len(rhs_batch)`` requests and
        return the per-request outputs ((n,) each for spmv requests,
        (n, jw) for spmm panels), bit-for-bit equal to running the
        original statement once per request."""
        B = len(rhs_batch)
        bucket = batch_bucket(B, self.buckets)
        with telemetry.span("serve.batch", requests=B, bucket=bucket) as sp:
            e = self._entry(bucket)
            buf = np.zeros((e.m, bucket * e.jw), np.float32)
            for r, x in enumerate(rhs_batch):
                x = np.asarray(x, np.float32)
                if e.jw == 1:
                    buf[:, r] = x.reshape(e.m)
                else:
                    buf[:, r * e.jw:(r + 1) * e.jw] = x.reshape(e.m, e.jw)
            X = Tensor.from_dense(e.rhs_name, buf)
            e.kernel = rebind_dense(e.kernel, {e.rhs_name: X},
                                    jit=self.jit, cache=False)
            if self.mesh is not None:
                from ..distributed.executor import to_spmd
                y = np.asarray(to_spmd(e.kernel, self.mesh)())
            else:
                y = np.asarray(e.kernel.run())
            sp.set(leaf=e.kernel.leaf_name)
        telemetry.METRICS.counter("serve.requests", B)
        telemetry.METRICS.counter("serve.batches")
        telemetry.METRICS.observe("serve.batch.occupancy", B / bucket)
        telemetry.METRICS.observe("serve.batch.padded_slot_waste",
                                  (bucket - B) / bucket)
        if e.jw == 1:
            return [y[:, r] for r in range(B)]
        return [y[:, r * e.jw:(r + 1) * e.jw] for r in range(B)]

    def explain(self) -> str:
        lines = [f"batched kernel over {self.stmt.signature()} "
                 f"buckets={self.buckets}"]
        for b, e in sorted(self._entries.items()):
            lines.append(f"  bucket {b}: leaf={e.kernel.leaf_name} "
                         f"pieces={e.kernel.strategy.pieces}")
        return "\n".join(lines)


def lower_batched(stmt: Assignment, machine: Machine, batch: int = 8,
                  schedule: Any = None, *, buckets=BATCH_BUCKETS,
                  jit: bool = True, mesh: Any = None) -> BatchedKernel:
    """Batched-serving entry point: a :class:`BatchedKernel` for ``stmt``
    with the bucket covering ``batch`` pre-lowered (one plan + one jitted
    runner, shared by every later ``run_many`` call in that bucket)."""
    return BatchedKernel(stmt, machine, schedule, buckets=buckets,
                         jit=jit, mesh=mesh).warm(batch)
