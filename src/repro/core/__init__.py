"""SpDISTAL core — the paper's contribution as a composable JAX module.

Four independent sub-languages (paper §II):
  - computation:  :mod:`repro.core.tin`       (tensor index notation)
  - formats:      :mod:`repro.core.formats`   (per-level Dense/Compressed)
  - distribution: :mod:`repro.core.tdn`       (universe/nnz/fused TDN)
  - scheduling:   :mod:`repro.core.schedule`  (divide/distribute/communicate)

plus the compilation machinery:
  - :mod:`repro.core.partition` — dependent partitioning (image/preimage)
  - :mod:`repro.core.lower`     — scheduled TIN → executable SPMD JAX
  - :mod:`repro.core.interp`    — CTF-analog interpretation baseline
"""
from . import formats
from .formats import (BCSC, BCSR, COO, CSC, CSF, CSR, DCSF, DCSR, DDC,
                      Compressed, Dense, DenseMat, DenseND, DenseVec, Format,
                      Singleton, SparseVec, capabilities, conversion_target,
                      format_key)
from .interp import interpret
from . import levels
from .levels import LevelTree, Walk, tree_of
# NOTE: the lowering entry point is re-exported as ``lower_stmt`` — the
# package attribute ``repro.core.lower`` stays bound to the SUBMODULE, so
# ``import repro.core.lower as L`` returns the module (the name-shadowing
# gotcha the re-plan PR had to work around with sys.modules). The function
# spelling inside the module, ``repro.core.lower.lower``, is unchanged.
from .lower import (AxisComm, CacheStats, CommStats, LoweredKernel,
                    clear_lowering_caches, default_grid_nnz_schedule,
                    default_grid_schedule, default_nnz_schedule,
                    default_row_schedule)
from .lower import lower as lower_stmt
from . import grid
from . import lower  # rebind the package attr to the submodule (see NOTE)
from .partition import (ShardedTensor, TensorPartition, image,
                        partition_by_bounds, partition_tensor_grid,
                        partition_tensor_nonzeros, partition_tensor_rows,
                        preimage, replicate_tensor)
from .schedule import CPUThread, Schedule, TPUGrid, VectorLanes
from .tdn import Distribution, Machine, dist
from .tensor import Tensor, TensorVar
from .tin import Access, Assignment, IndexVar, index_vars, parse_tin

__all__ = [
    "formats", "grid", "levels", "LevelTree", "Walk", "tree_of", "BCSC",
    "BCSR", "COO", "CSC", "CSF", "CSR", "DCSF", "DCSR",
    "DDC", "Compressed", "Dense", "DenseMat", "DenseND", "DenseVec",
    "Format", "Singleton", "capabilities", "conversion_target",
    "format_key", "SparseVec", "interpret", "AxisComm", "CacheStats",
    "CommStats", "LoweredKernel", "clear_lowering_caches",
    "default_grid_nnz_schedule", "default_grid_schedule",
    "default_nnz_schedule", "default_row_schedule", "lower", "lower_stmt",
    "image", "preimage", "partition_by_bounds", "partition_tensor_grid",
    "partition_tensor_nonzeros", "partition_tensor_rows",
    "replicate_tensor", "CPUThread", "Schedule", "TPUGrid", "VectorLanes",
    "Distribution", "Machine", "dist", "Tensor", "TensorVar", "Access",
    "Assignment", "IndexVar", "index_vars", "parse_tin", "ShardedTensor",
    "TensorPartition",
]
