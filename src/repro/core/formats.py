"""Format language — per-dimension level formats (paper §II-B, §III-B).

A tensor's *coordinate tree* has one level per dimension (in storage order).
Each level is stored with a *level format*:

- ``Dense``      — all coordinates of the level exist; stored implicitly as an
                   index range ``dom = [0, size)``.
- ``Compressed`` — only non-zero coordinates stored, with TACO's ``pos``/
                   ``crd`` arrays. Following the paper (§III-B, Fig. 7) the
                   ``pos`` region conceptually stores *(lo, hi)* range tuples
                   so dependent-partitioning ``image``/``preimage`` apply; we
                   keep the standard length-(parent+1) monotone ``pos`` array
                   and expose the (lo, hi) view as ``pos[i], pos[i+1]-1``.

A :class:`Format` is an ordered list of level formats plus a dimension
ordering (``mode_ordering``), so CSR/CSC/DCSR/CSF/COO are all spellable —
Figure 3 of the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


class LevelFormat:
    """Base class for level formats. Subclasses are stateless singletons."""

    name: str = "?"
    compressed: bool = False
    # COO-style levels that share the position space with their parent
    # (LevelFormat Singleton from Chou et al. [27]); used for fused levels.
    singleton: bool = False

    def __repr__(self) -> str:
        return self.name


class _Dense(LevelFormat):
    name = "Dense"
    compressed = False


class _Compressed(LevelFormat):
    name = "Compressed"
    compressed = True


class _Singleton(LevelFormat):
    """COO trailing level: one coordinate per parent position."""

    name = "Singleton"
    compressed = True
    singleton = True


Dense = _Dense()
Compressed = _Compressed()
Singleton = _Singleton()

_BY_NAME = {"Dense": Dense, "Compressed": Compressed, "Singleton": Singleton}


def level_format(x) -> LevelFormat:
    if isinstance(x, LevelFormat):
        return x
    if isinstance(x, str) and x in _BY_NAME:
        return _BY_NAME[x]
    raise ValueError(f"unknown level format {x!r}")


@dataclasses.dataclass(frozen=True)
class Format:
    """An ordered tuple of level formats + optional mode ordering.

    ``mode_ordering[lvl]`` gives the tensor dimension stored at coordinate
    tree level ``lvl``; identity if omitted (row-major-like). CSC is
    ``Format((Dense, Compressed), mode_ordering=(1, 0))``.

    ``block_shape`` spells *blocked* formats (BCSR): the levels then
    describe the coordinate tree of the **block grid** (dimension ``d`` has
    ``ceil(shape[d] / block_shape[d])`` block coordinates) and each stored
    leaf position carries a dense value block of that shape instead of a
    scalar. ``BCSR((2, 2))`` = ``Format((Dense, Compressed),
    block_shape=(2, 2))``.
    """

    levels: Tuple[LevelFormat, ...]
    mode_ordering: Optional[Tuple[int, ...]] = None
    block_shape: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        object.__setattr__(
            self, "levels", tuple(level_format(l) for l in self.levels)
        )
        if self.mode_ordering is None:
            object.__setattr__(
                self, "mode_ordering", tuple(range(len(self.levels)))
            )
        if sorted(self.mode_ordering) != list(range(len(self.levels))):
            raise ValueError(f"bad mode ordering {self.mode_ordering}")
        if self.block_shape is not None:
            object.__setattr__(
                self, "block_shape", tuple(int(b) for b in self.block_shape)
            )
            if len(self.block_shape) != len(self.levels):
                raise ValueError(
                    f"block_shape {self.block_shape} must have one entry per "
                    f"level ({len(self.levels)})")
            if any(b < 1 for b in self.block_shape):
                raise ValueError(f"bad block_shape {self.block_shape}")

    @property
    def order(self) -> int:
        return len(self.levels)

    @property
    def is_sparse(self) -> bool:
        return any(l.compressed for l in self.levels)

    @property
    def is_all_dense(self) -> bool:
        return not self.is_sparse

    @property
    def is_blocked(self) -> bool:
        return self.block_shape is not None

    def level_of_dim(self, dim: int) -> int:
        return self.mode_ordering.index(dim)

    def dim_of_level(self, lvl: int) -> int:
        return self.mode_ordering[lvl]

    def __repr__(self) -> str:
        lv = ",".join(l.name for l in self.levels)
        extra = ""
        if self.mode_ordering != tuple(range(len(self.levels))):
            extra += f", order={self.mode_ordering}"
        if self.block_shape is not None:
            extra += f", block={self.block_shape}"
        return f"Format([{lv}]{extra})"


# -- Common named formats (paper Fig. 3 and §VI) ----------------------------

def DenseVec() -> Format:
    return Format((Dense,))


def SparseVec() -> Format:
    return Format((Compressed,))


def DenseMat() -> Format:
    return Format((Dense, Dense))


def CSR() -> Format:
    return Format((Dense, Compressed))


def CSC() -> Format:
    return Format((Dense, Compressed), mode_ordering=(1, 0))


def DCSR() -> Format:
    return Format((Compressed, Compressed))


def COO(order: int = 2) -> Format:
    """COO: compressed outer level, singleton trailing levels."""
    return Format((Compressed,) + (Singleton,) * (order - 1))


def CSF(order: int = 3) -> Format:
    """Compressed sparse fiber — all levels compressed (FROSTT tensors)."""
    return Format((Dense,) + (Compressed,) * (order - 1))


def DDC() -> Format:
    """Two dense outer levels + compressed inner ("patents" in the paper)."""
    return Format((Dense, Dense, Compressed))


def DenseND(order: int) -> Format:
    return Format((Dense,) * order)


def BCSR(block: Tuple[int, int] = (2, 2)) -> Format:
    """Blocked CSR: a CSR coordinate tree over the block grid, with a dense
    ``block`` value tile per stored block position."""
    return Format((Dense, Compressed), block_shape=tuple(block))


def BCSC(block: Tuple[int, int] = (2, 2)) -> Format:
    """Blocked CSC: the column-major block grid — a CSC coordinate tree
    over the block grid with a dense value tile per stored block. Lowers
    directly through the blocked transpose walk (core/levels.py); no
    dedicated emitters exist for it."""
    return Format((Dense, Compressed), mode_ordering=(1, 0),
                  block_shape=tuple(block))


def DCSF(order: int = 3) -> Format:
    """Doubly-compressed sparse fiber — every level compressed (hyper-sparse
    FROSTT tensors with empty slices)."""
    return Format((Compressed,) * order)


# ---------------------------------------------------------------------------
# Capability queries — the format-dispatch layer (Chou et al.'s level-format
# abstraction made queryable). `core.lower` and the kernel emitters consult
# these instead of hard-coding per-kernel format assumptions; when a
# capability is missing the lowering engine inserts a logged format
# conversion (see lower._normalize_operands).
# ---------------------------------------------------------------------------

_KEY_TABLE = {
    ("Dense",): "vec",
    ("Compressed",): "spvec",
    ("Dense", "Dense"): "dense",
    ("Dense", "Compressed"): "csr",
    ("Compressed", "Compressed"): "dcsr",
    ("Compressed", "Singleton"): "coo",
    ("Dense", "Dense", "Dense"): "dense3",
    ("Dense", "Compressed", "Compressed"): "csf",
    ("Compressed", "Compressed", "Compressed"): "dcsf",
    ("Compressed", "Singleton", "Singleton"): "coo3",
    ("Dense", "Dense", "Compressed"): "ddc",
}


def format_key(f: Format) -> str:
    """Canonical short name for a spellable format — the format component of
    a conformance-matrix cell ID (e.g. ``spmm/dcsr/nnz/4x1``)."""
    names = tuple(l.name for l in f.levels)
    base = _KEY_TABLE.get(names)
    if base is None:
        base = "".join(n[0].lower() for n in names)
    if f.mode_ordering != tuple(range(len(f.levels))):
        if base == "csr" and f.mode_ordering == (1, 0):
            base = "csc"
        else:
            base += "@" + "".join(str(d) for d in f.mode_ordering)
    if f.is_blocked:
        base = f"b{base}" if base in ("csr", "csc") else f"b[{base}]"
    return base


@dataclasses.dataclass(frozen=True)
class FormatCaps:
    """What a format can do directly, as queried by the lowering engine.

    ``row_partitionable``: a universe (coordinate-value) partition of the
    tensor's dimension 0 maps onto contiguous storage — true when dimension
    0 is stored at the root level and values are scalars. Root may be Dense
    (CSR/CSF) or Compressed (DCSR/DCSF/COO: handled by bucketing the sorted
    root ``crd``, then densifying the window at materialization).

    ``nnz_partitionable``: an equal split of the leaf position space plus an
    image/preimage walk is well-defined — true for every unblocked sparse
    format.

    ``root_tracks_dim0``: the root level stores dimension 0, so non-zero
    partitions own contiguous *row* windows and leaves may compute into a
    local output slice; false (e.g. CSC) means nnz leaves must reduce over
    the full output extent instead.

    ``transpose_walkable``: dimension 0 is NOT at the storage root (CSC,
    BCSC) but the level tree's transpose walk (core/levels.py — an argsort
    of the stored coordinates into dimension-lexicographic order) realizes
    universe row windows directly, with a ``val_idx`` permutation back to
    storage positions for pattern-preserving outputs.

    ``block_row_partitionable`` / ``block_nnz_partitionable``: the blocked
    analogs — a universe partition of dimension 0 can be realized as a
    contiguous (or transpose-walked) *block-row* interval, and the stored
    block position space can be split evenly. True for every dense-root
    block grid (BCSR directly, BCSC via the blocked transpose walk);
    compressed-root block grids still go through a conversion.
    """

    key: str
    order: int
    row_major: bool
    root_compressed: bool
    blocked: bool
    row_partitionable: bool
    nnz_partitionable: bool
    root_tracks_dim0: bool
    transpose_walkable: bool = False
    block_row_partitionable: bool = False
    block_nnz_partitionable: bool = False


def capabilities(f: Format) -> FormatCaps:
    row_major = f.mode_ordering == tuple(range(len(f.levels)))
    root_compressed = f.levels[0].compressed
    dim0_at_root = f.dim_of_level(0) == 0
    blocked_direct = f.is_blocked and not root_compressed and f.is_sparse
    return FormatCaps(
        key=format_key(f),
        order=len(f.levels),
        row_major=row_major,
        root_compressed=root_compressed,
        blocked=f.is_blocked,
        row_partitionable=dim0_at_root and not f.is_blocked,
        nnz_partitionable=f.is_sparse and not f.is_blocked,
        root_tracks_dim0=dim0_at_root,
        transpose_walkable=f.is_sparse and not dim0_at_root,
        block_row_partitionable=blocked_direct,
        block_nnz_partitionable=blocked_direct,
    )


def supports_2d_default(f: Format, space: str) -> bool:
    """Default capability contract shared by the 2-D kernel families
    (spmv/spmm/sddmm/spadd3): universe needs a row walk of the operand —
    CSR directly, DCSR/COO via the densified row-window view, CSC via the
    transpose walk — and nnz needs an nnz-splittable position space (any
    unblocked sparse format). Blocked dense-root grids (BCSR, BCSC) lower
    directly under BOTH strategies at block granularity — block-row
    windows (transpose-walked for BCSC) for universe, equal stored-block
    splits for nnz — through the blocked leaves. Kernel modules wrap this
    in their own ``supports()`` so a family that needs a different walk
    (the spmttkrp override pattern) can diverge."""
    caps = capabilities(f)
    if caps.order != 2:
        return False
    if caps.blocked:
        return (caps.block_row_partitionable if space == "universe"
                else caps.block_nnz_partitionable)
    if space == "universe":
        return caps.row_partitionable or caps.transpose_walkable
    return caps.nnz_partitionable


def conversion_target(f: Format) -> Format:
    """The canonical format a tensor is converted to when no direct kernel
    exists for ``f`` (lower.py logs the fallback; conformance cells that hit
    this path are recorded in the ROADMAP open-items list)."""
    order = len(f.levels)
    if order == 1:
        return SparseVec()
    if order == 2:
        return CSR()
    return CSF(order)
