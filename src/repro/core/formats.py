"""Format language — per-dimension level formats (paper §II-B, §III-B).

A tensor's *coordinate tree* has one level per dimension (in storage order).
Each level is stored with a *level format*:

- ``Dense``      — all coordinates of the level exist; stored implicitly as an
                   index range ``dom = [0, size)``.
- ``Compressed`` — only non-zero coordinates stored, with TACO's ``pos``/
                   ``crd`` arrays. Following the paper (§III-B, Fig. 7) the
                   ``pos`` region conceptually stores *(lo, hi)* range tuples
                   so dependent-partitioning ``image``/``preimage`` apply; we
                   keep the standard length-(parent+1) monotone ``pos`` array
                   and expose the (lo, hi) view as ``pos[i], pos[i+1]-1``.

A :class:`Format` is an ordered list of level formats plus a dimension
ordering (``mode_ordering``), so CSR/CSC/DCSR/CSF/COO are all spellable —
Figure 3 of the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


class LevelFormat:
    """Base class for level formats. Subclasses are stateless singletons."""

    name: str = "?"
    compressed: bool = False
    # COO-style levels that share the position space with their parent
    # (LevelFormat Singleton from Chou et al. [27]); used for fused levels.
    singleton: bool = False

    def __repr__(self) -> str:
        return self.name


class _Dense(LevelFormat):
    name = "Dense"
    compressed = False


class _Compressed(LevelFormat):
    name = "Compressed"
    compressed = True


class _Singleton(LevelFormat):
    """COO trailing level: one coordinate per parent position."""

    name = "Singleton"
    compressed = True
    singleton = True


Dense = _Dense()
Compressed = _Compressed()
Singleton = _Singleton()

_BY_NAME = {"Dense": Dense, "Compressed": Compressed, "Singleton": Singleton}


def level_format(x) -> LevelFormat:
    if isinstance(x, LevelFormat):
        return x
    if isinstance(x, str) and x in _BY_NAME:
        return _BY_NAME[x]
    raise ValueError(f"unknown level format {x!r}")


@dataclasses.dataclass(frozen=True)
class Format:
    """An ordered tuple of level formats + optional mode ordering.

    ``mode_ordering[lvl]`` gives the tensor dimension stored at coordinate
    tree level ``lvl``; identity if omitted (row-major-like). CSC is
    ``Format((Dense, Compressed), mode_ordering=(1, 0))``.
    """

    levels: Tuple[LevelFormat, ...]
    mode_ordering: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        object.__setattr__(
            self, "levels", tuple(level_format(l) for l in self.levels)
        )
        if self.mode_ordering is None:
            object.__setattr__(
                self, "mode_ordering", tuple(range(len(self.levels)))
            )
        if sorted(self.mode_ordering) != list(range(len(self.levels))):
            raise ValueError(f"bad mode ordering {self.mode_ordering}")

    @property
    def order(self) -> int:
        return len(self.levels)

    @property
    def is_sparse(self) -> bool:
        return any(l.compressed for l in self.levels)

    @property
    def is_all_dense(self) -> bool:
        return not self.is_sparse

    def level_of_dim(self, dim: int) -> int:
        return self.mode_ordering.index(dim)

    def dim_of_level(self, lvl: int) -> int:
        return self.mode_ordering[lvl]

    def __repr__(self) -> str:
        lv = ",".join(l.name for l in self.levels)
        if self.mode_ordering != tuple(range(len(self.levels))):
            return f"Format([{lv}], order={self.mode_ordering})"
        return f"Format([{lv}])"


# -- Common named formats (paper Fig. 3 and §VI) ----------------------------

def DenseVec() -> Format:
    return Format((Dense,))


def SparseVec() -> Format:
    return Format((Compressed,))


def DenseMat() -> Format:
    return Format((Dense, Dense))


def CSR() -> Format:
    return Format((Dense, Compressed))


def CSC() -> Format:
    return Format((Dense, Compressed), mode_ordering=(1, 0))


def DCSR() -> Format:
    return Format((Compressed, Compressed))


def COO(order: int = 2) -> Format:
    """COO: compressed outer level, singleton trailing levels."""
    return Format((Compressed,) + (Singleton,) * (order - 1))


def CSF(order: int = 3) -> Format:
    """Compressed sparse fiber — all levels compressed (FROSTT tensors)."""
    return Format((Dense,) + (Compressed,) * (order - 1))


def DDC() -> Format:
    """Two dense outer levels + compressed inner ("patents" in the paper)."""
    return Format((Dense, Dense, Compressed))


def DenseND(order: int) -> Format:
    return Format((Dense,) * order)
