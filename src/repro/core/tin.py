"""Tensor Index Notation (TIN) — the computation language of SpDISTAL.

Paper §II-A: computation is described with TACO-style tensor index notation.
``a(i) = B(i,j) * c(j)`` declares an SpMV; index variables appearing only on
the right-hand side are sum-reduced.

This module defines the TIN AST (accesses, adds, muls, assignment) plus a
string front-end so expressions can be written exactly as in the paper::

    stmt = parse_tin("a(i) = B(i,j) * c(j)", a=a, B=B, c=c)

The AST is deliberately independent of data structures (formats.py),
distribution (tdn.py) and scheduling (schedule.py) — the separation of the
four sub-languages is the paper's first contribution.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple


class IndexVar:
    """A named index variable (paper: ``IndexVar i, j;``)."""

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def __repr__(self) -> str:
        return self.name

    def __eq__(self, other: object) -> bool:
        return isinstance(other, IndexVar) and other.name == self.name

    def __hash__(self) -> int:
        return hash(("IndexVar", self.name))


def index_vars(names: str) -> Tuple[IndexVar, ...]:
    """``i, j, k = index_vars("i j k")``"""
    return tuple(IndexVar(n) for n in names.replace(",", " ").split())


class TinExpr:
    """Base class for right-hand-side expressions."""

    def __add__(self, other: "TinExpr") -> "Add":
        return Add(self, _as_expr(other))

    def __radd__(self, other: "TinExpr") -> "Add":
        return Add(_as_expr(other), self)

    def __mul__(self, other: "TinExpr") -> "Mul":
        return Mul(self, _as_expr(other))

    def __rmul__(self, other: "TinExpr") -> "Mul":
        return Mul(_as_expr(other), self)

    # -- traversal helpers -------------------------------------------------
    def accesses(self) -> List["Access"]:
        raise NotImplementedError

    def index_vars(self) -> List[IndexVar]:
        seen: List[IndexVar] = []
        for acc in self.accesses():
            for iv in acc.idx:
                if iv not in seen:
                    seen.append(iv)
        return seen


@dataclasses.dataclass(frozen=True)
class Literal(TinExpr):
    value: float

    def accesses(self) -> List["Access"]:
        return []

    def __repr__(self) -> str:
        return repr(self.value)


def _as_expr(x: Any) -> TinExpr:
    if isinstance(x, TinExpr):
        return x
    if isinstance(x, (int, float)):
        return Literal(float(x))
    raise TypeError(f"cannot coerce {x!r} to a TIN expression")


class Access(TinExpr):
    """``B(i, j)`` — indexes tensor ``B`` with index variables ``(i, j)``.

    ``tensor`` is any object with ``.name``, ``.shape`` and ``.format``
    attributes (core.tensor.Tensor / TensorVar below).
    """

    __slots__ = ("tensor", "idx")

    def __init__(self, tensor: Any, idx: Sequence[IndexVar]):
        if len(idx) != len(tensor.shape):
            raise ValueError(
                f"access {tensor.name}({','.join(map(str, idx))}) has "
                f"{len(idx)} indices but tensor has order {len(tensor.shape)}"
            )
        self.tensor = tensor
        self.idx = tuple(idx)

    def accesses(self) -> List["Access"]:
        return [self]

    def __repr__(self) -> str:
        return f"{self.tensor.name}({','.join(v.name for v in self.idx)})"


@dataclasses.dataclass(frozen=True)
class Add(TinExpr):
    lhs: TinExpr
    rhs: TinExpr

    def accesses(self) -> List[Access]:
        return self.lhs.accesses() + self.rhs.accesses()

    def __repr__(self) -> str:
        return f"{self.lhs} + {self.rhs}"


@dataclasses.dataclass(frozen=True)
class Mul(TinExpr):
    lhs: TinExpr
    rhs: TinExpr

    def accesses(self) -> List[Access]:
        return self.lhs.accesses() + self.rhs.accesses()

    def __repr__(self) -> str:
        return f"{self.lhs} * {self.rhs}"


class Assignment:
    """``lhs = rhs`` (or ``lhs += rhs``) over index variables.

    Free variables (appearing only in rhs) are sum-reduced — the paper's
    semantics for tensor index notation.
    """

    def __init__(self, lhs: Access, rhs: TinExpr, accumulate: bool = False):
        self.lhs = lhs
        self.rhs = _as_expr(rhs)
        self.accumulate = accumulate

    # -- structural queries used by the scheduler / lowerer ----------------
    @property
    def result_vars(self) -> Tuple[IndexVar, ...]:
        return self.lhs.idx

    @property
    def reduction_vars(self) -> Tuple[IndexVar, ...]:
        return tuple(v for v in self.rhs.index_vars() if v not in self.lhs.idx)

    @property
    def all_vars(self) -> Tuple[IndexVar, ...]:
        out = list(self.lhs.idx)
        for v in self.rhs.index_vars():
            if v not in out:
                out.append(v)
        return tuple(out)

    def accesses(self) -> List[Access]:
        return [self.lhs] + self.rhs.accesses()

    def tensors(self) -> List[Any]:
        seen: List[Any] = []
        for acc in self.accesses():
            if acc.tensor not in seen:
                seen.append(acc.tensor)
        return seen

    def sparse_accesses(self) -> List[Access]:
        return [a for a in self.rhs.accesses() if a.tensor.format.is_sparse]

    def with_tensors(self, mapping: Dict[str, Any]) -> "Assignment":
        """A copy of the statement with tensors swapped by name — used by the
        lowering engine's format-conversion fallback (the converted tensor
        replaces the original throughout the AST). Index structure is
        untouched, so the signature and schedule stay valid."""
        if not mapping:
            return self

        def rebuild(e: TinExpr) -> TinExpr:
            if isinstance(e, Access):
                t = mapping.get(e.tensor.name, e.tensor)
                return Access(t, e.idx)
            if isinstance(e, Add):
                return Add(rebuild(e.lhs), rebuild(e.rhs))
            if isinstance(e, Mul):
                return Mul(rebuild(e.lhs), rebuild(e.rhs))
            return e

        lhs = rebuild(self.lhs)
        return Assignment(lhs, rebuild(self.rhs), accumulate=self.accumulate)

    def var_extent(self, v: IndexVar) -> int:
        """Dimension size an index variable ranges over (must be consistent)."""
        ext: Optional[int] = None
        for acc in self.accesses():
            for axis, iv in enumerate(acc.idx):
                if iv == v:
                    d = acc.tensor.shape[axis]
                    if ext is not None and ext != d:
                        raise ValueError(
                            f"index var {v} ranges over inconsistent extents "
                            f"{ext} vs {d}"
                        )
                    ext = d
        if ext is None:
            raise KeyError(f"index var {v} not used in statement")
        return ext

    def signature(self) -> str:
        """Canonical signature used to pick a specialized leaf kernel.

        E.g. SpMV ``a(i)=B(i,j)*c(j)`` with B sparse →
        ``"d1(i)=s2(i,j)*d1(j)"``.
        """

        def fmt_access(acc: Access) -> str:
            kind = "s" if acc.tensor.format.is_sparse else "d"
            return f"{kind}{len(acc.tensor.shape)}({','.join(v.name for v in acc.idx)})"

        def fmt_expr(e: TinExpr) -> str:
            if isinstance(e, Access):
                return fmt_access(e)
            if isinstance(e, Add):
                return f"{fmt_expr(e.lhs)}+{fmt_expr(e.rhs)}"
            if isinstance(e, Mul):
                return f"{fmt_expr(e.lhs)}*{fmt_expr(e.rhs)}"
            if isinstance(e, Literal):
                return "lit"
            raise TypeError(type(e))

        return f"{fmt_access(self.lhs)}={fmt_expr(self.rhs)}"

    def __repr__(self) -> str:
        op = "+=" if self.accumulate else "="
        return f"{self.lhs} {op} {self.rhs}"


# ---------------------------------------------------------------------------
# String front-end: parse "a(i) = B(i,j) * c(j)" given tensor bindings.
# ---------------------------------------------------------------------------

_ACCESS_RE = re.compile(r"([A-Za-z_]\w*)\s*\(\s*([\w\s,]*?)\s*\)")


def parse_tin(src: str, **tensors: Any) -> Assignment:
    """Parse a TIN statement string into an :class:`Assignment`.

    Supports ``=`` / ``+=`` assignment, ``+`` and ``*`` with standard
    precedence, and parenthesised sub-expressions.
    """
    if "+=" in src:
        lhs_src, rhs_src = src.split("+=", 1)
        accumulate = True
    else:
        lhs_src, rhs_src = src.split("=", 1)
        accumulate = False

    ivars: Dict[str, IndexVar] = {}

    def get_ivar(name: str) -> IndexVar:
        if name not in ivars:
            ivars[name] = IndexVar(name)
        return ivars[name]

    def parse_access(m: re.Match) -> Access:
        tname, idx_src = m.group(1), m.group(2)
        if tname not in tensors:
            raise KeyError(f"tensor {tname!r} not bound (pass {tname}=<tensor>)")
        idx = [get_ivar(s.strip()) for s in idx_src.split(",") if s.strip()]
        return Access(tensors[tname], idx)

    # Tokenize rhs: accesses, + * ( ) literals.
    tokens: List[Any] = []
    pos = 0
    s = rhs_src.strip()
    while pos < len(s):
        ch = s[pos]
        if ch.isspace():
            pos += 1
            continue
        if ch in "+*()":
            tokens.append(ch)
            pos += 1
            continue
        m = _ACCESS_RE.match(s, pos)
        if m:
            tokens.append(parse_access(m))
            pos = m.end()
            continue
        mnum = re.match(r"\d+(\.\d+)?", s[pos:])
        if mnum:
            tokens.append(Literal(float(mnum.group(0))))
            pos += mnum.end()
            continue
        raise SyntaxError(f"cannot tokenize TIN at: {s[pos:]!r}")

    # Recursive-descent: expr := term ('+' term)*; term := factor ('*' factor)*
    idx = 0

    def peek() -> Any:
        return tokens[idx] if idx < len(tokens) else None

    def parse_factor() -> TinExpr:
        nonlocal idx
        t = peek()
        if t == "(":
            idx += 1
            e = parse_expr()
            if peek() != ")":
                raise SyntaxError("unbalanced parens in TIN expression")
            idx += 1
            return e
        if isinstance(t, (Access, Literal)):
            idx += 1
            return t
        raise SyntaxError(f"unexpected token {t!r}")

    def parse_term() -> TinExpr:
        nonlocal idx
        e = parse_factor()
        while peek() == "*":
            idx += 1
            e = Mul(e, parse_factor())
        return e

    def parse_expr() -> TinExpr:
        nonlocal idx
        e = parse_term()
        while peek() == "+":
            idx += 1
            e = Add(e, parse_term())
        return e

    rhs = parse_expr()
    if idx != len(tokens):
        raise SyntaxError(f"trailing tokens in TIN expression: {tokens[idx:]}")

    lm = _ACCESS_RE.search(lhs_src)
    if lm is None:
        raise SyntaxError(f"cannot parse TIN lhs: {lhs_src!r}")
    lhs = parse_access(lm)
    return Assignment(lhs, rhs, accumulate=accumulate)
