"""Level-iterator abstraction — one format-generic walk over coordinate
hierarchies (Chou et al., *Format Abstraction for Sparse Tensor Algebra
Compilers*, composed with distribution as in SpDISTAL §III-B).

The lowering engine does NOT iterate formats; it iterates *level trees*.
A :class:`LevelTree` is instantiated from a tensor's format descriptor and
exposes, per level, the iteration capabilities the compiler needs:

- :class:`DenseIter`      — every coordinate of ``[0, size)`` exists;
  positions are implicit (``parent_pos * size + coord``).
- :class:`CompressedIter` — TACO ``pos``/``crd`` regions; children of
  parent position ``p`` live at positions ``[pos[p], pos[p+1])``.
- :class:`SingletonIter`  — COO trailing level: shares the parent's
  position space, one coordinate per position.
- **Block levels** — when ``block_shape`` is set, every iterator of the
  tree walks the *block grid* (level ``l`` has
  ``ceil(shape[d] / block[d])`` coordinates) and each leaf position
  carries a dense value tile instead of a scalar.

Two walks derive from a tree:

- :meth:`LevelTree.walk` — the **ordered** (storage-order) enumeration of
  all stored coordinates, aligned with the value region. This is what the
  nnz (coordinate-position) strategies split.
- :meth:`LevelTree.row_walk` — the dimension-lexicographic enumeration
  (sorted by dim 0, then dim 1, …). For row-major trees it IS the storage
  walk (``ordered=True``, identity permutation); for column-major roots
  (CSC, BCSC) it is the **transpose walk**: an ``argsort`` of the stored
  coordinates plus the permutation back to storage positions. Universe
  (coordinate-value) partitions of dimension 0 bucket this walk — which is
  what lets every column-major format lower DIRECTLY instead of paying a
  logged conversion to its row-major sibling.

Emitters consume *packed level arrays* — the per-color shard arrays
``core.partition`` materializes from a walk (``pos<l>``/``crd<l>``/
``vals`` for grouped trees, ``dim<d>`` coordinate columns for flat walks,
``val_idx`` scatter maps for permuted walks) — so one emitter per
(expression × strategy) serves every spellable format.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from . import formats as fmt


@dataclasses.dataclass(frozen=True)
class Walk:
    """An enumeration of a tree's stored coordinates.

    ``coords``: (N, order) coordinates in *dimension* order (block-grid
    coordinates for blocked trees). ``perm``: (N,) maps walk position →
    storage position (the index into the value region; identity when
    ``ordered``). ``ordered`` is True when the walk visits entries in
    storage order — the cheap case where no permutation is materialized."""

    coords: np.ndarray
    perm: np.ndarray
    ordered: bool

    @property
    def n(self) -> int:
        return int(self.coords.shape[0])


class LevelIter:
    """One level of a coordinate tree, as the lowering engine iterates it.

    ``size`` is the level's coordinate extent (block-grid extent for
    blocked trees); ``block`` the dense tile extent attached to each
    coordinate (1 for scalar trees); ``pos``/``crd`` the physical regions
    (None where implicit)."""

    kind: str = "?"
    compressed: bool = False
    singleton: bool = False

    def __init__(self, size: int, dim: int, block: int = 1,
                 pos: Optional[np.ndarray] = None,
                 crd: Optional[np.ndarray] = None):
        self.size = int(size)
        self.dim = int(dim)          # tensor dimension stored at this level
        self.block = int(block)
        self.pos = pos
        self.crd = crd

    def coord_range(self) -> Tuple[int, int]:
        """Universe iteration bounds of this level's coordinate space."""
        return (0, self.size)

    def children(self, parent_pos: int) -> Tuple[int, int]:
        """Position range of ``parent_pos``'s children at this level."""
        raise NotImplementedError

    def positions(self, parent_count: int) -> int:
        """Total positions at this level given the parent position count."""
        raise NotImplementedError

    def __repr__(self) -> str:
        b = f", block={self.block}" if self.block != 1 else ""
        return f"{self.kind}(size={self.size}, dim={self.dim}{b})"


class DenseIter(LevelIter):
    kind = "dense"

    def children(self, parent_pos: int) -> Tuple[int, int]:
        return (parent_pos * self.size, (parent_pos + 1) * self.size)

    def positions(self, parent_count: int) -> int:
        return parent_count * self.size


class CompressedIter(LevelIter):
    kind = "compressed"
    compressed = True

    def children(self, parent_pos: int) -> Tuple[int, int]:
        return (int(self.pos[parent_pos]), int(self.pos[parent_pos + 1]))

    def positions(self, parent_count: int) -> int:
        return int(self.pos[parent_count])


class SingletonIter(LevelIter):
    kind = "singleton"
    compressed = True
    singleton = True

    def children(self, parent_pos: int) -> Tuple[int, int]:
        return (parent_pos, parent_pos + 1)   # shared position space

    def positions(self, parent_count: int) -> int:
        return parent_count


@dataclasses.dataclass
class LevelTree:
    """A tensor's coordinate hierarchy as level iterators (storage order).

    Built by :func:`tree_of` / ``Tensor.level_tree()`` from the format
    descriptor. The predicates below are the ONLY format questions the
    generic emitters ask — adding a format means teaching the tree to
    answer them, not adding an emitter."""

    levels: Tuple[LevelIter, ...]
    shape: Tuple[int, ...]
    mode_ordering: Tuple[int, ...]
    block_shape: Optional[Tuple[int, ...]]
    _coords_fn: object = dataclasses.field(repr=False, default=None)

    @property
    def order(self) -> int:
        return len(self.levels)

    @property
    def blocked(self) -> bool:
        return self.block_shape is not None

    @property
    def root_dim(self) -> int:
        """Tensor dimension tracked by the storage root level."""
        return self.mode_ordering[0]

    @property
    def root_tracks_dim0(self) -> bool:
        return self.root_dim == 0

    @property
    def transposed(self) -> bool:
        """True for column-major roots (CSC, BCSC): a universe partition
        of dimension 0 needs the transpose walk."""
        return not self.root_tracks_dim0

    @property
    def grouped_middle(self) -> bool:
        """Order-3 trees with a grouped (non-singleton) middle level —
        what the two-level pos/crd leaf walk (CSF/DCSF) consumes."""
        return self.order >= 3 and not self.levels[1].singleton

    @property
    def trailing_singletons(self) -> bool:
        """COO-style trees: every level past the root is a singleton, so
        the only walk is the flat per-position coordinate enumeration."""
        return self.order >= 2 and all(l.singleton for l in self.levels[1:])

    # -- walks --------------------------------------------------------------

    def walk(self) -> Walk:
        """Storage-order enumeration of all stored coordinates (block-grid
        coordinates for blocked trees), aligned with the value region."""
        coords = np.asarray(self._coords_fn(), dtype=np.int64)
        n = coords.shape[0]
        ordered = self.mode_ordering == tuple(range(self.order))
        return Walk(coords=coords, perm=np.arange(n, dtype=np.int64),
                    ordered=ordered)

    def row_walk(self) -> Walk:
        """Dimension-lexicographic enumeration — the transpose walk for
        column-major roots, the plain walk otherwise. ``perm`` maps each
        walk position back to its storage position, so materializers can
        permute values and record ``val_idx`` scatter maps for
        pattern-preserving outputs."""
        w = self.walk()
        if w.ordered:
            return w
        # lexsort keys: last key is primary → feed dims in reverse
        perm = np.lexsort(tuple(w.coords[:, d]
                                for d in reversed(range(self.order))))
        return Walk(coords=w.coords[perm], perm=perm.astype(np.int64),
                    ordered=False)


def tree_of(tensor) -> LevelTree:
    """Instantiate the level tree of a Tensor (or TensorVar — walks then
    unavailable) from its format descriptor."""
    f: fmt.Format = tensor.format
    bs = f.block_shape
    its = []
    for l, lf in enumerate(f.levels):
        dim = f.dim_of_level(l)
        ld = getattr(tensor, "levels", None)
        size = (ld[l].size if ld else
                -(-tensor.shape[dim] // (bs[dim] if bs else 1)))
        block = bs[dim] if bs else 1
        pos = ld[l].pos if ld else None
        crd = ld[l].crd if ld else None
        if lf.singleton:
            its.append(SingletonIter(size, dim, block, pos, crd))
        elif lf.compressed:
            its.append(CompressedIter(size, dim, block, pos, crd))
        else:
            its.append(DenseIter(size, dim, block, pos, crd))
    coords_fn = None
    if hasattr(tensor, "coords"):
        coords_fn = tensor.block_coords if f.is_blocked else tensor.coords
    return LevelTree(levels=tuple(its), shape=tuple(tensor.shape),
                     mode_ordering=tuple(f.mode_ordering),
                     block_shape=bs, _coords_fn=coords_fn)
