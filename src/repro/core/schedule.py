"""Scheduling language (paper §II-C).

Transformations: ``divide``/``split`` (universe or non-zero strip-mining),
``fuse`` (coordinate/loop fusion), ``distribute`` (map a loop onto machine
dimensions), ``communicate`` (placement of data movement), ``parallelize``
(leaf parallelism), ``reorder``, ``precompute``.

A `Schedule` records the transformation list applied to a TIN statement and
canonicalizes it into a `DistStrategy` that the lowering engine (lower.py)
consumes — mirroring how SpDISTAL's scheduling commands drive the Fig. 9a
code-generation algorithm.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .tdn import Machine, MachineDim
from .tin import Assignment, IndexVar


class ParallelUnit:
    """Leaf-level parallel hardware (paper: CPUThread, GPUBlock, ...).

    On TPU the leaf unit is the vector lane / MXU tile driven by a Pallas
    grid — ``TPUGrid`` — or XLA's auto-vectorization — ``VectorLanes``.
    """

    def __init__(self, name: str):
        self.name = name

    def __repr__(self) -> str:
        return self.name


CPUThread = ParallelUnit("CPUThread")
TPUGrid = ParallelUnit("TPUGrid")
VectorLanes = ParallelUnit("VectorLanes")


@dataclasses.dataclass
class ScheduleOp:
    kind: str
    args: tuple


@dataclasses.dataclass
class DistStrategy:
    """Canonical distribution strategy extracted from a schedule.

    ``space`` is 'universe' (coordinate-value distributed loop → universe
    partitions) or 'nnz' (coordinate-position loop → non-zero partitions),
    paper §IV-C. ``vars`` are the pre-divide loop variables being
    distributed, one per machine dimension — a single entry is the classic
    1-D distribution; two entries map onto a 2-D processor grid (paper
    `distribute((i, k) → (x, y))`, the SUMMA-style tilings of §VI). For
    nnz strategies the first entry is the fused variable and later entries
    are the successive inner split variables of the nested pos-split."""

    space: str                      # 'universe' | 'nnz'
    vars: Tuple[IndexVar, ...]      # distributed index variables (outer)
    machine_dims: Tuple[MachineDim, ...]
    fused_vars: Optional[Tuple[IndexVar, ...]] = None   # for nnz via fusion
    communicate_at: Dict[str, str] = dataclasses.field(default_factory=dict)
    leaf_unit: Optional[ParallelUnit] = None
    # Pallas leaf tile hint for blocked formats: (block_R, block_nb) group
    # shape chosen by the autoscheduler's tune_ell pass (None → the
    # kernels' built-in fallback defaults).
    tile: Optional[Tuple[int, int]] = None
    # Per-operand replication: (tensor_name, machine_dim_name) pairs. A
    # replicated operand is NOT partitioned along the named machine axis —
    # every processor along it holds the full slice (the DISTAL
    # "1.5-D/2.5-D" communication-avoiding schedules): broadcast bytes are
    # paid once along that axis to save reduction hops elsewhere.
    replicate: Tuple[Tuple[str, str], ...] = ()

    @property
    def var(self) -> IndexVar:
        """First (row-axis) distributed variable — the whole strategy for
        1-D schedules; kept for the single-axis call sites."""
        return self.vars[0]

    @property
    def pieces(self) -> int:
        p = 1
        for d in self.machine_dims:
            p *= d.size
        return p

    @property
    def is_grid(self) -> bool:
        """True when the schedule distributes over a multi-dim machine
        grid (len(vars) > 1) — lowering routes to the grid subsystem."""
        return len(self.vars) > 1

    @property
    def grid_shape(self) -> Tuple[int, ...]:
        """Processor-grid shape: (P, Q) for 1-D/2-D strategies (Q = 1 when
        1-D), the full (P, Q, R, ...) tuple for higher-order grids."""
        sizes = [d.size for d in self.machine_dims]
        while len(sizes) < 2:
            sizes.append(1)
        return tuple(sizes)

    @property
    def space_label(self) -> str:
        """Strategy component of a conformance cell ID: ``rows`` for
        coordinate-value (universe) loops, ``nnz`` for coordinate-position
        loops."""
        return "rows" if self.space == "universe" else "nnz"

    @property
    def mesh_label(self) -> str:
        """Mesh-shape component of a conformance cell ID (``4x1``, ``2x2``,
        ``2x2x2``; a trailing ``r`` marks a replicated schedule)."""
        sizes = [d.size for d in self.machine_dims]
        while len(sizes) < 2:
            sizes.append(1)
        label = "x".join(str(s) for s in sizes)
        return label + ("r" if self.replicate else "")


class Schedule:
    """Fluent scheduling API bound to a TIN statement (paper Fig. 1)."""

    def __init__(self, stmt: Assignment, machine: Machine):
        self.stmt = stmt
        self.machine = machine
        self.ops: List[ScheduleOp] = []
        # derived state
        self._divided: Dict[str, Tuple[IndexVar, IndexVar, MachineDim, str]] = {}
        self._fused: Dict[str, Tuple[IndexVar, ...]] = {}
        self._distributed: List[IndexVar] = []
        self._communicate: Dict[str, str] = {}
        self._leaf_unit: Optional[ParallelUnit] = None
        self._reorder: Optional[Tuple[IndexVar, ...]] = None
        self._tile: Optional[Tuple[int, int]] = None
        self._replicate: List[Tuple[str, str]] = []
        # inner-split var -> the ORIGINAL loop variable it descends from,
        # so nested divides (divide j, then divide its inner half again)
        # canonicalize to the same origin var on both machine axes.
        self._inner_origin: Dict[str, IndexVar] = {}

    # -- transformations ----------------------------------------------------
    def fuse(self, i: IndexVar, j: IndexVar, f: IndexVar) -> "Schedule":
        """Collapse loops i, j into f (coordinate fusion when i, j index a
        sparse tensor's levels — enables non-zero divides)."""
        prior = self._fused.get(i.name)
        base = prior if prior is not None else (i,)
        self._fused[f.name] = tuple(base) + (j,)
        self.ops.append(ScheduleOp("fuse", (i, j, f)))
        return self

    def divide(self, i: IndexVar, io: IndexVar, ii: IndexVar,
               mdim: MachineDim, space: str = "universe") -> "Schedule":
        """Split loop ``i`` into ``pieces`` chunks (outer ``io``).

        ``space='universe'`` splits the coordinate range (paper divide);
        ``space='nnz'`` strip-mines non-zero positions (Senanayake et al.'s
        pos-split variant), used after ``fuse`` for non-zero distribution."""
        if space not in ("universe", "nnz"):
            raise ValueError(space)
        self._divided[io.name] = (i, ii, mdim, space)
        self._inner_origin[ii.name] = self._inner_origin.get(i.name, i)
        self.ops.append(ScheduleOp("divide", (i, io, ii, mdim, space)))
        return self

    # paper spells the nnz variant `split`/`pos`; alias for readability
    def pos_split(self, i: IndexVar, io: IndexVar, ii: IndexVar,
                  mdim: MachineDim) -> "Schedule":
        return self.divide(i, io, ii, mdim, space="nnz")

    def distribute(self, *vars: IndexVar) -> "Schedule":
        for v in vars:
            if v.name not in self._divided:
                raise ValueError(
                    f"distribute({v}): variable must be the outer result of "
                    "a divide/pos_split")
            self._distributed.append(v)
        self.ops.append(ScheduleOp("distribute", vars))
        return self

    def replicate(self, tensors: Sequence, mdim: MachineDim) -> "Schedule":
        """Replicate ``tensors`` along machine dimension ``mdim`` instead of
        partitioning them — the communication-avoiding knob (DISTAL's
        1.5-D/2.5-D schedules): every processor along ``mdim`` holds the
        operand's full slice, eliminating the reduction hops along the
        other axes at the cost of one broadcast along ``mdim``."""
        for t in tensors:
            self._replicate.append((t.name, mdim.name))
        self.ops.append(ScheduleOp("replicate", (tuple(tensors), mdim)))
        return self

    def communicate(self, tensors: Sequence, at: IndexVar) -> "Schedule":
        for t in tensors:
            self._communicate[t.name] = at.name
        self.ops.append(ScheduleOp("communicate", (tuple(tensors), at)))
        return self

    def parallelize(self, v: IndexVar, unit: ParallelUnit) -> "Schedule":
        self._leaf_unit = unit
        self.ops.append(ScheduleOp("parallelize", (v, unit)))
        return self

    def reorder(self, *vars: IndexVar) -> "Schedule":
        self._reorder = tuple(vars)
        self.ops.append(ScheduleOp("reorder", vars))
        return self

    def precompute(self, expr, i: IndexVar, iw: IndexVar) -> "Schedule":
        self.ops.append(ScheduleOp("precompute", (expr, i, iw)))
        return self

    def tile_hint(self, block_r: int, block_n: int) -> "Schedule":
        """Pin the Pallas leaf tile (block_R, block_nb) for blocked
        formats — set by the autoscheduler from ``tune_ell``; the kernels
        fall back to their built-in defaults when unset."""
        self._tile = (int(block_r), int(block_n))
        self.ops.append(ScheduleOp("tile_hint", self._tile))
        return self

    # -- canonicalization ---------------------------------------------------
    def strategy(self) -> DistStrategy:
        if not self._distributed:
            raise ValueError("schedule has no distribute() — nothing to lower")
        mdims: List[MachineDim] = []
        spaces = set()
        outer_vars = []
        for io in self._distributed:
            i, ii, mdim, space = self._divided[io.name]
            mdims.append(mdim)
            spaces.add(space)
            # resolve inner-split vars back to their original loop var so a
            # nested divide (j -> y, then its inner half -> z) reads as the
            # SAME origin var distributed over two machine axes
            outer_vars.append(self._inner_origin.get(i.name, i))
        if len(spaces) != 1:
            raise NotImplementedError("mixed universe/nnz distribution")
        space = spaces.pop()
        var = outer_vars[0]
        fused = self._fused.get(var.name)
        if space == "nnz" and fused is None and len(self._fused) == 0:
            # nnz split directly on a single sparse loop variable
            fused = (var,)
        return DistStrategy(
            space=space,
            vars=tuple(outer_vars),
            machine_dims=tuple(mdims),
            fused_vars=fused,
            communicate_at=dict(self._communicate),
            leaf_unit=self._leaf_unit,
            tile=self._tile,
            replicate=tuple(self._replicate),
        )

    def __repr__(self) -> str:
        return "Schedule[" + "; ".join(
            f"{op.kind}{op.args}" for op in self.ops) + "]"
