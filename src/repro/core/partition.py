"""Dependent partitioning for sparse coordinate trees (paper §III-A, §IV).

This module is the TPU/XLA adaptation of Legion's dependent partitioning:
instead of runtime colorings of dynamically-sized regions, we compute — at
*plan time*, on host — per-color ``(lo, hi)`` interval bounds for every level
of every tensor's coordinate tree, then *materialize* statically-shaped,
padded per-shard arrays that `jax.shard_map` can consume.

The level functions mirror paper Table I exactly:

- ``partition_by_bounds``        — Dense init (universe or nnz split)
- ``partition_by_value_ranges``  — Compressed universe init (bucket crd)
- ``image(pos, P_pos)``          — Compressed ``partitionFromParent``
- ``preimage(pos, P_crd)``       — Compressed ``partitionFromChild``

All partitions here are *interval* partitions (each color owns a contiguous
range). This covers every schedule in the paper's evaluation; arbitrary
colorings degrade to replication (communication-safe over-approximation),
which is Legion's coherence story made explicit.
"""
from __future__ import annotations

import contextlib
import dataclasses
import zlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import formats as fmt
from ..runtime import telemetry
from .cache import LRUCache
from .tensor import Tensor, INT

Bounds = np.ndarray  # (P, 2) int64, [lo, hi) per color


# ---------------------------------------------------------------------------
# Initial level partitions (paper: init/create/finalize *Partition entries)
# ---------------------------------------------------------------------------

def partition_by_bounds(n: int, pieces: int) -> Bounds:
    """Equal split of ``[0, n)`` into ``pieces`` colors (universe partition).

    Matches the paper's generated code: ``iLo = io * (dim / pieces)`` with
    ceil-div chunks so all elements are covered.
    """
    chunk = -(-n // pieces) if pieces else n
    lo = np.minimum(np.arange(pieces, dtype=np.int64) * chunk, n)
    hi = np.minimum(lo + chunk, n)
    return np.stack([lo, hi], axis=1)


def partition_nonzeros(nnz: int, pieces: int,
                       weights: Optional[np.ndarray] = None) -> Bounds:
    """Split of the position space ``[0, nnz)`` — the tilde operator.

    ``weights`` (pieces,) generalizes the equal split to heterogeneous
    shard speeds: shard p receives ~weights[p]/Σw of the non-zeros. This is
    the straggler-mitigation path (runtime/fault.StragglerMitigator emits
    the weights; re-lowering with them is the re-plan)."""
    if weights is None:
        return partition_by_bounds(nnz, pieces)
    w = np.asarray(weights, dtype=np.float64)
    assert w.shape == (pieces,) and (w > 0).all()
    ends = np.floor(np.cumsum(w / w.sum()) * nnz).astype(np.int64)
    ends[-1] = nnz
    starts = np.concatenate([[0], ends[:-1]])
    return np.stack([starts, ends], axis=1)


def partition_by_value_ranges(crd: np.ndarray, value_bounds: Bounds) -> Bounds:
    """Universe partition of a Compressed level: bucket sorted ``crd`` values
    into coordinate ranges (paper Table I, Compressed/universe).

    Requires globally sorted ``crd`` (true for root compressed levels such as
    a sparse vector or the fused level of COO).
    """
    lo = np.searchsorted(crd, value_bounds[:, 0], side="left")
    hi = np.searchsorted(crd, value_bounds[:, 1], side="left")
    return np.stack([lo, hi], axis=1).astype(np.int64)


# ---------------------------------------------------------------------------
# Dependent partitioning (paper §III-A; Table I derived partitions)
# ---------------------------------------------------------------------------

def image(pos: np.ndarray, parent_bounds: Bounds) -> Bounds:
    """``image(S, P_S, D)``: color crd positions pointed to by parent entries.

    For an interval partition of parent entries ``[lo, hi)``, the pointed-to
    crd positions are exactly ``[pos[lo], pos[hi])`` because ``pos`` is
    monotone — the contiguity that makes static materialization possible.
    """
    pos = np.asarray(pos, dtype=np.int64)
    return np.stack(
        [pos[parent_bounds[:, 0]], pos[parent_bounds[:, 1]]], axis=1
    )


def preimage(pos: np.ndarray, child_bounds: Bounds) -> Bounds:
    """``preimage(S, P_D, D)``: color parent entries whose pos-range
    intersects each child (position-space) interval ``[plo, phi)``.

    Returns possibly *overlapping* intervals — a parent entry straddling a
    boundary belongs to both colors (paper Fig. 6b). Empty child intervals
    produce empty parent intervals.
    """
    pos = np.asarray(pos, dtype=np.int64)
    plo, phi = child_bounds[:, 0], child_bounds[:, 1]
    # first parent whose end > plo ; first parent whose start >= phi
    lo = np.searchsorted(pos[1:], plo, side="right")
    hi = np.searchsorted(pos[:-1], phi, side="left")
    hi = np.maximum(hi, lo)  # empty intervals stay empty
    empty = plo >= phi
    lo = np.where(empty, 0, lo)
    hi = np.where(empty, 0, hi)
    return np.stack([lo, hi], axis=1)


# ---------------------------------------------------------------------------
# Full coordinate-tree partitions (paper §IV-A intuition + Fig. 9a)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LevelPartition:
    """Interval bounds for one level.

    ``coord_bounds``: bounds in the level's *coordinate* space (only
    meaningful for Dense levels / the root); ``pos_bounds``: bounds in the
    level's *position* space (crd/vals indices) for compressed levels.
    """

    coord_bounds: Optional[Bounds] = None
    pos_bounds: Optional[Bounds] = None
    replicated: bool = False


@dataclasses.dataclass
class TensorPartition:
    """A full coordinate-tree partition of one tensor (or replication)."""

    tensor: Tensor
    pieces: int
    levels: List[LevelPartition]
    replicated: bool = False
    # For nnz-partitions: bounds of the values/position space at the leaf.
    vals_bounds: Optional[Bounds] = None
    # Bounds over the *root coordinate space* (output-row ownership etc.).
    root_coord_bounds: Optional[Bounds] = None
    overlapping_root: bool = False  # preimage-derived roots may overlap
    # Grid shape when this is a multi-axis tile partition: (P, Q) colors
    # are row-major over the P×Q cross product of levels[0] row windows ×
    # levels[1] column windows; (P, Q, R) bricks extend the cross product
    # to levels[2] windows (core/grid.py). None for all 1-D partitions.
    grid: Optional[Tuple[int, ...]] = None
    # Transpose-walked universe partitions (column-major roots — CSC,
    # BCSC): the row walk's permutation, walk position → storage position.
    # ``vals_bounds`` then index the WALK space; materializers permute the
    # value region through this and carry ``val_idx`` scatter maps so
    # pattern-preserving outputs land back in storage order. None for
    # ordered (storage-order) walks.
    walk_perm: Optional[np.ndarray] = None

    def max_counts(self) -> Dict[str, int]:
        out = {}
        if self.vals_bounds is not None:
            out["vals"] = int((self.vals_bounds[:, 1] - self.vals_bounds[:, 0]).max())
        if self.root_coord_bounds is not None:
            out["rows"] = int(
                (self.root_coord_bounds[:, 1] - self.root_coord_bounds[:, 0]).max()
            )
        return out

    def imbalance(self) -> float:
        """max/mean − 1 of per-color vals counts — the paper's load-balance
        story (§II-D): universe partitions of skewed tensors → large value;
        non-zero partitions → ~0."""
        if self.vals_bounds is None:
            return 0.0
        counts = (self.vals_bounds[:, 1] - self.vals_bounds[:, 0]).astype(np.float64)
        if counts.mean() == 0:
            return 0.0
        return float(counts.max() / counts.mean() - 1.0)


def _dense_prefix(tensor: Tensor) -> int:
    return sum(1 for lf in tensor.format.levels if not lf.compressed)


def block_aligned_row_bounds(n: int, pieces: int, block_rows: int) -> Bounds:
    """Equal universe split of ``[0, n)`` whose cut points land on block-row
    boundaries: split the block-row grid evenly, then scale back to rows
    (clipped to ``n`` for the boundary block). Row-partitioning a blocked
    tensor and its unblocked co-operands with these bounds keeps every
    color's row window identical across formats."""
    grid_rows = -(-n // block_rows)
    bb = partition_by_bounds(grid_rows, pieces)
    return np.minimum(bb * block_rows, n)


def partition_tensor_rows(tensor: Tensor, row_bounds: Bounds) -> TensorPartition:
    """Universe partition of the ROOT level by coordinate intervals, derived
    downward through the whole tree (paper: ``partitionFromParent`` chain).

    Works for any supported format. Rows = coordinates of storage level 0.
    A Dense root keys the chain directly (CSR/CSF); a Compressed root
    (DCSR/DCSF/COO) is bucketed with ``partition_by_value_ranges`` over its
    sorted ``crd`` first — paper Table I's Compressed/universe entry — and
    the image chain continues from the resulting position interval. Blocked
    tensors partition at block-row granularity (see
    ``partition_tensor_block_rows``). Column-major roots (CSC, BCSC) —
    where dimension 0 is NOT stored at the root — bucket the level tree's
    TRANSPOSE walk instead (core/levels.py): per-color contiguous
    intervals of the row-sorted enumeration, carried with the permutation
    back to storage positions.
    """
    if tensor.format.is_blocked:
        if tensor.format.dim_of_level(0) != 0:
            return _partition_tensor_block_rows_walk(tensor, row_bounds)
        return partition_tensor_block_rows(tensor, row_bounds)
    if tensor.format.dim_of_level(0) != 0:
        return _partition_tensor_rows_walk(tensor, row_bounds)
    pieces = row_bounds.shape[0]
    levels: List[LevelPartition] = []
    order = tensor.order
    n_dense = _dense_prefix(tensor)

    if n_dense == 0:
        # Compressed (or COO fused) root: bucket stored row coords.
        root = tensor.levels[0]
        pos_bounds = partition_by_value_ranges(root.crd, row_bounds)
        levels.append(LevelPartition(coord_bounds=row_bounds.copy(),
                                     pos_bounds=pos_bounds.copy()))
        start_lvl = 1
    else:
        # Dense prefix: coordinate bounds multiply down (row-major position
        # math).
        levels.append(LevelPartition(coord_bounds=row_bounds.copy()))
        pos_bounds = row_bounds.astype(np.int64)
        for l in range(1, n_dense):
            size = tensor.levels[l].size
            pos_bounds = pos_bounds * size
            levels.append(
                LevelPartition(coord_bounds=None, pos_bounds=pos_bounds.copy()))
        start_lvl = n_dense
    # Compressed suffix: image through each pos array.
    for l in range(start_lvl, order):
        ld = tensor.levels[l]
        if ld.kind.singleton:
            levels.append(LevelPartition(pos_bounds=pos_bounds.copy()))
            continue
        pos_bounds = image(ld.pos, pos_bounds)
        levels.append(LevelPartition(pos_bounds=pos_bounds.copy()))
    if tensor.format.is_all_dense:
        # leaf position space = linearized dense positions
        for l in range(n_dense, order):  # pragma: no cover (n_dense == order)
            pass
        vb = row_bounds.astype(np.int64)
        for l in range(1, order):
            vb = vb * tensor.levels[l].size
        vals_bounds = vb
    else:
        vals_bounds = pos_bounds
    return TensorPartition(
        tensor=tensor,
        pieces=pieces,
        levels=levels,
        vals_bounds=vals_bounds,
        root_coord_bounds=row_bounds.copy(),
        overlapping_root=False,
    )


def partition_tensor_block_rows(tensor: Tensor, row_bounds: Bounds,
                                ) -> TensorPartition:
    """Universe partition of a blocked tensor at BLOCK-ROW granularity.

    The coordinate tree indexes the block grid, so a row interval realizes
    as a contiguous block-row interval: the given row bounds are snapped to
    block boundaries (identity when the caller used
    ``block_aligned_row_bounds``; unaligned cuts give the straddling block
    to the earlier color so windows stay disjoint), then the image chain
    derives the stored-block position interval exactly as for CSR.
    ``vals_bounds`` index the (n_blocks, br, bc) tile axis;
    ``root_coord_bounds`` stay in ROW space (clipped to the tensor edge) so
    output scatters are format-agnostic."""
    assert tensor.format.is_blocked and tensor.order == 2
    if _dense_prefix(tensor) != 1:
        raise ValueError(
            f"direct block partition needs a dense root: {tensor.format}")
    br = tensor.format.block_shape[0]
    n = tensor.shape[0]
    pieces = row_bounds.shape[0]
    blo = row_bounds[:, 0].astype(np.int64) // br
    bhi = -(-row_bounds[:, 1].astype(np.int64) // br)
    for p in range(1, pieces):          # disjoint block windows
        blo[p] = max(blo[p], bhi[p - 1])
        bhi[p] = max(bhi[p], blo[p])
    bb = np.stack([blo, bhi], axis=1)
    pos_bounds = image(tensor.levels[1].pos, bb)
    levels = [LevelPartition(coord_bounds=bb.copy()),
              LevelPartition(pos_bounds=pos_bounds.copy())]
    rows = np.minimum(bb * br, n)
    return TensorPartition(
        tensor=tensor, pieces=pieces, levels=levels,
        vals_bounds=pos_bounds, root_coord_bounds=rows,
        overlapping_root=False,
    )


def _partition_tensor_rows_walk(tensor: Tensor, row_bounds: Bounds,
                                ) -> TensorPartition:
    """Universe row partition of a COLUMN-MAJOR root (CSC) via the level
    tree's transpose walk: the stored entries are enumerated in
    dimension-lexicographic order (an argsort), so each row window maps to
    a contiguous interval of the WALK — bucketed with searchsorted exactly
    like a compressed root's sorted ``crd``. The walk permutation rides on
    the partition; materialization permutes values through it and keeps a
    ``val_idx`` map for pattern-preserving outputs."""
    pieces = row_bounds.shape[0]
    w = tensor.level_tree().row_walk()
    rows = w.coords[:, 0] if w.n else np.zeros((0,), np.int64)
    lo = np.searchsorted(rows, row_bounds[:, 0], side="left")
    hi = np.searchsorted(rows, row_bounds[:, 1], side="left")
    wb = np.stack([lo, hi], axis=1).astype(np.int64)
    levels = [LevelPartition(coord_bounds=row_bounds.astype(np.int64).copy(),
                             pos_bounds=wb.copy()),
              LevelPartition(pos_bounds=wb.copy())]
    return TensorPartition(
        tensor=tensor, pieces=pieces, levels=levels,
        vals_bounds=wb, root_coord_bounds=row_bounds.astype(np.int64).copy(),
        overlapping_root=False, walk_perm=w.perm,
    )


def _partition_tensor_block_rows_walk(tensor: Tensor, row_bounds: Bounds,
                                      ) -> TensorPartition:
    """Blocked transpose-walk universe partition (BCSC): the block-grid
    transpose walk sorted by (block-row, block-col) is bucketed into
    block-row windows; ``root_coord_bounds`` stay in ROW space (clipped to
    the tensor edge) so output scatters are format-agnostic, exactly as in
    ``partition_tensor_block_rows``."""
    assert tensor.format.is_blocked and tensor.order == 2
    if _dense_prefix(tensor) != 1:
        raise ValueError(
            f"direct block partition needs a dense root: {tensor.format}")
    br = tensor.format.block_shape[0]
    n = tensor.shape[0]
    pieces = row_bounds.shape[0]
    blo = row_bounds[:, 0].astype(np.int64) // br
    bhi = -(-row_bounds[:, 1].astype(np.int64) // br)
    for p in range(1, pieces):          # disjoint block windows
        blo[p] = max(blo[p], bhi[p - 1])
        bhi[p] = max(bhi[p], blo[p])
    bb = np.stack([blo, bhi], axis=1)
    w = tensor.level_tree().row_walk()
    brows = w.coords[:, 0] if w.n else np.zeros((0,), np.int64)
    lo = np.searchsorted(brows, bb[:, 0], side="left")
    hi = np.searchsorted(brows, bb[:, 1], side="left")
    wb = np.stack([lo, hi], axis=1).astype(np.int64)
    levels = [LevelPartition(coord_bounds=bb.copy(), pos_bounds=wb.copy()),
              LevelPartition(pos_bounds=wb.copy())]
    rows = np.minimum(bb * br, n)
    return TensorPartition(
        tensor=tensor, pieces=pieces, levels=levels,
        vals_bounds=wb, root_coord_bounds=rows,
        overlapping_root=False, walk_perm=w.perm,
    )


def partition_tensor_block_nonzeros(tensor: Tensor, pieces: int,
                                    weights: Optional[np.ndarray] = None,
                                    init_bounds: Optional[Bounds] = None,
                                    ) -> TensorPartition:
    """Non-zero partition of a blocked tensor: equal (or weighted) split of
    the STORED-BLOCK position space, root block-row ownership derived with
    preimage. The per-color payload is block-granular — each position moves
    a whole (br, bc) tile. Column-major grids (BCSC) derive the root
    windows in the root's OWN dimension (block-columns); leaves then
    reduce over the full output extent, the CSC story at block
    granularity."""
    assert tensor.format.is_blocked and tensor.order == 2
    if _dense_prefix(tensor) != 1:
        raise ValueError(
            f"direct block partition needs a dense root: {tensor.format}")
    root_dim = tensor.format.dim_of_level(0)
    b_root = tensor.format.block_shape[root_dim]
    n = tensor.shape[root_dim]
    n_blocks = tensor.levels[1].nnz or 0
    init = (partition_nonzeros(n_blocks, pieces, weights)
            if init_bounds is None
            else np.asarray(init_bounds, dtype=np.int64))
    up = preimage(tensor.levels[1].pos, init)       # root-level entry bounds
    levels = [LevelPartition(coord_bounds=up.copy()),
              LevelPartition(pos_bounds=init.copy())]
    rows = np.minimum(up * b_root, n)
    return TensorPartition(
        tensor=tensor, pieces=pieces, levels=levels,
        vals_bounds=init.astype(np.int64),
        root_coord_bounds=rows.astype(np.int64),
        overlapping_root=True,
    )


def partition_tensor_nonzeros(tensor: Tensor, pieces: int,
                              weights: Optional[np.ndarray] = None,
                              fused_levels: Optional[int] = None,
                              init_bounds: Optional[Bounds] = None,
                              ) -> TensorPartition:
    """Non-zero partition of the (fully or partially) fused coordinate tree.

    Default: split the leaf position space (vals) evenly, then derive
    upward with preimage (paper: coordinate fusion `xy→f` + tilde split,
    Fig. 5c / Fig. 8b). ``weights`` gives a heterogeneous split (straggler
    re-plan). ``fused_levels`` < order realizes PARTIAL fusion (paper
    Fig. 5's "non-zero tubes": T_xyz with xy→f splits the level-2 position
    space evenly, then derives the leaf via image and the root via
    preimage). Blocked tensors split their stored-block position space
    (``partition_tensor_block_nonzeros``). ``init_bounds`` overrides the
    equal/weighted split of the split-level position space with
    caller-supplied windows — the elastic resize path feeds merged
    survivor windows here so unaffected colors keep identical bounds."""
    if tensor.format.is_all_dense:
        raise ValueError("non-zero partition of a dense tensor — use rows")
    if tensor.format.is_blocked:
        return partition_tensor_block_nonzeros(tensor, pieces, weights,
                                               init_bounds=init_bounds)
    order = tensor.order
    n_dense = _dense_prefix(tensor)
    split_level = order - 1 if fused_levels is None else fused_levels - 1
    if not tensor.levels[split_level].kind.compressed:
        raise ValueError("partial fusion must end at a compressed level")
    n_at = (tensor.levels[split_level].nnz
            if tensor.levels[split_level].crd is not None else tensor.nnz)
    init_bounds = (partition_nonzeros(n_at, pieces, weights)
                   if init_bounds is None
                   else np.asarray(init_bounds, dtype=np.int64))
    levels: List[LevelPartition] = [LevelPartition() for _ in range(order)]
    # derive DOWNWARD from the split level to the leaf (image chain)
    down = init_bounds.astype(np.int64)
    levels[split_level] = LevelPartition(pos_bounds=down.copy())
    for l in range(split_level + 1, order):
        ld = tensor.levels[l]
        if ld.kind.singleton:
            levels[l] = LevelPartition(pos_bounds=down.copy())
            continue
        down = image(ld.pos, down)
        levels[l] = LevelPartition(pos_bounds=down.copy())
    vals_bounds = down
    # walk upward through compressed levels (preimage chain)
    pos_bounds = init_bounds.astype(np.int64)
    for l in range(split_level, n_dense - 1, -1):
        ld = tensor.levels[l]
        if levels[l].pos_bounds is None:
            levels[l] = LevelPartition(pos_bounds=pos_bounds.copy())
        if ld.kind.singleton:
            continue  # position space shared with parent
        pos_bounds = preimage(ld.pos, pos_bounds)
    # dense prefix: divide position bounds back into coordinates
    root_bounds = pos_bounds
    for l in range(n_dense - 1, 0, -1):
        size = tensor.levels[l].size
        lo = root_bounds[:, 0] // size
        hi = -(-root_bounds[:, 1] // size)
        root_bounds = np.stack([lo, hi], axis=1)
        levels[l] = LevelPartition(pos_bounds=root_bounds.copy())
    if n_dense:
        levels[0] = LevelPartition(coord_bounds=root_bounds.copy())
    else:
        # root is compressed; coordinates owned = crd[slice] range
        levels[0].pos_bounds = (
            levels[0].pos_bounds if levels[0].pos_bounds is not None else pos_bounds
        )
        crd0 = tensor.levels[0].crd
        pb = levels[0].pos_bounds
        if crd0 is None or crd0.size == 0:   # empty tensor: no coords owned
            root_bounds = np.zeros_like(pb)
        else:
            lo = np.where(pb[:, 0] < pb[:, 1],
                          crd0[np.minimum(pb[:, 0], len(crd0) - 1)], 0)
            hi = np.where(pb[:, 0] < pb[:, 1],
                          crd0[np.maximum(pb[:, 1] - 1, 0)] + 1, 0)
            root_bounds = np.stack([lo, hi], axis=1).astype(np.int64)
    return TensorPartition(
        tensor=tensor,
        pieces=pieces,
        levels=levels,
        vals_bounds=vals_bounds,
        root_coord_bounds=root_bounds.astype(np.int64),
        overlapping_root=True,
    )


def partition_tensor_grid(tensor: Tensor, row_bounds: Bounds,
                          col_bounds: Bounds) -> TensorPartition:
    """2-D cross-product tile partition: color ``(p, q)`` (row-major flat
    color ``p*Q + q``) owns the row window ``row_bounds[p]`` × column
    window ``col_bounds[q]`` of the tensor — the machine-grid tiling of
    paper Fig. 4c lifted to sparse coordinate trees (core/grid.py plans
    the per-axis communication these tiles imply).

    Unlike the 1-D partitions, a tile is NOT a contiguous interval of the
    value space, so ``vals_bounds`` stays None; the grid materializers
    (``materialize_csr_grid`` / ``materialize_bcsr_grid``) carry per-tile
    global position indices instead. Blocked tensors interpret the (row,
    col) windows at block granularity — the caller must pass block-aligned
    bounds (``block_aligned_row_bounds``) so windows realize as whole
    blocks."""
    P, Q = row_bounds.shape[0], col_bounds.shape[0]
    levels = [LevelPartition(coord_bounds=row_bounds.copy()),
              LevelPartition(coord_bounds=col_bounds.copy())]
    return TensorPartition(
        tensor=tensor, pieces=P * Q, levels=levels,
        vals_bounds=None, root_coord_bounds=row_bounds.copy(),
        overlapping_root=False, grid=(P, Q),
    )


def partition_tensor_grid3(tensor: Tensor, b0: Bounds, b1: Bounds,
                           b2: Bounds) -> TensorPartition:
    """Order-3 cross-product brick partition: color ``(p, q, r)`` (row-major
    flat color ``(p*Q + q)*R + r``) owns the dimension-0 window ``b0[p]`` ×
    dimension-1 window ``b1[q]`` × dimension-2 window ``b2[r]`` — the 2-D
    grid tiling lifted to P×Q×R machine grids for order-3 operands
    (spmttkrp bricks)."""
    P, Q, R = b0.shape[0], b1.shape[0], b2.shape[0]
    levels = [LevelPartition(coord_bounds=b0.copy()),
              LevelPartition(coord_bounds=b1.copy()),
              LevelPartition(coord_bounds=b2.copy())]
    return TensorPartition(
        tensor=tensor, pieces=P * Q * R, levels=levels,
        vals_bounds=None, root_coord_bounds=b0.copy(),
        overlapping_root=False, grid=(P, Q, R),
    )


def partition_tensor_cols(tensor: Tensor, col_bounds: Bounds,
                          ) -> TensorPartition:
    """Column partition of a DENSE tensor (dim 1 sliced into windows) —
    the co-operand plan for grid-distributed computations whose second
    loop variable indexes the operand's trailing dimension (e.g. D(k, j)
    under an (i, j) grid)."""
    if not tensor.format.is_all_dense:
        raise ValueError("column partition is dense-only; sparse operands "
                         "take grid tiles or replication")
    levels = [LevelPartition(),
              LevelPartition(coord_bounds=col_bounds.copy())]
    return TensorPartition(
        tensor=tensor, pieces=col_bounds.shape[0], levels=levels,
        vals_bounds=None, root_coord_bounds=None,
    )


def replicate_tensor(tensor: Tensor, pieces: int) -> TensorPartition:
    """Every color sees the whole tensor (TDN replication, paper Fig. 1
    ``ReplDense``)."""
    order = tensor.order
    return TensorPartition(
        tensor=tensor,
        pieces=pieces,
        levels=[LevelPartition(replicated=True) for _ in range(order)],
        replicated=True,
    )


# ---------------------------------------------------------------------------
# Materialization: partitions -> stacked, padded, statically-shaped shards
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardedTensor:
    """Statically-shaped stacked shards, ready for shard_map.

    ``kind`` selects the leaf-kernel calling convention:
      - ``dense_rows``: dense tensor split by leading-dim intervals.
      - ``csr_rows``  : CSR/CSF-style shard per color (local pos rebased).
      - ``coo_nnz``   : equal-nnz COO shard (rows/cols/vals + row offsets).
      - ``replicated``: single copy broadcast to every color.
    Arrays all have leading dim = pieces (except replicated).
    """

    kind: str
    pieces: int
    arrays: Dict[str, np.ndarray]
    meta: Dict[str, int]
    partition: TensorPartition

    def padding_waste(self) -> float:
        """Fraction of materialized value slots that are padding."""
        if self.kind in ("replicated",):
            return 0.0
        vb = self.partition.vals_bounds
        if vb is None or "vals" not in self.arrays:
            return 0.0
        real = float((vb[:, 1] - vb[:, 0]).sum())
        v = self.arrays["vals"]
        if v.ndim > 2:      # blocked shards: bounds count (br, bc) tiles
            real *= float(np.prod(v.shape[2:]))
        alloc = float(np.prod(v.shape))
        return 0.0 if alloc == 0 else 1.0 - real / alloc


def _pad_to(arr: np.ndarray, n: int, fill=0) -> np.ndarray:
    pad = n - arr.shape[0]
    if pad <= 0:
        return arr[:n]
    return np.concatenate([arr, np.full((pad,) + arr.shape[1:], fill, dtype=arr.dtype)])


# ---------------------------------------------------------------------------
# Shard-materialization cache: every materializer below consults one bounded
# LRU keyed by (materializer kind, tensor content fingerprint, partition
# interval fingerprint). A re-plan over unchanged operands (same schedule,
# or new straggler weights that happen to reproduce the same bounds) returns
# the packed arrays without touching numpy; any content change — including
# in-place mutation of vals/pos/crd — changes the CRC and re-packs. This
# generalizes the original one-off spadd3 add-stream cache to all six shard
# conventions (and bounds it).
# ---------------------------------------------------------------------------

SHARD_CACHE = LRUCache(capacity=64)
SHARD_CACHE_STATS = SHARD_CACHE.stats   # {"hits", "misses", "evictions"}


def set_shard_cache_capacity(capacity: int) -> None:
    """Re-bound the shard cache (entry cap, LRU eviction)."""
    SHARD_CACHE.set_capacity(capacity)


def clear_shard_cache() -> None:
    SHARD_CACHE.clear()


# Per-lower fingerprint memo: core.lower activates it for the duration of
# one lower() call, so the O(nnz) CRC over a tensor's storage is computed
# once even though the plan key and one or more materializers all need it.
# Keyed by object identity — valid only within a single lower, where
# in-place mutation mid-lower is already undefined; outside a memo scope
# every call recomputes (that recompute IS the invalidation mechanism).
_FP_MEMO: Optional[Dict[int, Tuple]] = None


def tensor_fingerprint(t: Tensor) -> Tuple:
    if _FP_MEMO is None:
        return t.fingerprint()
    fp = _FP_MEMO.get(id(t))
    if fp is None:
        fp = _FP_MEMO[id(t)] = t.fingerprint()
    return fp


@contextlib.contextmanager
def fingerprint_memo():
    global _FP_MEMO
    prev = _FP_MEMO
    _FP_MEMO = {}
    try:
        yield
    finally:
        _FP_MEMO = prev


def _crc_arrays(h: int, *arrays: Optional[np.ndarray]) -> int:
    for a in arrays:
        if a is None:
            h = zlib.crc32(b"-", h)
        else:
            h = zlib.crc32(
                np.ascontiguousarray(np.asarray(a, dtype=np.int64)), h)
    return h


def partition_fingerprint(part: TensorPartition) -> Tuple:
    """Hashable summary of a partition's interval structure; together with
    ``Tensor.fingerprint()`` it keys a shard materialization — weighted
    (straggler) re-plans change the bounds and therefore the key. Grid
    partitions fold in their (P, Q) shape so a 2×4 and a 4×2 tiling of the
    same windows key distinct shard sets."""
    h = 0
    for lp in part.levels:
        h = zlib.crc32(b"R" if lp.replicated else b"L", h)
        h = _crc_arrays(h, lp.coord_bounds, lp.pos_bounds)
    h = _crc_arrays(h, part.vals_bounds, part.root_coord_bounds)
    return (part.pieces, part.replicated, part.overlapping_root, part.grid, h)


def _cached_shards(key: Tuple, build: Callable[[], ShardedTensor],
                   partition: Optional[TensorPartition] = None,
                   ) -> ShardedTensor:
    """Cache front-end shared by the materializers: on a hit the packed
    arrays are reused but the ``partition`` field is refreshed to the
    caller's plan object (the bounds are equal by key construction; the
    tensor reference inside may be an older content-identical object)."""
    def _traced_build() -> ShardedTensor:
        with telemetry.span("partition.materialize", kind=str(key[0]),
                            fingerprint=str(key[1])[:64]) as sp:
            sh = build()
            sp.set(bytes=int(sum(np.asarray(a).nbytes
                                 for a in sh.arrays.values())),
                   pieces=sh.partition.pieces if sh.partition else None)
            return sh

    sh = SHARD_CACHE.get_or_build(key, _traced_build)
    if partition is not None:
        return dataclasses.replace(sh, partition=partition)
    return sh


def materialize_dense_rows(tensor: Tensor, bounds: Bounds,
                           pad_rows: Optional[int] = None,
                           cache: bool = True) -> ShardedTensor:
    tp = TensorPartition(tensor, bounds.shape[0],
                         [LevelPartition(coord_bounds=bounds)],
                         root_coord_bounds=bounds, vals_bounds=None)
    if not cache:
        # serving fast path: per-batch RHS contents change every call —
        # re-pack directly instead of churning SHARD_CACHE with one-shot
        # content fingerprints (and paying the CRC).
        return _materialize_dense_rows_impl(tensor, bounds, pad_rows, tp)
    key = ("dense_rows", tensor_fingerprint(tensor), _crc_arrays(0, bounds),
           -1 if pad_rows is None else int(pad_rows))
    return _cached_shards(
        key, lambda: _materialize_dense_rows_impl(tensor, bounds, pad_rows,
                                                  tp), partition=tp)


def _materialize_dense_rows_impl(tensor: Tensor, bounds: Bounds,
                                 pad_rows: Optional[int],
                                 tp: TensorPartition) -> ShardedTensor:
    dense = tensor.to_dense()
    pieces = bounds.shape[0]
    counts = bounds[:, 1] - bounds[:, 0]
    max_rows = int(pad_rows if pad_rows is not None else counts.max())
    shards = np.zeros((pieces, max_rows) + dense.shape[1:], dtype=dense.dtype)
    for p in range(pieces):
        lo, hi = int(bounds[p, 0]), int(bounds[p, 1])
        shards[p, : hi - lo] = dense[lo:hi]
    return ShardedTensor(
        kind="dense_rows",
        pieces=pieces,
        arrays={
            "vals": shards,
            "row_start": bounds[:, 0].astype(INT),
            "row_count": counts.astype(INT),
        },
        meta={"max_rows": max_rows, "n_rows": dense.shape[0]},
        partition=tp,
    )


def materialize_csr_rows(tensor: Tensor, part: TensorPartition) -> ShardedTensor:
    if part.walk_perm is not None:
        key = ("csr_rows_walk", tensor_fingerprint(tensor),
               partition_fingerprint(part))
        return _cached_shards(
            key, lambda: _materialize_csr_rows_walk_impl(tensor, part),
            partition=part)
    key = ("csr_rows", tensor_fingerprint(tensor),
           partition_fingerprint(part))
    return _cached_shards(
        key, lambda: _materialize_csr_rows_impl(tensor, part), partition=part)


def _materialize_csr_rows_walk_impl(tensor: Tensor, part: TensorPartition,
                                    ) -> ShardedTensor:
    """CSR-convention shard per color from a TRANSPOSE-WALKED row partition
    (column-major roots — CSC). Each color owns a contiguous interval of
    the row-sorted walk; the shard-local ``pos1`` is densified over the row
    window exactly like a compressed root's, ``crd1`` holds the column
    coordinates, ``vals`` is the value region PERMUTED into walk order and
    ``val_idx`` maps each slot back to its storage position (the scatter
    map pattern-preserving outputs use). Leaves written against the CSR
    calling convention consume these shards unchanged — the walk differs,
    the kernel contract does not."""
    pieces = part.pieces
    rb = part.root_coord_bounds
    row_counts = rb[:, 1] - rb[:, 0]
    max_rows = int(row_counts.max()) if pieces else 0
    perm = part.walk_perm
    coords = tensor.coords().astype(np.int64)      # storage order
    wrows = coords[perm, 0] if perm.size else np.zeros((0,), np.int64)
    wcols = coords[perm, 1] if perm.size else np.zeros((0,), np.int64)
    vb = part.vals_bounds                          # walk-space intervals
    counts = vb[:, 1] - vb[:, 0]
    max_nnz = int(counts.max()) if pieces else 0
    pos_shards = np.zeros((pieces, max_rows + 1), dtype=INT)
    crd_shards = np.zeros((pieces, max_nnz), dtype=INT)
    val_idx = np.zeros((pieces, max_nnz), dtype=INT)
    vals_shards = np.zeros((pieces, max_nnz), dtype=tensor.vals.dtype)
    for p in range(pieces):
        lo, hi = int(vb[p, 0]), int(vb[p, 1])
        rlo = int(rb[p, 0])
        wrows_win = max(int(rb[p, 1]) - rlo, 0)
        cnts = np.zeros(max_rows, dtype=np.int64)
        if hi > lo:
            np.add.at(cnts, wrows[lo:hi] - rlo, 1)
        pos = np.zeros(max_rows + 1, dtype=np.int64)
        np.cumsum(cnts, out=pos[1:])
        pos[wrows_win + 1:] = pos[wrows_win]       # padded rows stay empty
        pos_shards[p] = pos.astype(INT)
        crd_shards[p, : hi - lo] = wcols[lo:hi]
        val_idx[p, : hi - lo] = perm[lo:hi]
        vals_shards[p, : hi - lo] = tensor.vals[perm[lo:hi]]
    arrays = {
        "pos1": pos_shards,
        "crd1": crd_shards,
        "vals": vals_shards,
        "val_idx": val_idx,
        "nnz_count": counts.astype(INT),
        "row_start": rb[:, 0].astype(INT),
        "row_count": row_counts.astype(INT),
    }
    return ShardedTensor(
        kind="csr_rows", pieces=pieces, arrays=arrays,
        meta={"max_rows": max_rows, "max_nnz": max_nnz,
              "n_rows": tensor.shape[0], "permuted": 1},
        partition=part,
    )


def _materialize_csr_rows_impl(tensor: Tensor, part: TensorPartition,
                               ) -> ShardedTensor:
    """CSR / CSF-convention shard per color from a row-interval partition.

    Local ``pos`` arrays are rebased to the shard's crd window and padded so
    out-of-range rows are empty. Multi-level (CSF) shards keep one pos/crd
    pair per compressed level.

    Compressed-root formats (DCSR, DCSF, 2-D COO) are *densified to the row
    window*: the shard-local ``pos1`` is expanded to one entry per window
    row (absent rows get empty ranges), so every leaf kernel written against
    the CSR/CSF calling convention consumes these shards unchanged. This is
    the level-iterator view of the format abstraction — the iteration
    capability differs, the kernel contract does not.
    """
    pieces = part.pieces
    rb = part.root_coord_bounds
    row_counts = rb[:, 1] - rb[:, 0]
    max_rows = int(row_counts.max())
    n_dense = _dense_prefix(tensor)
    order = tensor.order

    arrays: Dict[str, np.ndarray] = {
        "row_start": rb[:, 0].astype(INT),
        "row_count": row_counts.astype(INT),
    }
    # inner dense sizes multiply row interval into position interval
    inner_dense = 1
    for l in range(1, n_dense):
        inner_dense *= tensor.levels[l].size

    start_lvl = n_dense
    if n_dense == 0:
        # ---- densify the compressed root over each shard's row window ----
        root = tensor.levels[0]
        p0b = part.levels[0].pos_bounds
        child = tensor.levels[1] if order > 1 else None
        if child is None:
            raise NotImplementedError(
                "row materialization of a 1-D compressed vector")
        c1b = part.levels[1].pos_bounds
        max_c1 = int((c1b[:, 1] - c1b[:, 0]).max())
        pos_shards = np.zeros((pieces, max_rows + 1), dtype=INT)
        crd_shards = np.zeros((pieces, max_c1), dtype=INT)
        for p in range(pieces):
            rlo = int(rb[p, 0])
            plo, phi = int(p0b[p, 0]), int(p0b[p, 1])
            wrows = max(int(rb[p, 1]) - rlo, 0)
            counts = np.zeros(max_rows, dtype=np.int64)
            stored_rows = root.crd[plo:phi].astype(np.int64) - rlo
            if child.kind.singleton:
                # COO: one root coord per position — histogram the window
                if stored_rows.size:
                    np.add.at(counts, stored_rows, 1)
            else:
                # DCSR/DCSF: scatter each stored row's child-range length
                per_row = (child.pos[plo + 1: phi + 1].astype(np.int64)
                           - child.pos[plo: phi])
                if stored_rows.size:
                    np.add.at(counts, stored_rows, per_row)
            pos = np.zeros(max_rows + 1, dtype=np.int64)
            np.cumsum(counts, out=pos[1:])
            pos[wrows + 1:] = pos[wrows]     # padded rows stay empty
            pos_shards[p] = pos.astype(INT)
            clo, chi = int(c1b[p, 0]), int(c1b[p, 1])
            crd_shards[p, : chi - clo] = child.crd[clo:chi]
        arrays["pos1"] = pos_shards
        arrays["crd1"] = crd_shards
        start_lvl = 2

    # per compressed level: slice pos (rebased), crd
    for l in range(start_lvl, order):
        ld = tensor.levels[l]
        lp = part.levels[l]
        if ld.kind.singleton:
            continue  # handled with the vals/pos space of parent
        parent_bounds = (
            rb.astype(np.int64) * inner_dense if l == n_dense
            else part.levels[l - 1].pos_bounds
        )
        pb = lp.pos_bounds
        max_parent = int((parent_bounds[:, 1] - parent_bounds[:, 0]).max())
        max_nnz_l = int((pb[:, 1] - pb[:, 0]).max())
        pos_shards = np.zeros((pieces, max_parent + 1), dtype=INT)
        crd_shards = np.zeros((pieces, max_nnz_l), dtype=INT)
        for p in range(pieces):
            plo, phi = int(parent_bounds[p, 0]), int(parent_bounds[p, 1])
            clo, chi = int(pb[p, 0]), int(pb[p, 1])
            local_pos = ld.pos[plo: phi + 1].astype(np.int64) - clo
            local_pos = _pad_to(local_pos.astype(INT), max_parent + 1,
                                fill=int(local_pos[-1]) if local_pos.size else 0)
            pos_shards[p] = local_pos
            crd_shards[p, : chi - clo] = ld.crd[clo:chi]
        arrays[f"pos{l}"] = pos_shards
        arrays[f"crd{l}"] = crd_shards
        # singleton children share this position space; emit their crd too
        for ls in range(l + 1, order):
            if not tensor.levels[ls].kind.singleton:
                break
            s_crd = np.zeros((pieces, max_nnz_l), dtype=INT)
            for p in range(pieces):
                clo, chi = int(pb[p, 0]), int(pb[p, 1])
                s_crd[p, : chi - clo] = tensor.levels[ls].crd[clo:chi]
            arrays[f"crd{ls}"] = s_crd

    vb = part.vals_bounds
    max_nnz = int((vb[:, 1] - vb[:, 0]).max())
    vals_shards = np.zeros((pieces, max_nnz), dtype=tensor.vals.dtype)
    nnz_counts = (vb[:, 1] - vb[:, 0]).astype(INT)
    for p in range(pieces):
        lo, hi = int(vb[p, 0]), int(vb[p, 1])
        vals_shards[p, : hi - lo] = tensor.vals[lo:hi]
    arrays["vals"] = vals_shards
    arrays["nnz_count"] = nnz_counts
    return ShardedTensor(
        kind="csr_rows",
        pieces=pieces,
        arrays=arrays,
        meta={"max_rows": max_rows, "max_nnz": max_nnz,
              "n_rows": tensor.shape[tensor.format.dim_of_level(0)]},
        partition=part,
    )


def materialize_coo_nnz(tensor: Tensor, part: TensorPartition) -> ShardedTensor:
    key = ("coo_nnz", tensor_fingerprint(tensor),
           partition_fingerprint(part))
    return _cached_shards(
        key, lambda: _materialize_coo_nnz_impl(tensor, part), partition=part)


def _materialize_coo_nnz_impl(tensor: Tensor, part: TensorPartition,
                              ) -> ShardedTensor:
    """Equal-nnz COO shards from a non-zero (fused) partition.

    Emits per-color coordinate columns (dimension order) + vals, padded to
    the uniform chunk size, plus the preimage-derived root row interval so
    leaves can compute into a local output slice that is later reduced
    (paper §II-D: "perfect load balance at the cost of communication to
    reduce into the output").
    """
    pieces = part.pieces
    coords = tensor.coords()  # (nnz, order), dimension order, storage-sorted
    vb = part.vals_bounds
    counts = vb[:, 1] - vb[:, 0]
    max_nnz = int(counts.max())
    arrays: Dict[str, np.ndarray] = {}
    for d in range(tensor.order):
        col = np.zeros((pieces, max_nnz), dtype=INT)
        for p in range(pieces):
            lo, hi = int(vb[p, 0]), int(vb[p, 1])
            col[p, : hi - lo] = coords[lo:hi, d]
        arrays[f"dim{d}"] = col
    vals = np.zeros((pieces, max_nnz), dtype=tensor.vals.dtype)
    for p in range(pieces):
        lo, hi = int(vb[p, 0]), int(vb[p, 1])
        vals[p, : hi - lo] = tensor.vals[lo:hi]
    arrays["vals"] = vals
    arrays["nnz_count"] = counts.astype(INT)
    rb = part.root_coord_bounds
    arrays["row_start"] = rb[:, 0].astype(INT)
    arrays["row_count"] = (rb[:, 1] - rb[:, 0]).astype(INT)
    return ShardedTensor(
        kind="coo_nnz",
        pieces=pieces,
        arrays=arrays,
        meta={"max_nnz": max_nnz,
              "max_rows": int((rb[:, 1] - rb[:, 0]).max()),
              "n_rows": tensor.shape[tensor.format.dim_of_level(0)],
              # Dimension tracked by the storage root: leaves may compute
              # into a local root-window output slice only when this is the
              # output-row dimension (0); otherwise (CSC) emitters reduce
              # over the full output extent.
              "root_dim": tensor.format.dim_of_level(0)},
        partition=part,
    )


def _blocked_meta(tensor: Tensor) -> Dict[str, int]:
    # grid extents are per DIMENSION (row grid / col grid) regardless of
    # which level stores which dimension — BCSC stores columns at the root
    br, bc = tensor.format.block_shape
    return {
        "br": br, "bc": bc,
        "n_rows": tensor.shape[0], "n_cols": tensor.shape[1],
        "grid_rows": tensor.levels[tensor.format.level_of_dim(0)].size,
        "grid_cols": tensor.levels[tensor.format.level_of_dim(1)].size,
    }


def materialize_bcsr_rows(tensor: Tensor, part: TensorPartition,
                          ) -> ShardedTensor:
    if part.walk_perm is not None:
        key = ("bcsr_rows_walk", tensor_fingerprint(tensor),
               partition_fingerprint(part))
        return _cached_shards(
            key, lambda: _materialize_bcsr_rows_walk_impl(tensor, part),
            partition=part)
    key = ("bcsr_rows", tensor_fingerprint(tensor),
           partition_fingerprint(part))
    return _cached_shards(
        key, lambda: _materialize_bcsr_rows_impl(tensor, part),
        partition=part)


def _materialize_bcsr_rows_walk_impl(tensor: Tensor, part: TensorPartition,
                                     ) -> ShardedTensor:
    """Blocked-CSR-convention shards from a TRANSPOSE-WALKED block-row
    partition (BCSC): the block-grid transpose walk gives each color a
    contiguous (block-row-sorted) interval; ``pos1``/``crd1`` walk the
    block-row window / global block-columns, ``vals`` carries the (br, bc)
    tiles permuted into walk order and ``val_idx`` the stored-block
    positions — the blocked analog of the scalar transpose-walk shards."""
    pieces = part.pieces
    br, bc = tensor.format.block_shape
    bb = part.levels[0].coord_bounds               # block-row windows
    vb = part.vals_bounds                          # walk-space intervals
    perm = part.walk_perm
    bcoords = tensor.block_coords().astype(np.int64)
    wbrow = bcoords[perm, 0] if perm.size else np.zeros((0,), np.int64)
    wbcol = bcoords[perm, 1] if perm.size else np.zeros((0,), np.int64)
    brow_counts = bb[:, 1] - bb[:, 0]
    max_brows = int(brow_counts.max()) if pieces else 0
    counts = vb[:, 1] - vb[:, 0]
    max_bnnz = int(counts.max()) if pieces else 0
    pos_shards = np.zeros((pieces, max_brows + 1), dtype=INT)
    crd_shards = np.zeros((pieces, max_bnnz), dtype=INT)
    val_idx = np.zeros((pieces, max_bnnz), dtype=INT)
    vals_shards = np.zeros((pieces, max_bnnz, br, bc),
                           dtype=tensor.vals.dtype)
    for p in range(pieces):
        lo, hi = int(vb[p, 0]), int(vb[p, 1])
        blo = int(bb[p, 0])
        wb_win = max(int(bb[p, 1]) - blo, 0)
        cnts = np.zeros(max_brows, dtype=np.int64)
        if hi > lo:
            np.add.at(cnts, wbrow[lo:hi] - blo, 1)
        pos = np.zeros(max_brows + 1, dtype=np.int64)
        np.cumsum(cnts, out=pos[1:])
        pos[wb_win + 1:] = pos[wb_win]
        pos_shards[p] = pos.astype(INT)
        crd_shards[p, : hi - lo] = wbcol[lo:hi]
        val_idx[p, : hi - lo] = perm[lo:hi]
        vals_shards[p, : hi - lo] = tensor.vals[perm[lo:hi]]
    rb = part.root_coord_bounds
    arrays = {
        "pos1": pos_shards,
        "crd1": crd_shards,
        "vals": vals_shards,
        "val_idx": val_idx,
        "row_start": rb[:, 0].astype(INT),
        "row_count": (rb[:, 1] - rb[:, 0]).astype(INT),
        "brow_start": bb[:, 0].astype(INT),
        "brow_count": brow_counts.astype(INT),
        "nnz_count": counts.astype(INT),
    }
    meta = dict(_blocked_meta(tensor), max_rows=max_brows * br,
                max_brows=max_brows, max_bnnz=max_bnnz, permuted=1)
    return ShardedTensor(kind="bcsr_rows", pieces=pieces, arrays=arrays,
                         meta=meta, partition=part)


def _materialize_bcsr_rows_impl(tensor: Tensor, part: TensorPartition,
                                ) -> ShardedTensor:
    """Blocked-CSR shard per color from a block-row interval partition.

    The per-shard layout is the CSR convention lifted to the block grid:
    ``pos1``/``crd1`` walk block-rows/block-columns, ``vals`` keeps each
    stored position's dense (br, bc) tile — the shard ships MXU-ready
    tiles, never scalarized entries. Boundary blocks retain their
    zero-padding cells; ``row_count`` (row space, clipped to the tensor
    edge) is what keeps that padding out of assembled results."""
    pieces = part.pieces
    br, bc = tensor.format.block_shape
    bb = part.levels[0].coord_bounds                 # block-row windows
    pb = part.levels[1].pos_bounds                   # stored-block windows
    brow_counts = bb[:, 1] - bb[:, 0]
    max_brows = int(brow_counts.max()) if pieces else 0
    max_bnnz = int((pb[:, 1] - pb[:, 0]).max()) if pieces else 0
    ld = tensor.levels[1]
    pos_shards = np.zeros((pieces, max_brows + 1), dtype=INT)
    crd_shards = np.zeros((pieces, max_bnnz), dtype=INT)
    vals_shards = np.zeros((pieces, max_bnnz, br, bc), dtype=tensor.vals.dtype)
    for p in range(pieces):
        blo, bhi = int(bb[p, 0]), int(bb[p, 1])
        clo, chi = int(pb[p, 0]), int(pb[p, 1])
        local_pos = ld.pos[blo: bhi + 1].astype(np.int64) - clo
        local_pos = _pad_to(local_pos.astype(INT), max_brows + 1,
                            fill=int(local_pos[-1]) if local_pos.size else 0)
        pos_shards[p] = local_pos
        crd_shards[p, : chi - clo] = ld.crd[clo:chi]
        vals_shards[p, : chi - clo] = tensor.vals[clo:chi]
    rb = part.root_coord_bounds
    arrays = {
        "pos1": pos_shards,
        "crd1": crd_shards,
        "vals": vals_shards,
        "row_start": rb[:, 0].astype(INT),
        "row_count": (rb[:, 1] - rb[:, 0]).astype(INT),
        "brow_start": bb[:, 0].astype(INT),
        "brow_count": brow_counts.astype(INT),
        "nnz_count": (pb[:, 1] - pb[:, 0]).astype(INT),
    }
    meta = dict(_blocked_meta(tensor), max_rows=max_brows * br,
                max_brows=max_brows, max_bnnz=max_bnnz)
    return ShardedTensor(kind="bcsr_rows", pieces=pieces, arrays=arrays,
                         meta=meta, partition=part)


def materialize_bcsr_nnz(tensor: Tensor, part: TensorPartition,
                         ) -> ShardedTensor:
    key = ("bcsr_nnz", tensor_fingerprint(tensor),
           partition_fingerprint(part))
    return _cached_shards(
        key, lambda: _materialize_bcsr_nnz_impl(tensor, part), partition=part)


def _materialize_bcsr_nnz_impl(tensor: Tensor, part: TensorPartition,
                               ) -> ShardedTensor:
    """Equal-stored-block shards from a block non-zero partition: per-color
    global (block-row, block-col) columns + (br, bc) value tiles, plus the
    preimage-derived block-row ownership window (overlapping — boundary
    block-rows reduce across colors, the paper's §II-D trade made at block
    granularity)."""
    pieces = part.pieces
    br, bc = tensor.format.block_shape
    vb = part.vals_bounds
    bcoords = tensor.block_coords().astype(np.int64)     # (nb, 2) dim order
    counts = vb[:, 1] - vb[:, 0]
    max_bnnz = int(counts.max()) if pieces else 0
    bdim0 = np.zeros((pieces, max_bnnz), dtype=INT)
    bdim1 = np.zeros((pieces, max_bnnz), dtype=INT)
    vals_shards = np.zeros((pieces, max_bnnz, br, bc), dtype=tensor.vals.dtype)
    for p in range(pieces):
        lo, hi = int(vb[p, 0]), int(vb[p, 1])
        bdim0[p, : hi - lo] = bcoords[lo:hi, 0]
        bdim1[p, : hi - lo] = bcoords[lo:hi, 1]
        vals_shards[p, : hi - lo] = tensor.vals[lo:hi]
    rb = part.root_coord_bounds
    bb = part.levels[0].coord_bounds
    arrays = {
        "bdim0": bdim0,
        "bdim1": bdim1,
        "vals": vals_shards,
        "nnz_count": counts.astype(INT),
        "row_start": rb[:, 0].astype(INT),
        "row_count": (rb[:, 1] - rb[:, 0]).astype(INT),
        "brow_start": bb[:, 0].astype(INT),
        "brow_count": (bb[:, 1] - bb[:, 0]).astype(INT),
    }
    meta = dict(_blocked_meta(tensor),
                max_rows=int((rb[:, 1] - rb[:, 0]).max()) if pieces else 0,
                max_brows=int((bb[:, 1] - bb[:, 0]).max()) if pieces else 0,
                max_bnnz=max_bnnz,
                # dimension tracked by the storage root: leaves may compute
                # into a block-row window only when this is 0 (BCSR);
                # otherwise (BCSC) they reduce over the full block grid.
                root_dim=tensor.format.dim_of_level(0))
    return ShardedTensor(kind="bcsr_nnz", pieces=pieces, arrays=arrays,
                         meta=meta, partition=part)


# ---------------------------------------------------------------------------
# 2-D grid materializers: cross-product row×col tiles for the grid
# distribution subsystem (core/grid.py). Each tile is a CSR-convention
# shard over its row window with COLUMN-LOCAL coordinates (rebased to the
# tile's column window) plus the global value positions of its entries —
# tiles are non-contiguous in the value space, so assembly scatters by
# index instead of by interval.
# ---------------------------------------------------------------------------

def materialize_csr_grid(tensor: Tensor, part: TensorPartition,
                         ) -> ShardedTensor:
    key = ("csr_grid", tensor_fingerprint(tensor),
           partition_fingerprint(part))
    return _cached_shards(
        key, lambda: _materialize_csr_grid_impl(tensor, part), partition=part)


def _materialize_csr_grid_impl(tensor: Tensor, part: TensorPartition,
                               ) -> ShardedTensor:
    """Row×col tile shards of any 2-D sparse matrix.

    Built from the level tree's ROW WALK (core/levels.py): the identity
    storage enumeration for row-major formats — per-tile entry order is
    CSR order for free — and the transpose walk for column-major roots
    (CSC), whose permutation re-sorts each tile's entries row-major and
    maps them back to storage positions. Per tile: ``pos1`` walks the
    tile's row window, ``crd1`` holds column-LOCAL coordinates,
    ``val_idx`` the global (storage) value positions — the scatter map
    for pattern-preserving outputs. Colors are row-major: flat color =
    p*Q + q."""
    P, Q = part.grid
    rb = part.levels[0].coord_bounds            # (P, 2) row windows
    cb = part.levels[1].coord_bounds            # (Q, 2) col windows
    walk = tensor.level_tree().row_walk()       # row-sorted, perm → storage
    coords = walk.coords.astype(np.int64)
    r, c = coords[:, 0], coords[:, 1]
    cmasks = [(c >= int(cb[q, 0])) & (c < int(cb[q, 1])) for q in range(Q)]
    tiles = []
    for p in range(P):
        rlo, rhi = int(rb[p, 0]), int(rb[p, 1])
        rmask = (r >= rlo) & (r < rhi)
        for q in range(Q):
            tiles.append(np.nonzero(rmask & cmasks[q])[0])
    max_rows = int((rb[:, 1] - rb[:, 0]).max())
    max_tnnz = max((int(t.shape[0]) for t in tiles), default=0)
    pos_shards = np.zeros((P * Q, max_rows + 1), dtype=INT)
    crd_shards = np.zeros((P * Q, max_tnnz), dtype=INT)
    val_idx = np.zeros((P * Q, max_tnnz), dtype=INT)
    vals_shards = np.zeros((P * Q, max_tnnz), dtype=tensor.vals.dtype)
    nnz_count = np.zeros((P * Q,), dtype=INT)
    for color, idx in enumerate(tiles):
        p, q = divmod(color, Q)
        rlo, rhi = int(rb[p, 0]), int(rb[p, 1])
        clo = int(cb[q, 0])
        k = idx.shape[0]
        counts = np.zeros(max_rows, dtype=np.int64)
        if k:
            np.add.at(counts, r[idx] - rlo, 1)
        pos = np.zeros(max_rows + 1, dtype=np.int64)
        np.cumsum(counts, out=pos[1:])
        pos[rhi - rlo + 1:] = pos[rhi - rlo]    # padded rows stay empty
        pos_shards[color] = pos.astype(INT)
        crd_shards[color, :k] = c[idx] - clo
        val_idx[color, :k] = walk.perm[idx]
        vals_shards[color, :k] = tensor.vals[walk.perm[idx]]
        nnz_count[color] = k
    arrays = {
        "pos1": pos_shards, "crd1": crd_shards, "vals": vals_shards,
        "val_idx": val_idx, "nnz_count": nnz_count,
        "row_start": rb[:, 0].astype(INT),
        "row_count": (rb[:, 1] - rb[:, 0]).astype(INT),
        "col_start": cb[:, 0].astype(INT),
        "col_count": (cb[:, 1] - cb[:, 0]).astype(INT),
    }
    meta = {"P": P, "Q": Q, "max_rows": max_rows, "max_tnnz": max_tnnz,
            "n_rows": tensor.shape[0], "n_cols": tensor.shape[1]}
    return ShardedTensor(kind="csr_grid", pieces=P * Q, arrays=arrays,
                         meta=meta, partition=part)


def materialize_bcsr_grid(tensor: Tensor, part: TensorPartition,
                          ) -> ShardedTensor:
    key = ("bcsr_grid", tensor_fingerprint(tensor),
           partition_fingerprint(part))
    return _cached_shards(
        key, lambda: _materialize_bcsr_grid_impl(tensor, part),
        partition=part)


def _materialize_bcsr_grid_impl(tensor: Tensor, part: TensorPartition,
                                ) -> ShardedTensor:
    """Blocked row×col tile shards: the CSR grid convention lifted to the
    block grid — windows are block-aligned (the planner guarantees it), so
    each tile owns whole (br, bc) value tiles; ``crd1`` holds block-col
    coordinates LOCAL to the tile's block-column window and ``val_idx``
    the global stored-block positions. Column-major block grids (BCSC)
    arrive through the blocked transpose walk, whose permutation re-sorts
    each tile's blocks block-row-major."""
    P, Q = part.grid
    br, bc = tensor.format.block_shape
    rb = part.levels[0].coord_bounds            # (P, 2) ROW windows
    cb = part.levels[1].coord_bounds            # (Q, 2) COL windows
    brb = np.stack([rb[:, 0] // br, -(-rb[:, 1] // br)], axis=1)
    bcb = np.stack([cb[:, 0] // bc, -(-cb[:, 1] // bc)], axis=1)
    walk = tensor.level_tree().row_walk()       # block-row-sorted
    bcoords = walk.coords.astype(np.int64)      # (nb, 2), dim order
    rblk, cblk = bcoords[:, 0], bcoords[:, 1]
    cmasks = [(cblk >= bcb[q, 0]) & (cblk < bcb[q, 1]) for q in range(Q)]
    tiles = []
    for p in range(P):
        rmask = (rblk >= brb[p, 0]) & (rblk < brb[p, 1])
        for q in range(Q):
            tiles.append(np.nonzero(rmask & cmasks[q])[0])
    max_brows = int((brb[:, 1] - brb[:, 0]).max())
    max_tbnnz = max((int(t.shape[0]) for t in tiles), default=0)
    pos_shards = np.zeros((P * Q, max_brows + 1), dtype=INT)
    crd_shards = np.zeros((P * Q, max_tbnnz), dtype=INT)
    val_idx = np.zeros((P * Q, max_tbnnz), dtype=INT)
    vals_shards = np.zeros((P * Q, max_tbnnz, br, bc),
                           dtype=tensor.vals.dtype)
    nnz_count = np.zeros((P * Q,), dtype=INT)
    for color, idx in enumerate(tiles):
        p, q = divmod(color, Q)
        blo, bhi = int(brb[p, 0]), int(brb[p, 1])
        k = idx.shape[0]
        counts = np.zeros(max_brows, dtype=np.int64)
        if k:
            np.add.at(counts, rblk[idx] - blo, 1)
        pos = np.zeros(max_brows + 1, dtype=np.int64)
        np.cumsum(counts, out=pos[1:])
        pos[bhi - blo + 1:] = pos[bhi - blo]
        pos_shards[color] = pos.astype(INT)
        crd_shards[color, :k] = cblk[idx] - int(bcb[q, 0])
        val_idx[color, :k] = walk.perm[idx]
        vals_shards[color, :k] = tensor.vals[walk.perm[idx]]
        nnz_count[color] = k
    arrays = {
        "pos1": pos_shards, "crd1": crd_shards, "vals": vals_shards,
        "val_idx": val_idx, "nnz_count": nnz_count,
        "row_start": rb[:, 0].astype(INT),
        "row_count": (rb[:, 1] - rb[:, 0]).astype(INT),
        "col_start": cb[:, 0].astype(INT),
        "col_count": (cb[:, 1] - cb[:, 0]).astype(INT),
        "brow_start": brb[:, 0].astype(INT),
        "bcol_start": bcb[:, 0].astype(INT),
        "bcol_count": (bcb[:, 1] - bcb[:, 0]).astype(INT),
    }
    meta = dict(_blocked_meta(tensor), P=P, Q=Q, max_brows=max_brows,
                max_tbnnz=max_tbnnz,
                max_rows=int((rb[:, 1] - rb[:, 0]).max()))
    return ShardedTensor(kind="bcsr_grid", pieces=P * Q, arrays=arrays,
                         meta=meta, partition=part)


def materialize_coo3_grid(tensor: Tensor, part: TensorPartition,
                          ) -> ShardedTensor:
    key = ("coo3_grid", tensor_fingerprint(tensor),
           partition_fingerprint(part))
    return _cached_shards(
        key, lambda: _materialize_coo3_grid_impl(tensor, part),
        partition=part)


def _materialize_coo3_grid_impl(tensor: Tensor, part: TensorPartition,
                                ) -> ShardedTensor:
    """P×Q×R brick shards of an order-3 sparse tensor in COO convention.

    Each brick (flat color ``(p*Q + q)*R + r``) holds its entries'
    coordinates LOCAL to the brick's three windows (``dim0``/``dim1``/
    ``dim2``) plus vals, padded to the widest brick. Padding slots keep
    vals = 0 so segment-sum leaves can consume the full padded width
    without masking. Entry order within a brick is storage order — the
    segment-reduction leaves are order-independent, so no walk permutation
    is needed regardless of the root's major dimension."""
    P, Q, R = part.grid
    b0 = part.levels[0].coord_bounds            # (P, 2) dim-0 windows
    b1 = part.levels[1].coord_bounds            # (Q, 2) dim-1 windows
    b2 = part.levels[2].coord_bounds            # (R, 2) dim-2 windows
    coords = tensor.coords().astype(np.int64)   # (nnz, 3), dimension order
    d0, d1, d2 = coords[:, 0], coords[:, 1], coords[:, 2]
    masks1 = [(d1 >= int(b1[q, 0])) & (d1 < int(b1[q, 1])) for q in range(Q)]
    masks2 = [(d2 >= int(b2[r, 0])) & (d2 < int(b2[r, 1])) for r in range(R)]
    bricks = []
    for p in range(P):
        m0 = (d0 >= int(b0[p, 0])) & (d0 < int(b0[p, 1]))
        for q in range(Q):
            for r in range(R):
                bricks.append(np.nonzero(m0 & masks1[q] & masks2[r])[0])
    max_bnnz = max((int(b.shape[0]) for b in bricks), default=0)
    n_colors = P * Q * R
    dim_shards = [np.zeros((n_colors, max_bnnz), dtype=INT) for _ in range(3)]
    vals_shards = np.zeros((n_colors, max_bnnz), dtype=tensor.vals.dtype)
    nnz_count = np.zeros((n_colors,), dtype=INT)
    starts = (b0[:, 0], b1[:, 0], b2[:, 0])
    for color, idx in enumerate(bricks):
        p, qr = divmod(color, Q * R)
        q, r = divmod(qr, R)
        k = idx.shape[0]
        for d, (dcol, win) in enumerate(zip((d0, d1, d2), (p, q, r))):
            dim_shards[d][color, :k] = dcol[idx] - int(starts[d][win])
        vals_shards[color, :k] = tensor.vals[idx]
        nnz_count[color] = k
    arrays = {
        "dim0": dim_shards[0], "dim1": dim_shards[1], "dim2": dim_shards[2],
        "vals": vals_shards, "nnz_count": nnz_count,
        "row_start": b0[:, 0].astype(INT),
        "row_count": (b0[:, 1] - b0[:, 0]).astype(INT),
    }
    meta = {"P": P, "Q": Q, "R": R, "max_bnnz": max_bnnz,
            "max_rows": int((b0[:, 1] - b0[:, 0]).max()),
            "n_rows": tensor.shape[0]}
    return ShardedTensor(kind="coo3_grid", pieces=n_colors, arrays=arrays,
                         meta=meta, partition=part)


def materialize_dense_grid(tensor: Tensor, row_bounds: Bounds,
                           col_bounds: Bounds,
                           cache: bool = True) -> ShardedTensor:
    """Dense matrix tiled by row windows × column windows — the co-operand
    plan when BOTH its indexing variables ride machine axes (e.g. C(k, j)
    under a replicated 2.5-D SpMM, sliced k-rows by the y axis and j-cols
    by the z axis). Shards stack tile-major: ``vals[g0, g1]`` is the
    (max_rw, max_cw)-padded tile for row window g0 × col window g1."""
    tp = partition_tensor_grid(tensor, row_bounds, col_bounds)
    if not cache:
        return _materialize_dense_grid_impl(tensor, row_bounds, col_bounds,
                                            tp)
    key = ("dense_grid", tensor_fingerprint(tensor),
           _crc_arrays(0, row_bounds, col_bounds))
    return _cached_shards(
        key, lambda: _materialize_dense_grid_impl(
            tensor, row_bounds, col_bounds, tp), partition=tp)


def _materialize_dense_grid_impl(tensor: Tensor, row_bounds: Bounds,
                                 col_bounds: Bounds,
                                 tp: TensorPartition) -> ShardedTensor:
    dense = tensor.to_dense()
    G0, G1 = row_bounds.shape[0], col_bounds.shape[0]
    rcounts = row_bounds[:, 1] - row_bounds[:, 0]
    ccounts = col_bounds[:, 1] - col_bounds[:, 0]
    max_rw, max_cw = int(rcounts.max()), int(ccounts.max())
    shards = np.zeros((G0, G1, max_rw, max_cw) + dense.shape[2:],
                      dtype=dense.dtype)
    for g0 in range(G0):
        rlo, rhi = int(row_bounds[g0, 0]), int(row_bounds[g0, 1])
        for g1 in range(G1):
            clo, chi = int(col_bounds[g1, 0]), int(col_bounds[g1, 1])
            shards[g0, g1, : rhi - rlo, : chi - clo] = dense[rlo:rhi, clo:chi]
    return ShardedTensor(
        kind="dense_grid", pieces=G0 * G1,
        arrays={"vals": shards,
                "row_start": row_bounds[:, 0].astype(INT),
                "row_count": rcounts.astype(INT),
                "col_start": col_bounds[:, 0].astype(INT),
                "col_count": ccounts.astype(INT)},
        meta={"max_rows": max_rw, "max_cols": max_cw,
              "n_rows": dense.shape[0], "n_cols": dense.shape[1]},
        partition=tp,
    )


def materialize_dense_cols(tensor: Tensor, bounds: Bounds,
                           cache: bool = True) -> ShardedTensor:
    """Dense tensor sliced into column windows along dim 1 (the grid
    co-operand whose indexing variable rides the second machine axis)."""
    tp = partition_tensor_cols(tensor, bounds)
    if not cache:
        return _materialize_dense_cols_impl(tensor, bounds, tp)
    key = ("dense_cols", tensor_fingerprint(tensor), _crc_arrays(0, bounds))
    return _cached_shards(
        key, lambda: _materialize_dense_cols_impl(tensor, bounds, tp),
        partition=tp)


def _materialize_dense_cols_impl(tensor: Tensor, bounds: Bounds,
                                 tp: TensorPartition) -> ShardedTensor:
    dense = tensor.to_dense()
    pieces = bounds.shape[0]
    counts = bounds[:, 1] - bounds[:, 0]
    max_cols = int(counts.max())
    shards = np.zeros((pieces, dense.shape[0], max_cols) + dense.shape[2:],
                      dtype=dense.dtype)
    for p in range(pieces):
        lo, hi = int(bounds[p, 0]), int(bounds[p, 1])
        shards[p, :, : hi - lo] = dense[:, lo:hi]
    return ShardedTensor(
        kind="dense_cols", pieces=pieces,
        arrays={"vals": shards,
                "col_start": bounds[:, 0].astype(INT),
                "col_count": counts.astype(INT)},
        meta={"max_cols": max_cols, "n_cols": dense.shape[1]},
        partition=tp,
    )


# ---------------------------------------------------------------------------
# Converted-tensor cache: `Tensor.to_format` results keyed by (content
# fingerprint, target format key) in a bounded LRU alongside SHARD_CACHE.
# Fallback conformance cells (csc/coo3 → CSR/CSF) pay the O(nnz) conversion
# walk once; warm re-lowers reuse the converted tensor outright (the
# converted tensor's own fingerprint then keys the shard/plan caches as
# usual). Hits/misses surface per-lower in CacheStats.
# ---------------------------------------------------------------------------

CONVERT_CACHE = LRUCache(capacity=32)
CONVERT_CACHE_STATS = CONVERT_CACHE.stats


def set_convert_cache_capacity(capacity: int) -> None:
    CONVERT_CACHE.set_capacity(capacity)


def clear_convert_cache() -> None:
    CONVERT_CACHE.clear()


def convert_tensor_cached(tensor: Tensor, target: "fmt.Format") -> Tensor:
    """``tensor.to_format(target)`` through the bounded conversion cache."""
    key = ("convert", tensor_fingerprint(tensor), fmt.format_key(target),
           getattr(target, "block_shape", None))
    hit = CONVERT_CACHE.get(key)
    if hit is not None:
        return hit
    out = tensor.to_format(target)
    CONVERT_CACHE.put(key, out)
    return out


# ---------------------------------------------------------------------------
# SpAdd non-zero strategy: the position space is the CONCATENATED
# stored-entry stream of all addends. Packing that stream is a
# materialization (not a plan) step — both the concatenated stream and the
# sliced chunk shards live in SHARD_CACHE, so a re-plan over the same
# operands reuses the shards outright and a re-plan with NEW straggler
# weights only re-slices the cached stream.
# ---------------------------------------------------------------------------

# Add-stream view of the shard cache (kept for observability: the original
# one-off stream cache exposed these and tests pin the re-plan semantics).
ADD_STREAM_STATS = {"hits": 0, "misses": 0}


def concat_entry_stream(tensors: Sequence[Tensor]) -> Dict[str, np.ndarray]:
    """Concatenated coordinate/value stream of the addends, in operand
    order. Blocked operands concatenate their BLOCK streams ((n_blocks, 2)
    grid coords + (n_blocks, br, bc) tiles); unblocked ones their scalar
    coordinate streams. Cached by content fingerprint so a weighted
    re-plan (new chunk bounds over the SAME operands) re-slices instead of
    re-walking the coordinate trees."""
    key = ("add_stream_src",
           tuple(tensor_fingerprint(t) for t in tensors))
    cached = SHARD_CACHE.get(key)
    if cached is not None:
        return cached
    if tensors[0].format.is_blocked:
        bs = tensors[0].format.block_shape
        coords = np.concatenate(
            [t.block_coords().astype(np.int64) for t in tensors], axis=0)
        vals = np.concatenate(
            [t.vals.reshape((-1,) + tuple(bs)) for t in tensors], axis=0)
    else:
        coords = np.concatenate([t.coords().astype(np.int64)
                                 for t in tensors], axis=0)
        vals = np.concatenate([np.asarray(t.vals).reshape(-1)
                               for t in tensors], axis=0)
    stream = {"coords": coords, "vals": vals}
    SHARD_CACHE.put(key, stream)
    return stream


def weights_fingerprint(weights: Optional[np.ndarray]) -> Optional[int]:
    """CRC key component for a straggler-weight vector (None = equal)."""
    if weights is None:
        return None
    return zlib.crc32(np.ascontiguousarray(
        np.asarray(weights, dtype=np.float64)))


def materialize_add_stream(tensors: Sequence[Tensor], pieces: int,
                           weights: Optional[np.ndarray] = None,
                           ) -> ShardedTensor:
    key = ("add_stream", tuple(tensor_fingerprint(t) for t in tensors),
           int(pieces), weights_fingerprint(weights))
    hit = SHARD_CACHE.get(key)
    if hit is not None:
        ADD_STREAM_STATS["hits"] += 1
        return hit
    ADD_STREAM_STATS["misses"] += 1
    with telemetry.span("partition.materialize", kind="add_stream") as sp:
        sh = _materialize_add_stream_impl(tensors, pieces, weights)
        sp.set(bytes=int(sum(np.asarray(a).nbytes
                             for a in sh.arrays.values())))
    SHARD_CACHE.put(key, sh)
    return sh


def _materialize_add_stream_impl(tensors: Sequence[Tensor], pieces: int,
                                 weights: Optional[np.ndarray] = None,
                                 ) -> ShardedTensor:
    """Equal (or straggler-weighted) chunks of the concatenated addend
    stream, padded to the uniform chunk size — the shard set consumed by
    the nnz SpAdd emitters (scalar or blocked)."""
    stream = concat_entry_stream(tensors)
    coords, vals = stream["coords"], stream["vals"]
    blocked = tensors[0].format.is_blocked
    bounds = partition_nonzeros(coords.shape[0], pieces, weights)
    counts = (bounds[:, 1] - bounds[:, 0]).astype(INT)
    max_c = int(counts.max()) if pieces else 0
    d0 = np.zeros((pieces, max_c), dtype=INT)
    d1 = np.zeros((pieces, max_c), dtype=INT)
    vshape = (pieces, max_c) + tuple(vals.shape[1:])
    vs = np.zeros(vshape, dtype=vals.dtype)
    for p in range(pieces):
        lo, hi = int(bounds[p, 0]), int(bounds[p, 1])
        d0[p, : hi - lo] = coords[lo:hi, 0]
        d1[p, : hi - lo] = coords[lo:hi, 1]
        vs[p, : hi - lo] = vals[lo:hi]
    t0 = tensors[0]
    part = TensorPartition(tensor=t0, pieces=pieces, levels=[],
                           vals_bounds=bounds.astype(np.int64))
    arrays = {"dim0": d0, "dim1": d1, "vals": vs, "nnz_count": counts}
    meta: Dict[str, int] = {"max_nnz": max_c,
                            "n_entries": int(coords.shape[0])}
    kind = "add_stream"
    if blocked:
        meta.update(_blocked_meta(t0))
        kind = "add_stream_blocked"
    return ShardedTensor(kind=kind, pieces=pieces, arrays=arrays, meta=meta,
                         partition=part)


def materialize_replicated(tensor: Tensor, pieces: int,
                           cache: bool = True) -> ShardedTensor:
    if not cache:
        return _materialize_replicated_impl(tensor, pieces)
    key = ("replicated", tensor_fingerprint(tensor), int(pieces))
    return _cached_shards(
        key, lambda: _materialize_replicated_impl(tensor, pieces),
        partition=replicate_tensor(tensor, pieces))


def _materialize_replicated_impl(tensor: Tensor, pieces: int) -> ShardedTensor:
    if tensor.format.is_all_dense:
        arrays = {"vals": tensor.to_dense()}
    else:
        arrays = {"vals": tensor.vals}
        for l, ld in enumerate(tensor.levels):
            if ld.pos is not None:
                arrays[f"pos{l}"] = ld.pos
            if ld.crd is not None:
                arrays[f"crd{l}"] = ld.crd
    return ShardedTensor(
        kind="replicated",
        pieces=pieces,
        arrays=arrays,
        meta={},
        partition=replicate_tensor(tensor, pieces),
    )


# ---------------------------------------------------------------------------
# Elastic materialization — per-PIECE shard caching + migration bounds
#
# The whole-set materializers above key one SHARD_CACHE entry per
# (tensor, full partition); any resize changes the partition fingerprint
# and re-packs everything. The elastic path (lower(..., elastic=True),
# used by core.lower.relower) instead caches one entry PER COLOR, keyed
# by the color's own window. Because every per-color derivation in the
# partitioners is row-independent (searchsorted / image / preimage are
# elementwise per color), slicing a partition to one color yields bounds
# identical to that color's rows of the full partition — so after a
# migration-style resize (a dead piece's window merged into a neighbor,
# ``elastic_row_bounds``) every surviving window is a cache hit and only
# the merged window re-packs. Stacking the per-piece shards with the
# same padding rules the whole-set impls use reproduces their output
# bit-for-bit, so runners (keyed on shapes + meta) are shared between
# the two paths.
# ---------------------------------------------------------------------------


def elastic_row_bounds(bounds: Bounds, dead: int) -> Bounds:
    """Migration bounds for losing piece ``dead`` of a 1-D split: the dead
    window is merged into its left neighbor (or the right one when piece 0
    dies), every other window is untouched. P−2 of the P−1 surviving
    windows are bitwise unchanged — the shard-reuse guarantee."""
    b = np.asarray(bounds, dtype=np.int64)
    pieces = b.shape[0]
    if not 0 <= dead < pieces:
        raise ValueError(f"dead piece {dead} out of range for {pieces} pieces")
    if pieces < 2:
        raise ValueError("cannot shrink a 1-piece partition")
    keep = np.delete(b, dead, axis=0)
    if dead == 0:
        keep[0, 0] = b[0, 0]
    else:
        keep[dead - 1, 1] = b[dead, 1]
    return keep


def _slice_bounds(b: Optional[Bounds], p: int) -> Optional[Bounds]:
    return None if b is None else b[p:p + 1]


def _slice_partition(part: TensorPartition, p: int) -> TensorPartition:
    """View of color ``p`` as a 1-piece partition (bounds rows sliced;
    ``walk_perm`` carried whole — it indexes storage, not colors)."""
    levels = [LevelPartition(coord_bounds=_slice_bounds(lv.coord_bounds, p),
                             pos_bounds=_slice_bounds(lv.pos_bounds, p),
                             replicated=lv.replicated)
              for lv in part.levels]
    return dataclasses.replace(
        part, pieces=1, levels=levels,
        vals_bounds=_slice_bounds(part.vals_bounds, p),
        root_coord_bounds=_slice_bounds(part.root_coord_bounds, p),
        grid=None)


def _stack_piece_shards(kind: str, piece_shards: List[ShardedTensor],
                        part: TensorPartition) -> ShardedTensor:
    """Stack per-color 1-piece shards into one whole-set ShardedTensor,
    reproducing the whole-set impls' padding bit-for-bit: ``pos*`` arrays
    edge-pad (out-of-range rows stay empty), other stacked arrays zero-pad,
    1-D per-color scalars concatenate; ``max_*`` meta takes the max."""
    first = piece_shards[0]
    arrays: Dict[str, np.ndarray] = {}
    for name in first.arrays:
        cols = [sh.arrays[name] for sh in piece_shards]
        if cols[0].ndim == 1:
            arrays[name] = np.concatenate(cols, axis=0)
            continue
        width = max(c.shape[1] for c in cols)
        padded = []
        for c in cols:
            pad = width - c.shape[1]
            if pad:
                spec = [(0, 0), (0, pad)] + [(0, 0)] * (c.ndim - 2)
                c = (np.pad(c, spec, mode="edge")
                     if name.startswith("pos") else np.pad(c, spec))
            padded.append(c)
        arrays[name] = np.concatenate(padded, axis=0)
    meta = {k: (max(sh.meta[k] for sh in piece_shards)
                if k.startswith("max_") else first.meta[k])
            for k in first.meta}
    return ShardedTensor(kind=kind, pieces=part.pieces, arrays=arrays,
                         meta=meta, partition=part)


def materialize_pieces(kind: str, tensor: Tensor,
                       part: TensorPartition) -> ShardedTensor:
    """Elastic counterpart of materialize_{csr,bcsr}_rows / *_nnz: one
    SHARD_CACHE entry per color, stacked. ``kind`` ∈ {csr_rows, bcsr_rows,
    coo_nnz, bcsr_nnz}; transpose walks dispatch automatically."""
    impls = {"csr_rows": _materialize_csr_rows_impl,
             "csr_rows_walk": _materialize_csr_rows_walk_impl,
             "bcsr_rows": _materialize_bcsr_rows_impl,
             "bcsr_rows_walk": _materialize_bcsr_rows_walk_impl,
             "coo_nnz": _materialize_coo_nnz_impl,
             "bcsr_nnz": _materialize_bcsr_nnz_impl}
    impl_key = kind
    if part.walk_perm is not None and kind in ("csr_rows", "bcsr_rows"):
        impl_key = kind + "_walk"
    impl = impls[impl_key]
    fp = tensor_fingerprint(tensor)
    piece_shards = []
    for p in range(part.pieces):
        sp = _slice_partition(part, p)
        key = (impl_key + "_piece", fp, partition_fingerprint(sp))
        piece_shards.append(
            SHARD_CACHE.get_or_build(key, lambda sp=sp: impl(tensor, sp)))
    stacked = _stack_piece_shards(piece_shards[0].kind, piece_shards, part)
    return stacked


def materialize_dense_rows_pieces(tensor: Tensor,
                                  bounds: Bounds) -> ShardedTensor:
    """Elastic counterpart of materialize_dense_rows (no ``pad_rows``
    clamp — the 1-D sparse paths never pass one)."""
    fp = tensor_fingerprint(tensor)
    bounds = np.asarray(bounds, dtype=np.int64)
    piece_shards = []
    for p in range(bounds.shape[0]):
        b = bounds[p:p + 1]
        tp = TensorPartition(tensor, 1, [LevelPartition(coord_bounds=b)],
                             root_coord_bounds=b, vals_bounds=None)
        key = ("dense_rows_piece", fp, _crc_arrays(0, b))
        piece_shards.append(SHARD_CACHE.get_or_build(
            key,
            lambda b=b, tp=tp: _materialize_dense_rows_impl(tensor, b, None,
                                                            tp)))
    full = TensorPartition(tensor, bounds.shape[0],
                           [LevelPartition(coord_bounds=bounds)],
                           root_coord_bounds=bounds, vals_bounds=None)
    return _stack_piece_shards("dense_rows", piece_shards, full)


def materialize_replicated_elastic(tensor: Tensor,
                                   pieces: int) -> ShardedTensor:
    """Replicated shards hold ONE copy regardless of piece count, so the
    elastic variant keys on content alone — every resize is a pure hit."""
    key = ("replicated_src", tensor_fingerprint(tensor))
    src = SHARD_CACHE.get_or_build(
        key, lambda: _materialize_replicated_impl(tensor, 1))
    return dataclasses.replace(src, pieces=pieces,
                               partition=replicate_tensor(tensor, pieces))
