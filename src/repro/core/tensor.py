"""Sparse/dense tensor data structure — pos/crd/vals regions (paper §III).

A :class:`Tensor` stores one coordinate-tree level per dimension, in
``format.mode_ordering`` order. Supported level layouts (covers every format
used in the paper's evaluation — CSR, CSC, DCSR, CSF, DDC, COO, dense):

- a (possibly empty) *leading prefix of Dense levels*, stored implicitly;
- followed by Compressed / Singleton levels with explicit ``pos``/``crd``.

Regions (paper Fig. 7):
  ``pos[lvl]``  int32, length = parent position count + 1, monotone. The
                paper's (lo, hi) tuple view of entry ``i`` is
                ``(pos[i], pos[i+1]-1)``.
  ``crd[lvl]``  int32, length = number of stored coordinates at the level.
  ``vals``      values at the last level's positions; for trailing dense
                levels after the last compressed level vals is a block.

Assembly is host-side numpy (this is the paper's "format conversion" /
assembly phase); compute kernels consume the arrays as jnp.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import formats as fmt
from .formats import Format
from .tin import Access, IndexVar

INT = np.int32


@dataclasses.dataclass
class LevelData:
    """Physical storage for one coordinate-tree level."""

    kind: fmt.LevelFormat
    size: int  # dimension extent (universe size of this level)
    pos: Optional[np.ndarray] = None  # int32 (parent_count + 1,)
    crd: Optional[np.ndarray] = None  # int32 (stored_coords,)

    @property
    def nnz(self) -> Optional[int]:
        return None if self.crd is None else int(self.crd.shape[0])


class Tensor:
    """A tensor with a TACO-style per-level sparse encoding."""

    def __init__(
        self,
        name: str,
        shape: Sequence[int],
        format: Format,
        levels: List[LevelData],
        vals: np.ndarray,
        dtype=np.float32,
    ):
        self.name = name
        self.shape = tuple(int(s) for s in shape)
        self.format = format
        self.levels = levels
        self.vals = vals
        self.dtype = dtype

    # ------------------------------------------------------------------
    # Constructors
    # ------------------------------------------------------------------
    @staticmethod
    def from_dense(name: str, arr: np.ndarray, format: Optional[Format] = None,
                   ) -> "Tensor":
        arr = np.asarray(arr)
        if format is None:
            format = fmt.DenseND(arr.ndim)
        if format.is_blocked:
            return Tensor._from_dense_blocked(name, arr, format)
        if format.is_all_dense:
            levels = [
                LevelData(format.levels[l], arr.shape[format.dim_of_level(l)])
                for l in range(arr.ndim)
            ]
            # store vals in storage (level) order
            vals = np.transpose(arr, format.mode_ordering).astype(arr.dtype)
            return Tensor(name, arr.shape, format, levels, vals, arr.dtype)
        coords = np.argwhere(arr != 0).astype(INT)
        vals = arr[tuple(coords.T)]
        return Tensor.from_coo(name, arr.shape, coords, vals, format)

    @staticmethod
    def _from_dense_blocked(name: str, arr: np.ndarray, format: Format,
                            ) -> "Tensor":
        """Assemble a blocked (BCSR-style) tensor: the level tree indexes the
        block grid; ``vals`` is (n_stored_blocks, *block_shape)."""
        bs = format.block_shape
        if arr.ndim != len(bs):
            raise ValueError(f"blocked format {format} on order-{arr.ndim}")
        grid = tuple(-(-s // b) for s, b in zip(arr.shape, bs))
        padded = np.zeros(tuple(g * b for g, b in zip(grid, bs)), arr.dtype)
        padded[tuple(slice(0, s) for s in arr.shape)] = arr
        # view as (g0, b0, g1, b1, ...) then move block dims last
        view = padded.reshape(
            tuple(x for g, b in zip(grid, bs) for x in (g, b)))
        perm = tuple(range(0, 2 * len(bs), 2)) + \
            tuple(range(1, 2 * len(bs), 2))
        blocks = np.transpose(view, perm)          # (g0, g1, ..., b0, b1, ..)
        grid_fmt = fmt.Format(format.levels, format.mode_ordering)
        if grid_fmt.is_all_dense:
            # dense block grid: every block is stored, in storage (level)
            # order — permute grid dims by the mode ordering and flatten.
            perm = tuple(grid_fmt.mode_ordering) + tuple(
                range(len(bs), 2 * len(bs)))
            block_vals = np.ascontiguousarray(
                np.transpose(blocks, perm)).reshape((-1,) + tuple(bs))
            levels = [
                LevelData(grid_fmt.levels[l], grid[grid_fmt.dim_of_level(l)])
                for l in range(len(bs))
            ]
            return Tensor(name, arr.shape, format, levels,
                          block_vals.astype(arr.dtype), arr.dtype)
        nz = np.argwhere(
            blocks.reshape(grid + (-1,)).any(axis=-1)).astype(np.int64)
        block_vals = blocks[tuple(nz.T)].astype(arr.dtype)  # (nb, *bs)
        # build the block-grid coordinate tree with a scalar-level from_coo,
        # then swap in the block values (same stored order: from_coo keeps
        # lexicographic storage order and the block coords are unique).
        skeleton = Tensor.from_coo(
            name, grid, nz, np.arange(nz.shape[0], dtype=np.float64),
            grid_fmt, dedupe=False)
        order_idx = skeleton.vals.astype(np.int64)
        return Tensor(name, arr.shape, format, skeleton.levels,
                      block_vals[order_idx], arr.dtype)

    @staticmethod
    def from_blocks(
        name: str,
        shape: Sequence[int],
        format: Format,
        block_coords: np.ndarray,
        block_vals: np.ndarray,
        dedupe: bool = True,
    ) -> "Tensor":
        """Assemble a blocked tensor directly from ``(n_blocks, order)``
        block-grid coordinates (dimension order) + ``(n_blocks, *block)``
        value tiles — the blocked analog of :meth:`from_coo`, used by the
        direct BCSR execution path to rebuild outputs without densifying.
        ``dedupe=True`` merges duplicate block coordinates by summing their
        tiles (chunk-boundary duplicates of the nnz strategy)."""
        assert format.is_blocked
        shape = tuple(int(s) for s in shape)
        bs = format.block_shape
        grid = tuple(-(-s // b) for s, b in zip(shape, bs))
        bc = np.asarray(block_coords, dtype=np.int64).reshape(-1, len(shape))
        bv = np.asarray(block_vals).reshape((-1,) + tuple(bs))
        if bc.shape[0] == 0:
            skeleton = Tensor.from_coo(
                name, grid, bc, np.zeros((0,), np.float64),
                fmt.Format(format.levels, format.mode_ordering), dedupe=False)
            return Tensor(name, shape, format, skeleton.levels,
                          bv.astype(bv.dtype), bv.dtype)
        if dedupe:
            lin = np.zeros(bc.shape[0], dtype=np.int64)
            for d in range(len(shape)):
                lin = lin * grid[d] + bc[:, d]
            order = np.argsort(lin, kind="stable")
            lin, bc, bv = lin[order], bc[order], bv[order]
            uniq, inv = np.unique(lin, return_inverse=True)
            merged = np.zeros((uniq.shape[0],) + tuple(bs), dtype=bv.dtype)
            np.add.at(merged, inv, bv)
            keep = np.searchsorted(lin, uniq)
            bc, bv = bc[keep], merged
        # grid-tree skeleton carries the stored order back to the tiles
        skeleton = Tensor.from_coo(
            name, grid, bc, np.arange(bc.shape[0], dtype=np.float64),
            fmt.Format(format.levels, format.mode_ordering), dedupe=False)
        order_idx = skeleton.vals.astype(np.int64)
        return Tensor(name, shape, format, skeleton.levels, bv[order_idx],
                      bv.dtype)

    @staticmethod
    def from_coo(
        name: str,
        shape: Sequence[int],
        coords: np.ndarray,
        vals: np.ndarray,
        format: Format,
        dedupe: bool = True,
    ) -> "Tensor":
        """Assemble from (nnz, order) coordinates in *dimension* order."""
        shape = tuple(int(s) for s in shape)
        order = len(shape)
        coords = np.asarray(coords, dtype=np.int64).reshape(-1, order)
        vals = np.asarray(vals)
        if format.is_blocked:
            dense = np.zeros(shape, dtype=vals.dtype)
            if coords.size:
                np.add.at(dense, tuple(coords.T), vals)
            return Tensor._from_dense_blocked(name, dense, format)
        if format.is_all_dense:
            dense = np.zeros(shape, dtype=vals.dtype)
            if coords.size:
                np.add.at(dense, tuple(coords.T), vals)
            return Tensor.from_dense(name, dense, format)

        # Reorder columns into storage order and sort lexicographically.
        perm = np.array(format.mode_ordering)
        sc = coords[:, perm]
        sizes = [shape[format.dim_of_level(l)] for l in range(order)]
        # linearize for sort / dedupe
        lin = np.zeros(sc.shape[0], dtype=np.int64)
        for l in range(order):
            lin = lin * sizes[l] + sc[:, l]
        sort_idx = np.argsort(lin, kind="stable")
        lin, sc, v = lin[sort_idx], sc[sort_idx], vals[sort_idx]
        if dedupe and lin.size:
            uniq, inv = np.unique(lin, return_inverse=True)
            vsum = np.zeros(uniq.shape[0], dtype=v.dtype)
            np.add.at(vsum, inv, v)
            keep = np.searchsorted(lin, uniq)
            sc, v = sc[keep], vsum

        # Split leading dense prefix from compressed suffix.
        n_dense = 0
        for l, lf in enumerate(format.levels):
            if lf.compressed:
                break
            n_dense += 1
        if any(not lf.compressed for lf in format.levels[n_dense:]):
            raise NotImplementedError(
                f"format {format}: Dense level after a Compressed level is "
                "not supported (not needed for any paper format)"
            )

        levels: List[LevelData] = [
            LevelData(format.levels[l], sizes[l]) for l in range(n_dense)
        ]
        dense_count = int(np.prod([sizes[l] for l in range(n_dense)], dtype=np.int64)) \
            if n_dense else 1

        # linear parent key over the dense prefix for each nnz
        parent_key = np.zeros(sc.shape[0], dtype=np.int64)
        for l in range(n_dense):
            parent_key = parent_key * sizes[l] + sc[:, l]
        parent_count = dense_count

        for l in range(n_dense, order):
            lf = format.levels[l]
            c = sc[:, l]
            if lf.singleton:
                levels.append(LevelData(lf, sizes[l], pos=None,
                                        crd=c.astype(INT)))
                # position space unchanged; parent_key extends per-coordinate
                parent_key = parent_key * sizes[l] + c
                parent_count = sc.shape[0]
                continue
            # Compressed: distinct (parent_key, c) pairs are exactly the rows
            # (input already deduped + sorted), unless deeper levels follow.
            # A Compressed level followed by Singleton levels (COO) is
            # non-unique: it stores one coordinate per nnz position.
            next_singleton = l + 1 < order and format.levels[l + 1].singleton
            if l == order - 1 or next_singleton:
                seg_key = parent_key
                child_key = c
                keep = np.ones(sc.shape[0], dtype=bool)
            else:
                full = parent_key * sizes[l] + c
                keep = np.ones(full.shape[0], dtype=bool)
                if full.size:
                    keep[1:] = full[1:] != full[:-1]
                seg_key = parent_key[keep]
                child_key = c[keep]
            counts = np.zeros(parent_count, dtype=np.int64)
            if seg_key.size:
                np.add.at(counts, seg_key, 1)
            pos = np.zeros(parent_count + 1, dtype=INT)
            np.cumsum(counts, out=pos[1:])
            levels.append(LevelData(lf, sizes[l], pos=pos,
                                    crd=child_key.astype(INT)))
            # next level's parent positions = stored coords of this level
            new_parent_key = np.cumsum(keep) - 1  # position index per nnz row
            parent_key = new_parent_key
            parent_count = int(child_key.shape[0])

        return Tensor(name, shape, format, levels, v, v.dtype)

    @staticmethod
    def zeros_dense(name: str, shape: Sequence[int], dtype=np.float32,
                    format: Optional[Format] = None) -> "Tensor":
        return Tensor.from_dense(name, np.zeros(shape, dtype=dtype), format)

    # ------------------------------------------------------------------
    # Introspection / conversion
    # ------------------------------------------------------------------
    @property
    def order(self) -> int:
        return len(self.shape)

    @property
    def nnz(self) -> int:
        if self.format.is_all_dense:
            return int(np.prod(self.shape))
        if self.format.is_blocked:
            return int(self.vals.size)  # stored values incl. in-block zeros
        return int(self.vals.shape[0])

    def level(self, lvl: int) -> LevelData:
        return self.levels[lvl]

    def level_tree(self):
        """The level-iterator view of this tensor (core/levels.py): the
        format-generic walk interface the lowering engine consumes instead
        of the format descriptor itself."""
        from .levels import tree_of
        return tree_of(self)

    def fingerprint(self) -> Tuple:
        """Content fingerprint: structural identity (format key, shape,
        dtype) + a CRC over every storage region (pos/crd/vals). This is
        the cache key unit of the re-plan fast path (partition.SHARD_CACHE,
        lower's plan/runner caches): two Tensors with equal fingerprints
        materialize identical shards, and an in-place mutation between
        lowers changes the CRC — recomputed on every call, O(nnz) streaming
        reads, far cheaper than re-packing."""
        h = zlib.crc32(np.ascontiguousarray(self.vals))
        for ld in self.levels:
            if ld.pos is not None:
                h = zlib.crc32(np.ascontiguousarray(ld.pos), h)
            if ld.crd is not None:
                h = zlib.crc32(np.ascontiguousarray(ld.crd), h)
        return (fmt.format_key(self.format), self.shape,
                str(np.dtype(self.dtype)), h)

    def block_coords(self) -> np.ndarray:
        """Blocked formats: (n_blocks, order) block-grid coordinates in
        dimension order (the scalar-level walk over the grid tree)."""
        assert self.format.is_blocked
        grid_fmt = fmt.Format(self.format.levels, self.format.mode_ordering)
        grid = tuple(self.levels[self.format.level_of_dim(d)].size
                     for d in range(self.order))
        proxy = Tensor(self.name, grid, grid_fmt, self.levels,
                       np.zeros(self.vals.shape[0], self.dtype), self.dtype)
        return proxy.coords()

    def _blocked_entries(self):
        """All stored cells of a blocked tensor: ((N, order) coords aligned
        with ``vals.reshape(-1)``, plus an in-bounds mask — boundary blocks
        of a block-unaligned shape carry padding cells past the tensor
        edge, which every external consumer must drop."""
        bc = self.block_coords().astype(np.int64)         # (nb, order)
        bs = self.format.block_shape
        inner = np.indices(bs).reshape(len(bs), -1).T      # (prod(bs), order)
        out = (bc[:, None, :] * np.asarray(bs)[None, None, :]
               + inner[None, :, :]).reshape(-1, self.order)
        mask = np.all(out < np.asarray(self.shape)[None, :], axis=1)
        return out, mask

    def coords(self) -> np.ndarray:
        """(nnz, order) coordinates in *dimension* order, aligned with
        ``vals``. Blocked formats are the exception: block-padding cells
        beyond the tensor boundary are dropped, so the row count may be
        smaller than ``vals.size`` — pair with ``_blocked_entries`` when
        value alignment matters."""
        if self.format.is_blocked:
            out, mask = self._blocked_entries()
            return out[mask]
        if self.format.is_all_dense:
            # enumerate in STORAGE order (vals is stored level-major), then
            # place each level's coordinate in its dimension column
            sizes = [self.levels[l].size for l in range(self.order)]
            idx = np.indices(sizes).reshape(self.order, -1).T
            out = np.zeros_like(idx)
            for l in range(self.order):
                out[:, self.format.dim_of_level(l)] = idx[:, l]
            return out.astype(INT)
        # Walk levels, expanding positions to coordinates (storage order).
        n_dense = sum(1 for lf in self.format.levels if not lf.compressed)
        cols: List[np.ndarray] = []
        # positions at current level
        if n_dense:
            sizes = [self.levels[l].size for l in range(n_dense)]
            dense_count = int(np.prod(sizes))
        else:
            dense_count = 1
        parent_ids = np.arange(dense_count, dtype=np.int64)
        # expand through compressed levels
        level_coord: List[np.ndarray] = []
        for l in range(n_dense, self.order):
            ld = self.levels[l]
            if ld.kind.singleton:
                level_coord.append(ld.crd.astype(np.int64))
                continue
            counts = np.diff(ld.pos.astype(np.int64))
            parent_ids = np.repeat(parent_ids, counts)
            # previously recorded coords share the parent position space and
            # must be expanded to the new position space too
            level_coord = [np.repeat(c, counts) for c in level_coord]
            level_coord.append(ld.crd.astype(np.int64))
        # decode dense prefix from parent_ids
        out = np.zeros((self.nnz, self.order), dtype=np.int64)
        rem = parent_ids
        for l in reversed(range(n_dense)):
            out[:, l] = rem % self.levels[l].size
            rem = rem // self.levels[l].size
        for j, c in enumerate(level_coord):
            out[:, n_dense + j] = c
        # storage order -> dimension order
        dimcols = np.zeros_like(out)
        for l in range(self.order):
            dimcols[:, self.format.dim_of_level(l)] = out[:, l]
        return dimcols.astype(INT)

    def to_dense(self) -> np.ndarray:
        if self.format.is_blocked:
            dense = np.zeros(self.shape, dtype=self.vals.dtype)
            c, mask = self._blocked_entries()
            if c.size:
                np.add.at(dense, tuple(c[mask].T),
                          self.vals.reshape(-1)[mask])
            return dense
        if self.format.is_all_dense:
            inv = np.argsort(self.format.mode_ordering)
            return np.transpose(
                self.vals.reshape([self.levels[l].size for l in range(self.order)]),
                inv,
            )
        dense = np.zeros(self.shape, dtype=self.vals.dtype)
        c = self.coords()
        if c.size:
            np.add.at(dense, tuple(c.T), self.vals)
        return dense

    def to_format(self, new_format: Format) -> "Tensor":
        """Convert to another spellable format (the paper's assembly /
        format-conversion phase; host-side numpy).

        Non-blocked sparse → sparse goes through the coordinate stream
        (explicitly stored zeros are preserved; duplicate COO entries merge
        by summation); anything involving a blocked or all-dense endpoint
        goes through the dense image."""
        if new_format == self.format:
            return self
        if new_format.order != self.order:
            raise ValueError(
                f"cannot convert order-{self.order} tensor {self.name} to "
                f"order-{new_format.order} format {new_format}")
        if (self.format.is_blocked or new_format.is_blocked
                or self.format.is_all_dense or new_format.is_all_dense):
            return Tensor.from_dense(self.name, self.to_dense(), new_format)
        return Tensor.from_coo(self.name, self.shape, self.coords(),
                               self.vals, new_format, dedupe=True)

    # TIN access sugar: B(i, j)
    def __call__(self, *idx: IndexVar) -> Access:
        return Access(self, idx)

    def __repr__(self) -> str:
        return (f"Tensor({self.name}, shape={self.shape}, {self.format}, "
                f"nnz={self.nnz})")


class TensorVar:
    """Shape/format-only stand-in used by the dry-run (no data allocated)."""

    def __init__(self, name: str, shape: Sequence[int], format: Format,
                 dtype=np.float32, nnz: Optional[int] = None):
        self.name = name
        self.shape = tuple(int(s) for s in shape)
        self.format = format
        self.dtype = dtype
        self.nnz = nnz

    def __call__(self, *idx: IndexVar) -> Access:
        return Access(self, idx)

    def __repr__(self) -> str:
        return f"TensorVar({self.name}, shape={self.shape}, {self.format})"
