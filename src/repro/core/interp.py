"""Interpretation baseline — the CTF analog (paper §I, §VI).

CTF executes a tensor algebra expression as a *series of pairwise*
distributed matmul / elementwise / transposition operations, materializing
dense(ish) intermediates between steps. The paper shows this costs 1–2
orders of magnitude vs. SpDISTAL's fused compiled kernels (Fig. 10:
299× SpMV, 161× SpTTV, 19.2× SpAdd3, 15.3× SDDMM).

This module reproduces that execution model faithfully enough to measure the
same effect: each multiplication is reduced to a pairwise contraction over
*densified* operands with materialized intermediates (including the
asymptotic blowup for expressions needing fusion, e.g. SDDMM materializes
the full C·D product); additions are executed pairwise with intermediate
assembly. No fusion, no format specialization — exactly what compilation
buys in the paper.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from .tin import Access, Add, Assignment, Literal, Mul, TinExpr
from .tensor import Tensor


def _densify(acc: Access) -> jnp.ndarray:
    return jnp.asarray(acc.tensor.to_dense())


def _flatten_mul(e: TinExpr) -> List[Access]:
    if isinstance(e, Mul):
        return _flatten_mul(e.lhs) + _flatten_mul(e.rhs)
    if isinstance(e, Access):
        return [e]
    raise NotImplementedError(type(e))


def _flatten_add(e: TinExpr) -> List[TinExpr]:
    if isinstance(e, Add):
        return _flatten_add(e.lhs) + _flatten_add(e.rhs)
    return [e]


def interpret(stmt: Assignment, jit: bool = False) -> np.ndarray:
    """Execute ``stmt`` CTF-style. Returns the dense result.

    Pairwise contraction order is chosen greedily to minimize each
    materialized intermediate (CTF also plans pair orders); the
    characteristic interpretation costs remain — every intermediate is a
    DENSE materialized tensor and execution is step-by-step. E.g. SDDMM
    materializes the full dense C·D product (the asymptotic cost the paper
    describes in §VI-A), instead of the even-worse 3-D outer product a
    naive left-to-right order would produce."""
    out_idx = [v.name for v in stmt.lhs.idx]
    terms = _flatten_add(stmt.rhs)
    result = None
    for term in terms:
        accs = _flatten_mul(term)
        dims: dict = {}
        for a in accs:
            for v, s in zip(a.idx, a.tensor.shape):
                dims[v.name] = s
        remaining = list(accs)
        # choose the starting factor that admits the smallest first
        # intermediate (CTF plans the contraction tree, not just the order)
        if len(remaining) > 1:
            cand = list(remaining)  # list.sort() empties the list mid-sort

            def start_cost(a):
                a_idx = [v.name for v in a.idx]
                best = None
                for b in cand:
                    if b is a:
                        continue
                    later = set(out_idx)
                    for rest in cand:
                        if rest is not a and rest is not b:
                            later.update(v.name for v in rest.idx)
                    keep = [i for i in dict.fromkeys(
                        a_idx + [v.name for v in b.idx]) if i in later]
                    n = 1
                    for i in keep:
                        n *= dims[i]
                    best = n if best is None else min(best, n)
                return best if best is not None else float("inf")

            remaining.sort(key=start_cost)
        first = remaining.pop(0)
        cur = _densify(first)
        cur_idx = [v.name for v in first.idx]
        while remaining:
            # greedy: pick the factor whose pairwise intermediate is
            # smallest
            def inter_size(acc):
                nxt_idx = [v.name for v in acc.idx]
                later = set(out_idx)
                for rest in remaining:
                    if rest is not acc:
                        later.update(v.name for v in rest.idx)
                keep = [i for i in dict.fromkeys(cur_idx + nxt_idx)
                        if i in later]
                n = 1
                for i in keep:
                    n *= dims[i]
                return n, keep

            best = min(remaining, key=lambda a: inter_size(a)[0])
            _, keep = inter_size(best)
            remaining.remove(best)
            nxt_arr = _densify(best)
            nxt_idx = [v.name for v in best.idx]
            spec = f"{''.join(cur_idx)},{''.join(nxt_idx)}->{''.join(keep)}"
            cur = jnp.einsum(spec, cur, nxt_arr)  # materialized intermediate
            cur = jax.block_until_ready(cur)      # CTF: step-by-step
            cur_idx = keep
        if cur_idx != out_idx:
            spec = f"{''.join(cur_idx)}->{''.join(out_idx)}"
            cur = jnp.einsum(spec, cur)
        result = cur if result is None else jax.block_until_ready(result + cur)
    return np.asarray(result)
