"""Bounded LRU caches for the re-plan fast path.

DISTAL-style systems separate the expensive format/partition *assembly*
step from steady-state execution; our analog is a trio of content-keyed
caches (shard materialization in :mod:`.partition`, plan memoization and
compiled runners in :mod:`.lower`, shard_map executables in
:mod:`repro.distributed.executor`) all built on this one LRU. Keys are
content fingerprints (CRC over storage regions), so a re-plan over
unchanged operands is near-free while any value or structure change —
including in-place mutation — misses and re-packs.

Every cache is bounded (the unbounded-growth latent in the original
one-off add-stream cache) and keeps ``hits`` / ``misses`` / ``evictions``
counters that :class:`repro.core.lower.LoweredKernel` snapshots per lower
call (``kernel.cache``), alongside ``CommStats``.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable, Optional, Sequence, Tuple


def avals_key(arrays: Sequence) -> Tuple:
    """Shapes/dtypes key component shared by the compiled-runner caches
    (core.lower._runner, distributed.executor._spmd_runner)."""
    return tuple((tuple(a.shape), str(a.dtype)) for a in arrays)


#: Default batch-size buckets for the serving fast path. Every incoming
#: batch pads up to the smallest bucket >= its size, so the compiled-runner
#: caches (keyed on avals, hence on the padded batch width) see at most
#: ``len(BATCH_BUCKETS)`` distinct SpMM widths no matter how request counts
#: fluctuate — bounded recompilation under mixed traffic.
BATCH_BUCKETS: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64)


def batch_bucket(n: int, buckets: Sequence[int] = BATCH_BUCKETS) -> int:
    """Smallest bucket >= ``n`` (next power of two beyond the table, so an
    oversized burst still lands on one of O(log n) shapes)."""
    if n < 1:
        raise ValueError(f"batch size must be >= 1, got {n}")
    for b in sorted(buckets):
        if n <= b:
            return int(b)
    b = 1 << (int(n) - 1).bit_length()
    return int(b)


# Private miss sentinel: ``None`` is a legitimate cached value (e.g. the
# tuned-plan cache recording "no feasible candidate"), so misses must be
# distinguishable from stored Nones.
_MISSING = object()


class LRUCache:
    """A bounded mapping with least-recently-used eviction + counters."""

    def __init__(self, capacity: int = 64):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._d: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.stats: Dict[str, int] = {"hits": 0, "misses": 0, "evictions": 0}

    def get(self, key: Hashable, default: Optional[Any] = None) -> Any:
        """Return the cached value (refreshing recency) or ``default``;
        counts a hit or a miss either way — pair every ``get`` with a
        ``put`` on a miss so the counters read as cache effectiveness.
        Pass a private sentinel as ``default`` when stored values may
        themselves be None."""
        try:
            value = self._d[key]
        except KeyError:
            self.stats["misses"] += 1
            return default
        self._d.move_to_end(key)
        self.stats["hits"] += 1
        return value

    def put(self, key: Hashable, value: Any) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.stats["evictions"] += 1

    def get_or_build(self, key: Hashable, factory: Callable[[], Any]) -> Any:
        """Return the cached value, or build + insert it (one hit or miss
        is counted either way). A factory that returns None caches None —
        subsequent calls hit instead of rebuilding."""
        value = self.get(key, _MISSING)
        if value is _MISSING:
            value = factory()
            self.put(key, value)
        return value

    def set_capacity(self, capacity: int) -> None:
        """Re-bound the cache (evicting oldest entries if shrinking)."""
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.stats["evictions"] += 1

    def clear(self) -> None:
        """Drop all entries (counters are kept; reset via reset_stats)."""
        self._d.clear()

    def reset_stats(self) -> None:
        self.stats.update(hits=0, misses=0, evictions=0)

    def items(self):
        """Snapshot of (key, value) pairs, oldest → newest. No recency or
        counter effects — the observability/checkpoint-export view (the
        tuned-plan cache rides checkpoints so an elastic restart skips
        re-search; see runtime/checkpoint.py)."""
        return list(self._d.items())

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key: Hashable) -> bool:  # no recency update
        return key in self._d
