"""Cost-model-driven autoscheduler (ROADMAP: the piece that decides).

``lower(stmt, machine, schedule="auto")`` routes here. Given an
Assignment + operand Tensors + a machine, the planner

1. enumerates candidate :class:`SchedulePoint`s — the 1-D rows and nnz
   strategies plus every 2-D grid factorization P×Q of ``pieces`` the
   grid subsystem supports, each carrying the Pallas ``(block_R,
   block_nb)`` tile from :func:`repro.kernels.autotune.tune_block_ell`
   when the sparse operand is blocked (infeasible tunes are skipped —
   the kernels then use their built-in fallback defaults);
2. scores each point with a roofline-style cost model
   (:class:`repro.launch.roofline.HardwareModel`) fed by the sparse
   operand's structural stats — the row-degree distribution recovered
   from its level-tree walk, nnz, shape — and the same byte formulas the
   lowering engine charges: 1-D replication/reduction from
   ``core.lower`` conventions, per-axis grid bytes from
   :func:`repro.core.grid.grid_axis_bytes`;
3. optionally refines the top-K points by actually lowering and timing
   the jitted runner (on-device measurement breaks model ties); and
4. memoizes the winner in ``_TUNED_PLAN_CACHE``, an LRU keyed like the
   plan cache — signature + operand content fingerprints + machine — so
   a warm re-lower skips the search entirely (``cache.tuned_hits``) and
   any in-place mutation misses.

The model intentionally shares constants and formulas with the
subsystems it predicts: grid bytes come from grid.py itself, 1-D bytes
mirror ``_compute_plans``'s replication rules, and time conversion uses
the roofline HardwareModel — so a model-vs-ledger drift is a bug, not a
calibration gap.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from . import formats as fmt
from . import levels
from . import lower as lower_mod
from ..runtime import telemetry
from .cache import LRUCache, _MISSING
from .partition import (partition_by_bounds, tensor_fingerprint,
                        weights_fingerprint)
from .schedule import Schedule
from .tdn import Machine
from .tensor import Tensor
from .tin import Assignment
from ..kernels.autotune import TuneResult, tune_block_ell
from ..launch.roofline import DEFAULT_HW, HardwareModel

log = logging.getLogger(__name__)

# Winner memoization: (signature, machine dim sizes, weights fingerprint,
# per-operand (name, content fingerprint, index vars)) -> SchedulePoint
# (or None when no candidate could be scored). Content keys mean in-place
# mutation re-searches while an unchanged re-lower skips straight to the
# cached winner.
_TUNED_PLAN_CACHE = LRUCache(capacity=64)
TUNED_PLAN_CACHE_STATS = _TUNED_PLAN_CACHE.stats


def clear_tuned_plan_cache() -> None:
    _TUNED_PLAN_CACHE.clear()


def set_tuned_plan_cache_capacity(capacity: int) -> None:
    _TUNED_PLAN_CACHE.set_capacity(capacity)


def export_tuned_entries() -> list:
    """Snapshot of the tuned-plan cache as (key, SchedulePoint-or-None)
    pairs, oldest → newest. Checkpoints persist this (picklable — keys are
    tuples of str/int, points are plain dataclasses) so a recovered run
    skips the candidate search for operands whose fingerprints survived."""
    return _TUNED_PLAN_CACHE.items()


def import_tuned_entries(entries) -> int:
    """Merge checkpointed tuned entries back in; existing keys win (the
    live entry is at least as fresh). Returns the number imported."""
    n = 0
    for key, point in entries:
        if key not in _TUNED_PLAN_CACHE:
            _TUNED_PLAN_CACHE.put(key, point)
            n += 1
    return n


# Signatures/format families the grid subsystem lowers directly (mirrors
# the conformance matrix's grid cells); other cells only get 1-D points.
_GRID_EXPRS = {"spmv", "spmm", "sddmm"}
_GRID_FORMAT_ROOTS = {"csr", "csc", "bcsr", "bcsc"}


@dataclasses.dataclass
class SearchConfig:
    """Search knobs. ``refine_top_k <= 0`` disables on-device timing —
    the model's ranking decides alone (used by fast conformance-style
    tests); the default measures the model's top 3 and lets wall clock
    pick."""

    refine_top_k: int = 3
    measure_warmup: int = 1
    measure_iters: int = 3


DEFAULT_CONFIG = SearchConfig()


@dataclasses.dataclass
class SchedulePoint:
    """One candidate schedule: strategy space × processor-grid
    factorization × Pallas tile. Self-contained — ``build`` reconstructs
    the Schedule + Machine from it, which is what makes the point itself
    cacheable."""

    space: str                       # 'universe' | 'nnz'
    grid: Tuple[int, ...]            # (P,), (P, Q), or (P, Q, R)
    tile: Optional[Tuple[int, int]] = None   # (block_R, block_nb)
    replicated: bool = False         # 2.5-D: sparse operand replicated on z
    est_cost_s: float = float("inf")
    measured_s: Optional[float] = None
    # Set on the WINNER only: every point the search scored, as plain
    # dicts (label / est_cost_s / measured_s) in model-cost order — the
    # provenance LoweredKernel.explain() renders, kept picklable so
    # checkpointed tuned entries carry it.
    candidates: Optional[List[Dict[str, Any]]] = None

    @property
    def label(self) -> str:
        kind = "rows" if self.space == "universe" else "nnz"
        mesh = "x".join(str(s) for s in self.grid)
        return f"{kind}/{mesh}" + ("r" if self.replicated else "")

    @property
    def canonical_grid(self) -> Tuple[int, ...]:
        """Grid with trailing singleton axes stripped — a P×1 (or 1-deep
        z) factorization IS the lower-order plan, and dedupe keys on
        this so refine never times the same executable twice."""
        g = list(self.grid)
        while len(g) > 1 and g[-1] == 1:
            g.pop()
        return tuple(g)

    @property
    def plan_key(self) -> Tuple:
        g = self.canonical_grid
        return (self.space, g, self.replicated and len(g) >= 3, self.tile)

    def machine_for(self, base: Machine) -> Machine:
        names = [d.name for d in base.dims]
        defaults = ["x", "y", "z", "w"]
        g = self.canonical_grid
        return Machine(*[(names[i] if i < len(names) else defaults[i], s)
                         for i, s in enumerate(g)])

    def build(self, stmt: Assignment,
              base: Machine) -> Tuple[Schedule, Machine]:
        m = self.machine_for(base)
        if self.replicated:
            s = lower_mod.default_replicated_schedule(stmt, m)
        elif len(m.dims) >= 3:
            s = lower_mod.default_grid3_schedule(stmt, m)
        elif len(m.dims) == 2:
            s = lower_mod.default_grid_schedule(stmt, m)
        elif self.space == "universe":
            s = lower_mod.default_row_schedule(stmt, m)
        else:
            s = lower_mod.default_nnz_schedule(stmt, m)
        if self.tile is not None:
            s.tile_hint(*self.tile)
        return s, m


# ---------------------------------------------------------------------------
# Structural stats: what the fingerprinted storage tells us at plan time
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StructStats:
    """Row-degree distribution + sizes of the distributed sparse operand,
    in walk coordinates (block-granular for blocked formats)."""

    entries: int                 # stored entries (blocks for blocked)
    n0: int                      # dim-0 extent of the walk coordinates
    deg: np.ndarray              # (n0,) stored entries per dim-0 coord
    entry_elems: int             # scalar elements per stored entry
    root_tracks_dim0: bool       # storage root iterates output rows
    tile: Optional[TuneResult] = None   # blocked formats: tuned group shape

    @property
    def imbalance(self) -> float:
        mean = self.deg.mean() if self.deg.size else 0.0
        return float(self.deg.max() / mean) if mean else 0.0


def structural_stats(stmt: Assignment) -> Optional[StructStats]:
    """Stats of the first sparse rhs operand (the distributed tensor by
    the default-schedule conventions); None when the statement has no
    sparse operand with storage (dense-only or dry-run)."""
    spas = stmt.sparse_accesses()
    if not spas:
        return None
    t = spas[0].tensor
    if not isinstance(t, Tensor) or getattr(t, "vals", None) is None:
        return None
    tree = levels.tree_of(t)
    w = tree.walk()
    bs = t.format.block_shape if t.format.is_blocked else None
    b0 = bs[0] if bs else 1
    n0 = max(-(-t.shape[0] // b0), 1)
    deg = np.bincount(w.coords[:, 0], minlength=n0) if w.coords.size \
        else np.zeros(n0, dtype=np.int64)
    tile = None
    if bs is not None:
        # tune the Pallas group shape over the row-major block-grid pos
        # (recovered from the degree histogram — valid for BCSC too, where
        # the pack happens after the transpose walk)
        row_pos = np.zeros(n0 + 1, np.int64)
        np.cumsum(deg, out=row_pos[1:])
        tile = tune_block_ell(row_pos, (bs[0], bs[1]))
        if tile.fallback:
            log.warning("plan_search: tuned tile infeasible for %s; "
                        "candidates keep the kernel fallback shape", t.name)
    return StructStats(
        entries=int(w.coords.shape[0]), n0=n0, deg=deg,
        entry_elems=int(np.prod(bs)) if bs else 1,
        root_tracks_dim0=t.format.dim_of_level(0) == 0,
        tile=tile,
    )


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------

def _grid_eligible(stmt: Assignment) -> bool:
    sig = stmt.signature()
    if lower_mod.expression_key(sig) not in _GRID_EXPRS:
        return False
    spas = stmt.sparse_accesses()
    if not spas or len(spas[0].idx) < 2:
        return False
    root = fmt.format_key(spas[0].tensor.format).split("(")[0]
    return root in _GRID_FORMAT_ROOTS


def _replicated_eligible(stmt: Assignment) -> bool:
    """2.5-D replicated candidates: scalar-format sparse operand (the
    replicated grid emitters don't walk blocked trees) and a loop
    variable outside the sparse index set to split over z (SpMM's output
    columns, SDDMM's contraction) — SpMV has no such variable."""
    if not _grid_eligible(stmt):
        return False
    spa = stmt.sparse_accesses()[0]
    if spa.tensor.format.is_blocked:
        return False
    return any(v not in spa.idx for v in stmt.all_vars)


def enumerate_points(stmt: Assignment, machine: Machine,
                     stats: Optional[StructStats] = None,
                     ) -> List[SchedulePoint]:
    """The search space: 1-D rows + 1-D nnz, and each 2-D factorization
    P×Q (P, Q > 1) of ``pieces`` for grid-distributable cells. 2-D nnz is
    NOT enumerated — a nested pos-split canonicalizes to the flat P·Q
    split, so it is never a distinct execution. Blocked operands carry
    the tuned tile on every point (None when the tune was infeasible)."""
    pieces = machine.n_procs
    tile = None
    if stats is not None and stats.tile is not None \
            and not stats.tile.fallback:
        tile = (stats.tile.block_r, stats.tile.block_n)
    pts = [SchedulePoint("universe", (pieces, 1), tile)]
    if stmt.sparse_accesses():
        pts.append(SchedulePoint("nnz", (pieces, 1), tile))
    if _grid_eligible(stmt):
        for P in range(2, pieces):
            if pieces % P == 0 and pieces // P > 1:
                pts.append(SchedulePoint("universe", (P, pieces // P), tile))
    if _replicated_eligible(stmt):
        # every P×Q×R factorization with a genuine replication depth
        # (R >= 2; R == 1 would just be the 2-D plan again)
        for P in range(2, pieces + 1):
            if pieces % P:
                continue
            rest = pieces // P
            for Q in range(1, rest):
                if rest % Q:
                    continue
                R = rest // Q
                if R >= 2:
                    pts.append(SchedulePoint("universe", (P, Q, R), tile,
                                             replicated=True))
    # dedupe by canonical plan key so degenerate factorizations that
    # coincide with a lower-order plan are scored (and refined) once
    uniq: Dict[Tuple, SchedulePoint] = {}
    for p in pts:
        uniq.setdefault(p.plan_key, p)
    return list(uniq.values())


# ---------------------------------------------------------------------------
# The cost model
# ---------------------------------------------------------------------------

def _entry_flops(stmt: Assignment) -> float:
    """FLOPs per stored SCALAR entry: 2 (multiply-add) times the extent
    of every loop that does not index the sparse operand (the dense
    fan-out — J for SpMM's output columns, K for SDDMM's contraction)."""
    spas = stmt.sparse_accesses()
    if not spas:
        return 2.0
    sparse_vars = set(spas[0].idx)
    seen: List = []
    for v in list(stmt.lhs.idx) + list(stmt.rhs.index_vars()):
        if v not in seen:
            seen.append(v)
    fan = 1.0
    for v in seen:
        if v not in sparse_vars:
            fan *= stmt.var_extent(v)
    return 2.0 * max(fan, 1.0)


def _replicated_universe(stmt: Assignment) -> List[Tensor]:
    """Operands a 1-D rows schedule replicates — mirrors
    ``_compute_plans``: everything not indexed by the distributed
    variable at (or through) its storage root."""
    dist_var = stmt.result_vars[0]
    out_name = stmt.lhs.tensor.name
    rep: List[Tensor] = []
    seen = set()
    for acc in stmt.accesses():
        t = acc.tensor
        if t.name in seen or t.name == out_name:
            continue
        seen.add(t.name)
        if dist_var in acc.idx:
            lvl_dim = acc.idx.index(dist_var)
            if t.format.level_of_dim(lvl_dim) == 0:
                continue
            if lvl_dim == 0 and t.format.is_sparse:
                continue   # transpose walk realizes the row windows
        rep.append(t)
    return rep


def _replicated_nnz(stmt: Assignment) -> Tuple[List[Tensor], bool]:
    """(replicated operands, output_partitioned) under the 1-D nnz
    schedule: everything but the position-space tensor replicates; a
    dense output whose leading variable is the position tensor's root
    variable is row-partitioned (small boundary-overlap reduce), any
    other output reduces at full extent."""
    pos_t = None
    for acc in stmt.rhs.accesses():
        if acc.tensor.format.is_sparse:
            pos_t = acc.tensor
            break
    out = stmt.lhs.tensor
    rep: List[Tensor] = []
    seen = set()
    for acc in stmt.rhs.accesses():
        t = acc.tensor
        if t.name in seen or (pos_t is not None and t.name == pos_t.name) \
                or t.name == out.name:
            continue
        seen.add(t.name)
        rep.append(t)
    out_partitioned = (
        pos_t is not None and not out.format.is_sparse and bool(stmt.lhs.idx)
        and stmt.lhs.idx[0] == lower_mod.pos_tensor_root_var(stmt, pos_t))
    return rep, out_partitioned


def estimate(stmt: Assignment, point: SchedulePoint, stats: StructStats,
             hw: HardwareModel = DEFAULT_HW) -> float:
    """Roofline-style score in seconds: max(compute, HBM) + network.

    Per-device work is the padded maximum over pieces — universe splits
    carry the row-degree imbalance (windows pad to the heaviest window),
    nnz splits are balanced by construction but pay the cross-piece
    output merge (the full output touched once more) plus the
    overlapping-row (or full-extent, for column-major roots) reduction
    the lowering engine charges."""
    grid = tuple(point.grid)
    P = grid[0]
    pieces = 1
    for s in grid:
        pieces *= s
    par = max(pieces // max(P, 1), 1)   # column-axis (y·z) work division
    flops_per_entry = _entry_flops(stmt) * stats.entry_elems
    bytes_per_entry = 8 + 4 * stats.entry_elems
    out_t = stmt.lhs.tensor
    out_bytes = lower_mod._nbytes(out_t)

    sig = stmt.signature()
    if point.space == "universe":
        bounds = partition_by_bounds(stats.n0, P)
        cum = np.zeros(stats.n0 + 1, np.int64)
        np.cumsum(stats.deg, out=cum[1:])
        win = cum[bounds[:, 1]] - cum[bounds[:, 0]]
        work = float(win.max()) / par         # leaves pad to the max window
        mem = work * bytes_per_entry
        if len(point.canonical_grid) > 1:
            from . import grid as grid_mod
            sched, _ = point.build(stmt, Machine.grid(*grid))
            axes = grid_mod.grid_axis_bytes(stmt, sched.strategy())
            comm = float(sum(a.network_bytes() for a in axes.values()))
        else:
            comm = float((pieces - 1) *
                         sum(lower_mod._nbytes(t)
                             for t in _replicated_universe(stmt)))
    else:
        work = float(-(-stats.entries // max(pieces, 1)))
        # scatter-assembly merge: the global output is touched once more
        mem = work * bytes_per_entry + out_bytes
        if (sig, "nnz") in lower_mod._SELF_MATERIALIZING:
            # spadd3/nnz ships every chunk's entry union to the merge
            tile_b = 8 + 4 * stats.entry_elems
            comm = float(stats.entries * tile_b)
        else:
            rep, out_partitioned = _replicated_nnz(stmt)
            comm = float((pieces - 1) *
                         sum(lower_mod._nbytes(t) for t in rep))
            if not stats.root_tracks_dim0 or not out_partitioned:
                comm += (pieces - 1) * out_bytes   # full-extent reduce
            else:
                # boundary rows overlap between adjacent nnz windows
                row_b = out_bytes / max(out_t.shape[0], 1)
                comm += (pieces - 1) * row_b
    return hw.bound_s(work * flops_per_entry, mem, comm)


# ---------------------------------------------------------------------------
# Measurement refinement + the search driver
# ---------------------------------------------------------------------------

def _measure(stmt: Assignment, point: SchedulePoint, base: Machine,
             weights, jit: bool, cfg: SearchConfig) -> float:
    import jax
    sched, m = point.build(stmt, base)
    k = lower_mod.lower(stmt, m, schedule=sched, weights=weights, jit=jit)
    best = float("inf")
    for _ in range(cfg.measure_warmup):
        jax.block_until_ready(k.run())
    for _ in range(cfg.measure_iters):
        t0 = time.perf_counter()
        jax.block_until_ready(k.run())
        best = min(best, time.perf_counter() - t0)
    return best


def search(stmt: Assignment, machine: Machine, *,
           weights=None, jit: bool = True,
           config: Optional[SearchConfig] = None,
           hw: HardwareModel = DEFAULT_HW) -> Optional[SchedulePoint]:
    """Enumerate, score, optionally measure, and return the winning
    point (None when nothing could be scored)."""
    cfg = config or DEFAULT_CONFIG
    with telemetry.span("plan_search.search",
                        sig=stmt.signature()) as search_sp:
        stats = structural_stats(stmt)
        points = enumerate_points(stmt, machine, stats)
        if not points:
            return None
        if stats is None:
            # dense-only statement: nothing structural to rank — keep rows
            return points[0]
        for p in points:
            try:
                p.est_cost_s = estimate(stmt, p, stats, hw)
            except Exception:                    # estimator gap: deprioritize
                log.exception("plan_search: estimate failed for %s", p.label)
                p.est_cost_s = float("inf")
        points.sort(key=lambda p: p.est_cost_s)
        if cfg.refine_top_k > 0 and len(points) > 1:
            for p in points[:cfg.refine_top_k]:
                try:
                    with telemetry.span("plan_search.measure",
                                        candidate=p.label) as msp:
                        p.measured_s = _measure(stmt, p, machine, weights,
                                                jit, cfg)
                        msp.set(measured_s=p.measured_s)
                except Exception:
                    log.exception("plan_search: measurement failed for %s",
                                  p.label)
                    p.measured_s = float("inf")
            measured = [p for p in points if p.measured_s is not None]
            measured.sort(key=lambda p: p.measured_s)
            winner = measured[0]
        else:
            winner = points[0]
        # Provenance: every scored candidate, model-cost order, on the
        # winner (what LoweredKernel.explain() renders).
        winner.candidates = [
            {"label": p.label, "est_cost_s": p.est_cost_s,
             "measured_s": (None if p.measured_s is None
                            else float(p.measured_s))}
            for p in points]
        search_sp.set(winner=winner.label, n_candidates=len(points))
    log.info("plan_search: %s -> %s (est %.3es, measured %s)",
             lower_mod.expression_key(stmt.signature()), winner.label,
             winner.est_cost_s,
             f"{winner.measured_s:.3e}s" if winner.measured_s is not None
             else "-")
    return winner


def _tuned_key(stmt: Assignment, machine: Machine, weights) -> Optional[Tuple]:
    """Like ``lower._plan_cache_key`` minus the strategy (the strategy is
    the cached VALUE here): signature + machine + operand content
    fingerprints. None disables caching (dry-run operands)."""
    ops = []
    for acc in stmt.accesses():
        t = acc.tensor
        if not isinstance(t, Tensor) or getattr(t, "vals", None) is None:
            return None
        ops.append((t.name, tensor_fingerprint(t),
                    tuple(v.name for v in acc.idx)))
    return (stmt.signature(), tuple(d.size for d in machine.dims),
            weights_fingerprint(weights), tuple(ops))


def resolve_auto(stmt: Assignment, machine: Machine, *, weights=None,
                 jit: bool = True, config: Optional[SearchConfig] = None,
                 ) -> Tuple[Schedule, Machine, Optional[SchedulePoint]]:
    """``lower(schedule="auto")`` entry: cached winner or fresh search.

    Returns (schedule, machine, point) — the machine is re-factorized to
    the winning grid shape (the planner owns the factorization; the
    total piece count is always the caller's)."""
    key = _tuned_key(stmt, machine, weights)
    if key is None:
        # dry-run: no storage to score; default rows, uncached
        return lower_mod.default_row_schedule(stmt, machine), machine, None
    point = _TUNED_PLAN_CACHE.get(key, _MISSING)
    telemetry.instant("plan_search.tuned_cache", hit=point is not _MISSING)
    if point is _MISSING:
        point = search(stmt, machine, weights=weights, jit=jit,
                       config=config)
        _TUNED_PLAN_CACHE.put(key, point)
    if point is None:
        return lower_mod.default_row_schedule(stmt, machine), machine, None
    sched, m = point.build(stmt, machine)
    return sched, m, point
