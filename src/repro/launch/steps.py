"""Step builders: train_step / prefill_step / decode_step per (arch × shape
× mesh), with sharding trees from the planner.

``input_specs`` (MULTI-POD DRY-RUN item 2) returns ShapeDtypeStruct
stand-ins for every model input — weak-type-correct, shardable, no device
allocation. The same builders back the real train/serve launchers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig, ShapeConfig
from ..distributed import planner
from ..distributed.mesh import axis_size, data_axes
from ..models.layers import ShardCtx
from ..models.model import LM
from ..optim.adamw import AdamWState, adamw_init, adamw_update
from ..optim.schedules import cosine_with_warmup


def make_ctx(mesh) -> ShardCtx:
    da = data_axes(mesh)
    return ShardCtx(batch=da, model="model" if "model" in mesh.axis_names
                    else None, seq="model", active=True,
                    dp=axis_size(mesh, *da) or 1)


def build_lm(cfg: ArchConfig, mesh, serve: bool = False) -> LM:
    if serve:
        # serving holds no optimizer state; bf16 params are the standard
        # deployment format (fits llama4-scout's 109B on one pod at TP=16)
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    return LM(cfg, make_ctx(mesh))


def effective_accum(cfg_batch: int, requested: int, mesh) -> int:
    """Largest accum ≤ requested with a data-shardable microbatch."""
    dp = axis_size(mesh, *data_axes(mesh)) or 1
    accum = max(requested, 1)
    while accum > 1 and (cfg_batch % accum or (cfg_batch // accum) % dp):
        accum -= 1
    return accum


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig, lm: LM) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.frontend != "none":
            specs["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    else:  # decode
        specs["token"] = jax.ShapeDtypeStruct((B,), jnp.int32)
        window = shape.attention_window or cfg.attention_window
        specs["cache"] = jax.eval_shape(
            lambda: lm.init_cache(B, S, window=window,
                                  src_len=cfg.frontend_tokens
                                  if cfg.is_encdec else 0))
    return specs


def abstract_state(lm: LM):
    params = lm.abstract_params()
    opt = jax.eval_shape(adamw_init, params)
    return params, opt


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(lm: LM, shape: ShapeConfig, mesh, *,
                    peak_lr: float = 3e-4, total_steps: int = 10000):
    cfg = lm.cfg
    requested = cfg.grad_accum_override or shape.grad_accum
    accum = effective_accum(shape.global_batch, requested, mesh)
    window = shape.attention_window or cfg.attention_window
    variant = cfg.train_attn_variant if shape.kind == "train" else "auto"
    has_frontend = cfg.frontend != "none"

    def loss_fn(params, tokens, frontend):
        return lm.loss(params, tokens, frontend, window=window,
                       variant=variant)

    def train_step(params, opt_state: AdamWState, tokens,
                   frontend=None):
        B, S = tokens.shape
        mb = B // accum
        tk = tokens.reshape(accum, mb, S)
        fe = (frontend.reshape(accum, mb, *frontend.shape[1:])
              if frontend is not None else None)

        # §Perf iteration 4 (REFUTED, reverted): accumulating inside a
        # single value_and_grad did NOT consolidate the gradient reduction —
        # the scan-transposed backward still reduces each microbatch's
        # partials into the FSDP-sharded accumulator, and the extra remat
        # recompute added ~60% all-gather traffic (llava train_4k: AR
        # 1173→1430 GB/dev, AG 796→1278 GB/dev). Per-microbatch reduction is
        # inherent to a sharded accumulator; the working lever is fewer,
        # larger microbatches (iteration 5 — grad_accum_override).
        def micro(gsum, sl):
            batch = sl[0]
            f = sl[1] if has_frontend else None
            loss, g = jax.value_and_grad(loss_fn)(params, batch, f)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return gsum, loss

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        xs = (tk, fe) if has_frontend else (tk,)
        gsum, losses = jax.lax.scan(micro, g0, xs)
        grads = jax.tree.map(lambda g: g / accum, gsum)
        warmup = max(min(200, total_steps // 10), 1)
        # schedule evaluated at the step being TAKEN (1-based): step-0 lr
        # would otherwise be exactly 0 and the first update a no-op
        lr = cosine_with_warmup(opt_state.step + 1, peak_lr=peak_lr,
                                warmup_steps=warmup, total_steps=total_steps)
        new_p, new_opt, gnorm = adamw_update(params, grads, opt_state, lr=lr)
        metrics = {"loss": losses.mean(), "gnorm": gnorm, "lr": lr}
        return new_p, new_opt, metrics

    return train_step, accum


def make_prefill_step(lm: LM, shape: ShapeConfig):
    cfg = lm.cfg
    window = shape.attention_window or cfg.attention_window

    def prefill_step(params, tokens, frontend=None):
        logits, _ = lm.apply(params, tokens, frontend, window=window,
                             last_only=True)
        return logits[:, 0]

    return prefill_step


def make_decode_step(lm: LM, shape: ShapeConfig):
    cfg = lm.cfg
    window = shape.attention_window or cfg.attention_window

    def decode_step(params, cache, token):
        return lm.decode_step(params, cache, token, window=window)

    return decode_step


# ---------------------------------------------------------------------------
# Sharding trees for a full step
# ---------------------------------------------------------------------------

def step_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh, lm: LM):
    """Returns (args_abstract, in_shardings, donate_argnums) for the cell's
    step function, ready for jax.jit(...).lower(*args_abstract)."""
    serve = shape.kind != "train"
    params_abs, opt_abs = abstract_state(lm)
    p_spec = planner.params_pspecs(params_abs, mesh, serve=serve)
    p_sh = planner.shardings_from(p_spec, mesh)
    specs = input_specs(cfg, shape, lm)
    if shape.kind == "train":
        o_spec = planner.opt_pspecs(opt_abs, params_abs, mesh)
        o_sh = planner.shardings_from(o_spec, mesh)
        b_sh = NamedSharding(mesh, planner.batch_pspec(mesh,
                                                       shape.global_batch))
        args = [params_abs, opt_abs, specs["tokens"]]
        shard = [p_sh, o_sh, b_sh]
        if "frontend" in specs:
            args.append(specs["frontend"])
            shard.append(NamedSharding(
                mesh, planner.frontend_pspec(mesh, shape.global_batch)))
        return tuple(args), tuple(shard), (0, 1)
    if shape.kind == "prefill":
        b_sh = NamedSharding(mesh, planner.batch_pspec(mesh,
                                                       shape.global_batch))
        args = [params_abs, specs["tokens"]]
        shard = [p_sh, b_sh]
        if "frontend" in specs:
            args.append(specs["frontend"])
            shard.append(NamedSharding(
                mesh, planner.frontend_pspec(mesh, shape.global_batch)))
        return tuple(args), tuple(shard), ()
    # decode
    cache_abs = specs["cache"]
    c_spec = planner.cache_pspecs(cache_abs, mesh, shape.global_batch)
    c_sh = planner.shardings_from(c_spec, mesh)
    tok_sh = NamedSharding(
        mesh, P(data_axes(mesh))
        if shape.global_batch % (axis_size(mesh, *data_axes(mesh)) or 1) == 0
        and shape.global_batch > 1 else P(None))
    return ((params_abs, cache_abs, specs["token"]),
            (p_sh, c_sh, tok_sh), (1,))


def build_step(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """One-stop: returns (jitted_fn, abstract_args) for the cell."""
    lm = build_lm(cfg, mesh, serve=shape.kind != "train")
    args, shardings, donate = step_shardings(cfg, shape, mesh, lm)
    if shape.kind == "train":
        fn, accum = make_train_step(lm, shape, mesh)
    elif shape.kind == "prefill":
        fn = make_prefill_step(lm, shape)
    else:
        fn = make_decode_step(lm, shape)
    jf = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
    return jf, args, lm
