"""Production mesh definitions (MULTI-POD DRY-RUN spec).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax
initialization.
"""
from __future__ import annotations

from ..compat import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return make_mesh_compat((1, 1), ("data", "model"))
