"""Production mesh definitions (MULTI-POD DRY-RUN spec).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax
initialization.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    shape = (1, 1)
    axes = ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))
