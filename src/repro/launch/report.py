"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table, and
render telemetry snapshots (:func:`telemetry_table`) — the markdown view
of ``repro.runtime.telemetry.METRICS.snapshot()`` / the ``telemetry``
block that ``benchmarks/run.py --json`` embeds in BENCH artifacts."""
from __future__ import annotations

import json
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

ARCH_ORDER = ["llava-next-34b", "zamba2-7b", "xlstm-125m", "starcoder2-15b",
              "llama3-8b", "internlm2-1.8b", "qwen3-14b", "olmoe-1b-7b",
              "llama4-scout-17b-a16e", "seamless-m4t-medium"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh="pod256"):
    recs = {}
    for f in OUT_DIR.glob(f"*_{mesh}.json"):
        r = json.loads(f.read_text())
        recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def table(mesh="pod256") -> str:
    recs = load(mesh)
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "bound | mem/dev | useful-FLOPs |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None:
                rows.append(f"| {a} | {s} | - | - | - | MISSING | - | - | - |")
                continue
            if r["status"] != "ok":
                rows.append(f"| {a} | {s} | - | - | - | "
                            f"FAIL: {r.get('error','')[:40]} | - | - | - |")
                continue
            rl = r["roofline"]
            rows.append(
                f"| {a} | {s} | {fmt_s(rl['compute_s'])} | "
                f"{fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} | "
                f"{rl['dominant'].replace('_s','')} | "
                f"{fmt_s(rl['roofline_bound_s'])} | "
                f"{r['memory']['peak_estimate_gib']:.2f}GiB | "
                f"{rl.get('useful_flops_ratio', 0):.2f} |")
    return "\n".join(rows)


def summary(mesh="pod256"):
    recs = load(mesh)
    ok = [r for r in recs.values() if r["status"] == "ok"]
    out = {
        "cells_ok": len(ok), "cells_total": len(recs),
        "over_16gib": sorted([(r["arch"], r["shape"],
                               r["memory"]["peak_estimate_gib"])
                              for r in ok
                              if r["memory"]["peak_estimate_gib"] > 16],
                             key=lambda t: -t[2]),
        "most_collective_bound": sorted(
            [(r["arch"], r["shape"],
              r["roofline"]["collective_s"] /
              max(r["roofline"]["roofline_bound_s"], 1e-12))
             for r in ok], key=lambda t: -t[2])[:5],
        "worst_compute_fraction": sorted(
            [(r["arch"], r["shape"],
              r["roofline"]["compute_fraction_at_bound"])
             for r in ok if r["shape"] == "train_4k"],
            key=lambda t: t[2])[:5],
    }
    return out


def _fmt_bytes(n: float) -> str:
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024
    return f"{n:.1f}GiB"


def telemetry_table(snapshot: dict) -> str:
    """Markdown render of a ``MetricsRegistry.snapshot()`` (or the
    ``telemetry`` block of a ``BENCH_<suite>.json``): cache hit rates,
    communication byte counters, histogram summaries, gauges."""
    out = []
    caches = snapshot.get("caches") or {}
    if caches:
        out += ["### Caches", "",
                "| cache | hits | misses | hit rate |", "|---|---|---|---|"]
        for name in sorted(caches):
            c = caches[name]
            rate = ("-" if c.get("hit_rate") is None
                    else f"{c['hit_rate']:.1%}")
            out.append(f"| {name} | {c['hits']} | {c['misses']} | {rate} |")
        out.append("")
    counters = snapshot.get("counters") or {}
    comm = {k: v for k, v in counters.items() if k.startswith("comm.")}
    other = {k: v for k, v in counters.items() if not k.startswith("comm.")}
    if comm:
        out += ["### Communication (modeled bytes, cumulative)", "",
                "| counter | bytes |", "|---|---|"]
        for k in sorted(comm):
            out.append(f"| {k} | {_fmt_bytes(comm[k])} |")
        out.append("")
    if other:
        out += ["### Counters", "", "| counter | value |", "|---|---|"]
        for k in sorted(other):
            v = other[k]
            out.append(f"| {k} | {v:g} |")
        out.append("")
    hists = snapshot.get("histograms") or {}
    if hists:
        out += ["### Histograms", "",
                "| name | count | mean | p50 | p90 | max |",
                "|---|---|---|---|---|---|"]
        for k in sorted(hists):
            h = hists[k]
            out.append(
                f"| {k} | {h['count']} | {h['mean']:.3e} | {h['p50']:.3e} "
                f"| {h['p90']:.3e} | {h['max']:.3e} |")
        out.append("")
    gauges = snapshot.get("gauges") or {}
    if gauges:
        out += ["### Gauges", "", "| gauge | value |", "|---|---|"]
        for k in sorted(gauges):
            out.append(f"| {k} | {gauges[k]:.4g} |")
        out.append("")
    return "\n".join(out) if out else "(empty telemetry snapshot)"


if __name__ == "__main__":
    import sys
    if len(sys.argv) > 2 and sys.argv[1] == "--telemetry":
        # render the telemetry block of a BENCH json (or a bare snapshot)
        payload = json.loads(Path(sys.argv[2]).read_text())
        print(telemetry_table(payload.get("telemetry", payload)))
        raise SystemExit(0)
    mesh = sys.argv[1] if len(sys.argv) > 1 else "pod256"
    print(table(mesh))
    print()
    print(json.dumps(summary(mesh), indent=2, default=str))
