"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import json
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

ARCH_ORDER = ["llava-next-34b", "zamba2-7b", "xlstm-125m", "starcoder2-15b",
              "llama3-8b", "internlm2-1.8b", "qwen3-14b", "olmoe-1b-7b",
              "llama4-scout-17b-a16e", "seamless-m4t-medium"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh="pod256"):
    recs = {}
    for f in OUT_DIR.glob(f"*_{mesh}.json"):
        r = json.loads(f.read_text())
        recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def table(mesh="pod256") -> str:
    recs = load(mesh)
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "bound | mem/dev | useful-FLOPs |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None:
                rows.append(f"| {a} | {s} | - | - | - | MISSING | - | - | - |")
                continue
            if r["status"] != "ok":
                rows.append(f"| {a} | {s} | - | - | - | "
                            f"FAIL: {r.get('error','')[:40]} | - | - | - |")
                continue
            rl = r["roofline"]
            rows.append(
                f"| {a} | {s} | {fmt_s(rl['compute_s'])} | "
                f"{fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} | "
                f"{rl['dominant'].replace('_s','')} | "
                f"{fmt_s(rl['roofline_bound_s'])} | "
                f"{r['memory']['peak_estimate_gib']:.2f}GiB | "
                f"{rl.get('useful_flops_ratio', 0):.2f} |")
    return "\n".join(rows)


def summary(mesh="pod256"):
    recs = load(mesh)
    ok = [r for r in recs.values() if r["status"] == "ok"]
    out = {
        "cells_ok": len(ok), "cells_total": len(recs),
        "over_16gib": sorted([(r["arch"], r["shape"],
                               r["memory"]["peak_estimate_gib"])
                              for r in ok
                              if r["memory"]["peak_estimate_gib"] > 16],
                             key=lambda t: -t[2]),
        "most_collective_bound": sorted(
            [(r["arch"], r["shape"],
              r["roofline"]["collective_s"] /
              max(r["roofline"]["roofline_bound_s"], 1e-12))
             for r in ok], key=lambda t: -t[2])[:5],
        "worst_compute_fraction": sorted(
            [(r["arch"], r["shape"],
              r["roofline"]["compute_fraction_at_bound"])
             for r in ok if r["shape"] == "train_4k"],
            key=lambda t: t[2])[:5],
    }
    return out


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "pod256"
    print(table(mesh))
    print()
    print(json.dumps(summary(mesh), indent=2, default=str))
