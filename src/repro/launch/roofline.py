"""Roofline accounting from compiled HLO (ROOFLINE ANALYSIS deliverable).

``compiled.cost_analysis()`` counts `while` bodies ONCE (verified on this
jax build), which would undercount a scan-over-layers model by ~n_layers×.
This module therefore does its own HLO-text accounting with trip-count
multipliers:

- **dot FLOPs**: every ``dot`` op contributes 2·|out|·K (K = contracted
  extent from the lhs shape + contracting dims); dots inside fusions are
  counted via the fusion's called computation.
- **memory bytes**: per top-level op, output bytes + operand bytes — the
  post-fusion HBM-traffic model (each fusion reads its inputs and writes
  its outputs once).
- **collective bytes**: payload (output) bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, bucketed by kind.
- `while` ops multiply their body+condition cost by the
  ``known_trip_count`` from backend_config; conditionals take the max
  branch; fusions/calls recurse for FLOPs only.

The compiled module is already SPMD-partitioned, so all numbers are
PER-DEVICE. Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Roofline hardware constants bundled with the time formulas — shared
    by the HLO report below and the plan-time cost model in
    :mod:`repro.core.plan_search` (which scores candidate schedules before
    any HLO exists)."""

    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    def compute_s(self, flops: float) -> float:
        return flops / self.peak_flops

    def memory_s(self, nbytes: float) -> float:
        return nbytes / self.hbm_bw

    def collective_s(self, nbytes: float) -> float:
        return nbytes / self.ici_bw

    def bound_s(self, flops: float, mem_bytes: float,
                coll_bytes: float) -> float:
        """Roofline bound: on-chip terms overlap (max), network adds."""
        return max(self.compute_s(flops), self.memory_s(mem_bytes)) \
            + self.collective_s(coll_bytes)


DEFAULT_HW = HardwareModel()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z]+\d*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|[a-z]+\d*\[[\d,]*\]\S*)\s*"
    r"([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BDIMS_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})

    def add(self, other: "Cost", mult: float = 1.0,
            mem: bool = True) -> None:
        self.flops += other.flops * mult
        if mem:
            self.mem_bytes += other.mem_bytes * mult
        for k in COLLECTIVE_KINDS:
            self.coll_bytes[k] += other.coll_bytes[k] * mult

    @property
    def total_coll(self) -> float:
        return sum(self.coll_bytes.values())


class HloAnalyzer:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[dict]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._cost_cache: Dict[str, Cost] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur_name = None
        cur_ops: List[dict] = []
        shapes: Dict[str, str] = {}
        for line in text.splitlines():
            # computation header: `%name (params...) -> type {` or
            # `ENTRY %name (...) ... {` — params may nest parens/brackets,
            # so key off the leading `%name (` + trailing `{` instead.
            header = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if (header and line.rstrip().endswith("{")
                    and "=" not in line.split("(")[0]):
                if cur_name is not None:
                    self.computations[cur_name] = cur_ops
                cur_name = header.group(2)
                cur_ops = []
                shapes = {}
                if header.group(1):
                    self.entry = cur_name
                continue
            if line.strip() == "}":
                if cur_name is not None:
                    self.computations[cur_name] = cur_ops
                    cur_name = None
                continue
            m = _OP_RE.match(line)
            if not m or cur_name is None:
                continue
            name, type_str, opcode, rest = m.groups()
            shapes[name] = type_str
            cur_ops.append({
                "name": name, "type": type_str, "opcode": opcode,
                "line": line, "shapes": shapes,
            })
        if cur_name is not None:
            self.computations[cur_name] = cur_ops

    # ------------------------------------------------------------------
    def _dot_flops(self, op: dict) -> float:
        out_dims = _shape_dims(op["type"])
        out_elems = 1
        for d in out_dims:
            out_elems *= d
        # contracted extent from the lhs operand's shape
        lhs_name_m = re.search(r"\(%?([\w\.\-]+)", op["line"].split("(", 1)[1]
                               if "(" in op["line"] else "")
        # simpler: first operand inside dot(...)
        args = op["line"].split(op["opcode"] + "(", 1)[-1]
        first = re.match(r"\s*%?([\w\.\-]+)", args)
        K = 1
        if first:
            lhs_shape = op["shapes"].get(first.group(1))
            if lhs_shape:
                dims = _shape_dims(lhs_shape)
                cm = _LHS_CDIMS_RE.search(op["line"])
                if cm and cm.group(1):
                    for idx in cm.group(1).split(","):
                        i = int(idx)
                        if i < len(dims):
                            K *= dims[i]
        return 2.0 * out_elems * K

    def _op_operand_bytes(self, op: dict) -> int:
        """HBM reads for one top-level op.

        For fusions, an operand that is only dynamic-sliced/gathered inside
        the fused computation is charged at the slice-output size, not the
        full array — otherwise a scan-over-layers model would be charged
        L× its weight stack (the slice-per-iteration pattern).
        """
        args = op["line"].split(op["opcode"] + "(", 1)
        if len(args) != 2:
            return 0
        # operand list ends at the first ')' (attrs like calls=%comp follow)
        operand_names = [m.group(1) for m in
                         re.finditer(r"%([\w\.\-]+)", args[1].split(")", 1)[0])]
        sliced_params = {}
        if op["opcode"] == "fusion":
            cm = _CALLS_RE.search(op["line"])
            comp = cm.group(1) if cm else None
            if comp in self.computations:
                sliced_params = self._sliced_param_reads(comp)
        total = 0
        for idx, name in enumerate(operand_names):
            t = op["shapes"].get(name)
            if not t:
                continue
            full = _shape_bytes(t)
            if idx in sliced_params:
                total += min(full, sliced_params[idx])
            else:
                total += full
        return total

    def _sliced_param_reads(self, comp: str) -> Dict[int, int]:
        """param index -> effective read bytes, for params consumed ONLY by
        dynamic-slice/gather ops inside the fused computation."""
        ops = self.computations.get(comp, [])
        param_idx: Dict[str, int] = {}
        for o in ops:
            if o["opcode"] == "parameter":
                pm = re.search(r"parameter\((\d+)\)", o["line"])
                if pm:
                    param_idx[o["name"]] = int(pm.group(1))
        uses: Dict[str, List[Tuple[str, int]]] = {n: [] for n in param_idx}
        for o in ops:
            if o["opcode"] == "parameter":
                continue
            args = o["line"].split(o["opcode"] + "(", 1)
            if len(args) != 2:
                continue
            for m in re.finditer(r"%([\w\.\-]+)", args[1]):
                if m.group(1) in uses:
                    uses[m.group(1)].append(
                        (o["opcode"], _shape_bytes(o["type"])))
        out: Dict[int, int] = {}
        for name, idx in param_idx.items():
            us = uses.get(name, [])
            # dynamic-update-slice writes into the param in place: it reads
            # nothing of it, so a param consumed only by slices/gathers/dus
            # is charged at the slice-read sizes (ds/gather outputs).
            slicelike = ("dynamic-slice", "gather", "dynamic-update-slice",
                         "convert", "bitcast")
            if us and all(k in slicelike for k, _ in us):
                out[idx] = sum(b for k, b in us if k in
                               ("dynamic-slice", "gather"))
        return out

    def _flops_only(self, comp: str) -> float:
        ops = self.computations.get(comp, [])
        total = 0.0
        for op in ops:
            if op["opcode"] == "dot":
                total += self._dot_flops(op)
            elif op["opcode"] in ("fusion", "call"):
                cm = _CALLS_RE.search(op["line"])
                if cm and cm.group(1) in self.computations:
                    total += self._flops_only(cm.group(1))
        return total

    def cost(self, comp: Optional[str] = None) -> Cost:
        comp = comp or self.entry
        if comp in self._cost_cache:
            return self._cost_cache[comp]
        c = Cost()
        for op in self.computations.get(comp, []):
            opcode = op["opcode"]
            out_bytes = _shape_bytes(op["type"])
            if opcode in ("parameter", "constant", "tuple",
                          "get-tuple-element", "bitcast", "copy"):
                # copy: XLA's copy-elision/donation removes loop-carry
                # copies at runtime; charging them would bill every scan
                # iteration the full carried state (verified to dominate
                # decode cells spuriously).
                continue
            if opcode == "while":
                trip = 1
                tm = _TRIP_RE.search(op["line"])
                if tm:
                    trip = int(tm.group(1))
                body = _CALLS_RE.search(op["line"])
                cond = _COND_RE.search(op["line"])
                if body and body.group(1) in self.computations:
                    c.add(self.cost(body.group(1)), mult=trip)
                if cond and cond.group(1) in self.computations:
                    c.add(self.cost(cond.group(1)), mult=trip)
                continue
            if opcode == "conditional":
                bm = _BRANCHES_RE.search(op["line"])
                if bm:
                    best = Cost()
                    for b in re.findall(r"%?([\w\.\-]+)", bm.group(1)):
                        if b in self.computations:
                            bc = self.cost(b)
                            if bc.flops >= best.flops:
                                best = bc
                    c.add(best)
                continue
            if opcode == "call":
                cm = _CALLS_RE.search(op["line"])
                if cm and cm.group(1) in self.computations:
                    c.add(self.cost(cm.group(1)))
                continue
            # leaf op: memory traffic model = out + operands.
            # dynamic-update-slice writes only the update in place — charge
            # the update operand, not the full array (otherwise a decode
            # step's KV-cache write would be charged cache_size × layers).
            # The same applies to fusions whose ROOT is a dus (scan ys
            # writes land in such fusions).
            is_dus = opcode == "dynamic-update-slice"
            if opcode == "fusion":
                cm0 = _CALLS_RE.search(op["line"])
                if cm0 and cm0.group(1) in self.computations:
                    inner = self.computations[cm0.group(1)]
                    # in-place fusion: contains a dus as large as the fusion
                    # output (possibly followed by converts/bitcasts)
                    for io in inner:
                        if io["opcode"] == "dynamic-update-slice" and \
                                _shape_bytes(io["type"]) >= out_bytes // 2:
                            is_dus = True
                            break
            if is_dus:
                ops_bytes = self._op_operand_bytes(op)
                c.mem_bytes += max(ops_bytes - out_bytes, 0)
                if opcode == "fusion":
                    cm0 = _CALLS_RE.search(op["line"])
                    if cm0 and cm0.group(1) in self.computations:
                        c.flops += self._flops_only(cm0.group(1))
                continue
            if opcode == "convert":
                # pure dtype conversions are XLA:CPU artifacts — the CPU
                # backend upconverts bf16 dot operands to f32 (whole-KV-cache
                # converts on decode cells). TPU's MXU is natively
                # bf16×bf16→f32, so these ops don't exist on the target.
                continue
            c.mem_bytes += out_bytes + self._op_operand_bytes(op)
            if opcode == "dot":
                c.flops += self._dot_flops(op)
            elif opcode == "fusion":
                cm = _CALLS_RE.search(op["line"])
                if cm and cm.group(1) in self.computations:
                    c.flops += self._flops_only(cm.group(1))
            for kind in COLLECTIVE_KINDS:
                if opcode.startswith(kind):
                    c.coll_bytes[kind] += out_bytes
                    break
        self._cost_cache[comp] = c
        return c


def roofline_report(hlo_text: str, *, model_flops_per_device: float = 0.0,
                    pieces_hint: str = "",
                    hw: HardwareModel = DEFAULT_HW) -> Dict:
    """Per-device roofline terms from a compiled SPMD HLO module."""
    an = HloAnalyzer(hlo_text)
    c = an.cost()
    compute_t = hw.compute_s(c.flops)
    memory_t = hw.memory_s(c.mem_bytes)
    coll_t = hw.collective_s(c.total_coll)
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    dominant = max(terms, key=terms.get)
    bound = max(compute_t, memory_t, coll_t)
    out = {
        "hlo_dot_flops_per_dev": c.flops,
        "hlo_mem_bytes_per_dev": c.mem_bytes,
        "hlo_coll_bytes_per_dev": c.coll_bytes,
        **terms,
        "dominant": dominant,
        "roofline_bound_s": bound,
        "compute_fraction_at_bound": (compute_t / bound) if bound else 0.0,
    }
    if model_flops_per_device:
        out["model_flops_per_dev"] = model_flops_per_device
        out["useful_flops_ratio"] = (model_flops_per_device / c.flops
                                     if c.flops else 0.0)
    return out
