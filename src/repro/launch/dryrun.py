import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell: build the step function,
``.lower()`` with ShapeDtypeStruct inputs, ``.compile()``, and record
memory_analysis / cost_analysis / the HLO-derived roofline terms
(launch/roofline.py). The 512 placeholder host devices exist ONLY here —
the XLA_FLAGS line above precedes every other import by design.

Usage:
    python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all --multi-pod both
Results land in experiments/dryrun/<arch>_<shape>_<mesh>.json.
"""
import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from pathlib import Path  # noqa: E402

import jax          # noqa: E402

from ..configs.base import all_archs, get_arch  # noqa: E402
from .mesh import make_production_mesh          # noqa: E402
from .roofline import HloAnalyzer, roofline_report  # noqa: E402
from . import steps as steps_mod                # noqa: E402

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def model_flops_per_device(cfg, shape, mesh) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) per device; decode counts one
    token per sequence, forward-only shapes count 2·N·D."""
    n_chips = mesh.devices.size
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / n_chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / n_chips
    tokens = shape.global_batch
    return 2.0 * n_active * tokens / n_chips


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             save_hlo: bool = False) -> dict:
    cfg = get_arch(arch_name)
    shape = cfg.shapes()[shape_name]
    mesh_tag = "pod512" if multi_pod else "pod256"
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_tag,
           "status": "error"}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with mesh:
            jf, args, lm = steps_mod.build_step(cfg, shape, mesh)
            lowered = jf.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
            rep = roofline_report(
                hlo, model_flops_per_device=model_flops_per_device(
                    cfg, shape, mesh))
            rec.update({
                "status": "ok",
                "lower_s": round(t_lower, 2),
                "compile_s": round(t_compile, 2),
                "n_devices": int(mesh.devices.size),
                "grad_accum": getattr(jf, "accum", shape.grad_accum),
                "memory": {
                    "argument_bytes_per_dev": mem.argument_size_in_bytes,
                    "output_bytes_per_dev": mem.output_size_in_bytes,
                    "temp_bytes_per_dev": mem.temp_size_in_bytes,
                    "alias_bytes_per_dev": mem.alias_size_in_bytes,
                    "peak_estimate_gib": round(
                        (mem.argument_size_in_bytes +
                         mem.output_size_in_bytes +
                         mem.temp_size_in_bytes -
                         mem.alias_size_in_bytes) / 2**30, 3),
                },
                "cost_analysis_flops_bodyonce": ca.get("flops", 0.0),
                "roofline": rep,
            })
            if save_hlo:
                (OUT_DIR / f"{arch_name}_{shape_name}_{mesh_tag}.hlo.txt"
                 ).write_text(hlo)
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = round(time.time() - t0, 2)
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    out = OUT_DIR / f"{arch_name}_{shape_name}_{mesh_tag}.json"
    out.write_text(json.dumps(rec, indent=2, default=float))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=["on", "off", "both"],
                    default="off")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    archs = list(all_archs()) if args.arch == "all" else [args.arch]
    pods = {"on": [True], "off": [False], "both": [False, True]}[
        args.multi_pod]
    for a in archs:
        cfg = get_arch(a)
        shapes = list(cfg.shapes()) if args.shape == "all" else [args.shape]
        for s in shapes:
            for mp in pods:
                rec = run_cell(a, s, mp, save_hlo=args.save_hlo)
                tag = "ok" if rec["status"] == "ok" else "FAIL"
                extra = ("" if rec["status"] == "ok"
                         else " :: " + rec.get("error", "?"))
                mem = rec.get("memory", {}).get("peak_estimate_gib", "-")
                print(f"[{tag}] {a} {s} {rec['mesh']} wall={rec['wall_s']}s "
                      f"mem/dev={mem}GiB{extra}", flush=True)


if __name__ == "__main__":
    main()
