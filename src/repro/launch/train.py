"""Training launcher — ties together configs, models, planner, pipeline,
checkpointing, fault tolerance.

Small-scale e2e (this container, examples/train_e2e.py uses it directly)::

    python -m repro.launch.train --arch internlm2-1.8b --steps 50 \
        --reduced --global-batch 8 --seq-len 128

Pod-scale usage is identical minus --reduced; mesh selection follows the
device topology (make_production_mesh on real pods, 1-device mesh here).
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeConfig, get_arch
from ..data.pipeline import DataConfig, Pipeline
from ..distributed import planner
from ..distributed.mesh import axis_size, data_axes, make_mesh
from ..models.model import LM
from ..optim.adamw import adamw_init
from ..runtime.checkpoint import CheckpointManager
from ..runtime.fault import RestartPolicy, StepWatchdog
from . import steps as steps_mod
from .mesh import make_production_mesh, make_smoke_mesh


def pick_mesh():
    n = len(jax.devices())
    if n >= 512:
        return make_production_mesh(multi_pod=True)
    if n >= 256:
        return make_production_mesh()
    if n == 1:
        return make_smoke_mesh()
    # generic small mesh: all devices on data
    return make_mesh((n, 1), ("data", "model"))


class Trainer:
    def __init__(self, cfg: ArchConfig, shape: ShapeConfig, *,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 peak_lr: float = 3e-4, total_steps: int = 10000):
        self.cfg, self.shape = cfg, shape
        self.mesh = pick_mesh()
        self.lm = steps_mod.build_lm(cfg, self.mesh)
        fn, self.accum = steps_mod.make_train_step(
            self.lm, shape, self.mesh, peak_lr=peak_lr,
            total_steps=total_steps)
        _, shardings, donate = steps_mod.step_shardings(
            cfg, shape, self.mesh, self.lm)
        self.step_fn = jax.jit(fn, in_shardings=shardings,
                               donate_argnums=donate)
        self.ckpt = (CheckpointManager(ckpt_dir) if ckpt_dir else None)
        self.ckpt_every = ckpt_every
        self.watchdog = StepWatchdog()
        self.metrics_log: list = []

        dp = axis_size(self.mesh, *data_axes(self.mesh)) or 1
        self.pipeline = Pipeline(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
            global_batch=shape.global_batch,
            frontend_tokens=cfg.frontend_tokens if cfg.frontend != "none"
            else 0, d_model=cfg.d_model))

        with self.mesh:
            params = self.lm.init_params(jax.random.PRNGKey(0))
            p_sh = planner.shardings_from(
                planner.params_pspecs(params, self.mesh), self.mesh)
            self.params = jax.device_put(params, p_sh)
            opt = adamw_init(self.params)
            o_sh = planner.shardings_from(planner.opt_pspecs(
                opt, params, self.mesh), self.mesh)
            self.opt = jax.device_put(opt, o_sh)
        self.step = 0
        if self.ckpt and self.ckpt.latest_step() is not None:
            self.restore()

    # ------------------------------------------------------------------
    def restore(self) -> None:
        like = {"params": self.params, "opt": self.opt,
                "cursor": self.pipeline.cursor(), "step": 0}
        step, state = self.ckpt.restore(like)
        self.params = jax.device_put(state["params"], jax.tree.map(
            lambda x: x.sharding, self.params))
        self.opt = jax.device_put(state["opt"], jax.tree.map(
            lambda x: x.sharding, self.opt))
        self.pipeline.restore(jax.tree.map(int, state["cursor"]))
        self.step = int(state["step"])

    def save(self, blocking: bool = False) -> None:
        if not self.ckpt:
            return
        self.ckpt.save(self.step, {
            "params": self.params, "opt": self.opt,
            "cursor": self.pipeline.cursor(), "step": self.step,
        }, blocking=blocking)

    # ------------------------------------------------------------------
    def run(self, n_steps: int, log_every: int = 10) -> Dict[str, Any]:
        with self.mesh:
            while self.step < n_steps:
                batch = next(self.pipeline)
                args = [self.params, self.opt,
                        jnp.asarray(batch["tokens"])]
                if "frontend" in batch:
                    args.append(jnp.asarray(batch["frontend"],
                                            jnp.bfloat16))
                self.watchdog.start()
                self.params, self.opt, metrics = self.step_fn(*args)
                jax.block_until_ready(metrics["loss"])
                straggled = self.watchdog.stop()
                self.step += 1
                rec = {"step": self.step,
                       "loss": float(metrics["loss"]),
                       "gnorm": float(metrics["gnorm"]),
                       "straggled": straggled}
                self.metrics_log.append(rec)
                if self.step % log_every == 0 or self.step == 1:
                    print(f"step {self.step:5d} loss {rec['loss']:.4f} "
                          f"gnorm {rec['gnorm']:.3f} "
                          f"({self.watchdog.median()*1000:.0f} ms/med)",
                          flush=True)
                if self.ckpt and self.step % self.ckpt_every == 0:
                    self.save()
            if self.ckpt:
                self.save(blocking=True)
        if not self.metrics_log:
            # resumed at/past n_steps: nothing to do (restart safety)
            return {"final_loss": float("nan"), "steps": self.step,
                    "median_step_s": 0.0}
        return {"final_loss": self.metrics_log[-1]["loss"],
                "steps": self.step,
                "median_step_s": self.watchdog.median()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--global-batch", type=int, default=0)
    ap.add_argument("--seq-len", type=int, default=0)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    import dataclasses as dc
    shape = ShapeConfig(
        "custom", "train",
        seq_len=args.seq_len or 4096,
        global_batch=args.global_batch or 256,
        grad_accum=args.grad_accum)
    tr = Trainer(cfg, shape, ckpt_dir=args.ckpt_dir or None,
                 total_steps=args.steps, peak_lr=args.lr)
    policy = RestartPolicy(max_restarts=3)
    restarts = policy.run_with_restarts(
        lambda: tr.run(args.steps),
        on_restart=lambda n: (print(f"[restart {n}] restoring"),
                              tr.restore() if tr.ckpt else None))
    print(f"done: final loss {tr.metrics_log[-1]['loss']:.4f}, "
          f"{restarts} restarts")


if __name__ == "__main__":
    main()
