"""Serving launcher — batched prefill + decode loop with continuous
batching slots.

Small-scale e2e (examples/serve_batched.py)::

    python -m repro.launch.serve --arch internlm2-1.8b --reduced \
        --requests 8 --max-new 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeConfig, get_arch
from ..distributed import planner
from ..models.model import LM
from . import steps as steps_mod
from .train import pick_mesh


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Fixed-slot continuous batching: up to ``slots`` concurrent requests
    share one KV cache; finished requests free their slot for the queue."""

    def __init__(self, cfg: ArchConfig, *, slots: int = 8,
                 context: int = 512, window: int = 0):
        self.cfg = cfg
        self.mesh = pick_mesh()
        self.lm = steps_mod.build_lm(cfg, self.mesh)
        self.context = context
        self.window = window
        with self.mesh:
            params = self.lm.init_params(jax.random.PRNGKey(0))
            p_sh = planner.shardings_from(
                planner.params_pspecs(params, self.mesh), self.mesh)
            self.params = jax.device_put(params, p_sh)
            self.cache = self.lm.init_cache(
                slots, context, window=window,
                src_len=cfg.frontend_tokens if cfg.is_encdec else 0)
        self.slots: List[Optional[Request]] = [None] * slots
        self._decode = jax.jit(
            lambda p, c, t: self.lm.decode_step(p, c, t,
                                                window=self.window))

    def _feed_tokens(self) -> np.ndarray:
        toks = np.zeros(len(self.slots), np.int32)
        for i, r in enumerate(self.slots):
            if r is None or r.done:
                continue
            pos = int(np.asarray(self.cache["pos"])[i])
            if pos < len(r.prompt):
                toks[i] = r.prompt[pos]
            elif r.out:
                toks[i] = r.out[-1]
        return toks

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        queue = list(requests)
        with self.mesh:
            while queue or any(r is not None and not r.done
                               for r in self.slots):
                for i in range(len(self.slots)):
                    if (self.slots[i] is None or self.slots[i].done) \
                            and queue:
                        self.slots[i] = queue.pop(0)
                toks = jnp.asarray(self._feed_tokens())
                logits, self.cache = self._decode(self.params, self.cache,
                                                  toks)
                nxt = np.asarray(jnp.argmax(logits, -1))
                pos = np.asarray(self.cache["pos"])
                for i, r in enumerate(self.slots):
                    if r is None or r.done:
                        continue
                    if pos[i] >= len(r.prompt):      # generation phase
                        r.out.append(int(nxt[i]))
                        if len(r.out) >= r.max_new or \
                                pos[i] >= self.context - 1:
                            r.done = True
        return {r.rid: r.out for r in requests}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--context", type=int, default=256)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size,
                                        rng.integers(4, 17),
                                        dtype=np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    srv = Server(cfg, slots=args.slots, context=args.context)
    t0 = time.time()
    out = srv.run(reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    print(f"served {len(out)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
