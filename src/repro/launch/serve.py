"""Serving launcher — batched prefill + decode loop with continuous
batching slots, plus the sparse-kernel serving fast path.

Two servers live here. :class:`Server` is the LM decode loop (fixed
continuous-batching slots over one KV cache). :class:`SparseKernelServer`
is the paper-side analog (ISSUE 10): a request queue over ONE lowered
sparse statement — the sparse operand (attention band mask, MoE dispatch
matrix) is frozen at construction, and each ``step`` drains the queue
into one bucketized batched SpMM (``core.lower.lower_batched``), so
steady-state serving pays zero plan/shard/runner recompilation.

Small-scale e2e (examples/serve_batched.py)::

    python -m repro.launch.serve --arch internlm2-1.8b --reduced \
        --requests 8 --max-new 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeConfig, get_arch
from ..distributed import planner
from ..models.model import LM
from ..runtime import telemetry
from . import steps as steps_mod
from .train import pick_mesh


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Fixed-slot continuous batching: up to ``slots`` concurrent requests
    share one KV cache; finished requests free their slot for the queue."""

    def __init__(self, cfg: ArchConfig, *, slots: int = 8,
                 context: int = 512, window: int = 0):
        self.cfg = cfg
        self.mesh = pick_mesh()
        self.lm = steps_mod.build_lm(cfg, self.mesh)
        self.context = context
        self.window = window
        with self.mesh:
            params = self.lm.init_params(jax.random.PRNGKey(0))
            p_sh = planner.shardings_from(
                planner.params_pspecs(params, self.mesh), self.mesh)
            self.params = jax.device_put(params, p_sh)
            self.cache = self.lm.init_cache(
                slots, context, window=window,
                src_len=cfg.frontend_tokens if cfg.is_encdec else 0)
        self.slots: List[Optional[Request]] = [None] * slots
        self._decode = jax.jit(
            lambda p, c, t: self.lm.decode_step(p, c, t,
                                                window=self.window))

    def _feed_tokens(self) -> np.ndarray:
        toks = np.zeros(len(self.slots), np.int32)
        for i, r in enumerate(self.slots):
            if r is None or r.done:
                continue
            pos = int(np.asarray(self.cache["pos"])[i])
            if pos < len(r.prompt):
                toks[i] = r.prompt[pos]
            elif r.out:
                toks[i] = r.out[-1]
        return toks

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        queue = list(requests)
        with self.mesh:
            while queue or any(r is not None and not r.done
                               for r in self.slots):
                for i in range(len(self.slots)):
                    if (self.slots[i] is None or self.slots[i].done) \
                            and queue:
                        self.slots[i] = queue.pop(0)
                toks = jnp.asarray(self._feed_tokens())
                logits, self.cache = self._decode(self.params, self.cache,
                                                  toks)
                nxt = np.asarray(jnp.argmax(logits, -1))
                pos = np.asarray(self.cache["pos"])
                for i, r in enumerate(self.slots):
                    if r is None or r.done:
                        continue
                    if pos[i] >= len(r.prompt):      # generation phase
                        r.out.append(int(nxt[i]))
                        if len(r.out) >= r.max_new or \
                                pos[i] >= self.context - 1:
                            r.done = True
        return {r.rid: r.out for r in requests}


@dataclasses.dataclass
class KernelRequest:
    """One queued sparse-kernel request: a dense RHS vector (or fixed-width
    panel) against the server's frozen sparse operand."""
    rid: int
    rhs: np.ndarray
    t_submit: float
    result: Optional[np.ndarray] = None
    latency_s: Optional[float] = None


class SparseKernelServer:
    """Request batching over one lowered sparse statement.

    ``submit`` enqueues a per-request RHS; ``step`` drains up to
    ``max_batch`` requests into one ``run_many`` call — requests share
    the plan, the packed sparse shards, and (per batch bucket) the jitted
    runner. Queue depth, per-request latency, and SLO attainment land in
    ``METRICS`` under ``serve.*`` (occupancy/padding come from
    ``BatchedKernel.run_many`` itself), rendered by
    ``launch/report.py --telemetry`` and captured in
    ``BENCH_serving.json``.

    ``schedule`` / ``buckets`` / ``mesh`` pass straight through to
    :func:`repro.core.lower.lower_batched`; ``slo_ms`` arms the
    ``serve.slo_violations`` counter and the attainment stat.
    """

    def __init__(self, stmt, machine, schedule: Any = None, *,
                 max_batch: int = 8, buckets=None, slo_ms: float = None,
                 mesh: Any = None, jit: bool = True):
        from ..core.cache import BATCH_BUCKETS
        from ..core.lower import BatchedKernel
        self.kernel = BatchedKernel(
            stmt, machine, schedule,
            buckets=BATCH_BUCKETS if buckets is None else buckets,
            jit=jit, mesh=mesh).warm(max_batch)
        self.max_batch = int(max_batch)
        self.slo_ms = slo_ms
        self.queue: "deque[KernelRequest]" = deque()
        self.done: Dict[int, KernelRequest] = {}
        self.latencies_ms: List[float] = []
        self._next_rid = 0

    def submit(self, rhs) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(KernelRequest(rid, np.asarray(rhs, np.float32),
                                        time.perf_counter()))
        telemetry.METRICS.gauge("serve.queue_depth", float(len(self.queue)))
        return rid

    def step(self) -> int:
        """Serve one batch off the queue; returns how many were served."""
        if not self.queue:
            return 0
        take = min(self.max_batch, len(self.queue))
        batch = [self.queue.popleft() for _ in range(take)]
        outs = self.kernel.run_many([r.rhs for r in batch])
        now = time.perf_counter()
        for r, y in zip(batch, outs):
            r.result = y
            r.latency_s = now - r.t_submit
            ms = r.latency_s * 1e3
            self.latencies_ms.append(ms)
            telemetry.METRICS.observe("serve.latency_ms", ms)
            if self.slo_ms is not None and ms > self.slo_ms:
                telemetry.METRICS.counter("serve.slo_violations")
            self.done[r.rid] = r
        telemetry.METRICS.gauge("serve.queue_depth", float(len(self.queue)))
        return take

    def drain(self) -> int:
        served = 0
        while self.queue:
            served += self.step()
        return served

    def result(self, rid: int) -> np.ndarray:
        return self.done[rid].result

    def stats(self) -> Dict[str, float]:
        """p50/p99 latency + SLO attainment over everything served."""
        lat = np.asarray(self.latencies_ms, np.float64)
        if lat.size == 0:
            return {"served": 0}
        out = {"served": int(lat.size),
               "p50_ms": float(np.percentile(lat, 50)),
               "p99_ms": float(np.percentile(lat, 99)),
               "max_ms": float(lat.max())}
        if self.slo_ms is not None:
            out["slo_ms"] = float(self.slo_ms)
            out["slo_attainment"] = float((lat <= self.slo_ms).mean())
        return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--context", type=int, default=256)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size,
                                        rng.integers(4, 17),
                                        dtype=np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    srv = Server(cfg, slots=args.slots, context=args.context)
    t0 = time.time()
    out = srv.run(reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    print(f"served {len(out)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
