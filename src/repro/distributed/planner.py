"""Sharding planner — PartitionSpec trees for params / optimizer / batch /
cache, rule-based by leaf path + shape.

Layout (DESIGN.md §5): FSDP × TP. Every 2-D weight is sharded over both the
'data' axis (FSDP — weights gathered per layer under scan) and the 'model'
axis (megatron TP — contraction-parallel dim). Stacked layer/group leading
axes are never sharded. Every rule checks divisibility and falls back to
replication for that dim, so one planner covers all ten archs (56-head
llava and 4-head xlstm included) on any mesh.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .mesh import axis_size, data_axes


def _div(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    ax = (axes,) if isinstance(axes, str) else tuple(axes)
    s = axis_size(mesh, *ax)
    return s > 0 and dim % s == 0


def _maybe(dim: int, mesh: Mesh, axes):
    return axes if axes is not None and _div(dim, mesh, axes) else None


# weight rules: (name match, trailing-rank, per-dim logical axes)
# logical 'fsdp' = data axes, 'tp' = model axis.
_W2_RULES = [
    # name fragment        -> (in_axis, out_axis) for (in, out) matrices
    ("unembed", ("fsdp", "tp")),
    ("embed", ("tp", "fsdp")),      # (vocab, d)
    ("wq", ("fsdp", "tp")),
    ("wk", ("fsdp", "tp")),
    ("wv", ("fsdp", "tp")),
    ("wo_gate", ("fsdp", "tp")),
    ("wo", ("tp", "fsdp")),         # (proj_out, d)
    ("wg", ("fsdp", "tp")),
    ("wu", ("fsdp", "tp")),
    ("wd", ("tp", "fsdp")),
    ("wx", ("fsdp", "tp")),
    ("wz", ("fsdp", "tp")),
    ("wB", ("fsdp", None)),
    ("wC", ("fsdp", None)),
    ("wdt", ("fsdp", None)),
    ("wi", ("fsdp", None)),
    ("wf", ("fsdp", None)),
    ("proj", ("fsdp", "tp")),
    ("router", ("fsdp", None)),
]


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def _resolve(axis: Optional[str], mesh: Mesh, serve: bool = False):
    if axis == "fsdp":
        if serve:
            # §Perf iteration 6: FSDP re-gathers weights on EVERY forward —
            # right for training (amortized against optimizer state), wrong
            # for serving where it re-pays the gather per decoded token.
            # Serving params are TP-only (replicated across data).
            return None
        da = data_axes(mesh)
        return da if da else None
    if axis == "tp":
        return "model" if "model" in mesh.axis_names else None
    return axis


def param_spec_for(path, leaf, mesh: Mesh, serve: bool = False) -> P:
    name = _leaf_name(path)
    shape = leaf.shape
    rank = len(shape)
    if rank == 0:
        return P()
    base = name.rsplit("/", 1)[-1]
    # match trailing-2 dims for matrices; experts get a leading E rule
    rule = None
    for frag, axes in _W2_RULES:
        if base == frag or base.startswith(frag):
            rule = axes
            break
    if rule is None or rank < 2:
        return P(*([None] * rank))
    in_ax = _resolve(rule[0], mesh, serve)
    out_ax = _resolve(rule[1], mesh, serve)
    lead = rank - 2
    spec = [None] * rank
    # MoE expert stacks: (..., E, d, f) — shard E on model (expert
    # parallelism) and d on fsdp; drops TP on f in exchange for EP.
    # Expert weights stay d-sharded EVEN for serving (weight-stationary):
    # llama4-scout's 96B of experts cannot replicate across data at
    # 16 GB/chip, and GSPMD reduces the small (g,e,C,f) partial outputs
    # instead of gathering the weights.
    moe_expert = "moe" in name and base in ("wg", "wu", "wd")
    if moe_expert:
        e_dim = lead - 1 if lead >= 1 else None
        if e_dim is not None and _div(shape[e_dim], mesh, "model") \
                and "model" in mesh.axis_names:
            spec[e_dim] = "model"
        fs = _resolve("fsdp", mesh, serve=False)
        d_pos = rank - 2 if base in ("wg", "wu") else rank - 1
        if fs is not None and _div(shape[d_pos], mesh, fs):
            spec[d_pos] = fs
        return P(*spec)
    spec[rank - 2] = _maybe(shape[rank - 2], mesh, in_ax)
    spec[rank - 1] = _maybe(shape[rank - 1], mesh, out_ax)
    # avoid duplicate axis use within one spec
    if spec[rank - 2] == spec[rank - 1]:
        spec[rank - 1] = None
    return P(*spec)


def params_pspecs(abstract_params, mesh: Mesh, serve: bool = False):
    """PartitionSpec tree for a params pytree (abstract or concrete).

    ``serve=True`` selects the TP-only layout (no FSDP weight regather per
    forward — see _resolve)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec_for(path, leaf, mesh, serve),
        abstract_params)


def opt_pspecs(abstract_opt, abstract_params, mesh: Mesh):
    """Optimizer state mirrors the param layout (step scalar replicated)."""
    pspec = params_pspecs(abstract_params, mesh)
    return type(abstract_opt)(step=P(), mu=pspec,
                              nu=jax.tree.map(lambda s: s, pspec))


def batch_pspec(mesh: Mesh, global_batch: int) -> P:
    da = data_axes(mesh)
    if da and global_batch % axis_size(mesh, *da) == 0:
        return P(da, None)
    return P(None, None)


def frontend_pspec(mesh: Mesh, global_batch: int) -> P:
    da = data_axes(mesh)
    if da and global_batch % axis_size(mesh, *da) == 0:
        return P(da, None, None)
    return P(None, None, None)


def cache_pspecs(abstract_cache, mesh: Mesh, batch: int):
    """Cache tree specs: batch on data axes when divisible; attention-cache
    sequence dim on 'model' (plus data axes when batch can't shard — the
    long_500k sequence-parallel layout); SSM state heads on 'model'."""
    da = data_axes(mesh)
    batch_ok = bool(da) and batch % axis_size(mesh, *da) == 0 and batch > 1

    def spec(path, leaf):
        name = _leaf_name(path).rsplit("/", 1)[-1]
        shape = leaf.shape
        rank = len(shape)
        if name == "pos" or rank <= 1:
            return P(*([None] * rank))
        if name in ("k", "v", "shared_k", "shared_v", "enc_k", "enc_v"):
            # (..., B, S, H, hd)
            sp = [None] * rank
            b_dim, s_dim = rank - 4, rank - 3
            if batch_ok:
                sp[b_dim] = da
                if _div(shape[s_dim], mesh, "model") and \
                        "model" in mesh.axis_names:
                    sp[s_dim] = "model"
            else:
                seq_axes = tuple(da) + (("model",) if "model" in
                                        mesh.axis_names else ())
                if seq_axes and _div(shape[s_dim], mesh, seq_axes):
                    sp[s_dim] = seq_axes
            return P(*sp)
        if name.startswith("ssm") or name.startswith("tail"):
            # (G, [gs], B, H, N, P) states — trailing 4 dims fixed
            sp = [None] * rank
            b_dim, h_dim = rank - 4, rank - 3
            if batch_ok and rank >= 4:
                sp[b_dim] = da
            if rank >= 4 and _div(shape[h_dim], mesh, "model") and \
                    "model" in mesh.axis_names:
                sp[h_dim] = "model"
            return P(*sp)
        if name.startswith("x"):
            # xLSTM states: (G, B, ...) — mLSTM (G,B,H,hd,hd+1), sLSTM
            # (G,B,2,d); batch is always dim 1, heads dim 2 only for rank≥5
            sp = [None] * rank
            if batch_ok and rank >= 2 and _div(shape[1], mesh, da):
                sp[1] = da
            if rank >= 5 and _div(shape[2], mesh, "model") and \
                    "model" in mesh.axis_names:
                sp[2] = "model"
            return P(*sp)
        return P(*([None] * rank))

    return jax.tree_util.tree_map_with_path(spec, abstract_cache)


def shardings_from(pspec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def sparse_pspecs(sharded_tensors, axis: str = "x"):
    """PartitionSpec maps for lowered sparse-kernel shards (executor.py).

    Stacked shard arrays (leading color axis, any kind but ``replicated``)
    shard over the machine ``axis``; replicated operands broadcast with
    ``P()``. Returns ``{tensor_name: {array_name: P}}`` so shard_map
    builders stay format-general — the array set differs per format
    (pos/crd levels, COO dim columns, densified-root views) but the
    placement rule does not."""
    out = {}
    for name, sh in sharded_tensors.items():
        kind = getattr(sh, "kind", "replicated")
        spec = P() if kind == "replicated" else P(axis)
        out[name] = {arr_name: spec for arr_name in sh.arrays}
    return out
