from . import collectives, mesh, planner

__all__ = ["collectives", "mesh", "planner"]
