"""Mesh utilities — the bridge between the paper's abstract Machine grids
and `jax.sharding.Mesh`.

`machine_to_mesh` realizes a TDN Machine as a JAX mesh (axis names map
one-to-one), so the same Machine object drives both the sparse-kernel
partition plans and the SPMD executor. All mesh constructors are FUNCTIONS
— importing this module never touches jax device state (the dry-run must
set XLA_FLAGS first).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from ..compat import make_mesh_compat
from ..core.tdn import Machine


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    want = int(np.prod(np.asarray(shape, dtype=np.int64))) if len(shape) else 1
    have = len(jax.devices())
    if want > have:
        raise ValueError(
            f"machine grid {tuple(int(s) for s in shape)} "
            f"({'×'.join(str(int(s)) for s in shape)} = {want} pieces) "
            f"exceeds the {have} visible device(s); shrink the grid or "
            f"expose more devices (e.g. XLA_FLAGS="
            f"--xla_force_host_platform_device_count={want} on CPU)")
    return make_mesh_compat(shape, axes)


def machine_to_mesh(machine: Machine) -> Mesh:
    return make_mesh([d.size for d in machine.dims],
                     [d.name for d in machine.dims])


def mesh_to_machine(mesh: Mesh) -> Machine:
    return Machine(*[(n, s) for n, s in
                     zip(mesh.axis_names, mesh.devices.shape)])


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes used for data parallelism ('pod' composes with 'data')."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh: Mesh, *axes: str) -> int:
    s = 1
    for a in axes:
        if a in mesh.axis_names:
            s *= mesh.devices.shape[mesh.axis_names.index(a)]
    return s
