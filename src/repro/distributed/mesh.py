"""Mesh utilities — the bridge between the paper's abstract Machine grids
and `jax.sharding.Mesh`.

`machine_to_mesh` realizes a TDN Machine as a JAX mesh (axis names map
one-to-one), so the same Machine object drives both the sparse-kernel
partition plans and the SPMD executor. All mesh constructors are FUNCTIONS
— importing this module never touches jax device state (the dry-run must
set XLA_FLAGS first).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from ..compat import make_mesh_compat
from ..core.tdn import Machine


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    want = int(np.prod(np.asarray(shape, dtype=np.int64))) if len(shape) else 1
    have = len(jax.devices())
    if want > have:
        raise ValueError(
            f"machine grid {tuple(int(s) for s in shape)} "
            f"({'×'.join(str(int(s)) for s in shape)} = {want} pieces) "
            f"exceeds the {have} visible device(s); shrink the grid or "
            f"expose more devices (e.g. XLA_FLAGS="
            f"--xla_force_host_platform_device_count={want} on CPU)")
    return make_mesh_compat(shape, axes)


def machine_to_mesh(machine: Machine) -> Mesh:
    return make_mesh([d.size for d in machine.dims],
                     [d.name for d in machine.dims])


def mesh_to_machine(mesh: Mesh) -> Machine:
    return Machine(*[(n, s) for n, s in
                     zip(mesh.axis_names, mesh.devices.shape)])


def resize_machine(machine: Machine, axis: str, size: int) -> Machine:
    """A new Machine with ``axis`` resized to ``size`` — the mesh-as-data
    primitive: machines are values, so elastic resize is construction, not
    mutation of trace state."""
    names = [d.name for d in machine.dims]
    if axis not in names:
        raise ValueError(f"machine has no axis {axis!r} (axes: {names})")
    if size < 1:
        raise ValueError(f"axis size must be >= 1, got {size}")
    return Machine(*[(d.name, size if d.name == axis else d.size)
                     for d in machine.dims])


def shrink_machine(machine: Machine, axis: Optional[str] = None,
                   by: int = 1) -> Machine:
    """The P→P−1 device-loss resize: shrink ``axis`` (default: the first
    dimension) by ``by`` pieces."""
    axis = axis if axis is not None else machine.dims[0].name
    cur = {d.name: d.size for d in machine.dims}.get(axis)
    if cur is None:
        raise ValueError(f"machine has no axis {axis!r}")
    if cur - by < 1:
        raise ValueError(
            f"cannot shrink axis {axis!r} from {cur} by {by}: no pieces left")
    return resize_machine(machine, axis, cur - by)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes used for data parallelism ('pod' composes with 'data')."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh: Mesh, *axes: str) -> int:
    s = 1
    for a in axes:
        if a in mesh.axis_names:
            s *= mesh.devices.shape[mesh.axis_names.index(a)]
    return s
