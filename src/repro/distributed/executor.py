"""SPMD executor for lowered sparse kernels — the shard_map backend.

`core.lower` runs kernels through a vmap simulation (single-process
correctness). This module runs the SAME leaf functions under
`jax.shard_map` on a real mesh: the stacked shard arrays' leading color
axis is sharded over the machine axis, replicated operands broadcast, and
the paper's ``communicate`` becomes explicit collectives
(distributed/collectives.py). The multi-device test suite launches this
under ``--xla_force_host_platform_device_count`` to prove the distributed
loop structure is coherent without real hardware.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..core.cache import LRUCache, avals_key
from ..core.lower import LoweredKernel
from ..core.tdn import Machine
from ..kernels import ref as K
from ..runtime import telemetry
from ..kernels.layout import (pack_mat_inner_blocks, pack_mat_row_blocks,
                              pack_rowwindow_blocks, pack_vec_blocks)
from .mesh import machine_to_mesh

# Compiled shard_map executables, keyed like core.lower's runner cache
# (builder name, mesh, axis, static trace constants, shard avals).
# Re-building the SPMD executor after a re-lower then reuses the jitted
# callable — jax's compilation cache hits instead of re-tracing the
# collective program.
_SPMD_RUN_CACHE = LRUCache(capacity=64)
SPMD_RUN_STATS = _SPMD_RUN_CACHE.stats


def set_spmd_cache_capacity(capacity: int) -> None:
    _SPMD_RUN_CACHE.set_capacity(capacity)


def clear_spmd_cache() -> None:
    _SPMD_RUN_CACHE.clear()


def _mesh_key(mesh: Mesh):
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(d.id for d in mesh.devices.flat))


def _spmd_runner(name, mesh, axis, static, arrays, build):
    """Return the jitted shard_map executable for a builder, reusing a
    cached one when (builder, mesh, axis, statics, shard avals) match."""
    key = (name, _mesh_key(mesh), axis, tuple(static), avals_key(arrays))

    def _jit_build():
        with telemetry.span("lower.jit", leaf=name, spmd=True):
            return jax.jit(build())

    return _SPMD_RUN_CACHE.get_or_build(key, _jit_build)


def _assemble_vals(total, out_vals, arrays, vals_bounds):
    """Host assembly of per-color leaf VALUE outputs into the global value
    region (scalar slots or (br, bc) tiles alike). Ordered walks fill by
    value-space interval; transpose-walked shards carry a ``val_idx``
    permutation in their packed level arrays and scatter home by stored
    position — the builders never ask which format produced the walk."""
    flat = np.zeros((total,) + out_vals.shape[2:], np.float32)
    cnt = np.asarray(arrays["nnz_count"])
    if "val_idx" in arrays:
        vi = np.asarray(arrays["val_idx"])
        for p in range(out_vals.shape[0]):
            k = int(cnt[p])
            flat[vi[p, :k]] = out_vals[p, :k]
        return flat
    for p in range(out_vals.shape[0]):
        lo = int(vals_bounds[p, 0])
        flat[lo: lo + cnt[p]] = out_vals[p, : cnt[p]]
    return flat


def spmv_rows_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    """Build the shard_map SpMV for a rows-lowered kernel. Returns a
    callable () -> y executing on ``mesh``."""
    B = kernel.shards[kernel.stmt.rhs.accesses()[0].tensor.name]
    c = kernel.shards[kernel.stmt.rhs.accesses()[1].tensor.name]
    n = kernel.stmt.lhs.tensor.shape[0]
    a = B.arrays
    max_rows = B.meta["max_rows"]

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P(), P(axis)),
            out_specs=P(axis))
        def run(pos, crd, vals, cvec, row_count):
            # leading shard axis has local extent 1 inside shard_map
            y = K.leaf_spmv_rows(pos[0], crd[0], vals[0], cvec)
            return y[None]
        return run

    run = _spmd_runner(
        "spmv_rows", mesh, axis, (),
        (a["pos1"], a["crd1"], a["vals"], c.arrays["vals"], a["row_count"]),
        build)

    def call():
        y_blocks = run(jnp.asarray(a["pos1"]), jnp.asarray(a["crd1"]),
                       jnp.asarray(a["vals"]), jnp.asarray(c.arrays["vals"]),
                       jnp.asarray(a["row_count"]))
        # assemble global output (disjoint row blocks)
        out = np.zeros(n, np.float32)
        rb = np.asarray(a["row_start"])
        cnt = np.asarray(a["row_count"])
        yb = np.asarray(y_blocks)
        for p in range(yb.shape[0]):
            out[rb[p]: rb[p] + cnt[p]] = yb[p, : cnt[p]]
        return out

    return call


def spmv_nnz_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    """Non-zero strategy under shard_map: every shard computes a partial
    over the FULL output range, reduced with psum — the explicit form of
    the paper's "communication to reduce into the output" (§II-D)."""
    B = kernel.shards[kernel.stmt.rhs.accesses()[0].tensor.name]
    c = kernel.shards[kernel.stmt.rhs.accesses()[1].tensor.name]
    n = kernel.stmt.lhs.tensor.shape[0]
    a = B.arrays

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P()),
            out_specs=P())
        def run(rows, cols, vals, cvec):
            y = K.leaf_spmv_nnz(rows[0], cols[0], vals[0], cvec, n)
            return jax.lax.psum(y, axis_name=axis)
        return run

    run = _spmd_runner(
        "spmv_nnz", mesh, axis, (n,),
        (a["dim0"], a["dim1"], a["vals"], c.arrays["vals"]), build)

    def call():
        return np.asarray(run(
            jnp.asarray(a["dim0"]), jnp.asarray(a["dim1"]),
            jnp.asarray(a["vals"]), jnp.asarray(c.arrays["vals"])))

    return call


def spmm_rows_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    """Row-based SpMM: each shard computes its row block against the
    replicated dense matrix (paper's SpMM algorithm, §VI-A)."""
    Bacc, Cacc = kernel.stmt.rhs.accesses()
    B = kernel.shards[Bacc.tensor.name]
    C = kernel.shards[Cacc.tensor.name]
    n, J = kernel.stmt.lhs.tensor.shape
    a = B.arrays

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P()),
            out_specs=P(axis))
        def run(pos, crd, vals, Cm):
            return K.leaf_spmm_rows(pos[0], crd[0], vals[0], Cm)[None]
        return run

    run = _spmd_runner(
        "spmm_rows", mesh, axis, (),
        (a["pos1"], a["crd1"], a["vals"], C.arrays["vals"]), build)

    def call():
        yb = np.asarray(run(jnp.asarray(a["pos1"]), jnp.asarray(a["crd1"]),
                            jnp.asarray(a["vals"]),
                            jnp.asarray(C.arrays["vals"])))
        out = np.zeros((n, J), np.float32)
        rs, cnt = np.asarray(a["row_start"]), np.asarray(a["row_count"])
        for p in range(yb.shape[0]):
            out[rs[p]: rs[p] + cnt[p]] = yb[p, : cnt[p]]
        return out

    return call


def sddmm_nnz_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    """Non-zero based SDDMM: equal-nnz COO shards, dense factors
    replicated; outputs stay position-aligned (no reduction needed — the
    output pattern equals the input pattern, paper §V-B)."""
    accs = kernel.stmt.rhs.accesses()
    B = kernel.shards[accs[0].tensor.name]
    C = kernel.shards[accs[1].tensor.name]
    D = kernel.shards[accs[2].tensor.name]
    a = B.arrays

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P(), P()),
            out_specs=P(axis))
        def run(rows, cols, vals, Cm, Dm):
            return K.leaf_sddmm_nnz(rows[0], cols[0], vals[0], Cm, Dm)[None]
        return run

    run = _spmd_runner(
        "sddmm_nnz", mesh, axis, (),
        (a["dim0"], a["dim1"], a["vals"], C.arrays["vals"],
         D.arrays["vals"]), build)

    def call():
        out_vals = np.asarray(run(
            jnp.asarray(a["dim0"]), jnp.asarray(a["dim1"]),
            jnp.asarray(a["vals"]), jnp.asarray(C.arrays["vals"]),
            jnp.asarray(D.arrays["vals"])))
        Bt = accs[0].tensor
        return _assemble_vals(Bt.nnz, out_vals, a,
                              kernel.plans[Bt.name].vals_bounds)

    return call


def spmm_nnz_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    """Non-zero SpMM under shard_map: full-extent partials + psum. Uses
    GLOBAL row ids, so it is format-general — CSC's column-ordered position
    space works unchanged (no row-window locality to exploit)."""
    from .planner import sparse_pspecs
    Bacc, Cacc = kernel.stmt.rhs.accesses()
    B = kernel.shards[Bacc.tensor.name]
    C = kernel.shards[Cacc.tensor.name]
    n = kernel.stmt.lhs.tensor.shape[0]
    a = B.arrays
    sp = sparse_pspecs({"B": B, "C": C}, axis)

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(sp["B"]["dim0"], sp["B"]["dim1"], sp["B"]["vals"],
                      sp["C"]["vals"]),
            out_specs=P())
        def run(rows, cols, vals, Cm):
            y = K.leaf_spmm_nnz(rows[0], cols[0], vals[0], Cm, n)
            return jax.lax.psum(y, axis_name=axis)
        return run

    run = _spmd_runner(
        "spmm_nnz", mesh, axis, (n,),
        (a["dim0"], a["dim1"], a["vals"], C.arrays["vals"]), build)

    def call():
        return np.asarray(run(
            jnp.asarray(a["dim0"]), jnp.asarray(a["dim1"]),
            jnp.asarray(a["vals"]), jnp.asarray(C.arrays["vals"])))

    return call


def sddmm_rows_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    """Row-based SDDMM under shard_map: B row shard (CSR convention — any
    row-partitionable format materializes to it) + C row block local, D
    replicated; per-shard output vals assembled by value-space bounds."""
    from .planner import sparse_pspecs
    accs = kernel.stmt.rhs.accesses()
    B = kernel.shards[accs[0].tensor.name]
    C = kernel.shards[accs[1].tensor.name]
    D = kernel.shards[accs[2].tensor.name]
    Bt = accs[0].tensor
    a = B.arrays
    sp = sparse_pspecs({"B": B, "C": C, "D": D}, axis)

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(sp["B"]["pos1"], sp["B"]["crd1"], sp["B"]["vals"],
                      sp["C"]["vals"], sp["D"]["vals"]),
            out_specs=P(axis))
        def run(pos, crd, vals, Cl, Dm):
            return K.leaf_sddmm_rows(pos[0], crd[0], vals[0], Cl[0],
                                     Dm)[None]
        return run

    run = _spmd_runner(
        "sddmm_rows", mesh, axis, (),
        (a["pos1"], a["crd1"], a["vals"], C.arrays["vals"],
         D.arrays["vals"]), build)

    def call():
        out_vals = np.asarray(run(
            jnp.asarray(a["pos1"]), jnp.asarray(a["crd1"]),
            jnp.asarray(a["vals"]), jnp.asarray(C.arrays["vals"]),
            jnp.asarray(D.arrays["vals"])))
        return _assemble_vals(Bt.nnz, out_vals, a,
                              kernel.plans[Bt.name].vals_bounds)

    return call


def bcsr_spmv_rows_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    """Direct blocked SpMV under shard_map: each color's shard carries
    (br, bc) value tiles over its block-row window; the dense vector is
    broadcast pre-packed into column blocks. Disjoint block-aligned row
    windows assemble without reduction."""
    B = kernel.shards[kernel.stmt.rhs.accesses()[0].tensor.name]
    c = kernel.shards[kernel.stmt.rhs.accesses()[1].tensor.name]
    n = kernel.stmt.lhs.tensor.shape[0]
    a = B.arrays
    c_blk = pack_vec_blocks(np.asarray(c.arrays["vals"]),
                            int(B.meta["grid_cols"]), int(B.meta["bc"]))

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P()),
            out_specs=P(axis))
        def run(pos, crd, tiles, cb):
            return K.leaf_bcsr_spmv_rows(pos[0], crd[0], tiles[0], cb)[None]
        return run

    run = _spmd_runner(
        "bcsr_spmv_rows", mesh, axis, (),
        (a["pos1"], a["crd1"], a["vals"], c_blk), build)

    def call():
        yb = np.asarray(run(jnp.asarray(a["pos1"]), jnp.asarray(a["crd1"]),
                            jnp.asarray(a["vals"]), jnp.asarray(c_blk)))
        out = np.zeros(n, np.float32)
        rs, cnt = np.asarray(a["row_start"]), np.asarray(a["row_count"])
        for p in range(yb.shape[0]):
            out[rs[p]: rs[p] + cnt[p]] = yb[p, : cnt[p]]
        return out

    return call


def bcsr_spmv_nnz_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    """Blocked non-zero SpMV under shard_map: every color reduces a
    full-block-grid partial with psum — global block-rows, so overlapping
    block-row ownership needs no window bookkeeping."""
    B = kernel.shards[kernel.stmt.rhs.accesses()[0].tensor.name]
    c = kernel.shards[kernel.stmt.rhs.accesses()[1].tensor.name]
    n = kernel.stmt.lhs.tensor.shape[0]
    gr = int(B.meta["grid_rows"])
    a = B.arrays
    c_blk = pack_vec_blocks(np.asarray(c.arrays["vals"]),
                            int(B.meta["grid_cols"]), int(B.meta["bc"]))

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P()),
            out_specs=P())
        def run(bd0, bd1, tiles, cb):
            y = K.leaf_bcsr_spmv_nnz(bd0[0], bd1[0], tiles[0], cb, gr)
            return jax.lax.psum(y, axis_name=axis)
        return run

    run = _spmd_runner(
        "bcsr_spmv_nnz", mesh, axis, (gr,),
        (a["bdim0"], a["bdim1"], a["vals"], c_blk), build)

    def call():
        y = np.asarray(run(jnp.asarray(a["bdim0"]), jnp.asarray(a["bdim1"]),
                           jnp.asarray(a["vals"]), jnp.asarray(c_blk)))
        return y[:n]

    return call


def bcsr_spmm_rows_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    """Blocked row-based SpMM: per color the shard's tiles contract against
    the broadcast row-blocked dense operand — every stored block a dense
    (br, bc) @ (bc, J) matmul."""
    Bacc, Cacc = kernel.stmt.rhs.accesses()
    B = kernel.shards[Bacc.tensor.name]
    C = kernel.shards[Cacc.tensor.name]
    n, J = kernel.stmt.lhs.tensor.shape
    a = B.arrays
    C_blk = pack_mat_row_blocks(np.asarray(C.arrays["vals"]),
                                int(B.meta["grid_cols"]), int(B.meta["bc"]))

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P()),
            out_specs=P(axis))
        def run(pos, crd, tiles, Cb):
            return K.leaf_bcsr_spmm_rows(pos[0], crd[0], tiles[0], Cb)[None]
        return run

    run = _spmd_runner(
        "bcsr_spmm_rows", mesh, axis, (),
        (a["pos1"], a["crd1"], a["vals"], C_blk), build)

    def call():
        yb = np.asarray(run(jnp.asarray(a["pos1"]), jnp.asarray(a["crd1"]),
                            jnp.asarray(a["vals"]), jnp.asarray(C_blk)))
        out = np.zeros((n, J), np.float32)
        rs, cnt = np.asarray(a["row_start"]), np.asarray(a["row_count"])
        for p in range(yb.shape[0]):
            out[rs[p]: rs[p] + cnt[p]] = yb[p, : cnt[p]]
        return out

    return call


def bcsr_spmm_nnz_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    """Blocked non-zero SpMM under shard_map: global block-rows over the
    full grid extent, psum-reduced — the blocked analog of spmm_nnz."""
    Bacc, Cacc = kernel.stmt.rhs.accesses()
    B = kernel.shards[Bacc.tensor.name]
    C = kernel.shards[Cacc.tensor.name]
    n = kernel.stmt.lhs.tensor.shape[0]
    gr = int(B.meta["grid_rows"])
    a = B.arrays
    C_blk = pack_mat_row_blocks(np.asarray(C.arrays["vals"]),
                                int(B.meta["grid_cols"]), int(B.meta["bc"]))

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P()),
            out_specs=P())
        def run(bd0, bd1, tiles, Cb):
            y = K.leaf_bcsr_spmm_nnz(bd0[0], bd1[0], tiles[0], Cb, gr)
            return jax.lax.psum(y, axis_name=axis)
        return run

    run = _spmd_runner(
        "bcsr_spmm_nnz", mesh, axis, (gr,),
        (a["bdim0"], a["bdim1"], a["vals"], C_blk), build)

    def call():
        y = np.asarray(run(jnp.asarray(a["bdim0"]), jnp.asarray(a["bdim1"]),
                           jnp.asarray(a["vals"]), jnp.asarray(C_blk)))
        return y[:n]

    return call


def bcsr_sddmm_rows_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    """Blocked row-based SDDMM under shard_map: B's block-row shard sampled
    against its local C row blocks (block-aligned windows) and the
    broadcast column-blocked D; tiles reassemble by value-space bounds."""
    accs = kernel.stmt.rhs.accesses()
    B = kernel.shards[accs[0].tensor.name]
    C = kernel.shards[accs[1].tensor.name]
    D = kernel.shards[accs[2].tensor.name]
    Bt = accs[0].tensor
    a = B.arrays
    br, bc = int(B.meta["br"]), int(B.meta["bc"])
    max_brows = int(B.meta["max_brows"])
    C_blk = pack_rowwindow_blocks(C.arrays["vals"], max_brows, br)
    D_blk = pack_mat_inner_blocks(np.asarray(D.arrays["vals"]),
                                  int(B.meta["grid_cols"]), bc)

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P(axis), P()),
            out_specs=P(axis))
        def run(pos, crd, tiles, Cl, Db):
            brow = K.rows_from_pos(pos[0], crd[0].shape[0])
            return K.leaf_bcsr_sddmm(brow, crd[0], tiles[0], Cl[0],
                                     Db)[None]
        return run

    run = _spmd_runner(
        "bcsr_sddmm_rows", mesh, axis, (),
        (a["pos1"], a["crd1"], a["vals"], C_blk, D_blk), build)

    def call():
        out_tiles = np.asarray(run(
            jnp.asarray(a["pos1"]), jnp.asarray(a["crd1"]),
            jnp.asarray(a["vals"]), jnp.asarray(C_blk), jnp.asarray(D_blk)))
        total_blocks = int(Bt.levels[1].nnz or 0)
        return _assemble_vals(total_blocks, out_tiles, a,
                              kernel.plans[Bt.name].vals_bounds)

    return call


def bcsr_sddmm_nnz_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    """Blocked non-zero SDDMM: equal stored-block shards sample the
    broadcast block-packed factors; output tiles stay aligned with the
    stored block positions (no reduction — pattern-preserving)."""
    accs = kernel.stmt.rhs.accesses()
    B = kernel.shards[accs[0].tensor.name]
    C = kernel.shards[accs[1].tensor.name]
    D = kernel.shards[accs[2].tensor.name]
    Bt = accs[0].tensor
    a = B.arrays
    br, bc = int(B.meta["br"]), int(B.meta["bc"])
    C_blk = pack_mat_row_blocks(np.asarray(C.arrays["vals"]),
                                int(B.meta["grid_rows"]), br)
    D_blk = pack_mat_inner_blocks(np.asarray(D.arrays["vals"]),
                                  int(B.meta["grid_cols"]), bc)

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P(), P()),
            out_specs=P(axis))
        def run(bd0, bd1, tiles, Cb, Db):
            return K.leaf_bcsr_sddmm(bd0[0], bd1[0], tiles[0], Cb, Db)[None]
        return run

    run = _spmd_runner(
        "bcsr_sddmm_nnz", mesh, axis, (),
        (a["bdim0"], a["bdim1"], a["vals"], C_blk, D_blk), build)

    def call():
        out_tiles = np.asarray(run(
            jnp.asarray(a["bdim0"]), jnp.asarray(a["bdim1"]),
            jnp.asarray(a["vals"]), jnp.asarray(C_blk), jnp.asarray(D_blk)))
        total_blocks = int(Bt.levels[1].nnz or 0)
        return _assemble_vals(total_blocks, out_tiles, a,
                              kernel.plans[Bt.name].vals_bounds)

    return call


# ---------------------------------------------------------------------------
# 2-D grid builders — the SUMMA-style executors over a genuine
# Mesh((P, Q), ("x", "y")). The flat-color shard arrays reshape to
# (P, Q, ...) and shard over both axes; the dense co-operand windows shard
# over ONE axis (broadcast along the other falls out of the spec), and the
# contraction reduction is a psum scoped to the y axis only.
# ---------------------------------------------------------------------------

def _grid_axes(mesh: Mesh) -> tuple:
    if len(mesh.axis_names) != 2:
        raise ValueError(f"grid executor needs a 2-D mesh, got "
                         f"{mesh.axis_names}")
    return mesh.axis_names[0], mesh.axis_names[1]


def _grid_axes3(mesh: Mesh) -> tuple:
    if len(mesh.axis_names) != 3:
        raise ValueError(f"3-D grid executor needs a 3-D mesh, got "
                         f"{mesh.axis_names}")
    return mesh.axis_names[0], mesh.axis_names[1], mesh.axis_names[2]


def _grid_reshape(a: np.ndarray, P: int, Q: int) -> np.ndarray:
    return np.asarray(a).reshape((P, Q) + a.shape[1:])


def spmm_grid_rows_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    """2-D SpMM: tile (p, q) multiplies its B tile against C's q-th
    k-window (broadcast along x by the in_spec) and the grid row psums its
    partials along y ONLY — the SUMMA reduction."""
    ax, ay = _grid_axes(mesh)
    Bacc, Cacc = kernel.stmt.rhs.accesses()
    B = kernel.shards[Bacc.tensor.name]
    C = kernel.shards[Cacc.tensor.name]
    n, J = kernel.stmt.lhs.tensor.shape
    a = B.arrays
    P_, Q_ = int(B.meta["P"]), int(B.meta["Q"])
    pos = _grid_reshape(a["pos1"], P_, Q_)
    crd = _grid_reshape(a["crd1"], P_, Q_)
    vals = _grid_reshape(a["vals"], P_, Q_)
    Cw = C.arrays["vals"]                       # (Q, max_kw, J)

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(ax, ay), P(ax, ay), P(ax, ay), P(ay)),
            out_specs=P(ax))
        def run(pos, crd, vals, Cw):
            y = K.leaf_spmm_rows(pos[0, 0], crd[0, 0], vals[0, 0], Cw[0])
            return jax.lax.psum(y, axis_name=ay)[None]
        return run

    run = _spmd_runner("spmm_grid_rows", mesh, (ax, ay), (),
                       (pos, crd, vals, Cw), build)

    def call():
        yb = np.asarray(run(jnp.asarray(pos), jnp.asarray(crd),
                            jnp.asarray(vals), jnp.asarray(Cw)))
        out = np.zeros((n, J), np.float32)
        rs, cnt = np.asarray(a["row_start"]), np.asarray(a["row_count"])
        for p in range(yb.shape[0]):
            out[rs[p]: rs[p] + cnt[p]] = yb[p, : cnt[p]]
        return out

    return call


def spmv_grid_rows_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    ax, ay = _grid_axes(mesh)
    B = kernel.shards[kernel.stmt.rhs.accesses()[0].tensor.name]
    c = kernel.shards[kernel.stmt.rhs.accesses()[1].tensor.name]
    n = kernel.stmt.lhs.tensor.shape[0]
    a = B.arrays
    P_, Q_ = int(B.meta["P"]), int(B.meta["Q"])
    pos = _grid_reshape(a["pos1"], P_, Q_)
    crd = _grid_reshape(a["crd1"], P_, Q_)
    vals = _grid_reshape(a["vals"], P_, Q_)
    cw = c.arrays["vals"]                       # (Q, max_kw)

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(ax, ay), P(ax, ay), P(ax, ay), P(ay)),
            out_specs=P(ax))
        def run(pos, crd, vals, cw):
            y = K.leaf_spmv_rows(pos[0, 0], crd[0, 0], vals[0, 0], cw[0])
            return jax.lax.psum(y, axis_name=ay)[None]
        return run

    run = _spmd_runner("spmv_grid_rows", mesh, (ax, ay), (),
                       (pos, crd, vals, cw), build)

    def call():
        yb = np.asarray(run(jnp.asarray(pos), jnp.asarray(crd),
                            jnp.asarray(vals), jnp.asarray(cw)))
        out = np.zeros(n, np.float32)
        rs, cnt = np.asarray(a["row_start"]), np.asarray(a["row_count"])
        for p in range(yb.shape[0]):
            out[rs[p]: rs[p] + cnt[p]] = yb[p, : cnt[p]]
        return out

    return call


def sddmm_grid_rows_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    """2-D SDDMM: owner-computes tiles — C row windows shard along x, D
    column windows along y, outputs stay tile-aligned (NO psum on either
    axis); host assembly scatters by the tiles' global value positions."""
    ax, ay = _grid_axes(mesh)
    accs = kernel.stmt.rhs.accesses()
    B = kernel.shards[accs[0].tensor.name]
    C = kernel.shards[accs[1].tensor.name]
    D = kernel.shards[accs[2].tensor.name]
    Bt = accs[0].tensor
    a = B.arrays
    P_, Q_ = int(B.meta["P"]), int(B.meta["Q"])
    pos = _grid_reshape(a["pos1"], P_, Q_)
    crd = _grid_reshape(a["crd1"], P_, Q_)
    vals = _grid_reshape(a["vals"], P_, Q_)
    Cw = C.arrays["vals"]                       # (P, max_rw, K)
    Dw = D.arrays["vals"]                       # (Q, K, max_mw)

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(ax, ay), P(ax, ay), P(ax, ay), P(ax), P(ay)),
            out_specs=P(ax, ay))
        def run(pos, crd, vals, Cw, Dw):
            out = K.leaf_sddmm_rows(pos[0, 0], crd[0, 0], vals[0, 0],
                                    Cw[0], Dw[0])
            return out[None, None]
        return run

    run = _spmd_runner("sddmm_grid_rows", mesh, (ax, ay), (),
                       (pos, crd, vals, Cw, Dw), build)

    def call():
        out_vals = np.asarray(run(
            jnp.asarray(pos), jnp.asarray(crd), jnp.asarray(vals),
            jnp.asarray(Cw), jnp.asarray(Dw)))    # (P, Q, max_tnnz)
        flat = np.zeros(Bt.nnz, np.float32)
        vi = np.asarray(a["val_idx"]).reshape(P_, Q_, -1)
        cnt = np.asarray(a["nnz_count"]).reshape(P_, Q_)
        for p in range(P_):
            for q in range(Q_):
                k = int(cnt[p, q])
                flat[vi[p, q, :k]] = out_vals[p, q, :k]
        return flat

    return call


def bcsr_spmm_grid_rows_spmd(kernel: LoweredKernel, mesh: Mesh,
                             axis: str = "x"):
    """Blocked 2-D SpMM: (br, bc) tile matmuls against the q-th window of
    the block-packed dense operand, psum along y."""
    ax, ay = _grid_axes(mesh)
    Bacc, Cacc = kernel.stmt.rhs.accesses()
    B = kernel.shards[Bacc.tensor.name]
    C = kernel.shards[Cacc.tensor.name]
    n, J = kernel.stmt.lhs.tensor.shape
    a = B.arrays
    P_, Q_ = int(B.meta["P"]), int(B.meta["Q"])
    pos = _grid_reshape(a["pos1"], P_, Q_)
    crd = _grid_reshape(a["crd1"], P_, Q_)
    vals = _grid_reshape(a["vals"], P_, Q_)
    from ..core.grid import pack_window_mat_row_blocks
    Cw = pack_window_mat_row_blocks(np.asarray(C.arrays["vals"]),
                                    int(a["bcol_count"].max()),
                                    int(B.meta["bc"]))

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(ax, ay), P(ax, ay), P(ax, ay), P(ay)),
            out_specs=P(ax))
        def run(pos, crd, tiles, Cw):
            y = K.leaf_bcsr_spmm_rows(pos[0, 0], crd[0, 0], tiles[0, 0],
                                      Cw[0])
            return jax.lax.psum(y, axis_name=ay)[None]
        return run

    run = _spmd_runner("bcsr_spmm_grid_rows", mesh, (ax, ay), (),
                       (pos, crd, vals, Cw), build)

    def call():
        yb = np.asarray(run(jnp.asarray(pos), jnp.asarray(crd),
                            jnp.asarray(vals), jnp.asarray(Cw)))
        out = np.zeros((n, J), np.float32)
        rs, cnt = np.asarray(a["row_start"]), np.asarray(a["row_count"])
        for p in range(yb.shape[0]):
            out[rs[p]: rs[p] + cnt[p]] = yb[p, : cnt[p]]
        return out

    return call


def spmm_grid_rep_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    """2.5-D replicated SpMM over Mesh((P, Q, R)): B's (P, Q) tiles shard
    over (x, y) and the in_spec's silence on z replicates them across the
    z-layers; C's (Q, R) dense grid shards over (y, z). Each z-layer runs
    the SUMMA for its own output-column slab, so the psum is scoped to y
    ONLY — the (QR−1)-hop all-reduce of an unreplicated 3-D spread shrinks
    to Q−1 hops, which is exactly what the z-axis broadcast bought."""
    ax, ay, az = _grid_axes3(mesh)
    Bacc, Cacc = kernel.stmt.rhs.accesses()
    B = kernel.shards[Bacc.tensor.name]
    C = kernel.shards[Cacc.tensor.name]
    n, J = kernel.stmt.lhs.tensor.shape
    a = B.arrays
    P_, Q_ = int(B.meta["P"]), int(B.meta["Q"])
    pos = _grid_reshape(a["pos1"], P_, Q_)
    crd = _grid_reshape(a["crd1"], P_, Q_)
    vals = _grid_reshape(a["vals"], P_, Q_)
    Cw = C.arrays["vals"]                       # (Q, R, max_kw, max_jw)

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(ax, ay), P(ax, ay), P(ax, ay), P(ay, az)),
            out_specs=P(ax, az))
        def run(pos, crd, vals, Cw):
            y = K.leaf_spmm_rows(pos[0, 0], crd[0, 0], vals[0, 0], Cw[0, 0])
            return jax.lax.psum(y, axis_name=ay)[None, None]
        return run

    run = _spmd_runner("spmm_grid_rep_rows", mesh, (ax, ay, az), (),
                       (pos, crd, vals, Cw), build)

    def call():
        yb = np.asarray(run(jnp.asarray(pos), jnp.asarray(crd),
                            jnp.asarray(vals), jnp.asarray(Cw)))
        out = np.zeros((n, J), np.float32)
        rs, cnt = np.asarray(a["row_start"]), np.asarray(a["row_count"])
        cs = np.asarray(C.arrays["col_start"])
        cw = np.asarray(C.arrays["col_count"])
        for p in range(yb.shape[0]):
            for r in range(yb.shape[1]):
                out[rs[p]: rs[p] + cnt[p], cs[r]: cs[r] + cw[r]] = \
                    yb[p, r, : cnt[p], : cw[r]]
        return out

    return call


def sddmm_grid_rep_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    """2.5-D replicated SDDMM: B's sampling tiles shard over (x, y) and
    replicate across z; the contraction variable k splits over z — C's
    (P, R) grid shards over (x, z), D's (R, Q) grid over (z, y). Each
    z-layer samples a partial dot product and the psum is scoped to z
    ONLY (the single reduction axis); outputs stay tile-aligned."""
    ax, ay, az = _grid_axes3(mesh)
    accs = kernel.stmt.rhs.accesses()
    B = kernel.shards[accs[0].tensor.name]
    C = kernel.shards[accs[1].tensor.name]
    D = kernel.shards[accs[2].tensor.name]
    Bt = accs[0].tensor
    a = B.arrays
    P_, Q_ = int(B.meta["P"]), int(B.meta["Q"])
    pos = _grid_reshape(a["pos1"], P_, Q_)
    crd = _grid_reshape(a["crd1"], P_, Q_)
    vals = _grid_reshape(a["vals"], P_, Q_)
    Cw = C.arrays["vals"]                       # (P, R, max_rw, max_kw)
    Dw = D.arrays["vals"]                       # (R, Q, max_kw, max_mw)

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(ax, ay), P(ax, ay), P(ax, ay), P(ax, az), P(az, ay)),
            out_specs=P(ax, ay))
        def run(pos, crd, vals, Cw, Dw):
            out = K.leaf_sddmm_rows(pos[0, 0], crd[0, 0], vals[0, 0],
                                    Cw[0, 0], Dw[0, 0])
            return jax.lax.psum(out, axis_name=az)[None, None]
        return run

    run = _spmd_runner("sddmm_grid_rep_rows", mesh, (ax, ay, az), (),
                       (pos, crd, vals, Cw, Dw), build)

    def call():
        out_vals = np.asarray(run(
            jnp.asarray(pos), jnp.asarray(crd), jnp.asarray(vals),
            jnp.asarray(Cw), jnp.asarray(Dw)))    # (P, Q, max_tnnz)
        flat = np.zeros(Bt.nnz, np.float32)
        vi = np.asarray(a["val_idx"]).reshape(P_, Q_, -1)
        cnt = np.asarray(a["nnz_count"]).reshape(P_, Q_)
        for p in range(P_):
            for q in range(Q_):
                k = int(cnt[p, q])
                flat[vi[p, q, :k]] = out_vals[p, q, :k]
        return flat

    return call


def spmttkrp_grid3_spmd(kernel: LoweredKernel, mesh: Mesh, axis: str = "x"):
    """P×Q×R brick SpMTTKRP over Mesh((P, Q, R)): the COO brick arrays
    shard over all three axes, C's row windows over y, D's over z; each
    brick segment-sums its contraction and the partials psum over (y, z)
    — the Q·R bricks sharing a row window — landing row-aligned on x."""
    ax, ay, az = _grid_axes3(mesh)
    accs = kernel.stmt.rhs.accesses()
    B = kernel.shards[accs[0].tensor.name]
    C = kernel.shards[accs[1].tensor.name]
    D = kernel.shards[accs[2].tensor.name]
    out_shape = kernel.stmt.lhs.tensor.shape
    a = B.arrays
    P_, Q_, R_ = int(B.meta["P"]), int(B.meta["Q"]), int(B.meta["R"])
    max_rows = int(B.meta["max_rows"])

    def brick(x):
        return np.asarray(x).reshape((P_, Q_, R_) + x.shape[1:])

    d0, d1, d2 = brick(a["dim0"]), brick(a["dim1"]), brick(a["dim2"])
    vals = brick(a["vals"])
    Cw = C.arrays["vals"]                       # (Q, max_jw, L)
    Dw = D.arrays["vals"]                       # (R, max_kw, L)

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(ax, ay, az), P(ax, ay, az), P(ax, ay, az),
                      P(ax, ay, az), P(ay), P(az)),
            out_specs=P(ax))
        def run(d0, d1, d2, vals, Cw, Dw):
            y = K.leaf_spmttkrp_nnz(d0[0, 0, 0], d1[0, 0, 0], d2[0, 0, 0],
                                    vals[0, 0, 0], Cw[0], Dw[0], max_rows)
            return jax.lax.psum(y, axis_name=(ay, az))[None]
        return run

    run = _spmd_runner("spmttkrp_grid3_rows", mesh, (ax, ay, az), (),
                       (d0, d1, d2, vals, Cw, Dw), build)

    def call():
        yb = np.asarray(run(jnp.asarray(d0), jnp.asarray(d1),
                            jnp.asarray(d2), jnp.asarray(vals),
                            jnp.asarray(Cw), jnp.asarray(Dw)))
        out = np.zeros(out_shape, np.float32)
        rs, cnt = np.asarray(a["row_start"]), np.asarray(a["row_count"])
        for p in range(yb.shape[0]):
            out[rs[p]: rs[p] + cnt[p]] = yb[p, : cnt[p]]
        return out

    return call


SPMD_BUILDERS: Dict[str, Callable] = {
    "spmv_rows": spmv_rows_spmd,
    "spmv_nnz": spmv_nnz_spmd,
    "spmm_rows": spmm_rows_spmd,
    "spmm_nnz": spmm_nnz_spmd,
    "sddmm_rows": sddmm_rows_spmd,
    "sddmm_nnz": sddmm_nnz_spmd,
    "bcsr_spmv_rows": bcsr_spmv_rows_spmd,
    "bcsr_spmv_nnz": bcsr_spmv_nnz_spmd,
    "bcsr_spmm_rows": bcsr_spmm_rows_spmd,
    "bcsr_spmm_nnz": bcsr_spmm_nnz_spmd,
    "bcsr_sddmm_rows": bcsr_sddmm_rows_spmd,
    "bcsr_sddmm_nnz": bcsr_sddmm_nnz_spmd,
    "spmv_grid_rows": spmv_grid_rows_spmd,
    "spmm_grid_rows": spmm_grid_rows_spmd,
    "sddmm_grid_rows": sddmm_grid_rows_spmd,
    "bcsr_spmm_grid_rows": bcsr_spmm_grid_rows_spmd,
    "spmm_grid_rep_rows": spmm_grid_rep_spmd,
    "sddmm_grid_rep_rows": sddmm_grid_rep_spmd,
    "spmttkrp_grid3_rows": spmttkrp_grid3_spmd,
}


def to_spmd(kernel: LoweredKernel, mesh: Mesh = None, axis: str = "x",
            overlap: bool = False, overlap_chunks: int = 2):
    """SPMD executor for a lowered kernel, when a builder exists.

    ``mesh`` is data, not trace state: pass nothing to realize the
    kernel's own Machine, a ``jax.sharding.Mesh``, or a ``Machine``
    directly (realized here) — the elastic path hands the resized Machine
    straight through after ``relower``.

    Grid (multi-axis) NON-ZERO kernels reuse their 1-D builders with the
    flat color axis sharded over BOTH mesh axes and the reduction psum
    scoped to both — the nested pos-split is the flat P*Q split.

    ``overlap=True`` selects the comm/compute-overlapped builder variant
    where one exists (grid SpMM): the dense co-operand is consumed in
    ``overlap_chunks`` column chunks whose SUMMA psums have no data
    dependence on the following chunk's leaf, so the compiled program can
    run chunk t's reduction while chunk t+1's leaf computes — bit-for-bit
    equal to the unchunked builder (column chunking never reorders any
    per-element reduction)."""
    if mesh is None:
        mesh = machine_to_mesh(kernel.machine)
    elif isinstance(mesh, Machine):
        mesh = machine_to_mesh(mesh)
    strat = kernel.strategy
    if getattr(strat, "is_grid", False) and strat.space == "nnz" \
            and len(mesh.axis_names) >= 2:
        axis = tuple(mesh.axis_names)
    if overlap:
        builder = OVERLAP_SPMD_BUILDERS.get(kernel.leaf_name)
        if builder is None:
            raise NotImplementedError(
                f"no overlapped shard_map builder for leaf "
                f"{kernel.leaf_name}; supported: "
                f"{sorted(OVERLAP_SPMD_BUILDERS)}")
        with telemetry.span("execute.spmd.build", leaf=kernel.leaf_name,
                            overlap=True, chunks=overlap_chunks):
            return builder(kernel, mesh, axis=axis, chunks=overlap_chunks)
    builder = SPMD_BUILDERS.get(kernel.leaf_name)
    if builder is None:
        raise NotImplementedError(
            f"no shard_map builder for leaf {kernel.leaf_name}; "
            "the vmap simulation backend covers it")
    with telemetry.span("execute.spmd.build", leaf=kernel.leaf_name):
        return builder(kernel, mesh, axis=axis)


# ---------------------------------------------------------------------------
# Per-piece leaf profiling (telemetry, ISSUE 9): run each color's leaf
# kernel ALONE and wall-time it through block_until_ready. The emitters
# vmap all pieces into one launch, so a straggler piece is invisible in
# aggregate wall time; the per-piece profile is the skew histogram whose
# flags feed the existing lower(weights=) straggler re-plan path.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PieceProfile:
    """Per-piece leaf wall times for one lowered kernel."""

    leaf_name: str
    seconds: np.ndarray               # (pieces,) best-of-iters per piece

    def skew(self) -> float:
        """max/mean piece time — 1.0 is perfectly balanced."""
        m = float(self.seconds.mean())
        return float(self.seconds.max()) / m if m > 0 else 1.0

    def stragglers(self, threshold: float = 1.5):
        """Piece ids slower than ``threshold``× the mean."""
        m = float(self.seconds.mean())
        if m <= 0:
            return []
        return [int(p) for p in np.nonzero(self.seconds > threshold * m)[0]]

    def replan_weights(self) -> np.ndarray:
        """Mean-normalized inverse-time weights for ``lower(weights=)`` /
        ``relower(weights=)`` — a faster piece gets proportionally more
        non-zeros, the same convention as StragglerMitigator.weights."""
        inv = 1.0 / np.maximum(self.seconds, 1e-12)
        return inv / inv.mean()

    def as_dict(self):
        return {"leaf": self.leaf_name,
                "seconds": [float(s) for s in self.seconds],
                "skew": self.skew()}


def _sparse_and_dense(kernel):
    accs = kernel.stmt.rhs.accesses()
    B = kernel.shards[accs[0].tensor.name]
    C = kernel.shards[accs[1].tensor.name]
    return B, C


def _pieces_spmv_rows(kernel):
    B, c = _sparse_and_dense(kernel)
    a = B.arrays
    cv = jnp.asarray(c.arrays["vals"])
    pos, crd, vals = (jnp.asarray(a["pos1"]), jnp.asarray(a["crd1"]),
                      jnp.asarray(a["vals"]))
    return K.leaf_spmv_rows, [(pos[p], crd[p], vals[p], cv)
                              for p in range(pos.shape[0])]


def _pieces_spmm_rows(kernel):
    B, C = _sparse_and_dense(kernel)
    a = B.arrays
    Cv = jnp.asarray(C.arrays["vals"])
    pos, crd, vals = (jnp.asarray(a["pos1"]), jnp.asarray(a["crd1"]),
                      jnp.asarray(a["vals"]))
    return K.leaf_spmm_rows, [(pos[p], crd[p], vals[p], Cv)
                              for p in range(pos.shape[0])]


def _pieces_spmv_nnz(kernel):
    from ..core.lower import _nnz_row_windows
    B, c = _sparse_and_dense(kernel)
    n = kernel.stmt.lhs.tensor.shape[0]
    row_start, _, max_rows = _nnz_row_windows(B, n)
    a = B.arrays
    rl = jnp.clip(jnp.asarray(a["dim0"])
                  - jnp.asarray(row_start)[:, None], 0, max_rows - 1)
    cols, vals = jnp.asarray(a["dim1"]), jnp.asarray(a["vals"])
    cv = jnp.asarray(c.arrays["vals"])

    def leaf(r, cc, v, cvec):
        return K.leaf_spmv_nnz(r, cc, v, cvec, max_rows)

    return leaf, [(rl[p], cols[p], vals[p], cv)
                  for p in range(rl.shape[0])]


def _pieces_spmm_nnz(kernel):
    from ..core.lower import _nnz_row_windows
    B, C = _sparse_and_dense(kernel)
    row_start, _, max_rows = _nnz_row_windows(
        B, kernel.stmt.lhs.tensor.shape[0])
    a = B.arrays
    rl = jnp.clip(jnp.asarray(a["dim0"])
                  - jnp.asarray(row_start)[:, None], 0, max_rows - 1)
    cols, vals = jnp.asarray(a["dim1"]), jnp.asarray(a["vals"])
    Cv = jnp.asarray(C.arrays["vals"])

    def leaf(r, cc, v, Cm):
        return K.leaf_spmm_nnz(r, cc, v, Cm, max_rows)

    return leaf, [(rl[p], cols[p], vals[p], Cv)
                  for p in range(rl.shape[0])]


def _pieces_spmv_grid_rows(kernel):
    B, c = _sparse_and_dense(kernel)
    a = B.arrays
    Q = int(B.meta["Q"])
    cw = jnp.asarray(c.arrays["vals"])          # (Q, max_kw)
    pos, crd, vals = (jnp.asarray(a["pos1"]), jnp.asarray(a["crd1"]),
                      jnp.asarray(a["vals"]))
    return K.leaf_spmv_rows, [(pos[p], crd[p], vals[p], cw[p % Q])
                              for p in range(pos.shape[0])]


def _pieces_spmm_grid_rows(kernel):
    B, C = _sparse_and_dense(kernel)
    a = B.arrays
    Q = int(B.meta["Q"])
    Cw = jnp.asarray(C.arrays["vals"])          # (Q, max_kw, J)
    pos, crd, vals = (jnp.asarray(a["pos1"]), jnp.asarray(a["crd1"]),
                      jnp.asarray(a["vals"]))
    return K.leaf_spmm_rows, [(pos[p], crd[p], vals[p], Cw[p % Q])
                              for p in range(pos.shape[0])]


#: leaf name -> (kernel) -> (leaf_fn, [per-piece arg tuples]). Every
#: piece's args share shapes, so the jitted leaf compiles once.
PIECE_PROFILERS: Dict[str, Callable] = {
    "spmv_rows": _pieces_spmv_rows,
    "spmm_rows": _pieces_spmm_rows,
    "spmv_nnz": _pieces_spmv_nnz,
    "spmm_nnz": _pieces_spmm_nnz,
    "spmv_grid_rows": _pieces_spmv_grid_rows,
    "spmm_grid_rows": _pieces_spmm_grid_rows,
}


def profile_pieces(kernel: LoweredKernel, iters: int = 3,
                   warmup: int = 1) -> PieceProfile:
    """Wall-time every piece's leaf kernel individually (best of
    ``iters`` after ``warmup``, synchronized with block_until_ready).

    Records one ``execute.piece`` span + an ``executor.piece_seconds``
    histogram observation per piece, and the profile's skew as the
    ``executor.piece_skew`` gauge — the telemetry surface the serving
    path's straggler re-plans read."""
    slicer = PIECE_PROFILERS.get(kernel.leaf_name)
    if slicer is None:
        raise NotImplementedError(
            f"no per-piece profiler for leaf {kernel.leaf_name}; "
            f"supported: {sorted(PIECE_PROFILERS)}")
    leaf, piece_args = slicer(kernel)
    jleaf = jax.jit(leaf)
    n = len(piece_args)
    secs = np.full(n, np.inf)
    for args in piece_args:                      # compile + warm every shape
        for _ in range(max(warmup, 1)):
            jax.block_until_ready(jleaf(*args))
    for _ in range(max(iters, 1)):
        for p, args in enumerate(piece_args):
            with telemetry.span("execute.piece", piece=p,
                                leaf=kernel.leaf_name) as sp:
                t0 = time.perf_counter()
                jax.block_until_ready(jleaf(*args))
                dt = time.perf_counter() - t0
                sp.set(seconds=dt)
            secs[p] = min(secs[p], dt)
    for s in secs:
        telemetry.METRICS.observe("executor.piece_seconds", float(s))
    prof = PieceProfile(leaf_name=kernel.leaf_name, seconds=secs)
    telemetry.METRICS.gauge("executor.piece_skew", prof.skew())
    return prof


# -- Comm/compute overlap ---------------------------------------------------
#
# The serving fast path's second layer: double-buffered shard transfers.
# The dense co-operand of an SpMM is consumed in column chunks; while the
# leaf kernel contracts chunk t-1, chunk t's shard transfer is already in
# flight (collectives.prefetch dispatches jax.device_put asynchronously).
# Column chunking is bit-for-bit exact — every output element's k-reduction
# runs in the same order as the unchunked kernel; chunks are independent
# output-column lanes concatenated at the end.

#: Leaves whose dense operand flows straight into the jitted runner as a
#: device array. The bcsr paths re-pack on the host (pack_mat_row_blocks
#: over np.asarray), which would force the transferred chunk back through
#: host memory and defeat the double buffering.
_OVERLAP_LEAVES = ("spmm_rows", "spmm_nnz", "spmm_grid_rows")


def _chunk_bounds(J: int, chunks: int):
    """Equal-width column chunks (last takes the remainder) — at most two
    distinct widths, so the runner caches hold at most two entries per
    leaf regardless of chunk count."""
    chunks = max(1, min(int(chunks), int(J)))
    cw = -(-int(J) // chunks)
    return [(s, min(int(J), s + cw)) for s in range(0, int(J), cw)]


def run_overlapped(kernel: LoweredKernel, chunks: int = 2,
                   overlap: bool = True) -> np.ndarray:
    """Execute an SpMM kernel with double-buffered dense-operand chunks.

    Pipelined loop: issue chunk t's shard transfer, compute chunk t-1's
    leaf (the transfer rides under it), block on the transfer, emit chunk
    t's runner against the landed device arrays. ``overlap=False`` runs
    the same chunking sequentially (issue, wait, compute) — the baseline
    the bench compares against; both orders return bit-for-bit identical
    results (and identical to ``kernel.run()``).

    Per-chunk attribution lands as ``execute.overlap.chunk`` instants
    (comm_s, hidden_s, bytes) under one ``execute.overlap`` span, rolled
    up by :func:`repro.runtime.telemetry.overlap_report`; byte totals are
    mirrored into ``kernel.comm.overlap_total_bytes`` /
    ``overlap_hidden_bytes`` (attribution only — never added to
    ``total_network_bytes``). ``hidden_s`` is the wall-clock window the
    transfer spent under the previous chunk's compute: the host cannot
    observe the exact landing instant without a callback, so the window
    is clamped to the measured issue→ready duration.
    """
    from ..core import grid as grid_mod
    from ..core import lower as lower_mod
    from ..core.tensor import Tensor
    from .collectives import prefetch, wait

    if kernel.leaf_name not in _OVERLAP_LEAVES:
        raise NotImplementedError(
            f"run_overlapped supports leaves {_OVERLAP_LEAVES}; got "
            f"{kernel.leaf_name} (bcsr paths re-pack on host)")
    stmt = kernel.stmt
    strat = kernel.strategy
    _, Cacc = stmt.rhs.accesses()
    cname = Cacc.tensor.name
    oname = stmt.lhs.tensor.name
    cplan = kernel.plans[cname]
    if not cplan.replicated and cplan.grid is None \
            and cplan.root_coord_bounds is None:
        raise NotImplementedError(
            "run_overlapped chunks the dense operand by columns; a "
            "column-partitioned operand's bounds would change per chunk")
    Cfull = np.asarray(cplan.tensor.to_dense(), np.float32)
    n, J = (int(d) for d in stmt.lhs.tensor.shape)
    bounds = _chunk_bounds(J, chunks)

    def prep(c0, c1):
        """Host-side pack of one chunk's shard (NOT the transfer)."""
        Ct = Tensor.from_dense(cname, Cfull[:, c0:c1])
        plan_t = dataclasses.replace(cplan, tensor=Ct)
        hs = lower_mod._materialize_dense_operand(
            Ct, plan_t, strat.pieces, cache=False)
        nb = int(sum(np.asarray(v).nbytes for v in hs.arrays.values()))
        return Ct, plan_t, hs, nb

    def build(c0, c1, Ct, plan_t, host_shard, dev_arrays):
        """Emit the chunk runner against the landed device arrays."""
        Ot = Tensor.from_dense(oname, np.zeros((n, c1 - c0), np.float32))
        cstmt = stmt.with_tensors({cname: Ct, oname: Ot})
        plans = dict(kernel.plans)
        plans[cname] = plan_t
        if oname in plans:
            plans[oname] = dataclasses.replace(plans[oname], tensor=Ot)
        shards = dict(kernel.shards)
        shards[cname] = dataclasses.replace(host_shard, arrays=dev_arrays)
        if getattr(strat, "is_grid", False) and strat.space == "universe":
            gp = grid_mod.compute_grid_plan(cstmt, strat)
            _, runner = grid_mod._emit_grid(cstmt, strat, gp, plans,
                                            shards, jit=True)
        else:
            _, runner = lower_mod._emit(cstmt, strat, plans, shards,
                                        jit=True)
        return runner

    results = [None] * len(bounds)
    total_comm = total_hidden = 0.0
    total_bytes = hidden_bytes = 0
    with telemetry.span("execute.overlap", leaf=kernel.leaf_name,
                        chunks=len(bounds), overlap=bool(overlap)) as osp:
        if not overlap or len(bounds) == 1:
            for t, (c0, c1) in enumerate(bounds):
                Ct, plan_t, hs, nb = prep(c0, c1)
                t0 = time.perf_counter()
                with telemetry.span("execute.overlap.xfer", chunk=t,
                                    bytes=nb):
                    dev = wait(prefetch(hs.arrays))
                comm = max(time.perf_counter() - t0, 1e-9)
                runner = build(c0, c1, Ct, plan_t, hs, dev)
                with telemetry.span("execute.overlap.compute", chunk=t):
                    results[t] = np.asarray(runner())
                telemetry.instant("execute.overlap.chunk", chunk=t,
                                  comm_s=comm, hidden_s=0.0, bytes=nb)
                total_comm += comm
                total_bytes += nb
        else:
            preps = [prep(c0, c1) for (c0, c1) in bounds]
            pending = None                # (chunk index, emitted runner)
            for t in range(len(bounds) + 1):
                inflight = None
                if t < len(bounds):
                    Ct, plan_t, hs, nb = preps[t]
                    t_issue = time.perf_counter()
                    with telemetry.span("execute.overlap.xfer", chunk=t,
                                        bytes=nb):
                        dev = prefetch(hs.arrays)      # async dispatch
                    inflight = (t, Ct, plan_t, hs, dev, t_issue, nb)
                t_comp_end = None
                if pending is not None:
                    pt, runner = pending
                    with telemetry.span("execute.overlap.compute",
                                        chunk=pt):
                        results[pt] = np.asarray(runner())
                    t_comp_end = time.perf_counter()
                    pending = None
                if inflight is not None:
                    ct, Ct, plan_t, hs, dev, t_issue, nb = inflight
                    dev = wait(dev)
                    t_ready = time.perf_counter()
                    comm = max(t_ready - t_issue, 1e-9)
                    hid = 0.0
                    if t_comp_end is not None:
                        hid = min(max(t_comp_end - t_issue, 0.0), comm)
                    telemetry.instant("execute.overlap.chunk", chunk=ct,
                                      comm_s=comm, hidden_s=hid, bytes=nb)
                    total_comm += comm
                    total_hidden += hid
                    total_bytes += nb
                    hidden_bytes += int(nb * (hid / comm))
                    c0, c1 = bounds[ct]
                    pending = (ct, build(c0, c1, Ct, plan_t, hs, dev))
        eff = (total_hidden / total_comm) if total_comm > 0 else 0.0
        osp.set(comm_s=total_comm, hidden_s=total_hidden, efficiency=eff)
    telemetry.METRICS.counter("executor.overlap.comm_seconds", total_comm)
    telemetry.METRICS.counter("executor.overlap.hidden_seconds",
                              total_hidden)
    telemetry.METRICS.counter("executor.overlap.bytes", float(total_bytes))
    telemetry.METRICS.counter("executor.overlap.hidden_bytes",
                              float(hidden_bytes))
    telemetry.METRICS.gauge("executor.overlap.efficiency", eff)
    kernel.comm.overlap_total_bytes += total_bytes
    kernel.comm.overlap_hidden_bytes += hidden_bytes
    return np.concatenate(results, axis=1)


def spmm_grid_rows_overlap_spmd(kernel: LoweredKernel, mesh: Mesh,
                                axis: str = "x", chunks: int = 2):
    """Overlapped 2-D SpMM: identical SUMMA to :func:`spmm_grid_rows_spmd`
    but the dense k-window is consumed in column chunks whose psums carry
    no data dependence on the next chunk's leaf — the compiled program is
    free to run chunk t's y-axis reduction while chunk t+1's local
    contraction executes. Bit-for-bit equal to the unchunked builder:
    column chunks are independent output lanes, and each lane's k-order
    psum tree is unchanged."""
    ax, ay = _grid_axes(mesh)
    Bacc, Cacc = kernel.stmt.rhs.accesses()
    B = kernel.shards[Bacc.tensor.name]
    C = kernel.shards[Cacc.tensor.name]
    n, J = kernel.stmt.lhs.tensor.shape
    a = B.arrays
    P_, Q_ = int(B.meta["P"]), int(B.meta["Q"])
    pos = _grid_reshape(a["pos1"], P_, Q_)
    crd = _grid_reshape(a["crd1"], P_, Q_)
    vals = _grid_reshape(a["vals"], P_, Q_)
    Cw = C.arrays["vals"]                       # (Q, max_kw, J)
    bounds = tuple(_chunk_bounds(int(J), chunks))

    def build():
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(ax, ay), P(ax, ay), P(ax, ay), P(ay)),
            out_specs=P(ax))
        def run(pos, crd, vals, Cw):
            outs = []
            for c0, c1 in bounds:
                y = K.leaf_spmm_rows(pos[0, 0], crd[0, 0], vals[0, 0],
                                     Cw[0][:, c0:c1])
                outs.append(jax.lax.psum(y, axis_name=ay))
            return jnp.concatenate(outs, axis=-1)[None]
        return run

    run = _spmd_runner("spmm_grid_rows_overlap", mesh, (ax, ay), (bounds,),
                       (pos, crd, vals, Cw), build)

    def call():
        yb = np.asarray(run(jnp.asarray(pos), jnp.asarray(crd),
                            jnp.asarray(vals), jnp.asarray(Cw)))
        out = np.zeros((n, J), np.float32)
        rs, cnt = np.asarray(a["row_start"]), np.asarray(a["row_count"])
        for p in range(yb.shape[0]):
            out[rs[p]: rs[p] + cnt[p]] = yb[p, : cnt[p]]
        return out

    return call


OVERLAP_SPMD_BUILDERS: Dict[str, Callable] = {
    "spmm_grid_rows": spmm_grid_rows_overlap_spmd,
}
