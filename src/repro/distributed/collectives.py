"""Collective helpers used inside shard_map code paths.

The LM stack relies on GSPMD-inserted collectives; these helpers serve the
explicitly-scheduled paths: the sparse-kernel SPMD executor (paper's
``communicate``) and the hierarchical cross-pod gradient reduction.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp


def replicate_all_gather(x: jax.Array, axis: str) -> jax.Array:
    """Paper ``communicate``: fetch the whole operand to every shard."""
    return jax.lax.all_gather(x, axis_name=axis, tiled=True)


def reduce_rows(x: jax.Array, axis: str) -> jax.Array:
    """Reduce overlapping output rows across shards (non-zero strategies)."""
    return jax.lax.psum(x, axis_name=axis)


def reduce_scatter_rows(x: jax.Array, axis: str) -> jax.Array:
    return jax.lax.psum_scatter(x, axis_name=axis, tiled=True)


def hierarchical_grad_reduce(grads, *, intra_axis: str = "data",
                             inter_axis: Optional[str] = "pod"):
    """Two-level data-parallel gradient reduction for multi-pod meshes:
    reduce-scatter within a pod (fast ICI), all-reduce the scattered shards
    across pods (slow DCI), all-gather back within the pod. Wire bytes on
    the slow links drop by the intra-pod factor vs. a flat all-reduce."""
    def one(g):
        g = jax.lax.psum_scatter(g, axis_name=intra_axis, tiled=True)
        if inter_axis is not None:
            g = jax.lax.psum(g, axis_name=inter_axis)
        return jax.lax.all_gather(g, axis_name=intra_axis, tiled=True)
    return jax.tree.map(one, grads)


def ppermute_ring(x: jax.Array, axis: str, shift: int = 1) -> jax.Array:
    """Ring shift — building block for overlap-friendly halo exchange."""
    n = jax.lax.axis_size(axis)
    perm = [(i, (i + shift) % n) for i in range(n)]
    return jax.lax.ppermute(x, axis_name=axis, perm=perm)


def prefetch(arrays):
    """Asynchronously start a shard transfer: ``jax.device_put`` on a
    pytree dispatches immediately and returns futures-like arrays, so the
    caller can run a leaf kernel while the transfer is in flight and only
    ``jax.block_until_ready`` the result when the data is next consumed —
    the double-buffering primitive behind
    ``distributed.executor.run_overlapped``."""
    return jax.device_put(arrays)


def wait(arrays):
    """Block until a :func:`prefetch` transfer has landed."""
    return jax.block_until_ready(arrays)
