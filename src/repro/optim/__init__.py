from .adamw import AdamWState, adamw_init, adamw_update
from .schedules import cosine_with_warmup
from . import grad_compress

__all__ = ["AdamWState", "adamw_init", "adamw_update",
           "cosine_with_warmup", "grad_compress"]
