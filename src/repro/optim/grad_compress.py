"""Gradient compression for cross-pod data parallelism.

Two schemes, both with error feedback so compression noise doesn't bias the
optimizer:

- **int8 quantized all-reduce**: per-tensor max-abs scaling to int8 before
  the cross-pod reduction (4× wire-format saving on the slow pod-to-pod
  links; intra-pod reductions stay bf16/fp32).

- **top-k sparse gradient exchange** — expressed with the paper's own
  machinery: the gradient becomes a *sparse vector* (values at top-|g|
  coordinates), exchanged with a fused-coordinate non-zero partition. This
  is SpDISTAL applied to the training system itself (DESIGN.md §6); the
  dense fallback path documents the equivalent jnp ops used under jit.

Both operate on a pytree and return (compressed_update, new_error_state).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def int8_quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_int8_ef(grads, err):
    """Quantize grads+error-feedback to int8; returns (q, scales, new_err).

    Under pjit, summing the dequantized values across the 'pod' axis is the
    compressed cross-pod all-reduce; XLA keeps the int8 form on the wire
    when the reduction is expressed over the quantized payload."""
    if err is None:
        err = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    comp = jax.tree.map(lambda g, e: int8_quantize(g.astype(jnp.float32) + e),
                        grads, err)
    q = jax.tree.map(lambda c: c[0], comp,
                     is_leaf=lambda x: isinstance(x, tuple))
    scales = jax.tree.map(lambda c: c[1], comp,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(
        lambda g, e, qq, s: g.astype(jnp.float32) + e - int8_dequantize(qq, s),
        grads, err, q, scales)
    return q, scales, new_err


def topk_sparsify(g: jax.Array, k_frac: float = 0.01):
    """Keep the top-|g| fraction; returns (values, flat_indices, shape).

    The (indices, values) pair is exactly a SpDISTAL sparse vector in
    fused-coordinate form; exchanging it across pods is a non-zero-
    partitioned all-gather (paper Fig. 5b applied to gradients)."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(int(flat.shape[0] * k_frac), 1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx, g.shape


def topk_densify(values, idx, shape, dtype=jnp.float32):
    n = 1
    for s in shape:
        n *= s
    out = jnp.zeros((n,), dtype)
    return out.at[idx].add(values.astype(dtype)).reshape(shape)


def compress_topk_ef(grads, err, k_frac: float = 0.01):
    """Top-k sparsification with error feedback over a pytree."""
    if err is None:
        err = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        acc = g.astype(jnp.float32) + e
        v, i, shp = topk_sparsify(acc, k_frac)
        dense = topk_densify(v, i, shp)
        return (v, i), acc - dense, dense

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    res = [one(g, e) for g, e in zip(flat_g, flat_e)]
    sparse = treedef.unflatten([r[0] for r in res])
    new_err = treedef.unflatten([r[1] for r in res])
    dense = treedef.unflatten([r[2] for r in res])
    return sparse, new_err, dense
