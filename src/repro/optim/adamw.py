"""AdamW with fully-sharded state.

Optimizer state is a pytree mirroring the params tree, so the same
PartitionSpec tree shards params, grads, and both moments — the FSDP layout
from distributed/planner.py applies verbatim. Moments are fp32 regardless of
param dtype (mixed-precision safe).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    mu: Any                  # first moment, pytree like params
    nu: Any                  # second moment, pytree like params


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def adamw_update(params, grads, state: AdamWState, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip_norm: Optional[float] = 1.0):
    """One AdamW step. ``lr`` may be a scalar or a schedule value.

    Global-norm clipping runs first (the norm reduction is the only
    cross-parameter collective; under pjit it fuses into the gradient
    reduce-scatter epilogue)."""
    step = state.step + 1
    if grad_clip_norm is not None:
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    else:
        gnorm = jnp.zeros((), jnp.float32)

    b1t = 1 - b1 ** step.astype(jnp.float32)
    b2t = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / b1t
        vhat = v / b2t
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), gnorm
