"""Pallas TPU SpAdd3 kernel — ``A(i,j) = B(i,j) + C(i,j) + D(i,j)``.

The paper's headline fusion win (§VI-A: 11.8×/38.5× over PETSc/Trilinos,
which must run two pairwise adds with intermediate assembly). The fused
TPU leaf accumulates all three operands' row blocks into one dense
(block_r, block_m) VMEM tile in a single pass — no intermediate sparse
matrix is ever assembled:

    tile = Σ_t onehot(rows_t)[block_r, block_n] @ (vals_t ⊙ onehot(cols_t)[block_n, block_m])

Both scatters are one-hot MXU matmuls. Re-compression of the dense tile to
the output CSR (when a sparse output is required) is XLA gather/scan work
performed outside the kernel (ops.py) — assembly is control-flow heavy and
belongs off the MXU (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import formats as fmt


def supports(format: "fmt.Format", space: str) -> bool:
    """Format-dispatch query. The union-add leaves iterate all operands in
    row order, so universe needs the row-window view for EVERY operand;
    the nnz strategy splits the concatenated coordinate stream of the
    three operands, which any unblocked sparse format can feed. Blocked
    operands lower directly via the tile-union leaves (kernels/bcsr.py),
    merging duplicate blocks by summing (br, bc) tiles — lower.py falls
    back to conversion when the three operands' block shapes disagree."""
    return fmt.supports_2d_default(format, space)


def _spadd3_kernel(r1, c1, v1, r2, c2, v2, r3, c3, v3, out_ref, *,
                   block_r: int, block_m: int):
    m = pl.program_id(1)

    def scatter(rows_ref, cols_ref, vals_ref):
        rows = rows_ref[0, :]
        cols = cols_ref[0, :] - m * block_m     # column relative to tile
        vals = vals_ref[0, :]
        bn = rows.shape[0]
        iota_r = jax.lax.broadcasted_iota(jnp.int32, (block_r, bn), 0)
        row_oh = (iota_r == rows[None, :]).astype(vals.dtype)
        iota_c = jax.lax.broadcasted_iota(jnp.int32, (bn, block_m), 1)
        col_oh = (iota_c == cols[:, None]).astype(vals.dtype)
        return row_oh @ (vals[:, None] * col_oh)

    out_ref[0, :, :] = (scatter(r1, c1, v1) + scatter(r2, c2, v2)
                        + scatter(r3, c3, v3))


def spadd3_dense_tiles(rows1, cols1, vals1, rows2, cols2, vals2,
                       rows3, cols3, vals3, *, n_rows: int, n_cols: int,
                       block_r: int = 8, block_m: int = 128,
                       interpret: bool = True) -> jax.Array:
    """Fused three-way add into dense row tiles.

    Each operand is given in row-block ELL form over the SAME row blocking
    (layout.ell_pack with equal block_r): arrays (n_rblocks, bnnz_t). The
    per-operand bnnz may differ. Returns dense (n_rblocks*block_r, n_cols).

    Note: one grid step scans each operand's whole row-block nnz; operands
    are typically same-density so tiles stay VMEM-sized.
    """
    n_rblocks = rows1.shape[0]
    mpad = -(-n_cols // block_m) * block_m
    grid = (n_rblocks, mpad // block_m)

    def spec(arr):
        return pl.BlockSpec((1, arr.shape[1]), lambda i, mj: (i, 0))

    out = pl.pallas_call(
        functools.partial(_spadd3_kernel, block_r=block_r, block_m=block_m),
        grid=grid,
        in_specs=[spec(rows1), spec(cols1), spec(vals1),
                  spec(rows2), spec(cols2), spec(vals2),
                  spec(rows3), spec(cols3), spec(vals3)],
        out_specs=pl.BlockSpec((1, block_r, block_m), lambda i, mj: (i, 0, mj)),
        out_shape=jax.ShapeDtypeStruct((n_rblocks, block_r, mpad), vals1.dtype),
        interpret=interpret,
    )(rows1, cols1, vals1, rows2, cols2, vals2, rows3, cols3, vals3)
    return out.reshape(n_rblocks * block_r, mpad)[:n_rows, :n_cols]
