"""Pallas TPU SpMM kernel — ``A(i,j) = B(i,k) · C(k,j)`` (paper §VI-A).

Row-block ELL leaf for the row-based distributed algorithm. Grid is
(row-block, j-block, nnz-block); each step gathers the needed rows of the
dense operand ``C`` into VMEM, scales by the sparse values, and reduces into
the (block_r, block_j) output tile with a one-hot MXU matmul:

    A_tile += onehot(rows_rel)[block_r, block_n] @ (vals ⊙ C[crd, j_tile])

This is the Senanayake et al. SpMM schedule re-tiled for the MXU: the
``block_n``-long gather feeds a (block_r × block_n) × (block_n × block_j)
matmul, so MXU utilization scales with nnz density rather than row lengths.
C is blocked along j only; its k extent stays resident in VMEM (fits for
k ≤ ~32K at block_j=128; larger k requires k-blocking with crd bucketing,
see DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import formats as fmt


def supports(format: "fmt.Format", space: str) -> bool:
    """Format-dispatch query — same capability contract as spmv (the sparse
    operand's row/nnz iteration is identical; only the dense operand
    changes). BCSR lowers directly: each stored block is a dense
    (br, bc) @ (bc, J) MXU matmul (kernels/bcsr.py)."""
    return fmt.supports_2d_default(format, space)


def _spmm_ell_kernel(rows_ref, crd_ref, vals_ref, c_ref, out_ref, *,
                     block_r: int):
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    rows = rows_ref[0, :]                        # (block_n,)
    crd = crd_ref[0, :]
    vals = vals_ref[0, :]
    cg = jnp.take(c_ref[...], crd, axis=0)       # (block_n, block_j) gather
    prod = vals[:, None] * cg
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (block_r, rows.shape[0]), 0)
    onehot = (iota_r == rows[None, :]).astype(prod.dtype)
    out_ref[0, :, :] += onehot @ prod            # MXU


def spmm_ell(rows_rel: jax.Array, crd: jax.Array, vals: jax.Array,
             C: jax.Array, *, block_r: int = 8, block_n: int = 128,
             block_j: int = 128, interpret: bool = True) -> jax.Array:
    """Returns Y of shape (n_rblocks * block_r, J_padded).

    ELL arrays: (n_rblocks, bnnz); C: (K, J). J is padded to block_j.
    """
    n_rblocks, bnnz = rows_rel.shape
    K, J = C.shape
    assert bnnz % block_n == 0
    jpad = -(-J // block_j) * block_j
    if jpad != J:
        C = jnp.pad(C, ((0, 0), (0, jpad - J)))
    grid = (n_rblocks, jpad // block_j, bnnz // block_n)
    out = pl.pallas_call(
        functools.partial(_spmm_ell_kernel, block_r=block_r),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i, j, n: (i, n)),
            pl.BlockSpec((1, block_n), lambda i, j, n: (i, n)),
            pl.BlockSpec((1, block_n), lambda i, j, n: (i, n)),
            pl.BlockSpec((K, block_j), lambda i, j, n: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_r, block_j), lambda i, j, n: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n_rblocks, block_r, jpad), vals.dtype),
        interpret=interpret,
    )(rows_rel, crd, vals, C)
    return out.reshape(n_rblocks * block_r, jpad)[:, :J]
