"""Pallas TPU SpMTTKRP kernel — ``A(i,l) = B(i,j,k) · C(j,l) · D(k,l)``.

Row-block ELL leaf over the CSF tensor's *flattened nnz* with per-nnz
(j, k) coordinates (packed by layout.ell_pack with ``extra``). Per grid
step:

    contrib[block_n, L] = vals ⊙ C[j, :] ⊙ D[k, :]
    A_tile[block_r, L]  += onehot(rows_rel) @ contrib          (MXU)

The factor matrices C, D stay VMEM-resident (J·L, K·L ≤ VMEM for the
factorization ranks the paper evaluates, L ≤ 64). The same kernel serves
both the row-based and the non-zero based distributed algorithms — only the
partitioning (and hence rows_rel construction) differs, which is exactly
the paper's separation of concerns.

SpTTV (``A(i,j) = B(i,j,k)·c(k)``) reuses spmv.spmv_ell with the level-1
position space as rows — no separate kernel needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import formats as fmt


def supports(format: "fmt.Format", space: str) -> bool:
    """Format-dispatch query for 3-D MTTKRP (and TTV). Universe needs a
    row-partitionable root plus a walkable body: a grouped (non-singleton
    compressed) middle level feeds the two-level pos/crd leaf (CSF
    directly, DCSF via the densified row window), and trailing-singleton
    trees (COO3) feed the FLAT per-position leaf bucketed by row window —
    the trailing-singleton walk of core/levels.py, so no conversion is
    needed. The nnz leaf consumes flat per-nnz (i, j, k) coordinates,
    which every unblocked 3-D sparse format provides."""
    caps = fmt.capabilities(format)
    if caps.order != 3:
        return False
    if space == "universe":
        grouped = (format.levels[1].compressed
                   and not format.levels[1].singleton)
        trailing = all(l.singleton for l in format.levels[1:])
        return caps.row_partitionable and (grouped or trailing)
    return caps.nnz_partitionable


def _spmttkrp_kernel(rows_ref, j_ref, k_ref, vals_ref, c_ref, d_ref, out_ref,
                     *, block_r: int):
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    rows = rows_ref[0, :]
    jj = j_ref[0, :]
    kk = k_ref[0, :]
    vals = vals_ref[0, :]
    cg = jnp.take(c_ref[...], jj, axis=0)       # (block_n, L)
    dg = jnp.take(d_ref[...], kk, axis=0)       # (block_n, L)
    contrib = vals[:, None] * cg * dg
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (block_r, rows.shape[0]), 0)
    onehot = (iota_r == rows[None, :]).astype(contrib.dtype)
    out_ref[0, :, :] += onehot @ contrib


def spmttkrp_ell(rows_rel: jax.Array, j: jax.Array, k: jax.Array,
                 vals: jax.Array, C: jax.Array, D: jax.Array, *,
                 block_r: int = 8, block_n: int = 128,
                 interpret: bool = True) -> jax.Array:
    """Returns A of shape (n_rblocks * block_r, L)."""
    n_rblocks, bnnz = rows_rel.shape
    L = C.shape[1]
    assert bnnz % block_n == 0
    grid = (n_rblocks, bnnz // block_n)
    out = pl.pallas_call(
        functools.partial(_spmttkrp_kernel, block_r=block_r),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i, n: (i, n)),
            pl.BlockSpec((1, block_n), lambda i, n: (i, n)),
            pl.BlockSpec((1, block_n), lambda i, n: (i, n)),
            pl.BlockSpec((1, block_n), lambda i, n: (i, n)),
            pl.BlockSpec(C.shape, lambda i, n: (0, 0)),
            pl.BlockSpec(D.shape, lambda i, n: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_r, L), lambda i, n: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rblocks, block_r, L), vals.dtype),
        interpret=interpret,
    )(rows_rel, j, k, vals, C, D)
    return out.reshape(n_rblocks * block_r, L)
