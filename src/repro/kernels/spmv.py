"""Pallas TPU SpMV kernels — ``a(i) = B(i,j) · c(j)`` (paper §VI-A).

Two kernels matching the paper's two distributed algorithms:

- :func:`spmv_ell` — row-block leaf for the universe (row-based) strategy.
  Operates on the row-block ELL layout (layout.py): grid over
  (row-block, nnz-block); the segmented row reduction is a one-hot matmul on
  the MXU; the dense vector ``c`` is held in VMEM and gathered per block.

- :func:`spmv_coo_phase1` — two-phase segmented reduction for the non-zero
  (position-space) strategy: phase 1 (this kernel) computes, per nnz block,
  rank-compacted partial sums + the row id of each rank; phase 2 (a cheap
  XLA ``segment_sum`` in ops.py) merges block partials. This replaces the
  GPU leaf's atomic reductions — the TPU has no atomics, so block-local
  compaction + a small fixup is the idiomatic equivalent (DESIGN.md §2).

VMEM budget: with ``block_r=8``-row output tiles, ``block_n=128`` nnz lanes
and ``c`` resident, the working set is ``c`` (4·m bytes) + 3 nnz blocks +
the (8, 128) one-hot tile — well under the ~16 MiB/core VMEM for m ≤ 1M.
For larger m the column dimension must be blocked with column-bucketed
layouts; see DESIGN.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import formats as fmt


def supports(format: "fmt.Format", space: str) -> bool:
    """Format-dispatch query (core.lower consults this before emitting).

    Row (universe) leaves consume any format whose dimension-0 partition
    maps to contiguous storage — CSR directly, DCSR/COO via the densified
    row-window view. Non-zero leaves need an nnz-splittable position space
    (any unblocked sparse format; non-row-major roots like CSC reduce over
    the full output extent instead of a row window). Blocked formats
    (BCSR) route to the direct blocked leaves (kernels/bcsr.py) under both
    strategies — block-row windows / stored-block splits."""
    return fmt.supports_2d_default(format, space)


# ---------------------------------------------------------------------------
# Row-based (universe) kernel
# ---------------------------------------------------------------------------

def _spmv_ell_kernel(rows_ref, crd_ref, vals_ref, c_ref, out_ref, *,
                     block_r: int):
    """One (row-block, nnz-block) grid step."""
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    rows = rows_ref[0, :]                      # (block_n,) relative row ids
    crd = crd_ref[0, :]                        # (block_n,) columns
    vals = vals_ref[0, :]                      # (block_n,)
    cvals = jnp.take(c_ref[:], crd, axis=0)    # VMEM gather
    prod = vals * cvals                        # (block_n,)
    # segmented reduce as a one-hot MXU matvec; padding rows_rel == block_r
    # select no output row.
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (block_r, rows.shape[0]), 0)
    onehot = (iota_r == rows[None, :]).astype(prod.dtype)
    out_ref[0, :] += onehot @ prod


def spmv_ell(rows_rel: jax.Array, crd: jax.Array, vals: jax.Array,
             c: jax.Array, *, block_r: int = 8, block_n: int = 128,
             interpret: bool = True) -> jax.Array:
    """Returns y of shape (n_rblocks * block_r,).

    Inputs are the `layout.ell_pack` arrays: (n_rblocks, bnnz) each; ``c``
    is the full dense vector (replicated operand of the row strategy).
    """
    n_rblocks, bnnz = rows_rel.shape
    assert bnnz % block_n == 0
    grid = (n_rblocks, bnnz // block_n)
    out = pl.pallas_call(
        functools.partial(_spmv_ell_kernel, block_r=block_r),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i, n: (i, n)),   # rows_rel
            pl.BlockSpec((1, block_n), lambda i, n: (i, n)),   # crd
            pl.BlockSpec((1, block_n), lambda i, n: (i, n)),   # vals
            pl.BlockSpec(c.shape, lambda i, n: (0,)),          # c in VMEM
        ],
        out_specs=pl.BlockSpec((1, block_r), lambda i, n: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rblocks, block_r), vals.dtype),
        interpret=interpret,
    )(rows_rel, crd, vals, c)
    return out.reshape(n_rblocks * block_r)


# ---------------------------------------------------------------------------
# Non-zero (position-space) kernel — two-phase segmented reduction
# ---------------------------------------------------------------------------

def _coo_phase1_kernel(rows_ref, crd_ref, vals_ref, c_ref, psum_ref, prow_ref):
    rows = rows_ref[0, :]
    crd = crd_ref[0, :]
    vals = vals_ref[0, :]
    prod = vals * jnp.take(c_ref[:], crd, axis=0)
    # rank-compact: rows are sorted within the block; rank = #row-changes
    first = jax.lax.broadcasted_iota(jnp.int32, rows.shape, 0) == 0
    prev = jnp.roll(rows, 1)
    newseg = jnp.where(first, True, rows != prev)
    rank = jnp.cumsum(newseg.astype(jnp.int32)) - 1          # (block_n,)
    bn = rows.shape[0]
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 0)
    onehot = (iota_r == rank[None, :]).astype(prod.dtype)
    psum_ref[0, :] = onehot @ prod                            # per-rank sums
    # row id per rank: only the segment-start position contributes (others
    # multiply by newseg == 0). Ranks past the block's last rank select
    # nothing -> row 0 with a zero partial, dropped/harmless in phase 2.
    # f32 matmul keeps row ids exact up to 2^24 (fine for shard-local rows;
    # larger shards would split the id into hi/lo lanes).
    prow_ref[0, :] = (onehot @ (rows * newseg).astype(prod.dtype)
                      ).astype(jnp.int32)


def spmv_coo_phase1(rows: jax.Array, crd: jax.Array, vals: jax.Array,
                    c: jax.Array, *, block_n: int = 128,
                    interpret: bool = True):
    """Phase 1: per-block rank partial sums + rank row ids.

    ``rows`` must be sorted (COO order — true after a non-zero partition of
    a row-major sparse tensor). Returns (partials, partial_rows), each of
    shape (n_blocks, block_n); ops.spmv_nnz merges with a segment-sum.
    """
    nnz = rows.shape[0]
    assert nnz % block_n == 0
    nb = nnz // block_n
    r2 = rows.reshape(nb, block_n)
    c2 = crd.reshape(nb, block_n)
    v2 = vals.reshape(nb, block_n)
    psum, prow = pl.pallas_call(
        _coo_phase1_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda b: (b, 0)),
            pl.BlockSpec((1, block_n), lambda b: (b, 0)),
            pl.BlockSpec((1, block_n), lambda b: (b, 0)),
            pl.BlockSpec(c.shape, lambda b: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda b: (b, 0)),
            pl.BlockSpec((1, block_n), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block_n), vals.dtype),
            jax.ShapeDtypeStruct((nb, block_n), jnp.int32),
        ],
        interpret=interpret,
    )(r2, c2, v2, c)
    return psum, prow
