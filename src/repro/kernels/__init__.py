"""SpDISTAL leaf kernels.

Per kernel: ``<name>.py`` (pl.pallas_call + BlockSpec, TPU target, validated
under interpret=True), ``ops.py`` (jit'd wrappers, impl="xla"|"pallas"),
``ref.py`` (pure-jnp oracles). ``layout.py`` holds the TPU-facing row-block
ELL / padded-COO packers.
"""
from . import layout, ref

__all__ = ["layout", "ops", "ref"]


def __getattr__(name):
    # ops imports jax at module scope; defer so `import repro.kernels.ref`
    # stays cheap for pure-numpy users.
    if name == "ops":
        import importlib
        return importlib.import_module(".ops", __name__)
    raise AttributeError(name)
