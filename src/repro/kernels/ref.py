"""Pure-jnp reference oracles for every SpDISTAL leaf kernel.

Two families:

1. **Dense oracles** (`dense_*`) — straight jnp.einsum on densified inputs.
   These define semantics for the paper's six evaluated expressions and are
   what every kernel (XLA leaf or Pallas) is asserted against.

2. **Shard leaves** (`leaf_*`) — per-shard, statically-shaped jnp
   implementations operating on the padded shard layouts produced by
   `core.partition`. These are the "generated leaf kernel" equivalents used
   by the simulation backend; Pallas kernels replace them on TPU.

Leaves consume **packed level arrays**, never format descriptors: the
positional arguments are the materialized regions of a level-tree walk
(core/levels.py) — ``pos``/``crd`` pairs for grouped walks, per-dimension
coordinate columns for flat walks, ``(br, bc)`` tile stacks for block
levels. Which format produced a walk is invisible here: a transpose-walked
CSC shard and a CSR shard feed the SAME leaf, which is what keeps the leaf
set at one per (expression × strategy × walk family) instead of one per
format.

Padding convention: padded nnz slots have ``vals == 0`` and ``crd == 0`` so
multiplicative kernels are unaffected; padded rows have empty pos ranges.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Dense oracles (semantics of the paper's evaluation kernels, §VI-A)
# ---------------------------------------------------------------------------

def dense_spmv(B, c):
    return jnp.einsum("ij,j->i", B, c)


def dense_spmm(B, C):
    return jnp.einsum("ik,kj->ij", B, C)


def dense_spadd3(B, C, D):
    return B + C + D


def dense_sddmm(Bpat, C, D):
    """A(i,j) = B(i,j) * C(i,k) * D(k,j) — sample dense product at B's nnz."""
    return Bpat * jnp.einsum("ik,kj->ij", C, D)


def dense_spttv(B, c):
    return jnp.einsum("ijk,k->ij", B, c)


def dense_spmttkrp(B, C, D):
    return jnp.einsum("ijk,jl,kl->il", B, C, D)


# ---------------------------------------------------------------------------
# Shard-leaf helpers
# ---------------------------------------------------------------------------

def rows_from_pos(pos: jnp.ndarray, n_positions: int) -> jnp.ndarray:
    """Expand a local pos array to a per-position parent index.

    ``pos``: (R+1,) monotone int32. Returns (n_positions,) row ids; padded
    positions (>= pos[-1]) clip to the last row, harmless since their vals
    are zero."""
    p = jnp.arange(n_positions, dtype=pos.dtype)
    r = jnp.searchsorted(pos, p, side="right") - 1
    return jnp.clip(r, 0, pos.shape[0] - 2)


# ---------------------------------------------------------------------------
# Shard leaves — rows (universe) strategy
# ---------------------------------------------------------------------------

def leaf_spmv_rows(pos, crd, vals, c):
    """y_local(R,) from a CSR row shard; c replicated (paper Fig. 9b leaf)."""
    R = pos.shape[0] - 1
    rows = rows_from_pos(pos, crd.shape[0])
    prod = vals * jnp.take(c, crd, axis=0)
    return jax.ops.segment_sum(prod, rows, num_segments=R)


def leaf_spmv_nnz(rows_local, cols, vals, c, max_rows):
    """y_local(max_rows,) from an equal-nnz COO shard; rows_local already
    rebased to the shard's root interval (overlap handled by caller
    reduction — paper §II-D non-zero algorithm)."""
    prod = vals * jnp.take(c, cols, axis=0)
    return jax.ops.segment_sum(prod, rows_local, num_segments=max_rows)


def leaf_spmm_rows(pos, crd, vals, C):
    """Y_local(R, J) = local CSR @ C, C(K, J) replicated."""
    R = pos.shape[0] - 1
    rows = rows_from_pos(pos, crd.shape[0])
    gathered = jnp.take(C, crd, axis=0)            # (N, J)
    prod = vals[:, None] * gathered
    return jax.ops.segment_sum(prod, rows, num_segments=R)


def leaf_spmm_nnz(rows_local, cols, vals, C, max_rows):
    gathered = jnp.take(C, cols, axis=0)
    prod = vals[:, None] * gathered
    return jax.ops.segment_sum(prod, rows_local, num_segments=max_rows)


def leaf_sddmm_nnz(rows, cols, vals, C, D):
    """out_vals(N,) = vals * <C[rows,:], D[:,cols]> — the fused SDDMM leaf
    (non-zero distributed algorithm, paper §VI-A)."""
    Cg = jnp.take(C, rows, axis=0)                 # (N, K)
    Dg = jnp.take(D, cols, axis=1).T               # (N, K)
    return vals * jnp.sum(Cg * Dg, axis=1)


def leaf_sddmm_rows(pos, crd, vals, C_local, D):
    """Row-window SDDMM leaf: B given as a local CSR/densified-root shard,
    C's matching row block local, D replicated. Output vals stay aligned
    with B's shard positions (pattern-preserving, paper §V-B)."""
    rows = rows_from_pos(pos, crd.shape[0])
    Cg = jnp.take(C_local, rows, axis=0)           # (N, K) local rows
    Dg = jnp.take(D, crd, axis=1).T                # (N, K)
    return vals * jnp.sum(Cg * Dg, axis=1)


def leaf_spadd3_rows(pos1, crd1, v1, pos2, crd2, v2, pos3, crd3, v3, n_cols):
    """Fused three-way sparse add over a row shard.

    Two-phase union assembly (Chou et al. [28]) fused across all three
    operands: lexsort concatenated (row, col) pairs, dedupe, segment-sum.
    Output is a padded union COO (rows, cols, vals, count). Static output
    size = N1+N2+N3. int32 throughout (TPU-friendly; no fused int64 key)."""
    R = pos1.shape[0] - 1
    rows = jnp.concatenate([
        rows_from_pos(pos1, crd1.shape[0]),
        rows_from_pos(pos2, crd2.shape[0]),
        rows_from_pos(pos3, crd3.shape[0]),
    ])
    cols = jnp.concatenate([crd1, crd2, crd3])
    vals = jnp.concatenate([v1, v2, v3])
    # padded slots: vals==0; push them past every valid row so they sort last
    valid = jnp.concatenate([
        jnp.arange(crd1.shape[0]) < (pos1[-1] - pos1[0]),
        jnp.arange(crd2.shape[0]) < (pos2[-1] - pos2[0]),
        jnp.arange(crd3.shape[0]) < (pos3[-1] - pos3[0]),
    ])
    rows = jnp.where(valid, rows, R).astype(jnp.int32)
    order = jnp.lexsort((cols, rows))
    rows_s, cols_s, vals_s = rows[order], cols[order], vals[order]
    valid_s = valid[order]
    newseg = jnp.concatenate([
        jnp.array([True]),
        (rows_s[1:] != rows_s[:-1]) | (cols_s[1:] != cols_s[:-1]),
    ])
    n = rows.shape[0]
    seg_id = jnp.cumsum(newseg) - 1
    out_vals = jax.ops.segment_sum(vals_s, seg_id, num_segments=n)
    first = jax.ops.segment_min(jnp.arange(n, dtype=jnp.int32), seg_id,
                                num_segments=n)
    first = jnp.clip(first, 0, n - 1)
    out_rows = jnp.take(rows_s, first)
    out_cols = jnp.take(cols_s, first)
    count = jnp.sum((newseg & valid_s).astype(jnp.int32))
    in_range = jnp.arange(n) < count
    out_rows = jnp.where(in_range, out_rows, 0).astype(jnp.int32)
    out_cols = jnp.where(in_range, out_cols, 0).astype(jnp.int32)
    out_vals = jnp.where(in_range, out_vals, 0)
    return out_rows, out_cols, out_vals, count


def leaf_spadd_union_chunk(rows, cols, vals, count, n_rows):
    """Per-chunk union leaf for the non-zero SpAdd strategy: the chunk is a
    slice of the CONCATENATED coordinate stream of all addends (the
    coordinate-position loop of an addition). Same two-phase union as
    leaf_spadd3_rows, over global rows; duplicates that straddle chunk
    boundaries merge in the host-side assembly's dedupe."""
    n = rows.shape[0]
    valid = jnp.arange(n) < count
    rows = jnp.where(valid, rows, n_rows).astype(jnp.int32)
    order = jnp.lexsort((cols, rows))
    rows_s, cols_s, vals_s = rows[order], cols[order], vals[order]
    valid_s = valid[order]
    newseg = jnp.concatenate([
        jnp.array([True]),
        (rows_s[1:] != rows_s[:-1]) | (cols_s[1:] != cols_s[:-1]),
    ])
    seg_id = jnp.cumsum(newseg) - 1
    out_vals = jax.ops.segment_sum(vals_s, seg_id, num_segments=n)
    first = jax.ops.segment_min(jnp.arange(n, dtype=jnp.int32), seg_id,
                                num_segments=n)
    first = jnp.clip(first, 0, n - 1)
    out_rows = jnp.take(rows_s, first)
    out_cols = jnp.take(cols_s, first)
    out_count = jnp.sum((newseg & valid_s).astype(jnp.int32))
    in_range = jnp.arange(n) < out_count
    out_rows = jnp.where(in_range, out_rows, 0).astype(jnp.int32)
    out_cols = jnp.where(in_range, out_cols, 0).astype(jnp.int32)
    out_vals = jnp.where(in_range, out_vals, 0)
    return out_rows, out_cols, out_vals, out_count


def leaf_spadd3_dense_rows(pos1, crd1, v1, pos2, crd2, v2, pos3, crd3, v3,
                           n_cols):
    """Dense-row-accumulate variant (the Pallas-kernel contract): scatter all
    three operands into dense local rows. Used when the output is consumed
    densely or re-compressed by XLA."""
    R = pos1.shape[0] - 1
    out = jnp.zeros((R, n_cols), dtype=v1.dtype)
    for pos, crd, v in ((pos1, crd1, v1), (pos2, crd2, v2), (pos3, crd3, v3)):
        rows = rows_from_pos(pos, crd.shape[0])
        out = out.at[rows, crd].add(v)
    return out


# ---------------------------------------------------------------------------
# Blocked (BCSR) leaves — every stored position carries a dense (br, bc)
# value tile, so the inner op per position is a dense tile matmul (the MXU
# contract the direct blocked path compiles to). Dense co-operands arrive
# pre-reshaped into matching blocks (kernels.bcsr pack_* helpers); boundary
# blocks keep their zero padding, which multiplies away.
# ---------------------------------------------------------------------------

def leaf_bcsr_spmv_rows(pos, crd, bvals, c_blk):
    """y_local(R*br,) from a blocked row shard: per stored block a
    (br, bc) @ (bc,) tile matvec, segment-summed over block-rows.
    ``c_blk`` is the dense vector in column blocks, (grid_cols, bc)."""
    R = pos.shape[0] - 1
    brow = rows_from_pos(pos, crd.shape[0])
    cg = jnp.take(c_blk, crd, axis=0)                  # (NB, bc)
    prod = jnp.einsum("nrc,nc->nr", bvals, cg)
    acc = jax.ops.segment_sum(prod, brow, num_segments=R)
    return acc.reshape(-1)


def leaf_bcsr_spmv_nnz(brow_local, bcol, bvals, c_blk, max_brows):
    """Equal-stored-block shard: block-rows already rebased to the shard's
    block-row window; padding blocks have zero tiles."""
    cg = jnp.take(c_blk, bcol, axis=0)
    prod = jnp.einsum("nrc,nc->nr", bvals, cg)
    acc = jax.ops.segment_sum(prod, brow_local, num_segments=max_brows)
    return acc.reshape(-1)


def leaf_bcsr_spmm_rows(pos, crd, bvals, C_blk):
    """Y_local(R*br, J): per stored block a dense (br, bc) @ (bc, J)
    matmul. ``C_blk`` is the dense operand in row blocks, (grid_cols, bc, J)."""
    R = pos.shape[0] - 1
    brow = rows_from_pos(pos, crd.shape[0])
    cg = jnp.take(C_blk, crd, axis=0)                  # (NB, bc, J)
    prod = jnp.einsum("nrc,ncj->nrj", bvals, cg)
    acc = jax.ops.segment_sum(prod, brow, num_segments=R)
    return acc.reshape(-1, cg.shape[-1])


def leaf_bcsr_spmm_nnz(brow_local, bcol, bvals, C_blk, max_brows):
    cg = jnp.take(C_blk, bcol, axis=0)
    prod = jnp.einsum("nrc,ncj->nrj", bvals, cg)
    acc = jax.ops.segment_sum(prod, brow_local, num_segments=max_brows)
    return acc.reshape(-1, cg.shape[-1])


def leaf_bcsr_sddmm(brow, bcol, bvals, C_blk, D_blk):
    """out tiles (NB, br, bc) = bvals ⊙ (C row-block @ D col-block), the
    pattern-preserving sampled product at block granularity. ``C_blk``
    (n_brow_blocks, br, K) row blocks — shard-local under rows, the full
    grid under nnz; ``D_blk`` (grid_cols, K, bc) column blocks."""
    Cg = jnp.take(C_blk, brow, axis=0)                 # (NB, br, K)
    Dg = jnp.take(D_blk, bcol, axis=0)                 # (NB, K, bc)
    sampled = jnp.einsum("nrk,nkc->nrc", Cg, Dg)
    return bvals * sampled


def _tile_union(brows, bcols, tiles, valid):
    """Shared two-phase union over (block-row, block-col) keyed TILE
    streams: lexsort, segment-sum duplicate tiles, compact. ``brows`` must
    already carry the past-every-valid sentinel on invalid slots."""
    if brows.shape[0] == 0:      # statically-empty stream (empty operands)
        return (brows.astype(jnp.int32), bcols.astype(jnp.int32), tiles,
                jnp.zeros((), jnp.int32))
    order = jnp.lexsort((bcols, brows))
    r_s, c_s, t_s = brows[order], bcols[order], tiles[order]
    valid_s = valid[order]
    newseg = jnp.concatenate([
        jnp.array([True]),
        (r_s[1:] != r_s[:-1]) | (c_s[1:] != c_s[:-1]),
    ])
    n = brows.shape[0]
    seg_id = jnp.cumsum(newseg) - 1
    out_tiles = jax.ops.segment_sum(t_s, seg_id, num_segments=n)
    first = jax.ops.segment_min(jnp.arange(n, dtype=jnp.int32), seg_id,
                                num_segments=n)
    first = jnp.clip(first, 0, n - 1)
    out_r = jnp.take(r_s, first)
    out_c = jnp.take(c_s, first)
    count = jnp.sum((newseg & valid_s).astype(jnp.int32))
    in_range = jnp.arange(n) < count
    out_r = jnp.where(in_range, out_r, 0).astype(jnp.int32)
    out_c = jnp.where(in_range, out_c, 0).astype(jnp.int32)
    out_tiles = jnp.where(in_range[:, None, None], out_tiles, 0)
    return out_r, out_c, out_tiles, count


def leaf_bcsr_spadd3_rows(pos1, crd1, t1, pos2, crd2, t2, pos3, crd3, t3):
    """Fused three-way blocked add over a block-row shard: union of the
    three block coordinate streams, duplicate blocks merged by summing
    their (br, bc) tiles — no scalarization. Returns a padded union block
    stream (brows_local, bcols, tiles, count)."""
    R = pos1.shape[0] - 1
    brows = jnp.concatenate([
        rows_from_pos(pos1, crd1.shape[0]),
        rows_from_pos(pos2, crd2.shape[0]),
        rows_from_pos(pos3, crd3.shape[0]),
    ])
    bcols = jnp.concatenate([crd1, crd2, crd3])
    tiles = jnp.concatenate([t1, t2, t3])
    valid = jnp.concatenate([
        jnp.arange(crd1.shape[0]) < (pos1[-1] - pos1[0]),
        jnp.arange(crd2.shape[0]) < (pos2[-1] - pos2[0]),
        jnp.arange(crd3.shape[0]) < (pos3[-1] - pos3[0]),
    ])
    brows = jnp.where(valid, brows, R).astype(jnp.int32)
    return _tile_union(brows, bcols, tiles, valid)


def leaf_bcsr_spadd_union_chunk(brows, bcols, tiles, count, n_brows):
    """Per-chunk union leaf for the blocked nnz SpAdd strategy: the chunk
    slices the concatenated BLOCK stream of all addends; duplicate blocks
    straddling chunk boundaries merge in the host assembly
    (Tensor.from_blocks dedupe)."""
    n = brows.shape[0]
    valid = jnp.arange(n) < count
    brows = jnp.where(valid, brows, n_brows).astype(jnp.int32)
    return _tile_union(brows, bcols, tiles, valid)


def leaf_bcsr_spadd3_dense(pos1, crd1, t1, pos2, crd2, t2, pos3, crd3, t3,
                           grid_cols):
    """Dense-accumulate variant of the blocked add (the XLA counterpart of
    the bcsr_spadd3 Pallas kernel): scatter-add all three tile streams into
    a dense block grid, return row-major dense (R*br, grid_cols*bc)."""
    R = pos1.shape[0] - 1
    br, bc = t1.shape[1], t1.shape[2]
    out = jnp.zeros((R, grid_cols, br, bc), dtype=t1.dtype)
    for pos, crd, t in ((pos1, crd1, t1), (pos2, crd2, t2), (pos3, crd3, t3)):
        brow = rows_from_pos(pos, crd.shape[0])
        out = out.at[brow, crd].add(t)
    return out.transpose(0, 2, 1, 3).reshape(R * br, grid_cols * bc)


def leaf_spttv_rows(pos1, crd1, pos2, crd2, vals, c):
    """A(i,j) = B(i,j,k)·c(k) over a CSF row shard. Output sparsity equals
    B's (i,j) pattern (paper §V-B) → returns vals aligned with level-1
    positions."""
    n_ij = crd1.shape[0]
    ij_of_nnz = rows_from_pos(pos2, crd2.shape[0])
    prod = vals * jnp.take(c, crd2, axis=0)
    return jax.ops.segment_sum(prod, ij_of_nnz, num_segments=n_ij)


def leaf_spttv_nnz(ij_local, k, vals, c, max_ij):
    prod = vals * jnp.take(c, k, axis=0)
    return jax.ops.segment_sum(prod, ij_local, num_segments=max_ij)


def leaf_spmttkrp_rows(pos1, crd1, pos2, crd2, vals, C, D):
    """A(i,l) = B(i,j,k)·C(j,l)·D(k,l) over a CSF row shard → (R, L)."""
    R = pos1.shape[0] - 1
    ij_of_nnz = rows_from_pos(pos2, crd2.shape[0])   # level-1 position per nnz
    i_of_ij = rows_from_pos(pos1, crd1.shape[0])     # row per level-1 position
    j = jnp.take(crd1, ij_of_nnz, axis=0)
    i = jnp.take(i_of_ij, ij_of_nnz, axis=0)
    contrib = vals[:, None] * jnp.take(C, j, axis=0) * jnp.take(D, crd2, axis=0)
    return jax.ops.segment_sum(contrib, i, num_segments=R)


def leaf_spmttkrp_nnz(i_local, j, k, vals, C, D, max_rows):
    contrib = vals[:, None] * jnp.take(C, j, axis=0) * jnp.take(D, k, axis=0)
    return jax.ops.segment_sum(contrib, i_local, num_segments=max_rows)
