"""Plan-level layout autotuner for the TPU sparse kernels.

Block shapes trade MXU alignment against ELL padding waste, and the right
choice depends on the matrix's row-degree distribution — a structural
property known at plan time. The tuner scores candidate (block_r, block_n)
pairs by a VMEM-aware cost model over the ACTUAL pos array (no execution
needed — this is a materialization-time decision, like the partitioner's
imbalance metric):

    cost = padded_nnz · (1 + onehot_overhead) subject to VMEM fit,

where padded_nnz counts ELL slots (compute ∝ slots on a static grid) and
onehot_overhead = block_r/block_n accounts for the one-hot matmul rows.
Heavy-row matrices therefore prefer small row blocks (less per-block
padding); uniform matrices prefer larger ones (fewer grid steps).
"""
from __future__ import annotations

import dataclasses
import logging
from typing import List, Optional, Sequence, Tuple

import numpy as np

log = logging.getLogger(__name__)

VMEM_BYTES = 16 * 2**20          # ~16 MiB/core usable
DEFAULT_BLOCK_R = (4, 8, 16, 32)
DEFAULT_BLOCK_N = (128, 256, 512)
# Candidate grids for the block-row-group (BCSR) ELL layout, where one
# stored entry is a whole (br, bc) tile rather than a scalar.
DEFAULT_BLOCK_GRID_R = (2, 4, 8, 16)
DEFAULT_BLOCK_GRID_N = (8, 16, 32)


@dataclasses.dataclass
class TuneResult:
    block_r: int
    block_n: int
    padded_nnz: int
    waste: float
    cost: float
    feasible: bool
    # True when no candidate fit VMEM and the smallest tile was returned
    # anyway — callers (e.g. the planner) should skip or penalize the point.
    fallback: bool = False


def ell_cost(pos: np.ndarray, block_r: int, block_n: int,
             dense_cols_bytes: int = 0, *, tile_elems: int = 1,
             vmem_bytes: int = VMEM_BYTES) -> TuneResult:
    """Cost of one (block_r, block_n) ELL layout for a CSR pos array.

    ``tile_elems`` scales the per-entry value footprint for blocked
    layouts, where each stored entry is a dense (br, bc) tile instead of
    one scalar."""
    pos = np.asarray(pos, dtype=np.int64)
    n_rows = pos.shape[0] - 1
    nnz = int(pos[-1])
    n_rb = max(-(-n_rows // block_r), 1)
    bpos = pos[np.minimum(np.arange(n_rb + 1) * block_r, n_rows)]
    bcounts = np.diff(bpos)
    bnnz = int(bcounts.max()) if bcounts.size else 0
    bnnz = max(-(-bnnz // block_n) * block_n, block_n)
    padded = n_rb * bnnz
    waste = 0.0 if padded == 0 else 1.0 - nnz / padded
    # VMEM: rows/crd blocks + value tiles + one-hot tile + output block
    vmem = 2 * block_n * 4 + block_n * 4 * tile_elems \
        + block_r * block_n * 4 + block_r * 4 * tile_elems \
        + dense_cols_bytes
    onehot_overhead = block_r / block_n
    cost = padded * (1.0 + onehot_overhead)
    return TuneResult(block_r, block_n, padded, waste, cost,
                      feasible=vmem <= vmem_bytes)


def tune_ell(pos: np.ndarray, *,
             block_r_candidates: Sequence[int] = DEFAULT_BLOCK_R,
             block_n_candidates: Sequence[int] = DEFAULT_BLOCK_N,
             dense_cols_bytes: int = 0, tile_elems: int = 1,
             vmem_bytes: int = VMEM_BYTES) -> TuneResult:
    """Pick the cheapest feasible (block_r, block_n) for this matrix.

    When no candidate fits VMEM the smallest tile is still returned so
    callers always get a layout, but the fallback is explicit: the result
    carries ``feasible=False, fallback=True`` and a warning is logged."""
    best: Optional[TuneResult] = None
    for br in block_r_candidates:
        for bn in block_n_candidates:
            r = ell_cost(pos, br, bn, dense_cols_bytes,
                         tile_elems=tile_elems, vmem_bytes=vmem_bytes)
            if not r.feasible:
                continue
            if best is None or r.cost < best.cost:
                best = r
    if best is None:  # fall back to the smallest tile — explicitly
        best = ell_cost(pos, min(block_r_candidates),
                        min(block_n_candidates), dense_cols_bytes,
                        tile_elems=tile_elems, vmem_bytes=vmem_bytes)
        best.fallback = True
        log.warning(
            "tune_ell: no (block_r, block_n) candidate fits VMEM "
            "(%d bytes); falling back to smallest tile (%d, %d) with "
            "feasible=False", vmem_bytes, best.block_r, best.block_n)
    return best


def tune_block_ell(pos: np.ndarray, block_shape: Tuple[int, int], *,
                   block_r_candidates: Sequence[int] = DEFAULT_BLOCK_GRID_R,
                   block_n_candidates: Sequence[int] = DEFAULT_BLOCK_GRID_N,
                   dense_cols_bytes: int = 0,
                   vmem_bytes: int = VMEM_BYTES) -> TuneResult:
    """Tune the (block_R, block_nb) Pallas group shape for a blocked-CSR
    shard whose ``pos`` indexes the block grid and whose entries are dense
    ``block_shape`` tiles."""
    br, bc = block_shape
    return tune_ell(pos, block_r_candidates=block_r_candidates,
                    block_n_candidates=block_n_candidates,
                    dense_cols_bytes=dense_cols_bytes,
                    tile_elems=int(br) * int(bc), vmem_bytes=vmem_bytes)


def heavy_row_split(pos: np.ndarray, crd: np.ndarray, vals: np.ndarray,
                    threshold_factor: float = 8.0):
    """Split heavy rows into a COO overflow lane (the ELL waste fix noted
    in DESIGN.md §9): every row keeps at most
    ``cap = ceil(threshold_factor · mean_degree)`` entries in the ELL
    part; the overflow beyond that cap goes to a sorted COO list handled
    by the two-phase segmented-reduction kernel.

    Returns ((pos', crd', vals'), (rows_t, cols_t, vals_t)) — ELL part +
    COO tail. Results combine by addition (both kernels scatter-add)."""
    pos = np.asarray(pos, dtype=np.int64)
    deg = np.diff(pos)
    n = deg.shape[0]
    mean = max(deg.mean(), 1.0)
    cap = int(max(np.ceil(threshold_factor * mean), 1))
    keep_counts = np.minimum(deg, cap)
    new_pos = np.zeros(n + 1, np.int64)
    np.cumsum(keep_counts, out=new_pos[1:])
    new_crd = np.zeros(int(new_pos[-1]), crd.dtype)
    new_vals = np.zeros(int(new_pos[-1]), vals.dtype)
    t_rows, t_cols, t_vals = [], [], []
    for r in range(n):
        lo, hi = int(pos[r]), int(pos[r + 1])
        k = int(keep_counts[r])
        new_crd[new_pos[r]: new_pos[r] + k] = crd[lo: lo + k]
        new_vals[new_pos[r]: new_pos[r] + k] = vals[lo: lo + k]
        if hi - lo > k:
            t_rows.append(np.full(hi - lo - k, r, np.int32))
            t_cols.append(crd[lo + k: hi])
            t_vals.append(vals[lo + k: hi])
    if t_rows:
        tail = (np.concatenate(t_rows), np.concatenate(t_cols),
                np.concatenate(t_vals))
    else:
        tail = (np.zeros(0, np.int32), np.zeros(0, crd.dtype),
                np.zeros(0, vals.dtype))
    return (new_pos.astype(np.int32), new_crd, new_vals), tail
