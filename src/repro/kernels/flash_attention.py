"""Pallas TPU flash attention — the LM stack's perf-critical leaf.

Causal GQA flash attention with explicit BlockSpec VMEM tiling:

- grid = (batch, kv_heads, q_blocks, kv_blocks); KV blocks iterate fastest
  so the output tile and the running (m, l) statistics live across the
  innermost dimension (same accumulation pattern as the sparse ELL kernels).
- queries are pre-reshaped to (B, Hkv, q_blocks·G·block_q, hd) with the G
  query groups of each block stacked row-wise, so one MXU tile is
  (G·block_q, hd) × (hd, block_k) against the UN-repeated K/V block — GQA
  comes for free with no KV repetition (the §Perf iteration-1 lesson,
  applied at kernel level).
- causal masking is positional; fully-masked KV blocks still execute (XLA
  grids are static) — the known ~2× FLOP overhead is the same one the
  roofline reports for the jnp paths.

Validated under interpret=True against models/attention's jnp oracle
(tests/test_flash_kernel.py) across shapes, head counts and group sizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, seq_len: int, groups: int,
                  scale: float):
    kb = pl.program_id(3)
    nkb = pl.num_programs(3)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # q: (G·block_q, hd) — G query groups stacked row-wise
    q = q_ref[0, 0, :, :]
    k = k_ref[0, 0, :, :]                      # (block_k, hd)
    v = v_ref[0, 0, :, :]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qb = pl.program_id(2)
    # rows are group-major: row = g·block_q + r  →  position = qb·block_q + r
    q_pos = qb * block_q + (jax.lax.broadcasted_iota(
        jnp.int32, (groups * block_q, block_k), 0) % block_q)
    kv_pos = kb * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (groups * block_q, block_k), 1)
    mask = (kv_pos <= q_pos) & (kv_pos < seq_len)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                        # (G·block_q, 1)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    # fully-masked block: m_new stays NEG_INF and exp(0)=1 would leak —
    # re-apply the mask to the probabilities
    p = jnp.where(mask, p, 0.0)
    l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kb == nkb - 1)
    def _finish():
        o_ref[0, 0, :, :] = (acc_ref[...] /
                             jnp.maximum(l_ref[...], 1e-30)
                             ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """Causal GQA flash attention.

    q: (B, S, H, hd); k, v: (B, S, Hkv, hd) with H = G·Hkv.
    Returns (B, S, H, hd). S is padded internally to the block sizes.
    """
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    Sp = -(-S // max(block_q, block_k)) * max(block_q, block_k)
    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    # (B, Hkv, G·S?, hd): group-major rows per q block:
    # row index = g * block_q + r within each (G·block_q) tile
    qg = q.reshape(B, Sp, Hkv, G, hd).transpose(0, 2, 3, 1, 4)  # B,K,G,S,hd
    nqb = Sp // block_q
    qg = qg.reshape(B, Hkv, G, nqb, block_q, hd).transpose(0, 1, 3, 2, 4, 5)
    qg = qg.reshape(B, Hkv, nqb * G * block_q, hd)
    kg = k.transpose(0, 2, 1, 3)               # (B, Hkv, Sp, hd)
    vg = v.transpose(0, 2, 1, 3)
    grid = (B, Hkv, nqb, Sp // block_k)
    gq = G * block_q

    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          seq_len=S, groups=G, scale=hd ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, gq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, gq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, nqb * gq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((gq, 1), jnp.float32),   # running max
            pltpu.VMEM((gq, 1), jnp.float32),   # running denom
            pltpu.VMEM((gq, hd), jnp.float32),  # output accum
        ],
        interpret=interpret,
    )(qg, kg, vg)
    # back to (B, S, H, hd)
    out = out.reshape(B, Hkv, nqb, G, block_q, hd).transpose(0, 1, 3, 2, 4, 5)
    out = out.reshape(B, Hkv, G, Sp, hd).transpose(0, 3, 1, 2, 4)
    out = out.reshape(B, Sp, H, hd)
    return out[:, :S]
