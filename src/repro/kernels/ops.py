"""Jit'd wrappers over the sparse kernels — the public compute API.

Every op takes ``impl``:
  - ``"xla"``    — the pure-jnp leaves from ref.py, jitted. Fast on this
                   CPU container; also the lowering used inside pjit'd model
                   code (XLA ops shard/fuse under GSPMD).
  - ``"pallas"`` — the TPU Pallas kernels, run with ``interpret=True`` off
                   TPU. This is the production TPU path; interpret mode
                   exists to validate kernel logic on CPU (per-kernel
                   allclose tests sweep shapes/dtypes against ref.py).

Layout packing (CSR → row-block ELL / padded COO) happens here so callers
hand over plain CSR/COO shard arrays.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .bcsr import bcsr_sddmm, bcsr_spadd3, bcsr_spmm, bcsr_spmv
from .layout import (bcsr_ell_pack, coo_block_pad, ell_pack,
                     pack_mat_inner_blocks, pack_mat_row_blocks,
                     pack_vec_blocks, resolve_bcsr_tile)
from .sddmm import sddmm_coo
from .spadd3 import spadd3_dense_tiles
from .spmm import spmm_ell
from .spmttkrp import spmttkrp_ell
from .spmv import spmv_coo_phase1, spmv_ell

def _interpret() -> bool:
    """Pallas interpret mode everywhere but real TPUs. Evaluated lazily so
    importing this module never initializes the JAX device topology (the
    dry-run must set XLA_FLAGS first)."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# SpMV
# ---------------------------------------------------------------------------

def spmv(pos, crd, vals, c, impl: str = "xla",
         block_r: int = 8, block_n: int = 128):
    """y(n,) = CSR(pos, crd, vals) @ c."""
    if impl == "xla":
        return jax.jit(ref.leaf_spmv_rows)(jnp.asarray(pos), jnp.asarray(crd),
                                           jnp.asarray(vals), jnp.asarray(c))
    blocks, = ell_pack(np.asarray(pos), np.asarray(crd), np.asarray(vals),
                       block_r=block_r, block_n=block_n)
    y = spmv_ell(jnp.asarray(blocks.rows_rel), jnp.asarray(blocks.crd),
                 jnp.asarray(blocks.vals), jnp.asarray(c),
                 block_r=block_r, block_n=block_n, interpret=_interpret())
    return y[: pos.shape[0] - 1]


def spmv_nnz(rows, cols, vals, c, n_rows: int, impl: str = "xla",
             block_n: int = 128):
    """y(n,) from sorted COO — the non-zero strategy leaf + merge."""
    if impl == "xla":
        f = jax.jit(partial(ref.leaf_spmv_nnz, max_rows=n_rows))
        return f(jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals),
                 jnp.asarray(c))
    r, cc, v, _ = coo_block_pad(np.asarray(rows), np.asarray(cols),
                                np.asarray(vals), block_n=block_n)
    psum, prow = spmv_coo_phase1(jnp.asarray(r), jnp.asarray(cc),
                                 jnp.asarray(v), jnp.asarray(c),
                                 block_n=block_n, interpret=_interpret())
    return jax.ops.segment_sum(psum.ravel(), prow.ravel(),
                               num_segments=n_rows)


# ---------------------------------------------------------------------------
# SpMM
# ---------------------------------------------------------------------------

def spmm(pos, crd, vals, C, impl: str = "xla",
         block_r: int = 8, block_n: int = 128, block_j: int = 128):
    """Y(n, J) = CSR @ C(K, J)."""
    if impl == "xla":
        return jax.jit(ref.leaf_spmm_rows)(jnp.asarray(pos), jnp.asarray(crd),
                                           jnp.asarray(vals), jnp.asarray(C))
    blocks, = ell_pack(np.asarray(pos), np.asarray(crd), np.asarray(vals),
                       block_r=block_r, block_n=block_n)
    y = spmm_ell(jnp.asarray(blocks.rows_rel), jnp.asarray(blocks.crd),
                 jnp.asarray(blocks.vals), jnp.asarray(C),
                 block_r=block_r, block_n=block_n, block_j=block_j,
                 interpret=_interpret())
    return y[: pos.shape[0] - 1]


# ---------------------------------------------------------------------------
# Blocked (BCSR) ops — the direct blocked path's public API. Inputs are the
# block-grid CSR arrays (pos over block-rows, crd block-columns, (nb, br,
# bc) value tiles); dense co-operands are packed into matching blocks here.
# ---------------------------------------------------------------------------

def spmv_bcsr(pos, crd, tiles, c, impl: str = "xla",
              block_R=None, block_nb=None):
    """y(grid_rows * br,) = BCSR(pos, crd, tiles) @ c — slice to n_rows.

    ``block_R``/``block_nb`` default to the autotuned group shape
    (``resolve_bcsr_tile``, fallback (8, 16))."""
    tiles = np.asarray(tiles)
    bc = tiles.shape[2]
    grid_cols = -(-np.asarray(c).shape[0] // bc)
    c_blk = pack_vec_blocks(np.asarray(c), grid_cols, bc)
    if impl == "xla":
        return jax.jit(ref.leaf_bcsr_spmv_rows)(
            jnp.asarray(pos), jnp.asarray(crd), jnp.asarray(tiles),
            jnp.asarray(c_blk))
    block_R, block_nb = resolve_bcsr_tile(
        np.asarray(pos), (tiles.shape[1], bc), block_R, block_nb)
    blocks = bcsr_ell_pack(np.asarray(pos), np.asarray(crd), tiles,
                           block_R=block_R, block_nb=block_nb)
    y = bcsr_spmv(jnp.asarray(blocks.brows_rel), jnp.asarray(blocks.crd),
                  jnp.asarray(blocks.vals), jnp.asarray(c_blk),
                  block_R=block_R, block_nb=block_nb,
                  interpret=_interpret())
    return y[: (pos.shape[0] - 1) * tiles.shape[1]]


def spmm_bcsr(pos, crd, tiles, C, impl: str = "xla",
              block_R=None, block_nb=None):
    """Y(grid_rows * br, J) = BCSR @ C(K, J) — slice to n_rows.

    ``block_R``/``block_nb`` default to the autotuned group shape
    (``resolve_bcsr_tile``, fallback (8, 16))."""
    tiles = np.asarray(tiles)
    bc = tiles.shape[2]
    C = np.asarray(C)
    grid_cols = -(-C.shape[0] // bc)
    C_blk = pack_mat_row_blocks(C, grid_cols, bc)
    if impl == "xla":
        return jax.jit(ref.leaf_bcsr_spmm_rows)(
            jnp.asarray(pos), jnp.asarray(crd), jnp.asarray(tiles),
            jnp.asarray(C_blk))
    block_R, block_nb = resolve_bcsr_tile(
        np.asarray(pos), (tiles.shape[1], bc), block_R, block_nb)
    blocks = bcsr_ell_pack(np.asarray(pos), np.asarray(crd), tiles,
                           block_R=block_R, block_nb=block_nb)
    y = bcsr_spmm(jnp.asarray(blocks.brows_rel), jnp.asarray(blocks.crd),
                  jnp.asarray(blocks.vals), jnp.asarray(C_blk),
                  block_R=block_R, block_nb=block_nb,
                  interpret=_interpret())
    return y[: (pos.shape[0] - 1) * tiles.shape[1]]


def sddmm_bcsr(brow, bcol, tiles, C, D, impl: str = "xla",
               block_nb: int = 16):
    """out tiles (nb, br, bc) = tiles ⊙ sampled C(n,K) @ D(K,m) blocks."""
    tiles = np.asarray(tiles)
    br, bc = tiles.shape[1], tiles.shape[2]
    C, D = np.asarray(C), np.asarray(D)
    C_blk = pack_mat_row_blocks(C, -(-C.shape[0] // br), br)
    D_blk = pack_mat_inner_blocks(D, -(-D.shape[1] // bc), bc)
    if impl == "xla":
        return jax.jit(ref.leaf_bcsr_sddmm)(
            jnp.asarray(brow), jnp.asarray(bcol), jnp.asarray(tiles),
            jnp.asarray(C_blk), jnp.asarray(D_blk))
    nb = tiles.shape[0]
    pad = -(-max(nb, 1) // block_nb) * block_nb - nb
    bpad = np.concatenate([np.asarray(brow, np.int32),
                           np.zeros(pad, np.int32)])
    cpad = np.concatenate([np.asarray(bcol, np.int32),
                           np.zeros(pad, np.int32)])
    tpad = np.concatenate([tiles, np.zeros((pad, br, bc), tiles.dtype)])
    out = bcsr_sddmm(jnp.asarray(bpad), jnp.asarray(cpad),
                     jnp.asarray(tpad), jnp.asarray(C_blk),
                     jnp.asarray(D_blk), block_nb=block_nb,
                     interpret=_interpret())
    return out[:nb]


def spadd3_bcsr_dense(bcsr1, bcsr2, bcsr3, n_rows: int, n_cols: int,
                      impl: str = "pallas", block_R=None):
    """Dense(n, m) = B + C + D from three blocked (pos, crd, tiles)
    triples sharing one block shape — the fused blocked add.

    ``block_R`` defaults to the autotuned group shape for the first
    operand's structure; one value is used for all three packs (the
    fused kernel iterates the three group grids in lockstep)."""
    t1 = np.asarray(bcsr1[2])
    bc = t1.shape[2]
    grid_cols = -(-n_cols // bc)
    if impl == "xla":
        f = jax.jit(partial(ref.leaf_bcsr_spadd3_dense, grid_cols=grid_cols))
        dense = f(*(jnp.asarray(x) for t in (bcsr1, bcsr2, bcsr3)
                    for x in t))
        return dense[:n_rows, :n_cols]
    block_R, _ = resolve_bcsr_tile(np.asarray(bcsr1[0]),
                                   (t1.shape[1], bc), block_R, None)
    packed = [bcsr_ell_pack(np.asarray(p), np.asarray(c), np.asarray(t),
                            block_R=block_R)
              for (p, c, t) in (bcsr1, bcsr2, bcsr3)]
    return bcsr_spadd3(*packed, n_rows=n_rows, n_cols=n_cols,
                       block_R=block_R, interpret=_interpret())


# ---------------------------------------------------------------------------
# SDDMM
# ---------------------------------------------------------------------------

def sddmm(rows, cols, vals, C, D, impl: str = "xla", block_n: int = 128):
    """out_vals(nnz,) = vals ⊙ (C @ D) sampled at (rows, cols)."""
    if impl == "xla":
        return jax.jit(ref.leaf_sddmm_nnz)(
            jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals),
            jnp.asarray(C), jnp.asarray(D))
    nnz = rows.shape[0]
    r, cc, v, _ = coo_block_pad(np.asarray(rows), np.asarray(cols),
                                np.asarray(vals), block_n=block_n)
    out = sddmm_coo(jnp.asarray(r), jnp.asarray(cc), jnp.asarray(v),
                    jnp.asarray(C), jnp.asarray(D), block_n=block_n,
                    interpret=_interpret())
    return out[:nnz]


# ---------------------------------------------------------------------------
# SpAdd3 (fused three-way add)
# ---------------------------------------------------------------------------

def spadd3_dense(csr1, csr2, csr3, n_rows: int, n_cols: int,
                 impl: str = "xla", block_r: int = 8, block_m: int = 128):
    """Dense(n, m) = B + C + D from three CSR triples (pos, crd, vals)."""
    if impl == "xla":
        f = jax.jit(partial(ref.leaf_spadd3_dense_rows, n_cols=n_cols))
        return f(*(jnp.asarray(x) for t in (csr1, csr2, csr3) for x in t))
    packed = []
    for pos, crd, vals in (csr1, csr2, csr3):
        blocks, = ell_pack(np.asarray(pos), np.asarray(crd), np.asarray(vals),
                           block_r=block_r, block_n=block_m)
        packed += [jnp.asarray(blocks.rows_rel), jnp.asarray(blocks.crd),
                   jnp.asarray(blocks.vals)]
    return spadd3_dense_tiles(*packed, n_rows=n_rows, n_cols=n_cols,
                              block_r=block_r, block_m=block_m,
                              interpret=_interpret())


# ---------------------------------------------------------------------------
# SpTTV — reuses the SpMV ELL kernel over level-1 positions
# ---------------------------------------------------------------------------

def spttv(pos1, crd1, pos2, crd2, vals, c, impl: str = "xla",
          block_r: int = 8, block_n: int = 128):
    """out_vals aligned with B's (i,j) positions (pattern-preserving)."""
    if impl == "xla":
        return jax.jit(ref.leaf_spttv_rows)(
            jnp.asarray(pos1), jnp.asarray(crd1), jnp.asarray(pos2),
            jnp.asarray(crd2), jnp.asarray(vals), jnp.asarray(c))
    n_ij = crd1.shape[0]
    blocks, = ell_pack(np.asarray(pos2), np.asarray(crd2), np.asarray(vals),
                       block_r=block_r, block_n=block_n)
    out = spmv_ell(jnp.asarray(blocks.rows_rel), jnp.asarray(blocks.crd),
                   jnp.asarray(blocks.vals), jnp.asarray(c),
                   block_r=block_r, block_n=block_n, interpret=_interpret())
    return out[:n_ij]


# ---------------------------------------------------------------------------
# SpMTTKRP
# ---------------------------------------------------------------------------

def spmttkrp(pos1, crd1, pos2, crd2, vals, C, D, impl: str = "xla",
             block_r: int = 8, block_n: int = 128):
    """A(n, L) = B(i,j,k)·C(j,l)·D(k,l) from a CSF shard."""
    if impl == "xla":
        return jax.jit(ref.leaf_spmttkrp_rows)(
            jnp.asarray(pos1), jnp.asarray(crd1), jnp.asarray(pos2),
            jnp.asarray(crd2), jnp.asarray(vals), jnp.asarray(C),
            jnp.asarray(D))
    # flatten CSF: per-nnz (i, j, k); rows = i from pos1∘pos2
    pos1_np, pos2_np = np.asarray(pos1, np.int64), np.asarray(pos2, np.int64)
    i_of_ij = np.repeat(np.arange(pos1_np.shape[0] - 1), np.diff(pos1_np))
    ij_of_nnz = np.repeat(np.arange(pos2_np.shape[0] - 1), np.diff(pos2_np))
    i_per_nnz = i_of_ij[ij_of_nnz]
    j_per_nnz = np.asarray(crd1)[ij_of_nnz]
    # rebuild a pos over i for ell packing
    n_rows = pos1_np.shape[0] - 1
    counts = np.bincount(i_per_nnz, minlength=n_rows)
    pos_i = np.zeros(n_rows + 1, np.int64)
    np.cumsum(counts, out=pos_i[1:])
    blocks, kk = ell_pack(pos_i, j_per_nnz.astype(np.int32),
                          np.asarray(vals), block_r=block_r,
                          block_n=block_n,
                          extra=(np.asarray(crd2, np.int32),))
    out = spmttkrp_ell(jnp.asarray(blocks.rows_rel), jnp.asarray(blocks.crd),
                       jnp.asarray(kk), jnp.asarray(blocks.vals),
                       jnp.asarray(C), jnp.asarray(D),
                       block_r=block_r, block_n=block_n,
                       interpret=_interpret())
    return out[:n_rows]
