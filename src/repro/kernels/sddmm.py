"""Pallas TPU SDDMM kernel — ``A(i,j) = B(i,j) · C(i,k) · D(k,j)``.

The paper's SDDMM uses a *non-zero based* algorithm and data distribution
(§VI-A: "achieves near perfect speedup due to its load balanced approach").
The leaf here matches: a flat nnz-block grid over the equal-nnz COO shard;
each step gathers the C rows / D columns its coordinates touch and forms the
sampled inner products on the VPU:

    out[nnz_blk] = vals ⊙ Σ_k C[rows, k] · D[k, cols]

The k reduction stays in registers (C gathered (block_n, K), D passed
pre-transposed so its gather is also row-major). Output is dense in the
position space — the paper's "sparsity pattern of the input is preserved in
the output" fast path (§V-B), so no assembly is needed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import formats as fmt


def supports(format: "fmt.Format", space: str) -> bool:
    """Format-dispatch query. SDDMM is pattern-preserving: the non-zero
    leaf is storage-order agnostic (per-position sampled products), so any
    unblocked sparse format works under nnz — including CSC, whose vals
    simply stay in column-major position order. Universe needs the
    row-window view. BCSR lowers directly to sampled block products
    (kernels/bcsr.py), the output tiles staying aligned with the stored
    block positions."""
    return fmt.supports_2d_default(format, space)


def _sddmm_kernel(rows_ref, cols_ref, vals_ref, c_ref, dt_ref, out_ref):
    rows = rows_ref[0, :]
    cols = cols_ref[0, :]
    vals = vals_ref[0, :]
    cg = jnp.take(c_ref[...], rows, axis=0)    # (block_n, K)
    dg = jnp.take(dt_ref[...], cols, axis=0)   # (block_n, K)  (D.T gather)
    out_ref[0, :] = vals * jnp.sum(cg * dg, axis=1)


def sddmm_coo(rows: jax.Array, cols: jax.Array, vals: jax.Array,
              C: jax.Array, D: jax.Array, *, block_n: int = 128,
              interpret: bool = True) -> jax.Array:
    """Returns out_vals (nnz,), aligned with the COO positions.

    ``rows``/``cols`` may contain out-of-range sentinels for padding; their
    vals are zero so the gather result is multiplied away (indices are
    clipped to stay in range).
    """
    nnz = rows.shape[0]
    assert nnz % block_n == 0
    nb = nnz // block_n
    n, K = C.shape
    m = D.shape[1]
    Dt = D.T  # row-major gather on TPU
    rows_c = jnp.clip(rows, 0, n - 1).reshape(nb, block_n)
    cols_c = jnp.clip(cols, 0, m - 1).reshape(nb, block_n)
    v2 = vals.reshape(nb, block_n)
    out = pl.pallas_call(
        _sddmm_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda b: (b, 0)),
            pl.BlockSpec((1, block_n), lambda b: (b, 0)),
            pl.BlockSpec((1, block_n), lambda b: (b, 0)),
            pl.BlockSpec(C.shape, lambda b: (0, 0)),
            pl.BlockSpec(Dt.shape, lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block_n), vals.dtype),
        interpret=interpret,
    )(rows_c, cols_c, v2, C, Dt)
    return out.reshape(nnz)
