"""TPU kernel-facing sparse layouts.

The paper's CSR leaves walk variable-length rows — natural on CPUs, adequate
on GPUs with atomics, but hostile to the TPU's static-shape, MXU-aligned
execution model. The TPU adaptation (DESIGN.md §2) re-blocks a CSR shard
into a **row-block ELL** layout:

- rows are grouped into blocks of ``block_r`` (MXU sublane-aligned);
- each row block's non-zeros are padded to the max across blocks, rounded up
  to a multiple of ``block_n`` (lane-aligned);
- per non-zero we store the *relative row* within its block (``rows_rel``),
  the column (``crd``) and the value.

A Pallas kernel then processes a (row-block × nnz-block) grid where the
segmented reduction becomes a dense one-hot matmul on the MXU:
``out[block_r] += onehot(rows_rel)[block_r, block_n] @ prod[block_n]``.
Padding slots carry ``rows_rel = block_r`` (no row selected) and
``vals = 0``.

``ell_pack`` is a plan/materialize-time transformation (host numpy), i.e.
part of the format machinery, not the compute hot path. Its padding waste is
reported just like partition imbalance.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

INT = np.int32

# Fallback Pallas group shape for blocked shards when the tuner has no
# feasible candidate (or is bypassed by an explicit caller choice).
FALLBACK_BLOCK_R = 8
FALLBACK_BLOCK_NB = 16


def resolve_bcsr_tile(pos: np.ndarray, block_shape: Tuple[int, int],
                      block_R: Optional[int] = None,
                      block_nb: Optional[int] = None) -> Tuple[int, int]:
    """Resolve the (block_R, block_nb) group shape for a blocked shard.

    Explicit values win; unset dimensions come from ``tune_block_ell``
    over the block-grid pos, with the historical (8, 16) defaults as the
    fallback when the tuner reports no feasible candidate."""
    if block_R is not None and block_nb is not None:
        return int(block_R), int(block_nb)
    from .autotune import tune_block_ell
    r = tune_block_ell(np.asarray(pos), block_shape)
    if r.fallback:
        return (int(block_R) if block_R is not None else FALLBACK_BLOCK_R,
                int(block_nb) if block_nb is not None else FALLBACK_BLOCK_NB)
    return (int(block_R) if block_R is not None else r.block_r,
            int(block_nb) if block_nb is not None else r.block_n)


@dataclasses.dataclass
class EllBlocks:
    """Row-block ELL arrays: all shaped (n_rblocks, bnnz)."""

    rows_rel: np.ndarray   # relative row in block; == block_r marks padding
    crd: np.ndarray        # column (or inner coordinate) per nnz
    vals: np.ndarray
    block_r: int
    n_rows: int

    @property
    def n_rblocks(self) -> int:
        return self.rows_rel.shape[0]

    @property
    def bnnz(self) -> int:
        return self.rows_rel.shape[1]

    def padding_waste(self) -> float:
        alloc = self.vals.size
        real = int((self.rows_rel < self.block_r).sum())
        return 0.0 if alloc == 0 else 1.0 - real / alloc


def ell_pack(pos: np.ndarray, crd: np.ndarray, vals: np.ndarray,
             block_r: int = 8, block_n: int = 128,
             extra: Tuple[np.ndarray, ...] = ()) -> Tuple[EllBlocks, ...]:
    """Re-block a CSR-like (pos, crd, vals) into row-block ELL.

    ``extra`` carries additional per-nnz arrays (e.g. the second coordinate
    of a CSF tensor) packed with the same permutation. Returns
    ``(EllBlocks, *extra_packed)``.
    """
    pos = np.asarray(pos, dtype=np.int64)
    n_rows = pos.shape[0] - 1
    nnz = int(pos[-1])
    n_rblocks = max(-(-n_rows // block_r), 1)
    # nnz per row block
    bpos = pos[np.minimum(np.arange(n_rblocks + 1) * block_r, n_rows)]
    bcounts = np.diff(bpos)
    bnnz = int(bcounts.max()) if n_rblocks else 0
    bnnz = max(-(-bnnz // block_n) * block_n, block_n)

    rows = np.repeat(np.arange(n_rows, dtype=np.int64), np.diff(pos))
    rr = np.full((n_rblocks, bnnz), block_r, dtype=INT)
    cc = np.zeros((n_rblocks, bnnz), dtype=INT)
    vv = np.zeros((n_rblocks, bnnz), dtype=vals.dtype)
    packed_extra = [np.zeros((n_rblocks, bnnz), dtype=INT) for _ in extra]
    for b in range(n_rblocks):
        lo, hi = int(bpos[b]), int(bpos[b + 1])
        k = hi - lo
        rr[b, :k] = (rows[lo:hi] - b * block_r).astype(INT)
        cc[b, :k] = crd[lo:hi]
        vv[b, :k] = vals[lo:hi]
        for e, src in enumerate(extra):
            packed_extra[e][b, :k] = src[lo:hi]
    blocks = EllBlocks(rows_rel=rr, crd=cc, vals=vv, block_r=block_r,
                       n_rows=n_rows)
    return (blocks, *packed_extra)


@dataclasses.dataclass
class BcsrEllBlocks:
    """Block-row-group ELL arrays for a blocked (BCSR) shard.

    The scalar row-block ELL lifted one level: groups of ``block_R``
    BLOCK-rows, each group's stored blocks padded to a lane-aligned count;
    per stored block we keep the relative block-row, the block-column and
    the dense (br, bc) value tile. ``brows_rel == block_R`` marks padding
    (zero tiles)."""

    brows_rel: np.ndarray   # (n_groups, bnnz)
    crd: np.ndarray         # (n_groups, bnnz) block-columns
    vals: np.ndarray        # (n_groups, bnnz, br, bc) tiles
    block_R: int
    n_brows: int

    def padding_waste(self) -> float:
        alloc = self.brows_rel.size
        real = int((self.brows_rel < self.block_R).sum())
        return 0.0 if alloc == 0 else 1.0 - real / alloc


def bcsr_ell_pack(pos: np.ndarray, crd: np.ndarray, tiles: np.ndarray,
                  block_R: Optional[int] = None,
                  block_nb: Optional[int] = None) -> BcsrEllBlocks:
    """Re-block a blocked-CSR (pos, crd, (nb, br, bc) tiles) into
    block-row-group ELL for the Pallas bcsr kernels.

    ``block_R``/``block_nb`` default to the autotuned group shape for
    this shard's structure (``resolve_bcsr_tile``); pass explicit values
    to pin a shape (e.g. from a schedule's ``tile_hint``)."""
    block_R, block_nb = resolve_bcsr_tile(
        pos, (tiles.shape[1], tiles.shape[2]), block_R, block_nb)
    pos = np.asarray(pos, dtype=np.int64)
    n_brows = pos.shape[0] - 1
    n_groups = max(-(-n_brows // block_R), 1)
    gpos = pos[np.minimum(np.arange(n_groups + 1) * block_R, n_brows)]
    gcounts = np.diff(gpos)
    bnnz = int(gcounts.max()) if n_groups else 0
    bnnz = max(-(-bnnz // block_nb) * block_nb, block_nb)
    brows = np.repeat(np.arange(n_brows, dtype=np.int64), np.diff(pos))
    br, bc = tiles.shape[1], tiles.shape[2]
    rr = np.full((n_groups, bnnz), block_R, dtype=INT)
    cc = np.zeros((n_groups, bnnz), dtype=INT)
    vv = np.zeros((n_groups, bnnz, br, bc), dtype=tiles.dtype)
    for g in range(n_groups):
        lo, hi = int(gpos[g]), int(gpos[g + 1])
        k = hi - lo
        rr[g, :k] = (brows[lo:hi] - g * block_R).astype(INT)
        cc[g, :k] = crd[lo:hi]
        vv[g, :k] = tiles[lo:hi]
    return BcsrEllBlocks(brows_rel=rr, crd=cc, vals=vv, block_R=block_R,
                         n_brows=n_brows)


# -- dense-operand packing for the blocked leaves ---------------------------
# Reshape unblocked co-operands into blocks aligned with a blocked sparse
# operand's (br, bc) grid. Host-side materialize-time work, numpy only so
# core.lower can call these without importing the Pallas modules.

def pack_vec_blocks(c: np.ndarray, grid_cols: int, bc: int) -> np.ndarray:
    """Dense vector (m,) → column blocks (grid_cols, bc), zero-padded."""
    c = np.asarray(c)
    out = np.zeros((grid_cols * bc,), dtype=c.dtype)
    out[: c.shape[0]] = c
    return out.reshape(grid_cols, bc)


def pack_mat_row_blocks(C: np.ndarray, grid: int, b: int) -> np.ndarray:
    """Dense matrix (n, K) → leading-dim blocks (grid, b, K), zero-padded."""
    C = np.asarray(C)
    out = np.zeros((grid * b, C.shape[1]), dtype=C.dtype)
    out[: C.shape[0]] = C
    return out.reshape(grid, b, C.shape[1])


def pack_rowwindow_blocks(Cv: np.ndarray, max_brows: int, b: int,
                          ) -> np.ndarray:
    """Per-color dense row windows (P, max_rows, K) → block-grid row
    blocks (P, max_brows, b, K), zero-padding rows past each window (the
    local C operand of the blocked row-based SDDMM)."""
    Cv = np.asarray(Cv)
    pad = max_brows * b - Cv.shape[1]
    Cv = np.pad(Cv, ((0, 0), (0, max(pad, 0)), (0, 0)))[:, : max_brows * b]
    return Cv.reshape(Cv.shape[0], max_brows, b, Cv.shape[2])


def pack_mat_inner_blocks(D: np.ndarray, grid: int, b: int) -> np.ndarray:
    """Dense matrix (K, m) → trailing-dim blocks (grid, K, b): the column
    blocks an SDDMM leaf gathers by block-column."""
    D = np.asarray(D)
    out = np.zeros((D.shape[0], grid * b), dtype=D.dtype)
    out[:, : D.shape[1]] = D
    return np.ascontiguousarray(
        out.reshape(D.shape[0], grid, b).transpose(1, 0, 2))


def coo_block_pad(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                  block_n: int = 128):
    """Pad sorted COO arrays to a multiple of ``block_n`` for the two-phase
    segmented-reduction kernel (padding rows get a sentinel id)."""
    nnz = rows.shape[0]
    n = max(-(-nnz // block_n) * block_n, block_n)
    sentinel = int(rows.max()) + 1 if nnz else 0
    r = np.full(n, sentinel, dtype=INT)
    c = np.zeros(n, dtype=INT)
    v = np.zeros(n, dtype=vals.dtype)
    r[:nnz], c[:nnz], v[:nnz] = rows, cols, vals
    return r, c, v, sentinel
