"""TPU kernel-facing sparse layouts.

The paper's CSR leaves walk variable-length rows — natural on CPUs, adequate
on GPUs with atomics, but hostile to the TPU's static-shape, MXU-aligned
execution model. The TPU adaptation (DESIGN.md §2) re-blocks a CSR shard
into a **row-block ELL** layout:

- rows are grouped into blocks of ``block_r`` (MXU sublane-aligned);
- each row block's non-zeros are padded to the max across blocks, rounded up
  to a multiple of ``block_n`` (lane-aligned);
- per non-zero we store the *relative row* within its block (``rows_rel``),
  the column (``crd``) and the value.

A Pallas kernel then processes a (row-block × nnz-block) grid where the
segmented reduction becomes a dense one-hot matmul on the MXU:
``out[block_r] += onehot(rows_rel)[block_r, block_n] @ prod[block_n]``.
Padding slots carry ``rows_rel = block_r`` (no row selected) and
``vals = 0``.

``ell_pack`` is a plan/materialize-time transformation (host numpy), i.e.
part of the format machinery, not the compute hot path. Its padding waste is
reported just like partition imbalance.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

INT = np.int32


@dataclasses.dataclass
class EllBlocks:
    """Row-block ELL arrays: all shaped (n_rblocks, bnnz)."""

    rows_rel: np.ndarray   # relative row in block; == block_r marks padding
    crd: np.ndarray        # column (or inner coordinate) per nnz
    vals: np.ndarray
    block_r: int
    n_rows: int

    @property
    def n_rblocks(self) -> int:
        return self.rows_rel.shape[0]

    @property
    def bnnz(self) -> int:
        return self.rows_rel.shape[1]

    def padding_waste(self) -> float:
        alloc = self.vals.size
        real = int((self.rows_rel < self.block_r).sum())
        return 0.0 if alloc == 0 else 1.0 - real / alloc


def ell_pack(pos: np.ndarray, crd: np.ndarray, vals: np.ndarray,
             block_r: int = 8, block_n: int = 128,
             extra: Tuple[np.ndarray, ...] = ()) -> Tuple[EllBlocks, ...]:
    """Re-block a CSR-like (pos, crd, vals) into row-block ELL.

    ``extra`` carries additional per-nnz arrays (e.g. the second coordinate
    of a CSF tensor) packed with the same permutation. Returns
    ``(EllBlocks, *extra_packed)``.
    """
    pos = np.asarray(pos, dtype=np.int64)
    n_rows = pos.shape[0] - 1
    nnz = int(pos[-1])
    n_rblocks = max(-(-n_rows // block_r), 1)
    # nnz per row block
    bpos = pos[np.minimum(np.arange(n_rblocks + 1) * block_r, n_rows)]
    bcounts = np.diff(bpos)
    bnnz = int(bcounts.max()) if n_rblocks else 0
    bnnz = max(-(-bnnz // block_n) * block_n, block_n)

    rows = np.repeat(np.arange(n_rows, dtype=np.int64), np.diff(pos))
    rr = np.full((n_rblocks, bnnz), block_r, dtype=INT)
    cc = np.zeros((n_rblocks, bnnz), dtype=INT)
    vv = np.zeros((n_rblocks, bnnz), dtype=vals.dtype)
    packed_extra = [np.zeros((n_rblocks, bnnz), dtype=INT) for _ in extra]
    for b in range(n_rblocks):
        lo, hi = int(bpos[b]), int(bpos[b + 1])
        k = hi - lo
        rr[b, :k] = (rows[lo:hi] - b * block_r).astype(INT)
        cc[b, :k] = crd[lo:hi]
        vv[b, :k] = vals[lo:hi]
        for e, src in enumerate(extra):
            packed_extra[e][b, :k] = src[lo:hi]
    blocks = EllBlocks(rows_rel=rr, crd=cc, vals=vv, block_r=block_r,
                       n_rows=n_rows)
    return (blocks, *packed_extra)


def coo_block_pad(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                  block_n: int = 128):
    """Pad sorted COO arrays to a multiple of ``block_n`` for the two-phase
    segmented-reduction kernel (padding rows get a sentinel id)."""
    nnz = rows.shape[0]
    n = max(-(-nnz // block_n) * block_n, block_n)
    sentinel = int(rows.max()) + 1 if nnz else 0
    r = np.full(n, sentinel, dtype=INT)
    c = np.zeros(n, dtype=INT)
    v = np.zeros(n, dtype=vals.dtype)
    r[:nnz], c[:nnz], v[:nnz] = rows, cols, vals
    return r, c, v, sentinel
