"""Pallas TPU blocked (BCSR) kernels — the direct blocked execution path.

SpDISTAL's thesis is that compiling for the *declared* format beats
converting (paper §IV, §VI); for blocked formats the declared structure is
exactly what the MXU wants: every stored position carries a dense
``(br, bc)`` value tile, so the leaf's inner op is a dense tile matmul
instead of the scalarized gather+segment-sum the bcsr→csr fallback paid.

All four 2-D families get a blocked leaf, each a lift of its scalar kernel
to block granularity over the ``layout.bcsr_ell_pack`` arrays:

- :func:`bcsr_spmv`   — grid (block-row group × block chunk); per block a
  ``(br, bc) @ (bc,)`` tile matvec, then the one-hot segmented-reduction
  trick from ``spmv.py`` applied to BLOCK-rows:
  ``out[block_R, br] += onehot(brows_rel) @ prod[chunk, br]``.
- :func:`bcsr_spmm`   — same grid; per block a ``(br, bc) @ (bc, J)`` MXU
  matmul against the j-blocked dense operand.
- :func:`bcsr_sddmm`  — flat block-chunk grid; sampled
  ``C[brow] @ D[bcol]`` tile products, output tiles aligned with the
  stored block positions (pattern-preserving, §V-B).
- :func:`bcsr_spadd3` — dense block-row-group accumulation of three
  operands' tile streams via row/col one-hots (the ``spadd3.py`` scatter at
  block granularity).

The dense co-operands arrive pre-reshaped into blocks matching the sparse
operand's blocking (``pack_*`` helpers below); boundary blocks of a
non-divisible shape keep their zero padding, which multiplies away and is
sliced off by the caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .layout import (bcsr_ell_pack, pack_mat_inner_blocks,
                     pack_mat_row_blocks, pack_vec_blocks)

# Format dispatch for these leaves lives in the kernel-family modules
# (spmv/spmm/sddmm/spadd3 supports() via formats.supports_2d_default's
# blocked clause) — this module only provides the kernels.


# ---------------------------------------------------------------------------
# SpMV — block-row-group × block-chunk grid
# ---------------------------------------------------------------------------

def _bcsr_spmv_kernel(brows_ref, crd_ref, bvals_ref, c_ref, out_ref, *,
                      block_R: int):
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    brows = brows_ref[0]                        # (chunk,) relative block-rows
    crd = crd_ref[0]                            # (chunk,) block-columns
    bv = bvals_ref[0]                           # (chunk, br, bc) tiles
    cg = jnp.take(c_ref[...], crd, axis=0)      # (chunk, bc) VMEM gather
    prod = jnp.einsum("nrc,nc->nr", bv, cg)     # per-tile (br,bc)@(bc,)
    iota = jax.lax.broadcasted_iota(jnp.int32, (block_R, brows.shape[0]), 0)
    onehot = (iota == brows[None, :]).astype(prod.dtype)
    out_ref[0] += onehot @ prod                 # block-granular segmented sum


def bcsr_spmv(brows_rel: jax.Array, crd: jax.Array, bvals: jax.Array,
              c_blk: jax.Array, *, block_R: int = 8, block_nb: int = 16,
              interpret: bool = True) -> jax.Array:
    """Returns y of shape (n_groups * block_R * br,).

    Inputs are ``layout.bcsr_ell_pack`` arrays; ``c_blk`` is the dense
    vector in column blocks (grid_cols, bc)."""
    n_groups, bnnz = brows_rel.shape
    br = bvals.shape[2]
    assert bnnz % block_nb == 0
    grid = (n_groups, bnnz // block_nb)
    out = pl.pallas_call(
        functools.partial(_bcsr_spmv_kernel, block_R=block_R),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_nb), lambda g, n: (g, n)),
            pl.BlockSpec((1, block_nb), lambda g, n: (g, n)),
            pl.BlockSpec((1, block_nb) + bvals.shape[2:],
                         lambda g, n: (g, n, 0, 0)),
            pl.BlockSpec(c_blk.shape, lambda g, n: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_R, br), lambda g, n: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_groups, block_R, br), bvals.dtype),
        interpret=interpret,
    )(brows_rel, crd, bvals, c_blk)
    return out.reshape(n_groups * block_R * br)


# ---------------------------------------------------------------------------
# SpMM — per block a dense (br, bc) @ (bc, J) MXU matmul
# ---------------------------------------------------------------------------

def _bcsr_spmm_kernel(brows_ref, crd_ref, bvals_ref, c_ref, out_ref, *,
                      block_R: int):
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    brows = brows_ref[0]
    crd = crd_ref[0]
    bv = bvals_ref[0]                            # (chunk, br, bc)
    cg = jnp.take(c_ref[...], crd, axis=0)       # (chunk, bc, J)
    prod = jnp.einsum("nrc,ncj->nrj", bv, cg)    # dense tile matmuls (MXU)
    iota = jax.lax.broadcasted_iota(jnp.int32, (block_R, brows.shape[0]), 0)
    onehot = (iota == brows[None, :]).astype(prod.dtype)
    out_ref[0] += jnp.einsum("Rn,nrj->Rrj", onehot, prod)


def bcsr_spmm(brows_rel: jax.Array, crd: jax.Array, bvals: jax.Array,
              C_blk: jax.Array, *, block_R: int = 8, block_nb: int = 16,
              interpret: bool = True) -> jax.Array:
    """Returns Y of shape (n_groups * block_R * br, J). ``C_blk`` is the
    dense operand in row blocks (grid_cols, bc, J); J stays VMEM-resident
    (j-block with multiple calls for very wide J, see spmm.py)."""
    n_groups, bnnz = brows_rel.shape
    br = bvals.shape[2]
    J = C_blk.shape[2]
    assert bnnz % block_nb == 0
    grid = (n_groups, bnnz // block_nb)
    out = pl.pallas_call(
        functools.partial(_bcsr_spmm_kernel, block_R=block_R),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_nb), lambda g, n: (g, n)),
            pl.BlockSpec((1, block_nb), lambda g, n: (g, n)),
            pl.BlockSpec((1, block_nb) + bvals.shape[2:],
                         lambda g, n: (g, n, 0, 0)),
            pl.BlockSpec(C_blk.shape, lambda g, n: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_R, br, J), lambda g, n: (g, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_groups, block_R, br, J),
                                       bvals.dtype),
        interpret=interpret,
    )(brows_rel, crd, bvals, C_blk)
    return out.reshape(n_groups * block_R * br, J)


# ---------------------------------------------------------------------------
# SDDMM — sampled C-row-block @ D-col-block tile products
# ---------------------------------------------------------------------------

def _bcsr_sddmm_kernel(brow_ref, bcol_ref, bvals_ref, c_ref, d_ref, out_ref):
    brow = brow_ref[0]
    bcol = bcol_ref[0]
    bv = bvals_ref[0]                            # (chunk, br, bc)
    cg = jnp.take(c_ref[...], brow, axis=0)      # (chunk, br, K)
    dg = jnp.take(d_ref[...], bcol, axis=0)      # (chunk, K, bc)
    out_ref[0] = bv * jnp.einsum("nrk,nkc->nrc", cg, dg)


def bcsr_sddmm(brow: jax.Array, bcol: jax.Array, bvals: jax.Array,
               C_blk: jax.Array, D_blk: jax.Array, *, block_nb: int = 16,
               interpret: bool = True) -> jax.Array:
    """Returns out tiles (n_blocks_padded, br, bc) aligned with the stored
    block positions. ``brow``/``bcol`` are GLOBAL block coordinates
    (clipped for padding slots — their tiles are zero); ``C_blk``
    (grid_rows, br, K), ``D_blk`` (grid_cols, K, bc)."""
    nb = brow.shape[0]
    assert nb % block_nb == 0
    n_chunks = nb // block_nb
    br, bc = bvals.shape[1], bvals.shape[2]
    b2 = brow.reshape(n_chunks, block_nb)
    c2 = bcol.reshape(n_chunks, block_nb)
    v2 = bvals.reshape(n_chunks, block_nb, br, bc)
    out = pl.pallas_call(
        _bcsr_sddmm_kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((1, block_nb), lambda g: (g, 0)),
            pl.BlockSpec((1, block_nb), lambda g: (g, 0)),
            pl.BlockSpec((1, block_nb, br, bc), lambda g: (g, 0, 0, 0)),
            pl.BlockSpec(C_blk.shape, lambda g: (0, 0, 0)),
            pl.BlockSpec(D_blk.shape, lambda g: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_nb, br, bc), lambda g: (g, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, block_nb, br, bc),
                                       bvals.dtype),
        interpret=interpret,
    )(b2, c2, v2, C_blk, D_blk)
    return out.reshape(nb, br, bc)


# ---------------------------------------------------------------------------
# SpAdd3 — dense block-row-group accumulation of three tile streams
# ---------------------------------------------------------------------------

def _bcsr_spadd3_kernel(r1, c1, v1, r2, c2, v2, r3, c3, v3, out_ref, *,
                        block_R: int, grid_cols: int):
    def scatter(brows_ref, bcols_ref, tiles_ref):
        brows = brows_ref[0]
        bcols = bcols_ref[0]
        tiles = tiles_ref[0]                     # (chunk, br, bc)
        n = brows.shape[0]
        iota_r = jax.lax.broadcasted_iota(jnp.int32, (block_R, n), 0)
        row_oh = (iota_r == brows[None, :]).astype(tiles.dtype)
        iota_c = jax.lax.broadcasted_iota(jnp.int32, (n, grid_cols), 1)
        col_oh = (iota_c == bcols[:, None]).astype(tiles.dtype)
        # both scatters are one-hot contractions at block granularity
        return jnp.einsum("Rn,nG,nrc->RrGc", row_oh, col_oh, tiles)

    out_ref[0] = (scatter(r1, c1, v1) + scatter(r2, c2, v2)
                  + scatter(r3, c3, v3))


def bcsr_spadd3(packed1, packed2, packed3, *, n_rows: int, n_cols: int,
                block_R: int = 8, interpret: bool = True) -> jax.Array:
    """Fused three-way blocked add into dense rows.

    Each ``packed`` is a ``layout.bcsr_ell_pack`` result over the SAME
    block-row grouping; returns dense (n_rows, n_cols) with the block
    padding sliced off."""
    n_groups = packed1.brows_rel.shape[0]
    br, bc = packed1.vals.shape[2], packed1.vals.shape[3]
    grid_cols = -(-n_cols // bc)

    def specs(p):
        chunk = p.brows_rel.shape[1]
        return [
            pl.BlockSpec((1, chunk), lambda g: (g, 0)),
            pl.BlockSpec((1, chunk), lambda g: (g, 0)),
            pl.BlockSpec((1, chunk, br, bc), lambda g: (g, 0, 0, 0)),
        ]

    out = pl.pallas_call(
        functools.partial(_bcsr_spadd3_kernel, block_R=block_R,
                          grid_cols=grid_cols),
        grid=(n_groups,),
        in_specs=specs(packed1) + specs(packed2) + specs(packed3),
        out_specs=pl.BlockSpec((1, block_R, br, grid_cols, bc),
                               lambda g: (g, 0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_groups, block_R, br, grid_cols, bc),
                                       packed1.vals.dtype),
        interpret=interpret,
    )(packed1.brows_rel, packed1.crd, packed1.vals,
      packed2.brows_rel, packed2.crd, packed2.vals,
      packed3.brows_rel, packed3.crd, packed3.vals)
    dense = out.reshape(n_groups * block_R * br, grid_cols * bc)
    return dense[:n_rows, :n_cols]
