"""Fault tolerance: restart policy, step watchdog, straggler mitigation.

At 1000+ nodes, node failure is routine and stragglers dominate tail step
time. The pieces here are host-side (framework) logic; the device-side
counterpart is that every step is a pure function of (state, batch) so any
step can be replayed from the last checkpoint.

- :class:`RestartPolicy` — exponential-backoff restart budget; the train
  launcher wraps its step loop with `run_with_restarts`.
- :class:`StepWatchdog` — per-step wall-time tracker; flags steps beyond
  k·median as straggler events.
- :class:`StragglerMitigator` — converts repeated straggler flags into a
  *re-plan*: the paper's own weighted non-zero partitioning, reused on the
  training system itself. A slow shard gets proportionally fewer non-zeros
  (sparse workloads) or a smaller microbatch slice (dense workloads).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 100
    backoff_s: float = 1.0
    backoff_factor: float = 2.0
    backoff_max_s: float = 300.0

    def run_with_restarts(self, step_loop: Callable[[], None],
                          on_restart: Optional[Callable[[int], None]] = None,
                          sleep=time.sleep) -> int:
        """Run ``step_loop`` until it completes; on exception restore from
        the latest checkpoint via ``on_restart`` and retry with backoff.
        Returns the number of restarts used."""
        restarts = 0
        delay = self.backoff_s
        while True:
            try:
                step_loop()
                return restarts
            except KeyboardInterrupt:
                raise
            except Exception:  # noqa: BLE001 — any step failure is retriable
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                if on_restart is not None:
                    on_restart(restarts)
                sleep(min(delay, self.backoff_max_s))
                delay *= self.backoff_factor


class StepWatchdog:
    """Flags straggling steps: wall time > threshold × running median."""

    def __init__(self, threshold: float = 2.0, window: int = 50):
        self.threshold = threshold
        self.window = window
        self.times: List[float] = []
        self.straggler_steps: List[int] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self) -> bool:
        """Returns True if this step straggled."""
        assert self._t0 is not None
        dt = time.monotonic() - self._t0
        self._t0 = None
        self._step += 1
        is_straggler = False
        if len(self.times) >= 5:
            med = float(np.median(self.times[-self.window:]))
            is_straggler = dt > self.threshold * med
        if is_straggler:
            self.straggler_steps.append(self._step)
        self.times.append(dt)
        return is_straggler

    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0


class StragglerMitigator:
    """Persistent-straggler response: weighted re-partitioning.

    Tracks per-shard slowness reports; when a shard exceeds the report
    budget, emits new partition weights (slow shard gets less work). For
    sparse workloads these weights feed ``weighted_nonzero_bounds`` — the
    paper's non-zero partition generalized to heterogeneous shard speeds.
    """

    def __init__(self, n_shards: int, report_budget: int = 3,
                 slowdown_discount: float = 0.5):
        self.n = n_shards
        self.budget = report_budget
        self.discount = slowdown_discount
        self.reports = np.zeros(n_shards, dtype=np.int64)
        self.weights = np.ones(n_shards, dtype=np.float64)

    def report_slow(self, shard: int) -> bool:
        """Returns True when a re-plan is warranted."""
        self.reports[shard] += 1
        if self.reports[shard] >= self.budget:
            self.weights[shard] *= self.discount
            self.reports[shard] = 0
            self.weights /= self.weights.mean()
            return True
        return False

    def weighted_nonzero_bounds(self, nnz: int) -> np.ndarray:
        """(P, 2) position bounds proportional to shard weights — the
        weighted generalization of partition_nonzeros."""
        frac = self.weights / self.weights.sum()
        ends = np.floor(np.cumsum(frac) * nnz).astype(np.int64)
        ends[-1] = nnz
        starts = np.concatenate([[0], ends[:-1]])
        return np.stack([starts, ends], axis=1)
