"""Fault tolerance: restart policy, step watchdog, straggler mitigation.

At 1000+ nodes, node failure is routine and stragglers dominate tail step
time. The pieces here are host-side (framework) logic; the device-side
counterpart is that every step is a pure function of (state, batch) so any
step can be replayed from the last checkpoint.

- :class:`RestartPolicy` — exponential-backoff restart budget; the train
  launcher wraps its step loop with `run_with_restarts`.
- :class:`StepWatchdog` — per-step wall-time tracker; flags steps beyond
  k·median as straggler events.
- :class:`StragglerMitigator` — converts repeated straggler flags into a
  *re-plan*: the paper's own weighted non-zero partitioning, reused on the
  training system itself. A slow shard gets proportionally fewer non-zeros
  (sparse workloads) or a smaller microbatch slice (dense workloads).
- :class:`FaultInjector` — deterministic fault simulation (device loss,
  shard corruption, straggler slowdown) at configurable steps of a run
  loop; drives the three mechanisms above against sparse kernels in
  :func:`repro.runtime.elastic.run_with_recovery` and the elastic tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class RestartPolicy:
    """Exponential-backoff restart budget.

    ``jitter`` spreads each delay uniformly over ``±jitter`` of its
    nominal value so a fleet restarting off the same failure doesn't
    thunder back in lockstep; ``seed`` makes the spread reproducible.
    A zero base delay stays zero (jitter is multiplicative)."""

    max_restarts: int = 100
    backoff_s: float = 1.0
    backoff_factor: float = 2.0
    backoff_max_s: float = 300.0
    jitter: float = 0.1
    seed: Optional[int] = None

    def run_with_restarts(self, step_loop: Callable[[], None],
                          on_restart: Optional[Callable[[int], None]] = None,
                          sleep=time.sleep) -> int:
        """Run ``step_loop`` until it completes; on exception restore from
        the latest checkpoint via ``on_restart`` and retry with backoff.
        Returns the number of restarts used."""
        restarts = 0
        delay = self.backoff_s
        rng = np.random.default_rng(self.seed)
        while True:
            try:
                step_loop()
                return restarts
            except KeyboardInterrupt:
                raise
            except Exception:  # noqa: BLE001 — any step failure is retriable
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                if on_restart is not None:
                    on_restart(restarts)
                scale = max(0.0, 1.0 + self.jitter * rng.uniform(-1.0, 1.0))
                sleep(min(delay, self.backoff_max_s) * scale)
                delay *= self.backoff_factor


class StepWatchdog:
    """Flags straggling steps: wall time > threshold × running median.

    ``warmup`` is the number of recorded steps required before any step
    can be flagged — the first few samples (compile, cache warm-up) would
    otherwise poison the median and mark ordinary steps as stragglers."""

    def __init__(self, threshold: float = 2.0, window: int = 50,
                 warmup: int = 5):
        self.threshold = threshold
        self.window = window
        self.warmup = max(int(warmup), 1)
        self.times: List[float] = []
        self.straggler_steps: List[int] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self) -> bool:
        """Returns True if this step straggled."""
        assert self._t0 is not None
        dt = time.monotonic() - self._t0
        self._t0 = None
        self._step += 1
        is_straggler = False
        if len(self.times) >= self.warmup:
            med = float(np.median(self.times[-self.window:]))
            is_straggler = dt > self.threshold * med
        if is_straggler:
            self.straggler_steps.append(self._step)
        self.times.append(dt)
        return is_straggler

    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0


class StragglerMitigator:
    """Persistent-straggler response: weighted re-partitioning.

    Tracks per-shard slowness reports; when a shard exceeds the report
    budget, emits new partition weights (slow shard gets less work). For
    sparse workloads these weights feed ``weighted_nonzero_bounds`` — the
    paper's non-zero partition generalized to heterogeneous shard speeds.
    """

    def __init__(self, n_shards: int, report_budget: int = 3,
                 slowdown_discount: float = 0.5):
        self.n = n_shards
        self.budget = report_budget
        self.discount = slowdown_discount
        self.reports = np.zeros(n_shards, dtype=np.int64)
        self.weights = np.ones(n_shards, dtype=np.float64)

    def report_slow(self, shard: int) -> bool:
        """Returns True when a re-plan is warranted."""
        self.reports[shard] += 1
        if self.reports[shard] >= self.budget:
            self.weights[shard] *= self.discount
            self.reports[shard] = 0
            self.weights /= self.weights.mean()
            return True
        return False

    def weighted_nonzero_bounds(self, nnz: int) -> np.ndarray:
        """(P, 2) position bounds proportional to shard weights — the
        weighted generalization of partition_nonzeros."""
        frac = self.weights / self.weights.sum()
        ends = np.floor(np.cumsum(frac) * nnz).astype(np.int64)
        ends[-1] = nnz
        starts = np.concatenate([[0], ends[:-1]])
        return np.stack([starts, ends], axis=1)


# ---------------------------------------------------------------------------
# Fault injection — deterministic failure simulation for the elastic loop
# ---------------------------------------------------------------------------


class DeviceLoss(RuntimeError):
    """A simulated device (piece) disappearing mid-run. Raised by
    :class:`FaultInjector`; :func:`..elastic.run_with_recovery` catches it,
    records the dead piece, and restarts on a shrunk machine."""

    def __init__(self, piece: int, step: int):
        super().__init__(f"device loss: piece {piece} at step {step}")
        self.piece = piece
        self.step = step


@dataclasses.dataclass
class FaultEvent:
    """One scheduled fault. ``kind`` ∈ {"device_loss", "corrupt",
    "straggler"}: device loss raises :class:`DeviceLoss` (piece ``piece``
    dies), corrupt perturbs the named tensor's values in place (detected
    downstream by content fingerprint against the last checkpoint),
    straggler reports a simulated per-step slowdown attributed to
    ``piece``. ``once`` events fire at most one time — a restarted loop
    replaying the same step does not re-fault."""

    step: int
    kind: str
    piece: int = 0
    tensor: Optional[str] = None
    slowdown_s: float = 0.0
    once: bool = True
    fired: int = 0


class FaultInjector:
    """Replays a list of :class:`FaultEvent` at configured steps.

    Call :meth:`before_step` at the top of each loop iteration with the
    live tensor map. Corruption mutates storage immediately; stragglers
    return the accumulated slowdown (seconds) the caller should simulate
    and record the slow piece in ``slow_piece``; device loss raises.
    ``log`` keeps a human-readable trace of everything that fired."""

    def __init__(self, events, seed: int = 0):
        self.events: List[FaultEvent] = list(events)
        self.rng = np.random.default_rng(seed)
        self.log: List[str] = []
        self.slow_piece: Optional[int] = None

    def before_step(self, step: int, tensors: Dict[str, object]) -> float:
        slowdown = 0.0
        self.slow_piece = None
        for ev in self.events:
            if ev.step != step or (ev.once and ev.fired):
                continue
            ev.fired += 1
            if ev.kind == "corrupt":
                if ev.tensor not in tensors:
                    raise KeyError(f"corrupt event names unknown tensor "
                                   f"{ev.tensor!r}")
                self._corrupt(tensors[ev.tensor])
                self.log.append(f"corrupt:{ev.tensor}@{step}")
            elif ev.kind == "straggler":
                slowdown += float(ev.slowdown_s)
                self.slow_piece = ev.piece
                self.log.append(f"straggler:{ev.piece}@{step}")
            elif ev.kind == "device_loss":
                self.log.append(f"device_loss:{ev.piece}@{step}")
                raise DeviceLoss(ev.piece, step)
            else:
                raise ValueError(f"unknown fault kind {ev.kind!r}")
        return slowdown

    def _corrupt(self, tensor) -> None:
        """Flip one stored value in place — the bit-rot analog. The next
        fingerprint of the tensor no longer matches the checkpointed one,
        which is exactly how real recovery detects silent corruption."""
        vals = np.asarray(tensor.vals).reshape(-1)
        if not vals.size:
            return
        idx = int(self.rng.integers(0, vals.size))
        vals[idx] = vals[idx] + 1.0
