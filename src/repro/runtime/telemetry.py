"""Telemetry: span tracing, unified metrics, and byte-ledger verification.

One observability layer for the whole lowering/execution pipeline
(ISSUE 9). Three pieces:

- :class:`Tracer` — a hierarchical span tracer. ``with span("lower.plan",
  sig=...)`` records a timed span nested under whatever span is open on
  the current thread; :meth:`Tracer.export_chrome` writes Chrome
  trace-event JSON loadable in Perfetto / ``chrome://tracing``. The
  module-global :data:`TRACER` starts **disabled**: every instrumentation
  site in ``core.lower`` / ``core.grid`` / ``core.partition`` /
  ``distributed.executor`` / ``runtime.elastic`` then costs one attribute
  read and one branch (the no-op singleton path — bounded by test).

- :class:`MetricsRegistry` — process-wide counters / gauges / histograms
  behind one :meth:`MetricsRegistry.snapshot` API. The snapshot also
  absorbs the pre-existing scattered cache counters (plan / shard /
  runner / convert / add-stream / tuned-plan / spmd-run) with derived hit
  rates, so ``benchmarks/run.py --json`` and ``launch/report.py`` read
  one structure instead of seven module globals.

- :func:`verify_byte_ledger` — the model-vs-ledger cross-check: re-derive
  the communication bytes a kernel *should* have charged from the
  statement + strategy alone (``grid.grid_axis_bytes`` for grids, the
  ``plan_search`` statement-level predictors for 1-D) and compare against
  the ``CommStats`` ledger the lowering actually recorded, per axis.
  Run over the full conformance census, this pins the paper's per-axis
  communication accounting (DISTAL §5) to the implementation.

Span taxonomy (all names dot-namespaced, stable — tests and CI parse
them): ``lower`` > ``lower.plan`` / ``lower.materialize`` / ``lower.jit``
/ ``lower.emit``; ``plan_search.search`` > ``plan_search.measure``;
``partition.materialize``; ``execute.spmd`` / ``execute.piece``;
``recovery.restore`` / ``recovery.replan`` / ``recovery.rejit``.

CLI smoke (the CI trace artifact)::

    PYTHONPATH=src python -m repro.runtime.telemetry --smoke \\
        --out TRACE_smoke.json
"""
from __future__ import annotations

import argparse
import json
import logging
import os
import sys
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Tracer", "MetricsRegistry", "TRACER", "METRICS", "span", "instant",
    "validate_chrome_trace", "configure_logging", "verify_byte_ledger",
    "smoke_trace",
]


# ---------------------------------------------------------------------------
# Span tracing
# ---------------------------------------------------------------------------


class _NullSpan:
    """The disabled-tracer span: a shared singleton whose enter/exit/set
    do nothing. ``Tracer.span`` returns it without allocating when
    tracing is off, so instrumentation sites cost one branch."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span. Created only on the enabled path; records itself
    into the owning tracer's event list on exit."""

    __slots__ = ("_tracer", "name", "id", "parent", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.args = args
        self.id = 0
        self.parent: Optional[int] = None
        self._t0 = 0.0

    def set(self, **attrs) -> "_Span":
        """Attach attributes discovered after the span opened (e.g. the
        chosen leaf name, a cache-delta)."""
        self.args.update(attrs)
        return self

    def __enter__(self) -> "_Span":
        tr = self._tracer
        stack = tr._stack()
        self.parent = stack[-1].id if stack else None
        with tr._lock:
            tr._seq += 1
            self.id = tr._seq
        stack.append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        tr = self._tracer
        stack = tr._stack()
        if stack and stack[-1] is self:
            stack.pop()
        tr._record({
            "name": self.name,
            "id": self.id,
            "parent": self.parent,
            "ts_us": (self._t0 - tr._epoch) * 1e6,
            "dur_us": (t1 - self._t0) * 1e6,
            "tid": threading.get_ident(),
            "args": self.args,
        })
        return False


class Tracer:
    """Thread-safe hierarchical span tracer with Chrome trace export.

    Parentage is tracked per thread (a thread-local span stack) and
    recorded by span *id* at open time — a parent span finishes after its
    children, so positional references cannot work. Disabled tracers
    return the shared no-op span from :meth:`span` and record nothing.
    """

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._local = threading.local()
        self._events: List[Dict[str, Any]] = []
        self._seq = 0
        self._epoch = time.perf_counter()

    # -- control ----------------------------------------------------------
    def enable(self) -> "Tracer":
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    def clear(self) -> None:
        with self._lock:
            self._events = []
            self._seq = 0
            self._epoch = time.perf_counter()

    # -- recording --------------------------------------------------------
    def _stack(self) -> List[_Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _record(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            self._events.append(ev)

    def span(self, name: str, **attrs):
        """Open a timed span: ``with tracer.span("lower.plan", sig=s):``.
        Returns the no-op singleton when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, attrs)

    def instant(self, name: str, **attrs) -> None:
        """A zero-duration marker event (cache hit/miss, fault, …)."""
        if not self.enabled:
            return
        stack = self._stack()
        self._record({
            "name": name,
            "id": None,
            "parent": stack[-1].id if stack else None,
            "ts_us": (time.perf_counter() - self._epoch) * 1e6,
            "dur_us": None,
            "tid": threading.get_ident(),
            "args": attrs,
        })

    # -- inspection -------------------------------------------------------
    def spans(self) -> List[Dict[str, Any]]:
        """Finished events, oldest first (instants have ``dur_us=None``)."""
        with self._lock:
            return list(self._events)

    def call_tree(self) -> List[Dict[str, Any]]:
        """Reconstruct span nesting from recorded parent ids: a forest of
        ``{"name", "dur_us", "args", "children": [...]}`` nodes."""
        nodes: Dict[int, Dict[str, Any]] = {}
        roots: List[Dict[str, Any]] = []
        spans = [e for e in self.spans() if e["id"] is not None]
        for ev in spans:
            nodes[ev["id"]] = {"name": ev["name"], "dur_us": ev["dur_us"],
                               "args": ev["args"], "children": []}
        for ev in spans:
            node = nodes[ev["id"]]
            parent = nodes.get(ev["parent"]) if ev["parent"] else None
            (parent["children"] if parent else roots).append(node)
        for n in nodes.values():
            n["children"].sort(key=lambda c: c["dur_us"] or 0, reverse=True)
        return roots

    # -- export -----------------------------------------------------------
    def export_chrome(self, path: str) -> str:
        """Write the Chrome trace-event JSON (``{"traceEvents": [...]}``,
        "X" complete events in µs) — open in Perfetto (ui.perfetto.dev)
        or ``chrome://tracing``. Returns ``path``."""
        pid = os.getpid()
        out = []
        for ev in self.spans():
            args = {k: _jsonable(v) for k, v in ev["args"].items()}
            if ev["id"] is not None:
                args["span_id"] = ev["id"]
                if ev["parent"] is not None:
                    args["parent_id"] = ev["parent"]
            rec = {"name": ev["name"], "pid": pid, "tid": ev["tid"],
                   "ts": round(ev["ts_us"], 3), "args": args}
            if ev["dur_us"] is None:
                rec.update(ph="i", s="t")
            else:
                rec.update(ph="X", dur=round(ev["dur_us"], 3))
            out.append(rec)
        payload = {"traceEvents": out,
                   "displayTimeUnit": "ms",
                   "otherData": {"tool": "repro.runtime.telemetry"}}
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=1)
        return path


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (tuple, list)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return str(v)


#: The process-wide tracer every instrumentation site records into.
#: Disabled by default — ``TRACER.enable()`` to start collecting.
TRACER = Tracer(enabled=False)


def span(name: str, **attrs):
    """Module-level convenience: a span on the global :data:`TRACER`."""
    return TRACER.span(name, **attrs)


def instant(name: str, **attrs) -> None:
    """Module-level convenience: an instant on the global :data:`TRACER`."""
    TRACER.instant(name, **attrs)


def overlap_report(tracer: "Tracer" = None) -> Dict[str, Any]:
    """Roll up comm/compute-overlap attribution from recorded spans.

    The overlapped executor (``distributed.executor.run_overlapped``)
    emits one ``execute.overlap.chunk`` instant per dense-operand chunk
    with ``comm_s`` (issue→ready transfer wall time), ``hidden_s`` (the
    slice of that window spent under the previous chunk's compute), and
    ``bytes``. This derives the serving dashboard's summary:
    ``efficiency = sum(hidden_s) / sum(comm_s)`` — the fraction of
    transfer time the pipeline hid behind leaf kernels (0.0 when nothing
    overlapped or tracing was disabled)."""
    tracer = tracer or TRACER
    chunks = [e for e in tracer.spans()
              if e["name"] == "execute.overlap.chunk"]
    comm_s = sum(float(e["args"].get("comm_s", 0.0)) for e in chunks)
    hidden_s = sum(float(e["args"].get("hidden_s", 0.0)) for e in chunks)
    nbytes = sum(int(e["args"].get("bytes", 0)) for e in chunks)
    return {
        "chunks": len(chunks),
        "comm_s": comm_s,
        "hidden_s": hidden_s,
        "bytes": nbytes,
        "efficiency": (hidden_s / comm_s) if comm_s > 0 else 0.0,
    }


def validate_chrome_trace(path: str,
                          require: Sequence[str] = ()) -> Dict[str, int]:
    """Load and structurally validate an exported trace. Asserts the
    trace-event envelope, event field types, and that every name in
    ``require`` appears at least once. Returns name → occurrence count."""
    with open(path) as fh:
        payload = json.load(fh)
    assert isinstance(payload, dict) and "traceEvents" in payload, \
        f"{path}: not a Chrome trace-event JSON object"
    events = payload["traceEvents"]
    assert isinstance(events, list) and events, f"{path}: no traceEvents"
    counts: Dict[str, int] = {}
    for ev in events:
        assert isinstance(ev.get("name"), str), f"bad event name: {ev!r}"
        assert ev.get("ph") in ("X", "i"), f"bad phase: {ev!r}"
        assert isinstance(ev.get("ts"), (int, float)), f"bad ts: {ev!r}"
        assert isinstance(ev.get("pid"), int) and isinstance(
            ev.get("tid"), int), f"bad pid/tid: {ev!r}"
        if ev["ph"] == "X":
            assert isinstance(ev.get("dur"), (int, float)) \
                and ev["dur"] >= 0, f"bad dur: {ev!r}"
        counts[ev["name"]] = counts.get(ev["name"], 0) + 1
    missing = [n for n in require if n not in counts]
    assert not missing, (
        f"{path}: required span names missing from trace: {missing}; "
        f"present: {sorted(counts)}")
    return counts


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

#: (snapshot key, module, attribute) for every pre-existing cache-stats
#: dict. Read through sys.modules so the registry never forces an import
#: (and never creates a cycle — telemetry is imported BY these modules).
_CACHE_SOURCES: Tuple[Tuple[str, str, str], ...] = (
    ("plan", "repro.core.lower", "PLAN_CACHE_STATS"),
    ("runner", "repro.core.lower", "RUNNER_CACHE_STATS"),
    ("shard", "repro.core.partition", "SHARD_CACHE_STATS"),
    ("convert", "repro.core.partition", "CONVERT_CACHE_STATS"),
    ("add_stream", "repro.core.partition", "ADD_STREAM_STATS"),
    ("tuned_plan", "repro.core.plan_search", "TUNED_PLAN_CACHE_STATS"),
    ("spmd_run", "repro.distributed.executor", "SPMD_RUN_STATS"),
)


class MetricsRegistry:
    """Counters, gauges, and histograms behind one lock and one
    :meth:`snapshot`. Histogram observations are kept raw (bounded use:
    per-piece timings, per-axis bytes) and summarized at snapshot time."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, List[float]] = {}

    def counter(self, name: str, inc: float = 1.0) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0.0) + inc

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            self._hists.setdefault(name, []).append(float(value))

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()

    @staticmethod
    def cache_stats() -> Dict[str, Dict[str, Any]]:
        """Hit/miss (+ derived hit rate) for every registered cache whose
        module is already imported."""
        out: Dict[str, Dict[str, Any]] = {}
        for key, mod_name, attr in _CACHE_SOURCES:
            mod = sys.modules.get(mod_name)
            stats = getattr(mod, attr, None) if mod else None
            if not isinstance(stats, dict):
                continue
            h, m = int(stats.get("hits", 0)), int(stats.get("misses", 0))
            entry: Dict[str, Any] = {"hits": h, "misses": m,
                                     "hit_rate": h / (h + m) if h + m else
                                     None}
            if "evictions" in stats:
                entry["evictions"] = int(stats["evictions"])
            out[key] = entry
        return out

    def snapshot(self) -> Dict[str, Any]:
        """One JSON-ready structure: counters, gauges, histogram
        summaries (count/min/max/mean/p50/p90/total), cache hit rates."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = {k: list(v) for k, v in self._hists.items()}
        summaries = {}
        for name, vals in hists.items():
            a = np.asarray(vals, dtype=np.float64)
            summaries[name] = {
                "count": int(a.size),
                "min": float(a.min()),
                "max": float(a.max()),
                "mean": float(a.mean()),
                "p50": float(np.percentile(a, 50)),
                "p90": float(np.percentile(a, 90)),
                "total": float(a.sum()),
            }
        return {"counters": counters, "gauges": gauges,
                "histograms": summaries, "caches": self.cache_stats()}


#: The process-wide registry every instrumentation site records into.
METRICS = MetricsRegistry()


def configure_logging(level: int = logging.INFO) -> logging.Logger:
    """Configure the ``repro`` logger hierarchy in one call. Every module
    logs under ``__name__`` (``repro.core.lower``, …), so a level + a
    handler on the ``repro`` root covers the whole package. Idempotent —
    an existing handler is kept, only the level changes."""
    root = logging.getLogger("repro")
    root.setLevel(level)
    if not root.handlers:
        h = logging.StreamHandler()
        h.setFormatter(logging.Formatter(
            "%(asctime)s %(levelname)-7s %(name)s: %(message)s"))
        root.addHandler(h)
    return root


# ---------------------------------------------------------------------------
# Byte-ledger verification
# ---------------------------------------------------------------------------


def _flat_predicted_bytes(kernel) -> Tuple[int, int]:
    """(replicate, reduce) bytes a 1-D (or per-color grid-nnz) lowering of
    ``kernel.stmt`` must charge, re-derived from the statement + plans —
    independent of the running totals ``_lower_impl`` accumulated."""
    from ..core import lower as L
    from ..core import plan_search as PS

    stmt, strat = kernel.stmt, kernel.strategy
    sig = stmt.signature()

    if (sig, strat.space) in L._SELF_MATERIALIZING:
        # spadd3/nnz: whole concatenated entry stream ships to the root —
        # coords+vals per scalar entry, coords + a (br, bc) tile per block.
        seen, n_entries, tile = set(), 0, 0
        for acc in stmt.rhs.accesses():
            t = acc.tensor
            if t.format.is_sparse and t.name not in seen:
                seen.add(t.name)
                n_entries += int(t.vals.shape[0])
                if t.format.is_blocked:
                    tile = int(np.prod(t.format.block_shape))
        red = n_entries * (8 + tile * 4) if tile else n_entries * 12
        return 0, red

    if strat.space == "universe":
        rep = sum(L._nbytes(t) for t in PS._replicated_universe(stmt))
        return int(rep), 0

    # nnz space: operands replicate, output partials reduce
    rep_ts, out_partitioned = PS._replicated_nnz(stmt)
    rep = sum(L._nbytes(t) for t in rep_ts)
    out_t = stmt.lhs.tensor
    if not out_partitioned and not L._output_is_assembled(sig):
        # _compute_plans replicates the dense output when its leading
        # variable is not the position tensor's root variable (CSC/BCSC)
        rep += L._nbytes(out_t)
    ov = kernel.plans[next(iter(kernel.plans))]   # position-tensor plan
    if ov.tensor.format.dim_of_level(0) != 0:
        red = L._nbytes(out_t)                    # full-extent partials
    elif ov.tensor.format.is_blocked:
        bb = ov.levels[0].coord_bounds
        br = ov.tensor.format.block_shape[0]
        red = int((bb[:, 1] - bb[:, 0]).sum()
                  - (bb[:, 1].max() - bb[:, 0].min())) * br * 4
    else:
        rb = ov.root_coord_bounds
        red = int((rb[:, 1] - rb[:, 0]).sum()
                  - (rb[:, 1].max() - rb[:, 0].min())) * 4
    return int(rep), int(red)


def verify_byte_ledger(kernel) -> Dict[str, Any]:
    """Cross-check the kernel's recorded :class:`~repro.core.lower.
    CommStats` ledger against statement-level model predictions, per
    machine axis. Covers replicate/broadcast and reduce bytes (the model
    has no view of ``redistribute_bytes`` — a property of the *data*
    distribution, not the schedule). Raises ``AssertionError`` on any
    mismatch; returns the check report."""
    from ..core import grid as grid_mod
    from ..core import lower as L  # noqa: F401 — force module availability

    stmt, strat, comm = kernel.stmt, kernel.strategy, kernel.comm
    checks: List[Dict[str, Any]] = []

    def chk(field: str, axis: Optional[str], pred: int, ledger: int) -> None:
        checks.append({"field": field, "axis": axis, "predicted": int(pred),
                       "ledger": int(ledger), "ok": int(pred) == int(ledger)})

    if strat.is_grid and strat.space == "universe":
        model = grid_mod.grid_axis_bytes(stmt, strat)
        assert set(model) == set(comm.axes), (
            f"axis sets differ: model {sorted(model)} "
            f"vs ledger {sorted(comm.axes)}")
        for name in model:
            chk("broadcast", name, model[name].broadcast_bytes,
                comm.axes[name].broadcast_bytes)
            chk("reduce", name, model[name].reduce_bytes,
                comm.axes[name].reduce_bytes)
    elif strat.is_grid:
        # grid nnz: flat prediction re-attributed hierarchically in grid
        # order — the same collective model _lower_impl applies.
        rep, red = _flat_predicted_bytes(kernel)
        m = 1
        for d in strat.machine_dims:
            ax = comm.axes[d.name]
            chk("broadcast", d.name, m * rep, ax.broadcast_bytes)
            chk("reduce", d.name, m * red, ax.reduce_bytes)
            m *= d.size
    else:
        rep, red = _flat_predicted_bytes(kernel)
        chk("replicate", None, rep, comm.replicate_bytes)
        chk("reduce", None, red, comm.reduce_bytes)

    report = {"cell": kernel.cell_id(), "checks": checks,
              "ok": all(c["ok"] for c in checks)}
    bad = [c for c in checks if not c["ok"]]
    assert not bad, (
        f"byte-ledger mismatch for {kernel.cell_id()}: " + "; ".join(
            f"{c['field']}" + (f"[{c['axis']}]" if c["axis"] else "")
            + f" predicted={c['predicted']} ledger={c['ledger']}"
            for c in bad))
    return report


# ---------------------------------------------------------------------------
# Smoke trace (CI artifact) — a traced 2-D grid SpMM lower + execute
# ---------------------------------------------------------------------------


def smoke_trace(out_path: str, n: int = 512, m: int = 512, j: int = 16,
                ) -> Dict[str, int]:
    """Lower + execute one SpMM on a 2x2 machine grid with tracing on,
    profile per-piece leaf wall times, verify the byte ledger, export the
    Chrome trace, and validate it. Returns the span-name counts. This is
    the CI `TRACE_smoke.json` producer and the acceptance-criteria check
    in one function."""
    import repro.core as rc
    from repro.core import formats as F
    from repro.core.lower import (clear_lowering_caches,
                                  default_grid_schedule, lower)
    from repro.core.tensor import Tensor
    from repro.distributed.executor import profile_pieces

    rng = np.random.default_rng(0)
    dB = ((rng.random((n, m)) < 0.05)
          * rng.standard_normal((n, m))).astype(np.float32)
    B = Tensor.from_dense("B", dB, F.CSR())
    C = Tensor.from_dense("C", rng.standard_normal((m, j)).astype(np.float32))
    stmt = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                        A=Tensor.zeros_dense("A", (n, j)), B=B, C=C)
    machine = rc.Machine(("x", 2), ("y", 2))

    clear_lowering_caches()
    TRACER.clear()
    TRACER.enable()
    try:
        kernel = lower(stmt, machine,
                       schedule=default_grid_schedule(stmt, machine))
        with TRACER.span("execute", leaf=kernel.leaf_name):
            kernel.run()
        prof = profile_pieces(kernel, iters=2, warmup=1)
        verify_byte_ledger(kernel)
    finally:
        TRACER.disable()
    TRACER.export_chrome(out_path)
    counts = validate_chrome_trace(out_path, require=(
        "lower", "lower.plan", "lower.materialize", "lower.jit",
        "execute", "execute.piece"))
    assert counts["execute.piece"] >= kernel.strategy.pieces, (
        f"expected per-piece timings for all {kernel.strategy.pieces} "
        f"pieces, saw {counts['execute.piece']} execute.piece spans")
    assert prof.seconds.shape[0] == kernel.strategy.pieces
    return counts


def _main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.runtime.telemetry",
        description="telemetry utilities (smoke trace / trace validation)")
    ap.add_argument("--smoke", action="store_true",
                    help="run a traced 2-D grid SpMM lower+execute")
    ap.add_argument("--out", default="TRACE_smoke.json",
                    help="trace output path (with --smoke)")
    ap.add_argument("--validate", metavar="TRACE",
                    help="validate an existing Chrome trace JSON")
    args = ap.parse_args(argv)
    if args.validate:
        counts = validate_chrome_trace(args.validate)
        print(json.dumps(counts, indent=2, sort_keys=True))
        return 0
    if args.smoke:
        counts = smoke_trace(args.out)
        print(f"wrote {args.out}")
        print(json.dumps(counts, indent=2, sort_keys=True))
        return 0
    ap.error("nothing to do: pass --smoke or --validate")
    return 2


if __name__ == "__main__":
    # `python -m repro.runtime.telemetry` executes this file as __main__,
    # a SECOND module instance whose TRACER is not the one the pipeline's
    # `from ..runtime import telemetry` records into — delegate to the
    # canonical instance so --smoke traces the real global tracer.
    import repro.runtime.telemetry as _canonical
    raise SystemExit(_canonical._main())
