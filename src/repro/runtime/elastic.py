"""Elastic scaling: resize the mesh, re-plan, resume.

Because every shard layout in this framework is a *pure function* of
(global state, mesh) — planner.params_pspecs for the LM stack,
Distribution.plan for sparse tensors — scaling to a different chip count is
just: checkpoint → build new mesh → re-derive specs → device_put host
arrays with the new shardings. No shard-format conversion pass is needed;
global shapes are the interchange format.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from ..distributed import planner


def reshard_state(host_state: Dict[str, Any], params_like, mesh: Mesh):
    """Place a host-restored {params, opt, ...} state onto ``mesh`` with
    freshly planned shardings (the elastic-restart path)."""
    p_spec = planner.params_pspecs(params_like, mesh)
    p_sh = planner.shardings_from(p_spec, mesh)
    out = dict(host_state)
    out["params"] = jax.device_put(host_state["params"], p_sh)
    if "opt" in host_state:
        o_spec = planner.opt_pspecs(host_state["opt"], params_like, mesh)
        o_sh = planner.shardings_from(o_spec, mesh)
        out["opt"] = jax.device_put(host_state["opt"], o_sh)
    return out


def valid_resize(global_batch: int, new_dp: int) -> bool:
    """A resize is legal when the global batch still shards evenly — the
    launcher keeps global batch fixed across resizes so optimization
    dynamics are unchanged."""
    return global_batch % max(new_dp, 1) == 0


def plan_resize(old_mesh_shape: Tuple[int, ...],
                available_chips: int,
                model_axis: int) -> Optional[Tuple[int, ...]]:
    """Pick the largest data axis that fits the surviving chip count,
    keeping the model axis intact (TP degree is architecture-bound)."""
    if available_chips < model_axis:
        return None
    data = available_chips // model_axis
    # keep power-of-two data axes for collective efficiency
    data = 1 << (data.bit_length() - 1)
    return (data, model_axis)
