"""Elastic scaling: resize the mesh, re-plan, resume.

Because every shard layout in this framework is a *pure function* of
(global state, mesh) — planner.params_pspecs for the LM stack,
Distribution.plan for sparse tensors — scaling to a different chip count is
just: checkpoint → build new mesh → re-derive specs → device_put host
arrays with the new shardings. No shard-format conversion pass is needed;
global shapes are the interchange format.

:func:`run_with_recovery` is the sparse-kernel realization: an iterative
executor loop wiring the fault harness (:mod:`.fault`), sparse
checkpointing (:mod:`.checkpoint`), and the elastic re-plan
(:func:`repro.core.lower.relower`) together — an injected device loss
restores the newest committed checkpoint, shrinks the machine to P−1,
re-lowers with per-piece shard reuse, and resumes to produce bit-for-bit
the unfaulted result.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from ..distributed import planner


def reshard_state(host_state: Dict[str, Any], params_like, mesh: Mesh):
    """Place a host-restored {params, opt, ...} state onto ``mesh`` with
    freshly planned shardings (the elastic-restart path)."""
    p_spec = planner.params_pspecs(params_like, mesh)
    p_sh = planner.shardings_from(p_spec, mesh)
    out = dict(host_state)
    out["params"] = jax.device_put(host_state["params"], p_sh)
    if "opt" in host_state:
        o_spec = planner.opt_pspecs(host_state["opt"], params_like, mesh)
        o_sh = planner.shardings_from(o_spec, mesh)
        out["opt"] = jax.device_put(host_state["opt"], o_sh)
    return out


def valid_resize(global_batch: int, new_dp: int) -> bool:
    """A resize is legal when the global batch still shards evenly — the
    launcher keeps global batch fixed across resizes so optimization
    dynamics are unchanged."""
    return global_batch % max(new_dp, 1) == 0


def plan_resize(old_mesh_shape: Tuple[int, ...],
                available_chips: int,
                model_axis: int) -> Optional[Tuple[int, ...]]:
    """Pick the largest data axis that fits the surviving chip count,
    keeping the model axis intact (TP degree is architecture-bound)."""
    if available_chips < model_axis:
        return None
    data = available_chips // model_axis
    # keep power-of-two data axes for collective efficiency
    data = 1 << (data.bit_length() - 1)
    return (data, model_axis)


# ---------------------------------------------------------------------------
# Sparse-kernel elastic execution: fault-injected run loop with
# checkpointed recovery and shrink-and-re-plan device-loss handling.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RecoveryReport:
    """What the elastic loop observed and paid: fault trace, recovery wall
    time split (restore / re-plan / re-jit), and the shard-reuse fraction
    of the post-loss re-lower (the elastic claim: ≥ 50% of shard-cache
    lookups hit on a migration-style P→P−1).

    The time split is DERIVED FROM THE TRACE: every recovery phase runs
    inside a ``recovery.restore`` / ``recovery.replan`` / ``recovery.rejit``
    span (recorded on a loop-local tracer and, when enabled, the global
    :data:`repro.runtime.telemetry.TRACER`), and the report sums span
    durations per phase at the end. Phases never nest, so
    ``restore_s + replan_s + rejit_s == recovery_s`` exactly — the
    previous hand-timed splits could double-count a straggler re-plan
    that landed in the same loop iteration as a device-loss re-plan."""

    steps: int = 0
    restarts: int = 0
    replans: int = 0                 # straggler-weight re-plans
    faults: List[str] = dataclasses.field(default_factory=list)
    healed: List[str] = dataclasses.field(default_factory=list)
    restored_step: Optional[int] = None
    restore_s: float = 0.0
    replan_s: float = 0.0
    rejit_s: float = 0.0
    recovery_s: float = 0.0          # total recovery wall time (all phases)
    shard_reuse: float = 0.0
    initial_pieces: int = 0
    final_pieces: int = 0

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def run_with_recovery(stmt, machine, steps: int, *, ckpt_dir: str,
                      schedule=None, injector=None, checkpoint_every: int = 1,
                      policy=None, watchdog=None, mitigator=None,
                      jit: bool = True, keep: int = 3,
                      ) -> Tuple[np.ndarray, "RecoveryReport"]:
    """Fault-tolerant iterative executor over one sparse kernel.

    Runs ``steps`` iterations of ``state += (t+1) · kernel.run()`` (a
    deterministic accumulation whose result is independent of piece count
    for row-family schedules and integer-valued operands — the bit-for-bit
    recovery yardstick), checkpointing the compressed trees + fingerprints
    + accumulator every ``checkpoint_every`` steps through
    :class:`..checkpoint.SparseCheckpoint`.

    Faults come from ``injector`` (:class:`..fault.FaultInjector`):

    - **device loss** — the step raises; ``RestartPolicy`` restarts the
      loop, which restores the newest committed checkpoint, shrinks the
      machine to P−1 (:func:`repro.distributed.mesh.shrink_machine`),
      re-lowers with migration bounds (:func:`repro.core.lower.relower`,
      per-piece shard reuse counted in the report), and resumes.
    - **corruption** — detected by CRC mismatch against the last
      checkpoint before the step runs; the tensor is healed in place and
      the kernel warm re-lowered (every shard a cache hit — the healed
      content fingerprints match the originals).
    - **straggler** — simulated slowdown; watchdog flags feed
      ``StragglerMitigator``; when its report budget trips on an nnz-space
      kernel, the weighted re-plan (``relower(..., weights=)``) rebalances.

    Returns ``(state, report)``.
    """
    import contextlib

    from ..core.lower import lower, relower
    from ..distributed.mesh import shrink_machine
    from . import telemetry
    from .checkpoint import SparseCheckpoint
    from .fault import DeviceLoss, RestartPolicy, StepWatchdog

    # Recovery phases are spans on a loop-local always-on tracer (the
    # report is derived from it) AND on the global tracer when the user
    # has tracing enabled.
    trace = telemetry.Tracer(enabled=True)

    @contextlib.contextmanager
    def _phase(name: str, **attrs):
        with contextlib.ExitStack() as st:
            st.enter_context(trace.span(f"recovery.{name}", **attrs))
            st.enter_context(
                telemetry.TRACER.span(f"recovery.{name}", **attrs))
            yield

    policy = policy if policy is not None else RestartPolicy(
        max_restarts=8, backoff_s=0.0, seed=0)
    watchdog = watchdog if watchdog is not None else StepWatchdog(
        threshold=4.0, warmup=1)
    ck = SparseCheckpoint(ckpt_dir, keep=keep)
    tensors: Dict[str, Any] = {}
    for acc in stmt.accesses():
        tensors.setdefault(acc.tensor.name, acc.tensor)

    kernel = lower(stmt, machine, schedule=schedule, jit=jit, elastic=True)
    report = RecoveryReport(steps=steps,
                            initial_pieces=kernel.strategy.pieces,
                            final_pieces=kernel.strategy.pieces)
    out0 = np.asarray(kernel.run())
    state = np.zeros_like(out0)
    ctx = {"kernel": kernel, "machine": machine, "state": state,
           "next": 0, "dead": None, "fresh": False}
    ck.save(0, tensors, {"state": ctx["state"]}, blocking=True)

    def do_step() -> None:
        t = ctx["next"]
        slowdown = 0.0
        if injector is not None:
            slowdown = injector.before_step(t, tensors)  # may raise DeviceLoss
            bad = ck.stale_operands(tensors)
            if bad:
                report.faults.append("corrupt:" + ",".join(bad))
                with _phase("restore", kind="corruption",
                            tensors=",".join(bad)):
                    ck.restore(tensors, {"state": ctx["state"]})
                report.healed.extend(bad)
                with _phase("replan", kind="corruption"):
                    ctx["kernel"] = relower(ctx["kernel"], ctx["machine"],
                                            jit=jit)
        watchdog.start()
        if ctx["fresh"]:
            # first run after a re-plan: the leaf re-compile (if the
            # runner cache missed) dominates this call
            with _phase("rejit", step=t):
                out = np.asarray(ctx["kernel"].run())
            ctx["fresh"] = False
        else:
            out = np.asarray(ctx["kernel"].run())
        if slowdown:
            time.sleep(slowdown)
        flagged = watchdog.stop()
        if (flagged and mitigator is not None and injector is not None
                and injector.slow_piece is not None):
            if (mitigator.report_slow(injector.slow_piece)
                    and ctx["kernel"].strategy.space == "nnz"):
                with _phase("replan", kind="straggler",
                            piece=injector.slow_piece):
                    ctx["kernel"] = relower(ctx["kernel"], ctx["machine"],
                                            weights=mitigator.weights,
                                            jit=jit)
                report.replans += 1
        nxt = t + 1
        ctx["state"] = ctx["state"] + nxt * out
        ctx["next"] = nxt
        if nxt % max(checkpoint_every, 1) == 0 or nxt == steps:
            ck.save(nxt, tensors, {"state": ctx["state"]}, blocking=True)

    def step_loop() -> None:
        while ctx["next"] < steps:
            try:
                do_step()
            except DeviceLoss as e:
                ctx["dead"] = e.piece
                report.faults.append(f"device_loss:{e.piece}@{e.step}")
                raise

    def on_restart(n: int) -> None:
        with _phase("restore", kind="restart", restart=n):
            step, extra, info = ck.restore(tensors, {"state": ctx["state"]})
        ctx["state"] = np.asarray(extra["state"])
        ctx["next"] = int(step)
        report.restored_step = int(step)
        report.healed.extend(info["restored"])
        dead, ctx["dead"] = ctx["dead"], None
        if dead is not None:
            with _phase("replan", kind="device_loss", piece=dead):
                new_machine = shrink_machine(ctx["machine"])
                ctx["kernel"] = relower(ctx["kernel"], new_machine,
                                        dead=dead, jit=jit)
            ctx["machine"] = new_machine
            report.shard_reuse = ctx["kernel"].cache.shard_reuse
        else:
            with _phase("replan", kind="restart"):
                ctx["kernel"] = relower(ctx["kernel"], ctx["machine"],
                                        jit=jit)
        ctx["fresh"] = True

    report.restarts = policy.run_with_restarts(step_loop, on_restart,
                                               sleep=lambda s: None)
    report.final_pieces = ctx["kernel"].strategy.pieces

    # Derive the time split from the trace: per-phase span duration sums.
    # Phases never nest, so the three splits sum exactly to recovery_s.
    durs: Dict[str, float] = {}
    for ev in trace.spans():
        if ev["dur_us"] is not None:
            durs[ev["name"]] = durs.get(ev["name"], 0.0) + ev["dur_us"] / 1e6
    report.restore_s = durs.get("recovery.restore", 0.0)
    report.replan_s = durs.get("recovery.replan", 0.0)
    report.rejit_s = durs.get("recovery.rejit", 0.0)
    report.recovery_s = sum(durs.values())
    return ctx["state"], report
