"""Sharded, asynchronous, atomic checkpointing.

Design for 1000+ nodes (DESIGN.md §6):

- **Sharded**: each host writes only the leaves (or leaf shards) it owns;
  the manifest records the pytree structure + leaf shapes/dtypes so restore
  can re-shard onto a *different* mesh (elastic restart).
- **Async**: `save()` snapshots device arrays to host memory synchronously
  (cheap) and writes to disk on a background thread — training continues.
- **Atomic**: writes land in ``step_<N>.tmp/`` and a single ``rename()``
  commits; a crash mid-write leaves the previous checkpoint intact. Restore
  picks the newest committed step.
- The data-pipeline cursor is part of the checkpoint so restart is
  deterministic (no skipped/duplicated batches).
- **Sparse-aware**: :class:`SparseCheckpoint` layers the compressed-tree
  snapshot (pos/crd/vals per level), per-tensor content fingerprints, and
  the tuned-plan cache on top, so elastic recovery restores only what
  changed and skips re-partitioning / re-search for unchanged operands.
"""
from __future__ import annotations

import json
import os
import pickle
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_names(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    seen: Dict[str, int] = {}
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path) or "leaf"
        # "/"-joined paths can collide (e.g. {"a": {"b": _}, "a/b": _});
        # manifests are keyed positionally but the names must still be
        # unambiguous for humans and for name-addressed partial restores.
        if name in seen:
            seen[name] += 1
            name = f"{name}#{seen[name]}"
        else:
            seen[name] = 0
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 process_index: Optional[int] = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.proc = (process_index if process_index is not None
                     else jax.process_index())
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any], *,
             blocking: bool = False) -> None:
        """Snapshot ``state`` (pytree of arrays + scalars) at ``step``."""
        self.wait()  # one in-flight checkpoint at a time
        # synchronous device→host snapshot (consistent view)
        host_leaves = [(n, np.asarray(l)) for n, l in
                       _flatten_with_names(state)]
        treedef = jax.tree_util.tree_structure(state)

        def write():
            try:
                tmp = self.dir / f"step_{step:08d}.tmp"
                final = self.dir / f"step_{step:08d}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                manifest = {"step": step, "proc": self.proc, "leaves": []}
                for i, (name, arr) in enumerate(host_leaves):
                    fn = f"leaf_{i:05d}_p{self.proc}.npy"
                    np.save(tmp / fn, arr)
                    manifest["leaves"].append(
                        {"name": name, "file": fn,
                         "shape": list(arr.shape), "dtype": str(arr.dtype)})
                manifest["treedef"] = str(treedef)
                (tmp / f"manifest_p{self.proc}.json").write_text(
                    json.dumps(manifest))
                os.replace(tmp, final)  # atomic commit
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint failed: {err!r}")

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if not p.name.endswith(".tmp"))
        return steps[-1] if steps else None

    def restore(self, like: Dict[str, Any],
                step: Optional[int] = None) -> Tuple[int, Dict[str, Any]]:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). Re-sharding onto a new mesh happens by the
        caller placing the returned host arrays with device_put — shapes
        are global, so any mesh works (elastic restart)."""
        self._sweep_orphans()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / f"manifest_p{self.proc}.json").read_text())
        leaves = [np.load(d / leaf["file"]) for leaf in manifest["leaves"]]
        treedef = jax.tree_util.tree_structure(like)
        return step, jax.tree_util.tree_unflatten(treedef, leaves)

    def _sweep_orphans(self) -> None:
        """Remove ``step_<N>.tmp/`` directories left by a crash mid-write.
        They never commit (os.replace is the commit point) so they are
        garbage — but without this sweep they accumulate forever. Skipped
        while an async save is in flight (its tmp dir is live)."""
        if self._thread is not None and self._thread.is_alive():
            return
        for p in self.dir.glob("step_*.tmp"):
            shutil.rmtree(p, ignore_errors=True)

    def _gc(self) -> None:
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if not p.name.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)


# ---------------------------------------------------------------------------
# Sparse checkpointing — compressed trees + plan fingerprints + tuned plans
# ---------------------------------------------------------------------------


class SparseCheckpoint:
    """Checkpoint/restore for sparse-kernel run loops.

    Each snapshot holds, per tensor, the full compressed tree (vals plus
    every level's pos/crd) and its content CRC — the same fingerprint that
    keys the shard/plan caches. On restore, tensors whose live CRC already
    matches the snapshot are left untouched (their cache entries stay
    valid → recovery skips re-partitioning them); mismatches are healed in
    place. The tuned-plan cache (core.plan_search) rides along as a pickle
    so a recovered ``schedule="auto"`` run skips re-search. Arbitrary
    extra state (accumulators, step counters) goes in ``extra``.
    """

    def __init__(self, directory: str, *, keep: int = 3,
                 process_index: Optional[int] = None):
        self.mgr = CheckpointManager(directory, keep=keep,
                                     process_index=process_index)
        self._last_fp: Dict[str, int] = {}

    # -- snapshot layout ------------------------------------------------
    @staticmethod
    def _leaves(t) -> Dict[str, np.ndarray]:
        out = {"vals": np.asarray(t.vals)}
        for l, ld in enumerate(t.levels):
            if ld.pos is not None:
                out[f"pos{l}"] = np.asarray(ld.pos)
            if ld.crd is not None:
                out[f"crd{l}"] = np.asarray(ld.crd)
        return out

    @staticmethod
    def _crc(t) -> int:
        return int(t.fingerprint()[-1])

    def _like(self, tensors: Dict[str, Any],
              extra_like: Dict[str, Any]) -> Dict[str, Any]:
        return {"extra": extra_like,
                "fp": {n: np.int64(0) for n in tensors},
                "tensors": {n: self._leaves(t) for n, t in tensors.items()},
                "tuned": np.zeros(0, dtype=np.uint8)}

    # -- save / restore -------------------------------------------------
    def save(self, step: int, tensors: Dict[str, Any],
             extra: Optional[Dict[str, Any]] = None, *,
             blocking: bool = True) -> None:
        from ..core import plan_search
        fps = {n: self._crc(t) for n, t in tensors.items()}
        tuned = np.frombuffer(
            pickle.dumps(plan_search.export_tuned_entries()),
            dtype=np.uint8).copy()
        state = {"extra": dict(extra or {}),
                 "fp": {n: np.int64(c) for n, c in fps.items()},
                 "tensors": {n: self._leaves(t) for n, t in tensors.items()},
                 "tuned": tuned}
        self.mgr.save(step, state, blocking=blocking)
        self._last_fp = fps

    def stale_operands(self, tensors: Dict[str, Any]) -> List[str]:
        """Tensors whose CURRENT content CRC deviates from the last
        committed snapshot — corruption detection through the exact
        fingerprints that key the shard caches."""
        return sorted(n for n, t in tensors.items()
                      if n in self._last_fp
                      and self._crc(t) != self._last_fp[n])

    def restore(self, tensors: Dict[str, Any],
                extra_like: Optional[Dict[str, Any]] = None,
                step: Optional[int] = None,
                ) -> Tuple[int, Dict[str, Any], Dict[str, Any]]:
        """Restore the newest (or given) step. Heals mismatched tensors in
        place, leaves matching ones alone, merges tuned-plan entries back,
        and returns ``(step, extra, info)`` where info counts what was
        ``reused`` vs ``restored`` (plus ``tuned_imported``)."""
        step, got = self.mgr.restore(
            self._like(tensors, dict(extra_like or {})), step=step)
        reused, restored = [], []
        for n, t in tensors.items():
            saved_crc = int(got["fp"][n])
            if self._crc(t) == saved_crc:
                reused.append(n)
            else:
                self._copy_into(t, got["tensors"][n])
                restored.append(n)
            self._last_fp[n] = saved_crc
        n_tuned = 0
        tuned = np.asarray(got.get("tuned", np.zeros(0, np.uint8)),
                           dtype=np.uint8)
        if tuned.size:
            from ..core import plan_search
            n_tuned = plan_search.import_tuned_entries(
                pickle.loads(tuned.tobytes()))
        return step, got["extra"], {"reused": reused, "restored": restored,
                                    "tuned_imported": n_tuned}

    def wait(self) -> None:
        self.mgr.wait()

    def latest_step(self) -> Optional[int]:
        return self.mgr.latest_step()

    @staticmethod
    def _copy_into(t, leaves: Dict[str, np.ndarray]) -> None:
        t.vals[...] = leaves["vals"]
        for l, ld in enumerate(t.levels):
            if ld.pos is not None:
                ld.pos[...] = leaves[f"pos{l}"]
            if ld.crd is not None:
                ld.crd[...] = leaves[f"crd{l}"]
