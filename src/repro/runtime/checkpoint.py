"""Sharded, asynchronous, atomic checkpointing.

Design for 1000+ nodes (DESIGN.md §6):

- **Sharded**: each host writes only the leaves (or leaf shards) it owns;
  the manifest records the pytree structure + leaf shapes/dtypes so restore
  can re-shard onto a *different* mesh (elastic restart).
- **Async**: `save()` snapshots device arrays to host memory synchronously
  (cheap) and writes to disk on a background thread — training continues.
- **Atomic**: writes land in ``step_<N>.tmp/`` and a single ``rename()``
  commits; a crash mid-write leaves the previous checkpoint intact. Restore
  picks the newest committed step.
- The data-pipeline cursor is part of the checkpoint so restart is
  deterministic (no skipped/duplicated batches).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_names(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out.append((name or "leaf", leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 process_index: Optional[int] = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.proc = (process_index if process_index is not None
                     else jax.process_index())
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any], *,
             blocking: bool = False) -> None:
        """Snapshot ``state`` (pytree of arrays + scalars) at ``step``."""
        self.wait()  # one in-flight checkpoint at a time
        # synchronous device→host snapshot (consistent view)
        host_leaves = [(n, np.asarray(l)) for n, l in
                       _flatten_with_names(state)]
        treedef = jax.tree_util.tree_structure(state)

        def write():
            try:
                tmp = self.dir / f"step_{step:08d}.tmp"
                final = self.dir / f"step_{step:08d}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                manifest = {"step": step, "proc": self.proc, "leaves": []}
                for i, (name, arr) in enumerate(host_leaves):
                    fn = f"leaf_{i:05d}_p{self.proc}.npy"
                    np.save(tmp / fn, arr)
                    manifest["leaves"].append(
                        {"name": name, "file": fn,
                         "shape": list(arr.shape), "dtype": str(arr.dtype)})
                manifest["treedef"] = str(treedef)
                (tmp / f"manifest_p{self.proc}.json").write_text(
                    json.dumps(manifest))
                os.replace(tmp, final)  # atomic commit
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint failed: {err!r}")

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if not p.name.endswith(".tmp"))
        return steps[-1] if steps else None

    def restore(self, like: Dict[str, Any],
                step: Optional[int] = None) -> Tuple[int, Dict[str, Any]]:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). Re-sharding onto a new mesh happens by the
        caller placing the returned host arrays with device_put — shapes
        are global, so any mesh works (elastic restart)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / f"manifest_p{self.proc}.json").read_text())
        leaves = [np.load(d / leaf["file"]) for leaf in manifest["leaves"]]
        treedef = jax.tree_util.tree_structure(like)
        return step, jax.tree_util.tree_unflatten(treedef, leaves)

    def _gc(self) -> None:
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if not p.name.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
