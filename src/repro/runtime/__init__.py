"""Runtime subsystems: checkpointing, elastic execution, fault harness,
telemetry.

Submodules load lazily (PEP 562): ``core.lower`` and the other pipeline
modules import :mod:`repro.runtime.telemetry` at module scope, and an
eager ``from . import elastic`` here would pull ``distributed`` (and
through it ``core``) back in while ``core.lower`` is still initializing.
"""
import importlib

__all__ = ["checkpoint", "elastic", "fault", "telemetry"]


def __getattr__(name):
    if name in __all__:
        mod = importlib.import_module(f".{name}", __name__)
        globals()[name] = mod
        return mod
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + __all__)
