from . import checkpoint, elastic, fault

__all__ = ["checkpoint", "elastic", "fault"]
