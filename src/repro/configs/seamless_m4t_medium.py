"""seamless-m4t-medium — encoder-decoder, multimodal. The speech frontend
is a stub (input_specs provides precomputed frame embeddings feeding the
12-layer encoder); the 12-layer decoder handles the decode shapes.
[arXiv:2308.11596; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,               # decoder layers
    encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,         # padded internally to 256256 for sharding
    head_dim=64,
    frontend="audio",
    frontend_tokens=1024,      # speech frames after downsampling (stub)
    source="arXiv:2308.11596; hf",
))
