"""Architecture configs — one module per assigned arch (``--arch <id>``)."""
from .base import (ArchConfig, ShapeConfig, STANDARD_SHAPES, all_archs,
                   get_arch, register)

__all__ = ["ArchConfig", "ShapeConfig", "STANDARD_SHAPES", "all_archs",
           "get_arch", "register"]
