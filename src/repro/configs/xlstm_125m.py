"""xlstm-125m — alternating mLSTM / sLSTM blocks (d_ff=0: the blocks carry
their own projections). [arXiv:2405.04517; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    xlstm_pattern=("m", "s"),
    source="arXiv:2405.04517; unverified",
))
