"""llama4-scout-17b-a16e — 16-expert top-1 MoE (extreme routing skew: the
case where the paper's non-zero partitioning matters most), early fusion.
The shared-expert branch of the released model is folded into the routed
experts (DESIGN.md §Arch-applicability).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    rope_theta=500000.0,
    moe_experts=16,
    moe_topk=1,
    moe_capacity_factor=1.5,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
))
