"""Architecture + shape configuration system.

Every assigned architecture is an :class:`ArchConfig` (one module per arch in
this package, selectable via ``--arch <id>`` in the launchers). Shapes are
the four assigned input shapes; ``long_500k`` lowers ``serve_step`` with
block-sparse sliding-window attention for full-attention archs (DESIGN.md
§4) and natively for SSM/hybrid archs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                 # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int
    grad_accum: int = 1       # microbatch accumulation steps (train)
    attention_window: int = 0  # >0 → block-sparse sliding window override


# grad_accum=16 → per-device microbatch of 1 sequence on the 16-wide data
# axis: keeps dense-attention activations + remat peaks inside v5e HBM.
TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256, grad_accum=16)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

STANDARD_SHAPES: Dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0         # 0 → d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # MoE
    moe_experts: int = 0
    moe_topk: int = 0
    moe_capacity_factor: float = 1.25
    moe_every: int = 1        # every Nth layer is MoE (llama4 interleave)
    # SSM (Mamba2-style)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    # hybrid: one shared attention block applied every N ssm layers
    hybrid_attn_every: int = 0
    # xLSTM: per-layer pattern, cycled over n_layers ("m"=mLSTM, "s"=sLSTM)
    xlstm_pattern: Tuple[str, ...] = ()
    # enc-dec
    encoder_layers: int = 0
    # modality frontend stub (precomputed embeddings via input_specs)
    frontend: str = "none"    # none|vision|audio
    frontend_tokens: int = 0
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    # training-shape overrides (§Perf iteration 5): fewer, larger
    # microbatches cut per-microbatch gradient reductions and FSDP weight
    # gathers; chunked attention keeps big-microbatch memory bounded.
    grad_accum_override: int = 0
    train_attn_variant: str = "auto"
    # attention defaults
    attention_window: int = 0
    source: str = ""          # provenance note ([arXiv/hf; tier])

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // max(self.n_heads, 1)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch natively supports very long context."""
        return self.family in ("ssm", "hybrid")

    def vocab_padded(self, multiple: int = 256) -> int:
        """Vocab padded so the embedding shards evenly on any mesh axis we
        use (≤ 256); logits beyond vocab_size are masked to -inf in loss."""
        return int(-(-self.vocab_size // multiple) * multiple)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, hd = self.d_model, self.d_ff, self.resolved_head_dim
        qkv = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        mlp = 3 * d * f
        if self.moe_experts:
            mlp = 3 * d * f * self.moe_experts + d * self.moe_experts
        ssm = 0
        if self.family in ("ssm", "hybrid") and not self.xlstm_pattern:
            di = self.ssm_expand * d
            ssm = d * (2 * di + 2 * self.ssm_groups * self.ssm_state) + di * d
        per_layer = {
            "dense": qkv + mlp, "moe": qkv + mlp, "vlm": qkv + mlp,
            "audio": qkv + mlp, "ssm": ssm or (qkv + mlp), "hybrid": ssm,
        }[self.family]
        n = self.n_layers * per_layer + 2 * self.vocab_size * d
        if self.family == "hybrid" and self.hybrid_attn_every:
            n += qkv + mlp  # one shared block
        if self.is_encdec:
            n += self.encoder_layers * (qkv + mlp)
        return int(n)

    def active_param_count(self) -> int:
        """6·N_active·D convention for MoE rooflines."""
        if not self.moe_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        qkv = d * self.resolved_head_dim * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * self.resolved_head_dim * d
        mlp_active = 3 * d * f * self.moe_topk + d * self.moe_experts
        return int(self.n_layers * (qkv + mlp_active)
                   + 2 * self.vocab_size * d)

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            moe_experts=min(self.moe_experts, 4),
            moe_topk=min(self.moe_topk, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            encoder_layers=min(self.encoder_layers, 2),
            frontend_tokens=min(self.frontend_tokens, 8),
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            remat=False,
        )

    def shapes(self) -> Dict[str, ShapeConfig]:
        """The assigned shape set, with per-arch long_500k handling."""
        out = dict(STANDARD_SHAPES)
        if not self.sub_quadratic:
            # full-attention archs run long_500k only with the block-sparse
            # sliding window built on the paper's format machinery
            out["long_500k"] = dataclasses.replace(
                out["long_500k"], attention_window=8192)
        return out


_REGISTRY: Dict[str, "ArchConfig"] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_archs() -> Dict[str, ArchConfig]:
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


def _load_all() -> None:
    from . import (internlm2_1_8b, llama3_8b, llama4_scout_17b_a16e,  # noqa
                   llava_next_34b, olmoe_1b_7b, qwen3_14b,
                   seamless_m4t_medium, starcoder2_15b, xlstm_125m,
                   zamba2_7b)
