"""zamba2-7b — hybrid Mamba2 backbone + one shared transformer block
(attn+MLP, weights reused) applied every 6 SSM layers.
[arXiv:2411.15242; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,              # d_model / n_heads
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    hybrid_attn_every=6,       # 13 shared-block applications + 3 tail layers
    source="arXiv:2411.15242; unverified",
))
