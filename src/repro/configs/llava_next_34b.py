"""llava-next-34b — VLM backbone (anyres tiling frontend is a stub;
input_specs provides precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    rope_theta=1000000.0,
    frontend="vision",
    frontend_tokens=576,       # one anyres base tile of 24x24 patches
    # §Perf iterations 4-5 tried accum=8 + chunked attention here: REFUTED
    # (activation TP all-reduces scale with tokens, not accum; chunked
    # attention's f32 flash carries pushed peak HBM to 27.7 GiB). Defaults
    # retained — see EXPERIMENTS.md §Perf.
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
))
