"""olmoe-1b-7b — 64-expert top-8 MoE, every layer. The expert dispatch is
the SpDISTAL coordinate-fusion + non-zero-partition path (models/moe.py).
[arXiv:2409.02060; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    head_dim=128,
    moe_experts=64,
    moe_topk=8,
    moe_capacity_factor=1.25,
    source="arXiv:2409.02060; hf",
))
