"""Chunkwise gated linear attention — shared core for Mamba2 SSD and mLSTM.

Both blocks are instances of the recurrence

    h_t = exp(g_t) · h_{t-1} + s_t · K_t ⊗ x_t          (state (H, N, P))
    y_t = Q_t · h_t

with per-block choices of gate ``g``, scale ``s``, keys ``K`` and queries
``Q`` (SSD: g = Δ·A, s = Δ, K/Q = B/C shared across heads; mLSTM: g = log f,
s = i, K/Q = k/q per head). The chunkwise-parallel form splits S into chunks
of Q_len: intra-chunk terms are dense matmuls (MXU work), inter-chunk state
is a short scan over S/Q_len steps — the TPU-friendly formulation of a
sub-quadratic sequence mixer (this is what makes long_500k native for the
ssm/hybrid archs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gla_chunked(xv: jax.Array, log_decay: jax.Array, scale: jax.Array,
                K: jax.Array, Q: jax.Array, chunk: int = 128,
                init_state: jax.Array | None = None):
    """Returns (y, final_state).

    xv:        (B, S, H, P) values
    log_decay: (B, S, H)    per-step log gate (≤ 0 for stability)
    scale:     (B, S, H)    per-step input scale
    K, Q:      (B, S, H, N) or (B, S, N) (shared across heads)
    """
    B, S, H, P = xv.shape
    if K.ndim == 3:
        K = jnp.broadcast_to(K[:, :, None, :], (B, S, H, K.shape[-1]))
    if Q.ndim == 3:
        Q = jnp.broadcast_to(Q[:, :, None, :], (B, S, H, Q.shape[-1]))
    N = K.shape[-1]
    assert S % chunk == 0, "pad sequence to a chunk multiple first"
    nc = S // chunk

    r4 = lambda t: t.reshape(B, nc, chunk, *t.shape[2:])
    xv_c, g_c, s_c = r4(xv), r4(log_decay), r4(scale)
    K_c, Q_c = r4(K), r4(Q)

    cum = jnp.cumsum(g_c.astype(jnp.float32), axis=2)       # (B,nc,Q,H)
    # intra-chunk: M[h,q,k] = (Q[q]·K[k]) exp(cum[q]-cum[k]) s[k]  (k ≤ q)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    decay = decay.transpose(0, 1, 4, 2, 3).astype(xv.dtype)  # (B,nc,H,Q,Q)
    qk = jnp.einsum("bcqhn,bckhn->bchqk", Q_c, K_c)
    M = qk * decay * s_c.astype(xv.dtype).transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", M, xv_c)

    # chunk-final states
    dec_to_end = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,nc,Q,H)
    kx = (dec_to_end * s_c.astype(jnp.float32)).astype(xv.dtype)
    h_chunk = jnp.einsum("bckh,bckhn,bckhp->bchnp", kx, K_c, xv_c)
    chunk_decay = jnp.exp(cum[:, :, -1, :]).astype(xv.dtype)  # (B,nc,H)

    def step(h, inp):
        hc, cd = inp
        h_new = h * cd[:, :, None, None] + hc
        return h_new, h

    h0 = (init_state if init_state is not None
          else jnp.zeros((B, H, N, P), xv.dtype))
    hs = jnp.swapaxes(h_chunk, 0, 1)
    cds = jnp.swapaxes(chunk_decay, 0, 1)
    h_last, h_prev = jax.lax.scan(step, h0, (hs, cds))
    h_prev = jnp.swapaxes(h_prev, 0, 1)                     # (B,nc,H,N,P)

    dec_from_start = jnp.exp(cum).astype(xv.dtype)          # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqhn,bcqh,bchnp->bcqhp",
                         Q_c, dec_from_start, h_prev)
    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y, h_last


def gla_decode_step(h: jax.Array, xv: jax.Array, log_decay: jax.Array,
                    scale: jax.Array, K: jax.Array, Q: jax.Array):
    """Single-token recurrence. h: (B,H,N,P); xv: (B,H,P);
    log_decay/scale: (B,H); K/Q: (B,H,N) or (B,N)."""
    B, H = log_decay.shape
    if K.ndim == 2:
        K = jnp.broadcast_to(K[:, None, :], (B, H, K.shape[-1]))
    if Q.ndim == 2:
        Q = jnp.broadcast_to(Q[:, None, :], K.shape)
    decay = jnp.exp(log_decay.astype(jnp.float32)).astype(xv.dtype)
    upd = jnp.einsum("bhn,bhp->bhnp", K, scale.astype(xv.dtype)[..., None] * xv)
    h_new = h * decay[:, :, None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Q, h_new)
    return y, h_new
