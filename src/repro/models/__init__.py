"""Model stack: LM assembly + per-family blocks."""
from .layers import NO_SHARD, ShardCtx
from .model import LM

__all__ = ["LM", "ShardCtx", "NO_SHARD"]
