"""Block-sparse attention masks through the paper's format machinery.

The (q-block × kv-block) mask of a sparse attention pattern IS a sparse
matrix; we store it in the paper's formats (Dense row-block level ×
Compressed column-block level — block-CSR) and reuse the same partitioning
machinery that distributes any other sparse tensor. ``band_plan`` builds
the sliding-window pattern used by long_500k on full-attention archs
(DESIGN.md §4); ``block_sparse_attention`` executes attention over an
ARBITRARY block mask by gathering only the listed kv blocks (ELL-packed,
like the TPU kernels in kernels/layout.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import formats as F
from ..core.tensor import Tensor
from ..kernels.layout import ell_pack


def band_plan(seq_len: int, q_block: int, window: int,
              name: str = "attn_mask") -> Tensor:
    """Causal sliding-window pattern as a block-CSR Tensor.

    Rows = query blocks, cols = kv blocks; entry present iff some (q, kv)
    pair inside the tile satisfies kv ≤ q and kv > q - window."""
    nq = -(-seq_len // q_block)
    rows, cols = [], []
    for qb in range(nq):
        q_hi = min((qb + 1) * q_block, seq_len) - 1
        q_lo = qb * q_block
        kv_lo_needed = max(q_lo - window + 1, 0)
        for kb in range(kv_lo_needed // q_block, qb + 1):
            rows.append(qb)
            cols.append(kb)
    coords = np.stack([np.array(rows), np.array(cols)], 1)
    vals = np.ones(coords.shape[0], np.float32)
    return Tensor.from_coo(name, (nq, nq), coords, vals, F.CSR())


def band_decode_kernel(seq_len: int, q_block: int, window: int,
                       machine, *, batch: int = 8, schedule=None):
    """The band mask lowered as the frozen sparse operand of a batched
    serving kernel (the ISSUE-10 fast path).

    Each decode request carries a per-kv-block summary vector ``v`` (one
    entry per block — e.g. a pooled value/score statistic), and
    ``y = attn_mask @ v`` aggregates it under the sliding-window pattern.
    The mask never changes between requests, so the plan, the packed CSR
    shards, and the per-bucket jitted runner are all built exactly once;
    ``run_many`` batches concurrent decode streams into one SpMM.
    Returns a :class:`repro.core.lower.BatchedKernel`."""
    from ..core.lower import lower_batched
    from ..core.tin import parse_tin
    mask = band_plan(seq_len, q_block, window)
    nq = mask.shape[0]
    stmt = parse_tin("y(i) = attn_mask(i,j) * v(j)",
                     y=Tensor.zeros_dense("y", (nq,)),
                     attn_mask=mask,
                     v=Tensor.zeros_dense("v", (nq,)))
    return lower_batched(stmt, machine, batch=batch, schedule=schedule)


def mask_to_ell(mask: Tensor, block_r: int = 1):
    """Pack the block mask's CSR into the ELL layout the gather kernel
    consumes: (nq, max_blocks) kv-block ids + validity."""
    pos = mask.levels[1].pos
    crd = mask.levels[1].crd
    nq = mask.shape[0]
    counts = np.diff(pos)
    maxb = int(counts.max()) if counts.size else 1
    idx = np.full((nq, maxb), -1, np.int32)
    for q in range(nq):
        lo, hi = int(pos[q]), int(pos[q + 1])
        idx[q, : hi - lo] = crd[lo:hi]
    return jnp.asarray(idx)


def block_sparse_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           block_idx: jax.Array, q_block: int,
                           causal: bool = True,
                           window: int = 0) -> jax.Array:
    """Attention over an arbitrary block mask.

    q, k, v: (B, S, H, hd); block_idx: (nq, maxb) kv-block ids (−1 = pad).
    Each query block gathers only its listed kv blocks — compute scales
    with nnz(blocks)·q_block², not S². Block sparsity is block-granular;
    ``causal`` and ``window`` refine the mask at element granularity inside
    edge blocks (band_plan + window reproduces exact sliding-window
    attention).
    """
    B, S, H, hd = q.shape
    nq, maxb = block_idx.shape
    pad = nq * q_block - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qb = q.reshape(B, nq, q_block, H, hd)
    kb = k.reshape(B, nq, q_block, H, hd)
    vb = v.reshape(B, nq, q_block, H, hd)
    scale = hd ** -0.5

    def one_qblock(qi):
        idx = block_idx[qi]                        # (maxb,)
        safe = jnp.maximum(idx, 0)
        kg = jnp.take(kb, safe, axis=1)            # (B, maxb, qb, H, hd)
        vg = jnp.take(vb, safe, axis=1)
        s = jnp.einsum("bqhd,bmkhd->bhqmk", qb[:, qi], kg
                       ).astype(jnp.float32) * scale
        q_pos = qi * q_block + jnp.arange(q_block)
        kv_pos = safe[:, None] * q_block + jnp.arange(q_block)[None, :]
        valid = (idx >= 0)[:, None] & (kv_pos < S)
        if causal:
            valid = valid[None, :, :] & \
                (kv_pos[None] <= q_pos[:, None, None])
        else:
            valid = jnp.broadcast_to(valid[None], (q_block, maxb, q_block))
        if window:
            valid = valid & (kv_pos[None] > q_pos[:, None, None] - window)
        s = jnp.where(valid[None, None], s, -1e30)
        w = jax.nn.softmax(s.reshape(B, H, q_block, -1), axis=-1)
        w = w.reshape(B, H, q_block, maxb, q_block).astype(q.dtype)
        return jnp.einsum("bhqmk,bmkhd->bqhd", w, vg)

    out = jax.lax.map(one_qblock, jnp.arange(nq))   # (nq, B, qb, H, hd)
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_block, H, hd)
    return out[:, :S]
