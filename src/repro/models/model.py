"""LM assembly for all ten assigned architectures.

One :class:`LM` wraps an ArchConfig into init / apply / decode / loss. Layers
are grouped into *scan groups* (heterogeneous stacks supported: llama4's
dense+MoE interleave, xLSTM's (m, s) pattern, zamba2's 6-Mamba+shared-attn
super-layer) and `jax.lax.scan`ned so HLO size — and dry-run compile time
for 80 (arch × shape × mesh) cells — is depth-independent. `jax.checkpoint`
around the group body implements activation rematerialization.

Decode carries a pytree cache stacked on the group axis and scans groups,
giving O(1) HLO for the serve step too.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from . import attention as A
from . import moe as MOE
from . import ssm as SSM
from . import xlstm as XL
from .layers import (NO_SHARD, ShardCtx, embed_init, mlp_apply, mlp_init,
                     rmsnorm)


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


class LM:
    """Functional language model for one architecture config."""

    def __init__(self, cfg: ArchConfig, ctx: ShardCtx = NO_SHARD):
        self.cfg = cfg
        self.ctx = ctx
        self.dtype = _dtype(cfg.dtype)
        self.param_dtype = _dtype(cfg.param_dtype)
        self.vp = cfg.vocab_padded()
        self._plan_groups()

    # ------------------------------------------------------------------
    # Layer grouping
    # ------------------------------------------------------------------
    def _plan_groups(self):
        cfg = self.cfg
        if cfg.family == "hybrid" and cfg.hybrid_attn_every:
            self.group_size = cfg.hybrid_attn_every
            self.n_groups = cfg.n_layers // self.group_size
            self.tail_layers = cfg.n_layers - self.n_groups * self.group_size
            self.group_kind = "hybrid"
        elif cfg.xlstm_pattern:
            self.group_size = len(cfg.xlstm_pattern)
            assert cfg.n_layers % self.group_size == 0
            self.n_groups = cfg.n_layers // self.group_size
            self.tail_layers = 0
            self.group_kind = "xlstm"
        elif cfg.moe_experts and cfg.moe_every > 1:
            self.group_size = cfg.moe_every
            assert cfg.n_layers % cfg.moe_every == 0
            self.n_groups = cfg.n_layers // cfg.moe_every
            self.tail_layers = 0
            self.group_kind = "moe_interleaved"
        elif cfg.moe_experts:
            self.group_size, self.n_groups = 1, cfg.n_layers
            self.tail_layers = 0
            self.group_kind = "moe"
        elif cfg.family == "ssm":
            self.group_size, self.n_groups = 1, cfg.n_layers
            self.tail_layers = 0
            self.group_kind = "ssm"
        else:
            self.group_size, self.n_groups = 1, cfg.n_layers
            self.tail_layers = 0
            self.group_kind = "dense"

    # ------------------------------------------------------------------
    # Init
    # ------------------------------------------------------------------
    def _init_attn(self, key):
        cfg = self.cfg
        return A.attn_init(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                           cfg.resolved_head_dim, qk_norm=cfg.qk_norm,
                           dtype=self.param_dtype)

    def _init_dense_layer(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "attn": self._init_attn(k1),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, self.param_dtype),
            "ln1": jnp.ones((cfg.d_model,), self.param_dtype),
            "ln2": jnp.ones((cfg.d_model,), self.param_dtype),
        }

    def _init_moe_layer(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "attn": self._init_attn(k1),
            "moe": MOE.moe_init(k2, cfg.d_model, cfg.d_ff, cfg.moe_experts,
                                self.param_dtype),
            "ln1": jnp.ones((cfg.d_model,), self.param_dtype),
            "ln2": jnp.ones((cfg.d_model,), self.param_dtype),
        }

    def _init_group(self, key):
        cfg = self.cfg
        kind = self.group_kind
        if kind == "dense":
            return self._init_dense_layer(key)
        if kind == "moe":
            return self._init_moe_layer(key)
        if kind == "moe_interleaved":
            ks = jax.random.split(key, self.group_size)
            return {
                "dense": jax.vmap(self._init_dense_layer)(ks[:-1]),
                "moe": self._init_moe_layer(ks[-1]),
            }
        if kind == "ssm":
            return {
                "ssm": SSM.ssm_init(key, cfg.d_model, state=cfg.ssm_state,
                                    expand=cfg.ssm_expand,
                                    head_dim=cfg.ssm_head_dim,
                                    dtype=self.param_dtype),
                "ln": jnp.ones((cfg.d_model,), self.param_dtype),
            }
        if kind == "hybrid":
            ks = jax.random.split(key, self.group_size)
            def one(k):
                return {
                    "ssm": SSM.ssm_init(k, cfg.d_model, state=cfg.ssm_state,
                                        expand=cfg.ssm_expand,
                                        head_dim=cfg.ssm_head_dim,
                                        dtype=self.param_dtype),
                    "ln": jnp.ones((cfg.d_model,), self.param_dtype),
                }
            return jax.vmap(one)(ks)
        if kind == "xlstm":
            out = {}
            ks = jax.random.split(key, self.group_size)
            for i, p in enumerate(cfg.xlstm_pattern):
                if p == "m":
                    out[f"m{i}"] = XL.mlstm_init(ks[i], cfg.d_model,
                                                 cfg.n_heads, self.param_dtype)
                else:
                    out[f"s{i}"] = XL.slstm_init(ks[i], cfg.d_model,
                                                 cfg.n_heads, self.param_dtype)
                out[f"ln{i}"] = jnp.ones((cfg.d_model,), self.param_dtype)
            return out
        raise ValueError(kind)

    def init_params(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        gkeys = jax.random.split(keys[0], self.n_groups)
        params: Dict[str, Any] = {
            "embed": embed_init(keys[1], self.vp, cfg.d_model,
                                self.param_dtype),
            "blocks": jax.vmap(self._init_group)(gkeys),
            "final_norm": jnp.ones((cfg.d_model,), self.param_dtype),
            "unembed": embed_init(keys[2], cfg.d_model, self.vp,
                                  self.param_dtype).reshape(cfg.d_model, self.vp),
        }
        if self.group_kind == "hybrid":
            params["shared_attn"] = {
                "attn": self._init_attn(keys[3]),
                "ln": jnp.ones((cfg.d_model,), self.param_dtype),
            }
            if cfg.d_ff:
                params["shared_attn"]["mlp"] = mlp_init(
                    jax.random.split(keys[3])[1], cfg.d_model, cfg.d_ff,
                    self.param_dtype)
                params["shared_attn"]["ln2"] = jnp.ones(
                    (cfg.d_model,), self.param_dtype)
            if self.tail_layers:
                tkeys = jax.random.split(keys[4], self.tail_layers)
                def one(k):
                    return {
                        "ssm": SSM.ssm_init(k, cfg.d_model,
                                            state=cfg.ssm_state,
                                            expand=cfg.ssm_expand,
                                            head_dim=cfg.ssm_head_dim,
                                            dtype=self.param_dtype),
                        "ln": jnp.ones((cfg.d_model,), self.param_dtype),
                    }
                params["tail"] = jax.vmap(one)(tkeys)
        if cfg.is_encdec:
            ekeys = jax.random.split(keys[5], cfg.encoder_layers)
            params["encoder"] = jax.vmap(self._init_dense_layer)(ekeys)
            params["enc_norm"] = jnp.ones((cfg.d_model,), self.param_dtype)
            ckeys = jax.random.split(keys[6], self.n_groups)
            params["cross"] = jax.vmap(
                lambda k: {"attn": self._init_attn(k),
                           "ln": jnp.ones((cfg.d_model,), self.param_dtype)}
            )(ckeys)
        return params

    def abstract_params(self):
        """ShapeDtypeStruct tree — zero-allocation init for the dry-run."""
        return jax.eval_shape(
            lambda k: self.init_params(k), jax.random.PRNGKey(0))

    # ------------------------------------------------------------------
    # Forward (train / prefill)
    # ------------------------------------------------------------------
    def _attn_kwargs(self, window: int, variant: str):
        cfg = self.cfg
        return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                    head_dim=cfg.resolved_head_dim,
                    rope_theta=cfg.rope_theta, window=window,
                    variant=variant, ctx=self.ctx)

    def _apply_group(self, gp, x, *, window: int, variant: str,
                     enc_out=None, aux_acc=None):
        cfg = self.cfg
        ctx = self.ctx
        kind = self.group_kind
        akw = self._attn_kwargs(window, variant)
        aux = jnp.zeros((), jnp.float32)
        if kind in ("dense", "moe"):
            h = rmsnorm(x, gp["ln1"])
            x = x + A.attention_apply(gp["attn"], h, **akw)
            if enc_out is not None and "cross" in gp:
                hc = rmsnorm(x, gp["cross"]["ln"])
                x = x + A.attention_apply(
                    gp["cross"]["attn"], hc, causal=False, use_rope=False,
                    kv_override=self._encode_kv(gp["cross"]["attn"], enc_out),
                    **akw)
            h = rmsnorm(x, gp["ln2"])
            if kind == "moe":
                y, aux = MOE.moe_apply(gp["moe"], h,
                                       n_experts=cfg.moe_experts,
                                       top_k=cfg.moe_topk,
                                       capacity_factor=cfg.moe_capacity_factor,
                                       ctx=ctx)
                x = x + y
            else:
                x = x + mlp_apply(gp["mlp"], h, ctx)
            return x, aux
        if kind == "moe_interleaved":
            def dense_body(xx, lp):
                h = rmsnorm(xx, lp["ln1"])
                xx = xx + A.attention_apply(lp["attn"], h, **akw)
                h = rmsnorm(xx, lp["ln2"])
                return xx + mlp_apply(lp["mlp"], h, ctx), None
            x, _ = jax.lax.scan(dense_body, x, gp["dense"])
            h = rmsnorm(x, gp["moe"]["ln1"])
            x = x + A.attention_apply(gp["moe"]["attn"], h, **akw)
            h = rmsnorm(x, gp["moe"]["ln2"])
            y, aux = MOE.moe_apply(gp["moe"]["moe"], h,
                                   n_experts=cfg.moe_experts,
                                   top_k=cfg.moe_topk,
                                   capacity_factor=cfg.moe_capacity_factor,
                                   ctx=ctx)
            return x + y, aux
        if kind == "ssm":
            h = rmsnorm(x, gp["ln"])
            return x + SSM.ssm_apply(gp["ssm"], h, state=cfg.ssm_state,
                                     expand=cfg.ssm_expand,
                                     head_dim=cfg.ssm_head_dim, ctx=ctx), aux
        if kind == "hybrid":
            def body(xx, lp):
                h = rmsnorm(xx, lp["ln"])
                return xx + SSM.ssm_apply(lp["ssm"], h, state=cfg.ssm_state,
                                          expand=cfg.ssm_expand,
                                          head_dim=cfg.ssm_head_dim,
                                          ctx=ctx), None
            shared = gp.pop("__shared__") if "__shared__" in gp else None
            x, _ = jax.lax.scan(body, x, gp)
            if shared is not None:
                # zamba2: ONE shared-weight transformer block (attn + MLP)
                # applied after every group of ssm layers (weights broadcast,
                # not scanned)
                h = rmsnorm(x, shared["ln"])
                x = x + A.attention_apply(shared["attn"], h, **akw)
                if "mlp" in shared:
                    h = rmsnorm(x, shared["ln2"])
                    x = x + mlp_apply(shared["mlp"], h, ctx)
            return x, aux
        if kind == "xlstm":
            for i, p in enumerate(cfg.xlstm_pattern):
                h = rmsnorm(x, gp[f"ln{i}"])
                if p == "m":
                    x = x + XL.mlstm_apply(gp[f"m{i}"], h,
                                           n_heads=cfg.n_heads, ctx=ctx)
                else:
                    x = x + XL.slstm_apply(gp[f"s{i}"], h,
                                           n_heads=cfg.n_heads, ctx=ctx)
            return x, aux
        raise ValueError(kind)

    def _encode_kv(self, attn_params, enc_out):
        cfg = self.cfg
        B, T, _ = enc_out.shape
        hd = cfg.resolved_head_dim
        dt = enc_out.dtype
        k = (enc_out @ attn_params["wk"].astype(dt)
             ).reshape(B, T, cfg.n_kv_heads, hd)
        v = (enc_out @ attn_params["wv"].astype(dt)
             ).reshape(B, T, cfg.n_kv_heads, hd)
        return k, v

    def _run_encoder(self, params, frontend_embeds, window, variant):
        akw = self._attn_kwargs(window, variant)
        def body(x, lp):
            h = rmsnorm(x, lp["ln1"])
            x = x + A.attention_apply(lp["attn"], h, causal=False, **akw)
            h = rmsnorm(x, lp["ln2"])
            return x + mlp_apply(lp["mlp"], h, self.ctx), None
        x, _ = jax.lax.scan(body, frontend_embeds.astype(self.dtype),
                            params["encoder"])
        return rmsnorm(x, params["enc_norm"])

    def apply(self, params, tokens, frontend_embeds=None, *, window: int = 0,
              variant: str = "auto",
              last_only: bool = False) -> Tuple[jax.Array, jax.Array]:
        """tokens: (B, S) int32 → (logits (B, S', vp), aux_loss).

        For decoder-only VLM/audio archs, frontend embeds are *prepended* to
        the token embeds (S' = T_f + S); for enc-dec they feed the encoder.
        """
        cfg = self.cfg
        ctx = self.ctx
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
        x = ctx.cs(x, "batch", None, None)
        enc_out = None
        if cfg.is_encdec:
            assert frontend_embeds is not None
            enc_out = self._run_encoder(params, frontend_embeds, 0, variant)
        elif frontend_embeds is not None:
            x = jnp.concatenate([frontend_embeds.astype(self.dtype), x], 1)
            x = ctx.cs(x, "batch", None, None)

        group_fn = functools.partial(self._apply_group, window=window,
                                     variant=variant)

        shared = params.get("shared_attn")

        def scan_body(carry, gp):
            xx, aux = carry
            if cfg.is_encdec:
                gp = dict(gp)  # merge cross-attn params into the group
                gp["cross"] = gp.pop("__cross__")
            if shared is not None:
                gp = dict(gp)
                gp["__shared__"] = shared  # broadcast, not scanned
            xx, a = group_fn(gp, xx, enc_out=enc_out)
            return (xx, aux + a), None

        body = scan_body
        if cfg.remat:
            body = jax.checkpoint(scan_body, prevent_cse=False)

        blocks = params["blocks"]
        if cfg.is_encdec:
            blocks = dict(blocks)
            blocks["__cross__"] = params["cross"]
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   blocks)
        if self.group_kind == "hybrid" and self.tail_layers:
            def tail_body(xx, lp):
                h = rmsnorm(xx, lp["ln"])
                return xx + SSM.ssm_apply(lp["ssm"], h, state=cfg.ssm_state,
                                          expand=cfg.ssm_expand,
                                          head_dim=cfg.ssm_head_dim,
                                          ctx=ctx), None
            x, _ = jax.lax.scan(tail_body, x, params["tail"])
        if last_only:
            x = x[:, -1:]   # prefill: only the next-token logits matter
        x = rmsnorm(x, params["final_norm"])
        logits = x @ params["unembed"].astype(self.dtype)
        logits = ctx.cs(logits, "batch", None, "model")
        return logits, aux

    # ------------------------------------------------------------------
    # Loss
    # ------------------------------------------------------------------
    def loss(self, params, tokens, frontend_embeds=None, *, window: int = 0,
             variant: str = "auto") -> jax.Array:
        cfg = self.cfg
        logits, aux = self.apply(params, tokens, frontend_embeds,
                                 window=window, variant=variant)
        S = tokens.shape[1]
        logits = logits[:, -S:]               # drop frontend positions
        lg = logits[:, :-1].astype(jnp.float32)
        tgt = tokens[:, 1:]
        # mask padded vocab entries
        vmask = jnp.arange(self.vp) < cfg.vocab_size
        lg = jnp.where(vmask[None, None, :], lg, -1e30)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
        ce = (lse - gold).mean()
        return ce + 0.01 * aux

    # ------------------------------------------------------------------
    # Decode
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, context: int, *, window: int = 0,
                   src_len: int = 0) -> Dict[str, Any]:
        """Cache pytree stacked on the group axis.

        ``context`` is the KV length for attention caches (the window size
        when windowed); SSM/xLSTM states are O(1)."""
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        Sc = min(window, context) if window else context
        G = self.n_groups
        cache: Dict[str, Any] = {"pos": jnp.zeros((batch,), jnp.int32)}
        kv = lambda: jnp.zeros((G, batch, Sc, cfg.n_kv_heads, hd), self.dtype)
        if self.group_kind in ("dense", "moe"):
            cache["k"], cache["v"] = kv(), kv()
        elif self.group_kind == "moe_interleaved":
            n_attn = self.group_size
            shp = (G, n_attn, batch, Sc, cfg.n_kv_heads, hd)
            cache["k"] = jnp.zeros(shp, self.dtype)
            cache["v"] = jnp.zeros(shp, self.dtype)
        elif self.group_kind == "ssm":
            shp = SSM.ssm_state_shape(batch, cfg.d_model, state=cfg.ssm_state,
                                      expand=cfg.ssm_expand,
                                      head_dim=cfg.ssm_head_dim)
            cache["ssm"] = jnp.zeros((G,) + shp, self.dtype)
        elif self.group_kind == "hybrid":
            shp = SSM.ssm_state_shape(batch, cfg.d_model, state=cfg.ssm_state,
                                      expand=cfg.ssm_expand,
                                      head_dim=cfg.ssm_head_dim)
            cache["ssm"] = jnp.zeros((G, self.group_size) + shp, self.dtype)
            # shared attention block: weights are shared across groups but
            # each group's invocation sees different activations, so the KV
            # cache is per-group (G, ...)
            cache["shared_k"] = jnp.zeros(
                (G, batch, Sc, cfg.n_kv_heads, hd), self.dtype)
            cache["shared_v"] = jnp.zeros_like(cache["shared_k"])
            if self.tail_layers:
                cache["tail_ssm"] = jnp.zeros(
                    (self.tail_layers,) + shp, self.dtype)
        elif self.group_kind == "xlstm":
            for i, p in enumerate(cfg.xlstm_pattern):
                if p == "m":
                    shp = XL.mlstm_state_shape(batch, cfg.d_model, cfg.n_heads)
                else:
                    shp = XL.slstm_state_shape(batch, cfg.d_model)
                cache[f"x{i}"] = jnp.zeros((G,) + shp,
                                           jnp.float32 if p == "s" else self.dtype)
        if cfg.is_encdec:
            cache["enc_k"] = jnp.zeros(
                (G, batch, src_len, cfg.n_kv_heads, hd), self.dtype)
            cache["enc_v"] = jnp.zeros_like(cache["enc_k"])
        return cache

    def decode_step(self, params, cache, token, *, window: int = 0):
        """token: (B,) int32 → (logits (B, vp), new cache)."""
        cfg = self.cfg
        ctx = self.ctx
        pos = cache["pos"]
        x = jnp.take(params["embed"], token[:, None], axis=0).astype(self.dtype)
        x = ctx.cs(x, "batch", None, None)
        akw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                   head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                   window=window, ctx=ctx)
        kind = self.group_kind

        if kind in ("dense", "moe"):
            encdec = cfg.is_encdec

            def body(x, gp, ck, cv, cek, cev):
                h = rmsnorm(x, gp["ln1"])
                y, nk, nv = A.attention_decode(gp["attn"], h, ck, cv, pos,
                                               **akw)
                x = x + y
                if encdec:
                    hc = rmsnorm(x, gp["cross"]["ln"])
                    x = x + self._cross_decode(gp["cross"]["attn"], hc,
                                               cek, cev)
                h = rmsnorm(x, gp["ln2"])
                if kind == "moe":
                    y2, _ = MOE.moe_apply(gp["moe"], h,
                                          n_experts=cfg.moe_experts,
                                          top_k=cfg.moe_topk,
                                          capacity_factor=cfg.moe_capacity_factor,
                                          ctx=ctx)
                    x = x + y2
                else:
                    x = x + mlp_apply(gp["mlp"], h, ctx)
                return x, (nk, nv)

            if encdec:
                blocks = dict(params["blocks"])
                blocks["cross"] = params["cross"]
                xs = (blocks, cache["k"], cache["v"],
                      cache["enc_k"], cache["enc_v"])
                x, (nk, nv) = jax.lax.scan(
                    lambda x, sl: body(x, *sl), x, xs)
            else:
                xs = (params["blocks"], cache["k"], cache["v"])
                x, (nk, nv) = jax.lax.scan(
                    lambda x, sl: body(x, sl[0], sl[1], sl[2], None, None),
                    x, xs)
            cache = dict(cache)
            cache["k"], cache["v"] = nk, nv

        elif kind == "moe_interleaved":
            def group_body(x, sl):
                gp, ck, cv = sl   # ck: (n_attn, B, Sc, H, hd)
                nks, nvs = [], []
                for li in range(self.group_size - 1):
                    lp = jax.tree.map(lambda t: t[li], gp["dense"])
                    h = rmsnorm(x, lp["ln1"])
                    y, nk, nv = A.attention_decode(lp["attn"], h, ck[li],
                                                   cv[li], pos, **akw)
                    x = x + y
                    h = rmsnorm(x, lp["ln2"])
                    x = x + mlp_apply(lp["mlp"], h, ctx)
                    nks.append(nk); nvs.append(nv)
                mp = gp["moe"]
                h = rmsnorm(x, mp["ln1"])
                y, nk, nv = A.attention_decode(mp["attn"], h, ck[-1], cv[-1],
                                               pos, **akw)
                x = x + y
                nks.append(nk); nvs.append(nv)
                h = rmsnorm(x, mp["ln2"])
                y2, _ = MOE.moe_apply(mp["moe"], h, n_experts=cfg.moe_experts,
                                      top_k=cfg.moe_topk,
                                      capacity_factor=cfg.moe_capacity_factor,
                                      ctx=ctx)
                x = x + y2
                return x, (jnp.stack(nks), jnp.stack(nvs))

            x, (nk, nv) = jax.lax.scan(group_body, x,
                                       (params["blocks"], cache["k"],
                                        cache["v"]))
            cache = dict(cache)
            cache["k"], cache["v"] = nk, nv

        elif kind == "ssm":
            def body(x, sl):
                gp, st = sl
                h = rmsnorm(x, gp["ln"])
                y, st2 = SSM.ssm_decode(gp["ssm"], h, st, state=cfg.ssm_state,
                                        expand=cfg.ssm_expand,
                                        head_dim=cfg.ssm_head_dim, ctx=ctx)
                return x + y, st2
            x, st = jax.lax.scan(body, x, (params["blocks"], cache["ssm"]))
            cache = dict(cache)
            cache["ssm"] = st

        elif kind == "hybrid":
            shared = params["shared_attn"]

            def group_body(x, sl):
                gp, st, sk, sv = sl
                sts = []
                for li in range(self.group_size):
                    lp = jax.tree.map(lambda t: t[li], gp)
                    h = rmsnorm(x, lp["ln"])
                    y, st2 = SSM.ssm_decode(lp["ssm"], h, st[li],
                                            state=cfg.ssm_state,
                                            expand=cfg.ssm_expand,
                                            head_dim=cfg.ssm_head_dim,
                                            ctx=ctx)
                    x = x + y
                    sts.append(st2)
                # shared attention block: weights broadcast from the carry
                # closure, KV cache scanned per group
                h = rmsnorm(x, shared["ln"])
                y, sk, sv = A.attention_decode(shared["attn"], h, sk, sv,
                                               pos, **akw)
                x = x + y
                if "mlp" in shared:
                    h = rmsnorm(x, shared["ln2"])
                    x = x + mlp_apply(shared["mlp"], h, ctx)
                return x, (jnp.stack(sts), sk, sv)

            x, (st, sk, sv) = jax.lax.scan(
                group_body, x, (params["blocks"], cache["ssm"],
                                cache["shared_k"], cache["shared_v"]))
            cache = dict(cache)
            cache["ssm"], cache["shared_k"], cache["shared_v"] = st, sk, sv
            if self.tail_layers:
                def tail_body(x, sl):
                    lp, st0 = sl
                    h = rmsnorm(x, lp["ln"])
                    y, st2 = SSM.ssm_decode(lp["ssm"], h, st0,
                                            state=cfg.ssm_state,
                                            expand=cfg.ssm_expand,
                                            head_dim=cfg.ssm_head_dim,
                                            ctx=ctx)
                    return x + y, st2
                x, tst = jax.lax.scan(tail_body, x,
                                      (params["tail"], cache["tail_ssm"]))
                cache["tail_ssm"] = tst

        elif kind == "xlstm":
            states = tuple(cache[f"x{i}"]
                           for i in range(len(cfg.xlstm_pattern)))

            def body(x, sl):
                gp = sl[0]
                sts = sl[1:]
                new_sts = []
                for i, p in enumerate(cfg.xlstm_pattern):
                    h = rmsnorm(x, gp[f"ln{i}"])
                    if p == "m":
                        y, st2 = XL.mlstm_decode(gp[f"m{i}"], h, sts[i],
                                                 n_heads=cfg.n_heads, ctx=ctx)
                    else:
                        y, st2 = XL.slstm_decode(gp[f"s{i}"], h, sts[i],
                                                 n_heads=cfg.n_heads, ctx=ctx)
                    x = x + y
                    new_sts.append(st2)
                return x, tuple(new_sts)

            x, new_states = jax.lax.scan(body, x,
                                         (params["blocks"],) + states)
            cache = dict(cache)
            for i in range(len(cfg.xlstm_pattern)):
                cache[f"x{i}"] = new_states[i]
        else:
            raise ValueError(kind)

        x = rmsnorm(x, params["final_norm"])
        logits = (x @ params["unembed"].astype(self.dtype))[:, 0]
        logits = ctx.cs(logits, "batch", "model")
        cache["pos"] = pos + 1
        return logits, cache

    def _cross_decode(self, attn_params, x, enc_k, enc_v):
        from .attention import _gqa_av, _gqa_scores
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        B = x.shape[0]
        dt = x.dtype
        q = (x @ attn_params["wq"].astype(dt)).reshape(B, 1, cfg.n_heads, hd)
        s = _gqa_scores(q, enc_k) * hd ** -0.5
        w = jax.nn.softmax(s, axis=-1).astype(dt)
        out = _gqa_av(w, enc_v)
        return out.reshape(B, 1, cfg.n_heads * hd) @ \
            attn_params["wo"].astype(dt)
