"""xLSTM blocks (Beck et al., arXiv:2405.04517) — xlstm-125m.

- **mLSTM**: matrix-memory LSTM = gated linear attention with exponential
  input gate and sigmoid forget gate; trained in the chunkwise-parallel form
  via :mod:`repro.models.gla`. The normalizer state n_t is folded into the
  same recurrence by augmenting the value vector with a constant 1 channel
  (its output channel IS q·n_t), so one gla pass yields both numerator and
  denominator.

- **sLSTM**: scalar-memory LSTM with exponential gating and per-head
  recurrent mixing, implemented as a `lax.scan` over time (HLO size is
  S-independent). Decode is the single recurrence step.

Both use the paper's (m, s) alternating pattern; mLSTM blocks carry the
up-projection (pre-LN residual), sLSTM blocks are followed by a small GLU.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .gla import gla_chunked, gla_decode_step
from .layers import NO_SHARD, ShardCtx, dense_init, rmsnorm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, d: int, n_heads: int, dtype=jnp.float32) -> Dict:
    head_dim = d // n_heads
    kq, kk, kv, ki, kf, ko = jax.random.split(key, 6)
    return {
        "wq": dense_init(kq, d, d, dtype),
        "wk": dense_init(kk, d, d, dtype),
        "wv": dense_init(kv, d, d, dtype),
        "wi": dense_init(ki, d, n_heads, jnp.float32),
        "wf": dense_init(kf, d, n_heads, jnp.float32),
        "wo": dense_init(ko, d, d, dtype),
        "norm": jnp.ones((d,), dtype),
    }


def _mlstm_gates(params, x):
    """Stabilized log gates: log f = logsigmoid(f_pre), log i = i_pre - m
    with a per-sequence max subtraction folded into the scale."""
    f_pre = x.astype(jnp.float32) @ params["wf"]
    i_pre = x.astype(jnp.float32) @ params["wi"]
    log_f = jax.nn.log_sigmoid(f_pre)              # (B,S,H) ≤ 0
    i_gate = jnp.exp(jnp.minimum(i_pre, 6.0))      # clipped exp input gate
    return log_f, i_gate


def mlstm_state_shape(batch: int, d: int, n_heads: int) -> Tuple[int, ...]:
    hd = d // n_heads
    return (batch, n_heads, hd, hd + 1)


def mlstm_apply(params: Dict, x: jax.Array, *, n_heads: int,
                chunk: int = 128, ctx: ShardCtx = NO_SHARD) -> jax.Array:
    B, S, d = x.shape
    dt_ = x.dtype
    hd = d // n_heads
    q = (x @ params["wq"].astype(dt_)).reshape(B, S, n_heads, hd)
    k = (x @ params["wk"].astype(dt_)).reshape(B, S, n_heads, hd) * hd ** -0.5
    v = (x @ params["wv"].astype(dt_)).reshape(B, S, n_heads, hd)
    log_f, i_gate = _mlstm_gates(params, x)
    # augment values with a ones channel -> last output channel = q·n_t
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    pad = (-S) % chunk
    if pad:
        f = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        q, k, v_aug, log_f, i_gate = map(f, (q, k, v_aug, log_f, i_gate))
    y_aug, _ = gla_chunked(v_aug, log_f, i_gate, k, q, chunk=chunk)
    y_aug = y_aug[:, :S]
    denom = jnp.maximum(jnp.abs(y_aug[..., -1:]), 1.0)
    y = (y_aug[..., :-1] / denom).reshape(B, S, d)
    y = rmsnorm(y, params["norm"])
    out = y @ params["wo"].astype(dt_)
    return ctx.cs(out, "batch", None, None)


def mlstm_decode(params: Dict, x: jax.Array, h: jax.Array, *, n_heads: int,
                 ctx: ShardCtx = NO_SHARD):
    """x: (B,1,d); h: (B,H,hd,hd+1) (matrix memory + normalizer column)."""
    B, _, d = x.shape
    dt_ = x.dtype
    hd = d // n_heads
    q = (x @ params["wq"].astype(dt_)).reshape(B, n_heads, hd)
    k = (x @ params["wk"].astype(dt_)).reshape(B, n_heads, hd) * hd ** -0.5
    v = (x @ params["wv"].astype(dt_)).reshape(B, n_heads, hd)
    log_f, i_gate = _mlstm_gates(params, x)
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    y_aug, h_new = gla_decode_step(h, v_aug, log_f[:, 0], i_gate[:, 0], k, q)
    denom = jnp.maximum(jnp.abs(y_aug[..., -1:]), 1.0)
    y = (y_aug[..., :-1] / denom).reshape(B, 1, d)
    y = rmsnorm(y, params["norm"])
    out = y @ params["wo"].astype(dt_)
    return ctx.cs(out, "batch", None, None), h_new


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, d: int, n_heads: int, dtype=jnp.float32) -> Dict:
    kz, ki, kf, ko, kr, kp = jax.random.split(key, 6)
    hd = d // n_heads
    return {
        "wz": dense_init(kz, d, d, dtype),
        "wi": dense_init(ki, d, d, jnp.float32),
        "wf": dense_init(kf, d, d, jnp.float32),
        "wo_gate": dense_init(ko, d, d, jnp.float32),
        # block-diagonal recurrent mixing per head
        "r": (jax.random.normal(kr, (n_heads, hd, hd)) * hd ** -0.5
              ).astype(jnp.float32),
        "proj": dense_init(kp, d, d, dtype),
        "norm": jnp.ones((d,), dtype),
    }


def slstm_state_shape(batch: int, d: int) -> Tuple[int, ...]:
    return (batch, 2, d)  # (c, h)


def _slstm_step(params, n_heads, carry, xt):
    """carry: (c, h) each (B, d); xt: (B, d) pre-activations packed."""
    c, h = carry
    B, d = c.shape
    hd = d // n_heads
    hh = h.reshape(B, n_heads, hd)
    rec = jnp.einsum("bhx,hxy->bhy", hh, params["r"]).reshape(B, d)
    z = jnp.tanh(xt @ params["wz"].astype(xt.dtype) + rec.astype(xt.dtype))
    i = jnp.exp(jnp.minimum(xt.astype(jnp.float32) @ params["wi"], 6.0))
    f = jax.nn.sigmoid(xt.astype(jnp.float32) @ params["wf"])
    o = jax.nn.sigmoid(xt.astype(jnp.float32) @ params["wo_gate"])
    c_new = f * c + i * z.astype(jnp.float32)
    n = jnp.maximum(jnp.abs(c_new), 1.0)
    h_new = o * (c_new / n)
    return (c_new, h_new.astype(jnp.float32)), h_new.astype(xt.dtype)


def slstm_apply(params: Dict, x: jax.Array, *, n_heads: int,
                ctx: ShardCtx = NO_SHARD) -> jax.Array:
    B, S, d = x.shape
    c0 = jnp.zeros((B, d), jnp.float32)
    h0 = jnp.zeros((B, d), jnp.float32)
    xs = jnp.swapaxes(x, 0, 1)                    # (S, B, d)
    (_, _), ys = jax.lax.scan(
        lambda carry, xt: _slstm_step(params, n_heads, carry, xt), (c0, h0), xs)
    y = jnp.swapaxes(ys, 0, 1)
    y = rmsnorm(y, params["norm"])
    out = y @ params["proj"].astype(x.dtype)
    return ctx.cs(out, "batch", None, None)


def slstm_decode(params: Dict, x: jax.Array, state: jax.Array, *,
                 n_heads: int, ctx: ShardCtx = NO_SHARD):
    """x: (B,1,d); state: (B,2,d) = (c,h)."""
    c, h = state[:, 0].astype(jnp.float32), state[:, 1].astype(jnp.float32)
    (c_new, h_new), y = _slstm_step(params, n_heads, (c, h), x[:, 0])
    y = rmsnorm(y[:, None, :], params["norm"])
    out = y @ params["proj"].astype(x.dtype)
    new_state = jnp.stack([c_new, h_new], axis=1).astype(state.dtype)
    return ctx.cs(out, "batch", None, None), new_state
