"""Functional building blocks shared by all architectures.

Params are plain pytrees (nested dicts of jnp arrays); layers are pure
functions. Sharding is injected with `jax.lax.with_sharding_constraint`
through a :class:`ShardCtx` carrying logical→mesh-axis specs so the same
model code runs on the single-pod and multi-pod meshes (and unsharded on one
CPU device for smoke tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Sharding context
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Logical axis → mesh axis mapping.

    ``batch`` is a tuple of mesh axes the batch dim is sharded over ((
    'pod','data') on the multi-pod mesh), ``model`` the tensor-parallel
    axis, ``seq`` the sequence-sharding axis for long-context decode.
    ``active=False`` (smoke tests, no mesh) turns every constraint into a
    no-op."""

    batch: Tuple[str, ...] = ()
    model: Optional[str] = None
    seq: Optional[str] = None
    active: bool = False
    # data-parallel degree: lets layers form per-data-shard groups with
    # static shapes (e.g. dp-local MoE dispatch, §Perf iteration 3)
    dp: int = 1

    def cs(self, x: jax.Array, *axes) -> jax.Array:
        """Constrain array to a PartitionSpec built from logical axis names
        ('batch' | 'model' | 'seq' | None per dim)."""
        if not self.active:
            return x
        spec = []
        for a in axes:
            if a == "batch":
                spec.append(self.batch if self.batch else None)
            elif a == "model":
                spec.append(self.model)
            elif a == "seq":
                spec.append(self.seq)
            else:
                spec.append(None)
        return jax.lax.with_sharding_constraint(x, P(*spec))


NO_SHARD = ShardCtx()


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# ---------------------------------------------------------------------------
# Core ops
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * gamma.astype(jnp.float32)
            ).astype(dt)


def rope_angles(positions: jax.Array, head_dim: int,
                theta: float = 10000.0) -> Tuple[jax.Array, jax.Array]:
    """positions: (..., S) int → (cos, sin) of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, hd); cos/sin: (..., S, hd//2), broadcast over H."""
    half = x.shape[-1] // 2
    c = jnp.expand_dims(cos, -2).astype(x.dtype)   # (..., S, 1, half)
    s = jnp.expand_dims(sin, -2).astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def swiglu(x_gate: jax.Array, x_up: jax.Array) -> jax.Array:
    return jax.nn.silu(x_gate) * x_up


def softmax_fp32(scores: jax.Array, axis: int = -1) -> jax.Array:
    return jax.nn.softmax(scores.astype(jnp.float32), axis=axis)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, f: int, dtype=jnp.float32) -> Dict[str, jax.Array]:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wg": dense_init(k1, d, f, dtype),
        "wu": dense_init(k2, d, f, dtype),
        "wd": dense_init(k3, f, d, dtype),
    }


def mlp_apply(params: Dict[str, jax.Array], x: jax.Array,
              ctx: ShardCtx = NO_SHARD) -> jax.Array:
    dt = x.dtype
    h = swiglu(x @ params["wg"].astype(dt), x @ params["wu"].astype(dt))
    h = ctx.cs(h, "batch", None, "model")
    out = h @ params["wd"].astype(dt)
    return ctx.cs(out, "batch", None, None)
