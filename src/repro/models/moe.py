"""Mixture-of-Experts with SpDISTAL-style sparse dispatch.

The router output is a sparse (tokens × experts) matrix with top-k non-zeros
per row. Dispatch is exactly the paper's coordinate-fusion story
(DESIGN.md §4):

- flatten the (token, expert) assignment pairs — coordinate fusion
  ``(t, e) → f`` (paper Fig. 5c);
- sort by expert — grouping the fused non-zeros by the expert level, i.e.
  building the CSC-ordered coordinate tree;
- split into fixed-capacity expert buckets — the static-shape realization of
  a non-zero partition of the expert dimension (capacity = padded shard
  size; dropped tokens = the imbalance the paper's nnz partitioning
  removes, reported by the aux loss / drop counter).

Experts are sharded on the 'model' mesh axis (expert parallelism); GSPMD
lowers the bucket gather/scatter into all-to-alls across the expert axis.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import formats as F
from ..core.tensor import Tensor
from .layers import NO_SHARD, ShardCtx, dense_init


def dispatch_tensor(tope, topw, n_experts: int,
                    name: str = "dispatch") -> Tensor:
    """The router's top-k assignment as the paper's sparse matrix: a
    (tokens × experts) CSR Tensor whose row ``t`` holds token ``t``'s
    combine weights at its chosen expert columns — the same object the
    coordinate-fusion dispatch in :func:`moe_apply` flattens and sorts,
    now first-class so the format/partition machinery (and the serving
    fast path) can consume it."""
    tope = np.asarray(tope)
    topw = np.asarray(topw, np.float32)
    N, k = tope.shape
    coords = np.stack([np.repeat(np.arange(N, dtype=np.int64), k),
                       tope.reshape(-1).astype(np.int64)], axis=1)
    return Tensor.from_coo(name, (N, int(n_experts)), coords,
                           topw.reshape(-1), F.CSR(), dedupe=True)


def combine_kernel(disp: Tensor, machine, *, batch: int = 8,
                   schedule=None):
    """The MoE combine ``y(t) = dispatch(t, e) * c(e)`` lowered as a
    batched serving kernel: each request is one model-dimension column of
    the stacked per-expert outputs, and ``run_many`` folds a batch of
    columns into a single SpMM against the frozen dispatch matrix.
    Returns a :class:`repro.core.lower.BatchedKernel`."""
    from ..core.lower import lower_batched
    from ..core.tin import parse_tin
    N, E = disp.shape
    stmt = parse_tin("y(i) = dispatch(i,j) * c(j)",
                     y=Tensor.zeros_dense("y", (int(N),)),
                     dispatch=disp,
                     c=Tensor.zeros_dense("c", (int(E),)))
    return lower_batched(stmt, machine, batch=batch, schedule=schedule)


def moe_init(key, d: int, f: int, n_experts: int, dtype=jnp.float32) -> Dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    # experts stacked on a leading E axis → shard E on 'model'
    scale_in = (2.0 / (d + f)) ** 0.5
    return {
        "router": dense_init(kr, d, n_experts, jnp.float32),
        "wg": (jax.random.normal(kg, (n_experts, d, f)) * scale_in).astype(dtype),
        "wu": (jax.random.normal(ku, (n_experts, d, f)) * scale_in).astype(dtype),
        "wd": (jax.random.normal(kd, (n_experts, f, d)) * scale_in).astype(dtype),
    }


def moe_apply(params: Dict, x: jax.Array, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25,
              ctx: ShardCtx = NO_SHARD) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (y, aux_loss).

    §Perf iteration 3 — **dp-local dispatch**: routing, sorting, rank
    computation and capacity assignment run independently per data-parallel
    group (``ctx.dp`` groups of N/dp tokens). The global-dispatch version
    sorted all tokens jointly, which forced GSPMD to all-gather activations
    and dispatch metadata on every layer (1.12 TB/device on olmoe
    prefill_32k). Group-local dispatch keeps everything data-sharded; the
    only cross-device movement left is the (group → expert) bucket exchange,
    which GSPMD lowers to the expected all-to-all over the expert axis.

    Static shapes throughout: per-group capacity C = ceil(N_loc·k/E · cf).
    Token order is restored by scatter-add with the combine weights.
    """
    B, S, d = x.shape
    N = B * S
    dt = x.dtype
    dp = max(ctx.dp, 1)
    if N % dp:
        dp = 1
    Nl = N // dp                                            # tokens per group
    xt = x.reshape(dp, Nl, d)
    xt = ctx.cs(xt, "batch", None, None)
    C = int(max(-(-Nl * top_k // n_experts) * capacity_factor, 1))

    def dispatch_one(xg):
        """Group-local routing + SpDISTAL coordinate-fusion dispatch."""
        logits = xg.astype(jnp.float32) @ params["router"]
        gates = jax.nn.softmax(logits, axis=-1)             # (Nl, E)
        topw, tope = jax.lax.top_k(gates, top_k)            # (Nl, k)
        topw = topw / (topw.sum(-1, keepdims=True) + 1e-9)
        # Switch-style load-balance aux loss
        me = gates.mean(0)
        cexp = jax.nn.one_hot(tope[:, 0], n_experts).mean(0)
        aux = n_experts * jnp.sum(me * cexp)

        # coordinate fusion (token, expert) -> f; sort by expert = group the
        # non-zeros by the expert level (paper Fig. 5c)
        e_flat = tope.reshape(-1)
        t_flat = jnp.repeat(jnp.arange(Nl, dtype=jnp.int32), top_k)
        w_flat = topw.reshape(-1).astype(dt)
        order = jnp.argsort(e_flat)
        e_s, t_s, w_s = e_flat[order], t_flat[order], w_flat[order]
        # rank within expert = position inside the non-zero partition
        pos_all = jnp.cumsum(jnp.ones_like(e_s, jnp.int32)) - 1
        seg_start = jnp.searchsorted(e_s, jnp.arange(n_experts), side="left")
        pos_in_e = pos_all - jnp.take(seg_start, e_s)
        keep = pos_in_e < C
        slot = jnp.where(keep, e_s * C + pos_in_e, n_experts * C)
        picked = jnp.take(xg, t_s, axis=0)
        buckets = jnp.zeros((n_experts * C, d), dt)
        buckets = buckets.at[slot].set(picked, mode="drop")
        return (buckets.reshape(n_experts, C, d), slot, t_s,
                (w_s * keep.astype(dt)), aux)

    buckets, slot, t_s, w_eff, aux = jax.vmap(dispatch_one)(xt)
    # (dp, E, C, d): groups stay on 'data', experts shard on 'model' — the
    # resharding below IS the dispatch all-to-all
    buckets = ctx.cs(buckets, "batch", "model", None, None)

    # --- expert FFNs (grouped einsum; E sharded on 'model') ---------------
    h = jnp.einsum("gecd,edf->gecf", buckets, params["wg"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", buckets, params["wu"].astype(dt))
    h = jax.nn.silu(h) * u
    h = ctx.cs(h, "batch", "model", None, None)
    y_e = jnp.einsum("gecf,efd->gecd", h, params["wd"].astype(dt))
    y_e = y_e.reshape(dp, n_experts * C, d)
    y_e = ctx.cs(y_e, "batch", None, None)      # combine all-to-all back

    # --- combine (scatter back with weights, per group) --------------------
    def combine_one(y_g, slot_g, t_g, w_g):
        contrib = jnp.take(y_g, jnp.minimum(slot_g, n_experts * C - 1),
                           axis=0)
        contrib = contrib * w_g[:, None]
        return jnp.zeros((Nl, d), dt).at[t_g].add(contrib)

    y = jax.vmap(combine_one)(y_e, slot, t_s, w_eff)
    y = ctx.cs(y.reshape(B, S, d), "batch", None, None)
    return y, aux.mean().astype(jnp.float32)
