"""Attention for all assigned architectures.

Variants (selected per shape, DESIGN.md §5):

- ``dense``    — masked einsum attention; best HLO for S ≤ 8K training.
- ``chunked``  — flash-style: lax.scan over KV blocks with running
                 max/denominator; O(S·Bk) memory for 32K prefill. The causal
                 mask skips nothing (XLA has no dynamic trip counts) — the
                 ~2× masked-FLOP overhead is visible in the roofline and
                 addressed in §Perf.
- ``windowed`` — block-sparse sliding window built on the paper's format
                 machinery: a Dense row-block level × banded Compressed
                 col-block level (models/sparse_attention.py provides the
                 mask plan). Used for long_500k on full-attention archs.
- decode       — single-token query against a (possibly sequence-sharded)
                 KV cache; GSPMD turns the softmax reductions into
                 collectives when the cache's S dim is sharded.

GQA throughout: kv heads ≤ q heads, repeated by ``G = H // Hkv``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import (NO_SHARD, ShardCtx, apply_rope, dense_init, rmsnorm,
                     rope_angles, softmax_fp32)


def attn_init(key, d: int, n_heads: int, n_kv: int, head_dim: int,
              qk_norm: bool = False, dtype=jnp.float32) -> Dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, d, n_heads * head_dim, dtype),
        "wk": dense_init(kk, d, n_kv * head_dim, dtype),
        "wv": dense_init(kv, d, n_kv * head_dim, dtype),
        "wo": dense_init(ko, n_heads * head_dim, d, dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), dtype)
        p["k_norm"] = jnp.ones((head_dim,), dtype)
    return p


def _project_qkv(params, x, n_heads, n_kv, head_dim, ctx: ShardCtx):
    B, S, _ = x.shape
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(B, S, n_heads, head_dim)
    k = (x @ params["wk"].astype(dt)).reshape(B, S, n_kv, head_dim)
    v = (x @ params["wv"].astype(dt)).reshape(B, S, n_kv, head_dim)
    q = ctx.cs(q, "batch", None, "model", None)
    k = ctx.cs(k, "batch", None, None, None)
    v = ctx.cs(v, "batch", None, None, None)
    if "q_norm" in params:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])
    return q, k, v


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    if groups == 1:
        return k
    B, S, Hkv, hd = k.shape
    return jnp.repeat(k, groups, axis=2)


# --- grouped-GQA einsums (§Perf iteration 1) -------------------------------
# Materializing repeated K/V ((B,S,H,hd) from (B,S,Hkv,hd)) forced GSPMD to
# all-gather the sequence-sharded KV cache on every decode layer (154 GB/dev
# on qwen3 decode_32k). Grouping the query heads instead keeps K/V in their
# native (possibly sequence-sharded) layout; the contraction touches each KV
# shard locally and only the softmax statistics cross shards.

def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,Q,H,hd), k: (B,S,Hkv,hd) -> scores (B,Hkv,G,Q,S) in f32.

    f32 via preferred_element_type (bf16 operands, f32 accumulation) — a
    post-hoc ``convert(dot(...))`` gets algebraically rewritten by XLA into
    converting the OPERANDS, i.e. the entire KV cache to f32 (§Perf iter 2:
    8 GB/step of spurious converts on qwen3 decode_32k)."""
    B, Q, H, hd = q.shape
    Hkv = k.shape[2]
    qg = q.reshape(B, Q, Hkv, H // Hkv, hd)
    return jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                      preferred_element_type=jnp.float32)


def _gqa_av(w: jax.Array, v: jax.Array) -> jax.Array:
    """w: (B,Hkv,G,Q,S), v: (B,S,Hkv,hd) -> out (B,Q,H,hd)."""
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    B, Q, Hkv, G, hd = out.shape
    return out.reshape(B, Q, Hkv * G, hd)


# ---------------------------------------------------------------------------
# Training / prefill attention
# ---------------------------------------------------------------------------

def _dense_attention(q, k, v, causal: bool, ctx: ShardCtx):
    B, S, H, hd = q.shape
    scale = hd ** -0.5
    scores = _gqa_scores(q, k) * scale            # (B,K,G,S,Skv)
    if causal:
        mask = jnp.tril(jnp.ones((S, k.shape[1]), bool),
                        k.shape[1] - S)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = softmax_fp32(scores).astype(q.dtype)
    out = _gqa_av(w, v)
    return ctx.cs(out, "batch", None, "model", None)


def _chunked_attention(q, k, v, causal: bool, ctx: ShardCtx,
                       kv_block: int = 1024):
    """Flash-style streaming softmax over KV blocks (memory-bounded).
    Grouped-GQA form: K/V blocks stay (B, kb, Hkv, hd)."""
    B, S, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    nb = -(-Sk // kv_block)
    pad = nb * kv_block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nb, kv_block, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, kv_block, Hkv, hd).transpose(1, 0, 2, 3, 4)
    scale = hd ** -0.5
    q_pos = jnp.arange(S)

    def step(carry, blk):
        m, l, acc = carry                       # (B,K,G,S) / (...,hd)
        kblk, vblk, bidx = blk
        kv_pos = bidx * kv_block + jnp.arange(kv_block)
        s = _gqa_scores(q, kblk) * scale  # (B,K,G,S,kb)
        mask = kv_pos[None, :] <= (q_pos[:, None] + (Sk - S))
        mask &= (kv_pos < Sk)[None, :]
        if not causal:
            mask = jnp.broadcast_to((kv_pos < Sk)[None, :], mask.shape)
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + p.sum(-1)
        upd = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(q.dtype), vblk)
        acc_new = acc * corr[..., None] + upd.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, S), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, S, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kb, vb, jnp.arange(nb)))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)
    return ctx.cs(out, "batch", None, "model", None)


def _windowed_attention(q, k, v, window: int, ctx: ShardCtx,
                        q_block: int = 1024):
    """Block-banded causal attention: each query block attends to the
    trailing ``window`` keys. The (q-block × kv-block) iteration space is
    the compressed banded level of sparse_attention.band_plan — only blocks
    inside the band are materialized, so compute scales with S·W not S²."""
    B, S, H, hd = q.shape
    assert k.shape[1] == S, "windowed path expects self-attention"
    nqb = -(-S // q_block)
    pad = nqb * q_block - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    span = window + q_block  # KV needed per q block
    scale = hd ** -0.5
    Sp = nqb * q_block

    def qblock(bidx):
        qs = bidx * q_block
        qb = jax.lax.dynamic_slice_in_dim(q, qs, q_block, 1)
        ks = jnp.clip(qs + q_block - span, 0, Sp - span)
        kb = jax.lax.dynamic_slice_in_dim(k, ks, span, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, ks, span, 1)
        s = _gqa_scores(qb, kb) * scale
        q_pos = qs + jnp.arange(q_block)
        kv_pos = ks + jnp.arange(span)
        mask = (kv_pos[None, :] <= q_pos[:, None]) & \
               (kv_pos[None, :] > q_pos[:, None] - window) & \
               (kv_pos[None, :] < S) & (q_pos[:, None] < S)
        s = jnp.where(mask[None, None, None], s, -1e30)
        w = softmax_fp32(s).astype(qb.dtype)
        return _gqa_av(w, vb)

    blocks = jax.lax.map(qblock, jnp.arange(nqb))  # (nqb, B, qb, H, hd)
    out = blocks.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, hd)[:, :S]
    return ctx.cs(out, "batch", None, "model", None)


def attention_apply(params: Dict, x: jax.Array, *, n_heads: int, n_kv: int,
                    head_dim: int, rope_theta: float = 10000.0,
                    causal: bool = True, window: int = 0,
                    variant: str = "auto", ctx: ShardCtx = NO_SHARD,
                    positions: Optional[jax.Array] = None,
                    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
                    use_rope: bool = True) -> jax.Array:
    """Full-sequence attention (training / prefill).

    ``kv_override`` supplies external K/V inputs for cross-attention (the
    enc-dec path); rope/causal are disabled there by the caller.
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim, ctx)
    if use_rope:
        pos = positions if positions is not None else jnp.arange(S)
        cos, sin = rope_angles(pos, head_dim, rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    if kv_override is not None:
        k, v = kv_override

    if variant == "auto":
        if window:
            variant = "windowed"
        elif S > 8192:
            variant = "chunked"
        else:
            variant = "dense"
    if variant == "windowed":
        out = _windowed_attention(q, k, v, window, ctx)
    elif variant == "chunked":
        out = _chunked_attention(q, k, v, causal, ctx)
    elif variant == "flash":
        # Pallas TPU kernel (kernels/flash_attention.py); interpret mode off
        # TPU. Opt-in (train_attn_variant="flash"): pallas custom-calls are
        # not part of the CPU dry-run's compiled path.
        from ..kernels.flash_attention import flash_attention
        assert causal, "flash variant is causal self-attention"
        out = flash_attention(q, k, v,
                              interpret=jax.default_backend() != "tpu")
        out = ctx.cs(out, "batch", None, "model", None)
    else:
        out = _dense_attention(q, k, v, causal, ctx)
    dt = x.dtype
    y = out.reshape(B, S, n_heads * head_dim) @ params["wo"].astype(dt)
    return ctx.cs(y, "batch", None, None)


# ---------------------------------------------------------------------------
# Decode (single token, KV cache)
# ---------------------------------------------------------------------------

def attention_decode(params: Dict, x: jax.Array, cache_k: jax.Array,
                     cache_v: jax.Array, pos: jax.Array, *, n_heads: int,
                     n_kv: int, head_dim: int, rope_theta: float = 10000.0,
                     window: int = 0, ctx: ShardCtx = NO_SHARD):
    """One decode step. x: (B, 1, d); cache_[kv]: (B, Sc, Hkv, hd) where Sc
    is the full context (decode_32k) or the ring-buffer window (long_500k
    windowed). Returns (y, new_cache_k, new_cache_v).

    The new KV is written at ``pos % Sc`` (identity when Sc == full context,
    ring-buffer semantics when Sc == window). The cache S dim may be sharded
    ('seq' logical axis) — GSPMD inserts the softmax reductions.
    """
    B, _, d = x.shape
    Sc = cache_k.shape[1]
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim, ctx)
    cos, sin = rope_angles(pos[:, None], head_dim, rope_theta)  # (B,1,half)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    slot = (pos % Sc).astype(jnp.int32)
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, slot].set(k[:, 0])
    cache_v = cache_v.at[bidx, slot].set(v[:, 0])
    cache_k = ctx.cs(cache_k, "batch", "seq", None, None)
    cache_v = ctx.cs(cache_v, "batch", "seq", None, None)
    scale = head_dim ** -0.5
    # grouped GQA: contract against the cache in its native layout — no
    # repeated-KV materialization (see _gqa_scores note)
    s = _gqa_scores(q, cache_k) * scale  # (B,K,G,1,S)
    kv_pos = jnp.arange(Sc)
    # slots are ring-buffer indices, not positions: a slot is valid once
    # written, i.e. slot < pos+1 before wrap-around, all slots after. RoPE
    # was applied at absolute positions so scores stay correct regardless
    # of slot order. (window == 0 means Sc is the full context, where slot
    # index == position and the same formula is the causal mask.)
    valid = kv_pos[None, :] < jnp.minimum(pos[:, None] + 1, Sc)
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    w = softmax_fp32(s).astype(q.dtype)
    out = _gqa_av(w, cache_v)
    y = out.reshape(B, 1, n_heads * head_dim) @ params["wo"].astype(x.dtype)
    return ctx.cs(y, "batch", None, None), cache_k, cache_v
