"""Mamba2-style state-space blocks (SSD) — zamba2's backbone.

Training uses the chunkwise-parallel SSD form via the shared
:mod:`repro.models.gla` core (g = Δ·A, s = Δ, K/Q = B/C projections shared
across heads). Decode carries the (H, P, N) state — O(1) per token, which is
what makes ``long_500k`` native for SSM/hybrid archs.

Simplifications vs. the full Mamba2 (noted for fidelity): no conv1d branch,
single B/C group, no bias terms. These do not change the distribution or
roofline structure of the block.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .gla import gla_chunked, gla_decode_step
from .layers import NO_SHARD, ShardCtx, dense_init, rmsnorm


def ssm_dims(d_model: int, expand: int, head_dim: int) -> Tuple[int, int]:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    return d_inner, n_heads


def ssm_init(key, d_model: int, *, state: int, expand: int = 2,
             head_dim: int = 64, groups: int = 1, dtype=jnp.float32) -> Dict:
    d_inner, n_heads = ssm_dims(d_model, expand, head_dim)
    kin, kz, kb, kc, kdt, ko = jax.random.split(key, 6)
    return {
        "wx": dense_init(kin, d_model, d_inner, dtype),
        "wz": dense_init(kz, d_model, d_inner, dtype),
        "wB": dense_init(kb, d_model, groups * state, dtype),
        "wC": dense_init(kc, d_model, groups * state, dtype),
        "wdt": dense_init(kdt, d_model, n_heads, dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),          # A = -exp(A_log)
        "D": jnp.ones((n_heads,), jnp.float32),
        "wo": dense_init(ko, d_inner, d_model, dtype),
        "norm": jnp.ones((d_inner,), dtype),
    }


def ssm_state_shape(cfg_batch: int, d_model: int, *, state: int,
                    expand: int = 2, head_dim: int = 64) -> Tuple[int, ...]:
    _, H = ssm_dims(d_model, expand, head_dim)
    return (cfg_batch, H, state, head_dim)


def _projections(params, x):
    dt_ = x.dtype
    B, S, d = x.shape
    d_inner = params["wx"].shape[1]
    H = params["wdt"].shape[1]
    head_dim = d_inner // H
    xh = (x @ params["wx"].astype(dt_)).reshape(B, S, H, head_dim)
    z = x @ params["wz"].astype(dt_)
    Bm = x @ params["wB"].astype(dt_)
    Cm = x @ params["wC"].astype(dt_)
    dt = jax.nn.softplus(x.astype(jnp.float32) @
                         params["wdt"].astype(jnp.float32))     # (B,S,H)
    return xh, z, Bm, Cm, dt, H, head_dim, d_inner


def ssm_apply(params: Dict, x: jax.Array, *, state: int, expand: int = 2,
              head_dim: int = 64, chunk: int = 128,
              ctx: ShardCtx = NO_SHARD) -> jax.Array:
    """Training / prefill forward. x: (B, S, d)."""
    B, S, d = x.shape
    dt_ = x.dtype
    xh, z, Bm, Cm, dt, H, hd, d_inner = _projections(params, x)
    xh = ctx.cs(xh, "batch", None, "model", None)
    A = -jnp.exp(params["A_log"])
    log_decay = dt * A[None, None, :]
    pad = (-S) % chunk
    if pad:
        f = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        xh, dt, Bm, Cm, log_decay = map(f, (xh, dt, Bm, Cm, log_decay))
    y, _ = gla_chunked(xh, log_decay, dt, Bm, Cm, chunk=chunk)
    y = y[:, :S]
    y = y + params["D"].astype(dt_)[None, None, :, None] * xh[:, :S]
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(y, params["norm"]) * jax.nn.silu(z)
    out = y @ params["wo"].astype(dt_)
    return ctx.cs(out, "batch", None, None)


def ssm_decode(params: Dict, x: jax.Array, h: jax.Array, *, state: int,
               expand: int = 2, head_dim: int = 64,
               ctx: ShardCtx = NO_SHARD):
    """One decode step. x: (B, 1, d); h: (B, H, N, P) carried state."""
    B, _, d = x.shape
    dt_ = x.dtype
    xh, z, Bm, Cm, dt, H, hd, d_inner = _projections(params, x)
    A = -jnp.exp(params["A_log"])
    log_decay = (dt * A[None, None, :])[:, 0]                 # (B,H)
    y, h_new = gla_decode_step(h, xh[:, 0], log_decay, dt[:, 0],
                               Bm[:, 0], Cm[:, 0])
    y = y + params["D"].astype(dt_)[None, :, None] * xh[:, 0]
    y = y.reshape(B, d_inner)
    y = rmsnorm(y, params["norm"]) * jax.nn.silu(z[:, 0])
    out = (y @ params["wo"].astype(dt_)).reshape(B, 1, d)
    return ctx.cs(out, "batch", None, None), h_new
