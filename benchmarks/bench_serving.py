"""Sparse serving fast path: batching throughput, latency vs SLO, and
comm/compute overlap (ISSUE 10).

The serving claim: B concurrent requests against one frozen sparse
operand should cost ONE bucketized SpMM (one plan, one shard pack, one
jitted runner), not B SpMVs — and the dense-operand shard transfers of
the underlying kernels should hide behind leaf compute. Suite rows:

  ``serve_per_request_loop_b{B}`` — B requests served one at a time
                                    through the same batched machinery
                                    (bucket 1) — the baseline a naive
                                    serving loop pays
  ``serve_run_many_b{B}``         — the same B requests as one
                                    ``run_many`` call (bit-for-bit equal
                                    outputs asserted)
  ``serve_batch_speedup_x``       — loop/batch throughput ratio (not a
                                    time; asserted >= 3 — the acceptance
                                    floor)
  ``serve_latency_p50``           — SparseKernelServer p50 under a
                                    6-wave steady-state queue (us)
  ``serve_latency_p99``           — … p99 (us); derived column reports
                                    SLO attainment
  ``serve_overlap_sequential``    — chunked SpMM, issue→wait→compute
                                    (no pipelining)
  ``serve_overlap_pipelined``     — double-buffered: chunk t's transfer
                                    rides under chunk t-1's compute
                                    (bit-for-bit vs ``kernel.run()``
                                    asserted)
  ``serve_overlap_efficiency_pct``— span-derived hidden/total transfer
                                    time ×100 (asserted > 0)
"""
from __future__ import annotations

import numpy as np

import repro.core as rc
from repro.core import formats as F
from repro.core.cache import batch_bucket
from repro.core.lower import RUNNER_CACHE_STATS, lower, lower_batched
from repro.core.tensor import Tensor
from repro.distributed.executor import run_overlapped
from repro.launch.serve import SparseKernelServer
from repro.runtime import telemetry

from .common import csv_row, time_fn


def _int_sparse(rng, n: int, m: int, density: float) -> np.ndarray:
    # integer-valued so every reduction order agrees bit for bit
    return (rng.integers(-3, 4, (n, m)) *
            (rng.random((n, m)) < density)).astype(np.float32)


def run(n: int = 4096, m: int = 4096, b: int = 8, j: int = 32,
        density: float = 0.01, slo_ms: float = 250.0) -> list:
    rows = []
    rng = np.random.default_rng(0)
    dB = _int_sparse(rng, n, m, density)
    machine = rc.Machine(("x", 4))

    def mkstmt():
        return rc.parse_tin("a(i) = B(i,j) * c(j)",
                            a=Tensor.zeros_dense("a", (n,)),
                            B=Tensor.from_dense("B", dB.copy(), F.CSR()),
                            c=Tensor.zeros_dense("c", (m,)))

    reqs = [rng.integers(-3, 4, m).astype(np.float32) for _ in range(b)]

    # --- batching throughput: run_many vs per-request loop ----------------
    bk = lower_batched(mkstmt(), machine, batch=b)
    bk.warm(1)                       # compile both buckets up front
    batch_out = bk.run_many(reqs)
    loop_out = [bk.run_many([r])[0] for r in reqs]
    for yb, yl, r in zip(batch_out, loop_out, reqs):
        ref = dB @ r
        assert np.array_equal(np.asarray(yb).ravel(), ref)
        assert np.array_equal(np.asarray(yl).ravel(), ref)

    t_loop = time_fn(lambda: [bk.run_many([r]) for r in reqs],
                     warmup=1, iters=5)
    t_batch = time_fn(lambda: bk.run_many(reqs), warmup=1, iters=5)
    rows.append(csv_row(f"serve_per_request_loop_b{b}", t_loop * 1e6))
    rows.append(csv_row(f"serve_run_many_b{b}", t_batch * 1e6,
                        f"bucket={batch_bucket(b)}"))
    speedup = t_loop / t_batch
    telemetry.METRICS.gauge("serve.batch_speedup", speedup)
    rows.append(csv_row("serve_batch_speedup_x", speedup))
    assert speedup >= 3.0, f"batching speedup {speedup:.2f}x < 3x floor"

    # steady-state serving must not recompile: mixed batch sizes inside
    # the warmed buckets leave the runner cache untouched (odd sizes pad
    # up to the nearest bucket instead of compiling a fresh width)
    bk.warm(b // 2 or 1)
    before = dict(RUNNER_CACHE_STATS)
    for size in (b, 1, b // 2 or 1, max(b - 3, 1), b):
        bk.run_many(reqs[:size])
    assert RUNNER_CACHE_STATS["misses"] == before["misses"], \
        "warm run_many recompiled a runner"

    # --- latency vs SLO through the server loop ---------------------------
    srv = SparseKernelServer(mkstmt(), machine, max_batch=b, slo_ms=slo_ms)
    srv.kernel.warm(1)
    for r in reqs:                   # warm every shape out of the stats
        srv.submit(r)
    srv.drain()
    srv.latencies_ms.clear()
    for _ in range(6):               # 6 waves of B requests, drained batchwise
        for r in reqs:
            srv.submit(rng.permutation(r))
        srv.drain()
    st = srv.stats()
    telemetry.METRICS.gauge("serve.latency_p50_ms", st["p50_ms"])
    telemetry.METRICS.gauge("serve.latency_p99_ms", st["p99_ms"])
    telemetry.METRICS.gauge("serve.slo_attainment", st["slo_attainment"])
    rows.append(csv_row("serve_latency_p50", st["p50_ms"] * 1e3,
                        f"slo_ms={slo_ms:g}"))
    rows.append(csv_row("serve_latency_p99", st["p99_ms"] * 1e3,
                        f"attainment={st['slo_attainment']:.0%}"))

    # --- comm/compute overlap on the underlying SpMM ----------------------
    dC = rng.integers(-3, 4, (m, j)).astype(np.float32)
    stmt = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                        A=Tensor.zeros_dense("A", (n, j)),
                        B=Tensor.from_dense("B", dB.copy(), F.CSR()),
                        C=Tensor.from_dense("C", dC))
    k = lower(stmt, machine)
    ref = np.asarray(k.run())
    assert np.array_equal(ref, run_overlapped(k, chunks=2, overlap=False))
    assert np.array_equal(ref, run_overlapped(k, chunks=2, overlap=True))

    t_seq = time_fn(lambda: run_overlapped(k, chunks=2, overlap=False),
                    warmup=1, iters=5)
    rows.append(csv_row("serve_overlap_sequential", t_seq * 1e6))
    was_enabled = telemetry.TRACER.enabled
    telemetry.TRACER.enable()
    try:
        t_ovl = time_fn(lambda: run_overlapped(k, chunks=2, overlap=True),
                        warmup=1, iters=5)
        rep = telemetry.overlap_report()
    finally:
        telemetry.TRACER.enabled = was_enabled
    rows.append(csv_row("serve_overlap_pipelined", t_ovl * 1e6,
                        f"chunks=2 hidden_s={rep['hidden_s']:.4f}"))
    assert rep["efficiency"] > 0.0, "no transfer time hidden"
    rows.append(csv_row("serve_overlap_efficiency_pct",
                        rep["efficiency"] * 100.0))
    return rows


if __name__ == "__main__":
    run()
