"""Communication-avoiding replication at FIXED piece count (ISSUE 7).

SpMM across 1-D (Px1), the best 2-D factorization, and the replicated
2.5-D grid (P×Q×R with the sparse operand replicated along z): equal
pieces, three communication structures. The 2.5-D plan pays |B|·(R−1)
broadcast bytes along z to shrink the output all-reduce from
|A|·(QR−1) to |A|·(Q−1) — a strict win whenever |A|·Q > |B|, which the
wide-output shape below sits squarely inside. SpMTTKRP compares the 1-D
row split against the P×Q×R COO-brick grid. Rows report wall time (us)
with comm volume + per-axis attribution in the derived column; the
*_comm_bytes rows carry the byte totals in the numeric column so
``BENCH_replication.json`` pins the trajectory.
"""
from __future__ import annotations

import numpy as np

import repro.core as rc
from repro.core import formats as F
from repro.core.lower import (clear_lowering_caches, default_grid3_schedule,
                              default_grid_schedule,
                              default_replicated_schedule,
                              default_row_schedule, lower)
from repro.core.tensor import Tensor
from .common import csv_row, time_fn


def _spmm_stmt(rng, n, m, j, density=0.02):
    dB = ((rng.random((n, m)) < density) *
          rng.standard_normal((n, m))).astype(np.float32)
    B = Tensor.from_dense("B", dB, F.CSR())
    C = Tensor.from_dense("C", rng.standard_normal((m, j)).astype(np.float32))
    return rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                        A=Tensor.zeros_dense("A", (n, j)), B=B, C=C)


def _spmttkrp_stmt(rng, dims, L, density=0.02):
    dB = ((rng.random(dims) < density) *
          rng.standard_normal(dims)).astype(np.float32)
    B = Tensor.from_dense("B", dB, F.COO(3))
    C = Tensor.from_dense(
        "C", rng.standard_normal((dims[1], L)).astype(np.float32))
    D = Tensor.from_dense(
        "D", rng.standard_normal((dims[2], L)).astype(np.float32))
    return rc.parse_tin("A(i,l) = B(i,j,k) * C(j,l) * D(k,l)",
                        A=Tensor.zeros_dense("A", (dims[0], L)), B=B, C=C,
                        D=D)


def _net(k):
    return k.comm.total_network_bytes()


def _axes(k):
    return ";".join(f"{a}_bytes={v.network_bytes()}"
                    for a, v in sorted(k.comm.axes.items()))


def run(n=4096, m=4096, j=128, pieces=8, dims3=(256, 128, 96), L=16):
    rng = np.random.default_rng(0)
    rows = []

    # ---- SpMM: 1-D vs best 2-D vs replicated 2.5-D --------------------
    stmt = _spmm_stmt(rng, n, m, j)
    clear_lowering_caches()
    m1 = rc.Machine(("x", pieces))
    k1 = lower(stmt, m1, schedule=default_row_schedule(stmt, m1))

    best2 = None
    for P in range(2, pieces):
        if pieces % P or pieces // P < 2:
            continue
        m2 = rc.Machine(("x", P), ("y", pieces // P))
        k2 = lower(stmt, m2, schedule=default_grid_schedule(stmt, m2))
        if best2 is None or _net(k2) < _net(best2):
            best2 = k2
    P2, Q2 = best2.strategy.grid_shape

    m3 = rc.Machine(("x", 2), ("y", pieces // 4), ("z", 2))
    k3 = lower(stmt, m3, schedule=default_replicated_schedule(stmt, m3))

    b1, b2, b3 = _net(k1), _net(best2), _net(k3)
    assert b3 < b2 < b1, (
        f"2.5-D SpMM must move strictly fewer bytes than the best 2-D "
        f"plan at equal pieces, which beats 1-D: {b3} < {b2} < {b1}")

    t1, t2, t3 = time_fn(k1.run), time_fn(best2.run), time_fn(k3.run)
    rep_mesh = "x".join(str(d.size) for d in m3.dims) + "r"
    rows += [
        csv_row(f"spmm_1d_{pieces}x1", t1 * 1e6, f"net_bytes={b1}"),
        csv_row(f"spmm_2d_{P2}x{Q2}", t2 * 1e6,
                f"net_bytes={b2};{_axes(best2)}"),
        csv_row(f"spmm_25d_{rep_mesh}", t3 * 1e6,
                f"net_bytes={b3};{_axes(k3)}"),
        csv_row(f"spmm_1d_{pieces}x1_comm_bytes", float(b1), ""),
        csv_row(f"spmm_2d_{P2}x{Q2}_comm_bytes", float(b2),
                f"saving_vs_1d={1.0 - b2 / b1:.3f}"),
        csv_row(f"spmm_25d_{rep_mesh}_comm_bytes", float(b3),
                f"saving_vs_2d={1.0 - b3 / b2:.3f}"),
    ]

    # ---- SpMTTKRP: 1-D rows vs P×Q×R bricks ----------------------------
    stmt3 = _spmttkrp_stmt(rng, dims3, L)
    clear_lowering_caches()
    k1 = lower(stmt3, m1, schedule=default_row_schedule(stmt3, m1))
    mb = rc.Machine(("x", 2), ("y", pieces // 4), ("z", 2))
    kb = lower(stmt3, mb, schedule=default_grid3_schedule(stmt3, mb))
    b1, bb = _net(k1), _net(kb)
    t1, tb = time_fn(k1.run), time_fn(kb.run)
    brick_mesh = "x".join(str(d.size) for d in mb.dims)
    rows += [
        csv_row(f"spmttkrp_1d_{pieces}x1", t1 * 1e6, f"net_bytes={b1}"),
        csv_row(f"spmttkrp_3d_{brick_mesh}", tb * 1e6,
                f"net_bytes={bb};{_axes(kb)}"),
        csv_row(f"spmttkrp_1d_{pieces}x1_comm_bytes", float(b1), ""),
        csv_row(f"spmttkrp_3d_{brick_mesh}_comm_bytes", float(bb),
                f"saving_vs_1d={1.0 - bb / b1:.3f}"),
    ]
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
