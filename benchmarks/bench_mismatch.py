"""Paper §II-D final paragraph (C4): independently chosen data and
computation distributions are legal; mismatches cost redistribution.

Measures the lowered kernels' communication model for the four
(data distribution × computation distribution) combinations of SpMV.
"""
from __future__ import annotations

import numpy as np

import repro.core as rc
from repro.core.lower import default_nnz_schedule, default_row_schedule, lower
from repro.core.tdn import dist
from repro.core.tensor import Tensor
from repro.data.spdata import powerlaw_matrix

from .common import csv_row

M = rc.Machine(("x", 16))


def run(n: int = 20000) -> list:
    rows = []
    B = powerlaw_matrix("B", n, n, 16, seed=0)
    c = Tensor.from_dense("c", np.random.default_rng(1)
                          .standard_normal(n).astype(np.float32))
    a = Tensor.zeros_dense("a", (n,))
    stmt = rc.parse_tin("a(i) = B(i,j) * c(j)", a=a, B=B, c=c)

    combos = {
        "rowdata_rowcomp": (dist(B, "xy -> x", M),
                            default_row_schedule(stmt, M)),
        "nnzdata_nnzcomp": (dist(B, "xy ~f> f", M),
                            default_nnz_schedule(stmt, M)),
        "nnzdata_rowcomp": (dist(B, "xy ~f> f", M),
                            default_row_schedule(stmt, M)),
        "rowdata_nnzcomp": (dist(B, "xy -> x", M),
                            default_nnz_schedule(stmt, M)),
    }
    for name, (d, sched) in combos.items():
        k = lower(stmt, M, schedule=sched, distributions={"B": d})
        cm = k.comm.as_dict()
        rows.append(csv_row(
            f"mismatch_{name}", 0.0,
            f"redistribute_bytes={cm['redistribute_bytes']};"
            f"total_net_bytes={cm['total_network_bytes']}"))
    return rows


if __name__ == "__main__":
    run()
