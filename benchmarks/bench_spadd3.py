"""Paper Fig. 10c analog (C2): fused SpAdd3 vs pairwise two-add execution.

PETSc/Trilinos must run (B+C)+D as two binary adds with an assembled
intermediate — the paper reports 11.8×/38.5× for SpDISTAL's fused kernel.
Here the pairwise baseline uses the same compiled machinery but forced
through a materialized intermediate, isolating the fusion effect.
"""
from __future__ import annotations

import numpy as np

import repro.core as rc
from repro.core import formats as F
from repro.core.lower import lower
from repro.core.tensor import Tensor
from repro.data.spdata import powerlaw_matrix

from .common import csv_row, time_fn

M = rc.Machine(("x", 4))


def run(n: int = 8000, m: int = 8000) -> list:
    rows = []
    Bt = powerlaw_matrix("B", n, m, avg_nnz_per_row=12, seed=0)
    Ct = powerlaw_matrix("C", n, m, avg_nnz_per_row=12, seed=1)
    Dt = powerlaw_matrix("D", n, m, avg_nnz_per_row=12, seed=2)
    A = Tensor.from_dense("A", np.zeros((n, m), np.float32), F.CSR())

    fused_stmt = rc.parse_tin("A(i,j) = B(i,j) + C(i,j) + D(i,j)",
                              A=A, B=Bt, C=Ct, D=Dt)
    k_fused = lower(fused_stmt, M)
    t_fused = time_fn(k_fused.run, iters=5)
    rows.append(csv_row("spadd3_fused", t_fused * 1e6,
                        f"nnz={Bt.nnz + Ct.nnz + Dt.nnz}"))

    # (B + C) -> assembled temporary -> (T + D): both phases pre-lowered so
    # the timing isolates execution + the intermediate assembly (the cost
    # libraries pay per §VI-A), not jit compilation.
    t1 = rc.parse_tin("T(i,j) = B(i,j) + C(i,j) + Z(i,j)",
                      T=A, B=Bt, C=Ct, Z=_zero_like(Bt))
    k1 = lower(t1, M)
    tmp = k1.run()
    tmp.name = "T"
    t2 = rc.parse_tin("A(i,j) = T(i,j) + D(i,j) + Z(i,j)",
                      A=A, T=tmp, D=Dt, Z=_zero_like(Bt))
    k2 = lower(t2, M)

    def pairwise():
        k1.run()        # first add + intermediate assembly
        return k2.run()  # second add over the assembled temporary

    t_pair = time_fn(pairwise, warmup=1, iters=3)
    rows.append(csv_row("spadd3_pairwise", t_pair * 1e6,
                        f"speedup={t_pair/t_fused:.1f}x"))
    return rows


_ZERO_CACHE = {}


def _zero_like(t: Tensor) -> Tensor:
    key = t.shape
    if key not in _ZERO_CACHE:
        coords = np.array([[0, 0]])
        _ZERO_CACHE[key] = Tensor.from_coo(
            "Z", t.shape, coords, np.zeros(1, np.float32), F.CSR())
    return _ZERO_CACHE[key]


if __name__ == "__main__":
    run()
