"""Level-iterator walks vs the conversion fallback they replaced.

Before the level-iterator refactor, every ``*/csc/rows`` cell converted
csc→csr at plan time and every ``spmttkrp/coo3/rows`` cell converted
coo3→csf — a logged O(nnz) re-assembly plus the row-major execution. The
transpose walk and the trailing-singleton walk lower those cells DIRECTLY:
this suite times both executions on the SAME inputs.

  ``csc_spmm_direct``     — transpose-walk lowering (this PR's path)
  ``csc_spmm_fallback``   — converted-CSR execution the fallback ran
  ``csc_convert``         — the csc→csr conversion the fallback also paid
  ``coo3_mttkrp_direct``  — trailing-singleton-walk lowering
  ``coo3_mttkrp_fallback``— converted-CSF execution
  ``coo3_convert``        — the coo3→csf conversion

Plan-time cost matters here too, so ``*_lower`` rows time a COLD lower
(caches cleared) for the direct path vs convert+lower for the fallback.
"""
from __future__ import annotations

import numpy as np

import repro.core as rc
from repro.core import formats as F
from repro.core.lower import clear_lowering_caches, default_row_schedule, lower
from repro.core.tensor import Tensor

from .common import csv_row, time_fn

M = rc.Machine(("x", 4))


def _sparse(rng, shape, density):
    return ((rng.random(shape) < density) *
            rng.standard_normal(shape)).astype(np.float32)


def run(n: int = 4096, m: int = 4096, density: float = 0.002, j: int = 64,
        dims3=(256, 128, 96), density3: float = 0.01, l3: int = 16) -> list:
    rows = []
    rng = np.random.default_rng(0)

    # ---- csc / rows: transpose walk vs csc→csr conversion -----------------
    dB = _sparse(rng, (n, m), density)
    B_csc = Tensor.from_dense("B", dB, F.CSC())
    t_conv = time_fn(lambda: B_csc.to_format(F.CSR()), warmup=1, iters=3)
    rows.append(csv_row("csc_convert", t_conv * 1e6, f"nnz={B_csc.nnz}"))
    B_csr = B_csc.to_format(F.CSR())
    Cd = rng.standard_normal((m, j)).astype(np.float32)

    def spmm_stmt(Bt):
        C = Tensor.from_dense("C", Cd)
        return rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                            A=Tensor.zeros_dense("A", (n, j)), B=Bt, C=C)

    k_direct = lower(spmm_stmt(B_csc), M)
    assert k_direct.fallbacks == [], k_direct.fallbacks
    k_fb = lower(spmm_stmt(B_csr), M)
    np.testing.assert_allclose(k_direct.run(), k_fb.run(), atol=1e-2)
    t_direct = time_fn(k_direct.run, iters=5)
    t_fb = time_fn(k_fb.run, iters=5)
    rows.append(csv_row("csc_spmm_direct", t_direct * 1e6,
                        f"leaf={k_direct.leaf_name}"))
    rows.append(csv_row("csc_spmm_fallback", t_fb * 1e6,
                        f"exec_ratio={t_fb / t_direct:.2f}x"))

    def cold_direct():
        clear_lowering_caches()
        lower(spmm_stmt(B_csc), M)

    def cold_fallback():
        clear_lowering_caches()
        lower(spmm_stmt(B_csc.to_format(F.CSR())), M)

    tl_d = time_fn(cold_direct, warmup=1, iters=3)
    tl_f = time_fn(cold_fallback, warmup=1, iters=3)
    rows.append(csv_row("csc_spmm_direct_lower", tl_d * 1e6, "cold plan"))
    rows.append(csv_row("csc_spmm_fallback_lower", tl_f * 1e6,
                        f"plan_ratio={tl_f / tl_d:.2f}x"))

    # ---- coo3 / rows: trailing-singleton walk vs coo3→csf -----------------
    dB3 = _sparse(rng, dims3, density3)
    B_coo3 = Tensor.from_dense("B", dB3, F.COO(3))
    t_conv3 = time_fn(lambda: B_coo3.to_format(F.CSF(3)), warmup=1, iters=3)
    rows.append(csv_row("coo3_convert", t_conv3 * 1e6, f"nnz={B_coo3.nnz}"))
    B_csf = B_coo3.to_format(F.CSF(3))
    Cf = rng.standard_normal((dims3[1], l3)).astype(np.float32)
    Df = rng.standard_normal((dims3[2], l3)).astype(np.float32)

    def mttkrp_stmt(Bt):
        return rc.parse_tin(
            "A(i,l) = B(i,j,k) * C(j,l) * D(k,l)",
            A=Tensor.zeros_dense("A", (dims3[0], l3)), B=Bt,
            C=Tensor.from_dense("C", Cf), D=Tensor.from_dense("D", Df))

    k3_direct = lower(mttkrp_stmt(B_coo3), M)
    assert k3_direct.fallbacks == [], k3_direct.fallbacks
    k3_fb = lower(mttkrp_stmt(B_csf), M)
    np.testing.assert_allclose(k3_direct.run(), k3_fb.run(), atol=1e-2)
    t3_d = time_fn(k3_direct.run, iters=5)
    t3_f = time_fn(k3_fb.run, iters=5)
    rows.append(csv_row("coo3_mttkrp_direct", t3_d * 1e6,
                        f"leaf={k3_direct.leaf_name}"))
    rows.append(csv_row("coo3_mttkrp_fallback", t3_f * 1e6,
                        f"exec_ratio={t3_f / t3_d:.2f}x"))
    return rows


if __name__ == "__main__":
    run()
