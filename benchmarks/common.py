"""Benchmark harness utilities: timing + CSV output."""
from __future__ import annotations

import time
from typing import Callable, List


def time_fn(fn: Callable, warmup: int = 3, iters: int = 10) -> float:
    """Median wall time in seconds (paper methodology: warm-up + timed)."""
    for _ in range(warmup):
        fn()
    ts: List[float] = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row, flush=True)
    return row
