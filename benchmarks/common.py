"""Benchmark harness utilities: timing + CSV output + result registry.

Every ``csv_row`` is also recorded in ``RESULTS`` so ``run.py --json`` can
emit a machine-readable ``BENCH_<suite>.json`` (name → us_per_call) per
suite — the perf-trajectory artifact uploaded by nightly CI."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

# (name, us_per_call) rows recorded since the last drain — run.py drains
# between suites so each suite gets its own JSON file.
RESULTS: List[Tuple[str, float]] = []


def time_fn(fn: Callable, warmup: int = 3, iters: int = 10) -> float:
    """Median wall time in seconds (paper methodology: warm-up + timed)."""
    for _ in range(warmup):
        fn()
    ts: List[float] = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    mid = len(ts) // 2
    if len(ts) % 2:
        return ts[mid]
    return 0.5 * (ts[mid - 1] + ts[mid])


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    row = f"{name},{us_per_call:.1f},{derived}"
    RESULTS.append((name, float(us_per_call)))
    print(row, flush=True)
    return row


def drain_results() -> Dict[str, float]:
    """Return rows recorded since the last drain and reset the registry.

    Duplicate names (cold/warm patterns timing the same name twice) are
    uniquified as ``name``, ``name#2``, ... instead of silently keeping
    only the last row per name."""
    out: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for name, us in RESULTS:
        n = counts.get(name, 0) + 1
        counts[name] = n
        out[name if n == 1 else f"{name}#{n}"] = us
    RESULTS.clear()
    return out
