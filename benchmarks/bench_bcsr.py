"""Direct blocked (BCSR) execution vs the bcsr→csr conversion fallback.

The paper's compilation thesis (§IV, §VI) applied to blocked formats: a
tensor DECLARED blocked should execute blocked — every stored position a
dense (br, bc) MXU tile — not be converted to CSR and scalarized. Before
the direct path landed, every ``*/bcsr/*`` conformance cell paid exactly
that conversion; this suite times both executions on the SAME inputs:

  ``bcsr_<expr>_direct``    — the direct blocked kernel (this PR's path)
  ``bcsr_<expr>_fallback``  — the converted-CSR execution the fallback ran
  ``bcsr_convert``          — the one-time bcsr→csr conversion the fallback
                              additionally paid at plan time
"""
from __future__ import annotations

import numpy as np

import repro.core as rc
from repro.core import formats as F
from repro.core.lower import default_row_schedule, lower
from repro.core.tensor import Tensor

from .common import csv_row, time_fn

M = rc.Machine(("x", 4))


def _block_sparse(name: str, n: int, m: int, block, block_density: float,
                  seed: int) -> Tensor:
    """Random block-dense BCSR matrix: dense random (br, bc) tiles at a
    sparse set of block-grid positions (assembled directly — no dense
    image)."""
    rng = np.random.default_rng(seed)
    br, bc = block
    gr, gc = -(-n // br), -(-m // bc)
    n_blocks = max(int(gr * gc * block_density), 1)
    lin = rng.choice(gr * gc, size=n_blocks, replace=False)
    coords = np.stack([lin // gc, lin % gc], axis=1)
    tiles = rng.standard_normal((n_blocks, br, bc)).astype(np.float32)
    return Tensor.from_blocks(name, (n, m), F.BCSR(block), coords, tiles)


def run(n: int = 4096, m: int = 4096, block=(8, 8),
        block_density: float = 0.02, j: int = 64) -> list:
    rows = []
    B = _block_sparse("B", n, m, block, block_density, seed=0)
    nnz = B.nnz

    # the conversion the fallback paid at plan time, timed once
    t_conv = time_fn(lambda: B.to_format(F.CSR()), warmup=1, iters=3)
    rows.append(csv_row("bcsr_convert", t_conv * 1e6, f"nnz={nnz}"))
    B_csr = B.to_format(F.CSR())

    cv = np.random.default_rng(1).standard_normal(m).astype(np.float32)
    Cd = np.random.default_rng(2).standard_normal((m, j)).astype(np.float32)

    def spmv_stmt(Bt):
        c = Tensor.from_dense("c", cv)
        return rc.parse_tin("a(i) = B(i,j) * c(j)",
                            a=Tensor.zeros_dense("a", (n,)), B=Bt, c=c)

    def spmm_stmt(Bt):
        C = Tensor.from_dense("C", Cd)
        return rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                            A=Tensor.zeros_dense("A", (n, j)), B=Bt, C=C)

    for expr, mk in (("spmv", spmv_stmt), ("spmm", spmm_stmt)):
        k_direct = lower(mk(B), M)
        assert k_direct.fallbacks == [], k_direct.fallbacks
        assert k_direct.leaf_name.startswith("bcsr_"), k_direct.leaf_name
        t_direct = time_fn(k_direct.run, iters=5)
        # the fallback execution: converted CSR tensor through the scalar
        # leaf (exactly what the logged-conversion cells ran before)
        k_fb = lower(mk(B_csr), M)
        t_fb = time_fn(k_fb.run, iters=5)
        np.testing.assert_allclose(k_direct.run(), k_fb.run(), atol=1e-2)
        rows.append(csv_row(f"bcsr_{expr}_direct", t_direct * 1e6,
                            f"leaf={k_direct.leaf_name}"))
        rows.append(csv_row(f"bcsr_{expr}_fallback", t_fb * 1e6,
                            f"speedup={t_fb / t_direct:.2f}x"))
    return rows


if __name__ == "__main__":
    run()
