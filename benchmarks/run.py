"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (paper §VI mapping):

  bench_vs_interp     — Fig. 10: compiled vs interpretation (C1)
  bench_spadd3        — Fig. 10c: fused vs pairwise adds (C2)
  bench_load_balance  — §II-D: universe vs non-zero partitions (C3)
  bench_mismatch      — §II-D: data vs computation distribution (C4)
  bench_weak_scaling  — Fig. 13: banded SpMV weak scaling
  bench_pallas_kernels— leaf/packing microbench
  bench_bcsr          — direct blocked (BCSR) path vs conversion fallback
  bench_replan        — re-plan fast path: cold lower vs warm re-lower
                        (plan/shard/runner caches) vs execute-only
  bench_mesh2d        — 1-D vs 2-D machine grid at fixed piece count:
                        SpMM comm volume (per-axis) + wall time
  bench_levels        — level-iterator walks: direct csc (transpose walk)
                        & coo3 (trailing-singleton walk) vs the
                        conversion-fallback execution they replaced
  bench_autotune      — autoscheduler: auto-chosen schedule vs best/worst
                        hand-picked cell + cold vs tuned-warm lower time
  bench_replication   — communication-avoiding replication: SpMM comm
                        volume + wall time across 1-D / best 2-D /
                        replicated 2.5-D grids and SpMTTKRP across
                        1-D / P×Q×R bricks, at fixed total pieces
  bench_fault         — elastic recovery: cold P−1 re-lower vs shard-
                        reusing relower(dead=…), plus the recovery wall
                        time split restore / re-plan / re-jit
  bench_serving       — serving fast path: run_many batching vs a
                        per-request loop, p50/p99 latency vs SLO through
                        SparseKernelServer, and double-buffered
                        comm/compute overlap efficiency

Scale flag: ``--quick`` shrinks inputs for CI-speed runs. ``--json`` also
writes a machine-readable ``BENCH_<suite>.json`` per suite to
``--out-dir`` — the perf-trajectory artifacts collected by nightly CI.
Each file is ``{"results": {name: us_per_call}, "telemetry": snapshot}``:
the telemetry snapshot (cache hit rates, per-axis communication byte
counters, per-piece skew when profiled) captures *why* the numbers moved,
not just wall time. Counters/gauges/histograms reset per suite; the cache
stats are process-cumulative.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma-separated suite names (default: all)")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<suite>.json alongside the CSV")
    ap.add_argument("--out-dir", default=".",
                    help="directory for the BENCH_*.json files")
    args = ap.parse_args()

    from repro.runtime import telemetry

    from . import (bench_autotune, bench_bcsr, bench_fault, bench_levels,
                   bench_load_balance, bench_mesh2d, bench_mismatch,
                   bench_pallas_kernels, bench_replan, bench_replication,
                   bench_serving, bench_spadd3, bench_vs_interp,
                   bench_weak_scaling)
    from .common import drain_results

    print("name,us_per_call,derived")
    suites = {
        "vs_interp": lambda: bench_vs_interp.run(
            *((4000, 4000, 8) if args.quick else (20000, 20000, 16)),
            dims3=(400, 300, 200) if args.quick else (1200, 900, 500)),
        "spadd3": lambda: bench_spadd3.run(
            *( (2000, 2000) if args.quick else (8000, 8000) )),
        "load_balance": bench_load_balance.run,
        "mismatch": bench_mismatch.run,
        "weak_scaling": lambda: bench_weak_scaling.run(
            base_n=8000 if args.quick else 40000),
        "pallas_kernels": lambda: bench_pallas_kernels.run(
            n=4000 if args.quick else 20000),
        "bcsr": lambda: bench_bcsr.run(
            *((1024, 1024) if args.quick else (4096, 4096)),
            j=32 if args.quick else 64),
        "replan": lambda: bench_replan.run(
            *((2048, 2048) if args.quick else (4096, 4096)),
            j=32 if args.quick else 64),
        "mesh2d": lambda: bench_mesh2d.run(
            *((1024, 1024) if args.quick else (4096, 4096)),
            j=32 if args.quick else 64),
        "levels": lambda: bench_levels.run(
            *((1024, 1024) if args.quick else (4096, 4096)),
            j=32 if args.quick else 64,
            dims3=(96, 64, 48) if args.quick else (256, 128, 96)),
        "autotune": lambda: bench_autotune.run(
            *((1024, 1024) if args.quick else (4096, 4096)),
            j=16 if args.quick else 64),
        "replication": lambda: bench_replication.run(
            *((1024, 1024) if args.quick else (4096, 4096)),
            j=32 if args.quick else 128,
            dims3=(96, 64, 48) if args.quick else (256, 128, 96),
            L=8 if args.quick else 16),
        "fault": lambda: bench_fault.run(
            *((1024, 1024) if args.quick else (4096, 4096)),
            j=32 if args.quick else 64),
        "serving": lambda: bench_serving.run(
            *((1024, 1024) if args.quick else (4096, 4096)),
            j=32 if args.quick else 64),
    }
    only = {s for s in args.only.split(",") if s} if args.only else None
    if only:
        unknown = only - suites.keys()
        if unknown:
            ap.error(f"unknown suite(s): {', '.join(sorted(unknown))}; "
                     f"available: {', '.join(suites)}")
    for name, fn in suites.items():
        if only is not None and name not in only:
            continue
        drain_results()        # reset the registry for this suite
        telemetry.METRICS.clear()   # per-suite counters/gauges/histograms
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception as e:  # noqa: BLE001 — report, keep the harness going
            print(f"{name}_ERROR,0,{type(e).__name__}:{e}", file=sys.stderr)
            raise
        if args.json:
            os.makedirs(args.out_dir, exist_ok=True)
            path = os.path.join(args.out_dir, f"BENCH_{name}.json")
            with open(path, "w") as fh:
                json.dump({"results": drain_results(),
                           "telemetry": telemetry.METRICS.snapshot()},
                          fh, indent=2, sort_keys=True)
            print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    main()
