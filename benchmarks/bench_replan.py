"""Re-plan fast path: cold lower vs warm re-lower vs execute-only.

SpDISTAL's headline claim is that compiled distributed sparse code beats
interpretation because the expensive work happens once, at compile time —
but before the fingerprinted plan/shard/runner caches, every `lower()`
call re-partitioned, re-packed every shard from numpy, and re-traced fresh
jit closures, so a straggler re-plan or a repeated solve paid full
compile+materialize cost each time. This suite quantifies the warm path
per kernel family:

  ``replan_<fam>_<expr>_cold``  — lower+run with ALL caches cleared first
                                  (what every re-lower cost before)
  ``replan_<fam>_<expr>_warm``  — re-lower+run over unchanged operands
                                  (plan memo + shard cache + jitted-runner
                                  reuse; hit counters asserted)
  ``replan_<fam>_<expr>_exec``  — run() only on an existing kernel (the
                                  floor the warm path approaches)
  ``replan_spadd3_weighted``    — straggler-weighted nnz re-plan: new chunk
                                  bounds over the SAME operands re-slice
                                  the cached concatenated stream
"""
from __future__ import annotations

import numpy as np

import repro.core as rc
from repro.core import formats as F
from repro.core.lower import clear_lowering_caches, default_nnz_schedule, lower
from repro.core.tensor import Tensor

from .common import csv_row, time_fn

M = rc.Machine(("x", 4))


def _csr_sparse(name: str, n: int, m: int, density: float, seed: int,
                ) -> Tensor:
    rng = np.random.default_rng(seed)
    nnz = max(int(n * m * density), 1)
    lin = rng.choice(n * m, size=nnz, replace=False)
    coords = np.stack([lin // m, lin % m], axis=1)
    vals = rng.standard_normal(nnz).astype(np.float32)
    return Tensor.from_coo(name, (n, m), coords, vals, F.CSR())


def _bcsr_sparse(name: str, n: int, m: int, block, block_density: float,
                 seed: int) -> Tensor:
    rng = np.random.default_rng(seed)
    br, bc = block
    gr, gc = -(-n // br), -(-m // bc)
    n_blocks = max(int(gr * gc * block_density), 1)
    lin = rng.choice(gr * gc, size=n_blocks, replace=False)
    coords = np.stack([lin // gc, lin % gc], axis=1)
    tiles = rng.standard_normal((n_blocks, br, bc)).astype(np.float32)
    return Tensor.from_blocks(name, (n, m), F.BCSR(block), coords, tiles)


def run(n: int = 4096, m: int = 4096, j: int = 64, density: float = 0.01,
        block=(8, 8), block_density: float = 0.02) -> list:
    rows = []
    rng = np.random.default_rng(1)
    cv = rng.standard_normal(m).astype(np.float32)
    Cd = rng.standard_normal((m, j)).astype(np.float32)

    def spmv_stmt(Bt):
        c = Tensor.from_dense("c", cv)
        return rc.parse_tin("a(i) = B(i,j) * c(j)",
                            a=Tensor.zeros_dense("a", (n,)), B=Bt, c=c)

    def spmm_stmt(Bt):
        C = Tensor.from_dense("C", Cd)
        return rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                            A=Tensor.zeros_dense("A", (n, j)), B=Bt, C=C)

    operands = {
        "csr": _csr_sparse("B", n, m, density, seed=0),
        "bcsr": _bcsr_sparse("B", n, m, block, block_density, seed=0),
    }
    for family, B in operands.items():
        for expr, mk in (("spmv", spmv_stmt), ("spmm", spmm_stmt)):
            stmt = mk(B)

            def cold():
                clear_lowering_caches()
                return lower(stmt, M).run()

            t_cold = time_fn(cold, warmup=0, iters=3)
            lower(stmt, M).run()              # prime every cache

            def warm():
                return lower(stmt, M).run()

            t_warm = time_fn(warm, warmup=1, iters=5)
            k = lower(stmt, M)
            # hit counters must confirm shard + runner + plan reuse
            assert k.cache.warm, f"warm re-lower re-assembled: {k.cache}"
            assert k.cache.shard_hits > 0 and k.cache.runner_hits > 0
            t_exec = time_fn(k.run, warmup=1, iters=5)
            rows.append(csv_row(f"replan_{family}_{expr}_cold",
                                t_cold * 1e6, f"nnz={B.nnz}"))
            rows.append(csv_row(
                f"replan_{family}_{expr}_warm", t_warm * 1e6,
                f"speedup={t_cold / t_warm:.1f}x"))
            rows.append(csv_row(f"replan_{family}_{expr}_exec",
                                t_exec * 1e6))

    # Straggler-weighted re-plan of the spadd3 nnz stream: the weights
    # change the chunk bounds (shard-cache miss on the sliced chunks) but
    # the concatenated stream itself is reused — re-slicing, not
    # re-walking the coordinate trees.
    Bt = _csr_sparse("B", n, m, density / 2, seed=3)
    Ct = _csr_sparse("C", n, m, density / 2, seed=4)
    Dt = _csr_sparse("D", n, m, density / 2, seed=5)
    A = Tensor.from_coo("A", (n, m), np.zeros((0, 2), np.int64),
                        np.zeros((0,), np.float32), F.CSR())
    stmt = rc.parse_tin("A(i,j) = B(i,j) + C(i,j) + D(i,j)",
                        A=A, B=Bt, C=Ct, D=Dt)
    sched = default_nnz_schedule(stmt, M)

    def cold_add():
        clear_lowering_caches()
        return lower(stmt, M, schedule=sched).run()

    t_cold = time_fn(cold_add, warmup=0, iters=3)
    lower(stmt, M, schedule=sched).run()
    w = np.array([1.0, 0.5, 1.0, 1.0])

    def weighted_replan():
        return lower(stmt, M, schedule=sched, weights=w).run()

    t_replan = time_fn(weighted_replan, warmup=1, iters=5)
    rows.append(csv_row("replan_spadd3_cold", t_cold * 1e6,
                        f"entries={Bt.nnz + Ct.nnz + Dt.nnz}"))
    rows.append(csv_row("replan_spadd3_weighted", t_replan * 1e6,
                        f"speedup={t_cold / t_replan:.1f}x"))
    return rows


if __name__ == "__main__":
    run()
