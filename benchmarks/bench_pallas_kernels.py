"""Kernel-level microbench: XLA leaves vs Pallas (interpret) per paper
kernel, plus the ELL packing overhead/waste. On TPU the Pallas column is
the production path; here interpret mode only checks that the packing
pipeline is not a bottleneck and reports layout padding waste.
"""
from __future__ import annotations

import numpy as np

from repro.core import formats as F
from repro.core.tensor import Tensor
from repro.data.spdata import powerlaw_matrix
from repro.kernels import ops
from repro.kernels.layout import ell_pack

from .common import csv_row, time_fn


def run(n: int = 20000) -> list:
    rows = []
    B = powerlaw_matrix("B", n, n, 16, seed=0)
    c = np.random.default_rng(1).standard_normal(n).astype(np.float32)
    pos, crd, vals = B.levels[1].pos, B.levels[1].crd, B.vals

    t = time_fn(lambda: np.asarray(
        ops.spmv(pos, crd, vals, c, impl="xla")), iters=5)
    rows.append(csv_row("spmv_xla_leaf", t * 1e6, f"nnz={B.nnz}"))

    blocks, = ell_pack(pos, crd, vals)
    t_pack = time_fn(lambda: ell_pack(pos, crd, vals), warmup=1, iters=3)
    rows.append(csv_row("ell_pack", t_pack * 1e6,
                        f"waste={blocks.padding_waste():.3f}"))
    return rows


if __name__ == "__main__":
    run()
