"""Autoscheduler benchmark: model-chosen schedule vs every hand-picked
cell, plus the tuned-plan cache's amortization of the search itself.

Rows per expression (spmv/spmm over a skewed power-law CSR matrix):

  autotune_<expr>_hand_<label> — each enumerable hand schedule (rows/nnz
                                 1-D + every 2-D factorization), run time
  autotune_<expr>_auto         — run time of the auto-chosen schedule;
                                 the derived column records the picked
                                 label vs the best/worst hand cells
  autotune_<expr>_lower_cold   — lower(schedule="auto") with ALL caches
                                 cleared: pays the candidate search
  autotune_<expr>_lower_warm   — tuned-warm re-lower: the memoized winner
                                 (cache hit asserted — no search)

The acceptance gate this drives: the auto run time stays within ~10% of
the best hand cell, and the warm lower is far below the cold one.
"""
from __future__ import annotations

import numpy as np

import repro.core as rc
from repro.core import plan_search as PS
from repro.core.lower import clear_lowering_caches, lower
from repro.core.tensor import Tensor
from repro.data.spdata import powerlaw_matrix

from .common import csv_row, time_fn

M = rc.Machine(("x", 4))


def run(n: int = 4096, m: int = 4096, j: int = 64,
        avg_nnz_per_row: int = 16) -> list:
    rows = []
    rng = np.random.default_rng(1)
    B = powerlaw_matrix("B", n, m, avg_nnz_per_row, seed=0)
    cv = rng.standard_normal(m).astype(np.float32)
    Cd = rng.standard_normal((m, j)).astype(np.float32)

    def spmv_stmt():
        return rc.parse_tin(
            "a(i) = B(i,j) * c(j)", a=Tensor.zeros_dense("a", (n,)), B=B,
            c=Tensor.from_dense("c", cv))

    def spmm_stmt():
        return rc.parse_tin(
            "A(i,j) = B(i,k) * C(k,j)",
            A=Tensor.zeros_dense("A", (n, j)), B=B,
            C=Tensor.from_dense("C", Cd))

    for expr, mk in (("spmv", spmv_stmt), ("spmm", spmm_stmt)):
        stmt = mk()
        # -- every hand-pickable cell, timed ------------------------------
        stats = PS.structural_stats(stmt)
        hand = {}
        for p in PS.enumerate_points(stmt, M, stats):
            sched, mach = p.build(stmt, M)
            k = lower(stmt, mach, schedule=sched)
            t = time_fn(k.run, warmup=1, iters=5)
            hand[p.label] = t
            rows.append(csv_row(
                f"autotune_{expr}_hand_{p.label.replace('/', '_')}",
                t * 1e6))
        best = min(hand, key=hand.get)
        worst = max(hand, key=hand.get)

        # -- the auto-chosen schedule -------------------------------------
        clear_lowering_caches()
        k_auto = lower(stmt, M, schedule="auto")
        t_auto = time_fn(k_auto.run, warmup=1, iters=5)
        rows.append(csv_row(
            f"autotune_{expr}_auto", t_auto * 1e6,
            f"picked={k_auto.tuned.label};best={best};worst={worst};"
            f"vs_best={t_auto / hand[best]:.2f}x"))

        # -- search amortization: cold lower vs tuned-warm re-lower -------
        def cold():
            clear_lowering_caches()
            return lower(stmt, M, schedule="auto")

        t_cold = time_fn(cold, warmup=0, iters=3)
        lower(stmt, M, schedule="auto")            # prime every cache

        def warm():
            return lower(stmt, M, schedule="auto")

        t_warm = time_fn(warm, warmup=1, iters=5)
        assert warm().cache.tuned_hits == 1, "warm lower must hit the " \
            "tuned-plan cache"
        rows.append(csv_row(f"autotune_{expr}_lower_cold", t_cold * 1e6))
        rows.append(csv_row(
            f"autotune_{expr}_lower_warm", t_warm * 1e6,
            f"speedup={t_cold / max(t_warm, 1e-12):.0f}x"))
    return rows


if __name__ == "__main__":
    run()
