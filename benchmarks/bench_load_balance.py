"""Paper §II-D / C3: universe (row-based) vs non-zero partitioning under
skew.

Reports the partition imbalance metric (max/mean − 1 of per-shard nnz) and
the simulated parallel time (max shard nnz, since leaf work ∝ nnz) for both
strategies across matrix families, plus the actual single-host wall time of
both compiled kernels.
"""
from __future__ import annotations

import numpy as np

import repro.core as rc
from repro.core.lower import default_nnz_schedule, default_row_schedule, lower
from repro.core.tensor import Tensor
from repro.data.spdata import banded_matrix, powerlaw_matrix, uniform_sparse

from .common import csv_row, time_fn

M = rc.Machine(("x", 16))


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    mats = {
        "powerlaw": powerlaw_matrix("B", 30000, 30000, 16, seed=0),
        "uniform": uniform_sparse("B", (30000, 30000), 16 / 30000, seed=1),
        "banded": banded_matrix("B", 30000, bandwidth=8, seed=2),
    }
    m = 30000
    c = Tensor.from_dense("c", rng.standard_normal(m).astype(np.float32))
    for name, B in mats.items():
        a = Tensor.zeros_dense("a", (B.shape[0],))
        stmt = rc.parse_tin("a(i) = B(i,j) * c(j)", a=a, B=B, c=c)
        k_rows = lower(stmt, M, schedule=default_row_schedule(stmt, M))
        k_nnz = lower(stmt, M, schedule=default_nnz_schedule(stmt, M))
        imb_r, imb_n = k_rows.imbalance(), k_nnz.imbalance()
        # simulated parallel step time = max shard nnz / per-shard rate
        vb_r = k_rows.plans["B"].vals_bounds
        vb_n = k_nnz.plans["B"].vals_bounds
        sim_r = int((vb_r[:, 1] - vb_r[:, 0]).max())
        sim_n = int((vb_n[:, 1] - vb_n[:, 0]).max())
        t_r = time_fn(k_rows.run, iters=5)
        t_n = time_fn(k_nnz.run, iters=5)
        rows.append(csv_row(
            f"loadbal_{name}_rows", t_r * 1e6,
            f"imbalance={imb_r:.2f};max_shard_nnz={sim_r}"))
        rows.append(csv_row(
            f"loadbal_{name}_nnz", t_n * 1e6,
            f"imbalance={imb_n:.2f};max_shard_nnz={sim_n};"
            f"sim_speedup={sim_r/max(sim_n,1):.2f}x"))
    return rows


if __name__ == "__main__":
    run()
