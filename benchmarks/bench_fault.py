"""Elastic recovery: cold re-lower vs shard-reusing relower, and the
recovery wall-time split.

The elastic claim (ISSUE 8): after losing one of P devices, re-planning on
the P−1 survivors should NOT pay the cold lower price — the migration
bounds leave P−2 partition windows bitwise unchanged, so their per-piece
SHARD_CACHE entries (plus the content-keyed replicated operand) are pure
hits and only the merged window re-packs. Suite rows:

  ``fault_cold_lower_p4``       — lower+run at P=4 with all caches cleared
                                  (the baseline every path starts from)
  ``fault_cold_relower_p3``     — fresh lower+run at P=3, caches cleared
                                  (what device-loss recovery cost WITHOUT
                                  elastic shard reuse)
  ``fault_elastic_relower_p3``  — relower(dead=1)+run from the warm P=4
                                  kernel (shard reuse asserted ≥ 50%,
                                  result asserted bit-for-bit)
  ``fault_recovery_total``      — full run_with_recovery with an injected
                                  device loss, minus the unfaulted run:
                                  the marginal price of one recovery
  ``fault_recovery_restore``    — …split: checkpoint restore
  ``fault_recovery_replan``     — …split: shrink + elastic re-plan
  ``fault_recovery_rejit``      — …split: first post-recovery execute
  ``fault_shard_reuse_pct``     — reuse fraction ×100 (not a time; lets
                                  the JSON artifact track the counter)
"""
from __future__ import annotations

import tempfile

import numpy as np

import repro.core as rc
from repro.core import formats as F
from repro.core.lower import clear_lowering_caches, lower, relower
from repro.core.tensor import Tensor
from repro.runtime.elastic import run_with_recovery
from repro.runtime.fault import FaultEvent, FaultInjector

from .common import csv_row, time_fn


def _int_sparse(rng, n: int, m: int, density: float) -> np.ndarray:
    # integer-valued so every reduction order agrees bit for bit
    return (rng.integers(-3, 4, (n, m)) *
            (rng.random((n, m)) < density)).astype(np.float32)


def run(n: int = 4096, m: int = 4096, j: int = 64,
        density: float = 0.01, steps: int = 4) -> list:
    rows = []
    rng = np.random.default_rng(0)
    dB = _int_sparse(rng, n, m, density)
    dC = rng.integers(-3, 4, (m, j)).astype(np.float32)

    def mkstmt():
        B = Tensor.from_dense("B", dB.copy(), F.CSR())
        C = Tensor.from_dense("C", dC.copy())
        return rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                            A=Tensor.zeros_dense("A", (n, j)), B=B, C=C)

    M4, M3 = rc.Machine(("x", 4)), rc.Machine(("x", 3))
    stmt = mkstmt()

    def cold_p4():
        clear_lowering_caches()
        return np.asarray(lower(stmt, M4, elastic=True).run())

    def cold_p3():
        clear_lowering_caches()
        return np.asarray(lower(stmt, M3).run())

    t = time_fn(cold_p4, warmup=1, iters=5)
    rows.append(csv_row("fault_cold_lower_p4", t * 1e6))
    ref = cold_p4()

    t = time_fn(cold_p3, warmup=1, iters=5)
    rows.append(csv_row("fault_cold_relower_p3", t * 1e6))

    # elastic path: warm P=4 kernel in cache, then migrate dead piece 1
    clear_lowering_caches()
    k4 = lower(stmt, M4, elastic=True)
    k4.run()

    def elastic_p3():
        k3 = relower(k4, M3, dead=1)
        out = np.asarray(k3.run())
        assert k3.cache.shard_reuse >= 0.5, k3.cache.shard_reuse
        assert np.array_equal(out, ref)
        return k3

    k3 = elastic_p3()
    reuse = k3.cache.shard_reuse
    t = time_fn(lambda: elastic_p3(), warmup=1, iters=5)
    rows.append(csv_row("fault_elastic_relower_p3", t * 1e6,
                        f"reuse={reuse:.0%}"))
    rows.append(csv_row("fault_shard_reuse_pct", reuse * 100.0))

    # full recovery loop: device loss mid-run vs the unfaulted run
    clear_lowering_caches()
    base, _ = run_with_recovery(mkstmt(), M4, steps,
                                ckpt_dir=tempfile.mkdtemp(prefix="bf_"))
    clear_lowering_caches()
    inj = FaultInjector([FaultEvent(step=steps // 2, kind="device_loss",
                                    piece=1)])
    state, rep = run_with_recovery(mkstmt(), M4, steps,
                                   ckpt_dir=tempfile.mkdtemp(prefix="bf_"),
                                   injector=inj)
    assert np.array_equal(state, base)
    assert rep.restarts == 1 and rep.shard_reuse >= 0.5
    total = rep.restore_s + rep.replan_s + rep.rejit_s
    rows.append(csv_row("fault_recovery_total", total * 1e6,
                        f"pieces={rep.initial_pieces}->{rep.final_pieces}"))
    rows.append(csv_row("fault_recovery_restore", rep.restore_s * 1e6))
    rows.append(csv_row("fault_recovery_replan", rep.replan_s * 1e6))
    rows.append(csv_row("fault_recovery_rejit", rep.rejit_s * 1e6))
    return rows


if __name__ == "__main__":
    run()
