"""Paper Fig. 13 analog: SpMV weak scaling on synthetic banded matrices.

Without real multi-node hardware, the scaling series reports the
plan-level quantities that determine weak-scaling efficiency — per-shard
nnz (constant = perfect), replicated-operand bytes per shard, and total
network bytes from the lowered kernel's communication model — plus
single-host wall time of the compiled kernel at the base size.
"""
from __future__ import annotations

import numpy as np

import repro.core as rc
from repro.core.lower import lower
from repro.core.tensor import Tensor
from repro.data.spdata import banded_matrix

from .common import csv_row, time_fn


def run(base_n: int = 40000, bandwidth: int = 8) -> list:
    rows = []
    for pieces in (1, 2, 4, 8, 16, 32):
        n = base_n * pieces          # weak scaling: n grows with machine
        B = banded_matrix("B", n, bandwidth=bandwidth, seed=0)
        c = Tensor.from_dense(
            "c", np.random.default_rng(1).standard_normal(n)
            .astype(np.float32))
        a = Tensor.zeros_dense("a", (n,))
        stmt = rc.parse_tin("a(i) = B(i,j) * c(j)", a=a, B=B, c=c)
        M = rc.Machine(("x", pieces))
        k = lower(stmt, M)
        vb = k.plans["B"].vals_bounds
        per_shard = int((vb[:, 1] - vb[:, 0]).max())
        t = time_fn(k.run, warmup=2, iters=3) if pieces <= 4 else 0.0
        rows.append(csv_row(
            f"weakscale_p{pieces}", t * 1e6,
            f"nnz_per_shard={per_shard};"
            f"net_bytes={k.comm.total_network_bytes()};"
            f"eff={vb[0,1]-vb[0,0]}/{per_shard}"))
    return rows


if __name__ == "__main__":
    run()
