"""Paper Fig. 10 analog: compiled SpDISTAL kernels vs the CTF-style
interpreter, on skewed (power-law) inputs.

The paper reports 299× (SpMV), 161× (SpTTV), 19.2× (SpAdd3), 15.3×
(SDDMM) median speedups of compilation over interpretation. The same
mechanism is measured here on one host: `core.lower` emits a fused,
format-specialized kernel; `core.interp` executes pairwise densified
contractions with materialized intermediates.
"""
from __future__ import annotations

import numpy as np

import repro.core as rc
from repro.core import formats as F
from repro.core.interp import interpret
from repro.core.lower import default_nnz_schedule, lower
from repro.core.tensor import Tensor
from repro.data.spdata import powerlaw_matrix, powerlaw_tensor3

from .common import csv_row, time_fn

M = rc.Machine(("x", 4))


def run(n: int = 20000, m: int = 20000, nnz_row: int = 16,
        dims3=(1200, 900, 500)) -> list:
    """dims3 sizes the 3-tensor so the INTERPRETER's densified intermediate
    (prod(dims3)·4 bytes, allocated per pairwise step) fits container RAM —
    the compiled path never densifies; only the baseline needs the cap."""
    rows = []
    B = powerlaw_matrix("B", n, m, avg_nnz_per_row=nnz_row, seed=0)
    c = Tensor.from_dense("c", np.random.default_rng(1)
                          .standard_normal(m).astype(np.float32))
    a = Tensor.zeros_dense("a", (n,))

    # ---- SpMV ----------------------------------------------------------
    stmt = rc.parse_tin("a(i) = B(i,j) * c(j)", a=a, B=B, c=c)
    k = lower(stmt, M)
    t_comp = time_fn(k.run)
    t_interp = time_fn(lambda: interpret(stmt), warmup=1, iters=3)
    rows.append(csv_row("spmv_compiled", t_comp * 1e6,
                        f"nnz={B.nnz}"))
    rows.append(csv_row("spmv_interpreted", t_interp * 1e6,
                        f"speedup={t_interp/t_comp:.1f}x"))

    # ---- SpMM (J=32) ----------------------------------------------------
    J = 32
    Cm = Tensor.from_dense("C", np.random.default_rng(2)
                           .standard_normal((m, J)).astype(np.float32))
    A2 = Tensor.zeros_dense("A", (n, J))
    smm = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)", A=A2, B=B, C=Cm)
    km = lower(smm, M)
    t_comp = time_fn(km.run, iters=5)
    t_interp = time_fn(lambda: interpret(smm), warmup=1, iters=3)
    rows.append(csv_row("spmm_compiled", t_comp * 1e6, f"J={J}"))
    rows.append(csv_row("spmm_interpreted", t_interp * 1e6,
                        f"speedup={t_interp/t_comp:.1f}x"))

    # ---- SDDMM (nnz-based, the paper's load-balanced schedule) ----------
    K = 32
    Cc = Tensor.from_dense("C", np.random.default_rng(3)
                           .standard_normal((n, K)).astype(np.float32))
    Dd = Tensor.from_dense("D", np.random.default_rng(4)
                           .standard_normal((K, m)).astype(np.float32))
    Apat = Tensor("A", B.shape, B.format, B.levels,
                  np.ones_like(B.vals), B.dtype)
    sd = rc.parse_tin("A(i,j) = B(i,j) * C(i,k) * D(k,j)",
                      A=Apat, B=B, C=Cc, D=Dd)
    ksd = lower(sd, M, schedule=default_nnz_schedule(sd, M))
    t_comp = time_fn(ksd.run, iters=5)
    t_interp = time_fn(lambda: interpret(sd), warmup=1, iters=2)
    rows.append(csv_row("sddmm_compiled", t_comp * 1e6, f"K={K}"))
    rows.append(csv_row("sddmm_interpreted", t_interp * 1e6,
                        f"speedup={t_interp/t_comp:.1f}x"))

    # ---- SpTTV on a 3-tensor --------------------------------------------
    dims = dims3
    B3 = powerlaw_tensor3("B", dims, avg_nnz_per_slice=128, seed=5)
    cv = Tensor.from_dense("c", np.random.default_rng(6)
                           .standard_normal(dims[2]).astype(np.float32))
    Att = Tensor.from_dense(
        "A", np.zeros(dims[:2], np.float32), F.CSR())
    sttv = rc.parse_tin("A(i,j) = B(i,j,k) * c(k)", A=Att, B=B3, c=cv)
    kt = lower(sttv, M)
    t_comp = time_fn(kt.run, iters=5)
    t_interp = time_fn(lambda: interpret(sttv), warmup=1, iters=2)
    rows.append(csv_row("spttv_compiled", t_comp * 1e6,
                        f"nnz={B3.nnz}"))
    rows.append(csv_row("spttv_interpreted", t_interp * 1e6,
                        f"speedup={t_interp/t_comp:.1f}x"))

    # ---- SpMTTKRP --------------------------------------------------------
    L = 32
    Cf = Tensor.from_dense("C", np.random.default_rng(7)
                           .standard_normal((dims[1], L)).astype(np.float32))
    Df = Tensor.from_dense("D", np.random.default_rng(8)
                           .standard_normal((dims[2], L)).astype(np.float32))
    Am = Tensor.zeros_dense("A", (dims[0], L))
    smk = rc.parse_tin("A(i,l) = B(i,j,k) * C(j,l) * D(k,l)",
                       A=Am, B=B3, C=Cf, D=Df)
    kk = lower(smk, M)
    t_comp = time_fn(kk.run, iters=5)
    t_interp = time_fn(lambda: interpret(smk), warmup=1, iters=2)
    rows.append(csv_row("spmttkrp_compiled", t_comp * 1e6, f"L={L}"))
    rows.append(csv_row("spmttkrp_interpreted", t_interp * 1e6,
                        f"speedup={t_interp/t_comp:.1f}x"))
    return rows


if __name__ == "__main__":
    run()
