"""1-D vs 2-D machine grids at FIXED piece count (ISSUE 4).

SpMM on Px1 vs the P/2 x 2 grid: same number of pieces, different
communication structure. The 1-D row distribution replicates the dense
operand to every piece (|C|*(PQ-1) network bytes); the SUMMA-style grid
broadcasts each k-window along x only and all-reduces output partials
along y only (|C|*(P-1) + |A|*(Q-1)) — strictly fewer whenever
|A| < P*|C|. Rows report wall time (us) with the comm volume and its
per-axis attribution in the derived column; the *_comm_bytes rows carry
the byte totals in the numeric column so BENCH_mesh2d.json pins the
trajectory.
"""
from __future__ import annotations

import numpy as np

import repro.core as rc
from repro.core import formats as F
from repro.core.lower import (clear_lowering_caches, default_grid_schedule,
                              default_row_schedule, lower)
from repro.core.tensor import Tensor
from .common import csv_row, time_fn


def _spmm_stmt(rng, n, m, j, density=0.05):
    dB = ((rng.random((n, m)) < density) *
          rng.standard_normal((n, m))).astype(np.float32)
    B = Tensor.from_dense("B", dB, F.CSR())
    C = Tensor.from_dense("C", rng.standard_normal((m, j)).astype(np.float32))
    return rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                        A=Tensor.zeros_dense("A", (n, j)), B=B, C=C)


def run(n=4096, m=4096, j=64, pieces=4):
    rng = np.random.default_rng(0)
    stmt = _spmm_stmt(rng, n, m, j)
    m1 = rc.Machine(("x", pieces))
    m2 = rc.Machine(("x", pieces // 2), ("y", 2))

    clear_lowering_caches()
    k1 = lower(stmt, m1, schedule=default_row_schedule(stmt, m1))
    k2 = lower(stmt, m2, schedule=default_grid_schedule(stmt, m2))

    b1 = k1.comm.total_network_bytes()
    b2 = k2.comm.total_network_bytes()
    ax = {name: a.network_bytes() for name, a in k2.comm.axes.items()}
    assert b2 < b1, (
        f"2-D SpMM must move strictly fewer bytes than 1-D at equal piece "
        f"count: 2-D {b2} vs 1-D {b1}")

    t1 = time_fn(k1.run)
    t2 = time_fn(k2.run)
    rows = [
        csv_row(f"spmm_1d_{pieces}x1", t1 * 1e6, f"net_bytes={b1}"),
        csv_row(f"spmm_2d_{pieces // 2}x2", t2 * 1e6,
                f"net_bytes={b2};" +
                ";".join(f"{a}_bytes={v}" for a, v in sorted(ax.items()))),
        csv_row(f"spmm_1d_{pieces}x1_comm_bytes", float(b1), ""),
        csv_row(f"spmm_2d_{pieces // 2}x2_comm_bytes", float(b2),
                f"saving={1.0 - b2 / b1:.3f}"),
    ]
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
