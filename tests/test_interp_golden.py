"""Hand-computed golden values for the interpreter oracle (ISSUE 1).

`core.interp.interpret` is the reference every conformance-matrix cell is
differentially checked against — so it must itself be pinned by values
computed BY HAND on tiny fixed tensors, not by another numpy expression.
Each case documents the arithmetic next to the assertion.
"""
import numpy as np

import repro.core as rc
from repro.core import formats as F
from repro.core.interp import interpret
from repro.core.tensor import Tensor


def T(name, arr, fm=None):
    return Tensor.from_dense(name, np.asarray(arr, np.float32), fm)


def test_spmv_golden():
    # B = [[1 0 2]          a[0] = 1*1 + 0*2 + 2*3 = 7
    #      [0 0 0]          a[1] = 0              (empty row)
    #      [0 3 4]]         a[2] = 3*2 + 4*3     = 18
    B = T("B", [[1, 0, 2], [0, 0, 0], [0, 3, 4]], F.CSR())
    c = T("c", [1, 2, 3])
    stmt = rc.parse_tin("a(i) = B(i,j) * c(j)",
                        a=Tensor.zeros_dense("a", (3,)), B=B, c=c)
    np.testing.assert_allclose(interpret(stmt), [7.0, 0.0, 18.0])


def test_sddmm_golden():
    # C·D = [[1],[2]] @ [[4, 5]] = [[4  5]
    #                               [8 10]]
    # A = B ⊙ (C·D), B = [[2 0], [0 3]]  ->  [[2*4  0], [0  3*10]]
    B = T("B", [[2, 0], [0, 3]], F.CSR())
    C = T("C", [[1], [2]])
    D = T("D", [[4, 5]])
    A = T("A", [[1, 0], [0, 1]], F.CSR())
    stmt = rc.parse_tin("A(i,j) = B(i,j) * C(i,k) * D(k,j)",
                        A=A, B=B, C=C, D=D)
    np.testing.assert_allclose(interpret(stmt), [[8.0, 0.0], [0.0, 30.0]])


def test_spadd3_golden():
    # [[1 0]    [[0  3]    [[5 0]     [[6 3]
    #  [0 2]] +  [0 -2]] +  [0 0]] =   [0 0]]   <- (1,1) cancels to zero
    B = T("B", [[1, 0], [0, 2]], F.CSR())
    C = T("C", [[0, 3], [0, -2]], F.CSR())
    D = T("D", [[5, 0], [0, 0]], F.CSR())
    A = T("A", [[0, 0], [0, 0]], F.CSR())
    stmt = rc.parse_tin("A(i,j) = B(i,j) + C(i,j) + D(i,j)",
                        A=A, B=B, C=C, D=D)
    np.testing.assert_allclose(interpret(stmt), [[6.0, 3.0], [0.0, 0.0]])


def test_spmm_golden():
    # [[1 2]   [[1 0]   [[1*1+2*3  1*0+2*1]   [[7 2]
    #  [0 3]] @ [3 1]] =  [3*3      3*1    ]] = [9 3]]
    B = T("B", [[1, 2], [0, 3]], F.CSR())
    C = T("C", [[1, 0], [3, 1]])
    stmt = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                        A=Tensor.zeros_dense("A", (2, 2)), B=B, C=C)
    np.testing.assert_allclose(interpret(stmt), [[7.0, 2.0], [9.0, 3.0]])


def test_spmttkrp_golden():
    # B(0,0,0)=1, B(0,1,1)=2;  C=[[1],[2]], D=[[3],[4]]  (L=1)
    # A[0] = 1*C[0]*D[0] + 2*C[1]*D[1] = 1*1*3 + 2*2*4 = 19 ; A[1] = 0
    dB = np.zeros((2, 2, 2), np.float32)
    dB[0, 0, 0] = 1
    dB[0, 1, 1] = 2
    B = T("B", dB, F.CSF(3))
    C = T("C", [[1], [2]])
    D = T("D", [[3], [4]])
    stmt = rc.parse_tin("A(i,l) = B(i,j,k) * C(j,l) * D(k,l)",
                        A=Tensor.zeros_dense("A", (2, 1)), B=B, C=C, D=D)
    np.testing.assert_allclose(interpret(stmt), [[19.0], [0.0]])


def test_interp_empty_golden():
    B = T("B", np.zeros((3, 3)), F.CSR())
    c = T("c", [1, 1, 1])
    stmt = rc.parse_tin("a(i) = B(i,j) * c(j)",
                        a=Tensor.zeros_dense("a", (3,)), B=B, c=c)
    np.testing.assert_allclose(interpret(stmt), np.zeros(3))
