"""Format round-trip property tests (scipy-free, ISSUE 1 satellite).

For every spellable format F and random sparse x:
  * ``from_format(x).to_dense() == x``  (assembly/disassembly inverse)
  * ``to_format`` between any two formats preserves the dense image
including zero-row, zero-column-block, and all-zero edge cases. Runs on the
deterministic hypothesis stub when the real library is absent.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import formats as F
from repro.core.tensor import Tensor

FORMATS_2D = [F.CSR(), F.CSC(), F.DCSR(), F.COO(2), F.BCSR((2, 2)),
              F.BCSR((3, 2)), F.DenseMat()]
FORMATS_3D = [F.CSF(3), F.DCSF(3), F.COO(3)]


def _rand_sparse(seed, n, m, density):
    rng = np.random.default_rng(seed)
    d = ((rng.random((n, m)) < density) *
         rng.standard_normal((n, m))).astype(np.float32)
    if n > 2:
        d[rng.integers(0, n)] = 0          # guaranteed empty row
    return d


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 17),
       m=st.integers(1, 17), density=st.floats(0.0, 0.6))
def test_from_dense_to_dense_roundtrip(seed, n, m, density):
    d = _rand_sparse(seed, n, m, density)
    for fm in FORMATS_2D:
        t = Tensor.from_dense("B", d, fm)
        got = t.to_dense()
        assert got.shape == d.shape, fm
        np.testing.assert_allclose(got, d, err_msg=str(fm))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), density=st.floats(0.0, 0.5))
def test_cross_format_conversion_preserves_dense(seed, density):
    d = _rand_sparse(seed, 11, 8, density)
    tensors = {str(fm): Tensor.from_dense("B", d, fm) for fm in FORMATS_2D}
    for src_name, src in tensors.items():
        for fm in FORMATS_2D:
            conv = src.to_format(fm)
            np.testing.assert_allclose(conv.to_dense(), d,
                                       err_msg=f"{src_name} -> {fm}")
            assert conv.format == fm


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), density=st.floats(0.0, 0.4))
def test_roundtrip_3d(seed, density):
    rng = np.random.default_rng(seed)
    d = ((rng.random((7, 6, 5)) < density) *
         rng.standard_normal((7, 6, 5))).astype(np.float32)
    d[rng.integers(0, 7)] = 0              # empty slice
    for fm in FORMATS_3D:
        t = Tensor.from_dense("T", d, fm)
        np.testing.assert_allclose(t.to_dense(), d, err_msg=str(fm))
        np.testing.assert_allclose(t.to_format(F.CSF(3)).to_dense(), d,
                                   err_msg=f"{fm} -> csf")


@pytest.mark.parametrize("fm", FORMATS_2D, ids=[F.format_key(f)
                                                for f in FORMATS_2D])
def test_all_zero_roundtrip(fm):
    z = np.zeros((6, 5), np.float32)
    t = Tensor.from_dense("Z", z, fm)
    np.testing.assert_allclose(t.to_dense(), z)
    for tgt in FORMATS_2D:
        np.testing.assert_allclose(t.to_format(tgt).to_dense(), z,
                                   err_msg=f"{fm} -> {tgt}")


def test_bcsr_unaligned_shape():
    """Shapes not divisible by the block: boundary blocks pad internally and
    the padding must never leak into the dense image."""
    rng = np.random.default_rng(7)
    d = ((rng.random((7, 5)) < 0.4) *
         rng.standard_normal((7, 5))).astype(np.float32)
    t = Tensor.from_dense("B", d, F.BCSR((3, 4)))
    assert t.to_dense().shape == (7, 5)
    np.testing.assert_allclose(t.to_dense(), d)
    np.testing.assert_allclose(t.to_format(F.CSR()).to_dense(), d)


def test_bcsr_stores_block_padding_zeros():
    """A single non-zero in a 2x2-blocked matrix stores one full block: nnz
    counts stored values (4), while the CSR conversion keeps only the one
    true non-zero."""
    d = np.zeros((4, 4), np.float32)
    d[1, 1] = 5.0
    t = Tensor.from_dense("B", d, F.BCSR((2, 2)))
    assert t.nnz == 4
    csr = t.to_format(F.CSR())
    assert csr.nnz == 1
    np.testing.assert_allclose(csr.to_dense(), d)


def test_dense_block_grid_roundtrip():
    """Blocked format over an all-Dense grid (every block stored): dropped
    zero blocks must stay zero, including under a column-major ordering —
    regression for the from_coo-skeleton shortcut corrupting them."""
    arr = np.arange(16, dtype=np.float32).reshape(4, 4)
    arr[:2, :2] = 0
    t = Tensor.from_dense(
        "B", arr, F.Format((F.Dense, F.Dense), block_shape=(2, 2)))
    np.testing.assert_allclose(t.to_dense(), arr)
    arr2 = np.arange(35, dtype=np.float32).reshape(7, 5)
    t2 = Tensor.from_dense(
        "B", arr2, F.Format((F.Dense, F.Dense), mode_ordering=(1, 0),
                            block_shape=(2, 3)))
    np.testing.assert_allclose(t2.to_dense(), arr2)


def test_format_keys_are_stable():
    """Cell IDs are a versioned artifact — renaming a key silently renames
    every conformance cell, so pin them."""
    assert F.format_key(F.CSR()) == "csr"
    assert F.format_key(F.CSC()) == "csc"
    assert F.format_key(F.DCSR()) == "dcsr"
    assert F.format_key(F.COO(2)) == "coo"
    assert F.format_key(F.BCSR((2, 2))) == "bcsr"
    assert F.format_key(F.CSF(3)) == "csf"
    assert F.format_key(F.DCSF(3)) == "dcsf"
    assert F.format_key(F.COO(3)) == "coo3"
