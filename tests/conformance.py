"""Differential conformance matrix: expression × format × strategy × mesh.

SpDISTAL's thesis is that tensor algebra expressions, sparse formats, and
distribution strategies compose independently (paper §I). This harness makes
that claim machine-checkable: it enumerates the full cross-product grid and
differentially verifies every compiled cell against the CTF-style
interpreter oracle (`core.interp.interpret`), which is itself pinned by
hand-computed goldens in test_interp_golden.py.

Cell IDs read ``<expression>/<format>/<strategy>/<mesh>``:

    spmm/dcsr/nnz/4x1  =  SpMM, sparse operand stored DCSR, non-zero
                          (coordinate-position) distribution, 4-piece 1-D
                          machine.

Every cell must lower DIRECTLY: the kernel family iterates the declared
format in place through its level-iterator walk (core/levels.py — row
windows, position splits, the transpose walk for column-major roots, the
trailing-singleton walk for COO trees). The logged format-conversion
fallback (``LoweredKernel.fallbacks``) still exists for formats outside
the matrix (e.g. compressed-root block grids), but since the
level-iterator refactor the census is fully direct and pinned that way —
a cell silently flipping to fallback is a regression. The census is
printed in the pytest terminal summary (see conftest.py).

Adding a row/column to the matrix:
  * new expression — add a builder to ``_build_stmt`` + an entry in
    ``EXPRESSIONS_2D`` / ``EXPRESSIONS_3D`` (and a leaf emitter pair in
    core/lower.py if it should lower directly);
  * new format — add its constructor to ``FORMATS_2D`` / ``FORMATS_3D``;
    give it a short name in ``formats._KEY_TABLE`` and, if a kernel family
    can iterate it directly, teach that family's ``supports()``.

Sparsity patterns are randomized per cell (seeded by the cell ID) and always
include empty rows and a dense (skewed) row; COO inputs are duplicate-free
by construction (``Tensor.from_dense`` dedupes). All-zero operands get their
own cells below.
"""
import logging
import zlib

import numpy as np
import pytest

import repro.core as rc
from repro.core import formats as F
from repro.core.interp import interpret
from repro.core.lower import (default_grid3_schedule,
                              default_grid_nnz_schedule,
                              default_grid_schedule, default_nnz_schedule,
                              default_replicated_schedule,
                              default_row_schedule, lower)
from repro.core.tensor import Tensor
from repro.runtime import telemetry

# cell_id -> {"status": "direct"|"fallback", "fallbacks": [...]}
CENSUS = {}

FORMATS_2D = [
    ("csr", F.CSR),
    ("csc", F.CSC),
    ("dcsr", F.DCSR),
    ("coo", lambda: F.COO(2)),
    ("bcsr", lambda: F.BCSR((2, 2))),
    ("bcsc", lambda: F.BCSC((2, 2))),
]
FORMATS_3D = [
    ("csf", lambda: F.CSF(3)),
    ("dcsf", lambda: F.DCSF(3)),
    ("coo3", lambda: F.COO(3)),
]
EXPRESSIONS_2D = ["spmv", "spmm", "sddmm", "spadd3"]
EXPRESSIONS_3D = ["spmttkrp"]
STRATEGIES = ["rows", "nnz"]
PIECES = [2, 4]

# 2-D machine-grid cells (the multi-axis distribution subsystem,
# core/grid.py): rows = SUMMA-style row×col tiles with per-axis
# communication, nnz = nested pos-split (flat P*Q chunks). Only the
# grid-distributable expressions join this column; since the
# level-iterator refactor the grid materializers walk column-major roots
# too (the row walk re-sorts each tile's entries), so csc/bcsc are in.
GRID_EXPRESSIONS = ["spmv", "spmm", "sddmm"]
GRID_FORMATS = [("csr", F.CSR), ("csc", F.CSC),
                ("bcsr", lambda: F.BCSR((2, 2))),
                ("bcsc", lambda: F.BCSC((2, 2)))]
GRID_MESHES = [(2, 2), (4, 2)]

# Order-3 machine-grid cells (ISSUE 7): spmttkrp on P×Q×R COO bricks
# (rows) / the flat nested pos-split (nnz); spadd3 rows rides the nested
# column split (one variable divided onto y AND z). The replicated cells
# are the communication-avoiding 2.5-D schedules — spmm/sddmm with the
# sparse operand's tiles shared across z.
GRID3_MESHES = [(2, 2, 2), (2, 1, 2)]
GRID3_SPADD3_FORMATS = [("csr", F.CSR), ("csc", F.CSC)]
REPLICATED_EXPRESSIONS = ["spmm", "sddmm"]
REPLICATED_FORMATS = [("csr", F.CSR), ("csc", F.CSC)]


def _sparse_2d(rng, n, m, density=0.25):
    d = ((rng.random((n, m)) < density) *
         rng.standard_normal((n, m))).astype(np.float32)
    d[rng.integers(0, n)] = 0                                   # empty row
    d[rng.integers(0, n)] = rng.standard_normal(m).astype(np.float32)  # skew
    return d


def _build_stmt(expr, fm, rng, empty=False):
    """TIN statement + dense-oracle closure for one matrix cell."""
    if expr in EXPRESSIONS_2D:
        n, m, K = 19, 13, 5
        dB = np.zeros((n, m), np.float32) if empty else _sparse_2d(rng, n, m)
        B = Tensor.from_dense("B", dB, fm)
        if expr == "spmv":
            c = Tensor.from_dense(
                "c", rng.standard_normal(m).astype(np.float32))
            return rc.parse_tin("a(i) = B(i,j) * c(j)",
                                a=Tensor.zeros_dense("a", (n,)), B=B, c=c)
        if expr == "spmm":
            C = Tensor.from_dense(
                "C", rng.standard_normal((m, 7)).astype(np.float32))
            return rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                                A=Tensor.zeros_dense("A", (n, 7)), B=B, C=C)
        if expr == "sddmm":
            C = Tensor.from_dense(
                "C", rng.standard_normal((n, K)).astype(np.float32))
            D = Tensor.from_dense(
                "D", rng.standard_normal((K, m)).astype(np.float32))
            A = Tensor.from_dense("A", (dB != 0) * 1.0, F.CSR())
            return rc.parse_tin("A(i,j) = B(i,j) * C(i,k) * D(k,j)",
                                A=A, B=B, C=C, D=D)
        if expr == "spadd3":
            d2 = (np.zeros((n, m), np.float32) if empty
                  else _sparse_2d(rng, n, m, 0.15))
            d3 = (np.zeros((n, m), np.float32) if empty
                  else _sparse_2d(rng, n, m, 0.1))
            return rc.parse_tin(
                "A(i,j) = B(i,j) + C(i,j) + D(i,j)",
                A=Tensor.from_dense("A", np.zeros((n, m), np.float32),
                                    F.CSR()),
                B=B, C=Tensor.from_dense("C", d2, fm),
                D=Tensor.from_dense("D", d3, fm))
    if expr == "spmttkrp":
        dims, L = (16, 9, 7), 4
        dB3 = np.zeros(dims, np.float32)
        if not empty:
            dB3 = ((rng.random(dims) < 0.12) *
                   rng.standard_normal(dims)).astype(np.float32)
            dB3[rng.integers(0, dims[0])] = 0                   # empty slice
        B = Tensor.from_dense("B", dB3, fm)
        C = Tensor.from_dense(
            "C", rng.standard_normal((dims[1], L)).astype(np.float32))
        D = Tensor.from_dense(
            "D", rng.standard_normal((dims[2], L)).astype(np.float32))
        return rc.parse_tin("A(i,l) = B(i,j,k) * C(j,l) * D(k,l)",
                            A=Tensor.zeros_dense("A", (dims[0], L)), B=B,
                            C=C, D=D)
    raise KeyError(expr)


def _check_cell(expr, fmt_name, fmt_ctor, strategy, pieces, empty=False,
                caplog=None, mesh=None, replicated=False):
    # deterministic per-cell seed (str hash is process-randomized);
    # ``mesh=(P, Q)`` / ``(P, Q, R)`` selects a machine grid + the grid
    # schedules; ``replicated`` the 2.5-D schedule (sparse operand
    # replicated along z)
    mesh_tag = pieces if mesh is None else \
        "x".join(str(s) for s in mesh) + ("r" if replicated else "")
    cell_tag = f"{expr}/{fmt_name}/{strategy}/{mesh_tag}/{empty}"
    rng = np.random.default_rng(zlib.crc32(cell_tag.encode()))
    stmt = _build_stmt(expr, fmt_ctor(), rng, empty=empty)
    if mesh is not None:
        names = ("x", "y", "z")
        machine = rc.Machine(*[(names[i], s) for i, s in enumerate(mesh)])
        if replicated:
            sched = default_replicated_schedule(stmt, machine)
        elif strategy == "nnz":
            sched = default_grid_nnz_schedule(stmt, machine)
        elif len(mesh) > 2:
            sched = default_grid3_schedule(stmt, machine)
        else:
            sched = default_grid_schedule(stmt, machine)
    else:
        machine = rc.Machine(("x", pieces))
        sched = (default_row_schedule(stmt, machine) if strategy == "rows"
                 else default_nnz_schedule(stmt, machine))
    with caplog.at_level(logging.WARNING, logger="repro.core.lower"):
        kernel = lower(stmt, machine, schedule=sched)
    result = kernel.run()
    got = result.to_dense() if isinstance(result, Tensor) else result
    expected = interpret(stmt)     # the oracle (pinned by golden tests)
    np.testing.assert_allclose(got, expected, atol=1e-3,
                               err_msg=f"cell {kernel.cell_id()}")
    # byte-ledger verification (telemetry): the statement-level model must
    # reproduce the CommStats ledger the lowering recorded, per axis.
    telemetry.verify_byte_ledger(kernel)
    # census + contract: a fallback cell must have logged its conversion.
    # Empty-operand cells are distinct matrix entries, not re-checks.
    cid = kernel.cell_id() + ("~empty" if empty else "")
    status = "fallback" if kernel.fallbacks else "direct"
    CENSUS[cid] = {"status": status, "fallbacks": list(kernel.fallbacks)}
    if kernel.fallbacks:
        assert any("converting to" in r.message for r in caplog.records), \
            f"cell {cid} fell back without logging the conversion"
    else:
        # A direct cell performs ZERO format conversions — the whole point
        # of the level-iterator walks is that the convert cache stays quiet
        # once every spellable format lowers in place.
        assert kernel.cache.convert_hits == 0, \
            f"direct cell {cid} served a cached conversion"
        assert kernel.cache.convert_misses == 0, \
            f"direct cell {cid} converted an operand"
    return kernel


@pytest.mark.conformance
@pytest.mark.parametrize("pieces", PIECES)
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("fmt_name,fmt_ctor", FORMATS_2D,
                         ids=[f[0] for f in FORMATS_2D])
@pytest.mark.parametrize("expr", EXPRESSIONS_2D)
def test_matrix_2d(expr, fmt_name, fmt_ctor, strategy, pieces, caplog):
    _check_cell(expr, fmt_name, fmt_ctor, strategy, pieces, caplog=caplog)


@pytest.mark.conformance
@pytest.mark.parametrize("pieces", PIECES)
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("fmt_name,fmt_ctor", FORMATS_3D,
                         ids=[f[0] for f in FORMATS_3D])
@pytest.mark.parametrize("expr", EXPRESSIONS_3D)
def test_matrix_3d(expr, fmt_name, fmt_ctor, strategy, pieces, caplog):
    _check_cell(expr, fmt_name, fmt_ctor, strategy, pieces, caplog=caplog)


@pytest.mark.conformance
@pytest.mark.parametrize("mesh", GRID_MESHES,
                         ids=[f"{p}x{q}" for p, q in GRID_MESHES])
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("fmt_name,fmt_ctor", GRID_FORMATS,
                         ids=[f[0] for f in GRID_FORMATS])
@pytest.mark.parametrize("expr", GRID_EXPRESSIONS)
def test_matrix_grid(expr, fmt_name, fmt_ctor, strategy, mesh, caplog):
    """Multi-axis cells: every {spmv, spmm, sddmm} × {csr, bcsr} ×
    {rows, nnz} cell on a genuine 2-D machine grid must lower DIRECT (no
    logged conversion) and match the interpreter oracle."""
    k = _check_cell(expr, fmt_name, fmt_ctor, strategy, mesh[0] * mesh[1],
                    caplog=caplog, mesh=mesh)
    assert k.fallbacks == [], f"grid cell {k.cell_id()} fell back"
    assert k.strategy.is_grid and k.strategy.grid_shape == mesh
    if strategy == "rows":
        # per-axis communication attribution is the point of the grid
        # subsystem: payload must live in the axes ledger, not the flat
        # replicate/reduce fields
        assert set(k.comm.axes) == {"x", "y"}
        assert k.comm.replicate_bytes == 0 and k.comm.reduce_bytes == 0


@pytest.mark.conformance
@pytest.mark.parametrize("mesh", GRID3_MESHES,
                         ids=["x".join(str(s) for s in m)
                              for m in GRID3_MESHES])
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("fmt_name,fmt_ctor", FORMATS_3D,
                         ids=[f[0] for f in FORMATS_3D])
def test_matrix_grid3(fmt_name, fmt_ctor, strategy, mesh, caplog):
    """Order-3 grid cells: spmttkrp over P×Q×R COO bricks (rows) and the
    flat nested pos-split (nnz) on a genuine 3-D machine grid — direct,
    oracle-checked, with the comm ledger attributed to all three axes."""
    pieces = mesh[0] * mesh[1] * mesh[2]
    k = _check_cell("spmttkrp", fmt_name, fmt_ctor, strategy, pieces,
                    caplog=caplog, mesh=mesh)
    assert k.fallbacks == [], f"grid3 cell {k.cell_id()} fell back"
    assert k.strategy.is_grid and k.strategy.grid_shape == mesh
    if strategy == "rows":
        assert set(k.comm.axes) == {"x", "y", "z"}
        assert k.comm.replicate_bytes == 0 and k.comm.reduce_bytes == 0


@pytest.mark.conformance
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("fmt_name,fmt_ctor", GRID3_SPADD3_FORMATS,
                         ids=[f[0] for f in GRID3_SPADD3_FORMATS])
def test_matrix_spadd3_grid3(fmt_name, fmt_ctor, strategy, caplog):
    """spadd3 on a 2×2×2 grid: rows rides the NESTED column split (the
    column variable divided onto y and z → Q·R joint windows, zero
    communication), nnz the flat 8-piece chunk union."""
    k = _check_cell("spadd3", fmt_name, fmt_ctor, strategy, 8,
                    caplog=caplog, mesh=(2, 2, 2))
    assert k.fallbacks == [], f"spadd3 grid3 cell {k.cell_id()} fell back"
    if strategy == "rows":
        assert sum(a.network_bytes() for a in k.comm.axes.values()) == 0


@pytest.mark.conformance
@pytest.mark.parametrize("fmt_name,fmt_ctor", REPLICATED_FORMATS,
                         ids=[f[0] for f in REPLICATED_FORMATS])
@pytest.mark.parametrize("expr", REPLICATED_EXPRESSIONS)
def test_matrix_replicated(expr, fmt_name, fmt_ctor, caplog):
    """2.5-D communication-avoiding cells: the sparse operand keeps its
    (P, Q) tiles and is replicated along z, which splits the loop
    variable outside its index set — z pays the replica broadcast and
    the reduction rides ONLY the axes replication leaves (y for spmm's
    SUMMA partials, z itself for sddmm's split contraction)."""
    k = _check_cell(expr, fmt_name, fmt_ctor, "rows", 8, caplog=caplog,
                    mesh=(2, 2, 2), replicated=True)
    assert k.fallbacks == [], f"replicated cell {k.cell_id()} fell back"
    assert k.strategy.mesh_label == "2x2x2r"
    assert set(k.comm.axes) == {"x", "y", "z"}
    assert k.comm.axes["z"].broadcast_bytes > 0
    if expr == "spmm":
        assert k.comm.axes["z"].reduce_bytes == 0
        assert k.comm.axes["y"].reduce_bytes > 0
    else:
        assert k.comm.axes["z"].reduce_bytes > 0
        assert k.comm.axes["y"].reduce_bytes == 0


@pytest.mark.conformance
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("fmt_name,fmt_ctor", FORMATS_2D,
                         ids=[f[0] for f in FORMATS_2D])
def test_matrix_empty_operands(fmt_name, fmt_ctor, strategy, caplog):
    """All-zero sparse operands across every format × strategy (the empty
    coordinate tree is the classic assembly edge case)."""
    _check_cell("spmv", fmt_name, fmt_ctor, strategy, 4, empty=True,
                caplog=caplog)


# -- smoke subset (unmarked): one direct + one fallback cell per strategy,
#    cheap enough for every push --------------------------------------------

@pytest.mark.parametrize("expr,fmt_name,strategy", [
    ("spmv", "csr", "rows"),
    ("spmm", "dcsr", "nnz"),
    ("sddmm", "csc", "nnz"),
    ("spadd3", "coo", "rows"),
    ("spmv", "bcsr", "nnz"),       # exercises the direct blocked path
    ("spmv", "csc", "rows"),       # exercises the transpose-walk path
    ("spmv", "bcsc", "rows"),      # exercises the blocked transpose walk
])
def test_matrix_smoke(expr, fmt_name, strategy, caplog):
    ctor = dict(FORMATS_2D)[fmt_name]
    _check_cell(expr, fmt_name, ctor, strategy, 2, caplog=caplog)


def test_direct_cells_do_not_convert(caplog):
    """No spellable format silently round-trips through its row-major
    sibling — the level-iterator walks are the point of the format
    abstraction: densified row windows (dcsr), position splits (coo), the
    transpose walk (csc, bcsc) and the trailing-singleton walk (coo3) all
    iterate the declared storage in place."""
    k = _check_cell("spmm", "dcsr", F.DCSR, "rows", 4, caplog=caplog)
    assert k.fallbacks == []
    k = _check_cell("spmv", "coo", lambda: F.COO(2), "nnz", 4, caplog=caplog)
    assert k.fallbacks == []
    k = _check_cell("spmm", "csc", F.CSC, "rows", 4, caplog=caplog)
    assert k.fallbacks == []
    k = _check_cell("sddmm", "bcsc", lambda: F.BCSC((2, 2)), "rows", 4,
                    caplog=caplog)
    assert k.fallbacks == []
    k = _check_cell("spmttkrp", "coo3", lambda: F.COO(3), "rows", 4,
                    caplog=caplog)
    assert k.fallbacks == []


# The versioned direct/fallback contract: which formats each strategy must
# iterate IN PLACE. A cell silently flipping from direct to fallback (or
# back) fails test_census_matches_contract below — update this table
# deliberately when adding a direct kernel (and prune the matching ROADMAP
# open item).
DIRECT_CONTRACT = {
    ("2d", "rows"): {"csr", "csc", "dcsr", "coo", "bcsr", "bcsc"},
    ("2d", "nnz"): {"csr", "csc", "dcsr", "coo", "bcsr", "bcsc"},
    ("3d", "rows"): {"csf", "dcsf", "coo3"},
    ("3d", "nnz"): {"csf", "dcsf", "coo3"},
}
_FMT_RANK = {f[0]: "2d" for f in FORMATS_2D}
_FMT_RANK.update({f[0]: "3d" for f in FORMATS_3D})


def test_census_matches_contract():
    """Every cell recorded so far must have the status the contract table
    predicts (runs after the matrix tests in file order; under -k subsets
    it checks whatever cells did run)."""
    for cid, entry in CENSUS.items():
        _, fmt_name, strategy, _ = cid.split("/")
        expected = ("direct" if fmt_name in
                    DIRECT_CONTRACT[(_FMT_RANK[fmt_name], strategy)]
                    else "fallback")
        assert entry["status"] == expected, \
            f"cell {cid}: {entry['status']}, contract says {expected}"


# Full-matrix totals, pinned so the cached lowering path (plan memo + shard
# cache + runner reuse, ISSUE 3) cannot silently flip a cell's status: when
# the whole matrix ran, the census must be exactly this. ISSUE 4 added the
# multi-axis (2x2 / 4x2 grid) cells; ISSUE 5's level-iterator walks made
# the last 11 fallback cells (csc/rows, spmttkrp/coo3/rows) direct and
# added the bcsc cells plus csc/bcsc grid columns; ISSUE 7 added the
# order-3 grid cells (spmttkrp bricks, spadd3 nested columns) and the
# replicated 2.5-D spmm/sddmm cells — the census stays fully direct:
# 96 2-D + 12 3-D + 48 grid + 12 3-D-grid + 4 spadd3-grid3 +
# 4 replicated + 12 empty-operand cells.
FULL_CENSUS_TOTALS = {"direct": 188, "fallback": 0}
_FULL_CELL_COUNT = 188


def test_census_totals_with_caching():
    if len(CENSUS) < _FULL_CELL_COUNT:
        pytest.skip("full matrix did not run (-k/-m subset)")
    counts = {"direct": 0, "fallback": 0}
    for entry in CENSUS.values():
        counts[entry["status"]] += 1
    assert counts == FULL_CENSUS_TOTALS, counts
    assert not any(v["fallbacks"] for v in CENSUS.values()), \
        "fully-direct matrix must perform zero conversions"
