"""Telemetry subsystem (ISSUE 9): hierarchical span tracer + Chrome
trace export, metrics registry snapshot, byte-ledger verification,
per-piece kernel profiling -> weighted re-plan, explain() provenance,
and the span-derived RecoveryReport time-split invariant (the
double-count bugfix regression)."""
import logging
import threading
import time

import numpy as np
import pytest

import repro.core as rc
from repro.core import formats as F
from repro.core.interp import interpret
from repro.core.lower import (clear_lowering_caches, default_grid_schedule,
                              default_nnz_schedule, default_row_schedule,
                              lower, relower)
from repro.core.tensor import Tensor
from repro.distributed.executor import profile_pieces
from repro.launch.report import telemetry_table
from repro.runtime import telemetry
from repro.runtime.elastic import run_with_recovery
from repro.runtime.fault import FaultEvent, FaultInjector, StragglerMitigator

M4 = rc.Machine(("x", 4))
M22 = rc.Machine(("x", 2), ("y", 2))


def _sparse(rng, n, m, density=0.25, ints=False):
    mask = rng.random((n, m)) < density
    v = (rng.integers(-3, 4, (n, m)).astype(np.float32) if ints
         else rng.standard_normal((n, m)).astype(np.float32))
    d = (mask * v).astype(np.float32)
    d[rng.integers(0, n)] = 0                                   # empty row
    return d


def _spmv(fm=None, n=19, m=13, seed=1):
    fm = fm if fm is not None else F.CSR()
    rng = np.random.default_rng(seed)
    B = Tensor.from_dense("B", _sparse(rng, n, m), fm)
    c = Tensor.from_dense("c", rng.standard_normal(m).astype(np.float32))
    return rc.parse_tin("a(i) = B(i,j) * c(j)",
                        a=Tensor.zeros_dense("a", (n,)), B=B, c=c)


def _spmm(n=48, m=40, j=8, seed=2, fm=None):
    rng = np.random.default_rng(seed)
    B = Tensor.from_dense("B", _sparse(rng, n, m),
                          fm if fm is not None else F.CSR())
    C = Tensor.from_dense("C", rng.standard_normal((m, j)).astype(np.float32))
    return rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                        A=Tensor.zeros_dense("A", (n, j)), B=B, C=C)


# ---------------------------------------------------------------------------
# Tracer core: nesting, threads, Chrome export round-trip
# ---------------------------------------------------------------------------

def test_span_nesting_and_chrome_roundtrip(tmp_path):
    tr = telemetry.Tracer(enabled=True)
    with tr.span("outer", who="test"):
        with tr.span("inner.a", k=1):
            pass
        with tr.span("inner.b"):
            with tr.span("leaf"):
                pass
        tr.instant("tick", n=7)

    def worker():
        with tr.span("thread.root"):
            pass

    t = threading.Thread(target=worker)
    t.start()
    t.join()

    path = str(tmp_path / "trace.json")
    assert tr.export_chrome(path) == path
    counts = telemetry.validate_chrome_trace(
        path, require=("outer", "inner.a", "inner.b", "leaf",
                       "tick", "thread.root"))
    assert counts["outer"] == 1 and counts["tick"] == 1

    # call_tree reconstructs the nesting from recorded parent ids
    roots = tr.call_tree()
    names = {r["name"] for r in roots}
    assert names == {"outer", "thread.root"}    # thread gets its own stack
    outer = next(r for r in roots if r["name"] == "outer")
    assert {c["name"] for c in outer["children"]} == {"inner.a", "inner.b"}
    inner_b = next(c for c in outer["children"] if c["name"] == "inner.b")
    assert [c["name"] for c in inner_b["children"]] == ["leaf"]
    assert outer["args"] == {"who": "test"}
    # parent spans strictly contain their children in time
    assert outer["dur_us"] >= inner_b["dur_us"] >= inner_b["children"][0][
        "dur_us"]


def test_disabled_tracer_is_noop_and_cheap():
    tr = telemetry.Tracer(enabled=False)
    with tr.span("never", big=list(range(100))) as sp:
        sp.set(late=1)
    tr.instant("never.i")
    assert tr.spans() == []
    # the disabled path hands back one shared null object — no allocation
    assert tr.span("a") is tr.span("b")

    n = 20000
    t0 = time.perf_counter()
    for _ in range(n):
        with tr.span("x", a=1):
            pass
    unit = (time.perf_counter() - t0) / n
    assert unit < 20e-6          # generous bound; typically well under 2us


def test_disabled_tracer_no_measurable_warm_relower_overhead():
    """Acceptance: with the global tracer disabled, the instrumentation
    cost of a warm re-lower is bounded by (spans it WOULD record) x (null
    span unit cost) — and that bound is a small fraction of the re-lower
    wall time itself."""
    stmt = _spmv()
    clear_lowering_caches()
    assert not telemetry.TRACER.enabled
    lower(stmt, M4)                                   # cold: fill caches

    t0 = time.perf_counter()
    k = lower(stmt, M4)                               # warm re-lower
    warm_s = time.perf_counter() - t0
    assert k.cache.warm

    telemetry.TRACER.clear()
    telemetry.TRACER.enable()
    try:
        lower(stmt, M4)
        n_events = len(telemetry.TRACER.spans())
    finally:
        telemetry.TRACER.disable()
        telemetry.TRACER.clear()
    assert n_events > 0

    tr = telemetry.Tracer(enabled=False)
    reps = 5000
    t0 = time.perf_counter()
    for _ in range(reps):
        with tr.span("x", a=1):
            pass
    unit = (time.perf_counter() - t0) / reps
    # every span site costs `unit` when disabled; total ≪ warm lower time
    assert n_events * unit < max(warm_s, 1e-4) * 0.05


# ---------------------------------------------------------------------------
# Pipeline instrumentation: traced grid lower+execute (the CI smoke body)
# ---------------------------------------------------------------------------

def test_smoke_trace_grid_spmm(tmp_path):
    path = str(tmp_path / "TRACE_smoke.json")
    counts = telemetry.smoke_trace(path, n=128, m=128, j=8)
    # smoke_trace already validates; pin the span taxonomy here too
    for name in ("lower", "lower.plan", "lower.materialize", "lower.jit",
                 "lower.emit", "execute", "execute.piece"):
        assert counts.get(name, 0) >= 1, f"missing span {name}"
    assert counts["execute.piece"] >= 4          # 2x2 grid -> >=4 pieces
    # the global tracer still holds the events (disabled, not cleared):
    # lowering spans must be nested under the top-level "lower" span
    roots = telemetry.TRACER.call_tree()
    lower_roots = [r for r in roots if r["name"] == "lower"]
    assert lower_roots
    kids = {c["name"] for r in lower_roots for c in r["children"]}
    assert {"lower.plan", "lower.materialize", "lower.emit"} <= kids
    telemetry.TRACER.clear()


# ---------------------------------------------------------------------------
# explain(): plan provenance with scored candidates
# ---------------------------------------------------------------------------

def test_explain_lists_scored_candidates():
    stmt = _spmv()
    clear_lowering_caches()
    k = lower(stmt, M4, schedule="auto")
    assert k.tuned is not None and k.tuned.candidates
    assert len(k.tuned.candidates) >= 2
    txt = k.explain()
    assert "autoscheduler winner" in txt and "<- winner" in txt
    for c in k.tuned.candidates:
        assert c["label"] in txt
    # hand-picked schedules say so instead of inventing candidates
    k2 = lower(stmt, M4, schedule=default_row_schedule(stmt, M4))
    assert "hand-picked schedule" in k2.explain()
    assert "comm:" in k2.explain()


# ---------------------------------------------------------------------------
# Byte-ledger verification: model vs recorded CommStats
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mk, sched", [
    (lambda: _spmv(F.CSR()), default_row_schedule),       # 1-D rows
    (lambda: _spmv(F.CSC()), default_nnz_schedule),       # output-replicated
    (lambda: _spmm(), default_nnz_schedule),              # 1-D nnz
    (lambda: _spmm(), default_grid_schedule),             # grid universe
], ids=["rows", "csc-nnz", "nnz", "grid"])
def test_byte_ledger_agrees(mk, sched):
    stmt = mk()
    machine = M22 if sched is default_grid_schedule else M4
    clear_lowering_caches()
    k = lower(stmt, machine, schedule=sched(stmt, machine))
    rep = telemetry.verify_byte_ledger(k)
    assert rep["ok"] and rep["checks"]
    np.testing.assert_allclose(k.run(), interpret(stmt), atol=1e-3)


def test_byte_ledger_spadd3_nnz():
    n, m = 24, 20

    def mk(name, seed):
        return Tensor.from_dense(
            name, _sparse(np.random.default_rng(seed), n, m), F.CSR())

    stmt = rc.parse_tin(
        "A(i,j) = B(i,j) + C(i,j) + D(i,j)",
        A=Tensor.zeros_dense("A", (n, m)),
        B=mk("B", 1), C=mk("C", 2), D=mk("D", 3))
    clear_lowering_caches()
    k = lower(stmt, M4, schedule=default_nnz_schedule(stmt, M4))
    rep = telemetry.verify_byte_ledger(k)
    assert rep["ok"]


def test_byte_ledger_catches_tampering():
    stmt = _spmv()
    clear_lowering_caches()
    k = lower(stmt, M4, schedule=default_row_schedule(stmt, M4))
    telemetry.verify_byte_ledger(k)
    k.comm.replicate_bytes += 1
    with pytest.raises(AssertionError, match="byte-ledger mismatch"):
        telemetry.verify_byte_ledger(k)


# ---------------------------------------------------------------------------
# Per-piece kernel profiling -> skew -> weighted re-plan
# ---------------------------------------------------------------------------

def test_profile_pieces_feeds_weighted_replan():
    stmt = _spmm()
    clear_lowering_caches()
    telemetry.METRICS.clear()
    k = lower(stmt, M4, schedule=default_nnz_schedule(stmt, M4))
    ref = np.asarray(k.run())
    prof = profile_pieces(k, iters=2, warmup=1)
    assert prof.leaf_name == k.leaf_name
    assert prof.seconds.shape == (k.strategy.pieces,)
    assert np.all(prof.seconds > 0) and prof.skew() >= 1.0
    w = prof.replan_weights()
    assert w.shape == prof.seconds.shape
    assert abs(w.mean() - 1.0) < 1e-6        # StragglerMitigator convention
    # slower piece -> smaller weight (fewer non-zeros next plan)
    assert np.argmin(w) == np.argmax(prof.seconds)
    k2 = relower(k, M4, weights=w)
    np.testing.assert_allclose(np.asarray(k2.run()), ref, atol=1e-4)
    snap = telemetry.METRICS.snapshot()
    h = snap["histograms"]["executor.piece_seconds"]
    assert h["count"] == k.strategy.pieces       # one best-of obs per piece
    assert snap["gauges"]["executor.piece_skew"] == pytest.approx(
        prof.skew())


def test_profile_pieces_grid_leaf():
    stmt = _spmm()
    clear_lowering_caches()
    k = lower(stmt, M22, schedule=default_grid_schedule(stmt, M22))
    prof = profile_pieces(k, iters=1, warmup=1)
    assert prof.seconds.shape == (k.strategy.pieces,)
    assert not prof.stragglers(threshold=1e9)


# ---------------------------------------------------------------------------
# Metrics registry, snapshot render, logging namespaces
# ---------------------------------------------------------------------------

def test_metrics_snapshot_and_render():
    stmt = _spmv()
    clear_lowering_caches()
    telemetry.METRICS.clear()
    lower(stmt, M4)
    lower(stmt, M4)                          # warm
    snap = telemetry.METRICS.snapshot()
    assert snap["counters"]["lower.count"] == 2
    assert snap["counters"]["lower.warm_count"] >= 1
    assert snap["counters"]["comm.network_bytes"] > 0
    assert snap["caches"]["plan"]["hits"] >= 1
    md = telemetry_table(snap)
    assert "### Caches" in md and "lower.count" in md
    assert telemetry_table({}) == "(empty telemetry snapshot)"
    telemetry.METRICS.clear()
    assert telemetry.METRICS.snapshot()["counters"] == {}


def test_logger_namespaces_and_configure_logging():
    import repro.core.lower as L
    import repro.core.plan_search as PS
    assert L.log.name == "repro.core.lower"        # was "repro.lower"
    assert PS.log.name == "repro.core.plan_search"
    root = telemetry.configure_logging(logging.DEBUG)
    assert root.name == "repro" and root.level == logging.DEBUG
    assert root.handlers
    # idempotent: a second call must not stack handlers
    n = len(root.handlers)
    telemetry.configure_logging(logging.INFO)
    assert len(root.handlers) == n


# ---------------------------------------------------------------------------
# Recovery: span-derived report — splits sum exactly to recovery_s
# (regression for the straggler+device-loss double-count bug)
# ---------------------------------------------------------------------------

def test_recovery_report_splits_sum_exactly(tmp_path_factory):
    rng = np.random.default_rng(9)
    dB = _sparse(rng, 48, 40, ints=True)
    dC = rng.integers(-3, 4, (40, 8)).astype(np.float32)

    def mkstmt():
        B = Tensor.from_dense("B", dB.copy(), F.CSR())
        C = Tensor.from_dense("C", dC.copy())
        return rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                            A=Tensor.zeros_dense("A", (48, 8)), B=B, C=C)

    s0 = mkstmt()
    clear_lowering_caches()
    ref, _ = run_with_recovery(s0, M4, 8,
                               ckpt_dir=str(tmp_path_factory.mktemp("r")),
                               schedule=default_nnz_schedule(s0, M4))

    # straggler re-plans AND a device loss in ONE run: the old hand-timed
    # report double-counted the straggler re-plan that landed in the same
    # recovery window as the device-loss re-plan.
    clear_lowering_caches()
    s1 = mkstmt()
    inj = FaultInjector(
        [FaultEvent(step=s, kind="straggler", piece=2, slowdown_s=0.05)
         for s in (2, 3, 4)]
        + [FaultEvent(step=6, kind="device_loss", piece=1)])
    mit = StragglerMitigator(4, report_budget=2)
    state, rep = run_with_recovery(
        s1, M4, 8, ckpt_dir=str(tmp_path_factory.mktemp("f")),
        schedule=default_nnz_schedule(s1, M4), injector=inj, mitigator=mit)

    assert np.array_equal(state, ref)
    assert rep.replans >= 1 and rep.restarts == 1
    assert rep.recovery_s > 0
    split_sum = rep.restore_s + rep.replan_s + rep.rejit_s
    assert abs(split_sum - rep.recovery_s) < 1e-9   # phases never nest
    # every phase that must have fired shows up in its own bucket
    assert rep.restore_s > 0 and rep.replan_s > 0 and rep.rejit_s > 0
