"""Pallas flash-attention kernel vs the jnp attention oracle (causal GQA),
swept over shapes, head/group counts, block sizes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention


def oracle(q, k, v):
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    qg = q.reshape(B, S, Hkv, H // Hkv, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) / hd ** 0.5
    mask = np.tril(np.ones((S, S), bool))
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, -1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return o.reshape(B, S, H, hd)


CASES = [
    # B, S, H, Hkv, hd, bq, bk
    (2, 256, 4, 2, 32, 128, 128),
    (1, 200, 8, 8, 16, 64, 128),       # MHA + ragged S (padding path)
    (2, 384, 6, 2, 64, 128, 64),       # G=3, uneven blocks
    (1, 128, 16, 2, 32, 64, 64),       # G=8 (starcoder2-like ratio)
]


@pytest.mark.parametrize("case", CASES, ids=[f"S{c[1]}H{c[2]}k{c[3]}"
                                             for c in CASES])
def test_flash_matches_oracle_f32(case):
    B, S, H, Hkv, hd, bq, bk = case
    ks = jax.random.split(jax.random.PRNGKey(sum(case)), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    got = flash_attention(q, k, v, block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle(q, k, v)),
                               atol=2e-5, rtol=2e-5)


def test_flash_bf16():
    B, S, H, Hkv, hd = 2, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.bfloat16)
    got = flash_attention(q, k, v)
    exp = oracle(q.astype(jnp.float32), k.astype(jnp.float32),
                 v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(exp),
                               atol=3e-2, rtol=3e-2)


def test_flash_first_token_and_padding_rows():
    """Row 0 attends only to itself; padded rows don't contaminate."""
    B, S, H, Hkv, hd = 1, 100, 2, 1, 16   # S pads to 128
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    got = flash_attention(q, k, v, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(got[0, 0, 0]),
                               np.asarray(v[0, 0, 0]), atol=1e-5, rtol=1e-5)


def test_flash_variant_in_model():
    """The kernel is reachable as a model attention variant and agrees with
    the dense path end-to-end."""
    from repro.configs.base import ArchConfig
    from repro.models.model import LM
    cfg = ArchConfig(name="fl", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                     head_dim=16, remat=False, dtype="float32")
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0, 128)
    dense_logits, _ = lm.apply(params, tokens, variant="dense")
    flash_logits, _ = lm.apply(params, tokens, variant="flash")
    np.testing.assert_allclose(np.asarray(flash_logits),
                               np.asarray(dense_logits), atol=1e-3,
                               rtol=1e-3)
