"""Minimal deterministic stand-in for `hypothesis` (not installed in the
container; pip installs are disallowed). conftest.py puts this package on
sys.path ONLY when the real library is missing, so environments that have
hypothesis keep full shrinking/fuzzing behavior.

Supported subset (everything the test-suite uses):
  @settings(max_examples=N, deadline=None)
  @given(name=strategy, ...)
  strategies.integers / floats / composite

Semantics: each test runs ``max_examples`` times with values drawn from a
fixed-seed numpy Generator — property coverage without randomness flake.
"""
from __future__ import annotations

import functools

import numpy as np

__version__ = "0.0-stub"


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: np.random.Generator):
        return self._sample(rng)


class strategies:  # namespace mirroring `hypothesis.strategies`
    @staticmethod
    def integers(min_value, max_value) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    @staticmethod
    def composite(fn):
        def builder(*args, **kwargs):
            def sample(rng):
                return fn(lambda s: s.sample(rng), *args, **kwargs)

            return _Strategy(sample)

        return builder


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", 20)
            rng = np.random.default_rng(0)
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strats.items()}
                fn(*args, **kwargs, **drawn)

        # pytest must not resolve the drawn parameters as fixtures: drop the
        # signature forwarding that functools.wraps sets up.
        del wrapper.__wrapped__
        return wrapper

    return deco
