"""Communication-avoiding replication invariants (ISSUE 7, hypothesis
stub–compatible property tests).

The 2.5-D contract, checked per plan against the lowered kernels' own
``CommStats.axes`` ledger:

  * replicated-operand broadcast bytes on the replication axis equal
    payload × (replicas − 1) on the wire;
  * the reduction the replication eliminates is GONE from the ledger
    (spmm reduces along y only, never z) and the surviving reduction is
    strictly smaller than the unreplicated plan's at equal pieces;
  * the replicated plan's result is BIT-FOR-BIT equal to the
    unreplicated 2-D plan on integer-valued inputs (output columns are
    independent lanes of the same leaf contraction);
  * a replica is fingerprint-shared through SHARD_CACHE, not copied per
    z-layer;
  * 3-D GridPlans uphold the tiling invariant, replication-aware.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core as rc
from repro.core import formats as F
from repro.core import plan_search as PS
from repro.core.grid import compute_grid_plan, grid_axis_bytes
from repro.core.lower import (_nbytes, default_grid_schedule,
                              default_replicated_schedule, lower)
from repro.core.partition import SHARD_CACHE
from repro.core.tensor import Tensor


def _int_sparse(rng, n, m, density=0.3):
    """Integer-valued sparse matrix: all partial sums are exact in fp32,
    so differently-ordered reductions must agree BIT FOR BIT."""
    return (rng.integers(-3, 4, (n, m)) *
            (rng.random((n, m)) < density)).astype(np.float32)


def _spmm_stmt(rng, n, m, J, fm=None, integer=True):
    dB = _int_sparse(rng, n, m) if integer else \
        ((rng.random((n, m)) < .3) * rng.standard_normal((n, m))
         ).astype(np.float32)
    dC = (rng.integers(-3, 4, (m, J)).astype(np.float32) if integer
          else rng.standard_normal((m, J)).astype(np.float32))
    B = Tensor.from_dense("B", dB, fm or F.CSR())
    C = Tensor.from_dense("C", dC)
    stmt = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                        A=Tensor.zeros_dense("A", (n, J)), B=B, C=C)
    return stmt, dB, dC


def _machine3(P, Q, R):
    return rc.Machine(("x", P), ("y", Q), ("z", R))


# ---------------------------------------------------------------------------
# Invariant 1: the replication ledger is self-consistent
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(n=st.integers(8, 40), m=st.integers(8, 40), J=st.integers(2, 12),
       P=st.integers(2, 3), Q=st.integers(1, 3), R=st.integers(2, 3),
       seed=st.integers(0, 999))
def test_replicated_broadcast_equals_payload_times_replicas(
        n, m, J, P, Q, R, seed):
    rng = np.random.default_rng(seed)
    stmt, dB, dC = _spmm_stmt(rng, n, m, J)
    M = _machine3(P, Q, R)
    k = lower(stmt, M, schedule=default_replicated_schedule(stmt, M))
    B = stmt.rhs.accesses()[0].tensor
    z = k.comm.axes["z"]
    # the replicated operand rides z un-sliced: the z hop broadcasts one
    # full payload to each of the R-1 extra layers
    assert z.size == R
    assert z.broadcast_bytes == _nbytes(B)
    assert z.network_bytes() == _nbytes(B) * (R - 1)
    # replication eliminates the z reduction entirely; partials sum on y
    assert z.reduce_bytes == 0
    assert k.comm.axes["y"].broadcast_bytes == 0
    if Q > 1:
        assert k.comm.axes["y"].reduce_bytes > 0


@settings(max_examples=8, deadline=None)
@given(n=st.integers(16, 48), m=st.integers(16, 48), J=st.integers(4, 16),
       P=st.integers(2, 3), Q=st.integers(2, 3), R=st.integers(2, 3),
       seed=st.integers(0, 999))
def test_replication_shrinks_reduction(n, m, J, P, Q, R, seed):
    """At equal pieces P×(Q·R), replication trades the (Q·R−1)-hop output
    all-reduce for a (Q−1)-hop one plus the z broadcast — the reduction
    bytes on the wire must shrink by exactly the eliminated hops."""
    rng = np.random.default_rng(seed)
    stmt, _, _ = _spmm_stmt(rng, n, m, J)
    M3 = _machine3(P, Q, R)
    k3 = lower(stmt, M3, schedule=default_replicated_schedule(stmt, M3))
    M2 = rc.Machine(("x", P), ("y", Q * R))
    k2 = lower(stmt, M2, schedule=default_grid_schedule(stmt, M2))
    red3 = sum(a.reduce_bytes * (a.size - 1) for a in k3.comm.axes.values())
    red2 = sum(a.reduce_bytes * (a.size - 1) for a in k2.comm.axes.values())
    payload = k2.comm.axes["y"].reduce_bytes
    assert payload > 0
    assert red2 - red3 == payload * (Q * R - 1) - payload * (Q - 1)


# ---------------------------------------------------------------------------
# Invariant 2: bit-for-bit agreement with the unreplicated 2-D plan
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(n=st.integers(8, 40), m=st.integers(8, 40), J=st.integers(2, 12),
       P=st.integers(2, 3), Q=st.integers(1, 3), R=st.integers(2, 3),
       fmt=st.sampled_from(["csr", "csc"]), seed=st.integers(0, 999))
def test_replicated_bit_for_bit_vs_2d(n, m, J, P, Q, R, fmt, seed):
    rng = np.random.default_rng(seed)
    fm = F.CSR() if fmt == "csr" else F.CSC()
    stmt, dB, dC = _spmm_stmt(rng, n, m, J, fm=fm)
    M3 = _machine3(P, Q, R)
    k3 = lower(stmt, M3, schedule=default_replicated_schedule(stmt, M3))
    M2 = rc.Machine(("x", P), ("y", Q))
    k2 = lower(stmt, M2, schedule=default_grid_schedule(stmt, M2))
    got3, got2 = np.asarray(k3.run()), np.asarray(k2.run())
    assert np.array_equal(got3, got2), \
        "z-slices are independent column lanes of the same contraction"
    assert np.array_equal(got3, dB @ dC)


# ---------------------------------------------------------------------------
# Invariant 3: the replica is fingerprint-shared, not copied per layer
# ---------------------------------------------------------------------------

def test_replica_shares_shards_with_2d_plan():
    rng = np.random.default_rng(3)
    stmt, _, _ = _spmm_stmt(rng, 30, 24, 8)
    M2 = rc.Machine(("x", 2), ("y", 2))
    k2 = lower(stmt, M2, schedule=default_grid_schedule(stmt, M2))
    misses_after_2d = SHARD_CACHE.stats["misses"]
    M3 = _machine3(2, 2, 2)
    k3 = lower(stmt, M3, schedule=default_replicated_schedule(stmt, M3))
    B = stmt.rhs.accesses()[0].tensor
    # same (P, Q) tiles -> same cache entry: the replicated plan reuses
    # the 2-D plan's packed tile arrays (the cache hit re-wraps only the
    # partition field), no per-z-layer copies
    for name in ("pos1", "crd1", "vals"):
        assert k3.shards[B.name].arrays[name] is k2.shards[B.name].arrays[name]
    assert SHARD_CACHE.stats["misses"] > misses_after_2d  # C regridded
    gp = compute_grid_plan(stmt, k3.strategy)
    gp.validate(30, 24, n_dep=8)
    gp.validate_coverage(k3.plans[B.name], B.shape)


# ---------------------------------------------------------------------------
# Invariant 4: 3-D grid plans tile the universe exactly once
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 40), m=st.integers(2, 40), d=st.integers(2, 40),
       P=st.integers(1, 4), Q=st.integers(1, 4), R=st.integers(1, 4),
       seed=st.integers(0, 99))
def test_brick_tiling_covers_universe_exactly_once(n, m, d, P, Q, R, seed):
    rng = np.random.default_rng(seed)
    dB = ((rng.random((n, m, d)) < .2) *
          rng.standard_normal((n, m, d))).astype(np.float32)
    B = Tensor.from_dense("B", dB, F.COO(3))
    L = 3
    stmt = rc.parse_tin(
        "A(i,l) = B(i,j,k) * C(j,l) * D(k,l)",
        A=Tensor.zeros_dense("A", (n, L)), B=B,
        C=Tensor.from_dense("C", rng.standard_normal((m, L)).astype(np.float32)),
        D=Tensor.from_dense("D", rng.standard_normal((d, L)).astype(np.float32)))
    M = _machine3(P, Q, R)
    from repro.core.lower import default_grid3_schedule
    gp = compute_grid_plan(stmt, default_grid3_schedule(stmt, M).strategy())
    gp.validate(n, m, n_dep=d)
    hits = np.zeros((n, m, d), np.int64)
    for p, q, r, rw, cw, dw in gp.tile_windows3():
        hits[rw[0]:rw[1], cw[0]:cw[1], dw[0]:dw[1]] += 1
    assert (hits == 1).all(), "bricks must partition the universe"


def test_validate_requires_dep_extent():
    rng = np.random.default_rng(0)
    stmt, _, _ = _spmm_stmt(rng, 20, 16, 4)
    M = _machine3(2, 2, 2)
    gp = compute_grid_plan(
        stmt, default_replicated_schedule(stmt, M).strategy())
    with pytest.raises(AssertionError, match="third-axis extent"):
        gp.validate(20, 16)


def test_replication_must_be_declared():
    """A 3-var schedule whose third variable misses the sparse operand is
    only legal with an explicit .replicate([B], z) — replication is a
    schedule decision, not an inference."""
    rng = np.random.default_rng(1)
    stmt, _, _ = _spmm_stmt(rng, 20, 16, 4)
    M = _machine3(2, 2, 2)
    s = default_replicated_schedule(stmt, M)
    s._replicate.clear()                 # strip the declaration
    with pytest.raises(ValueError, match="replicate"):
        lower(stmt, M, schedule=s)


# ---------------------------------------------------------------------------
# Acceptance pin: 2.5-D moves fewer bytes than the best 2-D at equal pieces
# ---------------------------------------------------------------------------

def test_replicated_spmm_beats_best_2d_comm_volume():
    """The bench_replication shape (n=m=200, 2% dense, J=64, 8 pieces):
    |A|·Q > |B|, so replicating B along z must beat EVERY unreplicated
    2-D factorization on total network bytes — the measurable win the
    autoscheduler's byte model is built to find."""
    rng = np.random.default_rng(7)
    stmt, _, _ = _spmm_stmt(rng, 200, 200, 64, integer=False)

    def net(k):
        return sum(a.network_bytes() for a in k.comm.axes.values()) \
            + (k.comm.replicate_bytes + k.comm.reduce_bytes) * 7

    M3 = _machine3(2, 2, 2)
    rep = lower(stmt, M3, schedule=default_replicated_schedule(stmt, M3))
    two_d = []
    for P, Q in ((2, 4), (4, 2)):
        M2 = rc.Machine(("x", P), ("y", Q))
        two_d.append(net(lower(stmt, M2,
                               schedule=default_grid_schedule(stmt, M2))))
    assert net(rep) < min(two_d), \
        f"2.5-D {net(rep)}B must beat best 2-D {min(two_d)}B"


def test_model_ledger_agreement_replicated():
    """grid_axis_bytes (the autoscheduler's model) and the lowered
    kernel's CommStats.axes (the ledger) must agree per axis on
    replicated plans — model-vs-ledger drift is a bug, not calibration."""
    rng = np.random.default_rng(11)
    stmt, _, _ = _spmm_stmt(rng, 40, 30, 8)
    M = _machine3(2, 2, 2)
    strat = default_replicated_schedule(stmt, M).strategy()
    k = lower(stmt, M, schedule=default_replicated_schedule(stmt, M))
    model = grid_axis_bytes(stmt, strat)
    for ax in ("x", "y", "z"):
        assert model[ax].broadcast_bytes == k.comm.axes[ax].broadcast_bytes
        assert model[ax].reduce_bytes == k.comm.axes[ax].reduce_bytes
