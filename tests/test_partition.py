"""Dependent partitioning properties (paper §III-A / Table I semantics)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import formats as F
from repro.core.partition import (image, materialize_coo_nnz,
                                  materialize_csr_rows, partition_by_bounds,
                                  partition_nonzeros,
                                  partition_tensor_nonzeros,
                                  partition_tensor_rows, preimage)
from repro.core.tensor import Tensor


@settings(max_examples=50, deadline=None)
@given(n=st.integers(0, 1000), p=st.integers(1, 16))
def test_bounds_cover_and_disjoint(n, p):
    b = partition_by_bounds(n, p)
    assert b.shape == (p, 2)
    covered = np.zeros(n, bool)
    for lo, hi in b:
        assert 0 <= lo <= hi <= n
        assert not covered[lo:hi].any()      # disjoint
        covered[lo:hi] = True
    assert covered.all()                     # total


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), p=st.integers(1, 8))
def test_image_preimage_inverse_ish(seed, p):
    """image(preimage(P)) must cover P (Galois connection property)."""
    rng = np.random.default_rng(seed)
    n = rng.integers(2, 40)
    counts = rng.integers(0, 7, n)
    pos = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=pos[1:])
    nnz = int(pos[-1])
    child = partition_nonzeros(nnz, p)
    parents = preimage(pos, child)
    back = image(pos, parents)
    for c in range(p):
        lo, hi = child[c]
        if lo >= hi:
            continue                       # empty set: trivially covered
        blo, bhi = back[c]
        assert blo <= lo and hi <= bhi     # superset after round trip


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), p=st.integers(1, 8))
def test_preimage_intersection_semantics(seed, p):
    """r ∈ preimage[c] ⇔ [pos[r], pos[r+1]) ∩ child[c] ≠ ∅ (for non-empty
    rows; empty rows may be included harmlessly at boundaries)."""
    rng = np.random.default_rng(seed)
    n = rng.integers(2, 30)
    counts = rng.integers(0, 5, n)
    pos = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=pos[1:])
    child = partition_nonzeros(int(pos[-1]), p)
    par = preimage(pos, child)
    for c in range(p):
        plo, phi = child[c]
        for r in range(n):
            intersects = max(pos[r], plo) < min(pos[r + 1], phi)
            inside = par[c, 0] <= r < par[c, 1]
            if intersects:
                assert inside
            if inside and pos[r] < pos[r + 1] and plo < phi:
                assert max(pos[r], plo) < min(pos[r + 1], phi) or \
                    pos[r] == pos[r + 1]


def _random_csr(seed, n=30, m=20, density=0.25, skew=True):
    rng = np.random.default_rng(seed)
    dense = ((rng.random((n, m)) < density) *
             rng.standard_normal((n, m))).astype(np.float32)
    if skew:
        dense[min(3, n - 1)] = rng.standard_normal(m)
    return Tensor.from_dense("B", dense, F.CSR()), dense


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), p=st.integers(1, 8))
def test_row_partition_covers_all_nnz(seed, p):
    t, dense = _random_csr(seed)
    part = partition_tensor_rows(t, partition_by_bounds(t.shape[0], p))
    vb = part.vals_bounds
    assert vb[0, 0] == 0 and vb[-1, 1] == t.nnz
    assert np.all(vb[1:, 0] == vb[:-1, 1])   # contiguous, disjoint


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), p=st.integers(1, 8))
def test_nnz_partition_balance(seed, p):
    """Non-zero partitions are balanced within one element (paper Fig. 5b)."""
    t, _ = _random_csr(seed)
    part = partition_tensor_nonzeros(t, p)
    counts = part.vals_bounds[:, 1] - part.vals_bounds[:, 0]
    assert counts.max() - counts.min() <= max(1, int(0.1 * counts.max())) \
        or counts.max() <= -(-t.nnz // p)


def test_materialize_csr_rows_reconstructs(rng):
    t, dense = _random_csr(1, n=19, m=13)
    part = partition_tensor_rows(t, partition_by_bounds(19, 4))
    sh = materialize_csr_rows(t, part)
    # reconstruct dense from shards
    out = np.zeros_like(dense)
    for pcs in range(4):
        rs = sh.arrays["row_start"][pcs]
        rc = sh.arrays["row_count"][pcs]
        pos = sh.arrays["pos1"][pcs]
        crd = sh.arrays["crd1"][pcs]
        vals = sh.arrays["vals"][pcs]
        for r in range(rc):
            for pp in range(pos[r], pos[r + 1]):
                out[rs + r, crd[pp]] += vals[pp]
    assert np.allclose(out, dense)


def test_materialize_coo_nnz_reconstructs(rng):
    t, dense = _random_csr(2, n=17, m=11)
    part = partition_tensor_nonzeros(t, 4)
    sh = materialize_coo_nnz(t, part)
    out = np.zeros_like(dense)
    for pcs in range(4):
        cnt = sh.arrays["nnz_count"][pcs]
        out[sh.arrays["dim0"][pcs, :cnt],
            sh.arrays["dim1"][pcs, :cnt]] += sh.arrays["vals"][pcs, :cnt]
    assert np.allclose(out, dense)


def test_imbalance_metric_story(rng):
    """The paper's §II-D claim: skewed matrices → universe partitions
    imbalanced, non-zero partitions balanced."""
    t, _ = _random_csr(3, n=64, m=64, density=0.05, skew=True)
    rows = partition_tensor_rows(t, partition_by_bounds(64, 8))
    nnz = partition_tensor_nonzeros(t, 8)
    assert nnz.imbalance() <= 0.15
    assert rows.imbalance() > nnz.imbalance()


def test_partial_fusion_tubes():
    """Paper Fig. 5: T_xyz with xy→f splits non-zero TUBES evenly; the
    leaf follows by image, the root by preimage."""
    rng = np.random.default_rng(9)
    dims = (30, 20, 15)
    d = ((rng.random(dims) < 0.1) * rng.standard_normal(dims)
         ).astype(np.float32)
    t = Tensor.from_dense("B", d, F.CSF(3))
    p = partition_tensor_nonzeros(t, 4, fused_levels=2)
    tube_counts = p.levels[1].pos_bounds[:, 1] - p.levels[1].pos_bounds[:, 0]
    assert tube_counts.max() - tube_counts.min() <= 4   # balanced tubes
    assert p.vals_bounds[0, 0] == 0 and p.vals_bounds[-1, 1] == t.nnz
    assert np.all(p.vals_bounds[1:, 0] == p.vals_bounds[:-1, 1])
    sh = materialize_coo_nnz(t, p)
    out = np.zeros(dims, np.float32)
    for pc in range(4):
        c = sh.arrays["nnz_count"][pc]
        out[sh.arrays["dim0"][pc, :c], sh.arrays["dim1"][pc, :c],
            sh.arrays["dim2"][pc, :c]] += sh.arrays["vals"][pc, :c]
    assert np.allclose(out, d)


def test_partial_fusion_via_tdn():
    from repro.core.tdn import Machine, dist
    rng = np.random.default_rng(10)
    dims = (12, 10, 8)
    d = ((rng.random(dims) < 0.15) * np.ones(dims)).astype(np.float32)
    t = Tensor.from_dense("B", d, F.CSF(3))
    M = Machine(("x", 3))
    dd = dist(("x", "y", "z"), "xy ~f> f", M)
    assert dd.nonzero and dd.fused == ("x", "y")
    plan = dd.plan(t)
    assert plan.vals_bounds[-1, 1] == t.nnz


def test_weighted_nonzero_partition_straggler_replan():
    """runtime/fault emits weights; the partition honors them — the paper's
    nnz partitioning generalized to heterogeneous shard speeds."""
    from repro.core.partition import partition_nonzeros
    from repro.runtime.fault import StragglerMitigator
    mit = StragglerMitigator(4, report_budget=1)
    mit.report_slow(2)
    b = partition_nonzeros(1000, 4, weights=mit.weights)
    counts = b[:, 1] - b[:, 0]
    assert counts.sum() == 1000
    assert counts[2] < counts[0]
    assert b[0, 0] == 0 and np.all(b[1:, 0] == b[:-1, 1])
