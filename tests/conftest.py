"""Shared fixtures. NOTE: no XLA_FLAGS here by design — smoke tests and
benches must see 1 device; only launch/dryrun.py (and the subprocess-based
SPMD tests) force a multi-device host platform."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_csr(rng, n, m, density=0.2, skew_row=None):
    from repro.core import formats as F
    from repro.core.tensor import Tensor
    dense = ((rng.random((n, m)) < density) *
             rng.standard_normal((n, m))).astype(np.float32)
    if skew_row is not None:
        dense[skew_row] = rng.standard_normal(m).astype(np.float32)
    return Tensor.from_dense("B", dense, F.CSR()), dense
