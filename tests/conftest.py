"""Shared fixtures. NOTE: no XLA_FLAGS here by design — smoke tests and
benches must see 1 device; only launch/dryrun.py (and the subprocess-based
SPMD tests) force a multi-device host platform."""
import os
import sys

import numpy as np
import pytest

try:  # real hypothesis when installed; deterministic stub otherwise
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_stubs"))


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    """Print the conformance-matrix pass/fallback census (ISSUE 1: the
    matrix is a versioned artifact; the census is its summary form)."""
    # Use the module instance pytest imported (cwd-on-sys.path would let
    # `from tests import conformance` create a SECOND instance whose census
    # is empty).
    conformance = sys.modules.get("conformance") or \
        sys.modules.get("tests.conformance")
    if conformance is None:
        return
    census = conformance.CENSUS
    if not census:
        return
    direct = sorted(c for c, v in census.items() if v["status"] == "direct")
    fallback = sorted(c for c, v in census.items()
                      if v["status"] == "fallback")
    tw = terminalreporter
    tw.section("conformance matrix census")
    tw.write_line(
        f"{len(census)} cells verified against core/interp.py: "
        f"{len(direct)} direct, {len(fallback)} via logged format conversion")
    for cid in fallback:
        conv = "; ".join(census[cid]["fallbacks"])
        tw.write_line(f"  fallback  {cid}  ({conv})")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_csr(rng, n, m, density=0.2, skew_row=None):
    from repro.core import formats as F
    from repro.core.tensor import Tensor
    dense = ((rng.random((n, m)) < density) *
             rng.standard_normal((n, m))).astype(np.float32)
    if skew_row is not None:
        dense[skew_row] = rng.standard_normal(m).astype(np.float32)
    return Tensor.from_dense("B", dense, F.CSR()), dense
