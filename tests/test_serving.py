"""Serving fast path: bucketized request batching, rebind, and
comm/compute overlap (ISSUE 10).

``run_many`` must be bit-for-bit against a per-request loop across the
format × strategy × machine matrix, steady-state serving must never
recompile a runner (batch bucketing bounds the cache), and the
double-buffered executors must be bit-for-bit against their unchunked
counterparts (integer-valued operands so every reduction order agrees
exactly).
"""
import numpy as np
import pytest

import repro.core as rc
from repro.core import formats as F
from repro.core.cache import BATCH_BUCKETS, batch_bucket
from repro.core.lower import (RUNNER_CACHE_STATS, default_grid_schedule,
                              default_nnz_schedule, lower, lower_batched,
                              rebind_dense)
from repro.core.tensor import Tensor
from repro.distributed.executor import run_overlapped
from repro.runtime import telemetry

from test_spmd import run_sub


def _int_sparse(rng, n, m, density=0.15):
    return (rng.integers(-3, 4, (n, m)) *
            (rng.random((n, m)) < density)).astype(np.float32)


def _spmv_stmt(dB, fmt):
    n, m = dB.shape
    return rc.parse_tin("a(i) = B(i,j) * c(j)",
                        a=Tensor.zeros_dense("a", (n,)),
                        B=Tensor.from_dense("B", dB.copy(), fmt),
                        c=Tensor.zeros_dense("c", (m,)))


# --- batch_bucket -----------------------------------------------------------

def test_batch_bucket():
    assert batch_bucket(1) == 1
    assert batch_bucket(3) == 4
    assert batch_bucket(8) == 8
    assert batch_bucket(9) == 16
    assert batch_bucket(max(BATCH_BUCKETS) + 1) == 2 * max(BATCH_BUCKETS)
    with pytest.raises(ValueError):
        batch_bucket(0)


# --- run_many bit-for-bit matrix -------------------------------------------

@pytest.mark.parametrize("fmt_name", ["csr", "bcsr"])
@pytest.mark.parametrize("sched", ["rows", "nnz", "grid"])
def test_run_many_matches_loop(fmt_name, sched):
    rng = np.random.default_rng(3)
    n, m = 96, 80
    dB = _int_sparse(rng, n, m)
    fmt = F.CSR() if fmt_name == "csr" else F.BCSR((4, 4))
    stmt = _spmv_stmt(dB, fmt)
    if sched == "grid":
        machine = rc.Machine(("x", 2), ("y", 2))
        schedule = default_grid_schedule
    else:
        machine = rc.Machine(("x", 4))
        schedule = default_nnz_schedule if sched == "nnz" else None
    if fmt_name == "bcsr" and sched == "grid":
        pytest.skip("no blocked grid SpMM cell for the promoted statement")
    bk = lower_batched(stmt, machine, batch=8, schedule=schedule)
    reqs = [rng.integers(-3, 4, m).astype(np.float32) for _ in range(8)]
    batch = bk.run_many(reqs)
    loop = [bk.run_many([r])[0] for r in reqs]
    for r, yb, yl in zip(reqs, batch, loop):
        ref = dB @ r
        assert np.array_equal(np.asarray(yb).ravel(), ref)
        assert np.array_equal(np.asarray(yl).ravel(), ref)


def test_run_many_spmm_panels():
    """Per-request fixed-width panels (jw > 1) stack into one wider SpMM."""
    rng = np.random.default_rng(4)
    n, m, jw = 64, 48, 3
    dB = _int_sparse(rng, n, m)
    stmt = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                        A=Tensor.zeros_dense("A", (n, jw)),
                        B=Tensor.from_dense("B", dB.copy(), F.CSR()),
                        C=Tensor.zeros_dense("C", (m, jw)))
    bk = lower_batched(stmt, rc.Machine(("x", 4)), batch=4)
    reqs = [rng.integers(-3, 4, (m, jw)).astype(np.float32)
            for _ in range(4)]
    outs = bk.run_many(reqs)
    for r, y in zip(reqs, outs):
        assert np.array_equal(np.asarray(y), dB @ r)


# --- bounded recompilation --------------------------------------------------

def test_mixed_batch_sizes_bounded_recompiles():
    rng = np.random.default_rng(5)
    n, m = 96, 80
    dB = _int_sparse(rng, n, m)
    bk = lower_batched(_spmv_stmt(dB, F.CSR()), rc.Machine(("x", 4)),
                       batch=8)
    reqs = [rng.integers(-3, 4, m).astype(np.float32) for _ in range(8)]
    for size in (8, 1, 2, 4):        # warm buckets 8, 1, 2, 4
        bk.run_many(reqs[:size])
    before = dict(RUNNER_CACHE_STATS)
    # every size <= 8 lands in a warmed bucket: zero runner misses
    for size in (2, 3, 5, 6, 7, 8, 1, 4):
        outs = bk.run_many(reqs[:size])
        for r, y in zip(reqs, outs):
            assert np.array_equal(np.asarray(y).ravel(), dB @ r)
    assert RUNNER_CACHE_STATS["misses"] == before["misses"]
    assert RUNNER_CACHE_STATS["hits"] > before["hits"]


def test_rebind_dense_rejects_sparse_and_unknown():
    rng = np.random.default_rng(6)
    dB = _int_sparse(rng, 32, 24)
    stmt = _spmv_stmt(dB, F.CSR())
    k = lower(stmt, rc.Machine(("x", 2)))
    with pytest.raises(ValueError):
        rebind_dense(k, {"B": Tensor.from_dense(
            "B", dB.copy(), F.CSR())})
    with pytest.raises(KeyError):
        rebind_dense(k, {"nope": Tensor.zeros_dense("nope", (24,))})
    # a legitimate dense rebind runs without re-planning
    c2 = rng.integers(-3, 4, 24).astype(np.float32)
    k2 = rebind_dense(k, {"c": Tensor.from_dense("c", c2)})
    assert np.array_equal(np.asarray(k2.run()).ravel(), dB @ c2)


# --- comm/compute overlap ---------------------------------------------------

@pytest.mark.parametrize("sched", ["rows", "nnz", "grid"])
def test_run_overlapped_bit_for_bit(sched):
    rng = np.random.default_rng(7)
    n, m, j = 96, 80, 24
    dB = _int_sparse(rng, n, m)
    dC = rng.integers(-3, 4, (m, j)).astype(np.float32)
    stmt = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                        A=Tensor.zeros_dense("A", (n, j)),
                        B=Tensor.from_dense("B", dB.copy(), F.CSR()),
                        C=Tensor.from_dense("C", dC))
    if sched == "grid":
        machine = rc.Machine(("x", 2), ("y", 2))
        k = lower(stmt, machine,
                  schedule=default_grid_schedule(stmt, machine))
    else:
        machine = rc.Machine(("x", 4))
        schedule = (default_nnz_schedule(stmt, machine)
                    if sched == "nnz" else None)
        k = lower(stmt, machine, schedule=schedule)
    ref = np.asarray(k.run())
    for chunks in (2, 3):
        assert np.array_equal(ref, run_overlapped(k, chunks=chunks))
        assert np.array_equal(
            ref, run_overlapped(k, chunks=chunks, overlap=False))


def test_overlap_telemetry_and_attribution():
    rng = np.random.default_rng(8)
    n, m, j = 96, 80, 24
    dB = _int_sparse(rng, n, m)
    dC = rng.integers(-3, 4, (m, j)).astype(np.float32)
    stmt = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                        A=Tensor.zeros_dense("A", (n, j)),
                        B=Tensor.from_dense("B", dB.copy(), F.CSR()),
                        C=Tensor.from_dense("C", dC))
    k = lower(stmt, rc.Machine(("x", 4)))
    tr = telemetry.TRACER
    was = tr.enabled
    tr.clear()
    tr.enable()
    try:
        run_overlapped(k, chunks=3)
        rep = telemetry.overlap_report()
    finally:
        tr.enabled = was
    assert rep["chunks"] == 3
    assert rep["comm_s"] > 0 and rep["bytes"] > 0
    assert 0 < rep["efficiency"] <= 1.0
    # attribution only: overlap bytes never inflate the comm model
    d = k.comm.as_dict()
    assert d["overlap_total_bytes"] == k.comm.overlap_total_bytes > 0
    assert k.comm.overlap_hidden_bytes <= k.comm.overlap_total_bytes
    assert d["total_network_bytes"] == k.comm.total_network_bytes()


def test_run_overlapped_rejects_bcsr():
    rng = np.random.default_rng(9)
    dB = _int_sparse(rng, 64, 48)
    dC = rng.integers(-3, 4, (48, 8)).astype(np.float32)
    stmt = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                        A=Tensor.zeros_dense("A", (64, 8)),
                        B=Tensor.from_dense("B", dB.copy(), F.BCSR((4, 4))),
                        C=Tensor.from_dense("C", dC))
    k = lower(stmt, rc.Machine(("x", 2)))
    with pytest.raises(NotImplementedError):
        run_overlapped(k)


# --- serving loop -----------------------------------------------------------

def test_sparse_kernel_server_queue_and_slo():
    from repro.launch.serve import SparseKernelServer
    rng = np.random.default_rng(10)
    n, m = 96, 80
    dB = _int_sparse(rng, n, m)
    srv = SparseKernelServer(_spmv_stmt(dB, F.CSR()), rc.Machine(("x", 4)),
                             max_batch=4, slo_ms=60_000.0)
    rids, rhss = [], []
    for _ in range(10):
        rhs = rng.integers(-3, 4, m).astype(np.float32)
        rids.append(srv.submit(rhs))
        rhss.append(rhs)
    assert srv.drain() == 10
    for rid, rhs in zip(rids, rhss):
        assert np.array_equal(np.asarray(srv.result(rid)).ravel(),
                              dB @ rhs)
    st = srv.stats()
    assert st["served"] == 10
    assert st["p50_ms"] <= st["p99_ms"] <= st["max_ms"]
    assert st["slo_attainment"] == 1.0
    snap = telemetry.METRICS.snapshot()
    assert "serve.latency_ms" in snap["histograms"]
    assert "serve.queue_depth" in snap["gauges"]


def test_band_decode_and_moe_combine_kernels():
    from repro.models.moe import combine_kernel, dispatch_tensor
    from repro.models.sparse_attention import band_decode_kernel, band_plan
    rng = np.random.default_rng(11)
    machine = rc.Machine(("x", 4))

    bk = band_decode_kernel(256, 16, 64, machine, batch=4)
    mask = band_plan(256, 16, 64).to_dense()
    nq = mask.shape[0]
    reqs = [rng.integers(-3, 4, nq).astype(np.float32) for _ in range(4)]
    for r, y in zip(reqs, bk.run_many(reqs)):
        assert np.array_equal(np.asarray(y).ravel(), mask @ r)

    N, E, topk = 48, 8, 2
    tope = np.stack([rng.choice(E, topk, replace=False) for _ in range(N)])
    topw = rng.integers(1, 4, (N, topk)).astype(np.float32)
    disp = dispatch_tensor(tope, topw, E)
    ck = combine_kernel(disp, machine, batch=4)
    dd = disp.to_dense()
    cols = [rng.integers(-3, 4, E).astype(np.float32) for _ in range(3)]
    for c, y in zip(cols, ck.run_many(cols)):
        assert np.array_equal(np.asarray(y).ravel(), dd @ c)


# --- SPMD overlap (subprocess mesh) ----------------------------------------

def test_spmd_overlap_bit_for_bit():
    out = run_sub("""
        import numpy as np
        import repro.core as rc
        from repro.core import formats as F
        from repro.core.lower import default_grid_schedule, lower
        from repro.core.tensor import Tensor
        from repro.distributed.executor import to_spmd

        rng = np.random.default_rng(0)
        n, m, j = 64, 48, 12
        dB = (rng.integers(-3, 4, (n, m)) *
              (rng.random((n, m)) < 0.2)).astype(np.float32)
        dC = rng.integers(-3, 4, (m, j)).astype(np.float32)
        stmt = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                            A=Tensor.zeros_dense("A", (n, j)),
                            B=Tensor.from_dense("B", dB, F.CSR()),
                            C=Tensor.from_dense("C", dC))
        M22 = rc.Machine(("x", 2), ("y", 2))
        k = lower(stmt, M22, schedule=default_grid_schedule(stmt, M22))
        assert k.leaf_name == "spmm_grid_rows", k.leaf_name
        base = to_spmd(k, M22)()
        for chunks in (2, 3):
            ov = to_spmd(k, M22, overlap=True, overlap_chunks=chunks)()
            assert np.array_equal(base, ov), chunks
        assert np.array_equal(base, np.asarray(k.run()))
        print("SPMD_OVERLAP_OK")
    """, devices=4)
    assert "SPMD_OVERLAP_OK" in out
