"""Direct blocked (BCSR) execution path: block-shape sweep against the
interpreter oracle, Pallas blocked-kernel validation, blocked shard_map
builders, and the satellite fixes that rode along (spttv output format,
spadd3 nnz stream materialization)."""
import numpy as np
import pytest

import repro.core as rc
from repro.core import formats as F
from repro.core import partition as P
from repro.core.interp import interpret
from repro.core.lower import default_nnz_schedule, default_row_schedule, lower
from repro.core.tensor import Tensor

# (1,1) degenerate blocks, square, rectangular, and two shapes that do NOT
# divide the 19x13 operand — boundary blocks carry padding cells that must
# never leak into results.
BLOCK_SHAPES = [(1, 1), (2, 2), (4, 8), (3, 5)]
N, M, K = 19, 13, 5


def _operand(rng, empty=False):
    if empty:
        return np.zeros((N, M), np.float32)
    d = ((rng.random((N, M)) < 0.25) *
         rng.standard_normal((N, M))).astype(np.float32)
    d[rng.integers(0, N)] = 0                                    # empty row
    return d


def _stmt(expr, fm, rng, empty=False):
    dB = _operand(rng, empty)
    B = Tensor.from_dense("B", dB, fm)
    if expr == "spmv":
        c = Tensor.from_dense("c", rng.standard_normal(M).astype(np.float32))
        return rc.parse_tin("a(i) = B(i,j) * c(j)",
                            a=Tensor.zeros_dense("a", (N,)), B=B, c=c)
    if expr == "spmm":
        C = Tensor.from_dense(
            "C", rng.standard_normal((M, 7)).astype(np.float32))
        return rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                            A=Tensor.zeros_dense("A", (N, 7)), B=B, C=C)
    if expr == "sddmm":
        C = Tensor.from_dense(
            "C", rng.standard_normal((N, K)).astype(np.float32))
        D = Tensor.from_dense(
            "D", rng.standard_normal((K, M)).astype(np.float32))
        A = Tensor.from_dense("A", (dB != 0) * 1.0, F.CSR())
        return rc.parse_tin("A(i,j) = B(i,j) * C(i,k) * D(k,j)",
                            A=A, B=B, C=C, D=D)
    d2 = _operand(rng, empty)
    d3 = _operand(rng, empty)
    return rc.parse_tin(
        "A(i,j) = B(i,j) + C(i,j) + D(i,j)",
        A=Tensor.from_dense("A", np.zeros((N, M), np.float32), F.CSR()),
        B=B, C=Tensor.from_dense("C", d2, fm),
        D=Tensor.from_dense("D", d3, fm))


@pytest.mark.parametrize("block", BLOCK_SHAPES,
                         ids=[f"{b[0]}x{b[1]}" for b in BLOCK_SHAPES])
@pytest.mark.parametrize("strategy", ["rows", "nnz"])
@pytest.mark.parametrize("expr", ["spmv", "spmm", "sddmm", "spadd3"])
def test_blocked_leaves_match_oracle(expr, strategy, block):
    """Property over the block-shape grid: every blocked cell lowers with
    NO conversion fallback and matches the interpreter oracle — including
    boundary blocks of the non-divisible shapes."""
    rng = np.random.default_rng(hash((expr, strategy, block)) % 2**31)
    stmt = _stmt(expr, F.BCSR(block), rng)
    machine = rc.Machine(("x", 3))       # non-divisible piece count
    sched = (default_row_schedule(stmt, machine) if strategy == "rows"
             else default_nnz_schedule(stmt, machine))
    k = lower(stmt, machine, schedule=sched)
    assert k.fallbacks == [], f"blocked cell fell back: {k.fallbacks}"
    assert k.leaf_name.startswith("bcsr_"), k.leaf_name
    res = k.run()
    got = res.to_dense() if isinstance(res, Tensor) else res
    np.testing.assert_allclose(got, interpret(stmt), atol=1e-3)


def test_blocked_empty_operands():
    rng = np.random.default_rng(0)
    for strategy in ("rows", "nnz"):
        stmt = _stmt("spadd3", F.BCSR((2, 2)), rng, empty=True)
        machine = rc.Machine(("x", 4))
        sched = (default_row_schedule(stmt, machine) if strategy == "rows"
                 else default_nnz_schedule(stmt, machine))
        k = lower(stmt, machine, schedule=sched)
        assert k.fallbacks == []
        np.testing.assert_allclose(k.run().to_dense(),
                                   np.zeros((N, M), np.float32))


def test_mixed_block_shapes_fall_back():
    """spadd3 with disagreeing block layouts cannot use the tile-union
    leaves — it must take the logged conversion, not miscompute."""
    rng = np.random.default_rng(1)
    B = Tensor.from_dense("B", _operand(rng), F.BCSR((2, 2)))
    C = Tensor.from_dense("C", _operand(rng), F.BCSR((3, 5)))
    D = Tensor.from_dense("D", _operand(rng), F.BCSR((2, 2)))
    stmt = rc.parse_tin(
        "A(i,j) = B(i,j) + C(i,j) + D(i,j)",
        A=Tensor.from_dense("A", np.zeros((N, M), np.float32), F.CSR()),
        B=B, C=C, D=D)
    machine = rc.Machine(("x", 2))
    k = lower(stmt, machine)
    assert len(k.fallbacks) == 3        # all blocked operands converted
    np.testing.assert_allclose(k.run().to_dense(), interpret(stmt),
                               atol=1e-3)


@pytest.mark.parametrize("shape,block", [((19, 13), (2, 2)),
                                         ((37, 53), (4, 8))])
def test_bcsr_pallas_kernels(shape, block):
    """Pallas blocked kernels (interpret mode) against the jnp leaves and
    the dense oracle."""
    from repro.kernels import ops
    rng = np.random.default_rng(hash(shape) % 2**31)
    n, m = shape
    dense = ((rng.random((n, m)) < 0.3) *
             rng.standard_normal((n, m))).astype(np.float32)
    t = Tensor.from_dense("B", dense, F.BCSR(block))
    pos, crd, tiles = t.levels[1].pos, t.levels[1].crd, t.vals
    c = rng.standard_normal(m).astype(np.float32)
    for impl in ("xla", "pallas"):
        y = np.asarray(ops.spmv_bcsr(pos, crd, tiles, c, impl=impl))[:n]
        np.testing.assert_allclose(y, dense @ c, atol=1e-3, rtol=1e-3)
    C = rng.standard_normal((m, 9)).astype(np.float32)
    for impl in ("xla", "pallas"):
        Y = np.asarray(ops.spmm_bcsr(pos, crd, tiles, C, impl=impl))[:n]
        np.testing.assert_allclose(Y, dense @ C, atol=1e-3, rtol=1e-3)
    Cs = rng.standard_normal((n, K)).astype(np.float32)
    Ds = rng.standard_normal((K, m)).astype(np.float32)
    bc_coords = t.block_coords()
    for impl in ("xla", "pallas"):
        out = np.asarray(ops.sddmm_bcsr(bc_coords[:, 0], bc_coords[:, 1],
                                        tiles, Cs, Ds, impl=impl))
        got = Tensor("o", t.shape, t.format, t.levels, out,
                     np.float32).to_dense()
        np.testing.assert_allclose(got, dense * (Cs @ Ds), atol=1e-3,
                                   rtol=1e-3)
    # fused blocked add (dense-tile output)
    triples, total = [(pos, crd, tiles)], dense.copy()
    for s in range(2):
        dd = ((rng.random((n, m)) < 0.2) *
              rng.standard_normal((n, m))).astype(np.float32)
        tt = Tensor.from_dense("X", dd, F.BCSR(block))
        triples.append((tt.levels[1].pos, tt.levels[1].crd, tt.vals))
        total += dd
    for impl in ("xla", "pallas"):
        got = np.asarray(ops.spadd3_bcsr_dense(*triples, n_rows=n, n_cols=m,
                                               impl=impl))
        np.testing.assert_allclose(got, total, atol=1e-3, rtol=1e-3)


def test_bcsr_spmd_builders():
    """Blocked shard_map builders wire up and match the vmap simulation."""
    from repro.distributed.executor import to_spmd
    rng = np.random.default_rng(2)
    dB = _operand(rng)
    B = Tensor.from_dense("B", dB, F.BCSR((2, 2)))
    cv = rng.standard_normal(M).astype(np.float32)
    c = Tensor.from_dense("c", cv)
    stmt = rc.parse_tin("a(i) = B(i,j) * c(j)",
                        a=Tensor.zeros_dense("a", (N,)), B=B, c=c)
    machine = rc.Machine(("x", 1))       # single-device CPU mesh
    for sched_fn in (default_row_schedule, default_nnz_schedule):
        k = lower(stmt, machine, schedule=sched_fn(stmt, machine))
        assert k.leaf_name.startswith("bcsr_spmv")
        np.testing.assert_allclose(to_spmd(k)(), dB @ cv, atol=1e-4)
    # spmm under both strategies (bcsr cells had working builders via the
    # conversion fallback before the direct path — keep that coverage)
    Cd = rng.standard_normal((M, 6)).astype(np.float32)
    C = Tensor.from_dense("C", Cd)
    stmt2 = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                         A=Tensor.zeros_dense("A", (N, 6)), B=B, C=C)
    for sched_fn in (default_row_schedule, default_nnz_schedule):
        k = lower(stmt2, machine, schedule=sched_fn(stmt2, machine))
        assert k.leaf_name.startswith("bcsr_spmm")
        np.testing.assert_allclose(to_spmd(k)(), dB @ Cd, atol=1e-3)
    # sddmm under both strategies
    Cs = Tensor.from_dense("C", rng.standard_normal((N, K)).astype(np.float32))
    Ds = Tensor.from_dense("D", rng.standard_normal((K, M)).astype(np.float32))
    A = Tensor.from_dense("A", (dB != 0) * 1.0, F.CSR())
    stmt3 = rc.parse_tin("A(i,j) = B(i,j) * C(i,k) * D(k,j)",
                         A=A, B=B, C=Cs, D=Ds)
    exp = dB * (np.asarray(Cs.to_dense()) @ np.asarray(Ds.to_dense()))
    for sched_fn in (default_row_schedule, default_nnz_schedule):
        k = lower(stmt3, machine, schedule=sched_fn(stmt3, machine))
        assert k.leaf_name.startswith("bcsr_sddmm")
        Bt = stmt3.rhs.accesses()[0].tensor
        tiles = to_spmd(k)()
        got = Tensor("o", Bt.shape, Bt.format, Bt.levels, tiles,
                     np.float32).to_dense()
        np.testing.assert_allclose(got, exp, atol=1e-3)


def test_from_blocks_roundtrip_and_dedupe():
    coords = np.array([[1, 0], [0, 1], [1, 0]])      # duplicate block
    tiles = np.stack([np.full((2, 2), v, np.float32) for v in (1, 2, 3)])
    t = Tensor.from_blocks("T", (4, 4), F.BCSR((2, 2)), coords, tiles)
    dense = t.to_dense()
    assert t.vals.shape == (2, 2, 2)                 # deduped
    np.testing.assert_allclose(dense[2:4, 0:2], np.full((2, 2), 4.0))
    np.testing.assert_allclose(dense[0:2, 2:4], np.full((2, 2), 2.0))
    # boundary padding stays out of the dense image
    t2 = Tensor.from_blocks("T2", (3, 3), F.BCSR((2, 2)),
                            np.array([[1, 1]]),
                            np.ones((1, 2, 2), np.float32))
    assert t2.to_dense().sum() == 1.0                # 3 of 4 cells padded


def test_spttv_output_format_follows_input():
    """DCSF input must yield a DCSR (not CSR) output — the row emitter
    reuses the input's level objects, the nnz emitter reassembles."""
    rng = np.random.default_rng(7)
    dims = (20, 15, 11)
    dB3 = ((rng.random(dims) < 0.1) *
           rng.standard_normal(dims)).astype(np.float32)
    cv = rng.standard_normal(dims[2]).astype(np.float32)
    machine = rc.Machine(("x", 4))
    for fm, want in ((F.CSF(3), "csr"), (F.DCSF(3), "dcsr")):
        for sched_fn in (default_row_schedule, default_nnz_schedule):
            B = Tensor.from_dense("B", dB3, fm)
            c = Tensor.from_dense("c", cv)
            A = Tensor.from_dense("A", np.zeros(dims[:2], np.float32),
                                  F.CSR())
            stmt = rc.parse_tin("A(i,j) = B(i,j,k) * c(k)", A=A, B=B, c=c)
            k = lower(stmt, machine, schedule=sched_fn(stmt, machine))
            res = k.run()
            assert F.format_key(res.format) == want
            np.testing.assert_allclose(
                res.to_dense(), np.einsum("ijk,k->ij", dB3, cv), atol=1e-4)


def test_spadd3_nnz_stream_reused_on_replan():
    """The concatenated addend stream is packed by the materialization
    layer and cached, so re-lowering over the same operands (a straggler
    re-plan) reuses it instead of re-walking the coordinate trees."""
    rng = np.random.default_rng(9)
    fm = F.CSR()
    Bt = Tensor.from_dense("B", _operand(rng), fm)
    Ct = Tensor.from_dense("C", _operand(rng), fm)
    Dt = Tensor.from_dense("D", _operand(rng), fm)
    A = Tensor.from_dense("A", np.zeros((N, M), np.float32), F.CSR())
    stmt = rc.parse_tin("A(i,j) = B(i,j) + C(i,j) + D(i,j)",
                        A=A, B=Bt, C=Ct, D=Dt)
    machine = rc.Machine(("x", 4))
    P.ADD_STREAM_STATS.update(hits=0, misses=0)
    k1 = lower(stmt, machine, schedule=default_nnz_schedule(stmt, machine))
    k2 = lower(stmt, machine, schedule=default_nnz_schedule(stmt, machine))
    assert P.ADD_STREAM_STATS["misses"] == 1
    assert P.ADD_STREAM_STATS["hits"] == 1
    expected = Bt.to_dense() + Ct.to_dense() + Dt.to_dense()
    np.testing.assert_allclose(k2.run().to_dense(), expected, atol=1e-4)
    assert "_addstream" in k1.shards
    assert k1.shards["_addstream"].kind == "add_stream"
    # in-place operand mutation must INVALIDATE the cache (fingerprint),
    # not serve stale values
    Bt.vals[:] = Bt.vals * 10.0
    k3 = lower(stmt, machine, schedule=default_nnz_schedule(stmt, machine))
    assert P.ADD_STREAM_STATS["misses"] == 2
    expected3 = Bt.to_dense() + Ct.to_dense() + Dt.to_dense()
    np.testing.assert_allclose(k3.run().to_dense(), expected3, atol=1e-4)
