"""Layout autotuner + heavy-row split: the ELL-waste fix, end to end."""
import numpy as np
import pytest

from repro.core import formats as F
from repro.core.tensor import Tensor
from repro.data.spdata import powerlaw_matrix, uniform_sparse
from repro.kernels import ops
from repro.kernels.autotune import ell_cost, heavy_row_split, tune_ell


def test_tuner_prefers_small_blocks_on_skew():
    skew = powerlaw_matrix("B", 2000, 2000, 8, seed=0)
    uni = uniform_sparse("B", (2000, 2000), 8 / 2000, seed=1)
    t_skew = tune_ell(skew.levels[1].pos)
    t_uni = tune_ell(uni.levels[1].pos)
    assert t_skew.feasible and t_uni.feasible
    # skewed matrices need smaller row blocks than uniform ones
    assert t_skew.block_r <= t_uni.block_r
    assert t_skew.waste <= ell_cost(skew.levels[1].pos, 32, 512).waste


def test_heavy_row_split_reduces_waste_and_stays_correct():
    rng = np.random.default_rng(2)
    B = powerlaw_matrix("B", 1500, 1500, 12, seed=3)
    pos, crd, vals = B.levels[1].pos, B.levels[1].crd, B.vals
    c = rng.standard_normal(1500).astype(np.float32)
    expected = B.to_dense() @ c

    (pos2, crd2, vals2), (tr, tc, tv) = heavy_row_split(pos, crd, vals)
    # waste strictly improves when heavy rows exist
    w_before = ell_cost(pos, 8, 128).waste
    w_after = ell_cost(pos2, 8, 128).waste
    assert w_after <= w_before
    # combined ELL + COO tail reproduces SpMV exactly
    y_ell = np.asarray(ops.spmv(pos2, crd2, vals2, c, impl="xla"))
    y_tail = np.zeros(1500, np.float32)
    if tr.size:
        np.add.at(y_tail, tr, tv * c[tc])
    np.testing.assert_allclose(y_ell + y_tail, expected, atol=1e-3,
                               rtol=1e-3)
    # tail holds only heavy-row overflow
    if tr.size:
        deg = np.diff(pos)
        assert deg[np.unique(tr)].min() > deg.mean()


def test_tuner_cost_monotone_in_padding():
    B = uniform_sparse("B", (512, 512), 0.02, seed=4)
    pos = B.levels[1].pos
    r = tune_ell(pos)
    assert 0 <= r.waste < 1
    assert r.padded_nnz >= int(pos[-1])


def test_tuner_infeasible_fallback_is_explicit(caplog):
    """No candidate fits a tiny VMEM budget: the tuner still returns the
    smallest tile (callers always get a layout) but the fallback is
    surfaced — feasible=False, fallback=True, and a logged warning —
    instead of the old silent best=smallest-tile swap."""
    import logging
    B = uniform_sparse("B", (256, 256), 0.02, seed=5)
    pos = B.levels[1].pos
    with caplog.at_level(logging.WARNING, logger="repro.kernels.autotune"):
        r = tune_ell(pos, vmem_bytes=64)          # nothing fits 64 bytes
    assert not r.feasible and r.fallback
    from repro.kernels.autotune import DEFAULT_BLOCK_N, DEFAULT_BLOCK_R
    assert (r.block_r, r.block_n) == (min(DEFAULT_BLOCK_R),
                                      min(DEFAULT_BLOCK_N))
    assert any("fits VMEM" in rec.message for rec in caplog.records)
    # a feasible tune never sets the flag
    ok = tune_ell(pos)
    assert ok.feasible and not ok.fallback


def test_planner_skips_infeasible_tile():
    """plan_search: an infeasible blocked tune yields points with NO tile
    hint (the kernels keep their fallback shape) rather than pinning an
    over-VMEM layout."""
    from repro.core import plan_search as PS
    from repro.kernels.autotune import TuneResult

    bad = TuneResult(2, 8, 0, 0.0, 0.0, feasible=False, fallback=True)
    good = TuneResult(4, 16, 0, 0.0, 0.0, feasible=True)
    stats_bad = PS.StructStats(entries=10, n0=4, deg=np.ones(4, np.int64),
                               entry_elems=4, root_tracks_dim0=True,
                               tile=bad)
    stats_good = PS.StructStats(entries=10, n0=4, deg=np.ones(4, np.int64),
                                entry_elems=4, root_tracks_dim0=True,
                                tile=good)
    import repro.core as rc
    B = powerlaw_matrix("B", 32, 32, 4, seed=6)
    rng = np.random.default_rng(7)
    c = Tensor.from_dense("c", rng.standard_normal(32).astype(np.float32))
    stmt = rc.parse_tin("a(i) = B(i,j) * c(j)",
                        a=Tensor.zeros_dense("a", (32,)), B=B, c=c)
    m = rc.Machine(("x", 4))
    assert all(p.tile is None
               for p in PS.enumerate_points(stmt, m, stats_bad))
    assert all(p.tile == (4, 16)
               for p in PS.enumerate_points(stmt, m, stats_good))
