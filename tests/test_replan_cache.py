"""Re-plan fast path: fingerprinted shard/plan caches + compiled-runner
reuse (ISSUE 3). Covers the straggler-weighted block splits that nothing
drove before, LRU bounding/eviction, content-fingerprint invalidation
(new tensors AND in-place mutation), the per-lower hit/miss counters on
LoweredKernel, and the shard_map executable cache."""
import numpy as np
import pytest

import repro.core as rc
from repro.core import formats as F

from repro.core import partition as P
from repro.core.interp import interpret
from repro.core.lower import (clear_lowering_caches, default_nnz_schedule,
                              default_row_schedule, lower)
from repro.core.tensor import Tensor
from repro.runtime.fault import StragglerMitigator

# `repro.core.lower` is the MODULE again (the package used to rebind the
# name to the function; the function is re-exported as rc.lower_stmt).
import repro.core.lower as L
assert L is not lower, "package attr 'lower' should be the submodule"

N, M_COLS = 19, 13
M4 = rc.Machine(("x", 4))


def _sparse(rng, density=0.25):
    d = ((rng.random((N, M_COLS)) < density) *
         rng.standard_normal((N, M_COLS))).astype(np.float32)
    d[rng.integers(0, N)] = 0                                    # empty row
    return d


def _spmv_stmt(dB, fm, seed=1):
    rng = np.random.default_rng(seed)
    B = Tensor.from_dense("B", dB, fm)
    c = Tensor.from_dense("c", rng.standard_normal(M_COLS).astype(np.float32))
    return rc.parse_tin("a(i) = B(i,j) * c(j)",
                        a=Tensor.zeros_dense("a", (N,)), B=B, c=c)


# ---------------------------------------------------------------------------
# Satellite 1: straggler-weighted block splits, driven end-to-end
# ---------------------------------------------------------------------------

def test_weighted_block_nonzero_splits():
    """partition_tensor_block_nonzeros honors straggler weights: the slow
    shard owns proportionally fewer stored blocks."""
    rng = np.random.default_rng(3)
    B = Tensor.from_dense("B", _sparse(rng, 0.4), F.BCSR((2, 2)))
    mit = StragglerMitigator(4, report_budget=1)
    mit.report_slow(2)
    part = P.partition_tensor_block_nonzeros(B, 4, weights=mit.weights)
    counts = part.vals_bounds[:, 1] - part.vals_bounds[:, 0]
    assert counts.sum() == (B.levels[1].nnz or 0)    # all blocks covered
    assert counts[2] < counts[0]                     # slow shard gets less
    equal = P.partition_tensor_block_nonzeros(B, 4)
    eq_counts = equal.vals_bounds[:, 1] - equal.vals_bounds[:, 0]
    assert not np.array_equal(counts, eq_counts)


@pytest.mark.parametrize("expr", ["spmv", "spmm"])
def test_weighted_block_replan_matches_oracle(expr):
    """The re-plan path end-to-end: lower blocked/nnz, then re-lower with
    skewed per-piece weights — differentially checked against interp, with
    unchanged operands' shards reused across the re-plan."""
    rng = np.random.default_rng(7)
    dB = _sparse(rng, 0.4)
    B = Tensor.from_dense("B", dB, F.BCSR((2, 2)))
    if expr == "spmv":
        c = Tensor.from_dense(
            "c", rng.standard_normal(M_COLS).astype(np.float32))
        stmt = rc.parse_tin("a(i) = B(i,j) * c(j)",
                            a=Tensor.zeros_dense("a", (N,)), B=B, c=c)
    else:
        C = Tensor.from_dense(
            "C", rng.standard_normal((M_COLS, 7)).astype(np.float32))
        stmt = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                            A=Tensor.zeros_dense("A", (N, 7)), B=B, C=C)
    clear_lowering_caches()
    sched = default_nnz_schedule(stmt, M4)
    k0 = lower(stmt, M4, schedule=sched)
    np.testing.assert_allclose(k0.run(), interpret(stmt), atol=1e-3)
    mit = StragglerMitigator(4, report_budget=1)
    mit.report_slow(1)
    k1 = lower(stmt, M4, schedule=sched, weights=mit.weights)
    assert k1.leaf_name.startswith("bcsr_")
    # weights actually changed the stored-block split of B ...
    assert not np.array_equal(k0.plans["B"].vals_bounds,
                              k1.plans["B"].vals_bounds)
    # ... while the replicated co-operand's shards were reused
    assert k1.cache.shard_hits >= 1
    np.testing.assert_allclose(k1.run(), interpret(stmt), atol=1e-3)


# ---------------------------------------------------------------------------
# Satellite 2: bounded caches + per-lower hit/miss counters
# ---------------------------------------------------------------------------

def test_cache_hit_counters_on_kernel():
    """LoweredKernel.cache records this lower's plan/shard/runner reuse
    (alongside CommStats): cold = all misses, warm = all hits."""
    rng = np.random.default_rng(11)
    stmt = _spmv_stmt(_sparse(rng), F.CSR())
    clear_lowering_caches()
    k1 = lower(stmt, M4)
    assert k1.cache.plan_misses == 1 and k1.cache.plan_hits == 0
    assert k1.cache.shard_misses == 3          # B, c, and the dense output
    assert k1.cache.runner_misses == 1
    assert not k1.cache.warm
    k2 = lower(stmt, M4)
    assert k2.cache.warm
    assert (k2.cache.plan_hits, k2.cache.shard_hits,
            k2.cache.runner_hits) == (1, 3, 1)
    d = k2.cache.as_dict()
    assert d["shard_hits"] == 3 and d["runner_misses"] == 0
    np.testing.assert_allclose(k2.run(), k1.run(), atol=1e-5)


def test_lru_cache_none_value_hits():
    """A factory that returns None caches None: the old ``is not None``
    miss test rebuilt it on every call (and counted a miss each time).
    One miss, then hits — the tuned-plan cache stores None winners."""
    from repro.core.cache import LRUCache
    cache = LRUCache(capacity=4)
    calls = []

    def factory():
        calls.append(1)
        return None

    for _ in range(3):
        assert cache.get_or_build("k", factory) is None
    assert len(calls) == 1
    assert cache.stats["misses"] == 1 and cache.stats["hits"] == 2
    assert "k" in cache


def test_shard_cache_lru_eviction():
    """The shard cache is bounded: with a tiny cap, older entries evict
    (no unbounded growth — the latent bug of the old add-stream cache)
    and evicted entries re-materialize correctly."""
    old_cap = P.SHARD_CACHE.capacity
    rng = np.random.default_rng(13)
    stmts = [_spmv_stmt(_sparse(rng), F.CSR(), seed=s) for s in range(3)]
    try:
        clear_lowering_caches()
        P.set_shard_cache_capacity(2)
        ev0 = P.SHARD_CACHE_STATS["evictions"]
        results = [lower(s, M4).run() for s in stmts]
        assert len(P.SHARD_CACHE) <= 2
        assert P.SHARD_CACHE_STATS["evictions"] > ev0
        # evicted shards re-pack on demand, results unchanged
        again = lower(stmts[0], M4)
        assert again.cache.shard_misses >= 1
        np.testing.assert_allclose(again.run(), results[0], atol=1e-5)
    finally:
        P.set_shard_cache_capacity(old_cap)


def test_runner_cache_lru_eviction():
    old_cap = L._RUNNER_CACHE.capacity
    rng = np.random.default_rng(17)
    stmt = _spmv_stmt(_sparse(rng), F.CSR())
    try:
        clear_lowering_caches()
        L.set_runner_cache_capacity(1)
        ev0 = L.RUNNER_CACHE_STATS["evictions"]
        lower(stmt, M4)                                       # spmv runner
        lower(stmt, M4, schedule=default_nnz_schedule(stmt, M4))  # evicts it
        assert len(L._RUNNER_CACHE) == 1
        assert L.RUNNER_CACHE_STATS["evictions"] > ev0
        k = lower(stmt, M4)                   # re-jits the evicted runner
        assert k.cache.runner_misses == 1
        np.testing.assert_allclose(k.run(), interpret(stmt), atol=1e-4)
    finally:
        L.set_runner_cache_capacity(old_cap)


def test_plan_memo_differential():
    """A memoized plan is exactly the plan a fresh partitioning walk would
    produce (_plans_equal over every tensor)."""
    rng = np.random.default_rng(19)
    stmt = _spmv_stmt(_sparse(rng), F.DCSR())
    clear_lowering_caches()
    lower(stmt, M4)
    k_memo = lower(stmt, M4)
    assert k_memo.cache.plan_hits == 1
    clear_lowering_caches()
    k_fresh = lower(stmt, M4)
    assert set(k_memo.plans) == set(k_fresh.plans)
    for name in k_memo.plans:
        assert L._plans_equal(k_memo.plans[name], k_fresh.plans[name]), name


# ---------------------------------------------------------------------------
# Satellite 3: invalidation — same shape, different content must re-pack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt_name,fmt_ctor,strategy", [
    ("csr", F.CSR, "rows"),            # materialize_csr_rows
    ("csr", F.CSR, "nnz"),             # materialize_coo_nnz
    ("coo", lambda: F.COO(2), "nnz"),
    ("bcsr", lambda: F.BCSR((2, 2)), "rows"),   # materialize_bcsr_rows
    ("bcsr", lambda: F.BCSR((2, 2)), "nnz"),    # materialize_bcsr_nnz
], ids=["csr-rows", "csr-nnz", "coo-nnz", "bcsr-rows", "bcsr-nnz"])
def test_invalidation_value_change(fmt_name, fmt_ctor, strategy):
    """A NEW Tensor with the same shape/pattern but different values (new
    crc) must not reuse the stale shard — while untouched co-operands with
    identical content still hit."""
    rng = np.random.default_rng(23)
    dB = _sparse(rng)
    fm = fmt_ctor()
    stmt1 = _spmv_stmt(dB, fm, seed=29)
    sched = (default_row_schedule if strategy == "rows"
             else default_nnz_schedule)
    clear_lowering_caches()
    k1 = lower(stmt1, M4, schedule=sched(stmt1, M4))
    r1 = k1.run()
    np.testing.assert_allclose(r1, interpret(stmt1), atol=1e-3)
    stmt2 = _spmv_stmt(dB * 3.0, fm, seed=29)    # same c content (seed)
    k2 = lower(stmt2, M4, schedule=sched(stmt2, M4))
    assert k2.cache.shard_misses >= 1            # B re-packed, not stale
    assert k2.cache.shard_hits >= 1              # identical c reused
    r2 = k2.run()
    np.testing.assert_allclose(r2, interpret(stmt2), atol=1e-3)
    np.testing.assert_allclose(r2, 3.0 * np.asarray(r1), atol=1e-3)


def test_invalidation_dense_and_replicated():
    """Dense-row and replicated shards invalidate on content change too
    (spmm: C is replicated under rows, the output is dense rows)."""
    rng = np.random.default_rng(31)
    dB = _sparse(rng)
    dC = rng.standard_normal((M_COLS, 7)).astype(np.float32)

    def mk(dCmat):
        B = Tensor.from_dense("B", dB, F.CSR())
        C = Tensor.from_dense("C", dCmat)
        return rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                            A=Tensor.zeros_dense("A", (N, 7)), B=B, C=C)

    clear_lowering_caches()
    stmt1 = mk(dC)
    r1 = lower(stmt1, M4).run()
    stmt2 = mk(dC * -2.0)
    k2 = lower(stmt2, M4)
    assert k2.cache.shard_misses >= 1            # replicated C re-packed
    np.testing.assert_allclose(k2.run(), dB @ (dC * -2.0), atol=1e-3)
    np.testing.assert_allclose(r1, dB @ dC, atol=1e-3)


def test_invalidation_inplace_mutation():
    """In-place mutation of vals between lowers changes the CRC: no stale
    plan, shard, or result."""
    rng = np.random.default_rng(37)
    stmt = _spmv_stmt(_sparse(rng), F.CSR())
    B = stmt.rhs.accesses()[0].tensor
    clear_lowering_caches()
    r1 = lower(stmt, M4).run()
    B.vals[:] = B.vals * 5.0
    k2 = lower(stmt, M4)
    assert not k2.cache.warm and k2.cache.shard_misses >= 1
    np.testing.assert_allclose(k2.run(), 5.0 * np.asarray(r1), atol=1e-3)


def test_plan_cache_rebinds_current_tensors():
    """A memoized plan must not pin stale tensor objects: mutate the
    original tensor AFTER its plan is cached, then lower a FRESH tensor
    whose content equals the original — the plan-key hit must serve the
    fresh tensor's data, not the mutated original's."""
    rng = np.random.default_rng(47)
    dB = _sparse(rng)
    stmt1 = _spmv_stmt(dB, F.CSR(), seed=53)
    clear_lowering_caches()
    r1 = lower(stmt1, M4).run()
    B1 = stmt1.rhs.accesses()[0].tensor
    B1.vals[:] = B1.vals * -9.0          # corrupt the pinned object
    stmt2 = _spmv_stmt(dB, F.CSR(), seed=53)   # original content, new objects
    k2 = lower(stmt2, M4)
    assert k2.cache.plan_hits == 1       # key matches original content
    np.testing.assert_allclose(k2.run(), r1, atol=1e-5)
    np.testing.assert_allclose(k2.run(), interpret(stmt2), atol=1e-3)


def test_spadd3_weighted_replan_reslices_cached_stream():
    """spadd3/nnz with NEW straggler weights: the chunk shards miss (new
    bounds) but the concatenated stream is reused — and the weighted
    result still matches the oracle."""
    rng = np.random.default_rng(41)
    Bt = Tensor.from_dense("B", _sparse(rng), F.CSR())
    Ct = Tensor.from_dense("C", _sparse(rng, 0.15), F.CSR())
    Dt = Tensor.from_dense("D", _sparse(rng, 0.1), F.CSR())
    A = Tensor.from_dense("A", np.zeros((N, M_COLS), np.float32), F.CSR())
    stmt = rc.parse_tin("A(i,j) = B(i,j) + C(i,j) + D(i,j)",
                        A=A, B=Bt, C=Ct, D=Dt)
    sched = default_nnz_schedule(stmt, M4)
    clear_lowering_caches()
    lower(stmt, M4, schedule=sched)
    P.ADD_STREAM_STATS.update(hits=0, misses=0)
    src_hits0 = P.SHARD_CACHE_STATS["hits"]
    w = np.array([1.0, 1.0, 0.25, 1.0])
    k = lower(stmt, M4, schedule=sched, weights=w)
    assert P.ADD_STREAM_STATS["misses"] == 1     # new bounds: chunks re-cut
    assert P.SHARD_CACHE_STATS["hits"] > src_hits0   # stream itself reused
    counts = k.shards["_addstream"].arrays["nnz_count"]
    assert counts[2] < counts[0]                 # weighted chunks
    expected = Bt.to_dense() + Ct.to_dense() + Dt.to_dense()
    np.testing.assert_allclose(k.run().to_dense(), expected, atol=1e-4)


# ---------------------------------------------------------------------------
# shard_map executable reuse (distributed/executor.py)
# ---------------------------------------------------------------------------

def test_spmd_runner_cache_reuse():
    from repro.distributed import executor
    rng = np.random.default_rng(43)
    dB = _sparse(rng)
    stmt = _spmv_stmt(dB, F.CSR())
    machine = rc.Machine(("x", 1))        # single-device CPU mesh
    executor.clear_spmd_cache()
    k1 = lower(stmt, machine)
    y1 = executor.to_spmd(k1)()
    misses1 = executor.SPMD_RUN_STATS["misses"]
    k2 = lower(stmt, machine)             # warm re-lower ...
    y2 = executor.to_spmd(k2)()           # ... reuses the jitted shard_map
    assert executor.SPMD_RUN_STATS["misses"] == misses1
    assert executor.SPMD_RUN_STATS["hits"] >= 1
    np.testing.assert_allclose(y1, y2, atol=1e-6)
    cv = np.asarray(stmt.rhs.accesses()[1].tensor.to_dense())
    np.testing.assert_allclose(y1, dB @ cv, atol=1e-4)


# ---------------------------------------------------------------------------
# Converted-tensor cache (ISSUE 4 satellite): fallback cells stop paying
# to_format on every warm lower. Since the level-iterator refactor (ISSUE
# 5) every spellable conformance format lowers DIRECTLY — csc/coo3 went
# direct via the transpose / trailing-singleton walks — so the fallback
# machinery is pinned here on a format that still genuinely converts: a
# COMPRESSED-ROOT block grid, which no blocked partitioner walks.
# ---------------------------------------------------------------------------

def _bdcsr():
    """Blocked DCSR — compressed-root block grid, conversion fallback."""
    return F.Format((F.Compressed, F.Compressed), block_shape=(2, 2))


def test_direct_cells_never_convert():
    """csc/rows lowers DIRECTLY through the transpose walk now: no logged
    fallback, no convert-cache traffic."""
    rng = np.random.default_rng(17)
    stmt = _spmv_stmt(_sparse(rng), F.CSC())
    clear_lowering_caches()
    k = lower(stmt, M4, schedule=default_row_schedule(stmt, M4))
    assert k.fallbacks == []
    assert k.cache.convert_misses == 0 and k.cache.convert_hits == 0
    np.testing.assert_allclose(k.run(), interpret(stmt), atol=1e-4)


def test_convert_cache_warm_fallback_lower():
    """A compressed-root blocked cell converts B once; the warm re-lower
    reuses the converted tensor (convert_hits on CacheStats) and stays
    fully warm."""
    rng = np.random.default_rng(17)
    stmt = _spmv_stmt(_sparse(rng), _bdcsr())
    sched = default_row_schedule(stmt, M4)    # b[dcsr]: conversion fallback
    clear_lowering_caches()
    k1 = lower(stmt, M4, schedule=sched)
    assert k1.fallbacks and k1.cache.convert_misses == 1
    assert k1.cache.convert_hits == 0 and not k1.cache.warm
    k2 = lower(stmt, M4, schedule=sched)
    assert k2.fallbacks == k1.fallbacks       # census unchanged by caching
    assert k2.cache.convert_hits == 1 and k2.cache.convert_misses == 0
    assert k2.cache.warm                      # plan/shard/runner/convert hit
    d = k2.cache.as_dict()
    assert d["convert_hits"] == 1
    np.testing.assert_allclose(k2.run(), k1.run(), atol=1e-6)


def test_convert_cache_invalidation_on_mutation():
    """In-place mutation of the declared-format operand changes its CRC,
    so the conversion re-runs instead of serving a stale converted image."""
    rng = np.random.default_rng(18)
    stmt = _spmv_stmt(_sparse(rng), _bdcsr())
    sched = default_row_schedule(stmt, M4)
    clear_lowering_caches()
    k1 = lower(stmt, M4, schedule=sched)
    B = stmt.rhs.accesses()[0].tensor
    B.vals[:] = B.vals * 2.0
    k2 = lower(stmt, M4, schedule=sched)
    assert k2.cache.convert_misses == 1
    np.testing.assert_allclose(k2.run(), k1.run() * 2.0, atol=1e-5)
