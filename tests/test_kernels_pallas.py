"""Per-kernel Pallas validation: shape/dtype sweeps, interpret=True vs the
ref.py pure-jnp oracles (deliverable c)."""
import numpy as np
import pytest

from repro.core import formats as F
from repro.core.tensor import Tensor
from repro.kernels import ops

SHAPES_2D = [(8, 8), (37, 53), (64, 128), (130, 65), (1, 7), (256, 17)]
DENSITIES = [0.05, 0.3]
DTYPES = [np.float32]


def _csr(rng, n, m, density, dtype):
    dense = ((rng.random((n, m)) < density) *
             rng.standard_normal((n, m))).astype(dtype)
    t = Tensor.from_dense("B", dense, F.CSR())
    return t, dense


@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("density", DENSITIES)
def test_spmv_pallas_sweep(shape, density):
    rng = np.random.default_rng(hash(shape) % 2**31)
    n, m = shape
    t, dense = _csr(rng, n, m, density, np.float32)
    c = rng.standard_normal(m).astype(np.float32)
    pos, crd = t.levels[1].pos, t.levels[1].crd
    ref = np.asarray(ops.spmv(pos, crd, t.vals, c, impl="xla"))
    got = np.asarray(ops.spmv(pos, crd, t.vals, c, impl="pallas"))
    np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(ref, dense @ c, atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("shape", SHAPES_2D[:4])
def test_spmv_nnz_pallas_sweep(shape):
    rng = np.random.default_rng(1)
    n, m = shape
    t, dense = _csr(rng, n, m, 0.25, np.float32)
    pos, crd = t.levels[1].pos, t.levels[1].crd
    rows = np.repeat(np.arange(n, dtype=np.int32), np.diff(pos))
    c = rng.standard_normal(m).astype(np.float32)
    got = np.asarray(ops.spmv_nnz(rows, crd, t.vals, c, n_rows=n,
                                  impl="pallas"))
    np.testing.assert_allclose(got, dense @ c, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("shape", SHAPES_2D[:4])
@pytest.mark.parametrize("j", [1, 16, 130])
def test_spmm_pallas_sweep(shape, j):
    rng = np.random.default_rng(2)
    n, m = shape
    t, dense = _csr(rng, n, m, 0.2, np.float32)
    C = rng.standard_normal((m, j)).astype(np.float32)
    pos, crd = t.levels[1].pos, t.levels[1].crd
    got = np.asarray(ops.spmm(pos, crd, t.vals, C, impl="pallas"))
    np.testing.assert_allclose(got, dense @ C, atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("shape", SHAPES_2D[:4])
@pytest.mark.parametrize("K", [4, 32])
def test_sddmm_pallas_sweep(shape, K):
    rng = np.random.default_rng(3)
    n, m = shape
    t, dense = _csr(rng, n, m, 0.2, np.float32)
    pos, crd = t.levels[1].pos, t.levels[1].crd
    rows = np.repeat(np.arange(n, dtype=np.int32), np.diff(pos))
    C = rng.standard_normal((n, K)).astype(np.float32)
    D = rng.standard_normal((K, m)).astype(np.float32)
    got = np.asarray(ops.sddmm(rows, crd, t.vals, C, D, impl="pallas"))
    exp = t.vals * (C[rows] * D[:, crd].T).sum(1)
    np.testing.assert_allclose(got, exp, atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("shape", [(16, 24), (65, 40)])
def test_spadd3_pallas_sweep(shape):
    rng = np.random.default_rng(4)
    n, m = shape
    ts = []
    total = np.zeros((n, m), np.float32)
    for i in range(3):
        t, dense = _csr(rng, n, m, 0.1 + 0.05 * i, np.float32)
        ts.append((t.levels[1].pos, t.levels[1].crd, t.vals))
        total += dense
    got = np.asarray(ops.spadd3_dense(*ts, n_rows=n, n_cols=m,
                                      impl="pallas"))
    np.testing.assert_allclose(got, total, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dims", [(10, 8, 6), (25, 13, 9)])
def test_spttv_spmttkrp_pallas_sweep(dims):
    rng = np.random.default_rng(5)
    L = 6
    dB = ((rng.random(dims) < 0.15) *
          rng.standard_normal(dims)).astype(np.float32)
    t = Tensor.from_dense("B", dB, F.CSF(3))
    p1, c1 = t.levels[1].pos, t.levels[1].crd
    p2, c2 = t.levels[2].pos, t.levels[2].crd
    cv = rng.standard_normal(dims[2]).astype(np.float32)
    tv = np.asarray(ops.spttv(p1, c1, p2, c2, t.vals, cv, impl="pallas"))
    i_of_ij = np.repeat(np.arange(dims[0]), np.diff(p1))
    got = np.zeros(dims[:2], np.float32)
    got[i_of_ij, c1] = tv
    np.testing.assert_allclose(got, np.einsum("ijk,k->ij", dB, cv),
                               atol=1e-4, rtol=1e-4)

    C = rng.standard_normal((dims[1], L)).astype(np.float32)
    D = rng.standard_normal((dims[2], L)).astype(np.float32)
    mk = np.asarray(ops.spmttkrp(p1, c1, p2, c2, t.vals, C, D,
                                 impl="pallas"))
    np.testing.assert_allclose(mk, np.einsum("ijk,jl,kl->il", dB, C, D),
                               atol=1e-3, rtol=1e-3)


def test_ell_padding_waste_reported():
    from repro.kernels.layout import ell_pack
    rng = np.random.default_rng(6)
    t, _ = _csr(rng, 64, 64, 0.1, np.float32)
    blocks, = ell_pack(t.levels[1].pos, t.levels[1].crd, t.vals)
    assert 0.0 <= blocks.padding_waste() < 1.0
