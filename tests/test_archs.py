"""Per-assigned-architecture smoke tests (deliverable f): REDUCED config of
the same family, one forward + one train step on CPU, asserting output
shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, all_archs, get_arch
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_smoke_mesh
from repro.models.model import LM
from repro.optim.adamw import adamw_init

ARCHS = sorted(all_archs())
SMOKE_SHAPE = ShapeConfig("smoke", "train", seq_len=32, global_batch=2,
                          grad_accum=1)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_arch(arch).reduced()
    mesh = make_smoke_mesh()
    with mesh:
        lm = steps_mod.build_lm(cfg, mesh)
        params = lm.init_params(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                    cfg.vocab_size)
        fe = (jax.random.normal(jax.random.PRNGKey(2),
                                (2, cfg.frontend_tokens, cfg.d_model),
                                jnp.float32)
              if cfg.frontend != "none" else None)
        logits, aux = jax.jit(
            lambda p, t, f: lm.apply(p, t, f))(params, tokens, fe)
        S_out = 32 + (cfg.frontend_tokens
                      if (cfg.frontend != "none" and not cfg.is_encdec)
                      else 0)
        assert logits.shape == (2, S_out, cfg.vocab_padded())
        assert not np.any(np.isnan(np.asarray(logits, np.float32)))

        # one full train step (grads + AdamW update)
        fn, accum = steps_mod.make_train_step(lm, SMOKE_SHAPE, mesh)
        opt = adamw_init(params)
        args = [params, opt, tokens] + ([fe.astype(jnp.bfloat16)]
                                        if fe is not None else [])
        new_p, new_opt, metrics = jax.jit(fn)(*args)
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["gnorm"]))
        # params actually changed (exact compare: warmup lr is tiny)
        changed = any(
            not np.array_equal(np.asarray(a, np.float32),
                               np.asarray(b, np.float32))
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_p)))
        assert changed


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_arch(arch).reduced()
    mesh = make_smoke_mesh()
    with mesh:
        lm = steps_mod.build_lm(cfg, mesh)
        params = lm.init_params(jax.random.PRNGKey(0))
        cache = lm.init_cache(2, 64, src_len=cfg.frontend_tokens
                              if cfg.is_encdec else 0)
        token = jnp.array([3, 5], jnp.int32)
        logits, cache2 = jax.jit(
            lambda p, c, t: lm.decode_step(p, c, t))(params, cache, token)
        assert logits.shape == (2, cfg.vocab_padded())
        assert not np.any(np.isnan(np.asarray(logits, np.float32)))
        assert int(cache2["pos"][0]) == 1


@pytest.mark.parametrize("arch", ["llama3-8b", "zamba2-7b", "xlstm-125m",
                                  "olmoe-1b-7b", "seamless-m4t-medium"])
def test_decode_matches_forward(arch):
    """Teacher-forced forward == step-by-step decode (cache correctness).

    MoE capacity is raised so no tokens drop (forward and decode see
    different token counts, hence different drop sets otherwise)."""
    cfg = dataclasses.replace(get_arch(arch).reduced(), dtype="float32",
                              moe_capacity_factor=16.0)
    mesh = make_smoke_mesh()
    with mesh:
        lm = steps_mod.build_lm(cfg, mesh)
        params = lm.init_params(jax.random.PRNGKey(0))
        S = 12
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0,
                                    cfg.vocab_size)
        fe = (jax.random.normal(jax.random.PRNGKey(2),
                                (2, cfg.frontend_tokens, cfg.d_model),
                                jnp.float32)
              if cfg.is_encdec else None)
        full, _ = jax.jit(lambda p, t, f: lm.apply(p, t, f))(
            params, tokens, fe)
        cache = lm.init_cache(2, S, src_len=cfg.frontend_tokens
                              if cfg.is_encdec else 0)
        if cfg.is_encdec:
            # encode once, stash cross K/V in the cache
            enc = lm._run_encoder(params, fe.astype(lm.dtype), 0, "auto")
            ek, ev = [], []
            for g in range(lm.n_groups):
                cp = jax.tree.map(lambda t: t[g], params["cross"])
                k, v = lm._encode_kv(cp["attn"], enc)
                ek.append(k); ev.append(v)
            cache["enc_k"] = jnp.stack(ek)
            cache["enc_v"] = jnp.stack(ev)
        step = jax.jit(lambda p, c, t: lm.decode_step(p, c, t))
        errs = []
        for s in range(S):
            lg, cache = step(params, cache, tokens[:, s])
            errs.append(float(np.abs(
                np.asarray(lg, np.float32) -
                np.asarray(full[:, s], np.float32)).max()))
        assert max(errs) < 5e-2, errs


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10
    fams = {get_arch(a).family for a in ARCHS}
    assert fams == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


def test_param_counts_plausible():
    """Config sanity: parameter counts near the names' billions."""
    approx = {
        "llama3-8b": 8e9, "qwen3-14b": 14e9, "starcoder2-15b": 15e9,
        "internlm2-1.8b": 1.8e9, "llava-next-34b": 34e9,
        "olmoe-1b-7b": 6.9e9, "zamba2-7b": 7e9, "xlstm-125m": 0.125e9,
    }
    for name, expect in approx.items():
        got = get_arch(name).param_count()
        assert 0.4 * expect < got < 2.1 * expect, (name, got, expect)
