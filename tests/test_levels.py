"""Level-iterator invariants (ISSUE 5 property tests, hypothesis
stub–compatible): walks and transpose walks enumerate EXACTLY the stored
coordinates, the permutation round-trips values through Tensor.from_*,
block levels cover non-divisible shapes, and the per-level iteration
capabilities (children ranges, position counts) agree with the physical
pos/crd regions."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core as rc
from repro.core import formats as F
from repro.core.levels import (CompressedIter, DenseIter, SingletonIter,
                               tree_of)
from repro.core.tensor import Tensor

FORMATS_2D = [F.CSR, F.CSC, F.DCSR, lambda: F.COO(2)]
FORMATS_3D = [lambda: F.CSF(3), lambda: F.DCSF(3), lambda: F.COO(3)]


def _sparse(rng, shape, density=0.3):
    return ((rng.random(shape) < density) *
            rng.standard_normal(shape)).astype(np.float32)


# ---------------------------------------------------------------------------
# Invariant 1: walk() / row_walk() enumerate exactly the stored coordinates
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 30), m=st.integers(1, 30), seed=st.integers(0, 999),
       fi=st.integers(0, len(FORMATS_2D) - 1))
def test_walk_enumerates_stored_coordinates_2d(n, m, seed, fi):
    rng = np.random.default_rng(seed)
    dense = _sparse(rng, (n, m))
    t = Tensor.from_dense("B", dense, FORMATS_2D[fi]())
    tree = tree_of(t)
    w = tree.walk()
    expect = {tuple(c) for c in np.argwhere(dense != 0)}
    assert {tuple(c) for c in w.coords} == expect
    # walk is vals-aligned: coords[i] stores vals[perm[i]]
    assert np.array_equal(w.perm, np.arange(w.n))
    for (i, j), v in zip(w.coords, t.vals):
        assert dense[i, j] == v


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 30), m=st.integers(1, 30), seed=st.integers(0, 999),
       fi=st.integers(0, len(FORMATS_2D) - 1))
def test_row_walk_is_dimension_lexicographic(n, m, seed, fi):
    """row_walk visits (row, col) lexicographically for EVERY format; for
    column-major roots it is the transpose walk and perm round-trips the
    value region."""
    rng = np.random.default_rng(seed)
    dense = _sparse(rng, (n, m))
    t = Tensor.from_dense("B", dense, FORMATS_2D[fi]())
    tree = tree_of(t)
    w = tree.row_walk()
    lin = w.coords[:, 0].astype(np.int64) * m + w.coords[:, 1]
    assert np.array_equal(lin, np.sort(lin)), "row walk must be row-sorted"
    # perm maps walk position -> storage position of the same entry
    for k in range(w.n):
        i, j = w.coords[k]
        assert t.vals[w.perm[k]] == dense[i, j]
    assert w.ordered == (not tree.transposed)


@settings(max_examples=15, deadline=None)
@given(dims=st.sampled_from([(6, 5, 4), (9, 3, 7), (4, 4, 4)]),
       seed=st.integers(0, 999), fi=st.integers(0, len(FORMATS_3D) - 1))
def test_walk_enumerates_stored_coordinates_3d(dims, seed, fi):
    rng = np.random.default_rng(seed)
    dense = _sparse(rng, dims, 0.2)
    t = Tensor.from_dense("B", dense, FORMATS_3D[fi]())
    tree = tree_of(t)
    w = tree.walk()
    expect = {tuple(c) for c in np.argwhere(dense != 0)}
    assert {tuple(c) for c in w.coords} == expect
    assert tree.trailing_singletons == (fi == 2)          # COO(3)
    assert tree.grouped_middle == (fi != 2)


# ---------------------------------------------------------------------------
# Invariant 2: round-trip through Tensor.from_* via the walk
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 25), m=st.integers(1, 25), seed=st.integers(0, 999),
       fi=st.integers(0, len(FORMATS_2D) - 1))
def test_walk_roundtrips_through_from_coo(n, m, seed, fi):
    """Reassembling from the row walk's (coords, permuted vals) rebuilds a
    tensor with the same dense image — the walk loses nothing."""
    rng = np.random.default_rng(seed)
    dense = _sparse(rng, (n, m))
    t = Tensor.from_dense("B", dense, FORMATS_2D[fi]())
    w = tree_of(t).row_walk()
    rebuilt = Tensor.from_coo("B2", t.shape, w.coords, t.vals[w.perm],
                              t.format, dedupe=False)
    np.testing.assert_array_equal(rebuilt.to_dense(), dense)


# ---------------------------------------------------------------------------
# Invariant 3: block levels cover non-divisible shapes
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 30), m=st.integers(2, 30),
       br=st.integers(1, 4), bc=st.integers(1, 4),
       seed=st.integers(0, 999), col_major=st.booleans())
def test_block_walk_covers_nondivisible_shapes(n, m, br, bc, seed,
                                               col_major):
    """Blocked trees walk the BLOCK grid: every stored block coordinate is
    in range (boundary blocks included for non-divisible shapes), every
    nonzero of the dense image is covered by a stored block, and the walk
    aligns with the (nb, br, bc) tile axis."""
    rng = np.random.default_rng(seed)
    dense = _sparse(rng, (n, m))
    fm = (F.BCSC((br, bc)) if col_major else F.BCSR((br, bc)))
    t = Tensor.from_dense("B", dense, fm)
    tree = tree_of(t)
    assert tree.blocked and tree.transposed == col_major
    w = tree.row_walk()
    grid = (-(-n // br), -(-m // bc))
    assert (w.coords >= 0).all()
    assert (w.coords < np.asarray(grid)).all()
    covered = np.zeros(grid, bool)
    covered[w.coords[:, 0], w.coords[:, 1]] = True
    for i, j in np.argwhere(dense != 0):
        assert covered[i // br, j // bc], "nonzero outside any stored block"
    # tile alignment: block (bi, bj) at walk position k holds the dense
    # window it covers (clipped at the boundary)
    for k in range(w.n):
        bi, bj = w.coords[k]
        tile = t.vals[w.perm[k]]
        win = dense[bi * br: bi * br + br, bj * bc: bj * bc + bc]
        np.testing.assert_array_equal(tile[: win.shape[0], : win.shape[1]],
                                      win)
    np.testing.assert_array_equal(t.to_dense(), dense)


# ---------------------------------------------------------------------------
# Invariant 4: per-level iteration capabilities match the physical regions
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 20), m=st.integers(1, 20), seed=st.integers(0, 999))
def test_level_children_ranges_match_pos_regions(n, m, seed):
    rng = np.random.default_rng(seed)
    t = Tensor.from_dense("B", _sparse(rng, (n, m)), F.CSR())
    tree = tree_of(t)
    root, leaf = tree.levels
    assert isinstance(root, DenseIter) and isinstance(leaf, CompressedIter)
    assert root.coord_range() == (0, n)
    assert root.positions(1) == n
    assert leaf.positions(n) == t.nnz
    total = 0
    for r in range(n):
        lo, hi = leaf.children(r)
        assert lo == t.levels[1].pos[r] and hi == t.levels[1].pos[r + 1]
        total += hi - lo
    assert total == t.nnz


def test_singleton_levels_share_parent_positions():
    rng = np.random.default_rng(0)
    t = Tensor.from_dense("B", _sparse(rng, (5, 4, 3), 0.3), F.COO(3))
    tree = tree_of(t)
    assert isinstance(tree.levels[1], SingletonIter)
    assert isinstance(tree.levels[2], SingletonIter)
    assert tree.levels[1].positions(7) == 7           # shared position space
    assert tree.levels[1].children(3) == (3, 4)


def test_tree_predicates():
    rng = np.random.default_rng(1)
    d = _sparse(rng, (8, 6))
    assert not tree_of(Tensor.from_dense("B", d, F.CSR())).transposed
    assert tree_of(Tensor.from_dense("B", d, F.CSC())).transposed
    assert tree_of(Tensor.from_dense("B", d, F.CSC())).row_walk().n == \
        int((d != 0).sum())
    bt = tree_of(Tensor.from_dense("B", d, F.BCSC((2, 2))))
    assert bt.blocked and bt.transposed and bt.block_shape == (2, 2)
    # empty tensors walk to empty, not to an error
    e = tree_of(Tensor.from_dense("B", np.zeros((4, 4), np.float32),
                                  F.CSC()))
    assert e.row_walk().n == 0
