"""Cost-model-driven autoscheduler (ISSUE 6): the model's structural
decisions (skewed rows → nnz split, uniform rows → universe split), the
tuned-plan cache (warm re-lower skips the search, in-place mutation
re-searches), tile threading for blocked operands, and an
auto-vs-interpreter sweep over every conformance expression × format
family."""
import zlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import conformance
import repro.core as rc
from repro.core import formats as F
from repro.core import plan_search as PS
from repro.core.interp import interpret
from repro.core.lower import clear_lowering_caches, lower
from repro.core.tensor import Tensor

M4 = rc.Machine(("x", 4))
MODEL_ONLY = PS.SearchConfig(refine_top_k=0)


@pytest.fixture(autouse=True)
def _model_only_auto(monkeypatch):
    """Rank by the cost model alone in tests: on-device refinement on a
    shared CI box is timing noise, and cold-lowering the top-K candidates
    of every sweep cell would dominate the suite's runtime."""
    monkeypatch.setattr(PS, "DEFAULT_CONFIG", MODEL_ONLY)
    clear_lowering_caches()
    yield


# ---------------------------------------------------------------------------
# Structural inputs with a KNOWN right answer. Tall-skinny shapes keep the
# replicated co-operand small, so the ranking is decided by the structural
# terms under test (window imbalance vs the nnz scatter-merge penalty),
# not by communication volume.
# ---------------------------------------------------------------------------

def _spmv(B: Tensor):
    rng = np.random.default_rng(0)
    c = Tensor.from_dense(
        "c", rng.standard_normal(B.shape[1]).astype(np.float32))
    return rc.parse_tin("a(i) = B(i,j) * c(j)",
                        a=Tensor.zeros_dense("a", (B.shape[0],)), B=B, c=c)


def _skewed_csr(n=1000, m=100, heavy=100) -> Tensor:
    """First ``heavy`` rows fully dense, the rest one entry each: a
    row-degree head that every contiguous row window P puts on one piece."""
    rows = np.concatenate([np.repeat(np.arange(heavy), m),
                           np.arange(heavy, n)])
    cols = np.concatenate([np.tile(np.arange(m), heavy),
                           np.arange(n - heavy) % m])
    coords = np.stack([rows, cols], axis=1)
    vals = np.random.default_rng(2).standard_normal(
        rows.size).astype(np.float32)
    return Tensor.from_coo("B", (n, m), coords, vals, F.CSR())


def _uniform_csr(n=1000, m=100, deg=8) -> Tensor:
    """Exactly ``deg`` entries in every row: row windows are perfectly
    balanced, so the nnz split's output-merge penalty is pure overhead."""
    rows = np.repeat(np.arange(n), deg)
    cols = (np.tile(np.arange(deg), n) * (m // deg)) % m
    coords = np.stack([rows, cols], axis=1)
    vals = np.random.default_rng(3).standard_normal(
        rows.size).astype(np.float32)
    return Tensor.from_coo("B", (n, m), coords, vals, F.CSR())


@settings(max_examples=8, deadline=None)
@given(heavy=st.integers(40, 160))
def test_model_picks_nnz_on_skewed_rows(heavy):
    """Skewed row degrees: the padded max window makes every universe
    split (1-D and 2-D) memory-bound on the heavy piece; the balanced nnz
    split wins despite its output-merge penalty."""
    stmt = _spmv(_skewed_csr(heavy=heavy))
    w = PS.search(stmt, M4, config=MODEL_ONLY)
    assert w.space == "nnz" and w.grid == (4, 1)


@settings(max_examples=8, deadline=None)
@given(deg=st.integers(3, 16))
def test_model_picks_rows_on_uniform(deg):
    """Uniform row degrees: windows are balanced, so the nnz split's
    extra pass over the global output is pure loss — rows wins."""
    stmt = _spmv(_uniform_csr(deg=deg))
    w = PS.search(stmt, M4, config=MODEL_ONLY)
    assert w.space == "universe"


def test_estimates_rank_both_regimes():
    """The same model orders the full candidate list, not just the
    winner: nnz beats every universe point on skew and loses to the flat
    rows split on uniform."""
    skew = _spmv(_skewed_csr())
    stats = PS.structural_stats(skew)
    pts = PS.enumerate_points(skew, M4, stats)
    costs = {p.label: PS.estimate(skew, p, stats) for p in pts}
    assert costs["nnz/4x1"] < min(c for l, c in costs.items() if l != "nnz/4x1")
    uni = _spmv(_uniform_csr())
    stats = PS.structural_stats(uni)
    pts = PS.enumerate_points(uni, M4, stats)
    costs = {p.label: PS.estimate(uni, p, stats) for p in pts}
    assert costs["rows/4x1"] < costs["nnz/4x1"]


# ---------------------------------------------------------------------------
# The tuned-plan cache (mirrors test_replan_cache.py's plan-cache pins)
# ---------------------------------------------------------------------------

def _small_spmv(fm, seed=11):
    rng = np.random.default_rng(seed)
    d = ((rng.random((19, 13)) < 0.3) *
         rng.standard_normal((19, 13))).astype(np.float32)
    d[3] = 0                                                    # empty row
    B = Tensor.from_dense("B", d, fm)
    c = Tensor.from_dense("c", rng.standard_normal(13).astype(np.float32))
    return rc.parse_tin("a(i) = B(i,j) * c(j)",
                        a=Tensor.zeros_dense("a", (19,)), B=B, c=c)


def test_auto_cold_then_warm_skips_search(monkeypatch):
    """Cold lower(schedule="auto") searches (tuned_misses); the unchanged
    re-lower serves the memoized point WITHOUT calling search — pinned by
    making a second search a test failure."""
    stmt = _small_spmv(F.CSR())
    k1 = lower(stmt, M4, schedule="auto")
    assert k1.tuned is not None
    assert k1.cache.tuned_misses == 1 and k1.cache.tuned_hits == 0
    assert not k1.cache.warm
    np.testing.assert_allclose(k1.run(), interpret(stmt), atol=1e-3)
    monkeypatch.setattr(
        PS, "search",
        lambda *a, **kw: pytest.fail("warm re-lower must skip the search"))
    k2 = lower(stmt, M4, schedule="auto")
    assert k2.cache.tuned_hits == 1 and k2.cache.tuned_misses == 0
    assert k2.cache.warm
    assert k2.tuned is k1.tuned          # the memoized point itself
    np.testing.assert_allclose(k2.run(), k1.run(), atol=1e-5)


def test_auto_invalidates_on_inplace_mutation():
    """In-place mutation of vals changes the content fingerprint in the
    tuned key: the re-lower re-searches instead of serving a stale winner
    (mirror of test_invalidation_inplace_mutation)."""
    stmt = _small_spmv(F.CSR())
    B = stmt.rhs.accesses()[0].tensor
    k1 = lower(stmt, M4, schedule="auto")
    r1 = k1.run()
    B.vals[:] = B.vals * 5.0
    k2 = lower(stmt, M4, schedule="auto")
    assert k2.cache.tuned_misses == 1 and not k2.cache.warm
    np.testing.assert_allclose(k2.run(), 5.0 * np.asarray(r1), atol=1e-3)


def test_auto_blocked_operand_carries_tuned_tile():
    """Blocked formats: the winning point carries the autotuned Pallas
    (block_R, block_nb) group shape and the built schedule threads it to
    the strategy (what the ops-layer emitters consume)."""
    stmt = _small_spmv(F.BCSR((2, 2)))
    k = lower(stmt, M4, schedule="auto")
    assert k.tuned is not None and k.tuned.tile is not None
    assert k.strategy.tile == k.tuned.tile
    np.testing.assert_allclose(k.run(), interpret(stmt), atol=1e-3)


def test_auto_unknown_string_rejected():
    stmt = _small_spmv(F.CSR())
    with pytest.raises(ValueError, match="unknown schedule string"):
        lower(stmt, M4, schedule="fast")


def test_tuned_cache_capacity_bound():
    """The tuned-plan cache is a bounded LRU like every other cache."""
    old = PS._TUNED_PLAN_CACHE.capacity
    try:
        PS.set_tuned_plan_cache_capacity(1)
        ev0 = PS.TUNED_PLAN_CACHE_STATS["evictions"]
        for seed in (11, 12, 13):
            lower(_small_spmv(F.CSR(), seed=seed), M4, schedule="auto")
        assert len(PS._TUNED_PLAN_CACHE) <= 1
        assert PS.TUNED_PLAN_CACHE_STATS["evictions"] > ev0
    finally:
        PS.set_tuned_plan_cache_capacity(old)


# ---------------------------------------------------------------------------
# Auto × the conformance matrix: every expression × format family must
# lower through schedule="auto" and match the interpreter oracle.
# ---------------------------------------------------------------------------

def _check_auto_cell(expr, fmt_name, fmt_ctor):
    rng = np.random.default_rng(
        zlib.crc32(f"auto/{expr}/{fmt_name}".encode()))
    stmt = conformance._build_stmt(expr, fmt_ctor(), rng)
    clear_lowering_caches()
    k = lower(stmt, M4, schedule="auto")
    assert k.tuned is not None, f"auto cell {expr}/{fmt_name} unplanned"
    result = k.run()
    got = result.to_dense() if isinstance(result, Tensor) else result
    np.testing.assert_allclose(got, interpret(stmt), atol=1e-3,
                               err_msg=f"auto cell {k.cell_id()}")


@pytest.mark.parametrize("fmt_name,fmt_ctor", conformance.FORMATS_2D,
                         ids=[f[0] for f in conformance.FORMATS_2D])
@pytest.mark.parametrize("expr", conformance.EXPRESSIONS_2D)
def test_auto_matrix_2d(expr, fmt_name, fmt_ctor):
    _check_auto_cell(expr, fmt_name, fmt_ctor)


@pytest.mark.parametrize("fmt_name,fmt_ctor", conformance.FORMATS_3D,
                         ids=[f[0] for f in conformance.FORMATS_3D])
@pytest.mark.parametrize("expr", conformance.EXPRESSIONS_3D)
def test_auto_matrix_3d(expr, fmt_name, fmt_ctor):
    _check_auto_cell(expr, fmt_name, fmt_ctor)


# ---------------------------------------------------------------------------
# ISSUE 7: replicated candidates + canonical-key dedupe
# ---------------------------------------------------------------------------

def _wide_spmm(n=200, m=200, J=64, density=0.02, seed=0):
    """|A|·Q > |B|: many output columns over a sparse-ish operand — the
    regime where replicating B along z beats every 2-D factorization."""
    rng = np.random.default_rng(seed)
    dB = ((rng.random((n, m)) < density) *
          rng.standard_normal((n, m))).astype(np.float32)
    B = Tensor.from_dense("B", dB, F.CSR())
    C = Tensor.from_dense("C", rng.standard_normal((m, J)).astype(np.float32))
    return rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                        A=Tensor.zeros_dense("A", (n, J)), B=B, C=C), dB, dC_ref(C)


def dC_ref(C):
    return np.asarray(C.to_dense())


def test_enumeration_dedupes_canonical_plans():
    """Degenerate factorizations that coincide with a lower-order plan
    (P×1 grids, z-depth-1 replication) must be enumerated ONCE — refine
    would otherwise time the same executable twice."""
    stmt, _, _ = _wide_spmm()
    M8 = rc.Machine(("x", 8))
    pts = PS.enumerate_points(stmt, M8, PS.structural_stats(stmt))
    keys = [p.plan_key for p in pts]
    assert len(keys) == len(set(keys)), "duplicate canonical plans enumerated"
    labels = {p.label for p in pts}
    # replicated triples present, every depth a genuine replication
    assert any(p.replicated for p in pts)
    for p in pts:
        if p.replicated:
            assert p.grid[2] >= 2
    # the flat candidates keep their pinned labels
    assert {"rows/8x1", "nnz/8x1"} <= labels


def test_replicated_point_label_and_machine():
    p = PS.SchedulePoint("universe", (2, 2, 2), None, replicated=True)
    assert p.label == "rows/2x2x2r"
    m = p.machine_for(rc.Machine(("x", 8)))
    assert [(d.name, d.size) for d in m.dims] == \
        [("x", 2), ("y", 2), ("z", 2)]
    # canonical stripping: a trailing singleton z IS the 2-D plan
    q = PS.SchedulePoint("universe", (4, 2, 1), None)
    assert q.plan_key == PS.SchedulePoint("universe", (4, 2), None).plan_key


def test_auto_picks_replicated_when_bytes_favor_it():
    """Acceptance: on the wide-output SpMM the byte model must rank a
    2.5-D replicated point first and lower(schedule='auto') must run it."""
    stmt, dB, dC = _wide_spmm()
    M8 = rc.Machine(("x", 8))
    winner = PS.search(stmt, M8, config=MODEL_ONLY)
    assert winner is not None and winner.replicated, winner.label
    clear_lowering_caches()
    k = lower(stmt, M8, schedule="auto")
    assert k.tuned is not None and k.tuned.replicated
    assert k.leaf_name == "spmm_grid_rep_rows"
    assert k.strategy.mesh_label.endswith("r")
    np.testing.assert_allclose(np.asarray(k.run()), dB @ dC, atol=1e-3)


def test_auto_still_picks_nnz_on_skewed_rows_with_replication_enabled():
    """The replicated candidates must not mask the structural nnz win:
    a skewed SpMM's row windows stay imbalanced under every universe
    factorization (replicated included)."""
    B = _skewed_csr()
    rng = np.random.default_rng(5)
    C = Tensor.from_dense(
        "C", rng.standard_normal((B.shape[1], 4)).astype(np.float32))
    stmt = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                        A=Tensor.zeros_dense("A", (B.shape[0], 4)), B=B, C=C)
    winner = PS.search(stmt, M4, config=MODEL_ONLY)
    assert winner is not None and winner.space == "nnz", winner.label
