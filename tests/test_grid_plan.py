"""Grid factorization invariants (ISSUE 4 property tests, hypothesis
stub–compatible): any P×Q GridPlan tiling covers the universe exactly
once, per-tile pos/crd rebasing round-trips, and 2-D cells agree with
their pieces-equal Px1 counterparts bit-for-bit on deterministic
(integer-valued, hence fp32-exact) inputs."""
import numpy as np
from hypothesis import given, settings, strategies as st

import repro.core as rc
from repro.core import formats as F
from repro.core.grid import GridPlan, compute_grid_plan
from repro.core.lower import (default_grid_nnz_schedule,
                              default_grid_schedule, default_nnz_schedule,
                              default_row_schedule, lower)
from repro.core.partition import (materialize_bcsr_grid,
                                  materialize_csr_grid,
                                  partition_by_bounds,
                                  partition_tensor_grid)
from repro.core.tensor import Tensor


def _int_sparse(rng, n, m, density=0.3):
    """Integer-valued sparse matrix: all partial sums are exact in fp32,
    so differently-ordered reductions must agree BIT FOR BIT."""
    return (rng.integers(-3, 4, (n, m)) *
            (rng.random((n, m)) < density)).astype(np.float32)


def _grid_plan_for(n, m, P, Q):
    return GridPlan(axis_x="x", axis_y="y",
                    row_bounds=partition_by_bounds(n, P),
                    col_bounds=partition_by_bounds(m, Q))


# ---------------------------------------------------------------------------
# Invariant 1: the P×Q tiles cover [0, n) × [0, m) exactly once
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 50), m=st.integers(1, 50),
       P=st.integers(1, 5), Q=st.integers(1, 5))
def test_tiling_covers_universe_exactly_once(n, m, P, Q):
    gp = _grid_plan_for(n, m, P, Q)
    gp.validate(n, m)                       # windows sorted/disjoint/gapless
    hits = np.zeros((n, m), dtype=np.int64)
    for _, _, (rlo, rhi), (clo, chi) in gp.tile_windows():
        hits[rlo:rhi, clo:chi] += 1
    assert (hits == 1).all(), "grid tiles must partition the universe"


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 40), m=st.integers(2, 40),
       P=st.integers(1, 4), Q=st.integers(1, 4), seed=st.integers(0, 999))
def test_blocked_grid_plan_covers_universe(n, m, P, Q, seed):
    """Block-aligned grid plans (computed through the real planner) still
    tile the universe exactly once, block snapping included."""
    rng = np.random.default_rng(seed)
    B = Tensor.from_dense("B", _int_sparse(rng, n, m), F.BCSR((2, 2)))
    c = Tensor.from_dense("c", rng.standard_normal(m).astype(np.float32))
    stmt = rc.parse_tin("a(i) = B(i,j) * c(j)",
                        a=Tensor.zeros_dense("a", (n,)), B=B, c=c)
    machine = rc.Machine(("x", P), ("y", Q))
    strat = default_grid_schedule(stmt, machine).strategy()
    gp = compute_grid_plan(stmt, strat)
    gp.validate(n, m)
    hits = np.zeros((n, m), dtype=np.int64)
    for _, _, (rlo, rhi), (clo, chi) in gp.tile_windows():
        hits[rlo:rhi, clo:chi] += 1
    assert (hits == 1).all()


# ---------------------------------------------------------------------------
# Invariant 2: per-tile pos/crd rebasing round-trips
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(P=st.integers(1, 4), Q=st.integers(1, 4), seed=st.integers(0, 999))
def test_csr_grid_rebase_roundtrip(P, Q, seed):
    rng = np.random.default_rng(seed)
    n, m = 23, 17
    dB = _int_sparse(rng, n, m)
    B = Tensor.from_dense("B", dB, F.CSR())
    part = partition_tensor_grid(B, partition_by_bounds(n, P),
                                 partition_by_bounds(m, Q))
    sh = materialize_csr_grid(B, part)
    a = sh.arrays
    got = np.zeros((n, m), np.float32)
    for color in range(P * Q):
        p, q = divmod(color, Q)
        rlo = int(a["row_start"][p])
        clo = int(a["col_start"][q])
        pos = a["pos1"][color].astype(np.int64)
        k = int(a["nnz_count"][color])
        rows = np.repeat(np.arange(pos.shape[0] - 1), np.diff(pos))[:k]
        got[rows + rlo, a["crd1"][color, :k] + clo] += a["vals"][color, :k]
        # val_idx maps tile entries back to their global value positions
        np.testing.assert_array_equal(
            a["vals"][color, :k], B.vals[a["val_idx"][color, :k]])
    np.testing.assert_array_equal(got, dB)


@settings(max_examples=10, deadline=None)
@given(P=st.integers(1, 3), Q=st.integers(1, 3), seed=st.integers(0, 999))
def test_bcsr_grid_rebase_roundtrip(P, Q, seed):
    rng = np.random.default_rng(seed)
    n, m = 22, 18
    dB = _int_sparse(rng, n, m)
    B = Tensor.from_dense("B", dB, F.BCSR((2, 2)))
    from repro.core.partition import block_aligned_row_bounds
    part = partition_tensor_grid(B, block_aligned_row_bounds(n, P, 2),
                                 block_aligned_row_bounds(m, Q, 2))
    sh = materialize_bcsr_grid(B, part)
    a = sh.arrays
    got = np.zeros((-(-n // 2) * 2, -(-m // 2) * 2), np.float32)
    for color in range(P * Q):
        p, q = divmod(color, Q)
        blo = int(a["brow_start"][p])
        cblo = int(a["bcol_start"][q])
        pos = a["pos1"][color].astype(np.int64)
        k = int(a["nnz_count"][color])
        brows = np.repeat(np.arange(pos.shape[0] - 1), np.diff(pos))[:k]
        for e in range(k):
            r0 = (brows[e] + blo) * 2
            c0 = (int(a["crd1"][color, e]) + cblo) * 2
            got[r0: r0 + 2, c0: c0 + 2] += a["vals"][color, e]
    np.testing.assert_array_equal(got[:n, :m], dB)


# ---------------------------------------------------------------------------
# Invariant 3: 2-D cells == pieces-equal Px1 counterparts, bit for bit
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(fmt=st.sampled_from(["csr", "bcsr"]),
       strategy=st.sampled_from(["rows", "nnz"]),
       seed=st.integers(0, 99))
def test_grid_matches_flat_counterpart_bitwise(fmt, strategy, seed):
    """A 2x2 SpMM cell and its pieces-equal 4x1 counterpart accumulate in
    different orders; on integer-valued inputs every fp32 sum is exact, so
    the results must be IDENTICAL, not just close."""
    rng = np.random.default_rng(seed)
    n, m, J = 19, 13, 7
    fm = F.CSR() if fmt == "csr" else F.BCSR((2, 2))
    B = Tensor.from_dense("B", _int_sparse(rng, n, m), fm)
    C = Tensor.from_dense("C", rng.integers(-3, 4, (m, J)).astype(np.float32))
    stmt = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                        A=Tensor.zeros_dense("A", (n, J)), B=B, C=C)
    M22 = rc.Machine(("x", 2), ("y", 2))
    M4 = rc.Machine(("x", 4))
    if strategy == "rows":
        kg = lower(stmt, M22, schedule=default_grid_schedule(stmt, M22))
        k1 = lower(stmt, M4, schedule=default_row_schedule(stmt, M4))
    else:
        kg = lower(stmt, M22, schedule=default_grid_nnz_schedule(stmt, M22))
        k1 = lower(stmt, M4, schedule=default_nnz_schedule(stmt, M4))
    np.testing.assert_array_equal(kg.run(), k1.run())


def test_grid_q1_equals_1d_path():
    """A (P, 1) grid degenerates to the 1-D row distribution exactly —
    same windows, same leaves modulo the q axis."""
    rng = np.random.default_rng(3)
    n, m = 19, 13
    B = Tensor.from_dense("B", _int_sparse(rng, n, m), F.CSR())
    c = Tensor.from_dense("c", rng.integers(-3, 4, m).astype(np.float32))
    stmt = rc.parse_tin("a(i) = B(i,j) * c(j)",
                        a=Tensor.zeros_dense("a", (n,)), B=B, c=c)
    M21 = rc.Machine(("x", 2), ("y", 1))
    M2 = rc.Machine(("x", 2))
    kg = lower(stmt, M21, schedule=default_grid_schedule(stmt, M21))
    k1 = lower(stmt, M2, schedule=default_row_schedule(stmt, M2))
    np.testing.assert_array_equal(kg.run(), k1.run())


# ---------------------------------------------------------------------------
# Per-axis communication: the SUMMA win
# ---------------------------------------------------------------------------

def test_2d_spmm_moves_fewer_bytes_than_1d():
    """At equal piece count, 2-D SpMM moves |C|(P-1) + |A|(Q-1) bytes vs
    1-D's |C|(PQ-1) — strictly fewer, attributed per axis."""
    rng = np.random.default_rng(5)
    n, m, J = 48, 40, 16
    B = Tensor.from_dense("B", _int_sparse(rng, n, m), F.CSR())
    C = Tensor.from_dense("C", rng.standard_normal((m, J)).astype(np.float32))
    stmt = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                        A=Tensor.zeros_dense("A", (n, J)), B=B, C=C)
    M22 = rc.Machine(("x", 2), ("y", 2))
    M4 = rc.Machine(("x", 4))
    kg = lower(stmt, M22, schedule=default_grid_schedule(stmt, M22))
    k1 = lower(stmt, M4, schedule=default_row_schedule(stmt, M4))
    assert kg.comm.pieces == k1.comm.pieces == 4
    assert kg.comm.total_network_bytes() < k1.comm.total_network_bytes()
    # C's k-windows broadcast along x; output partials reduce along y only
    assert kg.comm.axes["x"].broadcast_bytes > 0
    assert kg.comm.axes["x"].reduce_bytes == 0
    assert kg.comm.axes["y"].reduce_bytes > 0
    cm = kg.comm.as_dict()
    assert cm["axes"]["x"]["network_bytes"] + \
        cm["axes"]["y"]["network_bytes"] == cm["total_network_bytes"]


def test_grid_nnz_comm_attribution_totals_match_flat():
    """Grid nnz re-attributes the hierarchical broadcast/reduce to the
    axes without changing the total (b*(PQ-1))."""
    rng = np.random.default_rng(6)
    n, m = 19, 13
    B = Tensor.from_dense("B", _int_sparse(rng, n, m), F.CSR())
    c = Tensor.from_dense("c", rng.standard_normal(m).astype(np.float32))
    stmt = rc.parse_tin("a(i) = B(i,j) * c(j)",
                        a=Tensor.zeros_dense("a", (n,)), B=B, c=c)
    M22 = rc.Machine(("x", 2), ("y", 2))
    M4 = rc.Machine(("x", 4))
    kg = lower(stmt, M22, schedule=default_grid_nnz_schedule(stmt, M22))
    k1 = lower(stmt, M4, schedule=default_nnz_schedule(stmt, M4))
    assert kg.comm.replicate_bytes == 0 and kg.comm.reduce_bytes == 0
    assert kg.comm.total_network_bytes() == k1.comm.total_network_bytes()
